"""In-process hier/shared/naive collective equivalence over the topology
matrix, driven through the ``repro.comm.Communicator`` scheme dispatch.

Every check is parameterized over ``repro.substrate.default_matrix()``:
single node (1x8), the seed shape (2x4), its transpose (4x2), one chip per
pod (8x1 — bridge-only, the paper's worst case), and a tuple-axis mesh
(pod x (dp, tp)).  ``tests/conftest.py`` forces 8 host CPU devices before
jax initializes, so all of this runs in the main pytest process.
"""

import numpy as np
import pytest

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.comm import Communicator, primitives
from repro.core.plans import GatherPlan, NodeMap
from repro.substrate import VirtualCluster, default_matrix

MATRIX = default_matrix()


@pytest.fixture(params=MATRIX, ids=[t.label for t in MATRIX])
def vc(request) -> VirtualCluster:
    cluster = request.param
    if not cluster.available():
        pytest.skip(f"needs {cluster.num_devices} devices")
    return cluster


@pytest.fixture
def comm(vc) -> Communicator:
    return Communicator.from_cluster(vc)


# ---------------------------------------------------------------------------
# Allgather (paper §4.1)
# ---------------------------------------------------------------------------

def test_allgather_full_replication_matches_input(vc, comm):
    x = vc.rank_major_input()
    for scheme in ("naive", "hier", "pipelined"):
        out = vc.run(lambda v, s=scheme: comm.allgather(v, scheme=s),
                     x, out_specs=P(None))
        np.testing.assert_allclose(out, np.asarray(x))


def test_shared_allgather_is_one_copy_per_pod(vc, comm):
    x = vc.rank_major_input()
    m = x.shape[0] // vc.num_devices

    # chip (p, i) ends with shard i of the pod's single copy: contributions
    # of chip i of EVERY pod, pod-major.
    shards = vc.run(lambda v: comm.allgather(v, scheme="shared").shard, x)
    xs = np.asarray(x).reshape(vc.pods, vc.chips, m, -1)
    got = np.asarray(shards).reshape(vc.pods, vc.chips, vc.pods * m, -1)
    for p in range(vc.pods):
        for i in range(vc.chips):
            want = np.concatenate([xs[q, i] for q in range(vc.pods)], axis=0)
            np.testing.assert_allclose(got[p, i], want)


def test_shared_window_read_rank_order_roundtrip(vc, comm):
    x = vc.rank_major_input()
    full = vc.run(
        lambda v: comm.allgather(v, scheme="shared").read_rank_order(),
        x, out_specs=P(None))
    np.testing.assert_allclose(full, np.asarray(x))


# ---------------------------------------------------------------------------
# Broadcast (paper §4.2)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("root_kind", ["leader", "nonzero"])
def test_broadcast_matches_across_schemes(vc, comm, root_kind):
    """Every scheme must deliver the root's message; non-leader roots
    exercise the flat SMP-rank numbering on every scheme."""
    rng = np.random.default_rng(1)
    msg = rng.normal(size=(vc.num_devices, 8, 2)).astype(np.float32)
    x = jnp.asarray(msg)
    root = 0 if root_kind == "leader" else vc.num_devices - 2
    want = np.broadcast_to(msg[root], msg.shape)

    for scheme in ("naive", "hier", "pipelined"):
        out = vc.run(lambda v, s=scheme: comm.broadcast(
            v[0], root=root, scheme=s)[None], x)
        np.testing.assert_allclose(np.asarray(out), want, rtol=1e-6)

    # shared: each chip holds shard i of the root's message; reading gives it
    full = vc.run(lambda v: comm.broadcast(
        v[0], root=root, scheme="shared").read()[None], x)
    np.testing.assert_allclose(np.asarray(full), want, rtol=1e-6)


def test_broadcast_root_pod_alias_removed(vc):
    """The deprecated ``root_pod=`` alias is GONE (its one-release window
    closed): the primitive rejects it as an unknown kwarg, and the flat
    ``root = pod * chips`` spelling addresses the pod leader."""
    rng = np.random.default_rng(10)
    msg = rng.normal(size=(vc.num_devices, 4)).astype(np.float32)
    x = jnp.asarray(msg)
    pod = vc.pods - 1

    comm = Communicator.from_cluster(vc)
    got = vc.run(lambda v: comm.broadcast(
        v[0], root=pod * vc.chips, scheme="hier")[None], x)
    want = np.broadcast_to(msg[pod * vc.chips], msg.shape)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6)

    with pytest.raises(TypeError, match="root_pod"):
        primitives.hier_broadcast(jnp.zeros(4), root_pod=0,
                                  fast_axis=vc.fast, slow_axis=vc.slow)


def test_broadcast_out_of_range_root_rejected(vc, comm):
    """An out-of-range flat root must raise, not silently broadcast the
    wrong rank (or zeros)."""
    with pytest.raises(ValueError, match="out of range"):
        vc.run(lambda v: comm.broadcast(
            v[0], root=vc.num_devices, scheme="hier")[None],
            jnp.zeros((vc.num_devices, 4)))
    with pytest.raises(ValueError, match="out of range"):
        vc.run(lambda v: comm.broadcast(
            v[0], root=-1, scheme="shared").shard[None],
            jnp.zeros((vc.num_devices, 8)))


def test_fsdp_helpers_accept_list_axis(vc):
    """Regression: ``fsdp_gather``/``fsdp_scatter`` normalized the axis
    with ``isinstance(..., tuple)`` only, silently breaking the list
    spelling that ``_axes`` accepts everywhere else."""
    from repro.core import shared_buffer as sb

    x = vc.rank_major_input(m=2)
    fast_list = list(vc.fast_names)          # a LIST, the broken path
    out_spec = P(vc.slow) if vc.pods > 1 else P(None)
    full = vc.run(lambda v: sb.fsdp_gather(v, 0, fast_list), x,
                  out_specs=out_spec)
    np.testing.assert_allclose(np.asarray(full), np.asarray(x))

    # gather -> scatter roundtrip: the reduce-scatter of chips identical
    # replicas returns chips * the original shard
    rt = vc.run(lambda v: sb.fsdp_scatter(
        sb.fsdp_gather(v, 0, fast_list), 0, fast_list), x)
    np.testing.assert_allclose(np.asarray(rt), vc.chips * np.asarray(x),
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# Allreduce / reduce-scatter
# ---------------------------------------------------------------------------

def test_psum_schemes_agree(vc, comm):
    # m=16: tiles by chips (up to 8) AND the pipelined default n_chunks=2
    x = vc.rank_major_input(m=16, extra=4, seed=2)
    m = x.shape[0] // vc.num_devices
    want = np.asarray(x).reshape(vc.num_devices, m, -1).sum(0)

    for scheme in ("naive", "hier", "pipelined"):
        out = vc.run(lambda v, s=scheme: comm.allreduce(v, scheme=s),
                     x, out_specs=P(None))
        np.testing.assert_allclose(np.asarray(out)[:m], want, rtol=1e-5)

    shared = vc.run(lambda v: comm.allreduce(v, scheme="shared").read(),
                    x, out_specs=P(None))
    np.testing.assert_allclose(np.asarray(shared)[:m], want, rtol=1e-5)


@pytest.mark.parametrize("scheme", ["naive", "pipelined"])
def test_reduce_scatter_flat_slices(vc, comm, scheme):
    """naive/pipelined reduce_scatter: rank r ends with the r-th flat slice
    of the global sum (rank-major)."""
    R = vc.num_devices
    m = 4 * R
    x = jnp.arange(R * m, dtype=jnp.float32).reshape(R, m) / (R * m)
    want = np.asarray(x).sum(0)
    out = vc.run(lambda v: comm.reduce_scatter(v[0], scheme=scheme), x,
                 in_specs=(vc.spec,), out_specs=P(vc.axis_names))
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-5)


# ---------------------------------------------------------------------------
# All-to-all (flat vs node-aware two-phase)
# ---------------------------------------------------------------------------

def test_alltoall_schemes_agree(vc, comm):
    """The node-aware two-phase all-to-all must equal the flat exchange:
    rank r ends with chunk r of every rank, source-rank ordered."""
    R, e = vc.num_devices, 3
    x = jnp.arange(R * R * e, dtype=jnp.float32)
    want = np.arange(R * R * e, dtype=np.float32) \
        .reshape(R, R, e).transpose(1, 0, 2).reshape(R, -1)
    for scheme in ("naive", "hier"):
        out = vc.run(lambda v, s=scheme: comm.alltoall(v, scheme=s), x)
        np.testing.assert_allclose(np.asarray(out).reshape(R, -1), want)


# ---------------------------------------------------------------------------
# scheme="auto": bit-identical to the concrete scheme it resolves to
# ---------------------------------------------------------------------------

def test_auto_is_bit_identical_to_the_resolved_scheme(vc, comm):
    """Auto dispatch is a trace-time table lookup, NOT a different lowering:
    for every op family, ``scheme="auto"`` must produce bitwise the same
    result as naming the resolved scheme (with its resolved opts)
    explicitly.  Runs under whatever table is active (committed or empty),
    so both the measured and the modeled resolution paths stay covered."""
    from jax.sharding import PartitionSpec
    from repro.comm import SharedWindow, registry, tuning

    R = vc.num_devices
    e = R * 8                          # tiles every scheme's divisor (nc<=8)
    rng = np.random.default_rng(11)

    def raw(o):
        return o.shard if isinstance(o, SharedWindow) else o

    def specs(res, repl_spec, shared_spec):
        repl = registry.get_scheme(res.scheme).result_class == "replicated"
        return repl_spec if repl else shared_spec

    def run_pair(family, body, x, in_specs, repl_spec, shared_spec,
                 elems):
        res = tuning.resolve_for(comm, family, elems=elems)
        out_specs = specs(res, repl_spec, shared_spec)
        got = vc.run(lambda *a: body(*a, scheme="auto", opts={}),
                     *x, in_specs=in_specs, out_specs=out_specs)
        want = vc.run(lambda *a: body(*a, scheme=res.scheme, opts=res.opts),
                      *x, in_specs=in_specs, out_specs=out_specs)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=f"{family} ({res.scheme})")
        return res

    x1 = jnp.asarray(rng.normal(size=(R * 4, 2)).astype(np.float32))
    run_pair("allgather",
             lambda v, *, scheme, opts: raw(comm.allgather(v, scheme=scheme,
                                                           **opts)),
             (x1,), (vc.spec,), PartitionSpec(None), vc.spec, elems=8)

    xr = jnp.asarray(rng.normal(size=(R, e)).astype(np.float32) / R)
    run_pair("broadcast",
             lambda v, *, scheme, opts: raw(comm.broadcast(
                 v[0], root=R // 2, scheme=scheme, **opts))[None],
             (xr,), (vc.spec,), PartitionSpec(None), P(None, vc.fast),
             elems=e)
    run_pair("psum",
             lambda v, *, scheme, opts: raw(comm.allreduce(
                 v[0], scheme=scheme, **opts))[None],
             (xr,), (vc.spec,), PartitionSpec(None), P(None, vc.fast),
             elems=e)
    run_pair("reduce_scatter",
             lambda v, *, scheme, opts: raw(comm.reduce_scatter(
                 v[0], scheme=scheme, **opts)),
             (xr,), (vc.spec,), P(vc.axis_names), P(vc.fast), elems=e)

    xa = jnp.asarray(rng.normal(size=(R * R * 4,)).astype(np.float32))
    run_pair("alltoall",
             lambda v, *, scheme, opts: comm.alltoall(v, scheme=scheme,
                                                      **opts),
             (xa,), (vc.spec,), vc.spec, vc.spec, elems=4)

    # allgatherv returns (blocks, counts) in both classes; compare both
    valid = jnp.full((R, 1), e, jnp.int32)
    res = tuning.resolve_for(comm, "allgatherv", elems=e)
    repl = registry.get_scheme(res.scheme).result_class == "replicated"
    o_specs = (P(None), P(None)) if repl \
        else (P(None, vc.fast), P(None, vc.fast))
    got = vc.run(lambda v, val: comm.allgatherv(v, val, scheme="auto"),
                 xr, valid, in_specs=(vc.spec, vc.spec), out_specs=o_specs)
    want = vc.run(lambda v, val: comm.allgatherv(v, val, scheme=res.scheme,
                                                 **res.opts),
                  xr, valid, in_specs=(vc.spec, vc.spec), out_specs=o_specs)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=f"allgatherv ({res.scheme})")


# ---------------------------------------------------------------------------
# Irregular allgatherv + GatherPlan compaction (paper Figs 4/10)
# ---------------------------------------------------------------------------

def _irregular_case(vc, max_m=5, seed=3):
    rng = np.random.default_rng(seed)
    valid = rng.integers(1, max_m + 1,
                         size=(vc.pods, vc.chips)).astype(np.int32)
    data = rng.normal(size=(vc.pods, vc.chips, max_m)).astype(np.float32)
    for p in range(vc.pods):
        for i in range(vc.chips):
            data[p, i, valid[p, i]:] = 0.0
    return data, valid, max_m


def test_shared_allgatherv_roundtrip(vc, comm):
    data, valid, max_m = _irregular_case(vc)
    x = jnp.asarray(data.reshape(vc.num_devices, max_m))
    v = jnp.asarray(valid.reshape(vc.num_devices, 1))

    blocks, counts = vc.run(
        lambda xv, vv: comm.allgatherv(xv, vv, scheme="shared"),
        x, v, out_specs=(P(None, vc.fast), P(None, vc.fast)))
    b = np.asarray(blocks)      # (pods, chips, max_m)
    c = np.asarray(counts)      # (pods, chips, 1)
    assert b.shape == (vc.pods, vc.chips, max_m)
    for p in range(vc.pods):
        for i in range(vc.chips):
            np.testing.assert_allclose(b[p, i], data[p, i])
            assert c[p, i, 0] == valid[p, i]

    # compaction: ranks flattened in (pod, chip) order, each contributing its
    # valid prefix, tile the compact buffer exactly (paper's counts/displs).
    oracle = np.concatenate(
        [data[p, i, :valid[p, i]] for p in range(vc.pods)
         for i in range(vc.chips)])
    compact = np.concatenate(
        [b[p, i, :c[p, i, 0]] for p in range(vc.pods)
         for i in range(vc.chips)])
    assert compact.shape[0] == valid.sum()
    np.testing.assert_allclose(compact, oracle)


# pure plan algebra over the same matrix shapes — no devices needed, so
# these stay on even when the device budget is pinned below the matrix
_PLAN_SHAPES = sorted({(t.pods, t.chips) for t in MATRIX})


@pytest.mark.parametrize("pods,chips", _PLAN_SHAPES,
                         ids=[f"{p}x{c}" for p, c in _PLAN_SHAPES])
def test_gather_plan_regular_compaction_roundtrip(pods, chips):
    max_m = 5
    rng = np.random.default_rng(4)
    flat = rng.normal(size=(pods * chips, max_m)).astype(np.float32)
    plan = GatherPlan(NodeMap.smp(pods, chips), elem_per_rank=max_m)
    plan.check()
    compact = flat.reshape(-1)  # all ranks fully valid: rank-major concat
    for r in range(pods * chips):
        off = plan.rank_offset(r)
        np.testing.assert_allclose(compact[off:off + max_m], flat[r])
    assert plan.counts() == (chips * max_m,) * pods


@pytest.mark.parametrize("pods,chips", _PLAN_SHAPES,
                         ids=[f"{p}x{c}" for p, c in _PLAN_SHAPES])
def test_gather_plan_matches_device_layout(pods, chips):
    plan = GatherPlan(NodeMap.smp(pods, chips), elem_per_rank=4)
    plan.check()
    assert plan.counts() == (chips * 4,) * pods
    assert plan.displs() == tuple(chips * 4 * p for p in range(pods))
    nm = NodeMap.irregular([chips] * pods)
    assert nm.leaders() == tuple(range(0, pods * chips, chips))

    # the communicator's rank map is the same algebra
    comm = Communicator(fast_axis="data", slow_axis="pod", pods=pods,
                        chips=chips)
    assert comm.node_map == NodeMap.smp(pods, chips)


# ---------------------------------------------------------------------------
# Deprecated free-function shims: REMOVED (the one-release window closed)
# ---------------------------------------------------------------------------

def test_core_collectives_shims_are_gone():
    """``repro.core.collectives`` no longer exists — the Communicator is
    the only collective API (README migration table)."""
    with pytest.raises(ImportError):
        import repro.core.collectives  # noqa: F401
    from repro import core
    assert "collectives" not in core.__all__


# ---------------------------------------------------------------------------
# shared_to_rank_order: pure-numpy layout algebra (no devices needed)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pods,chips,chunk", [(2, 4, 3), (4, 2, 1), (1, 8, 2),
                                              (3, 5, 4)])
@pytest.mark.parametrize("axis", [0, 1])
def test_shared_to_rank_order_inverts_shared_layout(pods, chips, chunk, axis):
    n = pods * chips * chunk
    ranked = np.arange(n * 2, dtype=np.float32).reshape(n, 2)
    # shared_read layout: (local chip, pod, chunk) blocks along the axis
    shared = ranked.reshape(pods, chips, chunk, 2).swapaxes(0, 1) \
                   .reshape(n, 2)
    shared = np.moveaxis(shared[..., None], 0, axis)  # exercise axis != 0 too
    got = primitives.shared_to_rank_order(jnp.asarray(shared), num_pods=pods,
                                          chips_per_pod=chips, axis=axis)
    want = np.moveaxis(ranked[..., None], 0, axis)
    np.testing.assert_allclose(np.asarray(got), want)
