"""Production serving subsystem: queue admission, shared-window KV pages,
continuous batching, recorded decode collectives, and the serving bench
family.

The load-bearing claims, each pinned here:

* the continuous-batching scheduler's token streams are IDENTICAL to
  per-request generation — across heterogeneous prompt lengths, slot
  refill, temperature sampling, and slot count;
* KV-cache pages are node-``SharedWindow`` state: an open store epoch is
  unreadable (``WindowEpochError``) until the fence closes it, and the C1
  accounting (one node copy) holds for inference state;
* ``RecordedDecoder`` routes decode-step window gathers through a recorded
  ``CollectiveGraph`` with BIT-IDENTICAL logits (recorder on vs off) and
  replays the cached schedule per batch signature;
* ``materialize_params_on_mesh`` reads pod-replicated multi-pod windows
  through the node tier (never a bridge collective);
* ``greedy_generate`` compiles once per (model, s_max) — no re-jit per
  call;
* the ``serving`` bench family reports tokens/sec + p50/p99 per-token
  latency per topology and its schemes pass the link-inventory
  cross-check.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.comm import Communicator, SharedWindow, WindowEpochError
from repro.models import build_by_name
from repro.serving.engine import compiled_serve_fns, greedy_generate
from repro.serving.kv_cache import KVCachePages
from repro.serving.queue import AdmissionError, RequestQueue, bucket_len
from repro.serving.scheduler import (ContinuousBatchingScheduler, generate,
                                     _bucket_mode)
from repro.substrate import VirtualCluster

VC2 = VirtualCluster(pods=2, chips=4)
VC42 = VirtualCluster(pods=4, chips=2)
TUPLE = VirtualCluster(pods=2, chips=4, fast_axis=("dp", "tp"),
                       fast_shape=(2, 2), slow_axis="pod")
needs8 = pytest.mark.skipif(not VC2.available(), reason="needs 8 devices")


@pytest.fixture(scope="module")
def qwen():
    return build_by_name("qwen3-0.6b", reduced=True)


def _prompts(model, lengths, seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, model.cfg.vocab, size=n).astype(np.int32)
            for n in lengths]


# ---------------------------------------------------------------------------
# Request queue + admission control
# ---------------------------------------------------------------------------

def test_queue_validates_and_backpressures():
    q = RequestQueue(max_pending=2, max_prompt_len=8)
    with pytest.raises(AdmissionError, match="empty"):
        q.submit(np.zeros(0, np.int32), 4)
    with pytest.raises(AdmissionError, match="1-D"):
        q.submit(np.zeros((2, 3), np.int32), 4)
    with pytest.raises(AdmissionError, match="prompt"):
        q.submit(np.zeros(9, np.int32), 4)
    with pytest.raises(AdmissionError, match="max_new"):
        q.submit(np.zeros(3, np.int32), 0)
    q.submit(np.zeros(3, np.int32), 4)
    q.submit(np.zeros(3, np.int32), 4)
    with pytest.raises(AdmissionError, match="pending"):
        q.submit(np.zeros(3, np.int32), 4)
    assert len(q) == 2


def test_take_group_buckets_head_of_line_and_keeps_fifo():
    q = RequestQueue(lookahead=8)
    # prefill lengths (prompt - 1): 5->8, 9->16, 6->8, 3->4
    r0 = q.submit(np.zeros(6, np.int32), 1)    # bucket 8
    q.submit(np.zeros(10, np.int32), 1)        # bucket 16
    r2 = q.submit(np.zeros(7, np.int32), 1)    # bucket 8
    q.submit(np.zeros(4, np.int32), 1)         # bucket 4
    group = q.take_group(3, bucket="pow2")
    # head-of-line bucket is 8: picks r0 and r2, skips the 16 and the 4
    assert [r.rid for r in group] == [r0, r2]
    # FIFO preserved for the rest: one bucket per drain
    nxt = q.take_group(4, bucket="pow2")
    assert [bucket_len(r.prompt.size - 1, "pow2") for r in nxt] == [16]
    last = q.take_group(4, bucket="pow2")
    assert [bucket_len(r.prompt.size - 1, "pow2") for r in last] == [4]
    assert len(q) == 0


def test_bucket_len_modes():
    assert [bucket_len(n, "pow2") for n in (0, 1, 2, 3, 5, 8, 9)] == \
        [0, 1, 2, 4, 8, 8, 16]
    assert [bucket_len(n, "exact") for n in (0, 1, 5, 9)] == [0, 1, 5, 9]
    with pytest.raises(ValueError):
        bucket_len(3, "nope")


# ---------------------------------------------------------------------------
# Satellite: no re-jit per generate call
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_compiled_serve_fns_cached_per_model_and_smax(qwen):
    p1, d1 = compiled_serve_fns(qwen, 24)
    p2, d2 = compiled_serve_fns(qwen, 24)
    assert p1 is p2 and d1 is d2          # same (model, s_max): cache hit
    p3, _ = compiled_serve_fns(qwen, 32)
    assert p3 is not p1                   # different s_max: new entry

    params = qwen.init_params(0)
    prompts = _prompts(qwen, [8, 8])
    a = greedy_generate(qwen, params, np.stack(prompts), max_new=3, s_max=24)
    traced_p, traced_d = p1._cache_size(), d1._cache_size()
    assert traced_p > 0                   # generate used the cached fns
    b = greedy_generate(qwen, params, np.stack(prompts), max_new=3, s_max=24)
    assert p1._cache_size() == traced_p   # second call re-traced nothing
    assert d1._cache_size() == traced_d
    np.testing.assert_array_equal(a.tokens, b.tokens)


# ---------------------------------------------------------------------------
# KV-cache pages: epoch fences + C1 accounting
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_kv_pages_epoch_guard_and_c1(qwen):
    pages = KVCachePages.for_model(qwen, slots=2, s_max=16)
    _ = pages.cache                       # clean: readable
    sub = qwen.cache_init(1, 16)
    dirty = pages.admit(np.array([0]), sub)
    with pytest.raises(WindowEpochError):
        _ = dirty.cache                   # open epoch: dirty reads raise
    fenced = dirty.fence()
    _ = fenced.cache                      # fence closed the epoch
    e0 = next(iter(jax.tree.leaves(
        pages.windows, is_leaf=lambda x: isinstance(x, SharedWindow)))).epoch
    e1 = next(iter(jax.tree.leaves(
        fenced.windows, is_leaf=lambda x: isinstance(x, SharedWindow)))).epoch
    assert e1 == e0 + 1                   # slot reuse is epoch-guarded
    acct = fenced.assert_c1()
    assert acct["copies_per_node"] == 1   # paper C1 for inference state
    assert acct["resident_node_bytes"] == acct["logical_bytes"]


# ---------------------------------------------------------------------------
# Continuous batching: refill + per-request identity
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_scheduler_matches_greedy_on_uniform_prompts(qwen):
    params = qwen.init_params(0)
    prompts = _prompts(qwen, [9, 9, 9])
    want = greedy_generate(qwen, params, np.stack(prompts), max_new=5)
    got = generate(qwen, params, prompts, max_new=5, slots=3)
    np.testing.assert_array_equal(got.tokens, want.tokens)
    np.testing.assert_allclose(got.logprobs, want.logprobs,
                               rtol=2e-5, atol=1e-5)


@pytest.mark.slow
def test_slot_refill_heterogeneous_identity(qwen):
    """5 heterogeneous requests through 2 slots: finished slots are
    refilled mid-flight and every request's stream equals its solo run."""
    params = qwen.init_params(0)
    prompts = _prompts(qwen, [3, 9, 5, 1, 6])
    s_max = 16

    sched = ContinuousBatchingScheduler(qwen, params, slots=2, s_max=s_max)
    rids = [sched.queue.submit(p, 4) for p in prompts]
    results = sched.run()
    assert set(results) == set(rids)
    # refill actually happened: more admissions than slots, and some step
    # admitted while another lane was still decoding
    assert sum(s.admitted for s in sched.stats) == len(prompts)
    assert any(s.admitted and s.active > s.admitted for s in sched.stats)
    # per-slot position counters advanced per lane, not in lockstep
    assert not sched.active.any()

    for rid, p in zip(rids, prompts):
        solo = generate(qwen, params, [p], max_new=4, slots=1, s_max=s_max)
        np.testing.assert_array_equal(results[rid].tokens, solo.tokens)
        np.testing.assert_allclose(results[rid].logprobs, solo.logprobs,
                                   rtol=2e-5, atol=1e-5)


@pytest.mark.slow
def test_temperature_sampling_is_slot_independent(qwen):
    """temperature > 0: the sampled stream of a request is a function of
    (seed, rid, token index) — not of slot count or batch neighbours."""
    params = qwen.init_params(0)
    prompts = _prompts(qwen, [4, 7, 5], seed=11)
    a = generate(qwen, params, prompts, max_new=4, slots=2,
                 temperature=1.0, seed=7)
    b = generate(qwen, params, prompts, max_new=4, slots=3,
                 temperature=1.0, seed=7)
    np.testing.assert_array_equal(a.tokens, b.tokens)
    c = generate(qwen, params, prompts, max_new=4, slots=2,
                 temperature=1.0, seed=8)
    assert not np.array_equal(a.tokens, c.tokens)
    # greedy ties out with temperature=0 regardless of seed
    g0 = generate(qwen, params, prompts, max_new=4, slots=2, seed=1)
    g1 = generate(qwen, params, prompts, max_new=4, slots=2, seed=2)
    np.testing.assert_array_equal(g0.tokens, g1.tokens)


@pytest.mark.slow
def test_recurrent_model_uses_exact_buckets_and_finite_decode():
    """Recurrent/sliding-window models must not pad prefill (carried state)
    — and a prompt SHORTER than the attention window must decode finite
    (regression: the ring relayout's out-of-bounds gather used to leave
    NaN in never-written ring slots, poisoning decode attention)."""
    model = build_by_name("recurrentgemma-9b", reduced=True)
    assert _bucket_mode(model.cfg) == "exact"
    params = model.init_params(0)
    prompts = _prompts(model, [5, 5])     # 5 < window: the NaN regression
    res = greedy_generate(model, params, np.stack(prompts), max_new=3)
    assert np.isfinite(res.logprobs).all()
    got = generate(model, params, prompts, max_new=3, slots=2)
    np.testing.assert_array_equal(got.tokens, res.tokens)


@pytest.mark.slow
def test_scheduler_feeds_live_tuner(qwen):
    """Each decode step lands one latency observation in the LiveTuner,
    keyed like the nightly serving sweep's cells."""
    from repro.comm.tuning import topo_signature
    from repro.serving.live_tuning import LiveTuner
    tuner = LiveTuner(min_count=1)
    params = qwen.init_params(0)
    sched = ContinuousBatchingScheduler(qwen, params, slots=2, s_max=16,
                                        tuner=tuner)
    for p in _prompts(qwen, [4, 6]):
        sched.queue.submit(p, 3)
    sched.run()
    n_steps = len(sched.stats)
    assert n_steps > 0
    key = sched._tuner_key
    topo = topo_signature(key["pods"], key["chips"])
    est = tuner.estimate("serving", topo, "float32", key["nbytes"], "sync")
    assert est is not None and est > 0
    (cell_key, cell), = tuner._cells.items()
    assert cell_key[0] == "serving"
    assert cell.count["sync"] == n_steps


# ---------------------------------------------------------------------------
# Recorded decode collectives: bit-identity + schedule replay
# ---------------------------------------------------------------------------

def _cluster_model(vc, cfg_name="qwen3-0.6b"):
    from repro.configs import get_config
    from repro.models.transformer import build
    from repro.runtime.steps import cluster_ctx
    cfg = get_config(cfg_name).reduced()
    ctx = cluster_ctx(vc, opts=("serve_fsdp",))
    sizes = dict(zip(vc.axis_names, vc.axis_shapes))
    data = 1
    for a in ctx.fsdp_axes:
        data *= sizes[a]
    return build(cfg, ctx, data=data)


@needs8
@pytest.mark.slow
def test_recorded_decoder_bit_identical_and_replays():
    from repro.comm.stepgraph import Schedule
    from repro.serving.recorded import RecordedDecoder
    vc = VC2
    model = _cluster_model(vc)
    ctx = model.ctx
    params = model.init_params(0)
    leaves, tdef = jax.tree.flatten(params)
    pspecs = model.param_specs(serve=True, tp_axis=ctx.tp_axis,
                               fsdp_axis=ctx.fsdp_axes[0])
    in_specs = tuple(jax.tree.leaves(pspecs))
    B, s_max = 3, 16
    tok = jnp.asarray([[5], [9], [2]], jnp.int32)
    posv = jnp.asarray([0, 3, 1], jnp.int32)
    dec = RecordedDecoder(model)

    def run(fn):
        def body(*pl):
            p = jax.tree.unflatten(tdef, pl)
            _, lg = fn(p, model.cache_init(B, s_max), tok, posv)
            return lg
        return np.asarray(vc.run(body, *leaves, in_specs=in_specs,
                                 out_specs=P()))

    off = run(model.decode_fn)
    on = run(dec)
    np.testing.assert_array_equal(off, on)          # bit-identical
    assert np.isfinite(off).all()

    (sig, sched), = dec.schedules.items()
    assert isinstance(sched, Schedule)
    n_fsdp = sum(m.fsdp_dim is not None for m in jax.tree.leaves(
        model.serve_defs, is_leaf=lambda x: hasattr(x, "fsdp_dim")))
    gathers = [n for n in sched.graph.nodes if n.family == "gather"]
    assert len(gathers) == n_fsdp > 0   # every window leaf went via graph

    on2 = run(dec)                      # same signature: replay path
    np.testing.assert_array_equal(off, on2)
    assert len(dec.schedules) == 1

    dec.set_table(None)                 # new table drops cached schedules
    assert dec.schedules == {}


@pytest.mark.slow
def test_recorded_decoder_single_device_fallback(qwen):
    """No window store (ctx single): RecordedDecoder IS model.decode_fn."""
    from repro.serving.recorded import RecordedDecoder
    params = qwen.init_params(0)
    cache = qwen.cache_init(2, 8)
    tok = jnp.asarray([[1], [2]], jnp.int32)
    dec = RecordedDecoder(qwen)
    _, a = dec(params, cache, tok, jnp.asarray([0, 3], jnp.int32))
    _, b = qwen.decode_fn(params, cache, tok, jnp.asarray([0, 3], jnp.int32))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert dec.schedules == {}          # nothing recorded on the fallback


# ---------------------------------------------------------------------------
# Satellite: pod-replicated multi-pod windows materialize node-side
# ---------------------------------------------------------------------------

@needs8
@pytest.mark.slow
@pytest.mark.parametrize("vc", [VC2, VC42, TUPLE],
                         ids=lambda c: c.label)
def test_materialize_params_on_mesh_pod_replicated_windows(vc):
    """A multi-pod window is pod-replicated (one node copy per pod); the
    mesh-side read must gather through the node tier and hand back the
    NODE buffer — not a bridge collective over the pod stack."""
    from repro.serving.engine import materialize_params_on_mesh
    comm = Communicator.from_cluster(vc)
    assert comm.slow_axis is not None and comm.pods > 1
    buf = np.arange(8 * 3, dtype=np.float32).reshape(8, 3)
    # rank-major global: identical node buffers stacked slow-major
    w = jnp.asarray(np.concatenate([buf] * vc.pods, axis=0))
    out = materialize_params_on_mesh(
        {"w": SharedWindow(comm, w, axis=0, epoch=1), "b": jnp.ones(3)}, vc)
    np.testing.assert_array_equal(np.asarray(out["w"]), buf)
    np.testing.assert_array_equal(np.asarray(out["b"]), 1.0)
    # dirty multi-pod windows stay rejected on the mesh path
    with pytest.raises(ValueError, match="dirty"):
        materialize_params_on_mesh(
            {"w": SharedWindow(comm, w, epoch=1, dirty=True)}, vc)


# ---------------------------------------------------------------------------
# The serving bench family
# ---------------------------------------------------------------------------

def test_serving_schemes_registered_with_fallbacks():
    from repro.bench import serving  # noqa: F401  registers sync/recorded
    from repro.comm import registry, tuning
    assert {"sync", "recorded"} <= set(registry.scheme_names())
    for sch in registry.schemes_for("serving"):
        assert sch.result_class == "replicated"
    assert tuning.FALLBACK[None]["serving"] == "sync"
    assert tuning.FALLBACK["replicated"]["serving"] == "sync"


def test_serving_metrics_deterministic_and_monotone():
    from repro.bench.serving import serving_metrics
    a = serving_metrics(1000.0)
    b = serving_metrics(1000.0)
    assert a == b                        # pure function of the median
    slow = serving_metrics(2000.0)
    assert slow["tokens_per_s"] < a["tokens_per_s"]
    assert slow["p99_token_ms"] > a["p99_token_ms"]
    assert a["p99_token_ms"] >= a["p50_token_ms"] > 0
    with pytest.raises(ValueError):
        serving_metrics(0.0)


@needs8
@pytest.mark.slow
def test_serving_family_end_to_end_on_seed_shape():
    """Both serving schemes on 2x4: link-inventory cross-check passes and
    the report record carries tokens/sec + latency percentiles."""
    from repro.bench import report, suites
    cases = suites.build_cases(clusters=(VC2,), families=("serving",),
                               elems=(1024,))
    assert {c.scheme for c in cases} == {"sync", "recorded"}
    suite = suites.run_suite(cases, reps=2, log=None)
    for r in suite.cases:
        rec = report.case_record(r)
        assert rec["ok"], [c for c in rec["checks"] if not c["ok"]]
        sv = rec["serving"]
        assert sv["tokens_per_s"] > 0
        assert sv["p99_token_ms"] >= sv["p50_token_ms"] > 0
        assert rec["timing"]["p99_us"] >= rec["timing"]["p50_us"] > 0
