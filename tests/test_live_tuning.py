"""Live collective re-tuning: the session-local ``TuningTable`` overlay.

The acceptance claim pinned here: an injected latency shift observed by
the :class:`repro.serving.live_tuning.LiveTuner` flips a scheme winner
through ``tuning.resolve_for`` — WITHOUT touching the base table object or
the committed ``TUNING_default.json``.
"""

import copy

import pytest

from repro.comm import Communicator, tuning
from repro.comm.tuning import Choice, TuningEntry, TuningTable
from repro.core.plans import size_bucket
from repro.serving.live_tuning import LiveTuner
from repro.substrate import VirtualCluster

VC2 = VirtualCluster(pods=2, chips=4)


def _base() -> TuningTable:
    """One measured cell: psum on 2x4, naive (100us) beats shared (120us)."""
    return TuningTable(entries=(TuningEntry(
        family="psum", topo="2x4", dtype="float32", nbytes=4096,
        source="measured",
        ranking=(Choice("naive", median_us=100.0),
                 Choice("shared", median_us=120.0)),
    ),), meta={})


def test_observe_ewma_and_estimate():
    t = LiveTuner(_base(), alpha=0.5)
    t.observe("psum", pods=2, chips=4, nbytes=4096, scheme="naive", us=200.0)
    assert t.estimate("psum", "2x4", "float32", 4096, "naive") == 200.0
    t.observe("psum", pods=2, chips=4, nbytes=4096, scheme="naive", us=400.0)
    # EWMA with alpha=0.5: 0.5*200 + 0.5*400
    assert t.estimate("psum", "2x4", "float32", 4096, "naive") == 300.0
    # unobserved scheme / cell: no estimate
    assert t.estimate("psum", "2x4", "float32", 4096, "shared") is None
    assert t.estimate("psum", "4x2", "float32", 4096, "naive") is None
    with pytest.raises(ValueError):
        t.observe("psum", pods=2, chips=4, nbytes=4096, scheme="naive",
                  us=0.0)
    with pytest.raises(ValueError):
        LiveTuner(alpha=0.0)


def test_min_count_gates_single_outliers():
    t = LiveTuner(_base(), min_count=2)
    t.observe("psum", pods=2, chips=4, nbytes=4096, scheme="naive", us=500.0)
    # one outlier is not trusted: estimate withheld, overlay keeps base
    assert t.estimate("psum", "2x4", "float32", 4096, "naive") is None
    ov = t.overlay()
    assert ov.entries[0].ranking[0].scheme == "naive"
    assert ov.entries[0].ranking[0].median_us == 100.0


def test_latency_shift_flips_winner_without_touching_tables():
    """The acceptance-criteria scenario: live traffic shows 'naive' is now
    5x its swept latency; the overlay re-ranks and ``resolve_for`` picks
    'shared' — base table object and committed default stay untouched."""
    base = _base()
    base_snapshot = copy.deepcopy(base)
    committed_snapshot = copy.deepcopy(tuning.default_table())
    comm = Communicator.from_cluster(VC2)
    elems = 1024                            # 4096 B: the measured cell

    before = tuning.resolve_for(comm, "psum", elems=elems, table=base)
    assert before.scheme == "naive" and before.source == "measured"

    t = LiveTuner(base, min_count=2)
    for _ in range(2):                      # min_count satisfied
        t.observe("psum", pods=2, chips=4, nbytes=4096, scheme="naive",
                  us=500.0)
    after = tuning.resolve_for(comm, "psum", elems=elems, table=t.overlay())
    assert after.scheme == "shared" and after.source == "measured"

    # the shift lives ONLY in the overlay
    assert base == base_snapshot
    assert tuning.default_table() == committed_snapshot
    assert tuning.resolve_for(comm, "psum", elems=elems,
                              table=base).scheme == "naive"
    # overlay metadata records the live provenance
    ov = t.overlay()
    assert ov.meta["live_overlay"]["cells"] == 1
    # base median fills the scheme live never re-measured
    cell = ov.entries[0]
    assert {c.scheme: c.median_us for c in cell.ranking} == \
        {"shared": 120.0, "naive": pytest.approx(500.0)}


def test_overlay_synthesizes_unmeasured_cells():
    """A cell the nightly sweep never measured is synthesized from live
    data alone and becomes resolvable at its size bucket."""
    t = LiveTuner(_base())
    t.observe("allgather", pods=4, chips=2, nbytes=1 << 20, scheme="shared",
              us=80.0)
    t.observe("allgather", pods=4, chips=2, nbytes=1 << 20, scheme="naive",
              us=300.0)
    ov = t.overlay()
    synth = [e for e in ov.entries if e.family == "allgather"]
    assert len(synth) == 1
    e = synth[0]
    assert e.topo == "4x2" and e.source == "measured" and e.label == "live"
    assert e.bucket == size_bucket(1 << 20)
    assert [c.scheme for c in e.ranking] == ["shared", "naive"]
    # the base cell rode along untouched
    assert _base().entries[0] in ov.entries


def test_observe_comm_keys_by_communicator_topology():
    t = LiveTuner(_base())
    comm = Communicator.from_cluster(VC2)
    t.observe_comm(comm, "psum", nbytes=4096, scheme="shared", us=50.0)
    assert t.estimate("psum", "2x4", "float32", 4096, "shared") == 50.0
    loose = Communicator(fast_axis="x", slow_axis=None, pods=None, chips=None)
    with pytest.raises(ValueError, match="static"):
        t.observe_comm(loose, "psum", nbytes=4096, scheme="shared", us=50.0)


def test_use_installs_overlay_session_locally():
    t = LiveTuner(_base(), min_count=1)
    t.observe("psum", pods=2, chips=4, nbytes=4096, scheme="naive", us=500.0)
    comm = Communicator.from_cluster(VC2)
    with t.use():
        inside = tuning.resolve_for(comm, "psum", elems=1024)
        assert inside.scheme == "shared"
    outside = tuning.resolve_for(comm, "psum", elems=1024, table=_base())
    assert outside.scheme == "naive"
