"""Per-kernel allclose sweeps (interpret=True on CPU) vs the jnp oracles."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

F32_TOL = dict(rtol=2e-4, atol=2e-4)
BF16_TOL = dict(rtol=2e-2, atol=2e-2)


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,KV,Tq,Tkv,hd", [
    (1, 4, 4, 128, 128, 64),       # MHA square
    (2, 8, 2, 128, 128, 64),       # GQA 4:1
    (1, 4, 1, 64, 256, 32),        # MQA, Tq != Tkv (q at the end)
    (1, 3, 3, 96, 96, 16),         # non-128 shapes (padding path)
    (2, 4, 2, 256, 256, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, H, KV, Tq, Tkv, hd, dtype):
    rng = np.random.default_rng(0)
    q = _rand(rng, (B, H, Tq, hd), dtype)
    k = _rand(rng, (B, KV, Tkv, hd), dtype)
    v = _rand(rng, (B, KV, Tkv, hd), dtype)
    q_off = Tkv - Tq
    got = ops.flash_attention(q, k, v, causal=True, q_offset=q_off,
                              block_q=64, block_kv=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True, q_offset=q_off)
    tol = F32_TOL if dtype == jnp.float32 else BF16_TOL
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


@pytest.mark.parametrize("window", [16, 64])
def test_flash_attention_window(window):
    rng = np.random.default_rng(1)
    B, H, T, hd = 1, 2, 128, 32
    q = _rand(rng, (B, H, T, hd), jnp.float32)
    k = _rand(rng, (B, H, T, hd), jnp.float32)
    v = _rand(rng, (B, H, T, hd), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=32, block_kv=32, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **F32_TOL)


def test_flash_attention_noncausal():
    rng = np.random.default_rng(2)
    B, H, T, hd = 1, 2, 64, 32
    q = _rand(rng, (B, H, T, hd), jnp.float32)
    k = _rand(rng, (B, H, T, hd), jnp.float32)
    v = _rand(rng, (B, H, T, hd), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=False, block_q=32, block_kv=32,
                              interpret=True)
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **F32_TOL)


# ---------------------------------------------------------------------------
# SUMMA panel matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,K,N", [
    (128, 128, 128), (256, 128, 384), (128, 512, 128), (96, 160, 224),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_matches_ref(M, K, N, dtype):
    rng = np.random.default_rng(3)
    a = _rand(rng, (M, K), dtype)
    b = _rand(rng, (K, N), dtype)
    got = ops.matmul(a, b, block_m=64, block_n=64, block_k=64,
                     interpret=True)
    want = ref.matmul_ref(a, b)
    tol = F32_TOL if dtype == jnp.float32 else BF16_TOL
    # bf16 long-K accumulation: compare in fp32 with K-scaled tolerance
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol["rtol"] * max(1, K // 256 + 1),
                               atol=tol["atol"] * 8)


# ---------------------------------------------------------------------------
# LRU scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,T,C,bt,bc", [
    (1, 256, 128, 64, 64), (2, 512, 64, 128, 64), (1, 100, 48, 32, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lru_scan_matches_ref(B, T, C, bt, bc, dtype):
    rng = np.random.default_rng(4)
    # decays in (0, 1) — the RG-LRU regime
    a = jnp.asarray(rng.uniform(0.5, 0.999,
                                size=(B, T, C)).astype(np.float32))
    x = _rand(rng, (B, T, C), jnp.float32)
    got = ops.lru_scan(a.astype(dtype), x.astype(dtype), block_t=bt,
                       block_c=bc, interpret=True)
    want = ref.lru_scan_ref(a, x)
    tol = F32_TOL if dtype == jnp.float32 else dict(rtol=5e-2, atol=5e-2)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


def test_lru_scan_carry_across_blocks():
    """State must flow across time-grid steps (the scratch carry)."""
    B, T, C = 1, 128, 32
    a = jnp.full((B, T, C), 1.0, jnp.float32)
    x = jnp.ones((B, T, C), jnp.float32)
    got = ops.lru_scan(a, x, block_t=32, block_c=32, interpret=True)
    want = jnp.cumsum(x, axis=1)  # a=1 -> running sum
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
