"""Suite-wide configuration.

Two jobs, both of which must happen before any test module initializes jax
backends:

1. Force 8 fake host CPU devices so the VirtualCluster topology matrix
   (``repro.substrate``) runs *in-process* — no subprocess round-trips per
   topology.  An ``XLA_FLAGS`` already carrying a force flag wins (CI's
   ``slow`` job pins its own count); genuinely-single-device behaviour is
   covered by the subprocess isolation test in ``test_collectives.py``.

2. Make ``hypothesis`` optional: the property-test modules are skipped at
   collection when it is not installed (``pip install -r
   requirements-dev.txt`` to get it).
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                os.pardir, "src"))

# Importing the substrate imports jax but does not initialize its backends;
# the flag is still unset-able at this point.
from repro.substrate import ensure_host_device_count  # noqa: E402

ensure_host_device_count(8)

try:
    import hypothesis  # noqa: F401
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

_HYPOTHESIS_MODULES = ["test_attention_props.py", "test_moe_dispatch.py",
                       "test_plans.py", "test_pipeline_props.py",
                       "test_prefetch_props.py", "test_stepgraph_props.py",
                       "test_quantized_props.py"]

collect_ignore = [] if _HAVE_HYPOTHESIS else list(_HYPOTHESIS_MODULES)


def pytest_report_header(config):
    import jax
    lines = [f"jax {jax.__version__} | "
             f"XLA_FLAGS: {os.environ.get('XLA_FLAGS', '')}"]
    if not _HAVE_HYPOTHESIS:
        lines.append("hypothesis not installed — skipping property-test "
                     f"modules: {', '.join(_HYPOTHESIS_MODULES)}")
    return lines
