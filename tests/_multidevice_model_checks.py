"""Distributed model correctness: shard_map (hier + naive) vs single-device.

8 fake CPU devices; meshes (2,2,2)=(pod,data,model) and (1,8)->(data=1,model=8)
exercise head_tp, cp, MoE ep x tp_ff, mLSTM head groups, sLSTM batch groups.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.launch.mesh import make_mesh_from_topo, small_topo  # noqa: E402
from repro.models import build_by_name, make_batch  # noqa: E402
from repro.models.parallel import ParallelCtx  # noqa: E402
from repro.models.transformer import build  # noqa: E402
from repro.runtime.steps import make_train_step  # noqa: E402

CHECKS = []


def check(fn):
    CHECKS.append(fn)
    return fn


def single_device_step(cfg, batch, seed=0, lr=1e-3):
    """Reference: same math, ParallelCtx.single(), plain jax."""
    from repro.runtime.steps import make_ctx
    from repro.core.topology import MeshTopology
    topo1 = MeshTopology({"data": 1, "model": 1}, slow_axes=())
    mesh1 = make_mesh_from_topo(topo1)
    bundle = make_train_step(cfg, topo1, mesh1, mode="naive", lr=lr,
                             compute_dtype=jnp.float32)
    state = bundle.init_state(seed)
    new_state, metrics = jax.jit(bundle.fn)(state, batch)
    return state, new_state, metrics


def dist_step(cfg, batch, topo, mode, seed=0, lr=1e-3):
    mesh = make_mesh_from_topo(topo)
    bundle = make_train_step(cfg, topo, mesh, mode=mode, lr=lr,
                             compute_dtype=jnp.float32)
    state = bundle.init_state(seed)
    new_state, metrics = jax.jit(bundle.fn)(state, batch)
    return state, new_state, metrics


def compare(cfg, batch, topo, rtol=2e-4, atol=2e-5):
    _, ref_state, ref_metrics = single_device_step(cfg, batch)
    for mode in ("hier", "naive"):
        _, st, mt = dist_step(cfg, batch, topo, mode)
        np.testing.assert_allclose(float(mt["loss"]),
                                   float(ref_metrics["loss"]),
                                   rtol=rtol, err_msg=f"{mode} loss")
        np.testing.assert_allclose(float(mt["gnorm"]),
                                   float(ref_metrics["gnorm"]),
                                   rtol=5e-3, err_msg=f"{mode} gnorm")
        # params after one update must match the single-device reference
        ref_emb = np.asarray(ref_state["params"]["embed"])
        got_emb = np.asarray(jax.device_get(st["params"]["embed"]))
        np.testing.assert_allclose(got_emb, ref_emb, rtol=rtol, atol=atol,
                                   err_msg=f"{mode} embed update")


@check
def dense_head_tp_multipod():
    cfg = get_config("qwen3-0.6b").reduced(n_layers=2, d_model=64, n_heads=4)
    batch = make_batch(cfg, B=4, T=32, seed=1)
    compare(cfg, batch, small_topo(2, 2, 2))


@check
def dense_cp_mode():
    # n_heads=3 % tp=2 != 0 -> context-parallel attention
    cfg = get_config("starcoder2-7b").reduced(n_layers=2, d_model=48,
                                              n_heads=3, d_ff=64)
    batch = make_batch(cfg, B=4, T=32, seed=2)
    compare(cfg, batch, small_topo(2, 2, 2))


@check
def moe_ep_tp():
    cfg = get_config("granite-moe-3b-a800m").reduced(n_layers=2, d_model=64,
                                                     n_heads=4)
    # E=4 over tp=2 -> ep=2; widen capacity so no tokens drop (determinism)
    import dataclasses
    from repro.configs.base import MoESpec
    cfg = dataclasses.replace(cfg, moe=MoESpec(4, 2, 32, capacity_factor=8.0))
    batch = make_batch(cfg, B=4, T=32, seed=3)
    compare(cfg, batch, small_topo(2, 2, 2))


@check
def xlstm_head_groups():
    # tp=4 > nh=2 -> g=2 chips per head (group all-gather path) + sLSTM
    cfg = get_config("xlstm-1.3b").reduced(n_layers=8, d_model=64, n_heads=2)
    batch = make_batch(cfg, B=4, T=32, seed=4)
    compare(cfg, batch, small_topo(2, 1, 4))


@check
def recurrentgemma_hybrid():
    cfg = get_config("recurrentgemma-9b").reduced(n_layers=3, d_model=64,
                                                  n_heads=4)
    batch = make_batch(cfg, B=4, T=32, seed=5)
    compare(cfg, batch, small_topo(2, 2, 2))


@check
def vlm_and_audio():
    for name, seed in (("internvl2-1b", 6), ("musicgen-medium", 7)):
        cfg = get_config(name).reduced(n_layers=2, d_model=64, n_heads=4)
        batch = make_batch(cfg, B=4, T=32, seed=seed)
        compare(cfg, batch, small_topo(2, 2, 2))


def main():
    failures = []
    for fn in CHECKS:
        try:
            fn()
            print(f"PASS {fn.__name__}")
        except Exception as e:  # noqa: BLE001
            failures.append(fn.__name__)
            import traceback
            print(f"FAIL {fn.__name__}:")
            traceback.print_exc(limit=8)
    if failures:
        raise SystemExit(1)
    print("ALL OK")




def _register_decode2d():
    """decode2d must match baseline decode logits exactly (qwen3-family
    reduced arch: H=8, kv=4, tp=4 -> g_h=4? gcd(8,4,4)=4, g_s=1; use tp=8
    for g_h=4,g_s=2... run on (1,1,8): gcd(8,4,8)=4 -> g_h=4, g_s=2)."""
    import dataclasses as _dc
    import numpy as _np
    from repro.models import meta as _M
    from repro.runtime.steps import make_serve_steps, make_ctx
    from repro.launch.mesh import make_mesh_from_topo
    from repro.core.topology import MeshTopology

    def decode2d_matches_baseline():
        cfg = get_config("qwen3-0.6b").reduced(n_layers=2, d_model=64,
                                               n_heads=8, n_kv=4)
        topo = MeshTopology({"data": 1, "model": 8}, slow_axes=())
        mesh = make_mesh_from_topo(topo)
        B, T0, smax = 2, 16, 32
        batch = make_batch(cfg, B=B, T=T0, seed=9)
        outs = {}
        for opts in ((), ("decode2d",)):
            sb = make_serve_steps(cfg, topo, mesh, mode="hier",
                                  global_batch=B, s_max=smax, opts=opts,
                                  compute_dtype=jnp.float32)
            params = sb.model.init_params(0)
            if opts:
                # duplicate baseline attn weights into 2D layout so both
                # runs share identical math
                base = make_serve_steps(cfg, topo, mesh, mode="hier",
                                        global_batch=B, s_max=smax,
                                        compute_dtype=jnp.float32)
                bp = base.model.init_params(0)
                for i in range(len(cfg.pattern)):
                    a = params["units"][f"b{i}"]["attn"]
                    ab = bp["units"][f"b{i}"]["attn"]
                    for kind in ("wq", "wkv", "wo"):
                        stacked = _np.stack([
                            _M.relayout_attn_decode2d(w_, cfg, 8, kind)
                            for w_ in _np.asarray(ab[kind])])
                        # (U, tp, ...) -> param layout (U, tp, ...)
                        a[kind] = jnp.asarray(stacked)
                params = dict(params, units=params["units"])
                for k_ in ("embed", "unembed", "final_ln"):
                    if k_ in bp:
                        params[k_] = bp[k_]
                for i in range(len(cfg.pattern)):
                    pu = params["units"][f"b{i}"]
                    bu = bp["units"][f"b{i}"]
                    pu["attn"]["ln"] = bu["attn"]["ln"]
                    if "q_norm" in bu["attn"]:
                        pu["attn"]["q_norm"] = bu["attn"]["q_norm"]
                        pu["attn"]["k_norm"] = bu["attn"]["k_norm"]
                    if "ffn" in bu:
                        pu["ffn"] = bu["ffn"]
            tok = batch["tokens"][:, :1]
            local_cache = jax.eval_shape(
                lambda sb_=sb: sb_.model.cache_init(sb_.b_loc, smax))
            cache = jax.tree.map(
                lambda l: jnp.zeros((1, 8) + l.shape, l.dtype), local_cache)
            logits = None
            for t in range(4):
                cache, logits = jax.jit(sb.decode)(
                    params, cache, batch["tokens"][:, t:t + 1],
                    jnp.int32(t))
            outs[bool(opts)] = np.asarray(logits)
        np.testing.assert_allclose(outs[True], outs[False], rtol=2e-4,
                                   atol=2e-4)

    CHECKS.append(decode2d_matches_baseline)


_register_decode2d()


if __name__ == "__main__":
    main()
