"""Hypothesis properties of the step-graph building blocks.

The codec and the greedy partitioner are pure data plumbing, so their
invariants are checkable without a mesh: pack/unpack is a bit-exact
round-trip for ANY leaf list (shapes, dtypes, padding), the packed layout
is the program order, and ``greedy_buckets`` is an order-preserving
partition whose every bucket (except possibly the last) meets the byte
target.  The live-mesh equivalences (recorder vs ``lax.psum``, whole-step
on-vs-off) live in ``test_stepgraph.py``.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.comm.stepgraph import pack_leaves, unpack_leaves
from repro.core.plans import greedy_buckets

shapes = st.lists(
    st.lists(st.integers(min_value=0, max_value=4), min_size=0, max_size=3)
    .map(tuple),
    min_size=1, max_size=6)
dtypes = st.sampled_from([np.float32, np.float64, np.int32])
pads = st.integers(min_value=1, max_value=9)


@settings(deadline=None)
@given(shapes, dtypes, pads)
def test_pack_unpack_roundtrip(shs, dtype, pad_to):
    rng = np.random.default_rng(0)
    leaves = [jnp.asarray((rng.normal(size=s) * 100).astype(dtype))
              for s in shs]
    buf, spec = pack_leaves(leaves, pad_to=pad_to)
    assert buf.shape == (spec.total_elems,)
    assert spec.total_elems % pad_to == 0
    assert spec.total_elems == sum(spec.leaf_elems) + spec.pad_elems
    assert spec.pad_elems < pad_to
    out = unpack_leaves(buf, spec)
    assert len(out) == len(leaves)
    for a, b in zip(leaves, out):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(deadline=None)
@given(shapes, pads)
def test_pack_layout_is_program_order(shs, pad_to):
    """The flat buffer IS the concatenation of raveled leaves in call
    order — the property the issue-early schedule relies on (early leaves
    occupy early offsets) and the reason a psum of the buffer equals the
    per-leaf psums."""
    rng = np.random.default_rng(1)
    leaves = [jnp.asarray(rng.normal(size=s).astype(np.float32))
              for s in shs]
    buf, spec = pack_leaves(leaves, pad_to=pad_to)
    flat = np.concatenate([np.asarray(x).ravel() for x in leaves]
                          + [np.zeros(spec.pad_elems, np.float32)])
    np.testing.assert_array_equal(np.asarray(buf), flat)


msg_sizes = st.lists(st.integers(min_value=0, max_value=1 << 20),
                     min_size=0, max_size=40)
targets = st.integers(min_value=1, max_value=1 << 18)


@given(msg_sizes, targets)
def test_greedy_buckets_is_ordered_partition(sizes, target):
    buckets = greedy_buckets(sizes, target)
    flat = [i for b in buckets for i in b]
    assert flat == list(range(len(sizes)))       # partition, in order
    assert all(b for b in buckets)               # no empty buckets


@given(msg_sizes, targets)
def test_greedy_buckets_meet_target_except_tail(sizes, target):
    """Every closed bucket reached the target; only the tail may fall
    short, and removing any closed bucket's last member would put it
    under target (greedy minimality)."""
    buckets = greedy_buckets(sizes, target)
    for b in buckets[:-1]:
        total = sum(sizes[i] for i in b)
        assert total >= target
        assert total - sizes[b[-1]] < target
