"""Quantized wire-format collectives: registry schemes behind the
``precision=`` constraint, error model, error feedback, int4 packing, and
the dequant-fused ``ag_matmul`` fast path.

Every equivalence check runs over ``default_matrix()`` and asserts the
measured error against the SAME host-side error model the bench validator
uses (``CollectiveScheme.error_check``) — the declared bound is a ceiling,
never a vibe.  Call sites here opt in with ``precision="lossy"``; the
exact default refusing a concretely-named quantized scheme is part of the
contract under test.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.comm import Communicator, get_scheme, quantize as qz
from repro.substrate import VirtualCluster, default_matrix

MATRIX = default_matrix()

QUANT_PSUM = ("q8_hier", "qbf16_hier")
QUANT_ALLGATHER = ("q8_hier", "qbf16_hier", "q4_shared")


@pytest.fixture(params=MATRIX, ids=[t.label for t in MATRIX])
def vc(request) -> VirtualCluster:
    cluster = request.param
    if not cluster.available():
        pytest.skip(f"needs {cluster.num_devices} devices")
    return cluster


@pytest.fixture
def comm(vc) -> Communicator:
    return Communicator.from_cluster(vc)


def _payload(vc, m, seed=3, scale=3.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.normal(size=(vc.num_devices, m)).astype(np.float32) * scale)


# ---------------------------------------------------------------------------
# Matrix equivalence within the declared error bound
# ---------------------------------------------------------------------------

def test_quantized_allreduce_within_declared_bound(vc, comm):
    m = 128
    x = _payload(vc, m)
    exact = np.asarray(x).sum(axis=0)
    # single-pod communicator: the whole reduction IS the bridge (the
    # reduce_grads dispatch shape), so the error model's "quantized
    # contributions" count is the rank count, not the pod count
    pods, chips = (vc.pods, vc.chips) if vc.pods > 1 \
        else (vc.num_devices, 1)
    for name in QUANT_PSUM:
        out = np.asarray(vc.run(
            lambda v, s=name: comm.allreduce(
                v[0], scheme=s, precision="lossy")[None], x))
        bound, measured = get_scheme(name).error_check(
            "psum", inputs=(np.asarray(x),), output=out,
            pods=pods, chips=chips, elems=m)
        assert measured <= bound, (name, measured, bound)
        # the bound itself is small relative to the payload: the lossy
        # result is usably close to the exact sum, not merely "in bound"
        np.testing.assert_allclose(
            out, np.broadcast_to(exact, out.shape), atol=2 * bound)


def test_quantized_allgather_within_declared_bound(vc, comm):
    m = 64
    x = _payload(vc, m, seed=5)
    flat = jnp.ravel(x)                       # rank-major, m elems per rank
    for name in ("q8_hier", "qbf16_hier"):
        out = np.asarray(vc.run(
            lambda v, s=name: comm.allgather(
                v, scheme=s, precision="lossy")[None], flat))
        bound, measured = get_scheme(name).error_check(
            "allgather", inputs=(np.asarray(flat),), output=out,
            pods=vc.pods, chips=vc.chips, elems=m)
        assert measured <= bound, (name, measured, bound)
    # q4_shared returns the node's SharedWindow; the stacked shards are
    # the scheme's own declared layout reference
    out = np.asarray(vc.run(
        lambda v: comm.allgather(v, scheme="q4_shared",
                                 precision="lossy").shard, flat))
    bound, measured = get_scheme("q4_shared").error_check(
        "allgather", inputs=(np.asarray(flat),), output=out,
        pods=vc.pods, chips=vc.chips, elems=m)
    assert measured <= bound, ("q4_shared", measured, bound)


def test_own_pod_region_is_exact(vc, comm):
    """A pod never pays quantization error for its own contribution: rank
    (p, i)'s gathered buffer holds pod p's region bit-exactly."""
    m = 32
    x = _payload(vc, m, seed=11)
    flat = jnp.ravel(x)
    out = np.asarray(vc.run(
        lambda v: comm.allgather(v, scheme="q8_hier",
                                 precision="lossy")[None], flat))
    want = np.asarray(flat).reshape(vc.pods, vc.chips * m)
    got = out.reshape(vc.pods, vc.chips, vc.num_devices * m)
    for p in range(vc.pods):
        region = got[p, :, p * vc.chips * m:(p + 1) * vc.chips * m]
        np.testing.assert_array_equal(region, np.broadcast_to(
            want[p], (vc.chips, vc.chips * m)))


# ---------------------------------------------------------------------------
# precision= constraint semantics
# ---------------------------------------------------------------------------

def test_concrete_lossy_scheme_requires_opt_in(vc, comm):
    x = _payload(vc, 16)
    for family, call in (
            ("psum", lambda v: comm.allreduce(v[0], scheme="q8_hier")),
            ("allgather", lambda v: comm.allgather(jnp.ravel(v),
                                                   scheme="q4_shared"))):
        with pytest.raises(ValueError, match="lossy"):
            vc.run(call, x)


def test_error_feedback_requires_lossy():
    comm = Communicator(fast_axis="data", pods=1, chips=4)
    with pytest.raises(ValueError, match="lossy"):
        comm.allreduce(jnp.ones(4), error_feedback=jnp.float32(0))


# ---------------------------------------------------------------------------
# Error feedback: residual convergence over the multi-pod matrix
# ---------------------------------------------------------------------------

def test_error_feedback_residual_converges(vc, comm):
    """Repeating the SAME lossy reduction with the residual fed back must
    average out the quantization error: the T-step mean lands much closer
    to the exact sum than any single shot (the error-feedback guarantee —
    cumulative error stays bounded by one step's residual)."""
    if vc.pods < 2:
        pytest.skip("no bridge to compress")
    m = 128
    T = 8
    x = _payload(vc, m, seed=7)
    exact = np.asarray(x).sum(axis=0)

    def body(v):
        g = v[0]
        err = jnp.float32(0)
        acc = jnp.zeros_like(g)
        for _ in range(T):
            out, err = comm.allreduce(g, scheme="q8_hier",
                                      precision="lossy",
                                      error_feedback=err)
            acc = acc + out
        return (acc / T)[None]

    avg = np.asarray(vc.run(body, x))
    single = np.asarray(vc.run(
        lambda v: comm.allreduce(v[0], scheme="q8_hier",
                                 precision="lossy")[None], x))
    avg_err = float(np.max(np.abs(avg - exact)))
    single_err = float(np.max(np.abs(single - exact)))
    bound, _ = get_scheme("q8_hier").error_check(
        "psum", inputs=(np.asarray(x),), output=single,
        pods=vc.pods, chips=vc.chips, elems=m)
    assert avg_err <= bound
    # feedback must beat open-loop repetition of the same deterministic
    # error; theory says ~single_err/T, assert a conservative half
    assert avg_err <= max(single_err * 0.5, bound * 0.1), \
        (avg_err, single_err, bound)


def test_exact_pick_under_lossy_absorbs_residual(vc, comm):
    """An EXACT scheme reached under precision='lossy' with error feedback
    adds the carried residual into the payload and returns a zero
    residual — the loop closes with no error left behind."""
    m = 8
    x = jnp.ones((vc.num_devices, m), jnp.float32)

    def body(v):
        out, err = comm.allreduce(v[0], scheme="hier", precision="lossy",
                                  error_feedback=jnp.float32(0.5))
        return (out + err)[None]     # err must be exactly zero

    out = np.asarray(vc.run(body, x))
    np.testing.assert_allclose(
        out, (1.0 + 0.5) * vc.num_devices, rtol=1e-6)


# ---------------------------------------------------------------------------
# reduce_grads: the gradient-bridge integration
# ---------------------------------------------------------------------------

def test_reduce_grads_lossy_with_error_state():
    from repro.models.parallel import ParallelCtx
    vc = VirtualCluster(pods=4, chips=2)
    if not vc.available():
        pytest.skip("needs 8 devices")
    ctx = ParallelCtx(mode="hier", dp_axes=("pod",), pod_axis="pod")
    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.normal(size=(vc.num_devices, 16))
                    .astype(np.float32))

    def body(v):
        g = {"g": v[0]}
        out, state = ctx.reduce_grads(g, precision="lossy",
                                      error_state={"g": jnp.float32(0)})
        # residual grew into a gradient-shaped carry
        return out["g"][None], jnp.ravel(state["g"])[None]

    out, state = vc.run(body, x)
    got = np.asarray(out).reshape(vc.num_devices, -1)
    # bridge-only reduction: rank (p, i) holds sum over pods q of x[q, i]
    want = np.tile(np.asarray(x).reshape(vc.pods, vc.chips, -1)
                   .sum(axis=0), (vc.pods, 1))
    amax = float(np.max(np.abs(np.asarray(x))))
    tol = vc.pods * amax * (1 / 254) * 2 + 1e-5
    np.testing.assert_allclose(got, want, atol=tol)
    assert np.asarray(state).size   # non-degenerate residual came back


def test_reduce_grads_exact_default_unchanged():
    """The precision default must leave the existing exact path untouched
    (regression guard for the API fold)."""
    from repro.models.parallel import ParallelCtx
    vc = VirtualCluster(pods=4, chips=2)
    if not vc.available():
        pytest.skip("needs 8 devices")
    ctx = ParallelCtx(mode="naive", dp_axes=("pod", "data"),
                      pod_axis="pod")
    x = jnp.ones((vc.num_devices, 3), jnp.float32)
    out = vc.run(lambda v: ctx.reduce_grads({"g": v})["g"], x)
    np.testing.assert_allclose(np.asarray(out), 8.0)
    with pytest.raises(ValueError, match="lossy"):
        ctx.reduce_grads({"g": x}, error_state={"g": jnp.float32(0)})


# ---------------------------------------------------------------------------
# Deprecation shims (one release): old call sites warn and delegate
# ---------------------------------------------------------------------------

def test_compression_shims_warn_and_delegate():
    from repro.optim import compression
    vc = VirtualCluster(pods=4, chips=1)
    if not vc.available():
        pytest.skip("needs 4 devices")
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(vc.num_devices, 64))
                    .astype(np.float32))
    with pytest.warns(DeprecationWarning, match="int8_bridge_psum"):
        out = vc.run(lambda v: compression.int8_bridge_psum(
            v[0], vc.axis_names)[None], x)
    exact = np.asarray(x).sum(axis=0)
    amax = float(np.max(np.abs(np.asarray(x))))
    got = np.asarray(out)
    np.testing.assert_allclose(got, np.broadcast_to(exact, got.shape),
                               atol=vc.num_devices * amax / 254 * 2 + 1e-5)
    with pytest.warns(DeprecationWarning, match="make_error_feedback"):
        init, compress_leaf = compression.make_error_feedback(
            {"w": jnp.ones((3,))})
    state = init()
    assert state["w"].shape == (3,) and callable(compress_leaf)


# ---------------------------------------------------------------------------
# Per-block scales: the outlier regression
# ---------------------------------------------------------------------------

def test_block_scales_survive_outlier():
    """One huge gradient element must not collapse every OTHER block's
    grid to zero — the per-tensor-scale bug the per-block quantizer
    fixed.  Error outside the outlier's block stays bounded by that
    block's own amax, not the outlier's."""
    rng = np.random.default_rng(9)
    x = rng.normal(size=(512,)).astype(np.float32)
    x[3] = 1e4                               # synthetic outlier in block 0
    q, scale, meta = qz.block_quantize(jnp.asarray(x), block=64)
    deq = np.asarray(qz.block_dequantize(q, scale, meta, x.shape))
    err = np.abs(deq - x)
    rest_amax = float(np.max(np.abs(x[64:])))
    assert float(np.max(err[64:])) <= rest_amax / 254 + 1e-6
    # a per-tensor scale would quantize to steps of ~1e4/127 ~ 79: every
    # normal-sized element would round to zero
    assert float(np.max(np.abs(deq[64:]))) > 0.0
    # the outlier block itself still holds its own bound
    assert float(np.max(err[:64])) <= 1e4 / 254 + 1e-6


# ---------------------------------------------------------------------------
# int4 pack/unpack + groupwise weight quantization
# ---------------------------------------------------------------------------

def test_int4_pack_unpack_roundtrip_exact():
    vals = np.arange(-7, 8, dtype=np.int8)          # the full code book
    q = jnp.asarray(np.tile(vals, 6)[: 2 * 44])     # even length
    packed = qz.pack_int4(q)
    assert packed.dtype == jnp.uint8
    assert packed.shape[-1] == q.shape[-1] // 2
    np.testing.assert_array_equal(np.asarray(qz.unpack_int4(packed)),
                                  np.asarray(q))
    # 2-D panels pack along the last axis
    q2 = jnp.asarray(np.tile(vals, 10)[:128].reshape(4, 32), jnp.int8)
    np.testing.assert_array_equal(
        np.asarray(qz.unpack_int4(qz.pack_int4(q2))), np.asarray(q2))


def test_quantize_q4_groupwise_error_bound():
    rng = np.random.default_rng(21)
    w = rng.normal(size=(64, 8)).astype(np.float32)
    w[5, 2] = 40.0                          # outlier stays in group 0
    packed, scales = qz.quantize_q4(jnp.asarray(w), group=32)
    deq = np.asarray(qz.dequantize_q4(packed, scales, group=32))
    for g in range(2):
        blk = w[g * 32:(g + 1) * 32]
        err = np.abs(deq[g * 32:(g + 1) * 32] - blk)
        amax = np.max(np.abs(blk), axis=0)          # per-column group amax
        assert np.all(err <= amax / 14 + 1e-6), g


# ---------------------------------------------------------------------------
# Pallas dequant-fused matmul + the ag_matmul fast path
# ---------------------------------------------------------------------------

def test_q4_matmul_kernel_matches_dequant_reference():
    from repro.kernels.ops import q4_matmul
    rng = np.random.default_rng(17)
    a = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(64, 16)).astype(np.float32))
    packed, scales = qz.quantize_q4(w, group=32)
    ref = np.asarray(a @ qz.dequantize_q4(packed, scales, group=32))
    out = np.asarray(q4_matmul(a, packed, scales, group=32))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_ag_matmul_lossy_matches_host_quantized_reference():
    """``ag_matmul(..., precision="lossy")`` must equal the HOST-side
    quantize->dequantize matmul exactly (deterministic rounding): the
    collective wire format changes the bytes moved, not the math."""
    vc = VirtualCluster(pods=1, chips=4)
    if not vc.available():
        pytest.skip("needs 4 devices")
    comm = Communicator.from_cluster(vc)
    rng = np.random.default_rng(23)
    K, N, B = 4 * 64, 16, 3
    w = jnp.asarray(rng.normal(size=(K, N)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(B, K)).astype(np.float32))
    packed, scales = qz.quantize_q4(w, group=32)
    want = np.asarray(x @ qz.dequantize_q4(packed, scales, group=32))

    got = vc.run(
        lambda xs, ws: comm.ag_matmul(xs, ws, precision="lossy",
                                      q4_group=32),
        jnp.tile(x, (vc.num_devices, 1)), w,
        in_specs=(vc.spec, vc.spec))
    got = np.asarray(got).reshape(vc.num_devices, B, N)
    for r in range(vc.num_devices):
        np.testing.assert_allclose(got[r], want, rtol=1e-5, atol=1e-5)
    # exact path unchanged by the new keyword's default
    exact = vc.run(lambda xs, ws: comm.ag_matmul(xs, ws),
                   jnp.tile(x, (vc.num_devices, 1)), w,
                   in_specs=(vc.spec, vc.spec))
    np.testing.assert_allclose(
        np.asarray(exact).reshape(vc.num_devices, B, N)[0],
        np.asarray(x @ w), rtol=1e-4, atol=1e-4)
