"""scheme="auto" tuning-table dispatch: round-trip, interpolation,
modeled cold start, constraints, and the emit/staleness gates.

The resolution chain under test (``repro.comm.tuning.resolve``):
measured table entry (nearest size bucket) -> ``core.plans`` closed-form
prediction (unknown topology signature) -> static per-family fallback
(no static pods/chips counts at all).
"""

import json
import pathlib
import sys

import numpy as np
import pytest

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.bench import SCHEMA_VERSION as BENCH_SCHEMA
from repro.comm import Communicator, SharedWindow, tuning
from repro.core.plans import nearest_bucket, size_bucket
from repro.substrate import VirtualCluster, default_matrix

MATRIX = default_matrix()
REPO_SCRIPTS = pathlib.Path(__file__).resolve().parent.parent / "scripts"


# ---------------------------------------------------------------------------
# Pure helpers (plans.py)
# ---------------------------------------------------------------------------

def test_size_bucket_is_log2_rounded():
    assert size_bucket(4096) == 12
    assert size_bucket(4095) == 12          # nearest power of two
    assert size_bucket(6000) == 13          # rounds up past sqrt(2) mark
    assert size_bucket(1) == 0
    assert size_bucket(0) == 0


def test_nearest_bucket_ties_go_smaller():
    # 2^13 sits exactly between buckets 12 and 14 -> the smaller wins
    assert nearest_bucket(2 ** 13, [12, 14]) == 12
    assert nearest_bucket(100, [12, 18]) == 12
    assert nearest_bucket(10 ** 9, [12, 18]) == 18
    with pytest.raises(ValueError):
        nearest_bucket(64, [])


def test_topo_signature_distinguishes_factored_fast_tier():
    assert tuning.topo_signature(2, 4) == "2x4"
    assert tuning.topo_signature(2, 4, n_fast_axes=2) == "2x4-f2"
    assert tuning.topo_signature(1, 8) != tuning.topo_signature(8, 1)


# ---------------------------------------------------------------------------
# Synthetic bench reports (schema-shaped, controlled medians)
# ---------------------------------------------------------------------------

def _case(family, scheme, vc, elems, median, opts=None):
    return {"family": family, "scheme": scheme, "topology": vc.label,
            "pods": vc.pods, "chips": vc.chips,
            "fast_axes": len(vc.fast_names), "dtype": "float32",
            "elems": elems, "bytes_per_rank": elems * 4,
            "timing": {"median_us": median},
            "autotune": ({"param_grid": [dict(opts)], "best": dict(opts),
                          "results": []} if opts else None)}


def _report(cases):
    return {"schema": BENCH_SCHEMA, "generated_by": "test", "sweep": {},
            "jax_version": "test", "backend": "cpu", "cases": cases}


# a DIFFERENT winner per topology proves dispatch is per-signature, and
# pipelined's recorded n_chunks rides along through the autotune field
WINNERS = {"1x8": ("naive", {}), "2x4": ("shared", {}),
           "4x2": ("hier", {}), "8x1": ("pipelined", {"n_chunks": 2}),
           "2x(2x2)-pod.dp.tp": ("shared", {})}


def _matrix_report(elems=64):
    cases = []
    for vc in MATRIX:
        win, opts = WINNERS[vc.label]
        medians = {"naive": 40.0, "hier": 30.0, "shared": 20.0,
                   "pipelined": 25.0}
        medians[win] = 10.0               # force the intended winner
        for scheme, med in medians.items():
            cases.append(_case("allgather", scheme, vc, elems, med,
                               opts if scheme == "pipelined" else None))
    return _report(cases)


# ---------------------------------------------------------------------------
# Round-trip: emit -> save -> load -> dispatch picks the recorded winner
# ---------------------------------------------------------------------------

def test_emit_load_dispatch_round_trip_on_every_topology(tmp_path):
    table = tuning.TuningTable.from_bench_report(_matrix_report(),
                                                 source_name="synthetic")
    path = tmp_path / "TUNING.json"
    table.save(path)
    loaded = tuning.TuningTable.load(path)
    assert len(loaded) == len(MATRIX)
    assert loaded.meta["generated_from"] == "synthetic"
    for vc in MATRIX:
        comm = Communicator.from_cluster(vc)
        res = tuning.resolve_for(comm, "allgather", elems=64, table=loaded)
        want, opts = WINNERS[vc.label]
        assert res.scheme == want, vc.label
        assert res.source == "measured"
        if want == "pipelined":           # autotuned opts survive the fold
            assert res.opts == opts
        assert res.entry is not None and res.entry.label == vc.label


def test_dispatch_through_communicator_uses_the_table():
    """One end-to-end auto call per result class: the active table decides
    whether the caller gets a window or a replicated array."""
    vc = next(c for c in MATRIX if c.label == "2x4")
    if not vc.available():
        pytest.skip("needs 8 devices")
    comm = Communicator.from_cluster(vc)
    x = vc.rank_major_input(m=2, extra=2)
    table = tuning.TuningTable.from_bench_report(_matrix_report())
    with tuning.use_table(table):          # winner on 2x4: shared
        got = vc.run(lambda v: comm.allgather(v).shard, x)
        want = vc.run(lambda v: comm.allgather(v, scheme="shared").shard, x)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # same call, a table that crowns naive -> a replicated array comes back
    flip = _matrix_report()
    for case in flip["cases"]:
        if case["topology"] == "2x4":
            case["timing"]["median_us"] = \
                5.0 if case["scheme"] == "naive" else 50.0
    with tuning.use_table(tuning.TuningTable.from_bench_report(flip)):
        full = vc.run(lambda v: comm.allgather(v), x, out_specs=P(None))
        np.testing.assert_allclose(np.asarray(full), np.asarray(x))


# ---------------------------------------------------------------------------
# Nearest-bucket interpolation
# ---------------------------------------------------------------------------

def test_nearest_bucket_interpolation_at_unmeasured_sizes():
    vc = MATRIX[1]                         # 2x4
    small = _case("allgather", "naive", vc, 1024, 10.0)      # 4 KiB
    small2 = _case("allgather", "shared", vc, 1024, 20.0)
    big = _case("allgather", "naive", vc, 65536, 90.0)       # 256 KiB
    big2 = _case("allgather", "shared", vc, 65536, 30.0)
    table = tuning.TuningTable.from_bench_report(
        _report([small, small2, big, big2]))
    # below/near the small cell -> its winner (naive)
    for elems in (16, 1024, 4000):
        res = tuning.resolve(("allgather"), pods=2, chips=4, elems=elems,
                             table=table)
        assert (res.scheme, res.entry.nbytes) == ("naive", 4096), elems
    # near the big cell -> its winner (shared)
    for elems in (50000, 65536, 10 ** 6):
        res = tuning.resolve("allgather", pods=2, chips=4, elems=elems,
                             table=table)
        assert (res.scheme, res.entry.nbytes) == ("shared", 262144), elems
    # geometric midpoint (2^15 elems = bucket 17 bytes, equidistant from
    # buckets 12 and 18... pick the closer; exact ties go smaller)
    res = tuning.resolve("allgather", pods=2, chips=4, elems=2 ** 13,
                         table=table)
    assert res.entry.nbytes == 4096        # tie in log space -> smaller


# ---------------------------------------------------------------------------
# Modeled cold start + fallback
# ---------------------------------------------------------------------------

def test_modeled_fallback_on_unknown_topology_signature():
    table = tuning.TuningTable.from_bench_report(_matrix_report())
    assert "3x2" not in table.signatures()
    res = tuning.resolve("allgather", pods=3, chips=2, elems=64,
                         table=table)
    assert res.source == "modeled" and res.entry is None
    # the modeled pick is a real registry scheme that can run the cell
    from repro.comm import registry
    sch = registry.get_scheme(res.scheme)
    assert sch.candidates("allgather", pods=3, chips=2, elems=64)
    # empty table: every topology takes the modeled path
    with tuning.use_table(None):
        res = tuning.resolve("psum", pods=2, chips=4, elems=1024)
        assert res.source == "modeled"


def test_fallback_without_static_counts_matches_old_defaults():
    """A Communicator with no pods/chips (e.g. ParallelCtx's ad-hoc dp
    communicator) must behave exactly as the pre-auto hard-coded defaults
    did."""
    for family, want in (("allgather", "shared"), ("broadcast", "shared"),
                         ("psum", "shared"), ("alltoall", "hier")):
        res = tuning.resolve(family, pods=None, chips=None, elems=64)
        assert (res.scheme, res.source) == (want, "fallback"), family
    res = tuning.resolve("psum", pods=None, chips=None, elems=64,
                         result_class="replicated")
    assert res.scheme == "naive"
    with pytest.raises(ValueError, match="result"):
        tuning.resolve("alltoall", pods=None, chips=None, elems=64,
                       result_class="shared")


# ---------------------------------------------------------------------------
# Constraints: result class + tiling walk the ranking, never break it
# ---------------------------------------------------------------------------

def test_result_class_constraint_walks_the_ranking():
    table = tuning.TuningTable.from_bench_report(_matrix_report())
    # 2x4's measured winner is shared; a replicated-constrained caller
    # must get the best REPLICATED entry of the same cell instead
    res = tuning.resolve("allgather", pods=2, chips=4, elems=64,
                         result_class="replicated", table=table)
    assert res.scheme == "pipelined"       # 25us: best non-shared median
    assert res.source == "measured"


def test_tiling_filters_unrunnable_winner():
    """psum/shared needs chips | elems: a scalar dispatch must skip a
    recorded shared winner rather than fail to lower."""
    vc = MATRIX[1]
    cases = [_case("psum", "shared", vc, 1024, 10.0),
             _case("psum", "naive", vc, 1024, 40.0)]
    table = tuning.TuningTable.from_bench_report(_report(cases))
    res = tuning.resolve("psum", pods=2, chips=4, elems=1, table=table)
    assert res.scheme == "naive" and res.source == "measured"


def test_recorded_opts_revalidated_against_dispatch_size():
    """A pipelined winner recorded at n_chunks=8 must re-predict its chunk
    count when the dispatch size cannot tile 8 chunks."""
    vc = MATRIX[1]
    cases = [_case("allgather", "pipelined", vc, 1024, 10.0,
                   {"n_chunks": 8}),
             _case("allgather", "naive", vc, 1024, 40.0)]
    table = tuning.TuningTable.from_bench_report(_report(cases))
    res = tuning.resolve("allgather", pods=2, chips=4, elems=12,
                         table=table)   # 12 % 8 != 0
    assert res.scheme == "pipelined"
    assert res.opts["n_chunks"] in (1, 2, 4) and 12 % res.opts["n_chunks"] \
        == 0


def test_precision_exact_never_resolves_quantized():
    """The acceptance bar of the quantized wire formats: a default
    (``precision="exact"``) resolution must NEVER return a lossy scheme —
    not from a measured table that ranks one first, not from the modeled
    path, not from the committed table, on any matrix topology."""
    from repro.comm import registry
    vc = MATRIX[1]                          # 2x4
    cases = [_case("psum", "q8_hier", vc, 1024, 1.0),   # lossy ranked 1st
             _case("psum", "hier", vc, 1024, 30.0),
             _case("psum", "naive", vc, 1024, 40.0)]
    table = tuning.TuningTable.from_bench_report(_report(cases))
    res = tuning.resolve("psum", pods=2, chips=4, elems=1024, table=table)
    assert res.scheme == "hier" and res.source == "measured"
    # modeled path (empty table) + committed table, full matrix sweep
    tables = [tuning.TuningTable()]
    if tuning.default_table_path().exists():
        tables.append(tuning.TuningTable.load(tuning.default_table_path()))
    for tbl in tables:
        for cluster in MATRIX:
            for family in ("psum", "allgather"):
                for elems in (64, 1024, 65536):
                    res = tuning.resolve(
                        family, pods=cluster.pods, chips=cluster.chips,
                        elems=elems, n_fast_axes=len(cluster.fast_names),
                        table=tbl)
                    assert registry.get_scheme(res.scheme).precision \
                        == "exact", (cluster.label, family, elems,
                                     res.scheme)


def test_precision_lossy_walks_to_quantized_winner():
    vc = MATRIX[1]                          # 2x4
    cases = [_case("psum", "q8_hier", vc, 1024, 1.0),
             _case("psum", "hier", vc, 1024, 30.0)]
    table = tuning.TuningTable.from_bench_report(_report(cases))
    res = tuning.resolve("psum", pods=2, chips=4, elems=1024,
                         precision="lossy", table=table)
    assert res.scheme == "q8_hier" and res.source == "measured"
    # tol= caps the admitted error: q8 psum declares pods/254, so a
    # tolerance below that walks on to the exact runner-up
    res = tuning.resolve("psum", pods=2, chips=4, elems=1024,
                         precision="lossy", tol=1e-4, table=table)
    assert res.scheme == "hier"
    res = tuning.resolve("psum", pods=2, chips=4, elems=1024,
                         precision="lossy", tol=0.5, table=table)
    assert res.scheme == "q8_hier"


def test_precision_lossy_fallback_without_static_counts():
    """The reduce_grads dispatch shape: no pods/chips counts at all.
    Lossy opt-in compresses the bridge (q8), the exact default keeps the
    old fallback, and a shared-result caller never gets a replicated
    quantized scheme."""
    res = tuning.resolve("psum", pods=None, chips=None, elems=64,
                         precision="lossy")
    assert (res.scheme, res.source) == ("q8_hier", "fallback")
    assert tuning.resolve("psum", pods=None, chips=None, elems=64,
                          precision="lossy",
                          result_class="replicated").scheme == "q8_hier"
    assert tuning.resolve("psum", pods=None, chips=None,
                          elems=64).scheme == "shared"
    assert tuning.resolve("psum", pods=None, chips=None, elems=64,
                          precision="lossy",
                          result_class="shared").scheme == "shared"
    with pytest.raises(ValueError, match="precision"):
        tuning.resolve("psum", pods=2, chips=4, elems=64,
                       precision="fast-ish")


def test_concrete_scheme_with_wrong_result_constraint_raises():
    vc = MATRIX[1]
    if not vc.available():
        pytest.skip("needs 8 devices")
    comm = Communicator.from_cluster(vc)
    with pytest.raises(ValueError, match="replicated"):
        vc.run(lambda v: comm.allgather(v, scheme="shared",
                                        result="replicated").shard,
               vc.rank_major_input(m=1, extra=1))


# ---------------------------------------------------------------------------
# Emit CLI + winner cross-check + staleness gate
# ---------------------------------------------------------------------------

def test_emit_cli_round_trip_and_self_check(tmp_path):
    from repro.bench.__main__ import main
    bench = tmp_path / "bench.json"
    bench.write_text(json.dumps(_matrix_report()))
    out = tmp_path / "table.json"
    assert main(["--emit-tuning-table", "--bench", str(bench),
                 "--table-out", str(out)]) == 0
    table = json.loads(out.read_text())
    assert table["schema"] == tuning.SCHEMA_VERSION
    assert len(table["entries"]) == len(MATRIX)
    assert all(e["source"] == "measured" for e in table["entries"])


def test_tuning_table_checks_fail_on_disagreeing_winner():
    """validate.tuning_table_checks: a table whose recorded winner did NOT
    have the best pooled median in the run must fail."""
    from repro.bench.validate import tuning_table_checks
    rep = _matrix_report()
    table = tuning.TuningTable.from_bench_report(rep)
    assert all(ch.ok for ch in tuning_table_checks(table, rep))
    # now make the run disagree: naive suddenly 100x faster on 2x4
    for case in rep["cases"]:
        if case["topology"] == "2x4" and case["scheme"] == "naive":
            case["timing"]["median_us"] = 0.1
    bad = [ch for ch in tuning_table_checks(table, rep) if not ch.ok]
    assert bad and "2x4" in bad[0].name
    # zero overlap is itself a failure
    empty = _report([])
    checks = tuning_table_checks(table, empty)
    assert len(checks) == 1 and not checks[0].ok


def test_staleness_script_gates_committed_vs_fresh(tmp_path):
    sys.path.insert(0, str(REPO_SCRIPTS))
    import check_tuning_table as gate
    rep = _matrix_report()
    table = tuning.TuningTable.from_bench_report(rep)
    tpath = tmp_path / "TUNING.json"
    table.save(tpath)
    bpath = tmp_path / "fresh.json"
    bpath.write_text(json.dumps(rep))
    assert gate.main([str(tpath), "--schema-only"]) == 0
    assert gate.main([str(tpath), "--bench", str(bpath)]) == 0
    # fresh run flips the 2x4 winner far beyond the band -> stale
    for case in rep["cases"]:
        if case["topology"] == "2x4":
            case["timing"]["median_us"] = \
                1.0 if case["scheme"] == "naive" else 500.0
    bpath.write_text(json.dumps(rep))
    assert gate.main([str(tpath), "--bench", str(bpath),
                      "--tol", "3.0"]) == 1
    # schema gate has teeth: break the ranking order
    broken = json.loads(tpath.read_text())
    broken["entries"][0]["ranking"].reverse()
    tpath.write_text(json.dumps(broken))
    assert gate.main([str(tpath), "--schema-only"]) == 1


def test_committed_default_table_resolves_the_full_matrix():
    """The COMMITTED TUNING_default.json must cover every default_matrix()
    topology signature and resolve every op family on it (measured or —
    after a tiling walk-off — at worst modeled)."""
    path = tuning.default_table_path()
    if not path.exists():
        pytest.skip("no committed TUNING_default.json")
    table = tuning.TuningTable.load(path)
    for vc in MATRIX:
        comm = Communicator.from_cluster(vc)
        for family in ("allgather", "broadcast", "psum", "reduce_scatter",
                       "allgatherv", "alltoall"):
            res = tuning.resolve_for(comm, family, elems=1024, table=table)
            assert res.scheme, (vc.label, family)
            assert res.source == "measured", (vc.label, family, res.source)


# ---------------------------------------------------------------------------
# Serving: mesh-side window materialization dispatches through auto
# ---------------------------------------------------------------------------

def test_materialize_params_on_mesh_reads_multichip_windows():
    from repro.serving.engine import (materialize_params,
                                      materialize_params_on_mesh)
    vc = VirtualCluster(pods=1, chips=4)
    if not vc.available():
        pytest.skip("needs 4 devices")
    comm = Communicator.from_cluster(vc)
    w = jnp.arange(8 * 3, dtype=jnp.float32).reshape(8, 3)
    params = {"w": SharedWindow(comm, w, axis=0, epoch=1),
              "b": jnp.ones((3,))}
    with pytest.raises(ValueError, match="SharedWindow"):
        materialize_params(params)        # single-device path still refuses
    out = materialize_params_on_mesh(params, vc)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(w))
    np.testing.assert_allclose(np.asarray(out["b"]), 1.0)
    # a sharded dim other than 0 round-trips too
    w2 = jnp.arange(2 * 8, dtype=jnp.float32).reshape(2, 8)
    out2 = materialize_params_on_mesh(
        {"w": SharedWindow(comm, w2, axis=1, epoch=1)}, vc)
    np.testing.assert_allclose(np.asarray(out2["w"]), np.asarray(w2))
    # epoch integrity holds on the mesh path exactly as off it
    with pytest.raises(ValueError, match="dirty"):
        materialize_params_on_mesh(
            {"w": SharedWindow(comm, w, epoch=1, dirty=True)}, vc)
