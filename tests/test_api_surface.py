"""The api-surface check (scripts/check_api_surface.py) as a tier-1 test:
no module outside ``repro/comm`` (and the deprecated shim) may pass raw
``fast_axis=``/``slow_axis=`` kwargs — collectives go through the
``Communicator``.  CI runs the same script in the fast lane."""

import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "scripts"))

import check_api_surface  # noqa: E402


def test_repo_api_surface_is_clean():
    assert check_api_surface.violations(REPO) == []


def test_check_catches_a_violation(tmp_path):
    bad = tmp_path / "src" / "repro" / "runtime"
    bad.mkdir(parents=True)
    (bad / "rogue.py").write_text(
        "from repro.comm import primitives as p\n"
        "def f(x):\n"
        "    return p.naive_all_gather(x, fast_axis='data', "
        "slow_axis='pod')\n")
    hits = check_api_surface.violations(tmp_path)
    assert len(hits) == 1 and "rogue.py:3" in hits[0]
    assert check_api_surface.main([str(tmp_path)]) == 1


def test_check_catches_violation_before_constructor_same_line(tmp_path):
    # models/: outside the ctor-scan paths, so only the kwarg rule fires
    bad = tmp_path / "src" / "repro" / "models"
    bad.mkdir(parents=True)
    (bad / "mixed.py").write_text(
        "y = p.naive_all_gather(x, fast_axis='d'); "
        "c = Communicator(fast_axis='d')\n")
    hits = check_api_surface.violations(tmp_path)
    assert len(hits) == 1 and "mixed.py:1" in hits[0]


def test_check_catches_violation_after_constructor_same_line(tmp_path):
    bad = tmp_path / "src" / "repro" / "models"
    bad.mkdir(parents=True)
    (bad / "trailing.py").write_text(
        "c = Communicator(fast_axis='d'); "
        "y = p.naive_all_gather(x, fast_axis='d')\n")
    hits = check_api_surface.violations(tmp_path)
    assert len(hits) == 1 and "trailing.py:1" in hits[0]


def test_check_allows_constructor_spellings(tmp_path):
    ok = tmp_path / "src" / "repro" / "models"
    ok.mkdir(parents=True)
    (ok / "fine.py").write_text(
        "from repro.comm import Communicator\n"
        "from repro.substrate import VirtualCluster\n"
        "vc = VirtualCluster(pods=2, chips=4, fast_axis=('dp', 'tp'),\n"
        "                    fast_shape=(2, 2), slow_axis='pod')\n"
        "comm = Communicator(fast_axis='data', slow_axis='pod')\n"
        "fast_axis: str = 'data'   # annotated field, not a call kwarg\n")
    assert check_api_surface.violations(tmp_path) == []
    assert check_api_surface.main([str(tmp_path)]) == 0


# ---- bare-Communicator() check on the rebuild paths -------------------------
def test_ctor_caught_in_runtime_and_launch(tmp_path):
    for rel in ("src/repro/runtime", "src/repro/launch"):
        d = tmp_path / rel
        d.mkdir(parents=True)
        (d / "rogue.py").write_text(
            "from repro.comm import Communicator\n"
            "world = Communicator(fast_axis='data', slow_axis='pod')\n")
    hits = check_api_surface.ctor_violations(tmp_path)
    assert len(hits) == 2
    assert all("rogue.py:2" in h for h in hits)
    assert check_api_surface.main([str(tmp_path)]) == 1


def test_ctor_blessed_classmethods_allowed(tmp_path):
    ok = tmp_path / "src" / "repro" / "runtime"
    ok.mkdir(parents=True)
    (ok / "fine.py").write_text(
        "from repro.comm import Communicator\n"
        "world = Communicator.from_cluster(vc)\n"
        "topo_world = Communicator.from_topology(topo)\n"
        "node = world.split_type_shared()\n"
        "# a comment naming Communicator(fast_axis='d') is not a call\n")
    assert check_api_surface.ctor_violations(tmp_path) == []
    assert check_api_surface.main([str(tmp_path)]) == 0


def test_ctor_bare_allowed_outside_rebuild_paths(tmp_path):
    ok = tmp_path / "src" / "repro" / "models"
    ok.mkdir(parents=True)
    (ok / "wrapper.py").write_text(
        "from repro.comm import Communicator\n"
        "tp_comm = Communicator(fast_axis='model')\n")
    assert check_api_surface.ctor_violations(tmp_path) == []


# ---- raw lax.psum / lax.all_gather check ------------------------------------
def test_raw_collective_caught(tmp_path):
    bad = tmp_path / "src" / "repro" / "models"
    bad.mkdir(parents=True)
    (bad / "rogue.py").write_text(
        "from jax import lax\n"
        "def f(x):\n"
        "    return lax.psum(x, 'data')\n"
        "def g(x):\n"
        "    return lax.all_gather(x, 'data', axis=0, tiled=True)\n")
    hits = check_api_surface.raw_violations(tmp_path)
    assert len(hits) == 2
    assert "rogue.py:3" in hits[0] and "rogue.py:5" in hits[1]
    assert check_api_surface.main([str(tmp_path)]) == 1


def test_raw_collective_pragma_allows(tmp_path):
    ok = tmp_path / "src" / "repro" / "models"
    ok.mkdir(parents=True)
    (ok / "fine.py").write_text(
        "from jax import lax\n"
        "def f(x):\n"
        "    return lax.psum(x, 'tp')  # raw-collective: tp fast path\n")
    assert check_api_surface.raw_violations(tmp_path) == []
    assert check_api_surface.main([str(tmp_path)]) == 0


def test_raw_collective_allowed_paths(tmp_path):
    for rel in ("src/repro/comm", "src/repro/substrate",
                "src/repro/kernels"):
        d = tmp_path / rel
        d.mkdir(parents=True)
        (d / "impl.py").write_text(
            "from jax import lax\n"
            "def f(x):\n"
            "    return lax.psum(x, 'data')\n")
    assert check_api_surface.raw_violations(tmp_path) == []


def test_raw_collective_commented_call_not_flagged(tmp_path):
    ok = tmp_path / "src" / "repro" / "models"
    ok.mkdir(parents=True)
    (ok / "doc.py").write_text(
        "# the old path used lax.psum(x, 'data') directly\n"
        "def f(x):\n"
        "    return x\n")
    assert check_api_surface.raw_violations(tmp_path) == []


def test_raw_collective_pragma_on_preceding_line_allows(tmp_path):
    ok = tmp_path / "src" / "repro" / "models"
    ok.mkdir(parents=True)
    (ok / "long.py").write_text(
        "from jax import lax\n"
        "def f(x):\n"
        "    # raw-collective: call line too long for an inline pragma\n"
        "    return lax.psum(x, ('pod', 'data', 'model', 'extra_axis'))\n"
        "def g(x):\n"
        "    return lax.psum(x, 'data')   # two lines below the pragma:\n")
    hits = check_api_surface.raw_violations(tmp_path)
    assert len(hits) == 1 and "long.py:6" in hits[0]
