"""The api-surface check (scripts/check_api_surface.py) as a tier-1 test:
no module outside ``repro/comm`` (and the deprecated shim) may pass raw
``fast_axis=``/``slow_axis=`` kwargs — collectives go through the
``Communicator``.  CI runs the same script in the fast lane."""

import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "scripts"))

import check_api_surface  # noqa: E402


def test_repo_api_surface_is_clean():
    assert check_api_surface.violations(REPO) == []


def test_check_catches_a_violation(tmp_path):
    bad = tmp_path / "src" / "repro" / "runtime"
    bad.mkdir(parents=True)
    (bad / "rogue.py").write_text(
        "from repro.comm import primitives as p\n"
        "def f(x):\n"
        "    return p.naive_all_gather(x, fast_axis='data', "
        "slow_axis='pod')\n")
    hits = check_api_surface.violations(tmp_path)
    assert len(hits) == 1 and "rogue.py:3" in hits[0]
    assert check_api_surface.main([str(tmp_path)]) == 1


def test_check_catches_violation_before_constructor_same_line(tmp_path):
    bad = tmp_path / "src" / "repro" / "runtime"
    bad.mkdir(parents=True)
    (bad / "mixed.py").write_text(
        "y = p.naive_all_gather(x, fast_axis='d'); "
        "c = Communicator(fast_axis='d')\n")
    hits = check_api_surface.violations(tmp_path)
    assert len(hits) == 1 and "mixed.py:1" in hits[0]


def test_check_catches_violation_after_constructor_same_line(tmp_path):
    bad = tmp_path / "src" / "repro" / "runtime"
    bad.mkdir(parents=True)
    (bad / "trailing.py").write_text(
        "c = Communicator(fast_axis='d'); "
        "y = p.naive_all_gather(x, fast_axis='d')\n")
    hits = check_api_surface.violations(tmp_path)
    assert len(hits) == 1 and "trailing.py:1" in hits[0]


def test_check_allows_constructor_spellings(tmp_path):
    ok = tmp_path / "src" / "repro" / "runtime"
    ok.mkdir(parents=True)
    (ok / "fine.py").write_text(
        "from repro.comm import Communicator\n"
        "from repro.substrate import VirtualCluster\n"
        "vc = VirtualCluster(pods=2, chips=4, fast_axis=('dp', 'tp'),\n"
        "                    fast_shape=(2, 2), slow_axis='pod')\n"
        "comm = Communicator(fast_axis='data', slow_axis='pod')\n"
        "fast_axis: str = 'data'   # annotated field, not a call kwarg\n")
    assert check_api_surface.violations(tmp_path) == []
    assert check_api_surface.main([str(tmp_path)]) == 0
