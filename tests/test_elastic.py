"""Elastic fault-tolerant runtime: fault injection, communicator rebuild,
checkpointed recovery (``repro.runtime.elastic``).

Lane split (CI): the unmarked tests are the fast lane's fault-injection
smoke — plan grammar, event registration, one end-to-end pod-loss recovery
on the seed 2x4 shape.  The ``slow``-marked tests are the kill-a-pod-mid-
step matrix: over every multi-pod cluster of the topology matrix, lose a
node mid-run and prove the continued loss trajectory is BIT-IDENTICAL to a
reference run that started on the shrunk topology at the restored step —
plus the straggler-eviction and torn-checkpoint recovery interactions.
"""

import logging

import jax
import pytest

from repro.configs import get_config
from repro.runtime.elastic import (EVENT_HANDLERS, ElasticRuntime,
                                   FaultEvent, FaultPlan, register_event,
                                   reference_run)
from repro.runtime.fault_tolerance import StragglerPolicy
from repro.runtime.train_loop import train_elastic
from repro.substrate.cluster import VirtualCluster, default_matrix


def tiny_cfg():
    return get_config("qwen3-0.6b").reduced(n_layers=2, d_model=64,
                                            n_heads=4)


def _require(vc):
    if jax.device_count() < vc.num_devices:
        pytest.skip(f"needs {vc.num_devices} devices")


# ---------------------------------------------------------------------------
# FaultPlan grammar (pure python, no jax)
# ---------------------------------------------------------------------------

def test_fault_plan_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan((FaultEvent(kind="asteroid", step=3),))


def test_fault_plan_rejects_negative_step():
    with pytest.raises(ValueError, match="step"):
        FaultPlan((FaultEvent.pod_loss(-1),))


def test_fault_plan_fires_each_event_once():
    plan = FaultPlan((FaultEvent.pod_loss(3), FaultEvent.torn_checkpoint(3),
                      FaultEvent.host_slowdown(5, 1, factor=2.0)))
    fired = set()
    first = plan.pending(3, fired)
    assert [ev.kind for _, ev in first] == ["pod_loss", "torn_checkpoint"]
    for idx, _ in first:
        fired.add(idx)
    # a recovery replaying step 3 must not re-fire consumed events
    assert plan.pending(3, fired) == []
    assert [ev.kind for _, ev in plan.pending(5, fired)] == \
        ["host_slowdown"]


def test_event_constructors_fill_kind_fields():
    ev = FaultEvent.host_slowdown(7, 2, factor=3.0, duration=4)
    assert (ev.kind, ev.step, ev.host, ev.factor, ev.duration) == \
        ("host_slowdown", 7, 2, 3.0, 4)
    assert FaultEvent.pod_loss(1, pod=0).pod == 0
    assert FaultEvent.torn_checkpoint(2).kind == "torn_checkpoint"


def test_new_failure_kind_is_one_registration():
    """The extension contract: a new failure kind is ONE ``@register_event``
    — the plan validates it and the dispatch loop routes it, no other
    change anywhere."""
    calls = []

    @register_event("power_blip")
    def _blip(rt, ev):
        calls.append(ev.step)

    try:
        plan = FaultPlan((FaultEvent(kind="power_blip", step=4),))
        fired = set()
        for idx, ev in plan.pending(4, fired):
            fired.add(idx)
            EVENT_HANDLERS[ev.kind](None, ev)
        assert calls == [4]
        assert plan.pending(4, fired) == []
    finally:
        EVENT_HANDLERS.pop("power_blip", None)


# ---------------------------------------------------------------------------
# Fast-lane fault-injection smoke: one pod-loss recovery, end to end
# ---------------------------------------------------------------------------

def test_pod_loss_recovery_smoke(tmp_path, caplog):
    vc = VirtualCluster(pods=2, chips=4)
    _require(vc)
    plan = FaultPlan((FaultEvent.pod_loss(3, pod=1),))
    with caplog.at_level(logging.INFO, logger="repro.comm.tuning"):
        rep = train_elastic(tiny_cfg(), vc, steps=6,
                            ckpt_dir=str(tmp_path / "ckpt"), plan=plan,
                            save_every=2, global_batch=8, seq=16)
    assert len(rep.recoveries) == 1
    rec = rep.recoveries[0]
    assert rec.cause == "pod_loss" and rec.lost_pod == 1
    assert (rec.old_signature, rec.new_signature) == ("2x4", "1x4")
    assert rec.restored_step == 2
    # the shrunk signature is unseen: re-tune degrades to modeled, logged,
    # never a crash
    assert rec.retune.sources.get("modeled", 0) > 0
    assert "signature not in tuning table" in caplog.text
    # the loop replayed 2..5 on the survivor and finished
    assert sorted(rep.losses) == list(range(6))
    assert rep.cluster_label == "1x4" and rep.signature == "1x4"


# ---------------------------------------------------------------------------
# Slow lane: kill-a-pod-mid-step over the topology matrix, bit-identity
# ---------------------------------------------------------------------------

MULTI_POD = [vc for vc in default_matrix() if vc.pods > 1]


@pytest.mark.slow
@pytest.mark.parametrize("vc", MULTI_POD, ids=[vc.label for vc in MULTI_POD])
def test_kill_a_pod_mid_step_bit_identity(vc, tmp_path):
    """Lose the last pod mid-run; the recovered trajectory must equal — as
    exact floats — a reference run that STARTED on the shrunk topology at
    the restored step.  Identical restored state re-sharded onto the same
    mesh + identical re-recorded program + pure-function-of-step data
    stream leaves no room for drift."""
    _require(vc)
    cfg = tiny_cfg()
    plan = FaultPlan((FaultEvent.pod_loss(5, pod=vc.pods - 1),))
    rep = train_elastic(cfg, vc, steps=8, ckpt_dir=str(tmp_path / "ckpt"),
                        plan=plan, save_every=2, global_batch=8, seq=16)
    assert len(rep.recoveries) == 1
    rec = rep.recoveries[0]
    assert rec.old_signature != rec.new_signature
    # every shrunk signature is outside TUNING_default.json's sweep: the
    # re-resolution must fall to modeled pricing (and say so), not crash
    assert rec.retune.sources.get("modeled", 0) > 0
    assert rec.retune.signature == rec.new_signature

    survivor = vc.without_pod(vc.pods - 1)
    ref = reference_run(cfg, survivor, ckpt_dir=str(tmp_path / "ckpt"),
                        from_step=rec.restored_step, steps=8,
                        global_batch=8, seq=16)
    assert ref.start_step == rec.restored_step
    for s in sorted(ref.losses):
        assert rep.losses[s] == ref.losses[s], \
            f"step {s}: {rep.losses[s]} != {ref.losses[s]}"


@pytest.mark.slow
def test_straggler_eviction_triggers_elastic_shrink(tmp_path):
    """StragglerPolicy -> elastic-shrink interaction: a scripted slowdown
    drives the watchdog to evict a host; the evicted host's pod leaves the
    cluster, the signature changes, and tuning falls to modeled without
    error."""
    vc = VirtualCluster(pods=4, chips=2)
    _require(vc)
    plan = FaultPlan((FaultEvent.host_slowdown(2, 3, factor=8.0,
                                               duration=10),))
    rt = ElasticRuntime(tiny_cfg(), vc, ckpt_dir=str(tmp_path / "ckpt"),
                        plan=plan, save_every=2, global_batch=8, seq=16,
                        straggler_factory=lambda: StragglerPolicy(
                            patience=2))
    rep = rt.run(6)
    assert len(rep.recoveries) == 1
    rec = rep.recoveries[0]
    assert rec.cause == "straggler" and rec.lost_pod == 3
    assert (rec.old_signature, rec.new_signature) == ("4x2", "3x2")
    assert rec.retune.sources.get("modeled", 0) > 0
    assert sorted(rep.losses) == list(range(6))
    assert rep.cluster_label == "3x2"


@pytest.mark.slow
def test_torn_checkpoint_falls_back_during_recovery(tmp_path):
    """A torn newest checkpoint discovered during recovery costs one save
    interval, not the run: restore discards it with a warning, falls back
    to the previous intact step, and the recovery record names both the
    torn step and the stale saves invalidated after the fallback."""
    vc = VirtualCluster(pods=2, chips=4)
    _require(vc)
    plan = FaultPlan((FaultEvent.torn_checkpoint(5),
                      FaultEvent.pod_loss(5, pod=0)))
    rt = ElasticRuntime(tiny_cfg(), vc, ckpt_dir=str(tmp_path / "ckpt"),
                        plan=plan, save_every=2, global_batch=8, seq=16)
    rep = rt.run(7)
    assert len(rep.recoveries) == 1
    rec = rep.recoveries[0]
    assert rec.torn_discarded == (4,)        # the torn step, by name
    assert rec.restored_step == 2            # previous intact step
    assert 4 in rec.stale_dropped            # torn step invalidated on disk
    # replay 2..6 completed on the survivor
    assert sorted(rep.losses) == list(range(7))
