"""Multi-device collective correctness (subprocess: 8 fake CPU devices).

The main pytest process keeps 1 device (smoke tests must see 1 device); the
hier/shared/naive collective equivalence checks run in a child process that
sets XLA_FLAGS before importing jax.
"""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_multidevice_collectives():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", "_multidevice_checks.py")],
        capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, (
        f"multidevice checks failed:\nSTDOUT:\n{proc.stdout}\n"
        f"STDERR:\n{proc.stderr}")
    assert "ALL OK" in proc.stdout
