"""Single-device isolation smoke (the one remaining subprocess entry).

The main pytest process forces 8 fake CPU devices (conftest) so the
VirtualCluster topology matrix runs in-process — see
``test_collectives_matrix.py``.  This test is the converse guard: a child
process with the force flag stripped verifies the library — compat layer,
mesh construction, single-node collective paths — on a genuine 1-device
host.
"""

import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = r"""
import jax
import numpy as np
from jax.sharding import PartitionSpec as P

assert jax.device_count() == 1, f"expected 1 device, got {jax.device_count()}"

from repro.comm import Communicator
from repro.launch.mesh import make_mesh_from_topo
from repro.core.topology import MeshTopology
from repro.substrate import VirtualCluster

vc = VirtualCluster(pods=1, chips=1, fast_axis="data")
comm = Communicator.from_cluster(vc)
x = vc.rank_major_input(m=4, extra=2)

out = vc.run(lambda v: comm.allgather(v, scheme="hier"),
             x, out_specs=P(None))
np.testing.assert_allclose(out, np.asarray(x))

out = vc.run(lambda v: comm.allgather(v, scheme="shared").read(),
             x, out_specs=P(None))
np.testing.assert_allclose(out, np.asarray(x))

out = vc.run(lambda v: comm.allreduce(v, scheme="hier"),
             x, out_specs=P(None))
np.testing.assert_allclose(out, np.asarray(x))

out = vc.run(lambda v: comm.alltoall(v, scheme="hier"),
             x, out_specs=vc.spec)
np.testing.assert_allclose(out, np.asarray(x))

# production mesh path builds on 1 device too
make_mesh_from_topo(MeshTopology({"data": 1, "model": 1}, slow_axes=()))
print("SINGLE-DEVICE OK", jax.__version__)
"""


def test_single_device_isolation():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    env["JAX_PLATFORMS"] = "cpu"  # a GPU host would report >1 device
    proc = subprocess.run([sys.executable, "-c", _SCRIPT],
                          capture_output=True, text=True, env=env,
                          timeout=600)
    assert proc.returncode == 0, (
        f"single-device smoke failed:\nSTDOUT:\n{proc.stdout}\n"
        f"STDERR:\n{proc.stderr}")
    assert "SINGLE-DEVICE OK" in proc.stdout
