"""repro.bench: timer regressions, report schema, validation teeth.

The timer tests are regressions for the seed ``_collective_bench.timeit``
bugs: warmup evaluated ``fn(*xs)`` up to three times, and only the FIRST
output leaf was blocked on.  The validation tests prove the traffic
cross-check actually fails on a mismatch (it must — the bench's whole
value is that a number that disagrees with the model never gets written).
"""

import dataclasses
import json

import pytest

import jax.numpy as jnp

from repro.bench import SCHEMA_VERSION, report, runner, suites
from repro.bench.validate import BenchValidationError
from repro.substrate import VirtualCluster


# ---------------------------------------------------------------------------
# runner.timeit regressions
# ---------------------------------------------------------------------------

class _Leaf:
    """Pytree leaf that counts block_until_ready calls."""

    def __init__(self):
        self.blocked = 0

    def block_until_ready(self):
        self.blocked += 1
        return self


def test_timer_single_warmup_and_blocks_every_leaf():
    a, b, c = _Leaf(), _Leaf(), _Leaf()
    calls = []

    def fn():
        calls.append(1)
        return {"x": a, "y": (b, [c])}

    res = runner.timeit(fn, reps=4)
    # seed bug 1: warmup called fn up to 3x.  Exactly 1 warmup + 4 reps:
    assert len(calls) == 5
    # seed bug 2: only leaves[0] was blocked.  Every leaf, every call:
    assert a.blocked == b.blocked == c.blocked == 5
    assert res.reps == 4 and res.inner == 1
    assert res.min_us <= res.median_us <= res.max_us


def test_timer_tuple_output_real_arrays():
    x = jnp.arange(64.0)
    res = runner.timeit(lambda: (x * 2.0, {"y": x + 1.0}), reps=2)
    assert res.median_us > 0.0
    assert res.iqr_us >= 0.0


def test_timer_calibrates_inner_loop_for_tiny_fns():
    res = runner.timeit(lambda: None, reps=2, min_rep_s=1e-3)
    assert res.inner > 1


def test_timer_rejects_bad_reps():
    with pytest.raises(ValueError):
        runner.timeit(lambda: None, reps=0)


def test_timer_warmup_false_adds_no_extra_call():
    """run_suite executes each compiled case once for shard inspection and
    passes warmup=False: the sweep's per-case call count must be exactly
    that one execution + reps."""
    calls = []
    runner.timeit(lambda: calls.append(1), reps=3, warmup=False)
    assert len(calls) == 3
    res = runner.timeit(lambda: None, reps=3, warmup=False, min_rep_s=1e-3)
    assert res.inner > 1               # calibrated off the first timed rep


# ---------------------------------------------------------------------------
# Suite + report schema (golden)
# ---------------------------------------------------------------------------

_TOP_KEYS = {"schema", "generated_by", "jax_version", "backend",
             "device_count", "sweep", "matrix", "cases", "cross_checks",
             "validation"}
_CASE_KEYS = {"name", "csv_name", "family", "scheme", "topology", "pods",
              "chips", "elems", "bytes_per_rank", "dtype", "fast_axes",
              "populations", "timing", "traffic", "hlo", "checks",
              "autotune", "serving", "ok"}
_TIMING_KEYS = {"median_us", "mean_us", "min_us", "max_us", "iqr_us",
                "p50_us", "p99_us", "reps", "inner"}
_TRAFFIC_KEYS = {"slow_bytes", "fast_bytes", "result_bytes_per_node"}
_HLO_KEYS = {"fast_link_bytes_per_chip", "slow_link_bytes_per_chip",
             "fast_link_bytes_total", "slow_link_bytes_total", "by_op",
             "result_bytes_per_node"}
_CHECK_KEYS = {"name", "expected", "measured", "ok", "note"}
_CHECK_KEYS_1SIDED = _CHECK_KEYS | {"one_sided"}    # error/bound ceilings


@pytest.fixture(scope="module")
def small_suite():
    vc = VirtualCluster(pods=2, chips=2)
    cases = suites.build_cases(clusters=(vc,),
                               families=("allgather", "allgatherv"),
                               elems=(64,))
    return suites.run_suite(cases, reps=2)


def test_report_schema_golden(small_suite):
    suite = small_suite
    rep = report.to_report(suite, quick=True, reps=2,
                           families=("allgather", "allgatherv"), elems=(64,))
    assert rep["schema"] == SCHEMA_VERSION
    assert set(rep) == _TOP_KEYS
    assert rep["matrix"] == ["2x2"]
    # 4 exact + 3 quantized allgather schemes + 2 allgatherv schemes
    assert len(rep["cases"]) == 9
    for case in rep["cases"]:
        assert set(case) == _CASE_KEYS
        assert set(case["timing"]) == _TIMING_KEYS
        assert set(case["traffic"]) == _TRAFFIC_KEYS
        assert set(case["hlo"]) == _HLO_KEYS
        for ch in case["checks"]:
            assert set(ch) == (_CHECK_KEYS_1SIDED if ch.get("one_sided")
                               else _CHECK_KEYS)
        assert case["ok"] is True
    assert rep["validation"]["ok"] is True
    assert rep["validation"]["num_checks"] > 0
    assert {"C1", "C2", "bridge"} <= set(rep["validation"]["invariants"])
    json.dumps(rep)                    # fully serializable


def test_csv_rows_format_and_fixed_copies_column(small_suite):
    suite = small_suite
    rows = report.csv_rows(suite)
    assert len(rows) == 9
    by_name = {}
    for row in rows:
        name, us, derived = row.split(",", 2)
        assert name == suites.slug(name)       # run.py-matchable
        float(us)
        by_name[name] = dict(kv.split("=") for kv in derived.split(";"))
    # the fixed fig7 column: copies of the FULL result per node (C1),
    # NOT rank-contribution counts
    assert by_name["allgather_naive_2x2_64"]["copies_per_node"] == "2"
    assert by_name["allgather_shared_2x2_64"]["copies_per_node"] == "1"


# ---------------------------------------------------------------------------
# Validation teeth: a mismatch must fail the run
# ---------------------------------------------------------------------------

def test_validation_catches_traffic_model_mismatch():
    vc = VirtualCluster(pods=2, chips=2)
    shared = [c for c in suites.allgather_cases(vc, 64)
              if c.scheme == "shared"][0]
    bad = dataclasses.replace(
        shared, traffic=dataclasses.replace(
            shared.traffic, slow_bytes=shared.traffic.slow_bytes + 4096))
    with pytest.raises(BenchValidationError, match="model/bridge-bytes"):
        suites.run_suite([bad], reps=1)


def test_validation_catches_wrong_lowering():
    """A case claiming to be 'shared' but lowering the naive flat gather
    must trip both the link check and the measured C1 ratio."""
    vc = VirtualCluster(pods=2, chips=2)
    by_scheme = {c.scheme: c for c in suites.allgather_cases(vc, 64)}
    naive, shared = by_scheme["naive"], by_scheme["shared"]
    impostor = dataclasses.replace(naive, scheme="shared",
                                   traffic=shared.traffic)
    with pytest.raises(BenchValidationError, match="C1/allgather"):
        suites.run_suite([naive, impostor], reps=1)


def test_no_validate_skips_checks():
    vc = VirtualCluster(pods=2, chips=2)
    cases = list(suites.allgather_cases(vc, 64))[:1]
    suite = suites.run_suite(cases, reps=1, validate=False)
    assert suite.cases[0].checks == []
    assert suite.cross_checks == []


# ---------------------------------------------------------------------------
# Autotune sweep + skip-and-log + the reduce_scatter family
# ---------------------------------------------------------------------------

def test_autotune_records_every_candidate_and_picks_best():
    """A tunable scheme (pipelined) is swept per cell: every candidate
    timed, the best median recorded, the grid in the JSON record."""
    vc = VirtualCluster(pods=2, chips=2)
    cases = [c for c in suites.allgather_cases(vc, 64)
             if c.scheme == "pipelined"]
    assert len(cases) == 1
    assert cases[0].tunable_grid == ({"n_chunks": 1}, {"n_chunks": 2},
                                     {"n_chunks": 4}, {"n_chunks": 8})
    suite = suites.run_suite(cases, reps=2)
    at = suite.cases[0].autotune
    assert at is not None
    assert [r["n_chunks"] for r in at["results"]] == [1, 2, 4, 8]
    assert all(r["median_us"] > 0 for r in at["results"])
    best_us = min(r["median_us"] for r in at["results"])
    assert suite.cases[0].timing.median_us == best_us
    assert at["best"] in at["param_grid"]
    rec = report.case_record(suite.cases[0])
    assert rec["autotune"] == at
    # untunable schemes carry no autotune record
    naive = [c for c in suites.allgather_cases(vc, 64)
             if c.scheme == "naive"]
    assert suites.run_suite(naive, reps=1).cases[0].autotune is None


def test_indivisible_cells_skip_and_log_instead_of_raising():
    """Irregular sizes enter the sweep: schemes whose tiling divisor does
    not divide elems are skipped-and-logged; the rest of the cell runs."""
    vc = VirtualCluster(pods=2, chips=4)
    skips = []
    cases = suites.build_cases(clusters=(vc,), elems=(6,),
                               on_skip=skips.append)
    assert cases                                   # the cell still runs
    built = {(c.family, c.scheme) for c in cases}
    assert ("psum", "shared") not in built         # 6 % 4 != 0
    assert ("reduce_scatter", "naive") not in built  # 6 % 8 != 0
    assert ("allgather", "naive") in built
    assert any("psum/shared" in m for m in skips)
    assert all("skip" in m for m in skips)
    suite = suites.run_suite(cases, reps=1)        # and validates clean
    assert all(ch.ok for r in suite.cases for ch in r.checks)


def test_schemes_filter_and_unknown_scheme_rejected():
    vc = VirtualCluster(pods=2, chips=2)
    cases = suites.build_cases(clusters=(vc,), elems=(64,),
                               families=("allgather",),
                               schemes=("pipelined", "hier"))
    assert {c.scheme for c in cases} == {"pipelined", "hier"}
    with pytest.raises(ValueError, match="unknown schemes"):
        suites.build_cases(clusters=(vc,), elems=(64,),
                           schemes=("warp",))


def test_reduce_scatter_family_cross_checks():
    """The new family validates end-to-end: links, the registry-ratio C1
    (flat keeps 1/num_nodes of the window's resident bytes), and the
    naive/pipelined replicates-identity."""
    vc = VirtualCluster(pods=2, chips=2)
    cases = suites.build_cases(clusters=(vc,), elems=(64,),
                               families=("reduce_scatter",))
    assert {c.scheme for c in cases} == {"naive", "shared", "pipelined"}
    suite = suites.run_suite(cases, reps=1)
    c1 = [ch for ch in suite.cross_checks
          if ch.name.startswith("C1/reduce_scatter")]
    assert c1 and all(ch.ok for ch in c1)
    # flat slices: node keeps msg/num_nodes, window keeps the whole msg
    assert c1[0].expected == 1 / vc.pods


# ---------------------------------------------------------------------------
# Perf-regression gate (scripts/check_bench_regression.py)
# ---------------------------------------------------------------------------

def _fake_report(medians: dict) -> dict:
    """medians: (family, scheme, topology, elems) -> median_us."""
    return {"schema": SCHEMA_VERSION,
            "cases": [{"family": f, "scheme": s, "topology": t, "elems": e,
                       "timing": {"median_us": us}}
                      for (f, s, t, e), us in medians.items()]}


def _gate(tmp_path, base, fresh, *extra):
    import sys
    sys.path.insert(0, str(REPO_SCRIPTS))
    import check_bench_regression as gate
    b, f = tmp_path / "base.json", tmp_path / "fresh.json"
    b.write_text(json.dumps(_fake_report(base)))
    f.write_text(json.dumps(_fake_report(fresh)))
    return gate.main([str(b), str(f), *extra])


import pathlib

REPO_SCRIPTS = pathlib.Path(__file__).resolve().parent.parent / "scripts"


def test_regression_gate_normalizes_within_run(tmp_path):
    """2x slower hardware across the board must NOT trip the gate — only a
    scheme whose cost moved relative to its group's reference does."""
    key_n = ("allgather", "naive", "2x4", 1024)
    key_p = ("allgather", "pipelined", "2x4", 1024)
    base = {key_n: 100.0, key_p: 80.0}
    # uniformly slower machine: same ratios -> ok
    assert _gate(tmp_path, base, {key_n: 200.0, key_p: 160.0}) == 0
    # pipelined regressed 4x relative to naive -> fail at default tol 3.0
    assert _gate(tmp_path, base, {key_n: 100.0, key_p: 320.0}) == 1
    # ...but passes with a wide-enough band
    assert _gate(tmp_path, base, {key_n: 100.0, key_p: 320.0},
                 "--tol", "10") == 0


def test_regression_gate_catches_reference_scheme_regression(tmp_path):
    """The reference scheme's normalized value is 1.0 by construction; the
    machine-factor pass must still catch a regression confined to it."""
    keys = {s: ("allgather", s, "2x4", 1024)
            for s in ("naive", "hier", "pipelined")}
    base = {k: 100.0 for k in keys.values()}
    # only the reference got 10x slower: normalized pass is blind (other
    # schemes' fresh_norm SHRINKS), the raw/machine-factor pass is not
    fresh = {keys["naive"]: 1000.0, keys["hier"]: 100.0,
             keys["pipelined"]: 100.0}
    assert _gate(tmp_path, base, fresh) == 1
    # a uniformly 10x-slower machine stays ok (factor absorbs it)
    assert _gate(tmp_path, base, {k: 1000.0 for k in keys.values()}) == 0


def test_regression_gate_requires_overlap(tmp_path):
    """Zero overlapping cells is an error, not a silent pass."""
    base = {("allgather", "naive", "2x4", 256): 10.0}
    fresh = {("allgather", "naive", "2x4", 1024): 10.0}
    assert _gate(tmp_path, base, fresh) == 1


def _fake_report_p99(cells: dict) -> dict:
    """cells: (family, scheme, topology, elems) -> (median_us, p99_us)."""
    return {"schema": SCHEMA_VERSION,
            "cases": [{"family": f, "scheme": s, "topology": t, "elems": e,
                       "timing": {"median_us": med, "p99_us": p99}}
                      for (f, s, t, e), (med, p99) in cells.items()]}


def _gate_reports(tmp_path, base, fresh, *extra):
    import sys
    sys.path.insert(0, str(REPO_SCRIPTS))
    import check_bench_regression as gate
    b, f = tmp_path / "base.json", tmp_path / "fresh.json"
    b.write_text(json.dumps(base))
    f.write_text(json.dumps(fresh))
    return gate.main([str(b), str(f), *extra])


def test_regression_gate_p99_catches_tail_collapse(tmp_path):
    """Medians hold while a scheme's p99 explodes 10x relative to its
    reference ('recorded' — lexicographic first with no 'naive' present) —
    the median pass is blind, the percentile pass is not."""
    key_s = ("serving", "sync", "2x4", 1024)
    key_r = ("serving", "recorded", "2x4", 1024)
    base = _fake_report_p99({key_s: (100.0, 110.0), key_r: (80.0, 90.0)})
    ok = _fake_report_p99({key_s: (100.0, 130.0), key_r: (80.0, 90.0)})
    assert _gate_reports(tmp_path, base, ok) == 0
    bad = _fake_report_p99({key_s: (100.0, 9000.0), key_r: (80.0, 90.0)})
    assert _gate_reports(tmp_path, base, bad) == 1
    # the tail band is 2 * tol: widening --tol clears it
    assert _gate_reports(tmp_path, base, bad, "--tol", "100") == 0


def test_regression_gate_p99_skips_legacy_baselines(tmp_path):
    """A baseline predating p99_us (or carrying p99_us: 0.0 from a default
    TimingResult) must skip the percentile pass, not crash or fail."""
    key_n = ("allgather", "naive", "2x4", 1024)
    key_p = ("allgather", "pipelined", "2x4", 1024)
    legacy = _fake_report({key_n: 100.0, key_p: 80.0})
    fresh = _fake_report_p99({key_n: (100.0, 9000.0), key_p: (80.0, 9000.0)})
    assert _gate_reports(tmp_path, legacy, fresh) == 0
    zeroed = _fake_report_p99({key_n: (100.0, 0.0), key_p: (80.0, 0.0)})
    assert _gate_reports(tmp_path, zeroed, fresh) == 0


# ---------------------------------------------------------------------------
# End-to-end CLI (the CI bench-smoke path)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_quick_cli_covers_full_matrix(tmp_path):
    from repro.bench.__main__ import main
    out = tmp_path / "BENCH_collectives.json"
    rc = main(["--quick", "--reps", "1", "--out", str(out)])
    assert rc == 0
    rep = json.loads(out.read_text())
    assert rep["schema"] == SCHEMA_VERSION
    assert len(rep["matrix"]) == 5           # all five matrix topologies
    assert rep["validation"]["ok"] is True
    fams = {c["family"] for c in rep["cases"]}
    assert fams == set(suites.FAMILIES)
