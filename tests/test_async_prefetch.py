"""Async collective handles + the layer-parameter prefetcher.

Covers the tentpole surface end to end: ``allgather_async`` issue/resolve
equivalence against the eager shared-window gather over the full topology
matrix, torn-read (``WindowEpochError``) semantics on resolve-after-store,
the ``ParamGroup`` sharded -> in_flight -> unsharded lifecycle, bit-identical
train-step outputs with the prefetcher on vs off, and the ``step_time``
bench family's registry/traffic wiring.  The pure in-flight-budget
properties live in ``test_prefetch_props.py`` (hypothesis).
"""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.comm import Communicator, WindowEpochError
from repro.comm.handle import AsyncCollectiveHandle
from repro.models.meta import PMeta
from repro.models.parallel import ParamGroup
from repro.runtime.steps import cluster_ctx, make_step_bench
from repro.substrate import VirtualCluster, default_matrix

MATRIX = default_matrix()
VC2 = VirtualCluster(pods=2, chips=4)          # seed shape, store size 4
TUPLE = VirtualCluster(pods=2, chips=4, fast_axis=("dp", "tp"),
                       fast_shape=(2, 2), slow_axis="pod")

needs8 = pytest.mark.skipif(not VC2.available(), reason="needs 8 devices")


@pytest.fixture(params=MATRIX, ids=[t.label for t in MATRIX])
def vc(request) -> VirtualCluster:
    cluster = request.param
    if not cluster.available():
        pytest.skip(f"needs {cluster.num_devices} devices")
    return cluster


# ---------------------------------------------------------------------------
# AsyncCollectiveHandle: issue / resolve
# ---------------------------------------------------------------------------

def test_async_gather_matches_eager(vc):
    """resolve() returns exactly the eager shared-window gather — the async
    path changes scheduling, never bytes — on every matrix topology."""
    comm = Communicator.from_cluster(vc)
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(vc.num_devices, 3)).astype(np.float32))

    def body(v):
        h = comm.allgather_async(v)
        assert h.family == "allgather" and h.done
        eager = comm.allgather(v, scheme="shared").read()
        return jnp.stack([h.resolve(), eager])[None]

    out = np.asarray(vc.run(body, x))
    assert out.shape == (vc.num_devices, 2, vc.num_devices, 3)
    np.testing.assert_array_equal(out[:, 0], out[:, 1])
    # every rank's row is present somewhere in its node buffer
    np.testing.assert_allclose(np.sort(out[0, 0].ravel()),
                               np.sort(np.asarray(x).ravel()))


@needs8
def test_resolve_after_store_raises():
    """A store between issue and resolve tears the handle: resolve() must
    raise instead of returning stale bytes."""
    comm = Communicator.from_cluster(VC2)
    x = jnp.zeros((VC2.num_devices, 2), jnp.float32)

    def torn(v):
        h = comm.allgather_async(v)
        return dataclasses.replace(h, window=h.window.store(v)).resolve()

    with pytest.raises(WindowEpochError, match="torn"):
        VC2.run(torn, x)


@needs8
def test_resolve_after_fence_epoch_bump_raises():
    """A fence past the issue epoch (even back to a clean window) also
    tears the handle — the buffer was rewritten since issue."""
    comm = Communicator.from_cluster(VC2)
    x = jnp.zeros((VC2.num_devices, 2), jnp.float32)

    def torn(v):
        h = comm.allgather_async(v)
        bumped = h.window.store(v).fence_local(h.token)
        assert not dataclasses.replace(h, window=bumped).done
        return dataclasses.replace(h, window=bumped).resolve()

    with pytest.raises(WindowEpochError, match="torn"):
        VC2.run(torn, x)


@needs8
def test_issue_on_dirty_window_raises():
    """An async gather may not overlap an open store epoch."""
    comm = Communicator.from_cluster(VC2)
    x = jnp.zeros((VC2.num_devices, 2), jnp.float32)

    def dirty(v):
        win = comm.window(v, epoch=1).store(v)
        return AsyncCollectiveHandle.issue("allgather", win).resolve()

    with pytest.raises(WindowEpochError, match="dirty"):
        VC2.run(dirty, x)


# ---------------------------------------------------------------------------
# ParamGroup lifecycle
# ---------------------------------------------------------------------------

@needs8
def test_paramgroup_lifecycle_and_gather_identity():
    """sharded -> in_flight -> unsharded -> sharded; the group's gather is
    byte-identical to the eager ``gather_w`` load."""
    ctx = cluster_ctx(VC2)
    meta = {"w": PMeta(shape=(8, 4), fsdp_dim=0)}
    x = jnp.arange(VC2.num_devices * 2 * 4,
                   dtype=jnp.float32).reshape(VC2.num_devices * 2, 4)

    def body(w):
        g = ParamGroup(ctx, {"w": w}, meta)
        assert g.state == "sharded"
        g.unshard()
        assert g.state == "in_flight"
        g.unshard()                      # idempotent while in flight
        full = g.wait()["w"]
        assert g.state == "unsharded"
        g.reshard()
        assert g.state == "sharded"
        return jnp.stack([full, ctx.gather_w(w, 0)])[None]

    out = np.asarray(VC2.run(body, x))
    np.testing.assert_array_equal(out[:, 0], out[:, 1])


@needs8
def test_paramgroup_wait_on_torn_handle_raises():
    """A store tearing ONE window between unshard and wait fails the whole
    group's wait, exactly like a per-leaf resolve would."""
    ctx = cluster_ctx(VC2)
    meta = {"w": PMeta(shape=(8, 4), fsdp_dim=0)}
    x = jnp.zeros((VC2.num_devices * 2, 4), jnp.float32)

    def body(w):
        g = ParamGroup(ctx, {"w": w}, meta)
        g.unshard()
        h = g._handles["w"]
        g._handles = {"w": dataclasses.replace(
            h, window=h.window.store(w.astype(ctx.compute_dtype)))}
        return g.wait()["w"]

    with pytest.raises(WindowEpochError, match="torn"):
        VC2.run(body, x)


# ---------------------------------------------------------------------------
# Prefetch on/off: bit-identical step outputs
# ---------------------------------------------------------------------------

@pytest.mark.skipif(not TUPLE.available(), reason="needs 8 devices")
def test_prefetch_step_outputs_bit_identical():
    """The prefetcher reorders gather issue, never math: the full train
    step (fwd + bwd + bridge + optimizer) returns bit-identical scalars
    with prefetch on vs off, on the production-shaped tuple mesh."""
    from repro.configs import get_config
    cfg = get_config("starcoder2-7b").reduced()
    outs = []
    for opts in ((), ("prefetch",)):
        body, in_specs, out_specs, make_args, _ = make_step_bench(
            cfg, TUPLE, opts=opts, unroll=cfg.n_units)
        fn = jax.jit(TUPLE.smap(body, in_specs, out_specs))
        outs.append([np.asarray(o) for o in fn(*make_args())])
    for a, b in zip(*outs):
        np.testing.assert_array_equal(a, b)


def test_cluster_ctx_strips_prefetch_on_size1_store():
    """A size-1 store shards nothing: the prefetch opt must degrade to the
    eager path (same program) instead of paying handle plumbing for
    no-op gathers."""
    assert cluster_ctx(VirtualCluster(pods=8, chips=1),
                       opts=("prefetch",)).prefetch == 0
    if VC2.available():
        assert cluster_ctx(VC2, opts=("prefetch",)).prefetch == 2
        assert cluster_ctx(TUPLE, opts=("prefetch=3",)).prefetch == 3


# ---------------------------------------------------------------------------
# step_time bench family wiring
# ---------------------------------------------------------------------------

def test_step_time_registry_wiring():
    from repro.bench import step_time  # noqa: F401  (registers schemes)
    from repro.comm.registry import scheme_names, schemes_for
    assert {"eager", "prefetch", "stepgraph"} <= set(scheme_names())
    assert [s.name for s in schemes_for("step_time")] == \
        ["eager", "prefetch", "stepgraph"]


@needs8
def test_step_time_cases_traffic_recorded():
    """Case building walks the jaxpr link inventory and records per-cell
    traffic: both tiers nonzero on a bridged cluster, the replicated
    3-scalar result on node 0, and one case per (config, scheme)."""
    from repro.bench import step_time as st
    cases = list(st.step_time_cases(VC2))
    assert sorted(c.scheme for c in cases) == \
        ["eager", "eager", "prefetch", "prefetch",
         "stepgraph", "stepgraph"]
    for c in cases:
        assert c.family == "step_time"
        assert c.traffic.fast_bytes > 0
        assert c.traffic.slow_bytes > 0
        assert c.traffic.result_bytes_per_node == 3 * 4 * VC2.chips
    # the two configs are distinct cells
    assert len({c.elems for c in cases}) == 2
