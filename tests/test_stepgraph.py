"""The step-graph collective optimizer (``repro.comm.stepgraph``).

Covers the tentpole surface: the pack/unpack codec (bit-exact, padding,
dtype policing), the three rewrite passes on synthetic graphs (bucketing
with singleton demotion, same-epoch gather dedup, gather-first issue
order), the recorder against raw ``lax.psum`` on a live mesh, whole-step
on-vs-off bit-identity, and the ``link_entries`` jaxpr inventory that
proves bucketing reduced the physical slow-tier message count (satellite
coverage: deduped/bucketed jaxprs, ``axis_index_groups`` pricing).
Codec round-trip *properties* live in ``test_stepgraph_props.py``.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax import lax

from repro.comm import Communicator
from repro.comm.stepgraph import (CollectiveGraph, pack_leaves, optimize,
                                  unpack_leaves, SCHEMA_VERSION)
from repro.models.meta import PMeta
from repro.runtime.steps import cluster_ctx, make_step_bench
from repro.substrate import VirtualCluster, default_matrix

MATRIX = default_matrix()
VC2 = VirtualCluster(pods=2, chips=4)
TUPLE = VirtualCluster(pods=2, chips=4, fast_axis=("dp", "tp"),
                       fast_shape=(2, 2), slow_axis="pod")

needs8 = pytest.mark.skipif(not VC2.available(), reason="needs 8 devices")


@pytest.fixture(params=MATRIX, ids=[t.label for t in MATRIX])
def vc(request) -> VirtualCluster:
    cluster = request.param
    if not cluster.available():
        pytest.skip(f"needs {cluster.num_devices} devices")
    return cluster


# ---------------------------------------------------------------------------
# pack/unpack codec
# ---------------------------------------------------------------------------

def test_pack_unpack_roundtrip_bit_exact():
    rng = np.random.default_rng(3)
    leaves = [jnp.asarray(rng.normal(size=s).astype(np.float32))
              for s in [(3, 2), (), (5,), (1, 1, 4)]]
    buf, spec = pack_leaves(leaves, pad_to=7)
    assert buf.ndim == 1 and buf.shape[0] % 7 == 0
    assert spec.total_elems == buf.shape[0]
    assert spec.leaf_elems == (6, 1, 5, 4)
    out = unpack_leaves(buf, spec)
    for a, b in zip(leaves, out):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pack_rejects_empty_and_mixed_dtypes():
    with pytest.raises(ValueError):
        pack_leaves([])
    with pytest.raises(ValueError):
        pack_leaves([jnp.zeros(2, jnp.float32), jnp.zeros(2, jnp.bfloat16)])


def test_unpack_polices_buffer_shape():
    leaves = [jnp.arange(4, dtype=jnp.float32)]
    buf, spec = pack_leaves(leaves)
    with pytest.raises(ValueError):
        unpack_leaves(jnp.zeros(spec.total_elems + 1, jnp.float32), spec)


# ---------------------------------------------------------------------------
# the rewrite passes, on synthetic graphs
# ---------------------------------------------------------------------------

def _ar(g, *, axes=("pod", "data"), dtype="float32", shape=(8,),
        scheme="naive", bucketable=True, key=None):
    return g.add(family="allreduce", key=key, axes=axes, dtype=dtype,
                 shape=shape, elem_bytes=4, scheme=scheme,
                 bucketable=bucketable)


def test_bucketing_groups_by_axes_dtype_scheme():
    g = CollectiveGraph()
    for i in range(5):                                   # one bucket
        _ar(g, key=("a", i))
    _ar(g, axes=("pod",), key="other-axes")             # singleton -> single
    _ar(g, dtype="float64", key="other-dtype")          # singleton -> single
    sched = optimize(g, pods=2, chips=4)
    assert len(sched.buckets) == 1
    assert sorted(sched.buckets[0].nids) == list(range(5))
    assert sorted(sched.singles) == [5, 6]
    r = sched.report()
    assert r["schema"] == SCHEMA_VERSION
    assert r["allreduce"]["before_messages"] == 7
    assert r["allreduce"]["after_messages"] == 3
    assert r["allreduce"]["after_bytes"] == r["allreduce"]["before_bytes"]


def test_bucketing_skips_nonbucketable_and_auto():
    g = CollectiveGraph()
    _ar(g, bucketable=False, key="pinned")
    _ar(g, bucketable=False, key="pinned2")
    # a caller forcing bucketable=True with scheme="auto" must not crash
    # (auto resolves per message size; there is no registry entry for it)
    _ar(g, scheme="auto", bucketable=True, key="auto1")
    _ar(g, scheme="auto", bucketable=True, key="auto2")
    sched = optimize(g, pods=2, chips=4)
    assert not sched.buckets and len(sched.singles) == 4


def test_gather_dedup_same_key_same_epoch_only():
    g = CollectiveGraph()
    a = g.add(family="gather", key="w0", axes=("data",), dtype="float32",
              shape=(4,), elem_bytes=4, epoch=1)
    b = g.add(family="gather", key="w0", axes=("data",), dtype="float32",
              shape=(4,), elem_bytes=4, epoch=1)      # dup -> collapses
    c = g.add(family="gather", key="w0", axes=("data",), dtype="float32",
              shape=(4,), elem_bytes=4, epoch=2)      # fresh epoch -> kept
    d = g.add(family="gather", key="w1", axes=("data",), dtype="float32",
              shape=(4,), elem_bytes=4, epoch=1)      # other window -> kept
    sched = optimize(g, pods=2, chips=4)
    assert sched.gather_primary == {a: a, b: a, c: c, d: d}
    r = sched.report()
    assert r["gather"]["before_issues"] == 4
    assert r["gather"]["after_issues"] == 3


def test_order_frontloads_gathers():
    g = CollectiveGraph()
    _ar(g, key=("a", 0))
    _ar(g, key=("a", 1))
    g.add(family="gather", key="w0", axes=("data",), dtype="float32",
          shape=(4,), elem_bytes=4, epoch=1)
    sched = optimize(g, pods=2, chips=4)
    kinds = [k for k, _ in sched.order]
    assert kinds[0] == "gather" and set(kinds[1:]) <= {"bucket", "single"}


# ---------------------------------------------------------------------------
# recorder vs raw lax.psum on a live mesh
# ---------------------------------------------------------------------------

def test_recorder_matches_raw_psum(vc):
    """Recording + the rewritten schedule returns exactly what eager
    ``lax.psum`` over the same axes returns, on every matrix topology."""
    world = Communicator.from_cluster(vc)
    rng = np.random.default_rng(11)
    xs = [jnp.asarray(rng.normal(size=(vc.num_devices, 6)).astype(
        np.float32)) for _ in range(3)]

    def body(a, b, c):
        rec = world.record()
        refs = [rec.allreduce(v, axes=world.axes, scheme="naive",
                              key=i) for i, v in enumerate((a, b, c))]
        res = rec.run()
        got = [res[r] for r in refs]
        want = [lax.psum(v, world.axes) for v in (a, b, c)]
        return jnp.stack([jnp.stack(got), jnp.stack(want)])[None]

    out = np.asarray(vc.run(body, *xs))
    np.testing.assert_array_equal(out[:, 0], out[:, 1])


# ---------------------------------------------------------------------------
# whole step: stepgraph on vs off
# ---------------------------------------------------------------------------

def _step_outputs(vc, opts, sink=None):
    from repro.configs import get_config
    cfg = get_config("starcoder2-7b").reduced()
    body, in_specs, out_specs, make_args, _ = make_step_bench(
        cfg, vc, opts=opts, unroll=cfg.n_units, schedule_sink=sink)
    fn = jax.jit(vc.smap(body, in_specs, out_specs))
    return [np.asarray(o) for o in fn(*make_args())]


@needs8
def test_step_outputs_bit_identical_and_report_sane():
    """On the seed 2x4 shape the optimized step is bit-identical to eager
    and its schedule report passes the committed artifact's validator."""
    sink = []
    on = _step_outputs(VC2, ("stepgraph",), sink)
    off = _step_outputs(VC2, ())
    for a, b in zip(on, off):
        np.testing.assert_array_equal(a, b)
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                           / "scripts"))
    import check_schedule_report
    r = dict(sink[-1], config="starcoder2-7b", topology=VC2.label,
             pods=VC2.pods, chips=VC2.chips, elems=0)
    assert check_schedule_report.check_report(r, "test") == []
    ar = r["allreduce"]
    assert ar["after_messages"] < ar["before_messages"]


@pytest.mark.slow
def test_step_outputs_bit_identical_matrix(vc):
    """Full-matrix on-vs-off bit-identity of the whole train-step bench
    body (fwd + bwd + bridge + optimizer)."""
    for a, b in zip(_step_outputs(vc, ("stepgraph",)),
                    _step_outputs(vc, ())):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# link_entries: counting physical messages on rewritten jaxprs
# ---------------------------------------------------------------------------

@needs8
def test_link_entries_bucketing_reduces_slow_messages():
    """Bucketing must show up in the *lowering*: fewer slow-tier messages
    with the opt on, total wire bytes conserved (packing changes message
    count, never payload)."""
    from repro.bench.step_time import link_entries
    from repro.configs import get_config
    cfg = get_config("starcoder2-7b").reduced()
    ent = {}
    for name, opts in (("eager", ()), ("stepgraph", ("stepgraph",))):
        body, in_specs, out_specs, make_args, _ = make_step_bench(
            cfg, VC2, opts=opts, unroll=cfg.n_units)
        avals = tuple(jax.ShapeDtypeStruct(a.shape, a.dtype)
                      for a in make_args())
        ent[name] = link_entries(vc=VC2, example_args=avals,
                                 fn=VC2.smap(body, in_specs, out_specs))
    slow = {k: [e for e in v if e.tier == "slow"] for k, v in ent.items()}
    assert len(slow["stepgraph"]) < len(slow["eager"])
    for k, v in ent.items():
        assert all(e.group_size > 1 for e in v)
    tot = {k: sum(e.link_bytes * e.copies for e in v if e.tier == "slow")
           for k, v in slow.items()}
    assert tot["stepgraph"] == pytest.approx(tot["eager"])


@needs8
def test_link_entries_cse_one_entry_per_physical_message():
    """Two textually separate psums of the SAME operand are one HLO op
    after CSE — the inventory counts one message, not two."""
    from repro.bench.step_time import link_entries
    from jax.sharding import PartitionSpec as P

    def body(x):
        a = lax.psum(x, ("pod", "data"))
        b = lax.psum(x, ("pod", "data"))
        c = lax.psum(x * 2, ("pod", "data"))    # distinct operand: counted
        return (a + b + c)[None]

    avals = (jax.ShapeDtypeStruct((VC2.num_devices, 4), jnp.float32),)
    ent = link_entries(VC2.smap(body, (P(("pod", "data")),),
                                P(("pod", "data"))), avals, VC2)
    ars = [e for e in ent if e.kind == "ar"]
    assert len(ars) == 2


@needs8
def test_link_entries_axis_index_groups_pricing():
    """Grouped collectives price per replica group: psum over groups of 2
    on the 8-rank mesh has group_size 2 and the ring-model wire bytes of a
    2-rank allreduce (2 * out * (n-1)/n = out)."""
    from repro.bench.step_time import link_entries
    from jax.sharding import PartitionSpec as P

    groups = [[0, 1], [2, 3], [4, 5], [6, 7]]

    def body(x):
        return lax.psum(x, "data", axis_index_groups=groups)[None]

    vc = VirtualCluster(pods=1, chips=8)
    avals = (jax.ShapeDtypeStruct((8, 4), jnp.float32),)
    ent = link_entries(vc.smap(body, (P("data"),), P("data")),
                       avals, vc)
    ars = [e for e in ent if e.kind == "ar"]
    assert len(ars) == 1
    e = ars[0]
    assert e.group_size == 2 and e.tier == "fast"
    assert e.link_bytes == pytest.approx(e.out_bytes)


# ---------------------------------------------------------------------------
# reduce_grads: recorder routing matches the eager path
# ---------------------------------------------------------------------------

@needs8
def test_reduce_grads_recorder_matches_eager():
    """Routing the per-leaf bridge through the recorder returns exactly
    the eager ``reduce_grads`` result leaf-for-leaf."""
    ctx = cluster_ctx(VC2)
    world = Communicator.from_cluster(VC2)
    metas = [PMeta((8, 4)), PMeta((4,))]
    rng = np.random.default_rng(5)
    gs = [jnp.asarray(rng.normal(size=(VC2.num_devices,) + m.shape)
                      .astype(np.float32)) for m in metas]

    def body(ga, gb):
        grads = {"a": ga, "b": gb}
        eager = ctx.reduce_grads(grads, metas)
        rec = world.record()
        deferred = ctx.reduce_grads(grads, metas, recorder=rec)
        res = rec.run()
        opt = res.resolve(deferred)
        return jnp.concatenate(
            [jnp.stack([eager[k].ravel(), opt[k].ravel()])
             for k in ("a", "b")], axis=1)[None]

    out = np.asarray(VC2.run(body, *gs))
    np.testing.assert_array_equal(out[:, 0], out[:, 1])
