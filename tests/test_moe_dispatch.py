"""Property tests for the MoE capacity-dispatch tables (pure function —
the invariants any expert-parallel dispatch must satisfy)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs.base import MoESpec
from repro.models.moe import dispatch_tables, route


@given(st.integers(min_value=1, max_value=64),   # tokens
       st.integers(min_value=1, max_value=4),    # top-k
       st.integers(min_value=2, max_value=16),   # experts
       st.integers(min_value=1, max_value=8),    # capacity
       st.integers(min_value=0, max_value=3))    # seed
@settings(max_examples=80, deadline=None)
def test_dispatch_tables_invariants(N, k, E, C, seed):
    rng = np.random.default_rng(seed)
    idx = jnp.asarray(rng.integers(0, E, size=(N, k)).astype(np.int32))
    # local group = all experts (e0=0, n_local=E)
    table, slot = jax.jit(dispatch_tables, static_argnames=(
        "e0", "n_local", "capacity"))(idx, e0=0, n_local=E, capacity=C)
    table = np.asarray(table)
    slot = np.asarray(slot)
    assert table.shape == (E, C)

    # 1. every real entry points to a token that chose that expert
    flat = np.asarray(idx).reshape(-1)
    for e in range(E):
        for c in range(C):
            t = table[e, c]
            if t < N:
                s = slot[e, c]
                assert s >= 0
                assert s // k == t          # slot belongs to that token
                assert flat[s] == e         # and routed to this expert

    # 2. no (token, k-slot) is dispatched twice
    used = slot[slot >= 0]
    assert len(np.unique(used)) == len(used)

    # 3. capacity: expert e serves min(count_e, C) assignments, in order
    for e in range(E):
        count = int((flat == e).sum())
        served = int((table[e] < N).sum())
        assert served == min(count, C)
        # slots fill from the left
        real = table[e] < N
        assert not np.any(~real[:-1] & real[1:])


@given(st.integers(min_value=1, max_value=32),
       st.integers(min_value=2, max_value=64))
@settings(max_examples=60, deadline=None)
def test_ep_tp_factorization(tp, E):
    spec = MoESpec(num_experts=E, top_k=2, d_ff_expert=64)
    ep, tp_ff = spec.ep_tp(tp)
    assert ep * tp_ff == tp
    assert E % ep == 0


def test_route_gates_normalized():
    rng = np.random.default_rng(0)
    h = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))
    idx, gate = route(h, w, top_k=3)
    assert idx.shape == (32, 3) and gate.shape == (32, 3)
    np.testing.assert_allclose(np.asarray(gate).sum(-1), 1.0, rtol=1e-5)
    # top-k really is top-k
    logits = np.asarray(h) @ np.asarray(w)
    for i in range(32):
        want = set(np.argsort(logits[i])[-3:])
        assert set(np.asarray(idx)[i].tolist()) == want
