"""Integration tests: end-to-end training convergence, checkpoint-resume
continuity, serving engine, and the SUMMA/BPMF application examples."""

import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.topology import MeshTopology
from repro.data.synthetic import DataConfig
from repro.launch.mesh import make_mesh_from_topo
from repro.runtime.steps import make_train_step
from repro.runtime.train_loop import train

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _bundle(vocab=512, lr=3e-3):
    cfg = get_config("qwen3-0.6b").reduced(n_layers=2, d_model=128,
                                           n_heads=4, vocab=vocab)
    topo = MeshTopology({"data": 1, "model": 1}, slow_axes=())
    mesh = make_mesh_from_topo(topo)
    return cfg, make_train_step(cfg, topo, mesh, mode="hier", lr=lr,
                                compute_dtype=jnp.float32)


@pytest.mark.slow
def test_training_learns_structure():
    cfg, bundle = _bundle()
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=128, global_batch=8)
    report = train(bundle, steps=40, data_cfg=data_cfg, log_every=0)
    assert report.final_loss < np.log(cfg.vocab_padded) - 0.4
    assert report.losses[-1] < report.losses[0]


@pytest.mark.slow
def test_checkpoint_resume_continues_loss_curve(tmp_path):
    cfg, bundle = _bundle()
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=128, global_batch=8)
    ck = str(tmp_path / "ck")
    # uninterrupted run
    full = train(bundle, steps=20, data_cfg=data_cfg, log_every=0)
    # interrupted at step 10 (checkpoint), then resumed
    train(bundle, steps=10, data_cfg=data_cfg, ckpt_dir=ck,
          save_every=10, log_every=0)
    r2 = train(bundle, steps=20, data_cfg=data_cfg, ckpt_dir=ck,
               save_every=10, log_every=0)
    assert r2.resumed_from == 10
    # the resumed curve must continue the uninterrupted one exactly
    np.testing.assert_allclose(r2.losses, full.losses[10:], rtol=1e-5)


@pytest.mark.slow
def test_serving_engine_greedy():
    from repro.models import build_by_name
    from repro.serving.engine import greedy_generate
    model = build_by_name("qwen3-0.6b", reduced=True)
    params = model.init_params(0)
    prompts = np.random.default_rng(0).integers(
        0, model.cfg.vocab, size=(2, 16)).astype(np.int32)
    res = greedy_generate(model, params, prompts, max_new=4)
    assert res.tokens.shape == (2, 4)
    assert np.all(res.logprobs <= 0)


def _run_example(name, *args, timeout=1200):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, os.path.join(REPO, "examples", name), *args],
        capture_output=True, text=True, env=env, timeout=timeout)


@pytest.mark.slow
def test_summa_example():
    proc = _run_example("summa.py", "--n", "128")
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "intra-node copy bytes/round=0" in proc.stdout  # paper C2
    # the fused Hy_SUMMA variant ran and matched A@B exactly
    assert "pipelined" in proc.stdout
    for line in proc.stdout.splitlines():
        if "rel_err=" in line:
            assert float(line.split("rel_err=")[1].split()[0]) < 1e-5


@pytest.mark.slow
def test_bpmf_example():
    proc = _run_example("bpmf.py", "--iters", "6")
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "time ratio" in proc.stdout  # schemes agree (asserted in-script)


@pytest.mark.slow
def test_grad_compression_trains_close_to_exact():
    """int8+EF bridge compression must not derail training (tiny model)."""
    from repro.optim.compression import int8_bridge_psum
    cfg = get_config("qwen3-0.6b").reduced(n_layers=2, d_model=64,
                                           n_heads=4, vocab=256)
    topo = MeshTopology({"data": 1, "model": 1}, slow_axes=())
    mesh = make_mesh_from_topo(topo)
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8)

    exact = make_train_step(cfg, topo, mesh, mode="hier", lr=3e-3,
                            compute_dtype=jnp.float32)
    comp = make_train_step(cfg, topo, mesh, mode="hier", lr=3e-3,
                           compute_dtype=jnp.float32,
                           compress=lambda g, axes: int8_bridge_psum(g, axes))
    re_ = train(exact, steps=25, data_cfg=data_cfg, log_every=0)
    rc = train(comp, steps=25, data_cfg=data_cfg, log_every=0)
    assert abs(re_.final_loss - rc.final_loss) < 0.3
