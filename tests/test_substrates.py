"""Substrate tests: data determinism, checkpoint/restart + elastic reshape,
straggler policy, gradient compression, optimizer reference check."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.optim.adamw import adamw_init, adamw_update, cosine_schedule
from repro.optim.compression import int8_bridge_psum, make_error_feedback
from repro.runtime.fault_tolerance import (StragglerPolicy, elastic_topology)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_restartable():
    cfg = DataConfig(vocab=512, seq_len=64, global_batch=8)
    a = SyntheticLM(cfg)
    b1 = [a.next_batch()["tokens"] for _ in range(4)]
    # restart at step 2 reproduces batches 2,3 exactly
    b = SyntheticLM(cfg, start_step=2)
    np.testing.assert_array_equal(b.next_batch()["tokens"], b1[2])
    np.testing.assert_array_equal(b.next_batch()["tokens"], b1[3])


def test_data_hosts_disjoint_slices():
    cfg = DataConfig(vocab=512, seq_len=64, global_batch=8)
    h0 = SyntheticLM(cfg, host_id=0, num_hosts=2).next_batch()["tokens"]
    h1 = SyntheticLM(cfg, host_id=1, num_hosts=2).next_batch()["tokens"]
    assert h0.shape == (4, 65) and h1.shape == (4, 65)
    assert not np.array_equal(h0, h1)


def test_data_has_learnable_structure():
    cfg = DataConfig(vocab=512, seq_len=256, global_batch=4)
    toks = SyntheticLM(cfg).next_batch()["tokens"]
    # motif splicing makes some bigrams much more frequent than chance
    big = {}
    for row in toks:
        for a_, b_ in zip(row[:-1], row[1:]):
            big[(a_, b_)] = big.get((a_, b_), 0) + 1
    assert max(big.values()) >= 3


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    state = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
             "nested": {"b": jnp.ones((4,), jnp.int32)},
             "step": jnp.int32(7)}
    for s in (1, 2, 3):
        ck.save(s, state, blocking=True)
    assert ck.all_steps() == [2, 3]  # keep=2 gc'd step 1
    like = jax.tree.map(lambda x: np.zeros(x.shape, x.dtype), state)
    restored, step = ck.restore(like)
    assert step == 3
    np.testing.assert_array_equal(restored["a"], np.asarray(state["a"]))
    np.testing.assert_array_equal(restored["nested"]["b"],
                                  np.asarray(state["nested"]["b"]))


def test_checkpoint_atomic_no_partial(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(5, {"x": jnp.zeros((3,))}, blocking=True)
    # a stale tmp dir from a crashed writer must not be visible
    os.makedirs(os.path.join(str(tmp_path), ".tmp-9-123"), exist_ok=True)
    assert ck.all_steps() == [5]


def _tear(root, step):
    """Truncate a committed step's shard file (post-commit corruption)."""
    with open(os.path.join(str(root), f"step_{step:08d}", "shard_0.npz"),
              "wb") as f:
        f.write(b"torn")


def test_restore_falls_back_past_torn_newest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=5)
    for s in (1, 2, 3):
        ck.save(s, {"w": jnp.full((4,), float(s))}, blocking=True)
    _tear(tmp_path, 3)
    with pytest.warns(RuntimeWarning, match="checkpoint step 3 is torn"):
        restored, step = ck.restore({"w": np.zeros((4,), np.float32)})
    assert step == 2
    np.testing.assert_array_equal(restored["w"],
                                  np.full((4,), 2.0, np.float32))


def test_restore_every_step_torn_raises(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=5)
    for s in (1, 2):
        ck.save(s, {"w": jnp.zeros((4,))}, blocking=True)
        _tear(tmp_path, s)
    with pytest.warns(RuntimeWarning):
        with pytest.raises(FileNotFoundError, match="every candidate"):
            ck.restore({"w": np.zeros((4,), np.float32)})


def test_restore_torn_fallback_respects_pinned_step(tmp_path):
    """The fallback walks strictly OLDER steps than the pinned one — a
    newer checkpoint must never be substituted for a validated step."""
    ck = Checkpointer(str(tmp_path), keep=5)
    for s in (1, 2, 3):
        ck.save(s, {"w": jnp.full((4,), float(s))}, blocking=True)
    _tear(tmp_path, 2)
    with pytest.warns(RuntimeWarning, match="step 2 is torn"):
        restored, step = ck.restore({"w": np.zeros((4,), np.float32)},
                                    step=2)
    assert step == 1
    np.testing.assert_array_equal(restored["w"],
                                  np.full((4,), 1.0, np.float32))


def test_save_retries_transient_io(tmp_path):
    ck = Checkpointer(str(tmp_path), io_retries=3, retry_backoff_s=0.001)
    orig, calls = ck._write, {"n": 0}

    def flaky(step, host_state):
        calls["n"] += 1
        if calls["n"] <= 2:
            raise OSError("transient NFS hiccup")
        orig(step, host_state)

    ck._write = flaky
    ck.save(1, {"w": jnp.ones((4,))}, blocking=True)  # wait() inside
    assert calls["n"] == 3
    restored, step = ck.restore({"w": np.zeros((4,), np.float32)})
    assert step == 1


def test_save_terminal_failure_surfaces_on_wait(tmp_path):
    from repro.checkpoint.checkpointer import CheckpointSaveError
    ck = Checkpointer(str(tmp_path), io_retries=1, retry_backoff_s=0.001)

    def broken(step, host_state):
        raise OSError("disk on fire")

    ck._write = broken
    ck.save(1, {"w": jnp.ones((4,))})
    with pytest.raises(CheckpointSaveError, match="after 2 attempts"):
        ck.wait()
    # the error is surfaced once, not re-raised forever
    ck.wait()


def test_discard_after_drops_newer_steps(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=10)
    for s in (2, 4, 6, 8):
        ck.save(s, {"w": jnp.full((2,), float(s))}, blocking=True)
    assert ck.discard_after(4) == [6, 8]
    assert ck.all_steps() == [2, 4]
    assert ck.discard_after(4) == []


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_straggler_policy_flags_slow_host():
    pol = StragglerPolicy(patience=3)
    evicted = []
    for _ in range(10):
        evicted = pol.observe({0: 1.0, 1: 1.0, 2: 1.0, 3: 5.0})
        if evicted:
            break
    assert evicted == [3]


def test_straggler_recovers():
    pol = StragglerPolicy(patience=3)
    for _ in range(2):
        pol.observe({0: 1.0, 1: 4.0})
    # host recovers before patience runs out
    for _ in range(10):
        out = pol.observe({0: 1.0, 1: 1.0})
    assert out == []


def test_elastic_topology_shrinks():
    t = elastic_topology(512)
    assert t.axis_sizes == {"pod": 2, "data": 16, "model": 16}
    t = elastic_topology(256)
    assert t.axis_sizes == {"data": 16, "model": 16}
    t = elastic_topology(240)  # lost a host: 15 data groups
    assert t.axis_sizes == {"data": 15, "model": 16}
    with pytest.raises(ValueError):
        elastic_topology(8)


def test_elastic_topology_derives_model_from_prev():
    """A run launched with a non-default TP degree keeps it through every
    shrink: the model degree comes from the surviving run's own topology,
    not the hard-coded production 16."""
    prev = elastic_topology(256, model=8)
    assert prev.size("model") == 8
    shrunk = elastic_topology(248, prev=prev)  # lost one 8-chip group
    assert shrunk.size("model") == 8
    assert shrunk.size("data") == 31
    # explicit model= still overrides prev
    assert elastic_topology(248, model=4, prev=prev).size("model") == 4


def test_elastic_topology_stranded_chips_error():
    with pytest.raises(ValueError, match="2 stranded chip"):
        elastic_topology(250, model=8)  # 250 = 31*8 + 2
    # the message tells the operator both ways out
    with pytest.raises(ValueError, match="evict down to 248"):
        elastic_topology(250, model=8)


# ---------------------------------------------------------------------------
# elastic cluster shrink (VirtualCluster.without_pod / with_pods)
# ---------------------------------------------------------------------------

def test_cluster_without_pod_shrinks_and_drops_bridge():
    from repro.substrate.cluster import VirtualCluster
    vc = VirtualCluster(pods=2, chips=4)
    sv = vc.without_pod(1)
    assert (sv.pods, sv.chips) == (1, 4)
    assert sv.slow is None           # single node: no bridge tier at all
    assert sv.label == "1x4"
    big = VirtualCluster(pods=4, chips=2).without_pod()
    assert (big.pods, big.chips) == (3, 2) and big.label == "3x2"
    with pytest.raises(ValueError, match="last node"):
        sv.without_pod()
    with pytest.raises(ValueError, match="out of range"):
        vc.without_pod(5)


def test_cluster_with_pods_rejects_unresizable_tiers():
    from repro.substrate.cluster import VirtualCluster
    factored = VirtualCluster(pods=4, chips=2, slow_axis=("p0", "p1"),
                              slow_shape=(2, 2))
    with pytest.raises(ValueError, match="factored slow tier"):
        factored.with_pods(3)
    single = VirtualCluster(pods=1, chips=8)
    with pytest.raises(ValueError, match="no slow axis to grow"):
        single.with_pods(2)
    with pytest.raises(ValueError, match="below one node"):
        single.with_pods(0)


def test_cluster_shrink_keeps_factored_fast_tier():
    from repro.substrate.cluster import VirtualCluster
    vc = VirtualCluster(pods=2, chips=4, fast_axis=("dp", "tp"),
                        fast_shape=(2, 2), slow_axis="pod")
    sv = vc.without_pod(0)
    assert (sv.pods, sv.chips) == (1, 4)
    assert sv.fast_names == ("dp", "tp") and sv.fast_shape == (2, 2)
    assert sv.slow is None


# ---------------------------------------------------------------------------
# optimizer + compression
# ---------------------------------------------------------------------------

def test_adamw_matches_manual_reference():
    p = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]])}
    g = {"w": jnp.asarray([[0.1, 0.2], [-0.3, 0.4]])}
    m, v = adamw_init(p)
    lr, wd, b1, b2, eps = 1e-2, 0.1, 0.9, 0.95, 1e-8
    newp, newm, newv = adamw_update(p, g, m, v, jnp.int32(1), lr=lr,
                                    weight_decay=wd, b1=b1, b2=b2, eps=eps)
    gm = np.asarray(g["w"])
    m_ref = (1 - b1) * gm
    v_ref = (1 - b2) * gm * gm
    mhat = m_ref / (1 - b1)
    vhat = v_ref / (1 - b2)
    p_ref = np.asarray(p["w"]) - lr * (mhat / (np.sqrt(vhat) + eps)
                                       + wd * np.asarray(p["w"]))
    np.testing.assert_allclose(np.asarray(newp["w"]), p_ref, rtol=1e-6)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1e-3) < 1e-9
    assert float(lr(100)) < float(lr(50)) < float(lr(10))


def test_int8_psum_single_device_roundtrip():
    g = jnp.asarray(np.random.default_rng(0).normal(size=(64, 32))
                    .astype(np.float32))
    out = int8_bridge_psum(g, ())
    err = np.abs(np.asarray(out) - np.asarray(g)).max()
    amax = float(jnp.max(jnp.abs(g)))
    assert err <= amax / 127.0 + 1e-6  # one quantization step


def test_error_feedback_accumulates_residual():
    init, compress = make_error_feedback({"w": jnp.zeros((8,))})
    err = init()["w"]
    g = jnp.full((8,), 0.004, jnp.float32)
    total = 0.0
    for _ in range(50):
        out, err = compress(g, err, ())
        total += float(out.sum())
    # with error feedback the long-run average is unbiased
    assert abs(total - 50 * float(g.sum())) / (50 * float(g.sum())) < 0.05


def test_error_feedback_residual_bounded_across_pods():
    """Regression: the residual must be the LOCAL quantization error
    (g32 - q*scale), not local-minus-psum-total — the total includes the
    other pods' gradients, so that residual grows ~(P-1)*g per step and
    the feedback diverges instead of correcting rounding bias."""
    n = 2
    if len(jax.devices()) < n:
        pytest.skip("needs 2 devices")
    init, compress = make_error_feedback(jnp.zeros((n, 32)))
    step = jax.pmap(lambda g, e: compress(g, e, "p"), axis_name="p",
                    devices=jax.devices()[:n])
    rng = np.random.default_rng(0)
    # distinct per-pod magnitudes so local != total
    g = jnp.asarray(rng.normal(size=(n, 32)).astype(np.float32)
                    * np.asarray([[1.0], [3.0]], np.float32))
    err = jnp.zeros((n, 32), jnp.float32)
    total = np.zeros((n, 32), np.float32)
    for _ in range(12):
        scale = float(jnp.max(jnp.abs(g + err))) / 127.0
        out, err = step(g, err)
        total += np.asarray(out)
        # one quantization step, every step: the residual never compounds
        assert float(jnp.max(jnp.abs(err))) <= scale / 2 + 1e-6
    # and the long-run sum telescopes to the exact psum (minus one residual)
    exact = 12 * np.broadcast_to(np.asarray(g).sum(0), (n, 32))
    assert np.abs(total - exact).max() <= float(jnp.max(jnp.abs(err))) * n


def test_straggler_evicts_once_and_drops_state():
    """Regression: an evicted host must be returned exactly once; its EWMA
    and strike state are dropped so a dead host neither inflates the fleet
    median nor gets re-flagged every subsequent call."""
    pol = StragglerPolicy(patience=3)
    evictions = []
    for _ in range(20):
        evictions += pol.observe({0: 1.0, 1: 1.0, 2: 1.0, 3: 9.0})
    assert evictions == [3]
    assert 3 in pol.evicted
    assert 3 not in pol.ewma and 3 not in pol.strikes
    # the dead host's stale reports no longer move the fleet median
    assert float(np.median(list(pol.ewma.values()))) == pytest.approx(1.0)


def test_restart_resumes_pinned_step(tmp_path):
    """Regression: resume_or_init must restore the step it validated via
    latest_step(), not whatever is newest when restore() runs — a
    concurrent save landing in between must not switch checkpoints."""
    from repro.runtime.fault_tolerance import RestartManager
    ck = Checkpointer(str(tmp_path), keep=5)
    ck.save(100, {"w": jnp.full((4,), 100.0)}, blocking=True)
    rm = RestartManager(ck)
    validated = ck.latest_step()
    # a concurrent save lands after latest_step() was read
    ck.save(200, {"w": jnp.full((4,), 200.0)}, blocking=True)
    ck.latest_step = lambda: validated
    state, step = rm.resume_or_init(
        lambda: {"w": jnp.zeros((4,), jnp.float32)})
    assert step == 100
    np.testing.assert_array_equal(np.asarray(state["w"]),
                                  np.full((4,), 100.0, np.float32))


def test_restore_checks_manifest_dtypes(tmp_path):
    """Restore validates BOTH directions against the manifest: the shard
    bytes and the caller's template must match the recorded dtype."""
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"w": jnp.ones((4,), jnp.float32)}, blocking=True)
    with pytest.raises(AssertionError, match="dtype"):
        ck.restore({"w": np.zeros((4,), np.int32)})
    restored, _ = ck.restore({"w": np.zeros((4,), np.float32)})
    np.testing.assert_array_equal(restored["w"], np.ones((4,), np.float32))
