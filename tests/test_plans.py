"""Property tests (hypothesis) for the decomposition algebra in core/plans.py.

Invariants of the paper's scheme that must hold for ANY cluster shape:

* the allgatherv plan tiles the result buffer exactly (no gaps/overlaps);
* hybrid keeps exactly one result copy per node; naive keeps one per rank;
* hybrid removes ALL intra-node copy traffic for gather/broadcast;
* both schemes move identical per-payload bytes across the slow tier for the
  bridge exchange (the paper: inter-node traffic is unchanged);
* traffic is monotone in message size.
"""

import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.plans import (GatherPlan, NodeMap, allgather_traffic,
                              allgatherv_traffic, allreduce_traffic,
                              alltoall_traffic, best_chunk_count,
                              broadcast_traffic, collective_time_model,
                              overlap_efficiency, pipelined_time_model,
                              reduce_scatter_traffic)

nodes = st.integers(min_value=1, max_value=12)
ppn = st.integers(min_value=1, max_value=32)
msg = st.integers(min_value=1, max_value=1 << 20)
pops_st = st.lists(st.integers(min_value=1, max_value=32), min_size=1,
                   max_size=12)


@given(nodes, ppn, st.integers(min_value=1, max_value=4096))
@settings(max_examples=200, deadline=None)
def test_gather_plan_tiles_buffer(P, c, m):
    plan = GatherPlan(NodeMap.smp(P, c), elem_per_rank=m)
    plan.check()
    assert sum(plan.counts()) == P * c * m
    assert len(plan.displs()) == P


@given(st.lists(st.integers(min_value=1, max_value=32), min_size=1,
                max_size=12),
       st.integers(min_value=1, max_value=4096))
@settings(max_examples=200, deadline=None)
def test_gather_plan_irregular_population(pops, m):
    """Paper §5.1.3: irregularly populated nodes still tile the buffer."""
    plan = GatherPlan(NodeMap.irregular(pops), elem_per_rank=m)
    plan.check()
    assert plan.counts() == tuple(p * m for p in pops)
    # leaders are the first rank of each node
    leaders = plan.node_map.leaders()
    assert leaders[0] == 0
    for a, b in zip(leaders, leaders[1:]):
        assert b > a


@given(nodes, ppn, msg)
@settings(max_examples=200, deadline=None)
def test_allgather_memory_claim(P, c, m):
    """Paper C1: hybrid keeps ONE copy per node; naive keeps one per rank."""
    naive = allgather_traffic(scheme="naive", num_nodes=P, ranks_per_node=c,
                              bytes_per_rank=m)
    hier = allgather_traffic(scheme="hier", num_nodes=P, ranks_per_node=c,
                             bytes_per_rank=m)
    n = P * c * m
    assert hier.result_bytes_per_node == n
    assert naive.result_bytes_per_node == c * n
    assert naive.result_bytes_per_node // hier.result_bytes_per_node == c


@given(nodes, ppn, msg)
@settings(max_examples=200, deadline=None)
def test_allgather_intra_node_copy_claim(P, c, m):
    """Paper C2: hybrid removes all intra-node copies; bridge unchanged."""
    naive = allgather_traffic(scheme="naive", num_nodes=P, ranks_per_node=c,
                              bytes_per_rank=m)
    hier = allgather_traffic(scheme="hier", num_nodes=P, ranks_per_node=c,
                             bytes_per_rank=m)
    assert hier.fast_bytes == 0
    assert naive.fast_bytes >= 0
    if c > 1:
        assert naive.fast_bytes > 0
    # C3: identical slow-tier bytes (the bridge exchanges node regions)
    assert hier.slow_bytes == naive.slow_bytes


@given(pops_st, st.integers(min_value=1, max_value=1 << 16))
@settings(max_examples=200, deadline=None)
def test_allgatherv_traffic_consistent_with_gather_plan(pops, m):
    """The irregular traffic model and the GatherPlan displacement algebra
    describe the SAME exchange: bridge bytes are exactly every node region
    (the plan's counts) sent to the other P-1 leaders."""
    plan = GatherPlan(NodeMap.irregular(pops), elem_per_rank=m)
    plan.check()
    P = len(pops)
    hier = allgatherv_traffic(scheme="hier", populations=pops,
                              bytes_per_rank=m)
    naive = allgatherv_traffic(scheme="naive", populations=pops,
                               bytes_per_rank=m)
    assert hier.slow_bytes == sum(cnt * (P - 1) for cnt in plan.counts())
    assert hier.slow_bytes == plan.total_elems * (P - 1)
    # bridge bytes are scheme-independent (paper: inter-node unchanged)
    assert naive.slow_bytes == hier.slow_bytes
    # C2: the shared window removes ALL intra-node copies
    assert hier.fast_bytes == 0
    assert (naive.fast_bytes > 0) == any(p > 1 for p in pops)
    # C1, irregular form: the fullest node's population is the ratio
    assert hier.result_bytes_per_node == plan.total_elems
    assert naive.result_bytes_per_node == max(pops) * plan.total_elems
    assert naive.result_bytes_per_node // hier.result_bytes_per_node \
        == max(pops)


@given(nodes, ppn, msg)
@settings(max_examples=200, deadline=None)
def test_allgatherv_reduces_to_allgather_on_regular_pops(P, c, m):
    for scheme in ("naive", "hier"):
        flat = allgather_traffic(scheme=scheme, num_nodes=P,
                                 ranks_per_node=c, bytes_per_rank=m)
        irr = allgatherv_traffic(scheme=scheme, populations=[c] * P,
                                 bytes_per_rank=m)
        assert flat == irr


def test_allgatherv_traffic_rejects_bad_populations():
    with pytest.raises(ValueError):
        allgatherv_traffic(scheme="hier", populations=[], bytes_per_rank=1)
    with pytest.raises(ValueError):
        allgatherv_traffic(scheme="hier", populations=[2, 0],
                           bytes_per_rank=1)
    with pytest.raises(ValueError):
        allgatherv_traffic(scheme="smp", populations=[2], bytes_per_rank=1)


@given(nodes, ppn, msg)
@settings(max_examples=200, deadline=None)
def test_broadcast_claims(P, c, m):
    naive = broadcast_traffic(scheme="naive", num_nodes=P, ranks_per_node=c,
                              msg_bytes=m)
    hier = broadcast_traffic(scheme="hier", num_nodes=P, ranks_per_node=c,
                             msg_bytes=m)
    assert hier.fast_bytes == 0
    assert hier.slow_bytes == naive.slow_bytes == (P - 1) * m
    assert naive.result_bytes_per_node == c * hier.result_bytes_per_node


@given(nodes, ppn, msg)
@settings(max_examples=200, deadline=None)
def test_allreduce_slow_tier_never_worse(P, c, m):
    """The bridge reduction on shards crosses the slow tier at most as much
    as the flat ring's node-boundary hops."""
    naive = allreduce_traffic(scheme="naive", num_nodes=P, ranks_per_node=c,
                              msg_bytes=m)
    hier = allreduce_traffic(scheme="hier", num_nodes=P, ranks_per_node=c,
                             msg_bytes=m)
    assert hier.slow_bytes <= naive.slow_bytes + 1  # int rounding
    assert hier.result_bytes_per_node <= naive.result_bytes_per_node


@given(nodes, ppn, st.integers(min_value=1, max_value=1 << 18),
       st.integers(min_value=2, max_value=8))
@settings(max_examples=100, deadline=None)
def test_traffic_monotone_in_message(P, c, m, k):
    for fn, kw in ((allgather_traffic, "bytes_per_rank"),
                   (broadcast_traffic, "msg_bytes"),
                   (allreduce_traffic, "msg_bytes")):
        small = fn(scheme="hier", num_nodes=P, ranks_per_node=c, **{kw: m})
        big = fn(scheme="hier", num_nodes=P, ranks_per_node=c, **{kw: k * m})
        assert big.slow_bytes >= small.slow_bytes
        assert big.result_bytes_per_node >= small.result_bytes_per_node


@given(nodes, ppn, msg)
@settings(max_examples=200, deadline=None)
def test_alltoall_pairwise_accounting(P, c, m):
    """All-to-all invariants for ANY shape: total naive bytes == every
    ordered non-self pair moving m once; the node-aware scheme deletes
    exactly the intra-node pair bytes (C2-style) and cannot reduce the
    bridge (all data distinct); results are rank-private in both schemes so
    C1 does NOT apply (equal residency)."""
    R = P * c
    naive = alltoall_traffic(scheme="naive", num_nodes=P, ranks_per_node=c,
                             bytes_per_pair=m)
    hier = alltoall_traffic(scheme="hier", num_nodes=P, ranks_per_node=c,
                            bytes_per_pair=m)
    assert naive.slow_bytes + naive.fast_bytes == m * R * (R - 1)
    assert naive.slow_bytes == hier.slow_bytes
    assert hier.fast_bytes == 0
    assert naive.fast_bytes == m * P * c * (c - 1)
    assert naive.result_bytes_per_node == hier.result_bytes_per_node \
        == c * R * m
    # single node: everything is intra-node
    if P == 1:
        assert naive.slow_bytes == 0


@given(nodes, ppn, msg)
@settings(max_examples=50, deadline=None)
def test_time_model_positive_finite(P, c, m):
    t = collective_time_model(
        allgather_traffic(scheme="hier", num_nodes=P, ranks_per_node=c,
                          bytes_per_rank=m),
        num_nodes=P, ranks_per_node=c)
    assert t >= 0 and math.isfinite(t)


@given(nodes, ppn, msg)
@settings(max_examples=100, deadline=None)
def test_reduce_scatter_traffic_halves_the_allreduce_cycle(P, c, m):
    """hier reduce-scatter is exactly the first half of the hier allreduce
    RS+AG cycle per tier; the flat scheme's ring total is m*(R-1) and its
    resident bytes are the 1/num_nodes share (inverse C1)."""
    rs = reduce_scatter_traffic(scheme="hier", num_nodes=P,
                                ranks_per_node=c, msg_bytes=m)
    ar = allreduce_traffic(scheme="hier", num_nodes=P, ranks_per_node=c,
                           msg_bytes=m)
    assert abs(2 * rs.fast_bytes - ar.fast_bytes) <= 1     # int truncation
    assert abs(2 * rs.slow_bytes - ar.slow_bytes) <= 1
    assert rs.result_bytes_per_node == m

    flat = reduce_scatter_traffic(scheme="naive", num_nodes=P,
                                  ranks_per_node=c, msg_bytes=m)
    assert abs(flat.slow_bytes + flat.fast_bytes - m * (P * c - 1)) <= 1
    assert flat.result_bytes_per_node == m // P
    if P == 1:
        assert flat.slow_bytes == 0
    with pytest.raises(ValueError, match="unknown scheme"):
        reduce_scatter_traffic(scheme="quantum", num_nodes=P,
                               ranks_per_node=c, msg_bytes=m)


@given(nodes, ppn, msg, st.integers(min_value=1, max_value=16))
@settings(max_examples=200, deadline=None)
def test_pipelined_time_model_overlap_properties(P, c, m, n):
    """The overlap term: T(1) == the serial model; T is monotone
    non-increasing in n (alpha=0); T never beats the slower tier (the
    pipeline can hide the cheaper tier, not delete the dearer one); the
    serial/pipelined ratio lives in [1, 2]."""
    tr = allgather_traffic(scheme="hier", num_nodes=P, ranks_per_node=c,
                           bytes_per_rank=m)
    kw = dict(num_nodes=P, ranks_per_node=c)
    serial = collective_time_model(tr, **kw)
    assert pipelined_time_model(tr, n_chunks=1, **kw) == pytest.approx(
        serial)
    prev = None
    slow_t = (tr.slow_bytes / max(P, 1)) / 25e9
    fast_t = (tr.fast_bytes / max(P * c, 1)) / 100e9
    for k in (1, 2, 4, n):
        t = pipelined_time_model(tr, n_chunks=k, **kw)
        assert t >= max(slow_t, fast_t) - 1e-18
        if prev is not None and k >= 4:
            assert t <= prev + 1e-18
        prev = t
    eff = overlap_efficiency(tr, n_chunks=n, **kw)
    assert 1.0 - 1e-9 <= eff <= 2.0 + 1e-9
    best = best_chunk_count(tr, **kw)
    assert best in (1, 2, 4, 8)
    with pytest.raises(ValueError, match="n_chunks"):
        pipelined_time_model(tr, n_chunks=0, **kw)


def test_node_map_validation():
    with pytest.raises(ValueError):
        NodeMap((0, 2, 1))  # non-dense node ids
    with pytest.raises(ValueError):
        NodeMap.irregular([3, 0])
    nm = NodeMap.smp(2, 3)
    assert nm.leaders() == (0, 3)
    assert nm.local_rank(4) == 1
    assert nm.populations() == (3, 3)
