"""Multi-device correctness checks for the hierarchical collectives.

Run as a subprocess by tests/test_collectives.py — sets the host-device-count
flag BEFORE importing jax, so the main pytest process keeps 1 device.

Builds a (pod=2, data=4) mesh over 8 CPU devices and checks every hier/shared
collective against its naive (flat) counterpart and a numpy oracle.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402
from jax import shard_map  # noqa: E402

from repro.core import collectives as cc  # noqa: E402
from repro.core import sync  # noqa: E402
from repro.core.plans import GatherPlan, NodeMap  # noqa: E402

PODS, CHIPS = 2, 4
MESH = jax.make_mesh((PODS, CHIPS), ("pod", "data"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
FAST, SLOW = "data", "pod"

CHECKS = []


def check(fn):
    CHECKS.append(fn)
    return fn


def smap(f, in_specs, out_specs):
    return shard_map(f, mesh=MESH, in_specs=in_specs, out_specs=out_specs,
                     check_vma=False)


def global_input(m=6, extra=3, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.normal(size=(PODS * CHIPS * m, extra)).astype(np.float32))


# ---------------------------------------------------------------------------
@check
def allgather_full_replication_matches_naive():
    x = global_input()
    spec = P(("pod", "data"))

    naive = smap(lambda v: cc.naive_all_gather(v, fast_axis=FAST,
                                               slow_axis=SLOW),
                 (spec,), P(None))(x)
    hier = smap(lambda v: cc.hier_all_gather(v, fast_axis=FAST,
                                             slow_axis=SLOW),
                (spec,), P(None))(x)
    np.testing.assert_allclose(naive, np.asarray(x))
    np.testing.assert_allclose(hier, np.asarray(x))


@check
def shared_allgather_is_one_copy_per_pod():
    x = global_input()
    spec = P(("pod", "data"))
    m = x.shape[0] // (PODS * CHIPS)

    # chip (p, i) ends with shard i of the pod's single copy: contributions of
    # chip i of EVERY pod, pod-major.
    shards = smap(lambda v: cc.shared_all_gather(v, fast_axis=FAST,
                                                 slow_axis=SLOW),
                  (spec,), P(("pod", "data")))(x)
    xs = np.asarray(x).reshape(PODS, CHIPS, m, -1)
    # output layout: pod-major over devices -> (PODS, CHIPS, PODS*m, extra)
    got = np.asarray(shards).reshape(PODS, CHIPS, PODS * m, -1)
    for p in range(PODS):
        for i in range(CHIPS):
            want = np.concatenate([xs[q, i] for q in range(PODS)], axis=0)
            np.testing.assert_allclose(got[p, i], want)

    # shared_read + reorder reconstructs the rank-ordered buffer everywhere
    def read(v):
        shard = cc.shared_all_gather(v, fast_axis=FAST, slow_axis=SLOW)
        full = cc.shared_read(shard, fast_axis=FAST)
        return cc.shared_to_rank_order(full, num_pods=PODS,
                                       chips_per_pod=CHIPS)

    full = smap(read, (spec,), P(None))(x)
    np.testing.assert_allclose(full, np.asarray(x))


@check
def broadcast_matches_across_schemes():
    rng = np.random.default_rng(1)
    msg = rng.normal(size=(PODS * CHIPS, 8, 2)).astype(np.float32)
    x = jnp.asarray(msg)
    spec = P(("pod", "data"))  # each chip holds a (8,2) private buffer
    root = 0

    naive = smap(lambda v: cc.naive_broadcast(v[0], root=root, fast_axis=FAST,
                                              slow_axis=SLOW)[None],
                 (spec,), spec)(x)
    hier = smap(lambda v: cc.hier_broadcast(v[0], root_pod=0, fast_axis=FAST,
                                            slow_axis=SLOW)[None],
                (spec,), spec)(x)
    want = np.broadcast_to(msg[root], (PODS * CHIPS, 8, 2))
    np.testing.assert_allclose(np.asarray(naive), want, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(hier), want, rtol=1e-6)

    # shared: each chip holds shard i of the root's message; reading gives it
    def sh(v):
        shard = cc.shared_broadcast(v[0], root_pod=0, fast_axis=FAST,
                                    slow_axis=SLOW, axis=0)
        return cc.shared_read(shard, fast_axis=FAST)[None]

    full = smap(sh, (spec,), spec)(x)
    np.testing.assert_allclose(np.asarray(full), want, rtol=1e-6)


@check
def psum_schemes_agree():
    x = global_input(m=8, extra=4, seed=2)
    spec = P(("pod", "data"))
    m = x.shape[0] // (PODS * CHIPS)
    want = np.asarray(x).reshape(PODS * CHIPS, m, -1).sum(0)

    naive = smap(lambda v: cc.naive_psum(v, fast_axis=FAST, slow_axis=SLOW),
                 (spec,), P(None))(x[:, :])
    # local shard is (m, extra); want sum over chips -> (m, extra) replicated
    np.testing.assert_allclose(np.asarray(naive)[:m], want, rtol=1e-5)

    hier = smap(lambda v: cc.hier_psum(v, fast_axis=FAST, slow_axis=SLOW),
                (spec,), P(None))(x)
    np.testing.assert_allclose(np.asarray(hier)[:m], want, rtol=1e-5)

    def sh(v):
        shard = cc.shared_psum_scatter(v, fast_axis=FAST, slow_axis=SLOW)
        return cc.shared_read(shard, fast_axis=FAST)

    shared = smap(sh, (spec,), P(None))(x)
    np.testing.assert_allclose(np.asarray(shared)[:m], want, rtol=1e-5)


@check
def irregular_allgatherv_roundtrip():
    # 2 pods with different *valid* contribution lengths per chip (Fig. 10).
    rng = np.random.default_rng(3)
    max_m = 5
    valid = np.array([[3, 5, 2, 4], [1, 5, 5, 2]], dtype=np.int32)
    data = rng.normal(size=(PODS, CHIPS, max_m)).astype(np.float32)
    for p in range(PODS):
        for i in range(CHIPS):
            data[p, i, valid[p, i]:] = 0.0

    x = jnp.asarray(data.reshape(PODS * CHIPS, max_m))
    v = jnp.asarray(valid.reshape(PODS * CHIPS, 1))
    spec = P(("pod", "data"))

    def body(xv, vv):
        blocks, counts = cc.shared_all_gather_v(xv, vv, slow_axis=SLOW)
        return blocks, counts

    # gathered blocks: leading new dim = contributing pod; replicated over pod
    blocks, counts = smap(body, (spec, spec),
                          (P(None, "data"), P(None, "data")))(x, v)
    b = np.asarray(blocks)      # (PODS, CHIPS, max_m)
    c = np.asarray(counts)      # (PODS, CHIPS, 1)
    for i in range(CHIPS):
        for p in range(PODS):
            np.testing.assert_allclose(b[p, i], data[p, i])
            assert c[p, i, 0] == valid[p, i]

    # compaction via the one-off plan (paper's counts/displs): ranks flattened
    # in (pod, chip) order with per-rank valid prefixes tile the buffer.
    flat_valid = valid.reshape(-1)
    compact = np.concatenate(
        [data.reshape(PODS * CHIPS, max_m)[r, :flat_valid[r]]
         for r in range(PODS * CHIPS)])
    assert compact.shape[0] == flat_valid.sum()
    nm = NodeMap.irregular([CHIPS, CHIPS])
    assert nm.leaders() == (0, CHIPS)


@check
def sync_primitives_run():
    tok = jnp.ones((PODS * CHIPS,), jnp.float32)
    spec = P(("pod", "data"))
    out = smap(lambda t: sync.barrier(t, ("pod", "data")), (spec,), spec)(tok)
    np.testing.assert_allclose(np.asarray(out), 8.0)
    out2 = smap(lambda t: sync.flag_chain(t, ("pod", "data")),
                (spec,), spec)(tok)
    np.testing.assert_allclose(np.asarray(out2), 1.0)
    out3 = smap(lambda t: sync.leader_flag(t, fast_axis="data"),
                (spec,), spec)(tok)
    np.testing.assert_allclose(np.asarray(out3), 3.0)  # CHIPS-1 children


@check
def gather_plan_matches_device_layout():
    plan = GatherPlan(NodeMap.smp(PODS, CHIPS), elem_per_rank=4)
    plan.check()
    assert plan.counts() == (16, 16)
    assert plan.displs() == (0, 16)
    assert plan.rank_offset(5) == 16 + 4  # pod1, local1


def main():
    failures = []
    for fn in CHECKS:
        try:
            fn()
            print(f"PASS {fn.__name__}")
        except Exception as e:  # noqa: BLE001
            failures.append((fn.__name__, repr(e)))
            print(f"FAIL {fn.__name__}: {e!r}")
    if failures:
        raise SystemExit(1)
    print("ALL OK")


if __name__ == "__main__":
    main()
