"""HLO collective parser + roofline math unit tests (real HLO line formats,
including variadic tuples with /*index=N*/ comments and iota replica
groups)."""

import numpy as np

from repro.analysis.roofline import (CollectiveBytes, _first_group,
                                     _shape_bytes, extrapolate_cost,
                                     parse_collectives, roofline)

VARIADIC = ("  %all-reduce.2 = (f32[9496,64]{1,0}, f32[28,192,64]{2,1,0}, "
            "/*index=5*/f32[64,9496]{1,0}) all-reduce(%a, %b, %c), "
            "channel_id=1, replica_groups={{0,256},{1,257}}, "
            "use_global_device_ids=true, to_apply=%add")

SIMPLE_AG = ("  %all_gather.1 = bf16[16,4096,1024]{2,1,0} "
             "all-gather(%x), channel_id=2, replica_groups="
             "{{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}, dimensions={1}")

IOTA_RS = ("  %reduce-scatter.5 = f32[8,64]{1,0} reduce-scatter(%y), "
           "channel_id=3, replica_groups=[256,2]<=[2,256]T(1,0), "
           "dimensions={0}")


def test_shape_bytes_tuple_with_comments():
    got = _shape_bytes("(f32[9496,64]{1,0}, f32[28,192,64]{2,1,0}, "
                       "/*index=5*/f32[64,9496]{1,0})")
    want = 4 * (9496 * 64 + 28 * 192 * 64 + 64 * 9496)
    assert got == want


def test_first_group_brace_and_iota():
    n, ids = _first_group(VARIADIC, 512)
    assert n == 2 and ids == [0, 256]
    n, ids = _first_group(IOTA_RS, 512)
    assert n == 2 and ids == [0, 256]  # transpose(reshape) rows


def test_parse_cross_pod_classification():
    hlo = "\n".join([VARIADIC, SIMPLE_AG, IOTA_RS])
    cb = parse_collectives(hlo, num_devices=512, pod_size=256)
    # variadic AR crosses pods: 2*out*(n-1)/n with n=2 -> out bytes
    var_bytes = 4 * (9496 * 64 + 28 * 192 * 64 + 64 * 9496)
    np.testing.assert_allclose(cb.by_op["all-reduce/slow"], var_bytes)
    # iota RS also crosses pods: out*(n-1) = out
    np.testing.assert_allclose(cb.by_op["reduce-scatter/slow"], 8 * 64 * 4)
    assert cb.slow == cb.by_op["all-reduce/slow"] \
        + cb.by_op["reduce-scatter/slow"]
    # the AG is intra-pod (model axis)
    ag = 2 * 16 * 4096 * 1024 * 15 / 16
    np.testing.assert_allclose(cb.by_op["all-gather"], ag)
    assert cb.fast == cb.by_op["all-gather"]


def test_extrapolation_algebra():
    a = {"flops": 100.0, "bytes accessed": 60.0}
    b = {"flops": 150.0, "bytes accessed": 80.0}
    f, by = extrapolate_cost(a, b, n_units=10)
    assert f == 50.0 + 10 * 50.0       # outside 2A-B=50, unit=50
    assert by == 40.0 + 10 * 20.0

    ca = CollectiveBytes(fast=10.0, slow=2.0, by_op={"all-gather": 10.0})
    cb_ = CollectiveBytes(fast=14.0, slow=2.0, by_op={"all-gather": 14.0})
    comb = CollectiveBytes.combine(ca, cb_, 10)
    np.testing.assert_allclose(comb.fast, 6.0 + 10 * 4.0)
    np.testing.assert_allclose(comb.slow, 2.0)  # outside-loop slow unchanged


def test_roofline_terms_and_dominance():
    coll = CollectiveBytes(fast=200e9, slow=25e9)
    t = roofline(flops_per_dev=197e12, bytes_per_dev=819e9, coll=coll,
                 chips=256, notes={"flops": 0.0, "bytes": 0.0},
                 model_flops=197e12 * 256 * 0.5)
    np.testing.assert_allclose(t.compute_s, 1.0)
    np.testing.assert_allclose(t.memory_s, 1.0)
    np.testing.assert_allclose(t.fast_coll_s, 1.0)
    np.testing.assert_allclose(t.slow_coll_s, 1.0)
    assert t.dominant == "collective"
    np.testing.assert_allclose(t.useful_flops_ratio, 0.5)
