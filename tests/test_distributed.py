"""Distributed (8 fake devices) model correctness — subprocess wrapper.

hier (paper) and naive (pure-MPI analogue) training steps must match a
single-device reference bit-for-bit-ish (fp32, rtol 2e-4) across all
parallelism regimes; see tests/_multidevice_model_checks.py.
"""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_multidevice_model_correctness():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable,
         os.path.join(REPO, "tests", "_multidevice_model_checks.py")],
        capture_output=True, text=True, env=env, timeout=1800)
    assert proc.returncode == 0, (
        f"STDOUT:\n{proc.stdout[-4000:]}\nSTDERR:\n{proc.stderr[-4000:]}")
    assert "ALL OK" in proc.stdout
