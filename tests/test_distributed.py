"""Distributed model correctness, in-process (8 forced devices — conftest).

hier (paper) and naive (pure-MPI analogue) training steps must match a
single-device reference bit-for-bit-ish (fp32, rtol 2e-4) across all
parallelism regimes: head TP, context parallel, MoE ep x tp_ff, mLSTM head
groups, sLSTM batch groups, hybrid recurrence, VLM/audio frontends.

Port of the old subprocess ``_multidevice_model_checks.py`` into first-class
pytest; meshes are built through the substrate compat layer
(``make_mesh_from_topo``).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import MoESpec
from repro.core.topology import MeshTopology
from repro.launch.mesh import make_mesh_from_topo, small_topo
from repro.models import make_batch
from repro.runtime.steps import make_serve_steps, make_train_step

pytestmark = pytest.mark.slow


def _require(topo: MeshTopology):
    if jax.device_count() < topo.num_devices:
        pytest.skip(f"needs {topo.num_devices} devices")


def single_device_step(cfg, batch, seed=0, lr=1e-3):
    """Reference: same math, single-device topology, plain jax."""
    topo1 = MeshTopology({"data": 1, "model": 1}, slow_axes=())
    mesh1 = make_mesh_from_topo(topo1)
    bundle = make_train_step(cfg, topo1, mesh1, mode="naive", lr=lr,
                             compute_dtype=jnp.float32)
    state = bundle.init_state(seed)
    new_state, metrics = jax.jit(bundle.fn)(state, batch)
    return state, new_state, metrics


def dist_step(cfg, batch, topo, mode, seed=0, lr=1e-3):
    mesh = make_mesh_from_topo(topo)
    bundle = make_train_step(cfg, topo, mesh, mode=mode, lr=lr,
                             compute_dtype=jnp.float32)
    state = bundle.init_state(seed)
    new_state, metrics = jax.jit(bundle.fn)(state, batch)
    return state, new_state, metrics


def compare(cfg, batch, topo, rtol=2e-4, atol=2e-5):
    _require(topo)
    _, ref_state, ref_metrics = single_device_step(cfg, batch)
    for mode in ("hier", "naive"):
        _, st, mt = dist_step(cfg, batch, topo, mode)
        np.testing.assert_allclose(float(mt["loss"]),
                                   float(ref_metrics["loss"]),
                                   rtol=rtol, err_msg=f"{mode} loss")
        np.testing.assert_allclose(float(mt["gnorm"]),
                                   float(ref_metrics["gnorm"]),
                                   rtol=5e-3, err_msg=f"{mode} gnorm")
        # params after one update must match the single-device reference
        ref_emb = np.asarray(ref_state["params"]["embed"])
        got_emb = np.asarray(jax.device_get(st["params"]["embed"]))
        np.testing.assert_allclose(got_emb, ref_emb, rtol=rtol, atol=atol,
                                   err_msg=f"{mode} embed update")


TOPOS = {"2x2x2": small_topo(2, 2, 2), "1x2x2": small_topo(1, 2, 2)}


@pytest.mark.parametrize("topo", list(TOPOS.values()), ids=list(TOPOS))
def test_dense_head_tp(topo):
    cfg = get_config("qwen3-0.6b").reduced(n_layers=2, d_model=64, n_heads=4)
    batch = make_batch(cfg, B=4, T=32, seed=1)
    compare(cfg, batch, topo)


def test_dense_cp_mode():
    # n_heads=3 % tp=2 != 0 -> context-parallel attention
    cfg = get_config("starcoder2-7b").reduced(n_layers=2, d_model=48,
                                              n_heads=3, d_ff=64)
    batch = make_batch(cfg, B=4, T=32, seed=2)
    compare(cfg, batch, small_topo(2, 2, 2))


def test_moe_ep_tp():
    cfg = get_config("granite-moe-3b-a800m").reduced(n_layers=2, d_model=64,
                                                     n_heads=4)
    # E=4 over tp=2 -> ep=2; widen capacity so no tokens drop (determinism)
    cfg = dataclasses.replace(cfg, moe=MoESpec(4, 2, 32, capacity_factor=8.0))
    batch = make_batch(cfg, B=4, T=32, seed=3)
    compare(cfg, batch, small_topo(2, 2, 2))


def test_xlstm_head_groups():
    # tp=4 > nh=2 -> g=2 chips per head (group all-gather path) + sLSTM
    cfg = get_config("xlstm-1.3b").reduced(n_layers=8, d_model=64, n_heads=2)
    batch = make_batch(cfg, B=4, T=32, seed=4)
    compare(cfg, batch, small_topo(2, 1, 4))


def test_recurrentgemma_hybrid():
    cfg = get_config("recurrentgemma-9b").reduced(n_layers=3, d_model=64,
                                                  n_heads=4)
    batch = make_batch(cfg, B=4, T=32, seed=5)
    compare(cfg, batch, small_topo(2, 2, 2))


@pytest.mark.parametrize("name,seed", [("internvl2-1b", 6),
                                       ("musicgen-medium", 7)])
def test_vlm_and_audio(name, seed):
    cfg = get_config(name).reduced(n_layers=2, d_model=64, n_heads=4)
    batch = make_batch(cfg, B=4, T=32, seed=seed)
    compare(cfg, batch, small_topo(2, 2, 2))


def test_decode2d_matches_baseline():
    """decode2d must match baseline decode logits on (1, 1, 8):
    gcd(H=8, kv=4, tp=8) = 4 -> g_h=4, g_s=2."""
    from repro.models import meta as _M

    cfg = get_config("qwen3-0.6b").reduced(n_layers=2, d_model=64,
                                           n_heads=8, n_kv=4)
    topo = MeshTopology({"data": 1, "model": 8}, slow_axes=())
    _require(topo)
    mesh = make_mesh_from_topo(topo)
    B, T0, smax = 2, 16, 32
    batch = make_batch(cfg, B=B, T=T0, seed=9)
    outs = {}
    for opts in ((), ("decode2d",)):
        sb = make_serve_steps(cfg, topo, mesh, mode="hier",
                              global_batch=B, s_max=smax, opts=opts,
                              compute_dtype=jnp.float32)
        params = sb.model.init_params(0)
        if opts:
            # duplicate baseline attn weights into 2D layout so both
            # runs share identical math
            base = make_serve_steps(cfg, topo, mesh, mode="hier",
                                    global_batch=B, s_max=smax,
                                    compute_dtype=jnp.float32)
            bp = base.model.init_params(0)
            for i in range(len(cfg.pattern)):
                a = params["units"][f"b{i}"]["attn"]
                ab = bp["units"][f"b{i}"]["attn"]
                for kind in ("wq", "wkv", "wo"):
                    stacked = np.stack([
                        _M.relayout_attn_decode2d(w_, cfg, 8, kind)
                        for w_ in np.asarray(ab[kind])])
                    a[kind] = jnp.asarray(stacked)
            for k_ in ("embed", "unembed", "final_ln"):
                if k_ in bp:
                    params[k_] = bp[k_]
            for i in range(len(cfg.pattern)):
                pu = params["units"][f"b{i}"]
                bu = bp["units"][f"b{i}"]
                pu["attn"]["ln"] = bu["attn"]["ln"]
                if "q_norm" in bu["attn"]:
                    pu["attn"]["q_norm"] = bu["attn"]["q_norm"]
                    pu["attn"]["k_norm"] = bu["attn"]["k_norm"]
                if "ffn" in bu:
                    pu["ffn"] = bu["ffn"]
        local_cache = jax.eval_shape(
            lambda sb_=sb: sb_.model.cache_init(sb_.b_loc, smax))
        cache = jax.tree.map(
            lambda l: jnp.zeros((1, 8) + l.shape, l.dtype), local_cache)
        logits = None
        for t in range(4):
            cache, logits = jax.jit(sb.decode)(
                params, cache, batch["tokens"][:, t:t + 1], jnp.int32(t))
        outs[bool(opts)] = np.asarray(logits)
    np.testing.assert_allclose(outs[True], outs[False], rtol=2e-4, atol=2e-4)
