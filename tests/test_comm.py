"""repro.comm: Communicator structure, scheme registry, SharedWindow epoch
semantics, and ``core.sync`` primitives over the full topology matrix.

The sync primitives (``barrier``, ``flag_chain``, ``leader_flag``) had no
dedicated coverage before this suite; every check here runs over
``default_matrix()`` — single node, seed shape, transpose, bridge-only and
the tuple-axis mesh.
"""

import numpy as np
import pytest

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.comm import (Communicator, SharedWindow, WindowEpochError,
                        get_scheme, scheme_names, schemes_for)
from repro.core import sync
from repro.core.plans import NodeMap
from repro.substrate import VirtualCluster, default_matrix

MATRIX = default_matrix()


@pytest.fixture(params=MATRIX, ids=[t.label for t in MATRIX])
def vc(request) -> VirtualCluster:
    cluster = request.param
    if not cluster.available():
        pytest.skip(f"needs {cluster.num_devices} devices")
    return cluster


@pytest.fixture
def comm(vc) -> Communicator:
    return Communicator.from_cluster(vc)


# ---------------------------------------------------------------------------
# Communicator structure (MPI_Comm_split_type analogy)
# ---------------------------------------------------------------------------

def test_communicator_structure(vc, comm):
    assert comm.num_nodes == vc.pods
    assert comm.ranks_per_node == vc.chips
    assert comm.num_ranks == vc.num_devices
    assert comm.node_map == NodeMap.smp(vc.pods, vc.chips)

    node = comm.split_type_shared()
    assert node.slow_axis is None and node.chips == vc.chips
    if vc.pods > 1:
        bridge = comm.bridge()
        assert bridge.slow_axis is None and bridge.chips == vc.pods
    else:
        with pytest.raises(ValueError, match="no bridge"):
            comm.bridge()


def test_communicator_rank_indices(vc, comm):
    """``rank()`` is the flat SMP (pod, chip) row-major rank — the broadcast
    root numbering — and factors into (node_rank, local_rank)."""
    def body(_):
        r = comm.rank()
        return jnp.stack([r, comm.node_rank() * vc.chips + comm.local_rank()]
                         )[None]

    out = np.asarray(vc.run(body, jnp.zeros((vc.num_devices, 1))))
    assert out.shape == (vc.num_devices, 2)
    np.testing.assert_array_equal(out[:, 0], np.arange(vc.num_devices))
    np.testing.assert_array_equal(out[:, 1], np.arange(vc.num_devices))


def test_from_topology_matches_tiers():
    from repro.core.topology import multi_pod, single_pod
    from repro.launch.mesh import communicator_for_topo

    c = communicator_for_topo(multi_pod(pods=2, data=2, model=2))
    assert c.slow_axis == "pod" and c.fast_axis == ("data", "model")
    assert c.pods == 2 and c.chips == 4

    s = Communicator.from_topology(single_pod(data=4, model=2))
    assert s.slow_axis is None and s.pods == 1 and s.chips == 8


# ---------------------------------------------------------------------------
# Scheme registry
# ---------------------------------------------------------------------------

def test_registry_entries_and_errors():
    # the step_time/serving families register their schemes lazily on
    # first import — force them so registry contents don't depend on
    # test order
    from repro.bench import serving, step_time  # noqa: F401
    assert set(scheme_names()) == {"naive", "hier", "shared", "pipelined",
                                   "eager", "prefetch", "stepgraph",
                                   "sync", "recorded",
                                   "q8_hier", "qbf16_hier", "q4_shared"}
    assert get_scheme("shared").result_class == "shared"
    assert get_scheme("hier").result_class == "replicated"
    assert get_scheme("pipelined").result_class == "replicated"
    # quantized wire formats declare themselves lossy; everything else is
    # exact (the precision="exact" default filters on this flag)
    for name in ("q8_hier", "qbf16_hier", "q4_shared"):
        assert get_scheme(name).precision == "lossy"
    for name in ("naive", "hier", "shared", "pipelined"):
        assert get_scheme(name).precision == "exact"
    with pytest.raises(KeyError, match="registered"):
        get_scheme("quantum")
    # unsupported (scheme, family) pairs fail loudly, naming alternatives
    with pytest.raises(NotImplementedError, match="naive.*shared"):
        get_scheme("hier").op("reduce_scatter")
    assert [s.name for s in schemes_for("alltoall")] == ["naive", "hier"]
    assert [s.name for s in schemes_for("allgatherv")] == ["naive", "shared"]
    assert [s.name for s in schemes_for("reduce_scatter")] \
        == ["naive", "shared", "pipelined"]


def test_pipelined_registry_entry_mirrors_hier_closed_forms():
    """The pipelined entry must inherit hier's links/traffic exactly
    (chunking is linear — same total bytes) and declare a tunable grid
    filtered by each (family, topology, size) cell's tiling."""
    hier, pipe = get_scheme("hier"), get_scheme("pipelined")
    for fam in ("allgather", "broadcast", "psum"):
        assert pipe.links(fam, pods=2, chips=4, fast_shape=(4,),
                          elems=256) == \
            hier.links(fam, pods=2, chips=4, fast_shape=(4,), elems=256)
        assert pipe.traffic(fam, pods=2, chips=4, elems=256) == \
            hier.traffic(fam, pods=2, chips=4, elems=256)
    # candidate grids honor the per-family tiling divisors
    assert pipe.candidates("allgather", pods=2, chips=4, elems=8) == \
        ({"n_chunks": 1}, {"n_chunks": 2}, {"n_chunks": 4}, {"n_chunks": 8})
    assert pipe.candidates("psum", pods=2, chips=4, elems=8) == \
        ({"n_chunks": 1}, {"n_chunks": 2})          # 8 % (4*4) != 0
    assert pipe.candidates("reduce_scatter", pods=2, chips=4, elems=4) == ()
    # untunable schemes expose the single-candidate grid
    assert hier.candidates("allgather", pods=2, chips=4, elems=8) == ({},)
    assert get_scheme("shared").candidates("psum", pods=2, chips=4,
                                           elems=6) == ()


def test_registry_traffic_is_plans_closed_form():
    from repro.core import plans
    sch = get_scheme("shared")
    tr = sch.traffic("allgather", pods=2, chips=4, elems=16)
    assert tr == plans.allgather_traffic(scheme="hier", num_nodes=2,
                                         ranks_per_node=4, bytes_per_rank=64)
    # the node-aware alltoall declares zero intra-node copy bytes (C2-style)
    a2a = get_scheme("hier").traffic("alltoall", pods=2, chips=4, elems=8)
    assert a2a.fast_bytes == 0
    naive = get_scheme("naive").traffic("alltoall", pods=2, chips=4, elems=8)
    assert naive.slow_bytes == a2a.slow_bytes        # distinct data: no
    assert naive.result_bytes_per_node == a2a.result_bytes_per_node


def test_communicator_rejects_unknown_scheme(vc, comm):
    with pytest.raises(KeyError, match="registered"):
        vc.run(lambda v: comm.allgather(v, scheme="nope"),
               vc.rank_major_input(m=1))


def test_alltoall_traffic_model_properties():
    """Closed-form sanity: naive total == m*R*(R-1); hier deletes exactly
    the intra-node pair bytes; single-node slow == 0."""
    from repro.core.plans import alltoall_traffic
    for P_, c, m in [(2, 4, 8), (4, 2, 4), (8, 1, 12), (1, 8, 4)]:
        R = P_ * c
        nv = alltoall_traffic(scheme="naive", num_nodes=P_,
                              ranks_per_node=c, bytes_per_pair=m)
        hi = alltoall_traffic(scheme="hier", num_nodes=P_,
                              ranks_per_node=c, bytes_per_pair=m)
        assert nv.slow_bytes + nv.fast_bytes == m * R * (R - 1)
        assert hi.fast_bytes == 0
        assert nv.slow_bytes == hi.slow_bytes == m * P_ * (P_ - 1) * c * c
        assert nv.result_bytes_per_node == hi.result_bytes_per_node \
            == c * R * m
    with pytest.raises(ValueError, match="unknown scheme"):
        alltoall_traffic(scheme="shared", num_nodes=2, ranks_per_node=2,
                         bytes_per_pair=4)


# ---------------------------------------------------------------------------
# SharedWindow: fence()/epoch semantics (paper §6 integrity rules)
# ---------------------------------------------------------------------------

def test_window_fence_closes_epochs_and_orders_reads(vc, comm):
    x = vc.rank_major_input(m=2)

    def body(v):
        w = comm.allgather(v, scheme="shared")
        assert w.epoch == 1 and not w.dirty      # collective = closed epoch
        w2 = w.store(w.shard * 2.0)
        assert w2.dirty                          # store opened an epoch
        w3 = w2.fence()
        assert w3.epoch == 2 and not w3.dirty    # fence closed it
        return w3.read_rank_order()

    out = vc.run(body, x, out_specs=P(None))
    np.testing.assert_allclose(np.asarray(out), 2.0 * np.asarray(x),
                               rtol=1e-6)


def test_window_dirty_read_raises(vc, comm):
    x = vc.rank_major_input(m=1)
    with pytest.raises(WindowEpochError, match="fence"):
        vc.run(lambda v: comm.allgather(v, scheme="shared")
               .store(v).read(), x, out_specs=P(None))


def test_window_fence_value_preserving_all_dtypes(vc, comm):
    """fence() must only add ordering, never change the buffer — including
    integer windows, and including non-finite payloads (a near-overflow
    gradient must not be corrupted by its own synchronization)."""
    R = vc.num_devices
    for dtype in (jnp.float32, jnp.int32):
        x = jnp.arange(R * 4, dtype=dtype)
        out = vc.run(
            lambda v: comm.window(v, epoch=1).fence().shard, x)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(x))
    # NaN/inf in element 0 used to poison the whole window via the
    # arithmetic ordering token
    bad = np.full((R * 2,), np.nan, np.float32)
    bad[1::2] = np.inf
    out = vc.run(lambda v: comm.window(v, epoch=1).fence().shard,
                 jnp.asarray(bad))
    np.testing.assert_array_equal(np.asarray(out), bad)


def test_window_accumulate_is_reduce_scatter_store(vc, comm):
    """accumulate(): every on-node rank contributes a partial sum; after a
    fence the window holds the node-reduced buffer."""
    R = vc.num_devices
    m = 4 * vc.chips
    x = jnp.ones((R, m), jnp.float32)

    def body(v):
        w = comm.window(jnp.zeros((m // vc.chips,), jnp.float32))
        w = w.accumulate(v[0]).fence()
        return w.read()[None]

    out = vc.run(body, x, in_specs=(vc.spec,), out_specs=(
        P(None) if vc.pods == 1 else P(vc.slow, None)))
    got = np.asarray(out).reshape(-1, m)
    np.testing.assert_allclose(got, float(vc.chips))


def test_window_pytree_roundtrip():
    import jax
    comm = Communicator(fast_axis="data", pods=1, chips=4)
    w = SharedWindow(comm, jnp.arange(4.0), axis=0, epoch=3, dirty=True)
    leaves, treedef = jax.tree.flatten(w)
    w2 = jax.tree.unflatten(treedef, leaves)
    assert w2.epoch == 3 and w2.dirty and w2.comm == comm
    np.testing.assert_array_equal(np.asarray(w2.shard), np.arange(4.0))


# ---------------------------------------------------------------------------
# core.sync primitives over the full matrix
# ---------------------------------------------------------------------------

def test_barrier_world_and_per_tier(vc, comm):
    tok = jnp.ones((vc.num_devices,), jnp.float32)
    out = vc.run(lambda t: sync.barrier(t, vc.axis_names), tok)
    np.testing.assert_allclose(np.asarray(out), float(vc.num_devices))
    # node-tier barrier: sums over ranks_per_node only
    out_f = vc.run(lambda t: sync.barrier(t, vc.fast), tok)
    np.testing.assert_allclose(np.asarray(out_f), float(vc.chips))
    # communicator-level world barrier matches the raw one
    out_c = vc.run(comm.barrier, tok)
    np.testing.assert_allclose(np.asarray(out_c), float(vc.num_devices))


def test_flag_chain_permutes_ring(vc):
    """flag_chain is a ring send: rank r's token lands on its successor, so
    distinct tokens must come back a cyclic shift — not a reduction."""
    tok = jnp.arange(vc.num_devices, dtype=jnp.float32)
    out = np.asarray(vc.run(lambda t: sync.flag_chain(t, vc.axis_names), tok))
    assert sorted(out.tolist()) == sorted(range(vc.num_devices))
    assert not np.array_equal(out, np.asarray(tok)) or vc.num_devices == 1


def test_flag_chain_fast_tier_only(vc):
    """A node-tier chain permutes within each pod: pods keep their own
    token sets."""
    tok = jnp.arange(vc.num_devices, dtype=jnp.float32)
    out = np.asarray(vc.run(lambda t: sync.flag_chain(t, vc.fast), tok))
    pods = out.reshape(vc.pods, vc.chips)
    want = np.arange(vc.num_devices, dtype=np.float32) \
        .reshape(vc.pods, vc.chips)
    for p in range(vc.pods):
        assert sorted(pods[p].tolist()) == sorted(want[p].tolist())


def test_leader_flag_counts_children(vc):
    tok = jnp.ones((vc.num_devices,), jnp.float32)
    out = vc.run(lambda t: sync.leader_flag(t, fast_axis=vc.fast), tok)
    np.testing.assert_allclose(np.asarray(out), float(vc.chips - 1))


# ---------------------------------------------------------------------------
# Serving-engine integration: window-wrapped params
# ---------------------------------------------------------------------------

def test_engine_materializes_degenerate_windows():
    from repro.serving.engine import materialize_params

    comm1 = Communicator(fast_axis="data", pods=4, chips=1)
    params = {"w": SharedWindow(comm1, jnp.ones((2, 2)), epoch=1),
              "b": jnp.zeros((2,))}
    out = materialize_params(params)
    assert isinstance(out["w"], jnp.ndarray)
    np.testing.assert_allclose(np.asarray(out["w"]), 1.0)

    comm4 = Communicator(fast_axis="data", pods=1, chips=4)
    with pytest.raises(ValueError, match="SharedWindow"):
        materialize_params({"w": SharedWindow(comm4, jnp.ones((2, 2)),
                                              epoch=1)})
    with pytest.raises(ValueError, match="dirty"):
        materialize_params({"w": SharedWindow(comm1, jnp.ones((2, 2)),
                                              epoch=1, dirty=True)})
    # unknown width (no static chips count) is unreadable, not degenerate:
    # the shard may be a fraction of the weight
    comm_unk = Communicator(fast_axis="data")
    with pytest.raises(ValueError, match="unknown"):
        materialize_params({"w": SharedWindow(comm_unk, jnp.ones((2, 2)),
                                              epoch=1)})


# ---------------------------------------------------------------------------
# ParallelCtx gradient reduction through the communicator
# ---------------------------------------------------------------------------

def test_reduce_grads_covers_every_dp_shape():
    """The dp reduction must cover EXACTLY dp_axes for every constructible
    ctx — including bridge-only dp (dp_axes == (pod,), no node-tier data
    axis), which has no parameter-store communicator."""
    from repro.models.parallel import ParallelCtx

    vc = VirtualCluster(pods=4, chips=2)
    if not vc.available():
        pytest.skip("needs 8 devices")
    x = jnp.ones((vc.num_devices, 3), jnp.float32)

    cases = [
        # (ctx, expected summed-over rank count)
        (ParallelCtx(mode="naive", dp_axes=("pod", "data"),
                     pod_axis="pod"), 8),
        (ParallelCtx(mode="naive", dp_axes=("pod",), pod_axis="pod"), 4),
        (ParallelCtx(mode="naive", dp_axes=("data",), pod_axis="pod"), 2),
        (ParallelCtx(mode="hier", fsdp_axes=("data",), pod_axis="pod"), 4),
        (ParallelCtx(mode="hier", dp_axes=("pod",), pod_axis="pod"), 4),
    ]
    for ctx, want in cases:
        out = vc.run(lambda v, c=ctx: c.reduce_grads({"g": v})["g"], x)
        np.testing.assert_allclose(np.asarray(out), float(want),
                                   err_msg=f"{ctx.mode} dp={ctx.dp_axes} "
                                           f"fsdp={ctx.fsdp_axes}")
