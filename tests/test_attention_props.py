"""Property tests: the blocked online-softmax attention (models/attention)
must equal exact softmax attention for any shape/mask regime, and the
analytic FLOP formula must be consistent."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.kernels.ref import attention_ref
from repro.models.attention import attn_flops, flash_attention


@given(st.integers(1, 3),                    # B
       st.sampled_from([(4, 4), (4, 2), (8, 1), (6, 3)]),  # (H, KV)
       st.sampled_from([16, 32]),            # hd
       st.sampled_from([17, 33, 64, 100]),   # Tq
       st.integers(0, 2),                    # extra kv blocks
       st.sampled_from([None, 8, 24]),       # window
       st.integers(0, 5))                    # seed
@settings(max_examples=60, deadline=None)
def test_blocked_attention_equals_exact(B, heads, hd, Tq, extra, window,
                                        seed):
    H, KV = heads
    Tkv = Tq + 16 * extra
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, Tq, H, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, Tkv, KV, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, Tkv, KV, hd)).astype(np.float32))
    q_off = Tkv - Tq
    got = flash_attention(q, k, v, causal=True, window=window,
                          q_offset=q_off, H=H, block=16)
    # ref wants (B, H, T, hd)
    want = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                         v.transpose(0, 2, 1, 3), causal=True, window=window,
                         q_offset=q_off).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@given(st.integers(1, 8), st.integers(1, 512), st.integers(1, 16),
       st.sampled_from([32, 64]))
@settings(max_examples=50, deadline=None)
def test_attn_flops_monotone_and_bounded(B, T, H, hd):
    full = attn_flops(B, T, T, H, hd, causal=False, window=None)
    causal = attn_flops(B, T, T, H, hd, causal=True, window=None)
    windowed = attn_flops(B, T, T, H, hd, causal=True, window=max(T // 2, 1))
    assert windowed <= full + 1e-6
    assert causal <= full
    assert causal >= full / 2 - 1e-6  # (T+1)/2T of the pairs
