"""Pipelined (chunked two-phase) collectives + fused collective-matmul.

Equivalence discipline: every pipelined primitive must match its unchunked
reference scheme bit-for-bit-close over the WHOLE topology matrix
(single-node, seed, transpose, bridge-only, tuple-axis) for every valid
chunk count — chunking is scheduling, never semantics.  The double-buffered
window keeps the paper's §6 integrity rule: a mid-pipeline read of a
still-dirty buffer raises ``WindowEpochError`` (see also
``test_pipeline_props.py`` for the hypothesis n_chunks-invariance
property).
"""

import numpy as np
import pytest

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.comm import Communicator, SharedWindow, WindowEpochError, pipeline
from repro.substrate import VirtualCluster, default_matrix

MATRIX = default_matrix()


@pytest.fixture(params=MATRIX, ids=[t.label for t in MATRIX])
def vc(request) -> VirtualCluster:
    cluster = request.param
    if not cluster.available():
        pytest.skip(f"needs {cluster.num_devices} devices")
    return cluster


@pytest.fixture
def comm(vc) -> Communicator:
    return Communicator.from_cluster(vc)


# ---------------------------------------------------------------------------
# Chunk layout algebra (pure, no devices)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("blocks,nc,piece", [(1, 1, 4), (4, 2, 3),
                                             (8, 4, 1), (3, 5, 2)])
@pytest.mark.parametrize("axis", [0, 1])
def test_strided_split_merge_roundtrip(blocks, nc, piece, axis):
    n = blocks * nc * piece
    x = jnp.arange(n * 2, dtype=jnp.float32).reshape(n, 2)
    x = jnp.moveaxis(x[..., None], 0, axis)
    parts = pipeline._split_strided(x, axis, nc, blocks)
    assert len(parts) == nc
    back = pipeline._merge_strided(parts, axis, blocks)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


def test_split_rejects_indivisible():
    with pytest.raises(ValueError, match="n_chunks"):
        pipeline._split_blocked(jnp.zeros(6), 0, 4)
    with pytest.raises(ValueError, match="stride"):
        pipeline._split_strided(jnp.zeros(6), 0, 2, blocks=4)


# ---------------------------------------------------------------------------
# Equivalence vs the unchunked reference over the full matrix
# ---------------------------------------------------------------------------

CHUNKS = (1, 2, 4)


def test_pipelined_allgather_equals_hier_every_chunking(vc, comm):
    x = vc.rank_major_input(m=8, extra=2)
    want = np.asarray(vc.run(lambda v: comm.allgather(v, scheme="hier"),
                             x, out_specs=P(None)))
    for nc in CHUNKS:
        got = vc.run(lambda v, n=nc: comm.allgather(
            v, scheme="pipelined", n_chunks=n), x, out_specs=P(None))
        np.testing.assert_allclose(np.asarray(got), want, err_msg=f"nc={nc}")


def test_pipelined_broadcast_equals_hier_every_chunking(vc, comm):
    R = vc.num_devices
    msg = np.random.default_rng(3).normal(size=(R, 12, 2)).astype(np.float32)
    x = jnp.asarray(msg)
    root = R - 1                     # non-leader root
    want = np.asarray(vc.run(lambda v: comm.broadcast(
        v[0], root=root, scheme="hier")[None], x))
    for nc in CHUNKS:
        got = vc.run(lambda v, n=nc: comm.broadcast(
            v[0], root=root, scheme="pipelined", n_chunks=n)[None], x)
        np.testing.assert_allclose(np.asarray(got), want, err_msg=f"nc={nc}")


def test_pipelined_psum_equals_hier_every_chunking(vc, comm):
    R = vc.num_devices
    m = 4 * vc.chips * 4             # tiles by chips x every chunk count
    x = jnp.arange(R * m, dtype=jnp.float32).reshape(R, m) / (R * m)
    want = np.asarray(vc.run(lambda v: comm.allreduce(
        v[0], scheme="hier")[None], x))
    for nc in CHUNKS:
        got = vc.run(lambda v, n=nc: comm.allreduce(
            v[0], scheme="pipelined", n_chunks=n)[None], x)
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6,
                                   err_msg=f"nc={nc}")


def test_pipelined_reduce_scatter_equals_naive_every_chunking(vc, comm):
    R = vc.num_devices
    m = 4 * R * 4
    x = jnp.arange(R * m, dtype=jnp.float32).reshape(R, m) / (R * m)
    want = np.asarray(vc.run(lambda v: comm.reduce_scatter(
        v[0], scheme="naive"), x, in_specs=(vc.spec,),
        out_specs=P(vc.axis_names)))
    for nc in CHUNKS:
        got = vc.run(lambda v, n=nc: comm.reduce_scatter(
            v[0], scheme="pipelined", n_chunks=n), x, in_specs=(vc.spec,),
            out_specs=P(vc.axis_names))
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6,
                                   err_msg=f"nc={nc}")


# ---------------------------------------------------------------------------
# Double-buffered window epochs (paper §6 mid-pipeline)
# ---------------------------------------------------------------------------

def test_double_buffered_window_rejects_torn_read_mid_pipeline(vc, comm):
    """Walking the pipeline's own double-buffer sequence by hand: each
    chunk's staged intermediate opens a dirty epoch in buffer k%2; reading
    it BEFORE the epoch closes must raise — fence_local (the pipeline's
    zero-cost close) makes it readable and preserves the payload."""
    node = comm.split_type_shared()
    x = vc.rank_major_input(m=4)

    def body(v):
        chunks = pipeline._split_blocked(v, 0, 2)
        bufs, outs = [None, None], []
        for k, ck in enumerate(chunks):
            staged = node.allgather(ck, scheme="shared").shard
            win = SharedWindow(node, staged, axis=0, epoch=k, dirty=True)
            if k == 0:
                with pytest.raises(WindowEpochError, match="fence"):
                    win.read()              # torn read mid-pipeline
            win = win.fence_local(jnp.ones((), jnp.float32))
            assert not win.dirty and win.epoch == k + 1
            bufs[k % 2] = win
            outs.append(win.shard)
        return jnp.concatenate(outs, axis=0)

    out = vc.run(body, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x))


def test_fence_local_is_value_preserving_for_nonfinite(vc, comm):
    bad = np.full((vc.num_devices * 2,), np.nan, np.float32)
    bad[1::2] = np.inf
    out = vc.run(lambda v: comm.window(v, epoch=1)
                 .store(v).fence_local(jnp.ones((), jnp.float32)).shard,
                 jnp.asarray(bad))
    np.testing.assert_array_equal(np.asarray(out), bad)


# ---------------------------------------------------------------------------
# Fused collective-matmul
# ---------------------------------------------------------------------------

def _mm_case(vc, seed=0, k_per_rank=6, n_out=5, m_rows=4):
    rng = np.random.default_rng(seed)
    K = vc.chips * k_per_rank
    w = rng.normal(size=(K, n_out)).astype(np.float32)
    x = rng.normal(size=(m_rows, K)).astype(np.float32)
    return x, w


@pytest.mark.parametrize("nc", [1, 2, 3])
def test_ag_matmul_matches_unfused(vc, comm, nc):
    """x @ read(window) == the fused per-chunk gather/matmul, for every
    chunk count dividing the shard rows (incl. tuple-axis fast tiers)."""
    x, w = _mm_case(vc, k_per_rank=6)          # shard rows 6 % {1,2,3} == 0
    node = comm.split_type_shared()
    want = x @ w

    def body(w_sh):
        # local w_sh: this chip's (k_per_rank, n_out) window shard
        return node.ag_matmul(jnp.asarray(x), w_sh, n_chunks=nc)[None]

    w_tiled = jnp.asarray(np.tile(w.reshape(vc.chips, -1, w.shape[1]),
                                  (vc.pods, 1, 1)).reshape(-1, w.shape[1]))
    got = vc.run(body, w_tiled, in_specs=(vc.spec,), out_specs=vc.spec)
    got = np.asarray(got).reshape(vc.num_devices, *want.shape)
    for r in range(vc.num_devices):
        np.testing.assert_allclose(got[r], want, rtol=1e-5)


def test_ag_matmul_rows_matches_unfused(vc, comm):
    """read(window) @ b with the window sharded along OUTPUT rows (the
    SUMMA A-panel): per-chunk row panels merge to the exact product."""
    rng = np.random.default_rng(1)
    rows, k, n_out = vc.chips * 4, 3, 5
    a = rng.normal(size=(rows, k)).astype(np.float32)
    b = rng.normal(size=(k, n_out)).astype(np.float32)
    node = comm.split_type_shared()
    want = a @ b

    def body(a_sh):
        return node.ag_matmul_rows(a_sh, jnp.asarray(b), n_chunks=2)[None]

    a_tiled = jnp.asarray(np.tile(a.reshape(vc.chips, -1, k),
                                  (vc.pods, 1, 1)).reshape(-1, k))
    got = vc.run(body, a_tiled, in_specs=(vc.spec,), out_specs=vc.spec)
    got = np.asarray(got).reshape(vc.num_devices, *want.shape)
    for r in range(vc.num_devices):
        np.testing.assert_allclose(got[r], want, rtol=1e-5)


def test_matmul_rs_matches_unfused(vc, comm):
    """reduce_scatter(x @ w) over the node tier == the fused per-chunk
    matmul/scatter, independently per pod."""
    rng = np.random.default_rng(2)
    rows, k, n_out = vc.chips * 4, 3, 5
    node = comm.split_type_shared()
    xs = rng.normal(size=(vc.num_devices, rows, k)).astype(np.float32)
    w = rng.normal(size=(k, n_out)).astype(np.float32)

    def body(xi):
        return node.matmul_rs(xi[0], jnp.asarray(w), axis=0, n_chunks=2)

    out_specs = P(vc.axis_names)    # rank-major concat of the 1/c slices
    got = np.asarray(vc.run(body, jnp.asarray(xs), in_specs=(vc.spec,),
                            out_specs=out_specs))
    got = got.reshape(vc.pods, rows, n_out)
    for pd in range(vc.pods):
        want = sum(xs[pd * vc.chips + i] @ w for i in range(vc.chips))
        np.testing.assert_allclose(got[pd], want, rtol=1e-4)


def test_ag_matmul_through_pallas_kernel():
    """The fused path composes with the Pallas blocked-matmul kernel
    (interpret mode on CPU) — the ISSUE's compute-overlap accumulation."""
    vc = VirtualCluster(pods=1, chips=4)
    if not vc.available():
        pytest.skip("needs 4 devices")
    comm = Communicator.from_cluster(vc)
    x, w = _mm_case(vc, k_per_rank=8, n_out=4, m_rows=4)
    want = x @ w

    def body(w_sh):
        return comm.ag_matmul(jnp.asarray(x), w_sh, n_chunks=2,
                              use_kernel=True)[None]

    got = vc.run(body, jnp.asarray(w), in_specs=(vc.spec,),
                 out_specs=vc.spec)
    got = np.asarray(got).reshape(vc.num_devices, *want.shape)
    np.testing.assert_allclose(got[0], want, rtol=1e-4)


# ---------------------------------------------------------------------------
# ParallelCtx fast paths (the "overlap" opt)
# ---------------------------------------------------------------------------

def test_parallel_ctx_overlap_paths_match_baseline():
    """ffn-style ag_matmul (FSDP window read) and attention-style matmul_rs
    (SP scatter) must be numerically indistinguishable with the opt on."""
    from repro.models.parallel import ParallelCtx

    vc = VirtualCluster(pods=2, chips=4, fast_axis=("data", "model"),
                        fast_shape=(2, 2), slow_axis="pod")
    if not vc.available():
        pytest.skip("needs 8 devices")
    kw = dict(tp_axis="model", fsdp_axes=("data",),
              dp_axes=("pod", "data"), pod_axis="pod", tp=2, mode="hier",
              compute_dtype=jnp.float32)
    base = ParallelCtx(**kw)
    fused = ParallelCtx(**kw, opts=frozenset({"overlap"}))

    rng = np.random.default_rng(7)
    B, T, F, D = 2, 8, 4, 6          # w: (F*data, D), fsdp dim 0 over "data"
    w = rng.normal(size=(F * 2, D)).astype(np.float32)
    w2 = rng.normal(size=(D, 2 * D)).astype(np.float32)
    x = rng.normal(size=(B, T, F * 2)).astype(np.float32)

    def body_for(ctx):
        def body(w_sh, xv):
            # local w_sh: this rank's (F, D) fsdp shard; x replicated
            y = ctx.ag_matmul(xv, w_sh, 0)               # (B, T, D)
            z = ctx.matmul_rs(y, jnp.asarray(w2), 1)     # (B, T/tp, 2D)
            return z
        return body

    outs = {}
    for name, ctx in (("base", base), ("fused", fused)):
        outs[name] = np.asarray(vc.run(
            body_for(ctx), jnp.asarray(w), jnp.asarray(x),
            in_specs=(P("data"), P(None)), out_specs=P(None, "model")))
    # fused panels reassociate the fp32 accumulation — numerics, not bits
    np.testing.assert_allclose(outs["fused"], outs["base"], rtol=1e-4,
                               atol=1e-5)


def test_clamp_chunks_always_tiles():
    from repro.models.parallel import _clamp_chunks
    assert _clamp_chunks(2, 8) == 2
    assert _clamp_chunks(4, 6) == 3      # largest divisor <= 4
    assert _clamp_chunks(8, 7) == 7
    assert _clamp_chunks(2, 1) == 1
    assert _clamp_chunks(3, 0) == 1
