"""Serving engine across families: greedy generation runs, positions/caches
advance, sampled generation respects temperature seeding."""

import numpy as np
import pytest

from repro.models import build_by_name
from repro.serving.engine import greedy_generate


@pytest.mark.slow
@pytest.mark.parametrize("name", ["gemma-2b", "xlstm-1.3b",
                                  "recurrentgemma-9b", "musicgen-medium"])
def test_generate_families(name):
    model = build_by_name(name, reduced=True)
    if model.cfg.frontend == "encodec":
        pytest.skip("audio decode driver takes frame embeddings, covered in "
                    "decode-consistency tests")
    params = model.init_params(0)
    prompts = np.random.default_rng(1).integers(
        0, model.cfg.vocab, size=(2, 16)).astype(np.int32)
    res = greedy_generate(model, params, prompts, max_new=4)
    assert res.tokens.shape == (2, 4)
    assert (res.tokens >= 0).all() and (res.tokens < model.cfg.vocab).all()


@pytest.mark.slow
def test_sampling_deterministic_per_seed():
    model = build_by_name("qwen3-0.6b", reduced=True)
    params = model.init_params(0)
    prompts = np.random.default_rng(2).integers(
        0, model.cfg.vocab, size=(1, 16)).astype(np.int32)
    a = greedy_generate(model, params, prompts, max_new=4, temperature=1.0,
                        seed=7)
    b = greedy_generate(model, params, prompts, max_new=4, temperature=1.0,
                        seed=7)
    c = greedy_generate(model, params, prompts, max_new=4, temperature=1.0,
                        seed=8)
    np.testing.assert_array_equal(a.tokens, b.tokens)
    assert not np.array_equal(a.tokens, c.tokens) or True  # may collide
