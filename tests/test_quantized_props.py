"""Property tests for the int4 wire format (hypothesis; skipped at
collection when hypothesis is not installed — see ``tests/conftest.py``).

The pack/unpack pair is the one piece of the quantized schemes with a
bit-level contract (two codes per byte, bias to ``[1, 15]``): a rounding
bound won't catch a nibble swap, only exact round-trip over the full code
book will.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.comm import quantize as qz

codes = st.integers(min_value=-7, max_value=7)


@settings(deadline=None, max_examples=50)
@given(st.lists(codes, min_size=2, max_size=128).map(
    lambda v: v[: len(v) // 2 * 2]))
def test_pack_unpack_int4_roundtrip(vals):
    q = jnp.asarray(np.array(vals, np.int8))
    packed = qz.pack_int4(q)
    assert packed.dtype == jnp.uint8
    assert packed.shape[-1] == len(vals) // 2
    np.testing.assert_array_equal(np.asarray(qz.unpack_int4(packed)),
                                  np.array(vals, np.int8))


@settings(deadline=None, max_examples=25)
@given(st.integers(min_value=1, max_value=6),
       st.lists(codes, min_size=8, max_size=8))
def test_pack_int4_never_emits_zero_bytes(rows, vals):
    """The bias to [1, 15] means no nibble is ever 0: an all-zero packed
    buffer always signals a bug, never a legal payload."""
    q = jnp.asarray(np.tile(np.array(vals, np.int8), (rows, 1)))
    packed = np.asarray(qz.pack_int4(q))
    assert np.all((packed & 0xF) != 0) and np.all((packed >> 4) != 0)


@settings(deadline=None, max_examples=25)
@given(st.lists(st.floats(min_value=-100.0, max_value=100.0,
                          allow_nan=False, width=32),
                min_size=32, max_size=32),
       st.integers(min_value=1, max_value=4))
def test_quantize_q4_roundtrip_within_grid(col, ncols):
    """Groupwise int4 weight round-trip: error per element stays within
    half a quantization step of its group's amax grid."""
    w = np.tile(np.array(col, np.float32)[:, None], (1, ncols))
    packed, scales = qz.quantize_q4(jnp.asarray(w), group=32)
    deq = np.asarray(qz.dequantize_q4(packed, scales, group=32))
    amax = np.max(np.abs(w), axis=0)
    assert np.all(np.abs(deq - w) <= amax / 14 + 1e-6)
