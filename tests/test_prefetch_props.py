"""Hypothesis properties of the prefetcher's in-flight budget.

``prefetch_schedule`` is pure data (the event order the prefetch walk
executes), so the FSDP2-style lifecycle invariants are checkable without
tracing a model: bounded occupancy, per-group event ordering, program-order
compute, and exactly-once semantics for every event kind.
"""

from hypothesis import given, strategies as st

from repro.models.parallel import prefetch_schedule

ns = st.integers(min_value=0, max_value=12)
budgets = st.integers(min_value=0, max_value=8)


@given(ns, budgets)
def test_schedule_exactly_once_and_ordered(n, budget):
    events = prefetch_schedule(n, budget)
    assert len(events) == 4 * n
    for k in range(n):
        per = [ev for ev, g in events if g == k]
        assert per == ["unshard", "wait", "compute", "reshard"]


@given(ns, budgets)
def test_schedule_computes_in_program_order(n, budget):
    order = [g for ev, g in prefetch_schedule(n, budget) if ev == "compute"]
    assert order == list(range(n))


@given(ns, budgets)
def test_schedule_in_flight_budget_bounded(n, budget):
    """Between its unshard and its reshard a group occupies an unsharded
    slot; occupancy never exceeds the budget (floor 1 — the current group
    itself) and the budget is actually USED: with enough groups the
    steady-state occupancy reaches exactly min(budget, n)."""
    eff = max(1, budget)
    live, peak = set(), 0
    for ev, g in prefetch_schedule(n, budget):
        if ev == "unshard":
            assert g not in live
            live.add(g)
        elif ev in ("wait", "compute"):
            assert g in live          # never touch a group not in flight
        else:
            live.remove(g)
        peak = max(peak, len(live))
    assert not live                   # everything resharded at the end
    assert peak == min(eff, n)
