"""Hypothesis properties of the pipelined family.

The load-bearing invariant: for ANY valid chunk count the pipelined
collectives return exactly the unchunked reference result — chunking is
scheduling, never semantics.  Runs on the seed 2x4 cluster (the matrix
sweep lives in ``test_pipeline.py``); the pure latency-model properties
live in ``test_plans.py``.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.comm import Communicator
from repro.substrate import VirtualCluster

VC = VirtualCluster(pods=2, chips=4)
COMM = Communicator.from_cluster(VC)
R = VC.num_devices

needs_matrix = pytest.mark.skipif(not VC.available(),
                                  reason="needs 8 devices")

# per-rank message length = n_chunks * chips * k so EVERY family tiles
# (psum needs % (nc*c), reduce_scatter % (nc*R) — use nc*R*k)
chunk_counts = st.integers(min_value=1, max_value=8)
mults = st.integers(min_value=1, max_value=3)
seeds = st.integers(min_value=0, max_value=2 ** 16)


@needs_matrix
@given(chunk_counts, mults, seeds)
@settings(max_examples=12, deadline=None)
def test_allgather_invariant_to_n_chunks(nc, k, seed):
    m = nc * k
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(R * m, 2)).astype(np.float32))
    want = np.asarray(VC.run(lambda v: COMM.allgather(v, scheme="hier"),
                             x, out_specs=P(None)))
    got = VC.run(lambda v: COMM.allgather(v, scheme="pipelined",
                                          n_chunks=nc), x, out_specs=P(None))
    np.testing.assert_array_equal(np.asarray(got), want)


@needs_matrix
@given(chunk_counts, mults, seeds, st.integers(min_value=0,
                                               max_value=R - 1))
@settings(max_examples=12, deadline=None)
def test_broadcast_invariant_to_n_chunks(nc, k, seed, root):
    m = nc * k
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(R, m)).astype(np.float32))
    want = np.asarray(VC.run(lambda v: COMM.broadcast(
        v[0], root=root, scheme="hier")[None], x))
    got = VC.run(lambda v: COMM.broadcast(
        v[0], root=root, scheme="pipelined", n_chunks=nc)[None], x)
    np.testing.assert_array_equal(np.asarray(got), want)


@needs_matrix
@given(chunk_counts, mults, seeds)
@settings(max_examples=12, deadline=None)
def test_psum_and_reduce_scatter_invariant_to_n_chunks(nc, k, seed):
    m = nc * R * k                  # tiles for both families at any nc
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(R, m)).astype(np.float32) / R)

    want = np.asarray(VC.run(lambda v: COMM.allreduce(
        v[0], scheme="hier")[None], x))
    got = VC.run(lambda v: COMM.allreduce(
        v[0], scheme="pipelined", n_chunks=nc)[None], x)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6, atol=1e-7)

    want_rs = np.asarray(VC.run(lambda v: COMM.reduce_scatter(
        v[0], scheme="naive"), x, in_specs=(VC.spec,),
        out_specs=P(VC.axis_names)))
    got_rs = VC.run(lambda v: COMM.reduce_scatter(
        v[0], scheme="pipelined", n_chunks=nc), x, in_specs=(VC.spec,),
        out_specs=P(VC.axis_names))
    # two-phase RS reassociates the sum (pods first): bitwise equality is
    # not guaranteed against the flat ring, only numerics
    np.testing.assert_allclose(np.asarray(got_rs), want_rs, rtol=1e-5,
                               atol=1e-6)
