"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, shape + finiteness asserts (the FULL configs are exercised only via the
dry-run)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs
from repro.models import build_by_name, make_batch

ALL_ARCHS = ["qwen3-moe-235b-a22b", "granite-moe-3b-a800m", "xlstm-1.3b",
             "qwen3-0.6b", "starcoder2-7b", "gemma-2b", "mistral-nemo-12b",
             "internvl2-1b", "recurrentgemma-9b", "musicgen-medium"]


def test_all_archs_registered():
    assert sorted(ALL_ARCHS) == sorted(list_configs())


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_forward_and_grad(name):
    m = build_by_name(name, reduced=True)
    params = m.init_params(0)
    batch = make_batch(m.cfg, B=2, T=32)

    def lf(p):
        l, c = m.loss_fn(p, batch)
        return l / c

    loss, grads = jax.jit(jax.value_and_grad(lf))(params)
    assert np.isfinite(float(loss))
    # random init, uniform softmax: loss ~ ln(vocab)
    assert abs(float(loss) - np.log(m.cfg.vocab_padded)) < 1.0
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf))), name


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_param_count_close_to_spec(name):
    cfg = get_config(name)
    model_params = cfg.param_count()
    assert model_params > 0
    # stacked init shapes must reproduce the analytic count within 5%
    m = build_by_name(name)
    abstract = jax.eval_shape(lambda: m.init_params(0))
    total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(abstract))
    # moe physical layout pads nothing; vocab padding adds < 1%
    assert abs(total - model_params) / model_params < 0.05, (total,
                                                             model_params)


@pytest.mark.slow
@pytest.mark.parametrize("name", ["qwen3-0.6b", "qwen3-moe-235b-a22b",
                                  "xlstm-1.3b", "recurrentgemma-9b"])
def test_decode_matches_prefill(name):
    """Cache relayout / ring buffers / recurrent state continuation."""
    T, T0 = 32, 16
    m = build_by_name(name, reduced=True)
    params = m.init_params(0)
    batch = make_batch(m.cfg, B=2, T=T)
    _, ref_logits = jax.jit(lambda p, b: m.prefill_fn(p, b, T))(params, batch)

    if m.cfg.frontend == "encodec":
        b0 = {"frames": batch["frames"][:, :T0],
              "labels": batch["labels"][:, :T0]}
        steps = [batch["frames"][:, t:t + 1] for t in range(T0, T)]
    else:
        b0 = dict(batch, tokens=batch["tokens"][:, :T0 + 1])
        steps = [batch["tokens"][:, t:t + 1] for t in range(T0, T)]
    cache, logits = jax.jit(lambda p, b: m.prefill_fn(p, b, T))(params, b0)
    dec = jax.jit(m.decode_fn)
    for i, tok in enumerate(steps):
        cache, logits = dec(params, cache, tok, jnp.int32(T0 + i))
    # MoE capacity drops differ between prefill and decode token counts
    tol = 0.05 if m.cfg.moe else 1e-4
    err = float(jnp.max(jnp.abs(logits - ref_logits)))
    scale = float(jnp.max(jnp.abs(ref_logits))) + 1e-9
    assert err / scale < tol, (name, err / scale)


def test_reduced_configs_stay_in_family():
    for name in ALL_ARCHS:
        cfg = get_config(name)
        red = cfg.reduced()
        assert red.family == cfg.family
        assert red.pattern == cfg.pattern
        assert (red.moe is None) == (cfg.moe is None)
