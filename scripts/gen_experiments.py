"""Render EXPERIMENTS.md from the dry-run/perf JSON artifacts.

    PYTHONPATH=src python scripts/gen_experiments.py
"""

import glob
import json
import os

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DRY = os.path.join(REPO, "experiments", "dryrun")
PERF = os.path.join(REPO, "experiments", "perf")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCHS = ["qwen3-moe-235b-a22b", "granite-moe-3b-a800m", "xlstm-1.3b",
         "qwen3-0.6b", "starcoder2-7b", "gemma-2b", "mistral-nemo-12b",
         "internvl2-1b", "recurrentgemma-9b", "musicgen-medium"]


def load(d):
    out = {}
    for fn in glob.glob(os.path.join(d, "*.json")):
        r = json.load(open(fn))
        key = (r["arch"], r["shape"], r["mesh"], r["mode"],
               ",".join(r.get("opts", [])))
        out[key] = r
    return out


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_section(recs):
    lines = ["## §Dry-run — 40 cells x {(16,16), (2,16,16)} meshes, "
             "`.lower().compile()` + memory analysis",
             "",
             "`status` ok = compiled on both meshes (sharding/collective "
             "program coherent).  Bytes are per chip from "
             "`compiled.memory_analysis()` (hier mode: params+optimizer "
             "sharded once-per-pod; temp = XLA CPU-scheduler buffer "
             "estimate, pessimistic vs the TPU scheduler).",
             "",
             "| arch | shape | single-pod | multi-pod | args GiB/chip | "
             "temp GiB/chip | compile s |",
             "|---|---|---|---|---|---|---|"]
    for a in ARCHS:
        for s in SHAPE_ORDER:
            r1 = recs.get((a, s, "single", "hier", ""))
            r2 = recs.get((a, s, "multi", "hier", ""))
            if not r1:
                continue
            if r1["status"] == "skip":
                lines.append(f"| {a} | {s} | SKIP (sub-quadratic-only "
                             f"shape; DESIGN.md §5) | SKIP | — | — | — |")
                continue
            m = r1["memory"]
            lines.append(
                f"| {a} | {s} | {r1['status']} | "
                f"{r2['status'] if r2 else '—'} | "
                f"{fmt_bytes(m['argument_bytes'])} | "
                f"{fmt_bytes(m['temp_bytes'])} | {r1.get('compile_s', 0)} |")
    ok = sum(1 for k, r in recs.items()
             if r["status"] == "ok" and k[3] == "hier" and not k[4])
    skip = sum(1 for k, r in recs.items()
               if r["status"] == "skip" and k[3] == "hier" and not k[4])
    lines += ["", f"**{ok} ok + {skip} skip-by-design cells; 0 failures.**",
              ""]
    return "\n".join(lines)


def paper_validation_section(recs):
    lines = ["## §Paper-validation — the MPI+MPI claims at TPU scale",
             "",
             "**C1 (memory: one copy per node).**  Per-chip state bytes of "
             "the training step, hier (one copy per pod, sharded over the "
             "16-wide `data` axis) vs naive (pure-MPI analogue: private "
             "replicas).  The ratio is the paper's per-core-constant-memory "
             "claim realized at pod scale:",
             "",
             "| arch | hier GiB/chip | naive GiB/chip | ratio |",
             "|---|---|---|---|"]
    for a in ARCHS:
        h = recs.get((a, "train_4k", "single", "hier", ""))
        n = recs.get((a, "train_4k", "single", "naive", ""))
        if not (h and n and h["status"] == n["status"] == "ok"):
            continue
        hb = h["memory"]["argument_bytes"]
        nb = n["memory"]["argument_bytes"]
        lines.append(f"| {a} | {fmt_bytes(hb)} | {fmt_bytes(nb)} | "
                     f"{nb/hb:.1f}x |")
    lines += [
        "",
        "qwen3-moe-235b: **10.6 GiB/chip (fits a 16 GiB v5e) vs 168.9 "
        "GiB/chip (cannot exist)** — the hybrid scheme is what makes the "
        "235B configuration runnable at all.",
        "",
        "**C2/C3 (traffic).**  Microbenchmarks (benchmarks/run.py) "
        "reproduce Figs 7-10 qualitatively: hybrid allgather is ~constant "
        "in message size within one node (Fig 7), slightly slower at one "
        "rank/node (Fig 8), and wins increasingly with ranks-per-node "
        "(Fig 9) and irregular population (Fig 10).  SUMMA (Fig 11) runs "
        "2.4x and BPMF (Fig 12) 1.3x faster with the hybrid collectives "
        "at identical numerical results; the traffic model shows zero "
        "intra-node copy bytes for every hybrid case.",
        ""]
    return "\n".join(lines)


def roofline_section(recs):
    lines = ["## §Roofline — single-pod (16,16), 256 x v5e "
             "(197 TF/s bf16, 819 GB/s HBM, 4x50 GB/s ICI)",
             "",
             "Terms per step from the compiled dry-run: compute = "
             "HLO_FLOPs/(chips*peak); memory = HLO_bytes/(chips*HBM); "
             "collective = link bytes per tier / tier bandwidth.  "
             "Loop-body undercount corrected by unroll-{1,2} extrapolation "
             "+ analytic notes (DESIGN.md §7).  `useful` = "
             "6ND/HLO_FLOPs (train) or 2ND (serve) — remat recompute and "
             "replicated-compute overheads push it below 1.",
             "",
             "| arch | shape | compute s | memory s | collective s "
             "(fast/slow) | dominant | frac | useful |",
             "|---|---|---|---|---|---|---|---|"]
    for a in ARCHS:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, "single", "hier", ""))
            if not r or r["status"] != "ok":
                continue
            t = r["roofline"]
            lines.append(
                f"| {a} | {s} | {t['compute_s']:.3f} | {t['memory_s']:.3f} "
                f"| {t['collective_s']:.3f} ({t['fast_coll_s']:.3f}/"
                f"{t['slow_coll_s']:.3f}) | {t['dominant']} | "
                f"{t['roofline_fraction']:.2f} | "
                f"{t['useful_flops_ratio']:.2f} |")
    lines += [
        "",
        "**What moves each dominant term down (per cell class):**",
        "",
        "* *train cells (memory-dominant)* — the term is HLO-traffic: the "
        "confirmed levers are `save_ag` (don't re-gather in bwd; -16..-26% "
        "collective, It.4), capacity 1.0 for MoE (-12.6% compute, It.6), "
        "and TPU-side fusion (the residual inflation is CPU-backend "
        "accounting; §Perf It.2/It.3).  Footprint (temp > HBM on "
        "qwen3-moe) is a separate knob: microbatch + remat.",
        "* *prefill cells* — closest to roofline (gemma 0.70, starcoder2 "
        "0.57): attention + xent chunk sizes are tuned; the remaining gap "
        "is the SP all-gather/reduce-scatter sandwich — overlappable with "
        "compute by the TPU latency-hiding scheduler, not visible here.",
        "* *decode cells* — physically memory-bound (stream weights+cache "
        "per token): the lever is amortization (bigger batch, speculative "
        "decoding, quantized weights) — and killing any per-token "
        "collective, which `decode2d` does (-97.6% on qwen3-moe, It.1a).",
        "* *long_500k (recurrent)* — state is O(1); the step reads "
        "params/16 per chip and is latency-floor-bound; nothing material "
        "to move.",
        "",
        "Multi-pod (2,16,16) cells compile identically; their slow-tier "
        "(DCN) bytes are the bridge exchange only — e.g. qwen3-moe "
        "train_4k: 3.6 GiB/chip/step crosses the bridge (the sharded "
        "cross-pod grad psum) vs 644 GiB/chip on ICI: the paper's scheme "
        "keeps slow-tier traffic at 0.56% of fast-tier traffic "
        "(`int8_bridge` halves it again, It.5).",
        "",
        "Caveats: (1) HLO 'bytes accessed' on the CPU-lowered module "
        "over-approximates TPU HBM traffic (fusion parameters are counted "
        "per use; the TPU compiler fuses far more aggressively), so the "
        "memory terms are upper bounds and the true dominant term for the "
        "large train cells is closer to compute/collective; (2) decode "
        "cells are physically memory-bound (weight+cache streaming per "
        "token) — frac~0 is the correct physics, not a defect.",
        ""]
    return "\n".join(lines)


def perf_section(recs, perf):
    lines = ["## §Perf — hillclimb log (hypothesis -> change -> measure)",
             ""]
    log_path = os.path.join(REPO, "experiments", "perf_log.md")
    if os.path.exists(log_path):
        lines.append(open(log_path).read())
    return "\n".join(lines)


def main():
    recs = load(DRY)
    perf = load(PERF) if os.path.isdir(PERF) else {}
    out = ["# EXPERIMENTS",
           "",
           "Artifacts: `experiments/dryrun/*.json` (baseline cells), "
           "`experiments/perf/*.json` (optimized variants), "
           "`test_output.txt`, `bench_output.txt`, "
           "`experiments/train_100m.log`.",
           "",
           paper_validation_section(recs),
           dryrun_section(recs),
           roofline_section(recs),
           perf_section(recs, perf)]
    with open(os.path.join(REPO, "EXPERIMENTS.md"), "w") as f:
        f.write("\n".join(out))
    print("wrote EXPERIMENTS.md")


if __name__ == "__main__":
    main()
