#!/usr/bin/env python3
"""Tuning-table gate: schema sanity (cheap) + staleness vs a fresh sweep.

Two modes over the committed ``TUNING_default.json``:

* ``--schema-only`` — structural validation with NO third-party imports
  (runs in CI's dependency-free ``checks`` job): schema string, required
  entry fields, per-entry ranking sorted by median, known ``source`` tags,
  positive sizes.

* ``--bench FRESH.json [--tol 3.0]`` — the nightly STALENESS check: for
  every (family, topology signature, dtype, size) cell present in both the
  table and a freshly generated bench report, the table's recorded winner
  must still be within ``tol``x of the fresh run's own best median.  A
  committed table whose winners the hardware no longer agrees with fails
  the gate — regenerate with ``python -m repro.bench --emit-tuning-table
  --bench FRESH.json``.  Zero overlapping cells is an error (a gate that
  compares nothing passes forever).

Deliberately standalone (stdlib json only, duplicating the tiny
topology-signature rule) so it runs before any dependency install — the
same design as ``check_bench_regression.py``.

    python scripts/check_tuning_table.py TUNING_default.json --schema-only
    python scripts/check_tuning_table.py TUNING_default.json \
        --bench BENCH_fresh.json --tol 3.0
"""

from __future__ import annotations

import argparse
import json
import sys

SCHEMA = "repro.tuning/v1"
SOURCES = ("measured", "modeled")
ENTRY_FIELDS = ("family", "topo", "dtype", "nbytes", "source", "ranking")


def schema_errors(table: dict) -> list[str]:
    errs: list[str] = []
    if table.get("schema") != SCHEMA:
        return [f"schema is {table.get('schema')!r}, want {SCHEMA!r}"]
    entries = table.get("entries")
    if not isinstance(entries, list) or not entries:
        return ["table has no entries"]
    seen: set[tuple] = set()
    for i, e in enumerate(entries):
        tag = f"entries[{i}]"
        missing = [f for f in ENTRY_FIELDS if f not in e]
        if missing:
            errs.append(f"{tag}: missing fields {missing}")
            continue
        tag = f"{e['family']}/{e['topo']}/{e['dtype']}/b{e['nbytes']}"
        key = (e["family"], e["topo"], e["dtype"], e["nbytes"])
        if key in seen:
            errs.append(f"{tag}: duplicate cell")
        seen.add(key)
        if e["source"] not in SOURCES:
            errs.append(f"{tag}: bad source {e['source']!r}")
        if not isinstance(e["nbytes"], int) or e["nbytes"] <= 0:
            errs.append(f"{tag}: bad nbytes {e['nbytes']!r}")
        ranking = e["ranking"]
        if not isinstance(ranking, list) or not ranking:
            errs.append(f"{tag}: empty ranking")
            continue
        for c in ranking:
            if "scheme" not in c or not isinstance(c.get("opts", {}), dict):
                errs.append(f"{tag}: malformed choice {c!r}")
        if e["source"] == "measured":
            meds = [c.get("median_us") for c in ranking]
            if any(m is None for m in meds):
                errs.append(f"{tag}: measured entry without medians")
            elif meds != sorted(meds):
                errs.append(f"{tag}: ranking not sorted by median")
    return errs


def _signature(case: dict) -> str:
    # MUST mirror repro.comm.tuning.topo_signature (this script is
    # import-free by design); fast_axes was added to the report schema
    # alongside the table — older artifacts betray a factored fast tier
    # only through the dotted label
    n_fast = case.get("fast_axes", 2 if "." in case["topology"] else 1)
    sig = f"{case['pods']}x{case['chips']}"
    if n_fast > 1:
        sig += f"-f{n_fast}"
    return sig


def staleness_failures(table: dict, bench: dict, tol: float
                       ) -> tuple[list[str], list[str]]:
    """(report_rows, failures) of the winner-vs-fresh-best comparison."""
    cells: dict[tuple, dict[str, float]] = {}
    for case in bench.get("cases", []):
        key = (case["family"], _signature(case), case.get("dtype",
                                                          "float32"),
               int(case["bytes_per_rank"]))
        cells.setdefault(key, {})[case["scheme"]] = \
            float(case["timing"]["median_us"])
    rows, failures = [], []
    compared = 0
    for e in table.get("entries", []):
        if e.get("source") != "measured":
            continue
        key = (e["family"], e["topo"], e["dtype"], int(e["nbytes"]))
        cell = cells.get(key)
        if not cell:
            continue
        compared += 1
        winner = e["ranking"][0]["scheme"]
        name = f"{e['family']}/{e['topo']}/b{e['nbytes']}"
        if winner not in cell:
            failures.append(f"{name}: table winner {winner!r} not in the "
                            "fresh sweep — regenerate the table")
            continue
        best = min(cell.values())
        ratio = cell[winner] / best if best > 0 else 1.0
        ok = ratio <= tol
        rows.append(f"  {name}: winner {winner} {ratio:.2f}x fresh best "
                    f"{'ok' if ok else 'STALE'}")
        if not ok:
            fresh_winner = min(cell, key=cell.get)
            failures.append(
                f"{name}: committed winner {winner!r} is {ratio:.2f}x the "
                f"fresh best ({fresh_winner!r}) — tol {tol}x; regenerate "
                "TUNING_default.json from this sweep")
    if not compared:
        failures.append("no overlapping (family, topology, dtype, size) "
                        "cells between the table and the fresh report — "
                        "nothing was checked")
    return rows, failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="validate the committed scheme-selection tuning table")
    ap.add_argument("table", nargs="?", default="TUNING_default.json")
    ap.add_argument("--schema-only", action="store_true",
                    help="structural checks only (no bench report needed)")
    ap.add_argument("--bench", default=None,
                    help="fresh BENCH json for the staleness check")
    ap.add_argument("--tol", type=float, default=3.0,
                    help="staleness band: committed winner may trail the "
                         "fresh best by this factor (default %(default)s)")
    args = ap.parse_args(argv)

    with open(args.table) as f:
        table = json.load(f)
    errs = schema_errors(table)
    if errs:
        print(f"tuning-table check FAILED ({args.table}):", file=sys.stderr)
        for e in errs:
            print(f"  {e}", file=sys.stderr)
        return 1
    n = len(table["entries"])
    measured = sum(1 for e in table["entries"] if e["source"] == "measured")
    print(f"tuning-table schema OK: {n} entries ({measured} measured) in "
          f"{args.table}")
    if args.schema_only:
        return 0
    if not args.bench:
        print("tuning-table check: pass --schema-only or --bench FRESH.json",
              file=sys.stderr)
        return 2
    with open(args.bench) as f:
        bench = json.load(f)
    if not str(bench.get("schema", "")).startswith("repro.bench/"):
        print(f"tuning-table check: {args.bench} is not a repro.bench "
              f"report (schema={bench.get('schema')!r})", file=sys.stderr)
        return 1
    rows, failures = staleness_failures(table, bench, args.tol)
    print(f"tuning-table staleness: {len(rows)} compared cells "
          f"(tol {args.tol}x):")
    for r in rows:
        print(r)
    if failures:
        print("tuning-table staleness FAILED:", file=sys.stderr)
        for fl in failures:
            print(f"  {fl}", file=sys.stderr)
        return 1
    print("tuning-table staleness OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
