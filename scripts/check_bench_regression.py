#!/usr/bin/env python3
"""Perf-regression gate: fresh bench medians vs the committed baseline.

First consumer of the bench *trajectory*: ``BENCH_collectives.json`` is
regenerated on every perf PR, and this gate compares a freshly generated
report against the committed artifact, failing (exit 1) when a case got
slower beyond the tolerance band.

Raw microseconds are machine-dependent (a CI runner is not the laptop that
produced the baseline), so the comparison is **normalized within each
run**: every case's median is divided by its (family, topology, elems)
group's reference-scheme median from the SAME file.  A case regresses when

    fresh_norm > base_norm * tol

The reference scheme per group is the first registered scheme present in
BOTH files (deterministically ``naive`` today).  Because the reference's
own normalized value is identically 1.0, a second **machine-factor** pass
covers it: the global machine speed factor is estimated as the median of
raw fresh/base ratios over every common cell, and a REFERENCE cell whose
raw ratio exceeds ``factor * raw_tol`` fails — a regression confined to
the reference scheme (which would shrink every OTHER scheme's normalized
value and hide both) is caught here.  ``raw_tol`` defaults to ``2 * tol``:
raw cross-run ratios carry the full per-cell tail noise that the
normalized pass cancels, so the reference band is wider by design.  Only
(family, scheme, topology, elems) cells present in both files are
compared; zero overlap is an error (the gate would silently pass
forever).

    python scripts/check_bench_regression.py BASELINE FRESH [--tol 3.0]

``--tol`` is deliberately wide: quick-sweep medians on shared CI runners
are noisy, and the gate exists to catch structural regressions (a scheme
suddenly 3x its old relative cost — e.g. a lost overlap, an extra
collective), not single-digit-percent drift.

A third pass gates **latency percentiles**: normalized ``timing.p99_us``
is compared the same way over cells both files carry it (older baselines
without the field are skipped), at ``2 * tol`` — a serving engine can
hold its median while its tail collapses, which the median pass alone
would miss.

A fourth pass gates the **quantization error model**: every
``error/bound`` check the fresh report carries (one per quantized-scheme
case — measured max-abs error vs the scheme's declared ceiling) must
hold, and a fresh report that contains quantized cases but zero
``error/bound`` checks fails outright — a validator that silently stops
emitting the check would otherwise pass forever.  This pass reads only
the fresh report: error bounds are absolute statements about the scheme,
not relative to the baseline machine.
"""

from __future__ import annotations

import argparse
import json
import sys


def _cells(report: dict, stat: str = "median_us") -> dict[tuple, float]:
    """(family, scheme, topology, elems) -> ``timing[stat]``.

    Cells whose report predates the stat (older schema wrote no
    ``p99_us``) are simply absent — the percentile pass compares only
    cells both files carry, staying backward compatible."""
    out = {}
    for case in report.get("cases", []):
        key = (case["family"], case["scheme"], case["topology"],
               case["elems"])
        val = case["timing"].get(stat)
        if val is not None and float(val) > 0:
            out[key] = float(val)
    return out


def _group_reference(cells: dict[tuple, float]) -> dict[tuple, str]:
    """(family, topology, elems) -> reference scheme name (first scheme in
    sorted order that appears in the group — 'naive' sorts after 'hier',
    so pick explicitly: prefer 'naive', else lexicographic first)."""
    groups: dict[tuple, list[str]] = {}
    for (fam, sch, topo, elems) in cells:
        groups.setdefault((fam, topo, elems), []).append(sch)
    return {g: ("naive" if "naive" in ss else sorted(ss)[0])
            for g, ss in groups.items()}


def compare(base: dict, fresh: dict, tol: float) -> tuple[list[str],
                                                          list[str]]:
    """Returns (table_rows, failures)."""
    import statistics

    bc, fc = _cells(base), _cells(fresh)
    common = sorted(set(bc) & set(fc))
    if not common:
        return [], ["no overlapping (family, scheme, topology, elems) "
                    "cells between baseline and fresh report — regenerate "
                    "the baseline with sizes the gate's sweep also runs"]
    refs = _group_reference({k: bc[k] for k in common})
    rows, failures = [], []
    for key in common:
        fam, sch, topo, elems = key
        ref = refs[(fam, topo, elems)]
        base_ref = bc.get((fam, ref, topo, elems))
        fresh_ref = fc.get((fam, ref, topo, elems))
        if not base_ref or not fresh_ref:
            continue
        base_norm = bc[key] / base_ref
        fresh_norm = fc[key] / fresh_ref
        ok = fresh_norm <= base_norm * tol
        rows.append(f"  {fam}/{sch}/{topo}/e{elems}: base {base_norm:.2f}x "
                    f"fresh {fresh_norm:.2f}x {ref} "
                    f"{'ok' if ok else 'REGRESSED'}")
        if not ok:
            failures.append(
                f"{fam}/{sch}/{topo}/e{elems}: {fresh_norm:.2f}x {ref} vs "
                f"baseline {base_norm:.2f}x (tol {tol}x)")
    # machine-factor pass over the REFERENCE cells only: their normalized
    # value is 1.0 by construction, so they are the normalized pass's one
    # blind spot.  Non-reference cells are already covered above; raw
    # ratios carry full per-cell tail noise, hence the wider band.
    raw_tol = 2.0 * tol
    factor = statistics.median(fc[k] / bc[k] for k in common)
    rows.append(f"  machine speed factor (median raw fresh/base): "
                f"{factor:.2f}x")
    for key in common:
        fam, sch, topo, elems = key
        if sch != refs[(fam, topo, elems)]:
            continue
        raw = fc[key] / bc[key]
        if raw > factor * raw_tol:
            failures.append(
                f"{fam}/{sch}/{topo}/e{elems}: reference-scheme raw "
                f"{raw:.2f}x vs machine factor {factor:.2f}x (raw tol "
                f"{raw_tol}x) — regression not explained by host speed")
    # latency-percentile pass: gate p99 the way medians are gated, over
    # cells where BOTH files carry it (tail tolerance is wider — the p99
    # of a quick sweep is one sample deep).  A serving engine can hold its
    # median while its tail collapses; the median pass alone misses that.
    p99_tol = 2.0 * tol
    bp, fp = _cells(base, "p99_us"), _cells(fresh, "p99_us")
    p99_common = sorted(set(bp) & set(fp) & set(common))
    compared_p99 = 0
    for key in p99_common:
        fam, sch, topo, elems = key
        ref = refs[(fam, topo, elems)]
        base_ref = bp.get((fam, ref, topo, elems))
        fresh_ref = fp.get((fam, ref, topo, elems))
        if not base_ref or not fresh_ref:
            continue
        compared_p99 += 1
        base_norm = bp[key] / base_ref
        fresh_norm = fp[key] / fresh_ref
        if fresh_norm > base_norm * p99_tol:
            failures.append(
                f"{fam}/{sch}/{topo}/e{elems}: p99 {fresh_norm:.2f}x {ref} "
                f"vs baseline {base_norm:.2f}x (p99 tol {p99_tol}x)")
    rows.append(f"  p99 pass: {compared_p99} cells gated at {p99_tol}x"
                if compared_p99 else
                "  p99 pass: skipped (baseline carries no p99_us)")
    return rows, failures


def error_bound_pass(fresh: dict) -> tuple[list[str], list[str]]:
    """Gate the quantized schemes' error model on the FRESH report.

    Every ``error/bound`` check (measured max-abs quantization error vs
    the scheme's declared ceiling, one-sided) must be ``ok``.  Quantized
    cases are recognized by carrying such a check; if the report has
    none at all but names a ``q``-prefixed scheme, the validator stopped
    emitting the check and the gate fails rather than passing silently.
    """
    rows, failures = [], []
    n_bound = 0
    quantized_cases = 0
    for case in fresh.get("cases", []):
        bound_checks = [ch for ch in case.get("checks", [])
                        if ch.get("name") == "error/bound"]
        if str(case.get("scheme", "")).startswith("q"):
            quantized_cases += 1
        for ch in bound_checks:
            n_bound += 1
            if not ch.get("ok", False):
                failures.append(
                    f"{case['family']}/{case['scheme']}/{case['topology']}"
                    f"/e{case['elems']}: measured quantization error "
                    f"{ch.get('measured')} exceeds declared bound "
                    f"{ch.get('expected')}")
    if quantized_cases and not n_bound:
        failures.append(
            f"fresh report has {quantized_cases} quantized cases but no "
            "error/bound checks — the validator stopped emitting the "
            "error-model check")
    rows.append(f"  error-bound pass: {n_bound} checks over "
                f"{quantized_cases} quantized cases"
                if n_bound or quantized_cases else
                "  error-bound pass: skipped (no quantized cases in sweep)")
    return rows, failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="compare fresh bench medians against the committed "
                    "baseline (normalized within each run)")
    ap.add_argument("baseline", help="committed BENCH_collectives.json")
    ap.add_argument("fresh", help="freshly generated report")
    ap.add_argument("--tol", type=float, default=3.0,
                    help="normalized-median tolerance factor "
                         "(default %(default)s)")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    for rep, name in ((base, args.baseline), (fresh, args.fresh)):
        if not str(rep.get("schema", "")).startswith("repro.bench/"):
            print(f"bench-regression: {name} is not a repro.bench report "
                  f"(schema={rep.get('schema')!r})", file=sys.stderr)
            return 1

    rows, failures = compare(base, fresh, args.tol)
    eb_rows, eb_failures = error_bound_pass(fresh)
    rows += eb_rows
    failures += eb_failures
    print(f"bench-regression: {len(rows)} compared cells "
          f"(tol {args.tol}x, normalized within-run):")
    for r in rows:
        print(r)
    if failures:
        print("bench-regression FAILED:", file=sys.stderr)
        for f_ in failures:
            print(f"  {f_}", file=sys.stderr)
        return 1
    print("bench-regression OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
