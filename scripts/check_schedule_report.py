#!/usr/bin/env python3
"""Validate SCHEDULE_stepgraph.json — the committed step-graph schedule
report (``python -m repro.comm.stepgraph``).

Structural and arithmetic checks only, stdlib-only by design (the CI
``checks`` job runs without jax): the schema is what ``Schedule.report()``
emits, and the numbers must be internally consistent —

  * byte conservation: bucketing repacks messages, it never changes the
    payload (``after_bytes == before_bytes``; padding is reported
    separately per bucket and only ever adds);
  * message-count reduction: ``after_messages <= before_messages``, and
    every bucket holds >= 2 members (a singleton "bucket" would be the
    eager issue with extra steps);
  * the issue order covers exactly the rewritten schedule: one ``bucket``
    entry per bucket, one ``single``/``gather`` per surviving eager issue;
  * on at least one multi-pod topology the optimizer actually reduced the
    message count (the committed artifact must witness the rewrite, not
    just parse).

    python scripts/check_schedule_report.py [SCHEDULE_stepgraph.json]
"""

from __future__ import annotations

import json
import pathlib
import sys

SCHEMA = "repro.stepgraph/v1"

REPORT_KEYS = {"schema", "nodes", "allreduce", "gather", "buckets",
               "singles", "order", "config", "topology", "pods", "chips",
               "elems"}
BUCKET_KEYS = {"axes", "dtype", "scheme", "count", "bytes", "padded_bytes",
               "target_bytes"}
ORDER_KINDS = {"bucket", "single", "gather"}


def check_report(r: dict, where: str) -> list[str]:
    bad: list[str] = []

    def fail(msg: str) -> None:
        bad.append(f"{where}: {msg}")

    missing = REPORT_KEYS - set(r)
    if missing:
        fail(f"missing keys {sorted(missing)}")
        return bad
    if r["schema"] != SCHEMA:
        fail(f"schema {r['schema']!r} != {SCHEMA!r}")
    ar, ga = r["allreduce"], r["gather"]
    if ar["after_bytes"] != ar["before_bytes"]:
        fail(f"bucketing changed payload bytes: {ar['before_bytes']} -> "
             f"{ar['after_bytes']} (must conserve)")
    if ar["after_messages"] > ar["before_messages"]:
        fail(f"rewrite INCREASED allreduce messages: "
             f"{ar['before_messages']} -> {ar['after_messages']}")
    if ga["after_issues"] > ga["before_issues"]:
        fail(f"dedup INCREASED gather issues: "
             f"{ga['before_issues']} -> {ga['after_issues']}")
    for i, b in enumerate(r["buckets"]):
        miss = BUCKET_KEYS - set(b)
        if miss:
            fail(f"bucket[{i}] missing keys {sorted(miss)}")
            continue
        if b["count"] < 2:
            fail(f"bucket[{i}] has {b['count']} member(s); buckets pack "
                 ">= 2 operands, singletons stay eager")
        if b["padded_bytes"] < b["bytes"]:
            fail(f"bucket[{i}] padded_bytes {b['padded_bytes']} < payload "
                 f"{b['bytes']}")
    n_bucketed = sum(b["count"] for b in r["buckets"])
    if n_bucketed + r["singles"] != ar["before_messages"]:
        fail(f"accounting: {n_bucketed} bucketed + {r['singles']} single "
             f"!= {ar['before_messages']} recorded allreduces")
    if len(r["buckets"]) + r["singles"] != ar["after_messages"]:
        fail(f"accounting: {len(r['buckets'])} buckets + {r['singles']} "
             f"singles != {ar['after_messages']} issued messages")
    kinds = [k for k, _ in r["order"]]
    if not set(kinds) <= ORDER_KINDS:
        fail(f"unknown order kinds {sorted(set(kinds) - ORDER_KINDS)}")
    if kinds.count("bucket") != len(r["buckets"]):
        fail(f"order has {kinds.count('bucket')} bucket issues for "
             f"{len(r['buckets'])} buckets")
    if kinds.count("single") != r["singles"]:
        fail(f"order has {kinds.count('single')} single issues for "
             f"{r['singles']} singles")
    if kinds.count("gather") != ga["after_issues"]:
        fail(f"order has {kinds.count('gather')} gather issues for "
             f"{ga['after_issues']} deduped gathers")
    # issue-early: the reorder pass front-loads gathers before reductions
    if "gather" in kinds and kinds.index("gather") != 0:
        first_red = min(i for i, k in enumerate(kinds) if k != "gather")
        if any(k == "gather" for k in kinds[first_red:]):
            fail("gather issued after a reduction: the sink pass "
                 "front-loads all gather issues")
    return bad


def main(argv=None) -> int:
    args = argv if argv is not None else sys.argv[1:]
    path = pathlib.Path(args[0] if args else "SCHEDULE_stepgraph.json")
    doc = json.loads(path.read_text())
    bad: list[str] = []
    if doc.get("schema") != SCHEMA:
        bad.append(f"top-level schema {doc.get('schema')!r} != {SCHEMA!r}")
    reports = doc.get("reports", [])
    if not reports:
        bad.append("no reports")
    for r in reports:
        bad.extend(check_report(
            r, f"{r.get('config')}@{r.get('topology')}"))
    multi = [r for r in reports if r.get("pods", 1) > 1]
    if multi and not any(
            r["allreduce"]["after_messages"] < r["allreduce"]
            ["before_messages"] for r in multi):
        bad.append("no multi-pod schedule shows a message-count reduction "
                   "— the artifact does not witness the bucketing pass")
    if bad:
        print(f"schedule-report check FAILED ({path}):", file=sys.stderr)
        for b in bad:
            print(f"  {b}", file=sys.stderr)
        return 1
    n_topo = len({r["topology"] for r in reports})
    total_before = sum(r["allreduce"]["before_messages"] for r in reports)
    total_after = sum(r["allreduce"]["after_messages"] for r in reports)
    print(f"schedule-report check OK: {len(reports)} schedules over "
          f"{n_topo} topologies, allreduce messages "
          f"{total_before} -> {total_after}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
