#!/usr/bin/env python3
"""API-surface check: collectives go through ``repro.comm``, nowhere else.

Fails (exit 1) on two kinds of bypass:

1. **Raw tier kwargs** — any module outside ``src/repro/comm/`` passing
   ``fast_axis=`` / ``slow_axis=`` keyword arguments, the old free-function
   calling convention the ``Communicator`` replaced.
2. **Raw collective primitives** — ``lax.psum(`` / ``lax.all_gather(``
   call sites outside ``repro/comm``, ``repro/substrate`` and
   ``repro/kernels``.  Raw primitives bypass scheme dispatch AND the
   step-graph optimizer (``Communicator.record()`` cannot bucket or
   reorder a collective it never sees).  Known-legitimate sites carry an
   inline ``# raw-collective: <reason>`` pragma — the tp fast paths
   (``ag_tokens`` and friends in ``models/parallel.py``, where the single
   flat tp group has exactly one schedule) and the sync primitives in
   ``core/sync.py`` the machinery itself is built from.  (The quantized
   wire formats moved INTO the registry — ``comm/quantize.py`` bodies
   behind the ``q8_hier``/``qbf16_hier``/``q4_shared`` schemes.)
3. **Deprecated compression free functions** — ``int8_bridge_psum(`` call
   sites outside ``src/repro/comm/`` and ``src/repro/optim/``: the shim
   is one-release only; new call sites go through
   ``Communicator.allreduce(..., precision="lossy")`` /
   ``reduce_grads(..., precision="lossy")``.
4. **Bare ``Communicator(...)`` in the rebuild paths** — ``src/repro/
   runtime/`` and ``src/repro/launch/`` must construct communicators only
   via ``Communicator.from_cluster`` / ``Communicator.from_topology``: a
   bare constructor there carries no static pods/chips counts, so after an
   elastic rebuild the tuning signature is unresolvable and ``scheme=
   "auto"`` silently degrades to the static fallback instead of re-tuning
   for the surviving topology.

Allowed everywhere:
  * ``VirtualCluster(...)`` construction (the substrate's topology spec is
    where the axis names legitimately live);
  * ``Communicator(...)`` construction outside the rebuild paths (the tier
    spec, not a call) — inside ``repro/comm`` itself, ``models/``
    (trace-time axis wrappers), etc.;
  * annotated attribute/field definitions (``fast_axis: Axis = "data"``)
    never match the kwarg pattern.

Grep-based by design (no imports, no AST): run it anywhere, instantly.

    python scripts/check_api_surface.py [root]
"""

from __future__ import annotations

import pathlib
import re
import sys

KWARG_RE = re.compile(r"\b(?:fast_axis|slow_axis)\s*=(?!=)")
ALLOWED_LINE_RE = re.compile(r"\b(?:VirtualCluster|Communicator)\s*\(")
RAW_RE = re.compile(r"\blax\.(?:psum|all_gather)\s*\(")
RAW_PRAGMA = "raw-collective:"

SCAN_ROOTS = ("src/repro", "benchmarks", "examples")
ALLOWED_PATHS = (
    "src/repro/comm/",               # the API itself
)
RAW_ALLOWED_PATHS = (
    "src/repro/comm/",               # the primitives live here
    "src/repro/substrate/",          # compat shims wrap the primitives
    "src/repro/kernels/",            # Pallas bodies fuse their own wires
)

# deprecated one-release shims: no NEW call sites outside the shim's own
# module and the comm layer that implements the replacement
DEPRECATED_RE = re.compile(r"\bint8_bridge_psum\s*\(")
DEPRECATED_ALLOWED_PATHS = (
    "src/repro/comm/",
    "src/repro/optim/",
)

# bare Communicator() ctor: matches ``Communicator(`` and qualified
# ``comm.Communicator(`` but NOT the blessed ``Communicator.from_cluster(``
# / ``Communicator.from_topology(`` classmethods (a ``.`` follows the name)
CTOR_RE = re.compile(r"\bCommunicator\s*\(")
CTOR_SCAN_PATHS = (
    "src/repro/runtime/",            # elastic rebuild paths
    "src/repro/launch/",             # production launchers
)


def _scan_files(repo: pathlib.Path):
    for root in SCAN_ROOTS:
        base = repo / root
        if not base.exists():
            continue
        for path in sorted(base.rglob("*.py")):
            yield path, path.relative_to(repo).as_posix()


def kwarg_violations(repo: pathlib.Path) -> list[str]:
    out: list[str] = []
    for path, rel in _scan_files(repo):
        if any(rel.startswith(a) for a in ALLOWED_PATHS):
            continue
        depth = 0          # open-paren depth of an allowed call: its
        for lineno, line in enumerate(  # continuation lines are allowed
                path.read_text().splitlines(), start=1):
            code = line.split("#", 1)[0]
            m = ALLOWED_LINE_RE.search(code)
            if depth == 0 and m:
                # heuristic: text before the constructor and after its
                # same-line close is still checked; only the call's own
                # (possibly multi-line) argument list is exempt — a
                # violation nested INSIDE a constructor argument would
                # slip by, which AST-free grep accepts.
                if KWARG_RE.search(code[:m.start()]):
                    out.append(f"{rel}:{lineno}: {line.strip()}")
                d, end = 0, None
                for idx in range(m.start(), len(code)):
                    if code[idx] == "(":
                        d += 1
                    elif code[idx] == ")":
                        d -= 1
                        if d == 0:
                            end = idx + 1
                            break
                if end is None:          # call continues on next lines
                    depth = d
                    continue
                if KWARG_RE.search(code[end:]) and \
                        not ALLOWED_LINE_RE.search(code[end:]):
                    out.append(f"{rel}:{lineno}: {line.strip()}")
                continue
            if depth > 0:
                depth = max(depth + code.count("(") - code.count(")"), 0)
                continue
            if KWARG_RE.search(code):
                out.append(f"{rel}:{lineno}: {line.strip()}")
    return out


def raw_violations(repo: pathlib.Path) -> list[str]:
    """Raw ``lax.psum`` / ``lax.all_gather`` call sites outside the comm
    layers.  The pragma is checked on the FULL line (it lives in the
    comment the kwarg scan strips); a pragma on the line directly above
    also covers the call — the idiom when the call line has no room
    under the line-length limit."""
    out: list[str] = []
    for path, rel in _scan_files(repo):
        if any(rel.startswith(a) for a in RAW_ALLOWED_PATHS):
            continue
        lines = path.read_text().splitlines()
        for lineno, line in enumerate(lines, start=1):
            if RAW_PRAGMA in line:
                continue
            if lineno >= 2 and RAW_PRAGMA in lines[lineno - 2]:
                continue
            if RAW_RE.search(line.split("#", 1)[0]):
                out.append(f"{rel}:{lineno}: {line.strip()}")
    return out


def deprecated_violations(repo: pathlib.Path) -> list[str]:
    """Call sites of the deprecated ``optim.compression`` free functions
    outside ``repro/comm`` and ``repro/optim`` — those must migrate to the
    ``precision="lossy"`` Communicator dispatch before the shim goes."""
    out: list[str] = []
    for path, rel in _scan_files(repo):
        if any(rel.startswith(a) for a in DEPRECATED_ALLOWED_PATHS):
            continue
        for lineno, line in enumerate(path.read_text().splitlines(),
                                      start=1):
            if DEPRECATED_RE.search(line.split("#", 1)[0]):
                out.append(f"{rel}:{lineno}: {line.strip()}")
    return out


def ctor_violations(repo: pathlib.Path) -> list[str]:
    """Bare ``Communicator(...)`` constructions inside the rebuild paths
    (``runtime/``, ``launch/``) — these must go through ``from_cluster`` /
    ``from_topology`` so the static pods/chips counts (and with them the
    tuning-table signature) survive every elastic rebuild."""
    out: list[str] = []
    for path, rel in _scan_files(repo):
        if not any(rel.startswith(a) for a in CTOR_SCAN_PATHS):
            continue
        for lineno, line in enumerate(path.read_text().splitlines(),
                                      start=1):
            if CTOR_RE.search(line.split("#", 1)[0]):
                out.append(f"{rel}:{lineno}: {line.strip()}")
    return out


def violations(repo: pathlib.Path) -> list[str]:
    return kwarg_violations(repo) + raw_violations(repo) \
        + deprecated_violations(repo) + ctor_violations(repo)


def main(argv=None) -> int:
    args = argv if argv is not None else sys.argv[1:]
    repo = pathlib.Path(args[0]) if args else \
        pathlib.Path(__file__).resolve().parent.parent
    bad_kwargs = kwarg_violations(repo)
    bad_raw = raw_violations(repo)
    bad_deprecated = deprecated_violations(repo)
    bad_ctor = ctor_violations(repo)
    if bad_kwargs:
        print("api-surface check FAILED: raw fast_axis=/slow_axis= kwargs "
              "outside repro/comm — route these call sites through "
              "repro.comm.Communicator (README 'Communicator API'):",
              file=sys.stderr)
        for v in bad_kwargs:
            print(f"  {v}", file=sys.stderr)
    if bad_raw:
        print("api-surface check FAILED: raw lax.psum/lax.all_gather call "
              "sites outside repro/comm + repro/substrate + repro/kernels "
              "— dispatch through Communicator (so the scheme registry and "
              "the step-graph optimizer see them), or justify with an "
              "inline '# raw-collective: <reason>' pragma:",
              file=sys.stderr)
        for v in bad_raw:
            print(f"  {v}", file=sys.stderr)
    if bad_deprecated:
        print("api-surface check FAILED: deprecated int8_bridge_psum( call "
              "sites outside repro/comm + repro/optim — migrate to "
              "Communicator.allreduce(..., precision='lossy') / "
              "reduce_grads(..., precision='lossy') (the shim is "
              "one-release only):", file=sys.stderr)
        for v in bad_deprecated:
            print(f"  {v}", file=sys.stderr)
    if bad_ctor:
        print("api-surface check FAILED: bare Communicator(...) "
              "construction in the rebuild paths (src/repro/runtime, "
              "src/repro/launch) — use Communicator.from_cluster / "
              "Communicator.from_topology so static pods/chips counts "
              "(the tuning signature) survive elastic rebuilds:",
              file=sys.stderr)
        for v in bad_ctor:
            print(f"  {v}", file=sys.stderr)
    if bad_kwargs or bad_raw or bad_deprecated or bad_ctor:
        return 1
    print("api-surface check OK: all collective call sites go through "
          "repro.comm")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
