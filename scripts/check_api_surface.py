#!/usr/bin/env python3
"""API-surface check: collectives go through ``repro.comm``, nowhere else.

Fails (exit 1) if any module outside ``src/repro/comm/`` passes raw
``fast_axis=`` / ``slow_axis=`` keyword arguments — the old free-function
calling convention the ``Communicator`` replaced.  A violation means a
consumer bypassed the scheme registry and would silently miss future
scheme/validation coverage.  (The ``src/repro/core/collectives.py`` shim
exemption was dropped when the shim itself was removed.)

Allowed everywhere:
  * ``VirtualCluster(...)`` construction (the substrate's topology spec is
    where the axis names legitimately live);
  * ``Communicator(...)`` construction (same: the tier spec, not a call);
  * annotated attribute/field definitions (``fast_axis: Axis = "data"``)
    never match the kwarg pattern.

Grep-based by design (no imports, no AST): run it anywhere, instantly.

    python scripts/check_api_surface.py [root]
"""

from __future__ import annotations

import pathlib
import re
import sys

KWARG_RE = re.compile(r"\b(?:fast_axis|slow_axis)\s*=(?!=)")
ALLOWED_LINE_RE = re.compile(r"\b(?:VirtualCluster|Communicator)\s*\(")

SCAN_ROOTS = ("src/repro", "benchmarks", "examples")
ALLOWED_PATHS = (
    "src/repro/comm/",               # the API itself
)


def violations(repo: pathlib.Path) -> list[str]:
    out: list[str] = []
    for root in SCAN_ROOTS:
        base = repo / root
        if not base.exists():
            continue
        for path in sorted(base.rglob("*.py")):
            rel = path.relative_to(repo).as_posix()
            if any(rel.startswith(a) for a in ALLOWED_PATHS):
                continue
            depth = 0          # open-paren depth of an allowed call: its
            for lineno, line in enumerate(  # continuation lines are allowed
                    path.read_text().splitlines(), start=1):
                code = line.split("#", 1)[0]
                m = ALLOWED_LINE_RE.search(code)
                if depth == 0 and m:
                    # heuristic: text before the constructor and after its
                    # same-line close is still checked; only the call's own
                    # (possibly multi-line) argument list is exempt — a
                    # violation nested INSIDE a constructor argument would
                    # slip by, which AST-free grep accepts.
                    if KWARG_RE.search(code[:m.start()]):
                        out.append(f"{rel}:{lineno}: {line.strip()}")
                    d, end = 0, None
                    for idx in range(m.start(), len(code)):
                        if code[idx] == "(":
                            d += 1
                        elif code[idx] == ")":
                            d -= 1
                            if d == 0:
                                end = idx + 1
                                break
                    if end is None:          # call continues on next lines
                        depth = d
                        continue
                    if KWARG_RE.search(code[end:]) and \
                            not ALLOWED_LINE_RE.search(code[end:]):
                        out.append(f"{rel}:{lineno}: {line.strip()}")
                    continue
                if depth > 0:
                    depth = max(depth + code.count("(") - code.count(")"), 0)
                    continue
                if KWARG_RE.search(code):
                    out.append(f"{rel}:{lineno}: {line.strip()}")
    return out


def main(argv=None) -> int:
    args = argv if argv is not None else sys.argv[1:]
    repo = pathlib.Path(args[0]) if args else \
        pathlib.Path(__file__).resolve().parent.parent
    bad = violations(repo)
    if bad:
        print("api-surface check FAILED: raw fast_axis=/slow_axis= kwargs "
              "outside repro/comm — route these call sites through "
              "repro.comm.Communicator (README 'Communicator API'):",
              file=sys.stderr)
        for v in bad:
            print(f"  {v}", file=sys.stderr)
        return 1
    print("api-surface check OK: all collective call sites go through "
          "repro.comm")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
