"""Checkpoint/restart with elastic resharding.

Design for 1000+ nodes (DESIGN.md §9):
  * step-versioned directories, per-host shard files, atomic rename commit —
    a died writer never corrupts the latest checkpoint;
  * a JSON manifest records the logical layout (leaf paths, global shapes,
    dtypes) so restore can re-shard to ANY mesh (elastic shrink/grow);
  * async save: serialization happens on a worker thread; the train loop
    only blocks on the previous save (double-buffering);
  * restore-side resharding is host-side slicing: the paper's one-copy-
    per-pod layout means each pod restores one copy, sharded however the
    new mesh dictates.

On this CPU container every "host" is simulated in-process; the file format
(npz shards + manifest) is host-count independent.
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

MANIFEST = "manifest.json"


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_leaves_with_path(tree)
    return [(jax.tree_util.keystr(p), l) for p, l in flat]


class Checkpointer:
    def __init__(self, root: str, *, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state, *, blocking: bool = False) -> None:
        """Device-get now (cheap snapshot), write on a worker thread."""
        host = jax.tree.map(np.asarray, jax.device_get(state))
        self.wait()
        t = threading.Thread(target=self._write, args=(step, host),
                             daemon=True)
        t.start()
        self._thread = t
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_state) -> None:
        tmp = os.path.join(self.root, f".tmp-{step}-{os.getpid()}")
        final = os.path.join(self.root, f"step_{step:08d}")
        os.makedirs(tmp, exist_ok=True)
        leaves = _leaf_paths(host_state)
        manifest = {"step": step, "time": time.time(),
                    "leaves": [{"path": p, "shape": list(np.shape(l)),
                                "dtype": str(np.asarray(l).dtype)}
                               for p, l in leaves]}
        np.savez(os.path.join(tmp, "shard_0.npz"),
                 **{f"leaf_{i}": np.asarray(l)
                    for i, (_, l) in enumerate(leaves)})
        with open(os.path.join(tmp, MANIFEST), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):  # idempotent: this step already committed
            for fn in os.listdir(tmp):
                os.remove(os.path.join(tmp, fn))
            os.rmdir(tmp)
        else:
            os.rename(tmp, final)  # atomic commit
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            path = os.path.join(self.root, f"step_{s:08d}")
            for fn in os.listdir(path):
                os.remove(os.path.join(path, fn))
            os.rmdir(path)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_"):
                if os.path.exists(os.path.join(self.root, d, MANIFEST)):
                    out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like, *, step: Optional[int] = None,
                shardings=None):
        """Restore into the structure of ``like``; re-shard to the current
        mesh if ``shardings`` (a matching tree of NamedSharding) is given —
        this is the elastic path: the checkpoint layout is logical, the mesh
        is whatever survives."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        path = os.path.join(self.root, f"step_{step:08d}")
        with open(os.path.join(path, MANIFEST)) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "shard_0.npz"))
        leaves = [data[f"leaf_{i}"] for i in range(len(manifest["leaves"]))]
        want = [l for _, l in _leaf_paths(like)]
        assert len(want) == len(leaves), "structure mismatch"
        for w, l, rec in zip(want, leaves, manifest["leaves"]):
            assert tuple(w.shape) == tuple(l.shape) == tuple(rec["shape"]), (
                w.shape, l.shape)
            assert str(l.dtype) == rec["dtype"], \
                f"{rec['path']}: shard dtype {l.dtype} != " \
                f"manifest {rec['dtype']}"
            assert str(np.dtype(w.dtype)) == rec["dtype"], \
                f"{rec['path']}: template dtype {w.dtype} != " \
                f"manifest {rec['dtype']}"
        treedef = jax.tree.structure(like)
        restored = jax.tree.unflatten(treedef, leaves)
        if shardings is not None:
            restored = jax.tree.map(
                lambda arr, sh: jax.device_put(arr, sh), restored, shardings)
        return restored, step
