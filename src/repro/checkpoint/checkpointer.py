"""Checkpoint/restart with elastic resharding.

Design for 1000+ nodes (DESIGN.md §9):
  * step-versioned directories, per-host shard files, atomic rename commit —
    a died writer never corrupts the latest checkpoint;
  * a JSON manifest records the logical layout (leaf paths, global shapes,
    dtypes) so restore can re-shard to ANY mesh (elastic shrink/grow);
  * async save: serialization happens on a worker thread; the train loop
    only blocks on the previous save (double-buffering);
  * restore-side resharding is host-side slicing: the paper's one-copy-
    per-pod layout means each pod restores one copy, sharded however the
    new mesh dictates.

On this CPU container every "host" is simulated in-process; the file format
(npz shards + manifest) is host-count independent.
"""

from __future__ import annotations

import json
import os
import threading
import time
import warnings
import zipfile
import zlib
from typing import Any, Optional

import jax
import numpy as np

MANIFEST = "manifest.json"

#: What a torn (half-written / truncated / lost) step looks like when read
#: back: missing files, truncated npz archives, corrupt manifest JSON,
#: missing leaf keys.  Template/manifest MISMATCHES (shape, dtype, tree
#: structure) are deliberately NOT here — those are caller bugs and still
#: raise.  (json.JSONDecodeError is a ValueError subclass.)
TORN_ERRORS = (OSError, ValueError, KeyError, zipfile.BadZipFile, zlib.error)


class CheckpointSaveError(RuntimeError):
    """An async save failed terminally (every IO retry exhausted)."""


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_leaves_with_path(tree)
    return [(jax.tree_util.keystr(p), l) for p, l in flat]


class Checkpointer:
    def __init__(self, root: str, *, keep: int = 3, io_retries: int = 3,
                 retry_backoff_s: float = 0.05):
        self.root = root
        self.keep = keep
        self.io_retries = io_retries
        self.retry_backoff_s = retry_backoff_s
        os.makedirs(root, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state, *, blocking: bool = False) -> None:
        """Device-get now (cheap snapshot), write on a worker thread.

        Transient IO failures are retried with bounded exponential backoff
        (``io_retries`` x ``retry_backoff_s`` doubling); a save that fails
        every retry is TERMINAL and raises ``CheckpointSaveError`` from the
        next ``wait()``/``save()`` — never silently dropped, so a train
        loop cannot sail past its last durable state unaware."""
        host = jax.tree.map(np.asarray, jax.device_get(state))
        self.wait()
        t = threading.Thread(target=self._write_with_retries,
                             args=(step, host), daemon=True)
        t.start()
        self._thread = t
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise CheckpointSaveError(
                f"async checkpoint save failed after "
                f"{self.io_retries + 1} attempts: {err}") from err

    def _write_with_retries(self, step: int, host_state) -> None:
        delay = self.retry_backoff_s
        for attempt in range(self.io_retries + 1):
            try:
                self._write(step, host_state)
                return
            except OSError as e:
                if attempt == self.io_retries:
                    self._error = e      # terminal: surfaced by wait()
                    return
                time.sleep(delay)
                delay *= 2

    def _write(self, step: int, host_state) -> None:
        tmp = os.path.join(self.root, f".tmp-{step}-{os.getpid()}")
        final = os.path.join(self.root, f"step_{step:08d}")
        os.makedirs(tmp, exist_ok=True)
        leaves = _leaf_paths(host_state)
        manifest = {"step": step, "time": time.time(),
                    "leaves": [{"path": p, "shape": list(np.shape(l)),
                                "dtype": str(np.asarray(l).dtype)}
                               for p, l in leaves]}
        np.savez(os.path.join(tmp, "shard_0.npz"),
                 **{f"leaf_{i}": np.asarray(l)
                    for i, (_, l) in enumerate(leaves)})
        with open(os.path.join(tmp, MANIFEST), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):  # idempotent: this step already committed
            for fn in os.listdir(tmp):
                os.remove(os.path.join(tmp, fn))
            os.rmdir(tmp)
        else:
            os.rename(tmp, final)  # atomic commit
        self._gc()

    def _remove_step(self, step: int) -> None:
        path = os.path.join(self.root, f"step_{step:08d}")
        for fn in os.listdir(path):
            os.remove(os.path.join(path, fn))
        os.rmdir(path)

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            self._remove_step(s)

    def discard_after(self, step: int) -> list[int]:
        """Drop every checkpoint NEWER than ``step``.

        The elastic-recovery invalidation rule: after restoring step ``s``
        onto a rebuilt mesh, saves from the aborted timeline (steps > s,
        taken on the pre-failure topology's float trajectory) are stale —
        a later restore must see the recovered run's own saves, not them.
        Returns the dropped steps."""
        dropped = [s for s in self.all_steps() if s > step]
        for s in dropped:
            self._remove_step(s)
        return dropped

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_"):
                if os.path.exists(os.path.join(self.root, d, MANIFEST)):
                    out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like, *, step: Optional[int] = None,
                shardings=None):
        """Restore into the structure of ``like``; re-shard to the current
        mesh if ``shardings`` (a matching tree of NamedSharding) is given —
        this is the elastic path: the checkpoint layout is logical, the mesh
        is whatever survives.

        A torn step (truncated shard file, corrupt manifest — a writer that
        died mid-commit or post-commit corruption) is DISCARDED with a
        warning naming it, and the restore falls back to the previous
        intact step: the newest checkpoint being unreadable must cost one
        save interval, not the run.  ``step=`` pins the newest step the
        caller will accept (the validated-step protocol of
        ``RestartManager``); the fallback walks strictly OLDER steps, never
        newer ones."""
        steps = self.all_steps()
        if step is not None:
            steps = [s for s in steps if s <= step]
        if not steps:
            raise FileNotFoundError(f"no checkpoints under {self.root}"
                                    + (f" at step <= {step}"
                                       if step is not None else ""))
        last_err: Optional[BaseException] = None
        for s in reversed(steps):
            try:
                return self._load_step(like, s, shardings)
            except TORN_ERRORS as e:
                warnings.warn(
                    f"checkpoint step {s} is torn "
                    f"({type(e).__name__}: {e}); discarding it and falling "
                    "back to the previous intact step", RuntimeWarning,
                    stacklevel=2)
                last_err = e
        raise FileNotFoundError(
            f"no intact checkpoint under {self.root}: every candidate step "
            f"{steps} is torn") from last_err

    def _load_step(self, like, step: int, shardings):
        path = os.path.join(self.root, f"step_{step:08d}")
        with open(os.path.join(path, MANIFEST)) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "shard_0.npz"))
        leaves = [data[f"leaf_{i}"] for i in range(len(manifest["leaves"]))]
        want = [l for _, l in _leaf_paths(like)]
        assert len(want) == len(leaves), "structure mismatch"
        for w, l, rec in zip(want, leaves, manifest["leaves"]):
            assert tuple(w.shape) == tuple(l.shape) == tuple(rec["shape"]), (
                w.shape, l.shape)
            assert str(l.dtype) == rec["dtype"], \
                f"{rec['path']}: shard dtype {l.dtype} != " \
                f"manifest {rec['dtype']}"
            assert str(np.dtype(w.dtype)) == rec["dtype"], \
                f"{rec['path']}: template dtype {w.dtype} != " \
                f"manifest {rec['dtype']}"
        treedef = jax.tree.structure(like)
        restored = jax.tree.unflatten(treedef, leaves)
        if shardings is not None:
            restored = jax.tree.map(
                lambda arr, sh: jax.device_put(arr, sh), restored, shardings)
        return restored, step
