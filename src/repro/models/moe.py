"""Mixture-of-Experts block: top-k routing + capacity dispatch, EP-aware.

Train/prefill: experts sharded over the tp ("model") axis, factored as
(ep, tp_ff) = MoESpec.ep_tp(tp) so non-divisible expert counts (granite: 40
experts over 16 chips -> ep=8, tp_ff=2) still map exactly.  Tokens are the
sequence-parallel gather (all chips of a tp group see the same tokens), each
chip computes its local experts' capacity buffers, and ONE reduce-scatter
combines expert-parallel partial sums, ffn-TP partial sums and the SP return.

Serve (decode): 1-token batches are tiny, so the same dispatch runs over the
pod-gathered token set with experts spread over (model x data) — weights stay
put, tokens move (see DESIGN.md §5).

Dispatch is argsort-based (gather tables, no one-hot einsum) so HLO FLOPs
reflect real expert compute.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import activation, rms_norm
from repro.models.parallel import ParallelCtx


def route(h: jax.Array, router_w: jax.Array, top_k: int):
    """h: (N, d) -> (idx (N,k) int32, gate (N,k) f32) — softmaxed over top-k
    (Qwen3/granite style norm_topk_prob)."""
    logits = h.astype(jnp.float32) @ router_w.astype(jnp.float32)
    vals, idx = lax.top_k(logits, top_k)
    gate = jax.nn.softmax(vals, axis=-1)
    return idx.astype(jnp.int32), gate


def dispatch_tables(idx: jax.Array, *, e0: int, n_local: int, capacity: int):
    """Build gather/scatter tables for the local expert group.

    idx: (N, k) global expert ids.  Returns
      table   (n_local, capacity): token index feeding each expert slot
              (N = dummy/empty),
      gates_sel (n_local, capacity): routing-slot index into idx/gate rows
              (for combine), -1 when empty.
    """
    N, k = idx.shape
    flat = idx.reshape(N * k)
    local = flat - e0
    key = jnp.where((local >= 0) & (local < n_local), local, n_local)
    order = jnp.argsort(key, stable=True)                  # (N*k,)
    skey = key[order]
    counts = jnp.bincount(key, length=n_local + 1)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(N * k) - starts[skey]
    keep = (skey < n_local) & (pos < capacity)
    row = jnp.where(keep, skey, n_local)                   # clipped rows
    col = jnp.where(keep, pos, 0)
    tok = order // k
    table = jnp.full((n_local + 1, capacity), N, jnp.int32)
    table = table.at[row, col].set(jnp.where(keep, tok, N).astype(jnp.int32))
    slot = jnp.full((n_local + 1, capacity), -1, jnp.int32)
    slot = slot.at[row, col].set(jnp.where(keep, order, -1).astype(jnp.int32))
    return table[:n_local], slot[:n_local]


def expert_ffn(buf: jax.Array, w_in: jax.Array, w_out: jax.Array, act: str
               ) -> jax.Array:
    """buf: (E_loc, C, d); w_in: (E_loc, d, 2, dff_loc) — explicit gate/up
    axis so dff sharding never splits across the halves; w_out:
    (E_loc, dff_loc, d)."""
    u = jnp.einsum("ecd,edgf->ecgf", buf, w_in)
    a = activation(act, u[:, :, 0], u[:, :, 1])
    return jnp.einsum("ecf,efd->ecd", a, w_out)


def moe_block(x_sp: jax.Array, p: dict, meta: dict, ctx: ParallelCtx, cfg, *,
              serve: bool = False) -> jax.Array:
    """x_sp: (B, T/tp, d) (train/prefill) or (B, 1, d) (serve)."""
    spec = cfg.moe
    eps = cfg.norm_eps
    E, k = spec.num_experts, spec.top_k
    ep, tp_ff = spec.ep_tp(ctx.tp)
    n_local = E // ep

    h = rms_norm(x_sp, ctx.gather_w(p["ln"], meta["ln"].fsdp_dim), eps)
    if serve:
        # tokens move, weights stay: gather the pod's token set over the
        # data axis (hier; expert dff is stored data-sharded), or keep local
        # (naive; weights fully replicated).
        if ctx.mode == "hier" and ctx.fsdp_axes:
            hg = lax.all_gather(  # raw-collective: expert dispatch
                h, ctx.fsdp_axes, axis=0, tiled=True)
        else:
            hg = h
    else:
        hg = ctx.ag_tokens(h)                               # (B, T, d)
    B, T, d = hg.shape
    tokens = hg.reshape(B * T, d)
    N = B * T

    router = ctx.gather_w(p["router"], meta["router"].fsdp_dim)  # (d, E)
    idx, gate = route(tokens, router, k)

    ep_idx, _ = ctx.tp_group_rank(tp_ff)                    # outer=ep, inner=ff
    e0 = ep_idx * n_local
    capacity = int(N * k / E * spec.capacity_factor) + 1
    table, slot = dispatch_tables(idx, e0=e0, n_local=n_local,
                                  capacity=capacity)

    tok_pad = jnp.concatenate([tokens, jnp.zeros((1, d), tokens.dtype)])
    buf = jnp.take(tok_pad, table, axis=0)                  # (E_loc, C, d)

    # local expert weights: stored (tp, E_loc, d, 2*dff/tp_ff) sharded on
    # dim0 -> local (1, E_loc, d, n_in)
    w_in = ctx.gather_w(p["w_in"], meta["w_in"].fsdp_dim)[0]
    w_out = ctx.gather_w(p["w_out"], meta["w_out"].fsdp_dim)[0]
    out_buf = expert_ffn(buf, w_in, w_out, cfg.act)         # (E_loc, C, d)

    gflat = jnp.concatenate([gate.reshape(N * k),
                             jnp.zeros(1, gate.dtype)])
    gsel = jnp.where(slot >= 0, gflat[jnp.clip(slot, 0)],
                     0.0).astype(out_buf.dtype)
    out_buf = out_buf * gsel[..., None]

    y = jnp.zeros((N + 1, d), out_buf.dtype)
    y = y.at[table.reshape(-1)].add(out_buf.reshape(-1, d))
    y = y[:N].reshape(B, T, d)
    if serve:
        if ctx.mode == "hier" and ctx.fsdp_axes:
            # raw-collective: expert-dispatch fast path, both arms
            y = (lax.psum(y, (ctx.tp_axis,)  # raw-collective: above
                          + tuple(ctx.fsdp_axes))
                 if ctx.tp_axis else
                 lax.psum(y, ctx.fsdp_axes))  # raw-collective: above
            b_loc = x_sp.shape[0]
            r = lax.axis_index(ctx.fsdp_axes[0])
            y = lax.dynamic_slice_in_dim(y, r * b_loc, b_loc, 0)
        else:
            y = ctx.psum_tp(y)
        return x_sp + y
    return x_sp + ctx.rs_tokens(y)  # combines EP + ffn-TP partials + SP


def aux_load_balance_loss(idx: jax.Array, gate: jax.Array, E: int
                          ) -> jax.Array:
    """Switch-style auxiliary loss (fraction-dispatched x mean-gate)."""
    N, k = idx.shape
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)      # (N, k, E)
    frac = jnp.mean(jnp.sum(onehot, axis=1), axis=0)        # (E,)
    prob = jnp.mean(jnp.sum(onehot * gate[..., None], axis=1), axis=0)
    return E * jnp.sum(frac * prob)
