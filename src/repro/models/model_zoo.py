"""Public model API: build a Model from a config name + parallel context."""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.models.parallel import ParallelCtx
from repro.models.transformer import Model, build

__all__ = ["Model", "build", "build_by_name", "make_batch", "ParallelCtx"]


def build_by_name(name: str, ctx: Optional[ParallelCtx] = None,
                  data: int = 1, reduced: bool = False, **red_kw) -> Model:
    cfg = get_config(name)
    if reduced:
        cfg = cfg.reduced(**red_kw)
    return build(cfg, ctx or ParallelCtx.single(), data=data)


def make_batch(cfg: ModelConfig, B: int, T: int, seed: int = 0,
               np_module=np) -> dict:
    """Host-side synthetic batch with the right structure for the family."""
    rng = np.random.default_rng(seed)
    if cfg.frontend == "encodec":
        return {
            "frames": jnp.asarray(rng.normal(
                size=(B, T, cfg.d_frontend)).astype(np.float32)),
            "labels": jnp.asarray(rng.integers(
                0, cfg.vocab, size=(B, T)).astype(np.int32)),
        }
    out = {"tokens": jnp.asarray(rng.integers(
        0, cfg.vocab, size=(B, T + 1)).astype(np.int32))}
    if cfg.frontend == "vit":
        out["patches"] = jnp.asarray(rng.normal(
            size=(B, cfg.n_prefix, cfg.d_frontend)).astype(np.float32))
    return out
