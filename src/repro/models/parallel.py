"""ParallelCtx: how model math maps onto the mesh, in both collective modes.

Models in this framework are written as *local* shard_map bodies against a
``ParallelCtx``.  With ``ctx = ParallelCtx.single()`` every collective helper
is a no-op, so the exact same model code runs on one CPU device (smoke tests)
and on the production mesh.

Axis roles:
  * ``tp_axis``   ("model") — tensor/expert parallelism + sequence-parallel
                  residuals (Megatron-SP layout: activations between blocks
                  are token-sharded over tp).
  * ``fsdp_axes`` — where parameters are *stored*: in **hier** mode (the
                  paper's MPI+MPI scheme) weights live once per pod, sharded
                  over ``data`` (the MPI-3 shared window); in **naive** mode
                  (pure-MPI analogue) they are replicated over data/pod.
  * ``dp_axes``   — batch sharding (("pod","data") or ("data",)).
  * ``pod_axis``  — the bridge (slow tier); gradient reductions cross it once
                  per shard (multi-leader bridge exchange).

Weight access goes through ``gather_w`` (the "load from the node's shared
buffer": an intra-pod all-gather at use time in hier mode, identity in naive
mode); gradient reduction goes through ``reduce_grads``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.comm import AsyncCollectiveHandle, Communicator
from repro.comm.handle import _ordered
from repro.comm.window import WindowEpochError


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    tp_axis: Optional[str] = None          # "model"
    fsdp_axes: tuple[str, ...] = ()        # ("data",) in hier mode
    dp_axes: tuple[str, ...] = ()          # ("pod","data") / ("data",)
    pod_axis: Optional[str] = None         # "pod" on the multi-pod mesh
    tp: int = 1                            # size of tp_axis
    mode: str = "hier"                     # hier | naive
    compute_dtype: jnp.dtype = jnp.bfloat16
    # beyond-paper perf options (EXPERIMENTS.md §Perf); () = paper-faithful
    #   bf16_rope   — rotate q/k in compute dtype (fp32 angle tables only)
    #   bf16_xent   — bf16 logits, fp32 reductions in the streamed loss
    #   decode2d    — 2D (head-group x seq-group) decode attention: TP-
    #                 stationary attn weights, no per-step FSDP gather
    #   overlap     — fused collective-matmul fast paths: the FSDP window
    #                 read (gather_w) and the SP reduce-scatter (rs_tokens)
    #                 stream chunk-wise behind the adjacent matmul
    #                 (repro.comm.pipeline); overlap_chunks sets the depth
    #   prefetch[=N]— async layer-parameter prefetch: issue layer k+1's FSDP
    #                 window gather while layer k computes, <= N groups in
    #                 flight (default 2); hier mode only, see ParamGroup
    #   stepgraph   — step-graph collective optimizer: record the step's
    #                 whole collective schedule, then bucket small same-axes
    #                 allreduces / dedup gathers / issue-early-resolve-late
    #                 (repro.comm.stepgraph); off by default
    opts: frozenset = frozenset()
    overlap_chunks: int = 2

    @staticmethod
    def single(mode: str = "hier", opts=frozenset()) -> "ParallelCtx":
        return ParallelCtx(mode=mode, compute_dtype=jnp.float32,
                           opts=frozenset(opts))

    def has(self, opt: str) -> bool:
        return opt in self.opts

    @property
    def prefetch(self) -> int:
        """In-flight budget of the layer-parameter prefetcher (0 = off).

        ``"prefetch"`` in opts means budget 2 (double buffering);
        ``"prefetch=N"`` sets it explicitly.  Only meaningful where weights
        actually live in the pod-shared store (hier mode with fsdp axes) —
        elsewhere the gather is free and the prefetcher stays off."""
        if self.mode != "hier" or not self.fsdp_axes:
            return 0
        for o in self.opts:
            if o == "prefetch":
                return 2
            if o.startswith("prefetch="):
                return max(0, int(o[len("prefetch="):]))
        return 0

    @property
    def stepgraph(self) -> bool:
        """Step-graph collective optimizer: the train step records its
        collectives into a ``CollectiveGraph`` and runs the rewritten
        (bucketed / deduped / reordered) schedule instead of issuing
        eagerly.  Bit-identical outputs; off by default."""
        return "stepgraph" in self.opts

    # ---- indices -----------------------------------------------------------
    @property
    def tp_rank(self):
        return lax.axis_index(self.tp_axis) if self.tp_axis else 0

    def tp_group_rank(self, group: int):
        """(outer, inner) coords when the tp axis is factored as
        (tp//group, group): outer = rank // group, inner = rank % group."""
        r = self.tp_rank
        return r // group, r % group

    # ---- the data-tier communicator -----------------------------------------
    @property
    def comm(self) -> Optional[Communicator]:
        """The two-tier communicator of the parameter/gradient data path:
        fast tier = where parameters are stored (fsdp in hier mode, the
        non-pod dp axes in naive mode), slow tier = the bridge.  ``None``
        for a single-device ctx."""
        fast = self.fsdp_axes or tuple(a for a in self.dp_axes
                                       if a != self.pod_axis)
        if not fast:
            return None
        return Communicator(fast_axis=fast, slow_axis=self.pod_axis)

    # ---- weight load/store (the shared-memory window) -----------------------
    def gather_w(self, w: jax.Array, fsdp_dim: Optional[int]) -> jax.Array:
        """Load a weight from the pod-shared store.  hier: read through the
        node's ``SharedWindow`` — intra-pod all-gather of the FSDP shards at
        use time (cast first so bf16 moves, not fp32); AD transposes the
        read into the reduce-scatter store.  naive: local private copy, no
        traffic."""
        w = w.astype(self.compute_dtype)
        if self.mode == "hier" and self.fsdp_axes and fsdp_dim is not None:
            w = self.comm.window(w, axis=fsdp_dim, epoch=1).read()
        return w

    def ag_matmul(self, x: jax.Array, w: jax.Array,
                  fsdp_dim: Optional[int]) -> jax.Array:
        """``x @ gather_w(w, fsdp_dim)`` — the fused gather_w fast path.

        With the ``overlap`` opt (hier mode, weight FSDP-sharded along its
        contraction dim), the window read streams chunk-wise behind the
        panel matmuls (``comm.ag_matmul``); otherwise exactly the unfused
        read-then-matmul."""
        fusable = (self.has("overlap") and self.mode == "hier"
                   and bool(self.fsdp_axes) and fsdp_dim == 0
                   and w.ndim == 2)
        if fusable:
            shard = w.astype(self.compute_dtype)
            nc = _clamp_chunks(self.overlap_chunks, shard.shape[0])
            return self.comm.ag_matmul(x, shard, n_chunks=nc)
        return x @ self.gather_w(w, fsdp_dim)

    def matmul_rs(self, x: jax.Array, w: jax.Array, dim: int = 1
                  ) -> jax.Array:
        """``rs_tokens(x @ w, dim)`` — the fused rs_tokens fast path.

        With the ``overlap`` opt, the token-dim reduce-scatter of panel *k*
        overlaps the matmul of panel *k+1* (``comm.pipeline.matmul_rs``);
        otherwise exactly the unfused matmul-then-scatter."""
        if not self.tp_axis:
            return x @ w
        if self.has("overlap"):
            nc = _clamp_chunks(self.overlap_chunks,
                               x.shape[dim] // self.tp)
            if nc > 1:
                tp_comm = Communicator(fast_axis=self.tp_axis)
                return tp_comm.matmul_rs(x, w, axis=dim, n_chunks=nc)
        return self.rs_tokens(x @ w, dim)

    def grad_reduce_axes(self, meta) -> tuple[str, ...]:
        """Axes a gradient leaf still needs to be summed over — the single
        source of truth the step-graph optimizer rewrites under.

        The AD transpose of the hier weight gather already reduce-scattered
        over the fsdp axes; tp-sharded weights never replicate over the tp
        axis.  What is left: the bridge (pod) in hier mode — plus the fsdp
        axes for the tiny fsdp-replicated leaves (norms); the full dp tier
        in naive mode; plus the tp axis for tp-replicated leaves in both.
        Bridge axes come FIRST so the naive lowering (``lax.psum`` over
        slow + fast) matches the axes order exactly."""
        axes: tuple[str, ...] = ()
        if self.mode == "hier":
            if self.pod_axis:
                axes += (self.pod_axis,)
            if meta.fsdp_dim is None and self.fsdp_axes:
                axes += tuple(self.fsdp_axes)
        else:
            axes += tuple(self.dp_axes)
        if meta.tp_dim is None and self.tp_axis:
            axes += (self.tp_axis,)
        return axes

    def _axes_comm(self, axes: tuple[str, ...]) -> Communicator:
        """The two-tier communicator that reduces over EXACTLY ``axes``:
        pod is the slow tier when present alongside fast axes, else the
        whole (single-tier) communicator."""
        fast = tuple(a for a in axes if a != self.pod_axis)
        slow = self.pod_axis if (self.pod_axis in axes and fast) else None
        return Communicator(fast_axis=fast or axes, slow_axis=slow)

    def reduce_grads(self, grads, metas=None, *, compress=None,
                     recorder=None, precision: str = "exact",
                     tol: Optional[float] = None, error_state=None):
        """Bridge gradient reduction.  Gradients already match the param
        layout w.r.t. data (AD transposes the hier window reads into
        intra-pod reduce-scatters); what remains is the cross-pod (bridge)
        psum in hier mode, or the flat dp allreduce in naive mode.

        With ``metas`` (a leaf-aligned ``PMeta`` sequence) the reduction is
        per-leaf over ``grad_reduce_axes(meta)`` through ``Communicator``
        dispatch — the schedule-driven path.  ``precision="lossy"`` routes
        bridge-crossing leaves (hier mode) through the quantized wire
        formats of the scheme registry (auto-resolved, never named here);
        ``error_state`` (a grads-shaped tree of residuals, scalar
        ``jnp.float32(0)`` leaves to start) threads error feedback through
        those reductions, and the call then returns
        ``(grads, new_error_state)``.  ``compress`` is the legacy explicit
        hook (same leaves, caller-supplied fn); ``recorder`` (a
        ``Communicator.record()`` ``GraphRecorder``) defers every exact
        reduction into the step graph and returns ``Deferred`` leaves —
        resolve them with the ``ScheduleResult`` of ``recorder.run()``.
        Without ``metas``: the legacy whole-tree reduction (every leaf
        crosses the same axes)."""
        lossy = precision == "lossy"
        if error_state is not None and not lossy:
            raise ValueError("error_state requires precision='lossy'")
        errs = jax.tree.leaves(error_state) \
            if error_state is not None else None
        if metas is not None:
            leaves = jax.tree.leaves(grads)
            new_errs = [jnp.zeros((), jnp.float32) for _ in leaves]
            reduced, comms, lossy_comms = [], {}, {}
            for i, (g, meta) in enumerate(zip(leaves, metas)):
                axes = self.grad_reduce_axes(meta)
                if not axes:
                    reduced.append(g)
                    continue
                # bridge compression: the slow-tier (cross-pod) reduction
                # is quantized; on podless meshes it applies to every dp
                # reduction (keeps the path exercised at small scale).
                bridge = (self.pod_axis in axes) if self.pod_axis else True
                if compress is not None and self.mode == "hier" and bridge:
                    reduced.append(compress(g, axes))
                    continue
                if lossy and self.mode == "hier" and bridge:
                    # single-tier over EXACTLY axes: quantize once over the
                    # whole reduction (the legacy compress semantics —
                    # arbitrary leaf shapes flatten+pad into blocks).
                    comm = lossy_comms.get(axes)
                    if comm is None:
                        comm = lossy_comms[axes] = \
                            Communicator(fast_axis=axes)
                    if errs is not None:
                        out, new_errs[i] = comm.allreduce(
                            g, precision="lossy", tol=tol,
                            result="replicated", error_feedback=errs[i])
                    else:
                        out = comm.allreduce(g, precision="lossy", tol=tol,
                                             result="replicated")
                    reduced.append(out)
                    continue
                if recorder is not None:
                    reduced.append(recorder.allreduce(
                        g, axes=axes, scheme="naive", key=("grad", i)))
                    continue
                comm = comms.get(axes)
                if comm is None:
                    comm = comms[axes] = self._axes_comm(axes)
                reduced.append(comm.allreduce(g, scheme="naive",
                                              result="replicated"))
            tree = jax.tree.unflatten(jax.tree.structure(grads), reduced)
            if error_state is not None:
                return tree, jax.tree.unflatten(
                    jax.tree.structure(grads), new_errs)
            return tree
        if self.mode == "hier":
            if self.pod_axis is None:
                return (grads, error_state) if error_state is not None \
                    else grads
            if lossy:
                bcomm = Communicator(fast_axis=self.pod_axis)
                leaves = jax.tree.leaves(grads)
                if errs is not None:
                    pairs = [bcomm.allreduce(g, precision="lossy", tol=tol,
                                             result="replicated",
                                             error_feedback=e)
                             for g, e in zip(leaves, errs)]
                    st = jax.tree.structure(grads)
                    return (jax.tree.unflatten(st, [o for o, _ in pairs]),
                            jax.tree.unflatten(st, [e for _, e in pairs]))
                return jax.tree.map(
                    lambda g: bcomm.allreduce(g, precision="lossy", tol=tol,
                                              result="replicated"), grads)
            comm = self.comm
            if comm is None:     # no node tier: the bridge is the whole comm
                comm = Communicator(fast_axis=self.pod_axis)
                return jax.tree.map(
                    lambda g: comm.allreduce(g, result="replicated"), grads)
            return jax.tree.map(comm.bridge_psum, grads)
        axes = self.dp_axes
        if not axes:
            return grads
        if error_state is not None:
            raise ValueError("error_state needs the hier bridge path "
                             "(metas, or hier mode)")
        # the dp reduction's own communicator: reduce over EXACTLY dp_axes.
        # scheme="auto" + the replicated constraint: the tuning table (or
        # the closed forms) picks the reduction schedule, but the result
        # must stay a plain per-rank gradient, never a window.
        fast = tuple(a for a in axes if a != self.pod_axis)
        slow = self.pod_axis if (self.pod_axis in axes and fast) else None
        dp_comm = Communicator(fast_axis=fast or axes, slow_axis=slow)
        return jax.tree.map(
            lambda g: dp_comm.allreduce(g, result="replicated",
                                        precision=precision, tol=tol),
            grads)

    # ---- tp collectives ------------------------------------------------------
    def ag_tokens(self, x: jax.Array, dim: int = 1) -> jax.Array:
        """Sequence-parallel all-gather: (B, T/tp, d) -> (B, T, d).
        Output is checkpoint-named so the save_ag remat policy can keep it
        across the bwd instead of re-gathering (§Perf)."""
        if not self.tp_axis:
            return x
        from jax.ad_checkpoint import checkpoint_name
        out = lax.all_gather(  # raw-collective: ag_tokens tp fast path (allowlisted)
            x, self.tp_axis, axis=dim, tiled=True)
        return checkpoint_name(out, "ag_out")

    def rs_tokens(self, x: jax.Array, dim: int = 1) -> jax.Array:
        """Sequence-parallel reduce-scatter: partial (B, T, d) -> (B, T/tp, d)."""
        if not self.tp_axis:
            return x
        return lax.psum_scatter(x, self.tp_axis, scatter_dimension=dim,
                                tiled=True)

    def psum_tp(self, x: jax.Array) -> jax.Array:
        if not self.tp_axis:
            return x
        return lax.psum(x, self.tp_axis)  # raw-collective: psum_tp fast path

    def group_all_gather(self, x: jax.Array, *, group: int, dim: int
                         ) -> jax.Array:
        """All-gather within contiguous subgroups of the tp axis (the
        axis_index_groups trick used for mLSTM head groups and split-K)."""
        if not self.tp_axis or group == 1:
            return x
        n = self.tp
        groups = [list(range(s, s + group)) for s in range(0, n, group)]
        return lax.all_gather(  # raw-collective: grouped tp fast path
            x, self.tp_axis, axis=dim, tiled=True, axis_index_groups=groups)

    def group_psum(self, x: jax.Array, *, group: int) -> jax.Array:
        if not self.tp_axis or group == 1:
            return x
        n = self.tp
        groups = [list(range(s, s + group)) for s in range(0, n, group)]
        return lax.psum(  # raw-collective: grouped tp fast path
            x, self.tp_axis, axis_index_groups=groups)

    def pmax_tp(self, x: jax.Array) -> jax.Array:
        """Cross-shard max.  Implemented as all_gather+max rather than pmax:
        pmax has no JVP rule, and this shows up inside differentiated loss
        code (as a softmax stabilizer)."""
        if not self.tp_axis:
            return x
        # raw-collective: pmax_tp tp fast path
        g = lax.all_gather(x, self.tp_axis)   # (tp, ...)
        return jnp.max(g, axis=0)

    # ---- sizes ---------------------------------------------------------------
    def shard(self, n: int) -> int:
        assert n % self.tp == 0, f"{n} not divisible by tp={self.tp}"
        return n // self.tp


# ---------------------------------------------------------------------------
# Async parameter prefetch (FSDP2-style sharded <-> unsharded lifecycle)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ParamGroup:
    """One layer's parameters as an unshard/reshard unit.

    Mirrors torch FSDP2's ``_fsdp_param_group``: a group's weights live
    *sharded* in the pod store; ``unshard()`` issues every FSDP-dim gather
    as an ``AsyncCollectiveHandle`` (no data consumed yet), ``wait()``
    resolves the handles into the full per-layer tree, ``reshard()`` drops
    the full copy so at most ``budget`` groups are ever unsharded.

    The gather per leaf is byte-identical to ``ParallelCtx.gather_w`` (cast
    to compute dtype FIRST, then read through the window), so prefetched and
    eager execution produce bit-identical math.
    """

    ctx: ParallelCtx
    params: object                 # this layer's (sharded) param tree
    metas: object                  # matching tree with PMeta leaves
    _handles: object = None        # issued but unresolved (in-flight)
    _full: object = None           # resolved full copy (unsharded)

    @property
    def state(self) -> str:
        """sharded -> in_flight -> unsharded lifecycle probe (tests)."""
        if self._full is not None:
            return "unsharded"
        return "in_flight" if self._handles is not None else "sharded"

    def unshard(self) -> "ParamGroup":
        """Issue the group's gathers (idempotent while in flight).

        The whole group shares ONE ordering token — the analogue of FSDP2
        recording a single CUDA event per param-group bucket rather than
        one per tensor: the leaves gather independently, one barrier pins
        "all of this group's gathers have issued" (2 barrier ops per group
        instead of 2 per leaf — measurably cheaper in the step bench)."""
        if self._handles is not None or self._full is not None:
            return self
        ctx = self.ctx

        def read(w, m):
            w = w.astype(ctx.compute_dtype)
            dim = getattr(m, "fsdp_dim", None)
            if ctx.mode == "hier" and ctx.fsdp_axes and dim is not None:
                win = ctx.comm.window(w, axis=dim, epoch=1)
                return (win, win.read())
            return w

        read_tree = jax.tree.map(read, self.params, self.metas)
        is_pair = lambda x: isinstance(x, tuple)  # noqa: E731
        pairs = [p for p in jax.tree.leaves(read_tree, is_leaf=is_pair)
                 if is_pair(p)]
        if pairs:
            vals, token = _ordered(tuple(v for _, v in pairs),
                                   jnp.ones((), jnp.float32))
        else:
            vals, token = (), None
        it = iter(zip((w for w, _ in pairs), vals))

        def to_handle(p):
            if not is_pair(p):
                return p
            win, v = next(it)
            return AsyncCollectiveHandle(
                family="allgather", window=win, value=v, token=token,
                issue_epoch=win.epoch)

        self._handles = jax.tree.map(to_handle, read_tree, is_leaf=is_pair)
        return self

    def wait(self):
        """Resolve the in-flight gathers; returns the full param tree.

        The group resolves as a unit (one barrier against the shared issue
        token); each handle's epoch is still checked individually, so a
        store tearing ONE window fails the wait exactly like a per-leaf
        ``resolve`` would."""
        if self._full is None:
            assert self._handles is not None, \
                "ParamGroup.wait() before unshard()"
            is_h = lambda x: isinstance(x, AsyncCollectiveHandle)  # noqa: E731
            handles = [h for h in jax.tree.leaves(self._handles, is_leaf=is_h)
                       if is_h(h)]
            for h in handles:
                if not h.done:
                    raise WindowEpochError(
                        f"wait on a torn {h.family} handle: the window was "
                        f"stored to or fenced past epoch {h.issue_epoch} "
                        f"(now epoch {h.window.epoch}, "
                        f"dirty={h.window.dirty}) — re-issue after the "
                        "fence")
            if handles:
                vals, _ = _ordered(tuple(h.value for h in handles),
                                   handles[0].token)
            it = iter(vals) if handles else iter(())

            def resolve(h):
                return next(it) if is_h(h) else h

            self._full = jax.tree.map(resolve, self._handles, is_leaf=is_h)
            self._handles = None
        return self._full

    def reshard(self) -> "ParamGroup":
        """Free the unsharded copy (back to the sharded store)."""
        self._full = None
        self._handles = None
        return self


def prefetch_schedule(n: int, budget: int) -> list[tuple[str, int]]:
    """The prefetcher's event order for ``n`` groups with at most
    ``budget`` in flight: prime ``budget`` unshards, then per group —
    wait, compute, reshard, and backfill the next unshard.  Pure data so
    the in-flight invariants are property-testable without tracing."""
    budget = max(1, budget)
    events = [("unshard", k) for k in range(min(budget, n))]
    for k in range(n):
        events.append(("wait", k))
        events.append(("compute", k))
        events.append(("reshard", k))
        if k + budget < n:
            events.append(("unshard", k + budget))
    return events


def prefetch_walk(groups, fn, x, budget: int):
    """Drive ``x = fn(x, k, full_params_k)`` over ``groups`` with the
    bounded-prefetch schedule.  Inside one jitted step the issued gathers
    overlap the preceding groups' compute via XLA dataflow — the FSDP2
    implicit-prefetch pattern."""
    groups = list(groups)
    for ev, k in prefetch_schedule(len(groups), budget):
        if ev == "unshard":
            groups[k].unshard()
        elif ev == "wait":
            groups[k].wait()
        elif ev == "compute":
            x = fn(x, k, groups[k].wait())
        else:
            groups[k].reshard()
    return x


def _clamp_chunks(n_chunks: int, extent: int) -> int:
    """Largest chunk count <= ``n_chunks`` that tiles ``extent`` (the fused
    paths must never change shapes — they fall back to fewer chunks)."""
    nc = max(1, min(n_chunks, extent if extent > 0 else 1))
    while extent % nc:
        nc -= 1
    return nc


def tp_slice(x: jax.Array, rank, tp: int, dim: int) -> jax.Array:
    """Dynamic slice of the tp-local piece along ``dim`` (used where a weight
    is stored unsharded but consumed shard-wise)."""
    size = x.shape[dim] // tp
    start = [0] * x.ndim
    start[dim] = rank * size
    sizes = list(x.shape)
    sizes[dim] = size
    return lax.dynamic_slice(x, start, sizes)
