"""xLSTM blocks: mLSTM (chunked-parallel linear attention with matrix memory)
and sLSTM (sequential scalar-memory RNN).  arXiv:2405.04517.

TPU adaptation (DESIGN.md §5):
  * mLSTM is evaluated in *chunkwise-parallel* form — intra-chunk masked
    linear attention + cross-chunk state recurrence via
    ``lax.associative_scan`` — so the lowering contains NO sequential loops
    and HLO cost analysis counts every FLOP.
  * Sharding: heads x v-slices over tp (head-major flattened inner dim); q/k
    are computed per head group from a group all-gather.
  * sLSTM is inherently sequential (recurrent nonlinearity): it runs as a
    ``lax.scan`` over time, batch-sharded over tp groups; its recurrent FLOPs
    are reported analytically (``slstm_scan_flops``) to the roofline.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import rms_norm
from repro.models.parallel import ParallelCtx


def causal_conv1d(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv: x (B, T, C), w (C, K)."""
    K = w.shape[1]
    out = x * w[:, -1]
    for j in range(1, K):
        shifted = jnp.pad(x, ((0, 0), (j, 0), (0, 0)))[:, :-j]
        out = out + shifted * w[:, K - 1 - j]
    return out


def _head_layout(ctx: ParallelCtx, nh: int, hd: int):
    """hpc: heads per chip, g: chips per head, vs: local v-slice width."""
    tp = ctx.tp
    hpc = max(nh // tp, 1)
    g = max(tp // nh, 1)
    return hpc, g, hd // g


# ---------------------------------------------------------------------------
# mLSTM chunkwise-parallel scan
# ---------------------------------------------------------------------------

def mlstm_parallel(q, k, v, ig, fg, *, chunk: int = 128,
                   return_state: bool = False):
    """q, k: (B, T, h, hd); v: (B, T, h, vs); ig, fg: (B, T, h) raw gates.
    Returns (B, T, h, vs) (+ final stabilized state for decode continuation
    when ``return_state``).  Stabilized with a per-sequence input-gate max."""
    B, T, h, hd = q.shape
    vs = v.shape[-1]
    S = min(chunk, T)
    assert T % S == 0, f"T={T} not divisible by chunk={S}"
    nc = T // S

    log_f = jax.nn.log_sigmoid(fg.astype(jnp.float32))       # (B, T, h)
    m = lax.stop_gradient(jnp.max(ig, axis=1, keepdims=True))  # (B, 1, h)
    li = (ig - m).astype(jnp.float32)                        # log i', <= 0

    def cshape(x):  # (B, T, ...) -> (B, nc, S, ...)
        return x.reshape((B, nc, S) + x.shape[2:])

    qc, kc, vc = cshape(q.astype(jnp.float32)), cshape(k.astype(jnp.float32)), \
        cshape(v.astype(jnp.float32))
    lfc, lic = cshape(log_f), cshape(li)
    F = jnp.cumsum(lfc, axis=2)                              # incl. cumsum
    Ftot = F[:, :, -1]                                       # (B, nc, h)

    # intra-chunk: A[t, j] = exp(F[t]-F[j]+li[j]) * (q_t . k_j), j <= t
    smat = jnp.einsum("bcthd,bcshd->bchts", qc, kc) / (hd ** 0.5)
    logw = (F[:, :, :, None, :] - F[:, :, None, :, :]
            + lic[:, :, None, :, :])                         # (B,c,t,s,h)
    tri = jnp.tril(jnp.ones((S, S), bool))
    w = jnp.where(tri[None, None, :, :, None], jnp.exp(logw), 0.0)
    wq = w.transpose(0, 1, 4, 2, 3) * smat                   # (B,c,h,t,s)
    o_intra = jnp.einsum("bchts,bcshv->bcthv", wq, vc)
    den_intra = jnp.sum(wq, axis=-1).transpose(0, 1, 3, 2)   # (B,c,t,h)

    # chunk summaries: dC = sum_j exp(Ftot - F[j] + li[j]) k_j v_j^T
    wsum = jnp.exp(Ftot[:, :, None, :] - F + lic)            # (B,c,S,h)
    dC = jnp.einsum("bcsh,bcshd,bcshv->bchdv", wsum, kc, vc)
    dn = jnp.einsum("bcsh,bcshd->bchd", wsum, kc)
    D = jnp.exp(Ftot)                                        # (B,c,h)

    # cross-chunk associative prefix:  (D, dC, dn) o (D', dC', dn')
    def combine(a, b):
        Da, Ca, na = a
        Db, Cb, nb = b
        return (Da * Db, Db[..., None, None] * Ca + Cb,
                Db[..., None] * na + nb)

    Dp, Cp, np_ = lax.associative_scan(combine, (D, dC, dn), axis=1)
    zC = jnp.zeros_like(Cp[:, :1])
    zn = jnp.zeros_like(np_[:, :1])
    C_prev = jnp.concatenate([zC, Cp[:, :-1]], axis=1)       # state before c
    n_prev = jnp.concatenate([zn, np_[:, :-1]], axis=1)

    decay_t = jnp.exp(F)                                     # (B,c,S,h)
    o_inter = jnp.einsum("bcthd,bchdv->bcthv", qc, C_prev) \
        * decay_t[..., None] / (hd ** 0.5)
    den_inter = jnp.einsum("bcthd,bchd->bcth", qc, n_prev) \
        * decay_t / (hd ** 0.5)

    num = o_intra + o_inter
    den = den_intra + den_inter                              # (B,c,t,h)
    den = jnp.maximum(jnp.abs(den), 1.0)
    out = num / den[..., None]
    out = out.reshape(B, T, h, vs).astype(q.dtype)
    if return_state:
        state = {"C": Cp[:, -1], "n": np_[:, -1],
                 "m": jnp.squeeze(m, 1).astype(jnp.float32)}
        return out, state
    return out


def mlstm_decode_step(state: dict, q, k, v, ig, fg):
    """One-token recurrence.  state: C (B,h,hd,vs), n (B,h,hd), m (B,h);
    q,k: (B,h,hd); v: (B,h,vs)."""
    C, n, m = state["C"], state["n"], state["m"]
    hd = q.shape[-1]
    log_f = jax.nn.log_sigmoid(fg.astype(jnp.float32))
    m_new = jnp.maximum(log_f + m, ig.astype(jnp.float32))
    fp = jnp.exp(log_f + m - m_new)
    ip = jnp.exp(ig - m_new)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    C = fp[..., None, None] * C + ip[..., None, None] \
        * (kf[..., :, None] * vf[..., None, :])
    n = fp[..., None] * n + ip[..., None] * kf
    qf = q.astype(jnp.float32) / (hd ** 0.5)
    num = jnp.einsum("bhd,bhdv->bhv", qf, C)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n))
    den = jnp.maximum(den, jnp.exp(-m_new))
    out = num / den[..., None]
    return {"C": C, "n": n, "m": m_new}, out.astype(q.dtype)


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------

def _mlstm_qkv_gates(xh, p, ctx: ParallelCtx, nh: int, hd: int, hpc: int):
    """xh: (B, T, hpc, hd) gathered head inputs -> q, k (B,T,hpc,hd),
    gates (B,T,hpc,2).  Weight tensors are (nh, hd, .) stored tp-replicated;
    slice this chip's heads."""
    h0 = (ctx.tp_rank * hpc) % nh if ctx.tp_axis else 0
    wq = lax.dynamic_slice_in_dim(p["wq"], h0, hpc, 0)
    wk = lax.dynamic_slice_in_dim(p["wk"], h0, hpc, 0)
    wif = lax.dynamic_slice_in_dim(p["wif"], h0, hpc, 0)
    q = jnp.einsum("bthd,hde->bthe", xh, wq.astype(xh.dtype))
    k = jnp.einsum("bthd,hde->bthe", xh, wk.astype(xh.dtype))
    gates = jnp.einsum("bthd,hdg->bthg", xh, wif.astype(xh.dtype))
    return q, k, gates


def mlstm_block(x_sp, p, meta, ctx: ParallelCtx, cfg, *, chunk: int = 128,
                state: dict | None = None, decode: bool = False,
                return_state: bool = False):
    """x_sp: (B, T/tp, d) (train) or (B, 1, d) (decode)."""
    nh, din = cfg.n_heads, cfg.d_inner
    hd = din // nh
    hpc, g, vs = _head_layout(ctx, nh, hd)
    eps = cfg.norm_eps

    h = rms_norm(x_sp, ctx.gather_w(p["ln"], meta["ln"].fsdp_dim), eps)
    hg = h if decode else ctx.ag_tokens(h)                   # (B, T, d)
    B, T, _ = hg.shape

    w_up = ctx.gather_w(p["w_up"], meta["w_up"].fsdp_dim)    # (d, 2, din/tp)
    u = jnp.einsum("btd,dgf->btgf", hg, w_up)
    z_loc, x_loc = u[:, :, 0], u[:, :, 1]                    # (B,T,din/tp)

    conv_w = ctx.gather_w(p["conv"], meta["conv"].fsdp_dim)  # (din/tp, K)
    if decode:
        cx = state["conv"]                                   # (B, K-1, C)
        xin = jnp.concatenate([cx, x_loc], axis=1)
        xc = causal_conv1d(xin, conv_w)[:, -1:]
        new_conv = xin[:, 1:]
    else:
        xc = causal_conv1d(x_loc, conv_w)
    xc = jax.nn.silu(xc)

    # per-head-group gather: (B,T,hpc,vs) -> (B,T,hpc,hd)
    xh = ctx.group_all_gather(xc.reshape(B, T, hpc, vs), group=g, dim=3)
    q, k, gates = _mlstm_qkv_gates(xh, {k_: ctx.gather_w(p[k_],
                                                         meta[k_].fsdp_dim)
                                        for k_ in ("wq", "wk", "wif")},
                                   ctx, nh, hd, hpc)
    # v: full-head input x local v-slice of Wv
    wv = ctx.gather_w(p["wv"], meta["wv"].fsdp_dim)          # (nh, hd, hd)
    h0 = (ctx.tp_rank * hpc) % nh if ctx.tp_axis else 0
    sl = (ctx.tp_rank % g) * vs if ctx.tp_axis else 0
    wv = lax.dynamic_slice(wv, (h0, 0, sl), (hpc, hd, vs))
    v = jnp.einsum("bthd,hdv->bthv", xh, wv.astype(xh.dtype))

    ig, fg = gates[..., 0], gates[..., 1]
    if decode:
        new_state, o = mlstm_decode_step(
            {k2: state[k2] for k2 in ("C", "n", "m")},
            q[:, 0], k[:, 0], v[:, 0], ig[:, 0], fg[:, 0])
        o = o[:, None]
        new_state["conv"] = new_conv
    elif return_state:
        o, new_state = mlstm_parallel(q, k, v, ig, fg, chunk=min(chunk, T),
                                      return_state=True)
        K = cfg.conv_kernel
        new_state["conv"] = x_loc[:, -(K - 1):].astype(x_loc.dtype)
    else:
        o = mlstm_parallel(q, k, v, ig, fg, chunk=min(chunk, T))
        new_state = None

    o = o.reshape(B, T, hpc * vs) * jax.nn.silu(z_loc)
    w_down = ctx.gather_w(p["w_down"], meta["w_down"].fsdp_dim)  # (din/tp, d)
    y = o @ w_down
    if decode:
        out = x_sp + ctx.psum_tp(y)
        return out, new_state
    out = x_sp + ctx.rs_tokens(y)
    return (out, new_state) if return_state else out


def mlstm_state_init(cfg, B: int, ctx: ParallelCtx, dtype=jnp.float32):
    nh = cfg.n_heads
    hd = cfg.d_inner // nh
    hpc, g, vs = _head_layout(ctx, nh, hd)
    return {"C": jnp.zeros((B, hpc, hd, vs), jnp.float32),
            "n": jnp.zeros((B, hpc, hd), jnp.float32),
            "m": jnp.full((B, hpc), -1e30, jnp.float32),
            "conv": jnp.zeros((B, cfg.conv_kernel - 1,
                               cfg.d_inner // max(ctx.tp, 1)), dtype)}


# ---------------------------------------------------------------------------
# sLSTM (sequential; batch-sharded over tp)
# ---------------------------------------------------------------------------

def slstm_cell(carry, gx, r_w, nh: int):
    """carry: (h, c, n, m) each (b, d); gx: (b, 4, d) input-side gates;
    r_w: (nh, dh, 4, dh) recurrent block-diagonal weights."""
    h, c, n, m = carry
    b, d = h.shape
    dh = d // nh
    hr = h.reshape(b, nh, dh)
    gr = jnp.einsum("bhd,hdgf->bhgf", hr, r_w)               # (b, nh, 4, dh)
    g = gx + gr.transpose(0, 2, 1, 3).reshape(b, 4, d)
    it, ft, zt, ot = g[:, 0], g[:, 1], g[:, 2], g[:, 3]
    m_new = jnp.maximum(ft + m, it)
    ip = jnp.exp(it - m_new)
    fp = jnp.exp(ft + m - m_new)
    c_new = fp * c + ip * jnp.tanh(zt)
    n_new = fp * n + ip
    h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1e-6)
    return (h_new, c_new, n_new, m_new)


def slstm_block(x_sp, p, meta, ctx: ParallelCtx, cfg, *,
                state: dict | None = None, decode: bool = False,
                return_state: bool = False):
    d, nh = cfg.d_model, cfg.n_heads
    eps = cfg.norm_eps
    h_in = rms_norm(x_sp, ctx.gather_w(p["ln"], meta["ln"].fsdp_dim), eps)
    hg = h_in if decode else ctx.ag_tokens(h_in)             # (B, T, d)
    B, T, _ = hg.shape

    w_x = ctx.gather_w(p["w_x"], meta["w_x"].fsdp_dim)       # (d, 4, d)
    r_w = ctx.gather_w(p["r"], meta["r"].fsdp_dim).astype(jnp.float32)
    b_g = ctx.gather_w(p["b"], meta["b"].fsdp_dim)           # (4, d)
    gx = jnp.einsum("btd,dgf->btgf", hg, w_x) + b_g          # (B, T, 4, d)
    gx = gx.astype(jnp.float32)

    if decode:
        carry = (state["h"], state["c"], state["n"], state["m"])
        new = slstm_cell(carry, gx[:, 0], r_w, nh)
        hs = new[0][:, None].astype(hg.dtype)                # (B, 1, d)
        new_state = dict(zip(("h", "c", "n", "m"), new))
        w_out = ctx.gather_w(p["w_out"], meta["w_out"].fsdp_dim)
        return x_sp + hs @ w_out, new_state

    # batch-shard the sequential scan over tp groups
    tp = ctx.tp
    nb = min(tp, B)            # distinct sequences handled in parallel
    cps = tp // nb             # chips replicating each sequence
    bs = B // nb
    if ctx.tp_axis:
        seq_idx = ctx.tp_rank // cps
        primary = (ctx.tp_rank % cps) == 0
        gxm = lax.dynamic_slice_in_dim(gx, seq_idx * bs, bs, 0)
    else:
        seq_idx, primary, gxm = 0, True, gx

    z = jnp.zeros((bs, d), jnp.float32)
    carry0 = (z, z, z, jnp.full((bs, d), -1e30, jnp.float32))

    def step(carry, gxt):
        new = slstm_cell(carry, gxt, r_w, nh)
        return new, new[0]

    final, hs = lax.scan(step, carry0, gxm.swapaxes(0, 1))   # (T, bs, d)
    hs = hs.swapaxes(0, 1).astype(hg.dtype)                  # (bs, T, d)

    new_state = None
    if return_state:
        def widen(s):  # (bs, d) -> (B, d) replicated via masked psum
            if not ctx.tp_axis:
                return s
            full = jnp.zeros((B, d), s.dtype)
            full = lax.dynamic_update_slice_in_dim(
                full, s * jnp.asarray(primary, s.dtype), seq_idx * bs, 0)
            # raw-collective: flat tp fast path (one group, one schedule)
            return lax.psum(full, ctx.tp_axis)
        new_state = dict(zip(("h", "c", "n", "m"), map(widen, final)))

    w_out = ctx.gather_w(p["w_out"], meta["w_out"].fsdp_dim)  # (d, d)
    y_me = hs @ w_out
    if ctx.tp_axis:
        y_full = jnp.zeros((B, T, d), y_me.dtype)
        y_full = lax.dynamic_update_slice_in_dim(
            y_full, y_me * jnp.float32(primary).astype(y_me.dtype),
            seq_idx * bs, 0)
        out = x_sp + ctx.rs_tokens(y_full)
    else:
        out = x_sp + y_me
    return (out, new_state) if return_state else out


def slstm_state_init(cfg, B: int, dtype=jnp.float32):
    d = cfg.d_model
    z = jnp.zeros((B, d), jnp.float32)
    return {"h": z, "c": z, "n": z, "m": jnp.full((B, d), -1e30, jnp.float32)}


def slstm_scan_flops(cfg, B: int, T: int) -> float:
    """Analytic recurrent FLOPs hidden inside the time scan (per layer)."""
    d, nh = cfg.d_model, cfg.n_heads
    dh = d // nh
    return 2.0 * B * T * nh * dh * 4 * dh
