"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

The recurrence is elementwise-diagonal, so it tensor-parallelizes perfectly:
all d_rnn channels shard over tp, the temporal scan is a fully-parallel
``lax.associative_scan`` per channel (counted exactly by HLO cost analysis),
and the only collectives are the standard SP all-gather / reduce-scatter.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import rms_norm
from repro.models.parallel import ParallelCtx
from repro.models.xlstm import causal_conv1d

C_COEF = 8.0


def rglru_scan(log_a: jax.Array, x: jax.Array) -> jax.Array:
    """h_t = a_t * h_{t-1} + x_t, elementwise.  (B, T, C) inputs."""
    def combine(p, q):
        la1, x1 = p
        la2, x2 = q
        return la1 + la2, jnp.exp(la2) * x1 + x2

    _, h = lax.associative_scan(combine, (log_a, x), axis=1)
    return h


def rglru_block(x_sp, p, meta, ctx: ParallelCtx, cfg, *,
                state: dict | None = None, decode: bool = False,
                return_state: bool = False):
    """x_sp: (B, T/tp, d) or (B, 1, d) decode."""
    eps = cfg.norm_eps
    h_in = rms_norm(x_sp, ctx.gather_w(p["ln"], meta["ln"].fsdp_dim), eps)
    hg = h_in if decode else ctx.ag_tokens(h_in)             # (B, T, d)
    B, T, _ = hg.shape

    w_x = ctx.gather_w(p["w_x"], meta["w_x"].fsdp_dim)       # (d, 2, dr/tp)
    u = jnp.einsum("btd,dgf->btgf", hg, w_x)
    y_gate = jax.nn.gelu(u[:, :, 0])                         # (B,T,dr/tp)
    x_br = u[:, :, 1]

    conv_w = ctx.gather_w(p["conv"], meta["conv"].fsdp_dim)  # (dr/tp, K)
    if decode:
        cx = state["conv"]
        xin = jnp.concatenate([cx, x_br], axis=1)
        xc = causal_conv1d(xin, conv_w)[:, -1:]
        new_conv = xin[:, 1:]
    else:
        xc = causal_conv1d(x_br, conv_w)

    w_rg = ctx.gather_w(p["w_rg"], meta["w_rg"].fsdp_dim)    # (d, 2, dr/tp)
    g = jnp.einsum("btd,dgf->btgf", hg, w_rg).astype(jnp.float32)
    r = jax.nn.sigmoid(g[:, :, 0])
    i = jax.nn.sigmoid(g[:, :, 1])
    lam = ctx.gather_w(p["lam"], meta["lam"].fsdp_dim).astype(jnp.float32)
    log_a = -C_COEF * jax.nn.softplus(lam) * r               # (B,T,dr/tp)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    gx = (beta * i * xc.astype(jnp.float32))

    if decode:
        h_prev = state["h"]                                  # (B, dr/tp)
        h_new = jnp.exp(log_a[:, 0]) * h_prev + gx[:, 0]
        h_seq = h_new[:, None]
        new_state = {"h": h_new, "conv": new_conv}
    else:
        h_seq = rglru_scan(log_a, gx)                        # (B,T,dr/tp)
        new_state = None
        if return_state:
            K = cfg.conv_kernel
            new_state = {"h": h_seq[:, -1],
                         "conv": x_br[:, -(K - 1):].astype(x_br.dtype)}

    o = (h_seq.astype(hg.dtype) * y_gate)
    w_out = ctx.gather_w(p["w_out"], meta["w_out"].fsdp_dim)  # (dr/tp, d)
    y = o @ w_out
    if decode:
        return x_sp + ctx.psum_tp(y), new_state
    out = x_sp + ctx.rs_tokens(y)
    return (out, new_state) if return_state else out


def rglru_state_init(cfg, B: int, ctx: ParallelCtx, dtype=jnp.float32):
    dr_loc = cfg.rnn_width // max(ctx.tp, 1)
    return {"h": jnp.zeros((B, dr_loc), jnp.float32),
            "conv": jnp.zeros((B, cfg.conv_kernel - 1, dr_loc), dtype)}
