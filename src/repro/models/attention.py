"""Attention: blocked online-softmax (flash-style, pure jnp) + parallel modes.

Two sharded modes (chosen per arch by head divisibility):
  * head_tp — q heads sharded over tp; x all-gathered, out reduce-scattered
              (Megatron-SP).  Requires H % tp == 0; kv heads are
              replicated-compute when kv % tp != 0 (GQA: kv tiny).
  * cp      — context parallel: tokens stay sequence-sharded; full KV is
              all-gathered (small for GQA); q-chunk attention is local.
              Works for ANY head count — the universal fallback.

Decode uses split-K: the KV cache is T-sharded over tp, each chip computes a
partial softmax over its chunk, merged with a logsumexp psum (FlashDecoding).

The KV-block scan body is counted once by HLO cost analysis; the roofline adds
the analytic attention-FLOP correction (``attn_flops``).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import rms_norm, rope
from repro.models.parallel import ParallelCtx

NEG = -1e30


def _kv_head_map(nq_local: int, q_head_offset, H: int, kv: int,
                 kv_head_offset=0):
    """kv-head index (local to the kv shard) for each local q head."""
    group = H // kv
    return (q_head_offset + jnp.arange(nq_local)) // group - kv_head_offset


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    q_offset=0, q_head_offset=0, kv_head_offset=0,
                    H: Optional[int] = None, kv_total: Optional[int] = None,
                    block: int = 1024, bf16_probs: bool = False) -> jax.Array:
    """q: (B, Tq, nq, hd); k, v: (B, Tkv, kv, hd) (full KV).

    ``q_offset``: global position of q[.., 0, ..] (sequence-parallel chunk);
    ``q_head_offset``: global head index of q head 0 (head-parallel shard).
    Online softmax over KV blocks — memory O(Tq * block).
    """
    B, Tq, nq, hd = q.shape
    Tkv, kv = k.shape[1], k.shape[2]
    H = H if H is not None else nq
    scale = 1.0 / math.sqrt(hd)
    block = min(block, Tkv)
    pad = (-Tkv) % block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_blocks = (Tkv + pad) // block

    kvmap = _kv_head_map(nq, q_head_offset, H, kv_total or kv,
                         kv_head_offset)                   # (nq,)
    qpos = q_offset + jnp.arange(Tq)                       # (Tq,)

    kb = k.reshape(B, n_blocks, block, kv, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, n_blocks, block, kv, hd).transpose(1, 0, 2, 3, 4)
    qf = (q * scale).astype(jnp.float32)

    def body(carry, inp):
        o, m, l = carry
        bidx, kblk, vblk = inp
        kpos = bidx * block + jnp.arange(block)            # (block,)
        kq = jnp.take(kblk, kvmap, axis=2)                 # (B, block, nq, hd)
        vq = jnp.take(vblk, kvmap, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kq.astype(jnp.float32))
        mask = kpos[None, :] < Tkv                         # padding
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= qpos[:, None] - kpos[None, :] < window
        s = jnp.where(mask[None, None], s, NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        if bf16_probs:
            # §Perf opt: the (bq, block)-sized probabilities move to the PV
            # matmul in bf16 (fp32 row stats m/l keep the softmax exact).
            pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(q.dtype),
                            vq.astype(q.dtype),
                            preferred_element_type=jnp.float32)
        else:
            pv = jnp.einsum("bhqk,bkhd->bhqd", p, vq.astype(jnp.float32))
        o_new = o * alpha[..., None] + pv
        return (o_new, m_new, l_new), None

    o0 = jnp.zeros((B, nq, Tq, hd), jnp.float32)
    m0 = jnp.full((B, nq, Tq), NEG, jnp.float32)
    l0 = jnp.zeros((B, nq, Tq), jnp.float32)
    (o, m, l), _ = lax.scan(body, (o0, m0, l0),
                            (jnp.arange(n_blocks), kb, vb))
    out = o / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)       # (B, Tq, nq, hd)


def attn_flops(B: int, Tq: int, Tkv: int, H: int, hd: int, *,
               causal: bool, window: Optional[int]) -> float:
    """Analytic matmul FLOPs of one attention call (QK^T + PV), global."""
    if window is not None:
        eff = min(window, Tkv)
        pairs = B * Tq * eff
    elif causal and Tq == Tkv:
        pairs = B * Tq * (Tq + 1) // 2
    else:
        pairs = B * Tq * Tkv
    return 4.0 * pairs * H * hd


# ---------------------------------------------------------------------------
# Train/prefill block
# ---------------------------------------------------------------------------

def attn_block(x_sp: jax.Array, p: dict, meta: dict, ctx: ParallelCtx, cfg, *,
               mode: str, window: Optional[int], t_offset: int = 0,
               return_kv: bool = False):
    """x_sp: (B, T/tp, d).  Returns new x_sp (and this layer's (k, v) local
    T-chunk when ``return_kv`` — used by prefill to build the cache)."""
    H, kv, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    eps = cfg.norm_eps
    B, T_loc, d = x_sp.shape
    h = rms_norm(x_sp, ctx.gather_w(p["ln"], meta["ln"].fsdp_dim), eps)

    wq = ctx.gather_w(p["wq"], meta["wq"].fsdp_dim)
    wkv = ctx.gather_w(p["wkv"], meta["wkv"].fsdp_dim)
    wo = ctx.gather_w(p["wo"], meta["wo"].fsdp_dim)

    if mode == "head_tp":
        hg = ctx.ag_tokens(h)                               # (B, T, d)
        T = hg.shape[1]
        q = (hg @ wq).reshape(B, T, H // ctx.tp, hd)
        kvp = jnp.einsum("btd,dgk->btgk", hg, wkv)
        kvp = kvp.reshape(B, T, 2, wkv.shape[-1] // hd, hd)
        q_off, q_hoff = 0, ctx.tp_rank * (H // ctx.tp)
    else:  # cp
        q = (h @ wq).reshape(B, T_loc, H, hd)
        kvp = jnp.einsum("btd,dgk->btgk", h, wkv)
        kvp = kvp.reshape(B, T_loc, 2, kv, hd)
        q_off, q_hoff = ctx.tp_rank * T_loc, 0
    k, v = kvp[:, :, 0], kvp[:, :, 1]

    if cfg.qk_norm:
        q = rms_norm(q, ctx.gather_w(p["q_norm"], meta["q_norm"].fsdp_dim),
                     eps)
        k = rms_norm(k, ctx.gather_w(p["k_norm"], meta["k_norm"].fsdp_dim),
                     eps)
    if cfg.pos == "rope":
        rdt = ctx.compute_dtype if ctx.has("bf16_rope") else None
        tq = t_offset + q_off + jnp.arange(q.shape[1])
        tk = t_offset + (jnp.arange(k.shape[1]) if mode == "head_tp"
                         else q_off + jnp.arange(T_loc))
        q = rope(q, tq, cfg.rope_theta, rdt)
        k = rope(k, tk, cfg.rope_theta, rdt)

    k_loc, v_loc = k, v  # this chip's T-chunk (cp) / full (head_tp)
    if mode == "cp":
        k = ctx.ag_tokens(k)                                # (B, T, kv, hd)
        v = ctx.ag_tokens(v)
        q_pos_off = t_offset + q_off
    else:
        q_pos_off = t_offset
    kv_local = k.shape[2]
    kv_hoff = ctx.tp_rank * kv_local if kv_local != kv else 0

    import functools as _ft
    attn_f = _ft.partial(flash_attention, causal=True, window=window,
                         q_offset=q_pos_off, q_head_offset=q_hoff,
                         kv_head_offset=kv_hoff, H=H, kv_total=kv,
                         bf16_probs=ctx.has("bf16_probs"))
    if ctx.has("remat_attn"):
        # §Perf opt: recompute attention in the bwd instead of saving the
        # per-block fp32 intermediates from the fwd residuals.
        attn_f = jax.checkpoint(attn_f)
    o = attn_f(q, k, v)
    o = o.reshape(o.shape[0], o.shape[1], -1)
    if mode == "head_tp":
        # output projection through the fused rs_tokens fast path: with the
        # "overlap" opt the SP reduce-scatter streams behind the matmul;
        # without it this is exactly rs_tokens(o @ wo)
        out = x_sp + ctx.matmul_rs(o, wo)
        if return_kv:
            # cache stores the T-sharded chunk: slice mine from full k, v
            k_loc = lax.dynamic_slice_in_dim(k, ctx.tp_rank * T_loc, T_loc, 1)
            v_loc = lax.dynamic_slice_in_dim(v, ctx.tp_rank * T_loc, T_loc, 1)
    else:
        out = x_sp + o @ wo
    if return_kv:
        return out, (k_loc, v_loc)
    return out


# ---------------------------------------------------------------------------
# Decode (split-K over the T-sharded cache)
# ---------------------------------------------------------------------------

def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     ctx: ParallelCtx, *, pos, H: int,
                     window: Optional[int] = None,
                     ring: bool = False) -> jax.Array:
    """q: (B, 1, H, hd) (all heads, replicated-compute);
    k/v_cache: (B, S/tp, kv, hd) local chunk.  ``pos``: current global
    position — a scalar shared by the batch, or a (B,) vector of per-slot
    positions (continuous batching over heterogeneous sequence lengths).
    ``ring``: cache is a ring buffer of size ``window`` (global kv index =
    pos - window + 1 .. pos, stored mod window)."""
    B, _, nH, hd = q.shape
    S_loc, kv = k_cache.shape[1], k_cache.shape[2]
    scale = 1.0 / math.sqrt(hd)
    base = ctx.tp_rank * S_loc
    slot = base + jnp.arange(S_loc)                         # local slots
    pos = jnp.asarray(pos)
    if pos.ndim == 1:                    # per-slot positions: (B, 1)
        pos = pos[:, None]               # broadcasts against slot (S_loc,)
    if ring:
        W = window
        # slot s holds global index: the largest g <= pos with g % W == s
        gidx = pos - ((pos - slot) % W)
        valid = (gidx >= 0) & (gidx <= pos) & (pos - gidx < W)
    else:
        gidx = slot
        valid = gidx <= pos
        if window is not None:
            valid &= (pos - gidx) < window

    kvmap = _kv_head_map(nH, 0, H, kv)
    kq = jnp.take(k_cache, kvmap, axis=2).astype(jnp.float32)
    vq = jnp.take(v_cache, kvmap, axis=2).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale, kq)
    mask = valid if valid.ndim == 2 else valid[None]        # (B | 1, S_loc)
    s = jnp.where(mask[:, None, None, :], s, NEG)
    m = jnp.max(s, axis=-1)                                 # (B, H, 1)
    M = ctx.pmax_tp(m)
    p = jnp.exp(s - M[..., None])
    l = ctx.psum_tp(jnp.sum(p, axis=-1))
    o = ctx.psum_tp(jnp.einsum("bhqk,bkhd->bhqd", p, vq))
    out = o / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)        # (B, 1, H, hd)


def cache_write(cache: jax.Array, new: jax.Array, ctx: ParallelCtx, *, pos,
                window: Optional[int] = None) -> jax.Array:
    """Write (B, 1, kv, hd) into the T-sharded (B, S/tp, kv, hd) cache at
    global position ``pos`` — a shared scalar or a (B,) vector of per-slot
    positions (ring-buffer when ``window``).  Every chip computes the same
    ``new``; only the owner's mask hits."""
    S_loc = cache.shape[1]
    pos = jnp.asarray(pos)
    gpos = pos % window if window is not None else pos
    owner = gpos // S_loc
    local = gpos - owner * S_loc
    if pos.ndim == 1:                    # per-slot positions: (B, S_loc)
        hit = jnp.arange(S_loc)[None, :] == local[:, None]
        if ctx.tp_axis:
            hit &= (ctx.tp_rank == owner)[:, None]
    else:
        hit = (jnp.arange(S_loc) == local) & (ctx.tp_rank == owner) \
            if ctx.tp_axis else (jnp.arange(S_loc) == local)
        hit = hit[None]
    return jnp.where(hit[:, :, None, None], new.astype(cache.dtype), cache)
