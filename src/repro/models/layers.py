"""Shared layer math: norms, positions, embeddings, FFN, streamed loss.

Everything is a pure function of (params, inputs, ctx) running inside a
shard_map body (or single-device when ctx.tp_axis is None).  Residual stream
is sequence-parallel: (B, T/tp, d) between blocks.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.parallel import ParallelCtx


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))
            ).astype(dt)


def activation(kind: str, gate: jax.Array, up: Optional[jax.Array]
               ) -> jax.Array:
    if kind == "gelu":
        return jax.nn.gelu(gate)
    fn = jax.nn.gelu if kind == "geglu" else jax.nn.silu
    return fn(gate) * up


def rope(x: jax.Array, positions: jax.Array, theta: float,
         compute_dtype=None) -> jax.Array:
    """x: (..., T, n, hd); positions: (T,) global token positions.

    ``compute_dtype``: rotate in this dtype (bf16_rope opt) — the angle
    tables stay fp32, only the (B,T,n,hd)-sized products narrow."""
    hd = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # (T, hd/2)
    dt = compute_dtype or jnp.float32
    cos = jnp.cos(ang)[None, :, None, :].astype(dt)
    sin = jnp.sin(ang)[None, :, None, :].astype(dt)
    x1, x2 = jnp.split(x.astype(dt), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def rope_decode(x: jax.Array, pos, theta: float,
                compute_dtype=None) -> jax.Array:
    """Decode-step rope: x is (B, 1, n, hd); ``pos`` is a position scalar
    shared by the batch, or a (B,) vector of per-slot positions (continuous
    batching).  The scalar path matches ``rope(x, pos[None], ...)``."""
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        return rope(x, pos[None], theta, compute_dtype)
    hd = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = pos.astype(jnp.float32)[:, None] * freqs[None, :]     # (B, hd/2)
    dt = compute_dtype or jnp.float32
    cos = jnp.cos(ang)[:, None, None, :].astype(dt)
    sin = jnp.sin(ang)[:, None, None, :].astype(dt)
    x1, x2 = jnp.split(x.astype(dt), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def sinusoidal_pe(positions: jax.Array, d: int) -> jax.Array:
    """(T,) -> (T, d) classic transformer PE."""
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Vocab-parallel embedding / unembedding
# ---------------------------------------------------------------------------

def embed(ids: jax.Array, emb: jax.Array, ctx: ParallelCtx, *,
          sp: bool = False) -> jax.Array:
    """Vocab-parallel lookup.  emb: local (V/tp, d) vocab shard.

    ``sp=True``: ids are the FULL (B, T) sequence; every rank looks all
    tokens up in its vocab shard and the partials are reduce-SCATTERED over
    the token dim, yielding the (B, T/tp, d) sequence-parallel stream (one
    collective, each rank keeps its own chunk — summing full partials with a
    plain psum would mix different ranks' token chunks).
    ``sp=False`` (decode): ids are replicated; partials are psum'd.
    """
    v_loc = emb.shape[0]
    off = ctx.tp_rank * v_loc
    local = ids - off
    valid = (local >= 0) & (local < v_loc)
    local = jnp.clip(local, 0, v_loc - 1)
    out = (jnp.take(emb, local, axis=0)
           * valid[..., None]).astype(ctx.compute_dtype)
    if sp:
        return ctx.rs_tokens(out)
    return ctx.psum_tp(out)


def unembed_xent(x_sp: jax.Array, labels: jax.Array, mask: jax.Array,
                 unemb: jax.Array, ctx: ParallelCtx, *,
                 chunk: int = 512, softcap: Optional[float] = None
                 ) -> tuple[jax.Array, jax.Array]:
    """Streamed vocab-parallel cross-entropy (Megatron-SP layout).

    x_sp: (B, T/tp, d) SP activations; labels/mask: FULL (B, T);
    unemb: local (d, V/tp).  x is gathered to full T first so the vocab
    psums (max / sum-exp / correct-logit) combine the SAME tokens on every
    tp rank; the resulting nll is tp-replicated, so the sums are divided by
    tp — the caller's flat psum over (tp, dp) is then exact.  Logits are
    never materialized beyond (B, chunk, V/tp).
    NOTE: the chunk scan body is counted once by HLO cost analysis; the
    roofline adds the analytic 2*B*T*d*V correction (see analysis/roofline).
    """
    B, _, d = x_sp.shape
    xg = ctx.ag_tokens(x_sp)                               # (B, T, d)
    T = xg.shape[1]
    v_loc = unemb.shape[1]
    off = ctx.tp_rank * v_loc
    chunk = min(chunk, T)
    n_chunks = T // chunk
    rem = T - n_chunks * chunk

    def chunk_loss(xc, lc, mc):
        # bf16_xent opt: every (B, chunk, V/tp)-sized array stays narrow;
        # reductions accumulate fp32 (sum dtype), stats are per-row scalars.
        ldt = ctx.compute_dtype if ctx.has("bf16_xent") else jnp.float32
        logits = xc.astype(ldt) @ unemb.astype(ldt)
        if softcap:
            logits = softcap * jnp.tanh(logits / softcap)
        # stabilizer only — gradients flow through se (exact softmax grad)
        mx = lax.stop_gradient(ctx.pmax_tp(
            jnp.max(logits, axis=-1).astype(jnp.float32)))
        p = jnp.exp(logits - mx[..., None].astype(ldt))
        se = ctx.psum_tp(jnp.sum(p, axis=-1, dtype=jnp.float32))
        lse = mx + jnp.log(se)
        lloc = lc - off
        ok = (lloc >= 0) & (lloc < v_loc)
        lloc = jnp.clip(lloc, 0, v_loc - 1)
        corr = ctx.psum_tp(
            (jnp.take_along_axis(logits, lloc[..., None], axis=-1)[..., 0]
             * ok).astype(jnp.float32))
        nll = (lse - corr) * mc
        return jnp.sum(nll), jnp.sum(mc)

    total, count = jnp.float32(0.0), jnp.float32(0.0)
    if n_chunks:
        xs = xg[:, :n_chunks * chunk].reshape(B, n_chunks, chunk, d)
        ls = labels[:, :n_chunks * chunk].reshape(B, n_chunks, chunk)
        ms = mask[:, :n_chunks * chunk].reshape(B, n_chunks, chunk)

        def body(carry, inp):
            xc, lc, mc = inp
            s, c = chunk_loss(xc, lc, mc)
            return (carry[0] + s, carry[1] + c), None

        (total, count), _ = lax.scan(
            body, (total, count),
            (xs.swapaxes(0, 1), ls.swapaxes(0, 1), ms.swapaxes(0, 1)))
    if rem:
        s, c = chunk_loss(xg[:, n_chunks * chunk:],
                          labels[:, n_chunks * chunk:],
                          mask[:, n_chunks * chunk:])
        total, count = total + s, count + c
    return total / ctx.tp, count / ctx.tp


def decode_logits(x: jax.Array, unemb: jax.Array, ctx: ParallelCtx, *,
                  softcap: Optional[float] = None) -> jax.Array:
    """x: (B, 1, d) -> full-vocab logits (B, 1, V) (gathered over tp)."""
    logits = x.astype(jnp.float32) @ unemb.astype(jnp.float32)
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    if ctx.tp_axis:
        logits = lax.all_gather(  # raw-collective: flat tp fast path
            logits, ctx.tp_axis, axis=-1, tiled=True)
    return logits


# ---------------------------------------------------------------------------
# Dense FFN (Megatron-SP: AG tokens -> col/row parallel -> RS tokens)
# ---------------------------------------------------------------------------

def ffn(x_sp: jax.Array, p: dict, meta: dict, ctx: ParallelCtx, *,
        act: str, eps: float) -> jax.Array:
    # issue every window read up front (issue-early discipline: the weight
    # gathers are independent of the token math, so XLA is free to overlap
    # them with the norm/SP-gather below — same values, earlier issue)
    w_ln = ctx.gather_w(p["ln"], meta["ln"].fsdp_dim)
    # w_in: (d, g, dff) with g in {1 (gelu), 2 (gated)}; tp shards dff so the
    # gate/up halves stay aligned under contiguous sharding.
    w_in = ctx.gather_w(p["w_in"], meta["w_in"].fsdp_dim)  # (d, g, dff/tp)
    h = rms_norm(x_sp, w_ln, eps)
    hg = ctx.ag_tokens(h)                                  # (B, T, d)
    u = jnp.einsum("btd,dgf->btgf", hg, w_in)
    if act == "gelu":
        a = activation(act, u[:, :, 0], None)
    else:
        a = activation(act, u[:, :, 0], u[:, :, 1])
    # down-projection through the fused gather_w fast path: with the
    # "overlap" opt the FSDP window read streams behind the panel matmuls;
    # without it this is exactly a @ gather_w(w_out)  (w_out: (dff/tp, d))
    y = ctx.ag_matmul(a, p["w_out"], meta["w_out"].fsdp_dim)
    return x_sp + ctx.rs_tokens(y)


def ffn_decode(x: jax.Array, p: dict, meta: dict, ctx: ParallelCtx, *,
               act: str, eps: float) -> jax.Array:
    """Decode-shape FFN: 1 token, no SP AG (token replicated over tp);
    col/row parallel with a single psum."""
    w_ln = ctx.gather_w(p["ln"], meta["ln"].fsdp_dim)
    w_in = ctx.gather_w(p["w_in"], meta["w_in"].fsdp_dim)
    h = rms_norm(x, w_ln, eps)
    u = jnp.einsum("btd,dgf->btgf", h, w_in)
    if act == "gelu":
        a = activation(act, u[:, :, 0], None)
    else:
        a = activation(act, u[:, :, 0], u[:, :, 1])
    w_out = ctx.gather_w(p["w_out"], meta["w_out"].fsdp_dim)
    return x + ctx.psum_tp(a @ w_out)
