"""Parameter metadata: global shapes, TP dims, FSDP dims, init rules.

Every param leaf carries a ``PMeta``.  The same tree drives:
  * host-side init (smoke tests, examples),
  * ShapeDtypeStruct construction (dry-run),
  * PartitionSpec construction per (mode, mesh),
  * the gather-at-use calls inside the model (``fsdp_dim``).

Sharding policy (DESIGN.md §5):
  * ``tp_dim``  — sharded over the "model" axis (TP/EP); identical in naive
    and hier modes (the paper keeps computational parallelism unchanged).
  * ``fsdp_dim`` — hier mode only: the dim sharded over "data" — the pod's
    MPI-3 shared window; gathered at use by ``ParallelCtx.gather_w``.
  * ``data_dim`` — serve-only sharded *storage* (expert dff slices): never
    gathered; the compute is written against the local slice.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class PMeta:
    shape: tuple[int, ...]
    tp_dim: Optional[int] = None
    fsdp_dim: Optional[int] = None
    data_dim: Optional[int] = None
    init: str = "normal"           # normal | out | zeros | ones | lam
    dtype: jnp.dtype = jnp.float32


def _resolve_fsdp(meta: PMeta, data: int, mode: str, serve: bool,
                  force: bool = False) -> PMeta:
    """Pick the FSDP dim: largest dim divisible by the data-axis size,
    excluding tp/data dims.  Serve: only when explicitly requested upstream
    (meta.fsdp_dim == -2 sentinel, or ``force`` — the ``serve_fsdp`` opt
    keeping serve weights in the pod-shared one-copy-per-node store)."""
    if mode != "hier" or data <= 1:
        meta.fsdp_dim = None
        return meta
    if serve and not force and meta.fsdp_dim != -2:
        meta.fsdp_dim = None
        return meta
    best, best_size = None, 0
    for dim, s in enumerate(meta.shape):
        if dim == meta.tp_dim or dim == meta.data_dim:
            continue
        if s % data == 0 and s // data >= 1 and s > best_size:
            best, best_size = dim, s
    meta.fsdp_dim = best
    return meta


def attn_mode_for(cfg: ModelConfig, tp: int) -> str:
    return "head_tp" if cfg.n_heads % tp == 0 else "cp"


def decode2d_groups(cfg: ModelConfig, tp: int):
    """(g_h, g_s) factorization of the tp axis for 2D decode attention:
    g_h head groups (must divide H and kv) x g_s seq groups.  None if the
    arch can't use it (g_h would be 1)."""
    g_h = math.gcd(math.gcd(cfg.n_heads, cfg.n_kv), tp)
    if g_h <= 1 or tp % g_h:
        return None
    return g_h, tp // g_h


# ---------------------------------------------------------------------------
# Per-block param/meta definitions
# ---------------------------------------------------------------------------

def attn_defs(cfg: ModelConfig, tp: int, serve: bool,
              opts=frozenset()) -> dict[str, PMeta]:
    d, H, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    mode = attn_mode_for(cfg, tp)
    d2d = decode2d_groups(cfg, tp) if ("decode2d" in opts and serve) else None
    if serve and d2d:
        # 2D decode: head-group-sharded weights, duplicated over the seq
        # subgroups (storage x g_s for attn; no per-step gather at all).
        g_h, g_s = d2d
        out = {
            "ln": PMeta((d,), init="zeros"),
            "wq": PMeta((tp, d, H * hd // g_h), tp_dim=0),
            "wkv": PMeta((tp, d, 2, kv * hd // g_h), tp_dim=0),
            "wo": PMeta((tp, H * hd // g_h, d), tp_dim=0, init="out"),
        }
        if cfg.qk_norm:
            out["q_norm"] = PMeta((hd,), init="zeros")
            out["k_norm"] = PMeta((hd,), init="zeros")
        return out
    if serve:
        q_tp = kv_tp = None
        o_tp = None
    else:
        q_tp = 1 if mode == "head_tp" else None
        kv_tp = 2 if (mode == "head_tp" and kv % tp == 0) else None
        o_tp = 0 if mode == "head_tp" else None
    out = {
        "ln": PMeta((d,), init="zeros"),
        "wq": PMeta((d, H * hd), tp_dim=q_tp),
        "wkv": PMeta((d, 2, kv * hd), tp_dim=kv_tp),
        "wo": PMeta((H * hd, d), tp_dim=o_tp, init="out"),
    }
    if cfg.qk_norm:
        out["q_norm"] = PMeta((hd,), init="zeros")
        out["k_norm"] = PMeta((hd,), init="zeros")
    if serve and _attn_bytes(cfg) > 4e9:
        # big-attn serve (qwen3-moe): keep the paper's one-copy-per-pod store
        for k in ("wq", "wkv", "wo"):
            out[k].fsdp_dim = -2  # sentinel: resolve even in serve mode
    return out


def _attn_bytes(cfg: ModelConfig) -> float:
    d, H, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    per = d * (H + 2 * kv) * hd + H * hd * d
    n_attn = sum(1 for k in cfg.block_kinds if k in ("attn", "local"))
    return 2.0 * per * n_attn


def ffn_defs(cfg: ModelConfig, tp: int) -> dict[str, PMeta]:
    d, dff = cfg.d_model, cfg.d_ff
    g = 1 if cfg.act == "gelu" else 2
    return {
        "ln": PMeta((d,), init="zeros"),
        "w_in": PMeta((d, g, dff), tp_dim=2),
        "w_out": PMeta((dff, d), tp_dim=0, init="out"),
    }


def moe_defs(cfg: ModelConfig, tp: int, serve: bool) -> dict[str, PMeta]:
    d = cfg.d_model
    spec = cfg.moe
    ep, tp_ff = spec.ep_tp(tp)
    e_loc = spec.num_experts // ep
    n_ff = spec.d_ff_expert // tp_ff
    return {
        "ln": PMeta((d,), init="zeros"),
        "router": PMeta((d, spec.num_experts)),
        "w_in": PMeta((tp, e_loc, d, 2, n_ff), tp_dim=0,
                      data_dim=4 if serve else None),
        "w_out": PMeta((tp, e_loc, n_ff, d), tp_dim=0, init="out",
                       data_dim=2 if serve else None),
    }


def mlstm_defs(cfg: ModelConfig, tp: int) -> dict[str, PMeta]:
    d, din, nh = cfg.d_model, cfg.d_inner, cfg.n_heads
    hd = din // nh
    return {
        "ln": PMeta((d,), init="zeros"),
        "w_up": PMeta((d, 2, din), tp_dim=2),
        "conv": PMeta((din, cfg.conv_kernel), tp_dim=0),
        "wq": PMeta((nh, hd, hd)),
        "wk": PMeta((nh, hd, hd)),
        "wv": PMeta((nh, hd, hd)),
        "wif": PMeta((nh, hd, 2)),
        "w_down": PMeta((din, d), tp_dim=0, init="out"),
    }


def slstm_defs(cfg: ModelConfig, tp: int) -> dict[str, PMeta]:
    d, nh = cfg.d_model, cfg.n_heads
    dh = d // nh
    return {
        "ln": PMeta((d,), init="zeros"),
        "w_x": PMeta((d, 4, d)),
        "r": PMeta((nh, dh, 4, dh)),
        "b": PMeta((4, d), init="zeros"),
        "w_out": PMeta((d, d), init="out"),
    }


def rglru_defs(cfg: ModelConfig, tp: int) -> dict[str, PMeta]:
    d, dr = cfg.d_model, cfg.rnn_width
    return {
        "ln": PMeta((d,), init="zeros"),
        "w_x": PMeta((d, 2, dr), tp_dim=2),
        "conv": PMeta((dr, cfg.conv_kernel), tp_dim=0),
        "w_rg": PMeta((d, 2, dr), tp_dim=2),
        "lam": PMeta((dr,), tp_dim=0, init="lam"),
        "w_out": PMeta((dr, d), tp_dim=0, init="out"),
    }


def block_defs(kind: str, cfg: ModelConfig, tp: int, serve: bool,
               opts=frozenset()) -> dict:
    if kind in ("attn", "local"):
        out = {"attn": attn_defs(cfg, tp, serve, opts)}
        if cfg.moe:
            out["moe"] = moe_defs(cfg, tp, serve)
        elif cfg.d_ff:
            out["ffn"] = ffn_defs(cfg, tp)
        return out
    if kind == "mlstm":
        return {"mlstm": mlstm_defs(cfg, tp)}
    if kind == "slstm":
        return {"slstm": slstm_defs(cfg, tp)}
    if kind == "rglru":
        out = {"rglru": rglru_defs(cfg, tp)}
        if cfg.moe:
            out["moe"] = moe_defs(cfg, tp, serve)
        elif cfg.d_ff:
            out["ffn"] = ffn_defs(cfg, tp)
        return out
    raise ValueError(kind)


def model_defs(cfg: ModelConfig, tp: int, data: int, mode: str,
               serve: bool = False, opts=frozenset()) -> dict:
    """Full meta tree.  'units' metas describe PER-LAYER shapes (they get a
    stacked leading dim at materialization)."""
    d = cfg.d_model
    defs: dict = {
        "embed": PMeta((cfg.vocab_padded, d), tp_dim=0),
        "final_ln": PMeta((d,), init="zeros"),
    }
    if not cfg.tie_embeddings:
        defs["unembed"] = PMeta((d, cfg.vocab_padded), tp_dim=1)
    if cfg.frontend:
        defs["frontend"] = PMeta((cfg.d_frontend, d))
    defs["units"] = {f"b{i}": block_defs(k, cfg, tp, serve, opts)
                     for i, k in enumerate(cfg.pattern)}
    if cfg.remainder_kinds:
        defs["rem"] = {f"r{i}": block_defs(k, cfg, tp, serve, opts)
                       for i, k in enumerate(cfg.remainder_kinds)}
    force = serve and "serve_fsdp" in opts
    return jax.tree.map(
        lambda m: _resolve_fsdp(m, data, mode, serve, force), defs,
        is_leaf=lambda x: isinstance(x, PMeta))


# ---------------------------------------------------------------------------
# Materialization: init / abstract shapes / PartitionSpecs
# ---------------------------------------------------------------------------

def _stacked_shape(meta: PMeta, stacked: Optional[int]) -> tuple[int, ...]:
    return ((stacked,) + meta.shape) if stacked else meta.shape


def init_leaf(meta: PMeta, key, n_layers: int, stacked: Optional[int]
              ) -> jax.Array:
    shape = _stacked_shape(meta, stacked)
    if meta.init == "zeros":
        return jnp.zeros(shape, meta.dtype)
    if meta.init == "ones":
        return jnp.ones(shape, meta.dtype)
    if meta.init == "lam":
        # RG-LRU: target a in [0.9, 0.999] at r=1 -> softplus(lam) = -log(a)/C
        a = np.linspace(0.9, 0.999, meta.shape[-1])
        lam = np.log(np.expm1(np.maximum(-np.log(a) / 8.0, 1e-8)))
        out = np.broadcast_to(lam, shape).astype(np.float32)
        return jnp.asarray(out)
    scale = 0.02
    if meta.init == "out":
        scale = 0.02 / math.sqrt(2.0 * max(n_layers, 1))
    return (jax.random.normal(key, shape, meta.dtype) * scale)


def init_params(defs: dict, cfg: ModelConfig, seed: int = 0) -> dict:
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, PMeta))
    keys = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
    paths = jax.tree_util.tree_leaves_with_path(
        defs, is_leaf=lambda x: isinstance(x, PMeta))

    def depth_of(path) -> Optional[int]:
        return cfg.n_units if (path and getattr(path[0], "key", None)
                               == "units") else None

    out = [init_leaf(m, k, cfg.n_layers, depth_of(p))
           for (p, m), k in zip(paths, keys)]
    return jax.tree.unflatten(treedef, out)


def abstract_params(defs: dict, cfg: ModelConfig, specs: dict) -> dict:
    """ShapeDtypeStructs with shardings attached (dry-run input)."""
    def mk(path, meta, spec):
        stacked = cfg.n_units if (path and getattr(path[0], "key", None)
                                  == "units") else None
        return jax.ShapeDtypeStruct(_stacked_shape(meta, stacked), meta.dtype,
                                    sharding=spec)
    paths = jax.tree_util.tree_leaves_with_path(
        defs, is_leaf=lambda x: isinstance(x, PMeta))
    spec_leaves = jax.tree.leaves(specs,
                                  is_leaf=lambda x: isinstance(x, P))
    treedef = jax.tree.structure(defs,
                                 is_leaf=lambda x: isinstance(x, PMeta))
    return jax.tree.unflatten(
        treedef, [mk(p, m, s) for (p, m), s in zip(paths, spec_leaves)])


def param_specs(defs: dict, cfg: ModelConfig, *, tp_axis: Optional[str],
                fsdp_axis: Optional[str]) -> dict:
    """PartitionSpec tree (stacked dims accounted for)."""
    def mk(path, meta: PMeta):
        stacked = bool(path and getattr(path[0], "key", None) == "units")
        off = 1 if stacked else 0
        ndim = len(meta.shape) + off
        spec = [None] * ndim
        if meta.tp_dim is not None and tp_axis:
            spec[meta.tp_dim + off] = tp_axis
        if meta.fsdp_dim is not None and fsdp_axis:
            spec[meta.fsdp_dim + off] = fsdp_axis
        if meta.data_dim is not None and fsdp_axis:
            spec[meta.data_dim + off] = fsdp_axis
        return P(*spec)

    paths = jax.tree_util.tree_leaves_with_path(
        defs, is_leaf=lambda x: isinstance(x, PMeta))
    treedef = jax.tree.structure(defs,
                                 is_leaf=lambda x: isinstance(x, PMeta))
    return jax.tree.unflatten(treedef, [mk(p, m) for p, m in paths])


def relayout_attn_decode2d(w, cfg: ModelConfig, tp: int, kind: str):
    """Re-layout a baseline attention weight into the decode2d storage:
    entry[r] = the head-group slice for chip r (duplicated over the g_s seq
    chips of each head group).  kind: wq (d, H*hd) | wkv (d, 2, kv*hd) |
    wo (H*hd, d)."""
    import numpy as np
    g = decode2d_groups(cfg, tp)
    assert g, "arch has no decode2d factorization"
    g_h, g_s = g
    hd = cfg.head_dim
    out = []
    for r in range(tp):
        hg = r // g_s
        if kind == "wq":
            ncol = cfg.n_heads * hd // g_h
            out.append(w[:, hg * ncol:(hg + 1) * ncol])
        elif kind == "wkv":
            ncol = cfg.n_kv * hd // g_h
            out.append(w[:, :, hg * ncol:(hg + 1) * ncol])
        elif kind == "wo":
            nrow = cfg.n_heads * hd // g_h
            out.append(w[hg * nrow:(hg + 1) * nrow, :])
        else:
            raise ValueError(kind)
    return np.stack([np.asarray(x) for x in out])
