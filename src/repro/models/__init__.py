from repro.models.model_zoo import Model, ParallelCtx, build, build_by_name, make_batch

__all__ = ["Model", "ParallelCtx", "build", "build_by_name", "make_batch"]
