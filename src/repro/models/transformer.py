"""Decoder assembly: pattern-unit scan, train/prefill/decode entry points.

The model is a stack of *pattern units* (cfg.pattern repeated cfg.n_units
times, plus an unrolled remainder).  Unit params are stacked on a leading dim
and the stack is traversed with ``lax.scan`` (+ jax.checkpoint remat), so
compiles stay fast at 94 layers; the roofline corrects loop-body FLOP
undercounts via unroll-extrapolation + the analytic notes in ``cost_notes``.

All functions are shard_map bodies: arrays are LOCAL shards, collective
semantics live in ParallelCtx / the block implementations.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import meta as M
from repro.models.attention import (attn_block, attn_flops, cache_write,
                                    decode_attention)
from repro.models.layers import (decode_logits, embed, ffn, ffn_decode,
                                 rms_norm, sinusoidal_pe, unembed_xent)
from repro.models.moe import moe_block
from repro.models.parallel import (ParallelCtx, ParamGroup, prefetch_walk,
                                   tp_slice)
from repro.models.rglru import rglru_block, rglru_state_init
from repro.models.xlstm import (mlstm_block, mlstm_state_init, slstm_block,
                                slstm_scan_flops, slstm_state_init)

KV_BLOCK = 1024   # flash attention KV block (roofline notes depend on it)
XENT_CHUNK = 512
MLSTM_CHUNK = 128


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    ctx: ParallelCtx
    defs: Any            # PMeta tree (train) — serve variants built on demand
    serve_defs: Any

    # ---- params ------------------------------------------------------------
    def init_params(self, seed: int = 0):
        return M.init_params(self.defs, self.cfg, seed)

    def param_specs(self, *, serve: bool = False, tp_axis="model",
                    fsdp_axis="data"):
        defs = self.serve_defs if serve else self.defs
        return M.param_specs(defs, self.cfg, tp_axis=tp_axis,
                             fsdp_axis=fsdp_axis)

    def abstract_params(self, specs, *, serve: bool = False):
        defs = self.serve_defs if serve else self.defs
        return M.abstract_params(defs, self.cfg, specs)

    # ---- entry points (shard_map bodies) ------------------------------------
    def loss_fn(self, params, batch):
        return _loss(self.cfg, self.ctx, self.defs, params, batch)

    def prefill_fn(self, params, batch, s_max: int, *, unroll: int = 1):
        # prefill is big-token work: it runs in the TRAIN parallel layout
        return _prefill(self.cfg, self.ctx, self.defs, params, batch, s_max,
                        unroll=unroll)

    def decode_fn(self, params, cache, token, pos, *, unroll: int = 1):
        return _decode(self.cfg, self.ctx, self.serve_defs, params, cache,
                       token, pos, unroll=unroll)

    def cache_init(self, B_loc: int, s_max: int):
        return _cache_init(self.cfg, self.ctx, B_loc, s_max)

    def cost_notes(self, *, kind: str, B: int, T: int) -> dict[str, float]:
        return _cost_notes(self.cfg, kind=kind, B=B, T=T)


def build(cfg: ModelConfig, ctx: ParallelCtx, data: int = 1) -> Model:
    defs = M.model_defs(cfg, ctx.tp, data, ctx.mode, serve=False,
                        opts=ctx.opts)
    serve_defs = M.model_defs(cfg, ctx.tp, data, ctx.mode, serve=True,
                              opts=ctx.opts)
    return Model(cfg, ctx, defs, serve_defs)


# ---------------------------------------------------------------------------
# Blocks dispatch
# ---------------------------------------------------------------------------

def _mix(kind: str, x, p, mt, ctx, cfg, *, serve=False):
    """Channel-mixing half of attn/local/rglru blocks."""
    if cfg.moe:
        return moe_block(x, p["moe"], mt["moe"], ctx, cfg, serve=serve)
    if not cfg.d_ff:
        return x
    f = ffn_decode if serve else ffn
    return f(x, p["ffn"], mt["ffn"], ctx, act=cfg.act, eps=cfg.norm_eps)


def _block_train(kind: str, x, p, mt, ctx, cfg, *, return_state=False):
    if kind in ("attn", "local"):
        window = cfg.window if kind == "local" else None
        mode = M.attn_mode_for(cfg, ctx.tp)
        if return_state:
            x, kv = attn_block(x, p["attn"], mt["attn"], ctx, cfg, mode=mode,
                               window=window, return_kv=True)
        else:
            x = attn_block(x, p["attn"], mt["attn"], ctx, cfg, mode=mode,
                           window=window)
        x = _mix(kind, x, p, mt, ctx, cfg)
        return (x, {"k": kv[0], "v": kv[1]}) if return_state else x
    if kind == "mlstm":
        chunk = MLSTM_CHUNK
        for o in ctx.opts:   # §Perf knob: --opts mchunk=256
            if o.startswith("mchunk="):
                chunk = int(o[7:])
        out = mlstm_block(x, p["mlstm"], mt["mlstm"], ctx, cfg,
                          chunk=chunk, return_state=return_state)
        return out
    if kind == "slstm":
        out = slstm_block(x, p["slstm"], mt["slstm"], ctx, cfg,
                          return_state=return_state)
        return out
    if kind == "rglru":
        out = rglru_block(x, p["rglru"], mt["rglru"], ctx, cfg,
                          return_state=return_state)
        if return_state:
            x, st = out
            x = _mix(kind, x, p, mt, ctx, cfg)
            return x, st
        x = _mix(kind, out, p, mt, ctx, cfg)
        return x
    raise ValueError(kind)


def _decode_attn_2d(x, p, mt, state, ctx, cfg, *, pos, window):
    """2D decode attention (EXPERIMENTS.md §Perf): the tp axis is factored
    into g_h head groups x g_s seq groups.  Attention weights stay sharded
    by head group (no per-step FSDP gather); the cache chunk is S/g_s per
    chip; partial softmax merges within the head group's g_s chips."""
    import math as _math
    from repro.models.attention import _kv_head_map
    if jnp.ndim(pos) != 0:
        raise ValueError("decode2d decode attention needs a scalar pos; "
                         "per-slot position vectors (continuous batching) "
                         "are only supported on the 1D decode path")
    g_h, g_s = M.decode2d_groups(cfg, ctx.tp)
    H, kv, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    Hg, kvg = H // g_h, kv // g_h
    ring = window is not None
    eps = cfg.norm_eps
    B = x.shape[0]

    h = rms_norm(x, ctx.gather_w(p["attn"]["ln"],
                                 mt["attn"]["ln"].fsdp_dim), eps)
    wq = p["attn"]["wq"][0].astype(ctx.compute_dtype)   # (d, Hg*hd)
    wkv = p["attn"]["wkv"][0].astype(ctx.compute_dtype)  # (d, 2, kvg*hd)
    wo = p["attn"]["wo"][0].astype(ctx.compute_dtype)   # (Hg*hd, d)
    q = (h @ wq).reshape(B, 1, Hg, hd)
    kvp = jnp.einsum("btd,dgk->btgk", h, wkv).reshape(B, 1, 2, kvg, hd)
    k_new, v_new = kvp[:, :, 0], kvp[:, :, 1]
    if cfg.qk_norm:
        q = rms_norm(q, ctx.gather_w(p["attn"]["q_norm"],
                                     mt["attn"]["q_norm"].fsdp_dim), eps)
        k_new = rms_norm(k_new, ctx.gather_w(
            p["attn"]["k_norm"], mt["attn"]["k_norm"].fsdp_dim), eps)
    if cfg.pos == "rope":
        from repro.models.layers import rope
        rdt = ctx.compute_dtype if ctx.has("bf16_rope") else None
        pos_arr = jnp.full((1,), pos)
        q = rope(q, pos_arr, cfg.rope_theta, rdt)
        k_new = rope(k_new, pos_arr, cfg.rope_theta, rdt)

    # cache write: slot owner within my head group's seq chips
    kc_, vc_ = state["k"], state["v"]                   # (B, S/g_s, kvg, hd)
    S_loc = kc_.shape[1]
    gpos = pos % window if window is not None else pos
    s_idx = ctx.tp_rank % g_s
    owner = gpos // S_loc
    local = gpos - owner * S_loc
    hit = (jnp.arange(S_loc) == local) & (s_idx == owner)
    kc_ = jnp.where(hit[None, :, None, None], k_new.astype(kc_.dtype), kc_)
    vc_ = jnp.where(hit[None, :, None, None], v_new.astype(vc_.dtype), vc_)

    # partial attention over my S/g_s chunk
    base = s_idx * S_loc
    slot = base + jnp.arange(S_loc)
    if ring:
        W = window
        gidx = pos - ((pos - slot) % W)
        valid = (gidx >= 0) & (gidx <= pos) & (pos - gidx < W)
    else:
        valid = slot <= pos
    kvmap = _kv_head_map(Hg, 0, Hg, kvg)
    kq = jnp.take(kc_, kvmap, axis=2).astype(jnp.float32)
    vq = jnp.take(vc_, kvmap, axis=2).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk",
                   q.astype(jnp.float32) / _math.sqrt(hd), kq)
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    m_loc = jnp.max(s, axis=-1)
    mg = ctx.group_all_gather(m_loc[None], group=g_s, dim=0)
    m_all = jnp.max(mg, axis=0)
    pexp = jnp.exp(s - m_all[..., None])
    l = ctx.group_psum(jnp.sum(pexp, axis=-1), group=g_s)
    o = ctx.group_psum(jnp.einsum("bhqk,bkhd->bhqd", pexp, vq), group=g_s)
    o = (o / jnp.maximum(l[..., None], 1e-30)).transpose(0, 2, 1, 3)
    # out proj on my head group; only the seq-primary contributes to the
    # cross-head-group psum (others are duplicates)
    y = (o.reshape(B, 1, Hg * hd).astype(ctx.compute_dtype) @ wo)
    y = jnp.where(s_idx == 0, y, jnp.zeros_like(y))
    y = ctx.psum_tp(y)
    x = x + y
    return x, {"k": kc_, "v": vc_}


def _block_decode(kind: str, x, p, mt, state, ctx, cfg, *, pos):
    if kind in ("attn", "local"):
        window = cfg.window if kind == "local" else None
        if ctx.has("decode2d") and ctx.tp_axis \
                and M.decode2d_groups(cfg, ctx.tp):
            x, st = _decode_attn_2d(x, p, mt, state, ctx, cfg, pos=pos,
                                    window=window)
            x = _mix(kind, x, p, mt, ctx, cfg, serve=True)
            return x, st
        ring = window is not None
        H, kv, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
        h = rms_norm(x, ctx.gather_w(p["attn"]["ln"],
                                     mt["attn"]["ln"].fsdp_dim), cfg.norm_eps)
        wq = ctx.gather_w(p["attn"]["wq"], mt["attn"]["wq"].fsdp_dim)
        wkv = ctx.gather_w(p["attn"]["wkv"], mt["attn"]["wkv"].fsdp_dim)
        wo = ctx.gather_w(p["attn"]["wo"], mt["attn"]["wo"].fsdp_dim)
        B = x.shape[0]
        q = (h @ wq).reshape(B, 1, H, hd)
        kvp = jnp.einsum("btd,dgk->btgk", h, wkv).reshape(B, 1, 2, kv, hd)
        k_new, v_new = kvp[:, :, 0], kvp[:, :, 1]
        if cfg.qk_norm:
            q = rms_norm(q, ctx.gather_w(p["attn"]["q_norm"],
                                         mt["attn"]["q_norm"].fsdp_dim),
                         cfg.norm_eps)
            k_new = rms_norm(k_new, ctx.gather_w(
                p["attn"]["k_norm"], mt["attn"]["k_norm"].fsdp_dim),
                cfg.norm_eps)
        if cfg.pos == "rope":
            from repro.models.layers import rope_decode
            rdt = ctx.compute_dtype if ctx.has("bf16_rope") else None
            q = rope_decode(q, pos, cfg.rope_theta, rdt)
            k_new = rope_decode(k_new, pos, cfg.rope_theta, rdt)
        kc = cache_write(state["k"], k_new, ctx, pos=pos, window=window)
        vc = cache_write(state["v"], v_new, ctx, pos=pos, window=window)
        o = decode_attention(q, kc, vc, ctx, pos=pos, H=H, window=window,
                             ring=ring)
        # q/kv/o replicated over tp (decode_attention merged with psums), so
        # y is identical on every chip — plain residual add, no collective.
        y = o.reshape(B, 1, H * hd) @ wo
        x = x + y
        x = _mix(kind, x, p, mt, ctx, cfg, serve=True)
        return x, {"k": kc, "v": vc}
    if kind == "mlstm":
        x, st = mlstm_block(x, p["mlstm"], mt["mlstm"], ctx, cfg,
                            state=state, decode=True)
        return x, st
    if kind == "slstm":
        x, st = slstm_block(x, p["slstm"], mt["slstm"], ctx, cfg,
                            state=state, decode=True)
        return x, st
    if kind == "rglru":
        x, st = rglru_block(x, p["rglru"], mt["rglru"], ctx, cfg,
                            state=state, decode=True)
        x = _mix(kind, x, p, mt, ctx, cfg, serve=True)
        return x, st
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Embedding / loss glue
# ---------------------------------------------------------------------------

def _embed_sp(cfg, ctx, defs, params, batch, *, T: int):
    """Build the sequence-parallel input embedding (B, T/tp, d) plus FULL
    (labels, mask) of shape (B, T) — the streamed loss consumes full-T
    labels (see unembed_xent)."""
    tp, rank = ctx.tp, ctx.tp_rank
    T_loc = T // tp
    t0 = rank * T_loc if ctx.tp_axis else 0
    pos_loc = t0 + jnp.arange(T_loc)

    if cfg.frontend == "encodec":
        frames = batch["frames"]                            # (B, T, d_f)
        fr_loc = tp_slice(frames, rank, tp, 1) if ctx.tp_axis else frames
        w_fe = ctx.gather_w(params["frontend"], defs["frontend"].fsdp_dim)
        x = fr_loc.astype(ctx.compute_dtype) @ w_fe
        labels = batch["labels"]
        mask = jnp.ones_like(labels, jnp.float32)
    else:
        tokens = batch["tokens"]                            # (B, T+1)
        ids = tokens[:, :T]
        labels = tokens[:, 1:T + 1]
        emb = ctx.gather_w(params["embed"], defs["embed"].fsdp_dim)
        x = embed(ids, emb, ctx, sp=ctx.tp_axis is not None)
        mask = jnp.ones_like(labels, jnp.float32)
        if cfg.frontend == "vit":
            patches = batch["patches"]                      # (B, P, d_f)
            w_fe = ctx.gather_w(params["frontend"], defs["frontend"].fsdp_dim)
            pe = patches.astype(ctx.compute_dtype) @ w_fe   # (B, P, d)
            P_ = cfg.n_prefix
            idx = jnp.clip(pos_loc, 0, P_ - 1)
            pex = jnp.take(pe, idx, axis=1)
            is_patch = (pos_loc < P_)[None, :, None]
            x = jnp.where(is_patch, pex, x)
            mask = mask * ((jnp.arange(T) + 1) >= P_)[None, :]
    if cfg.tie_embeddings:  # gemma-style input scaling
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if cfg.pos == "sinusoidal":
        x = x + sinusoidal_pe(pos_loc, cfg.d_model)[None].astype(x.dtype)
    return x, labels, mask


def _unembed_weight(cfg, ctx, defs, params):
    if cfg.tie_embeddings:
        w = ctx.gather_w(params["embed"], defs["embed"].fsdp_dim)
        return w.T                                          # (d, V/tp)
    return ctx.gather_w(params["unembed"], defs["unembed"].fsdp_dim)


# ---------------------------------------------------------------------------
# Train loss
# ---------------------------------------------------------------------------

def _scan_units(cfg, ctx, defs, params, x, *, collect_state=False,
                unroll: int = 1):
    kinds = cfg.pattern

    def unit(x, pu):
        states = {}
        for i, k in enumerate(kinds):
            key = f"b{i}"
            if collect_state:
                x, st = _block_train(k, x, pu[key], defs["units"][key], ctx,
                                     cfg, return_state=True)
                states[key] = st
            else:
                x = _block_train(k, x, pu[key], defs["units"][key], ctx, cfg)
        return (x, states) if collect_state else x

    if collect_state:
        def body(x, pu):
            x, st = unit(x, pu)
            return x, st
        x, states = lax.scan(body, x, params["units"], unroll=unroll)
        return x, states

    if ctx.has("save_ag"):
        # §Perf: keep collective outputs across the bwd — the remat
        # recompute then skips every re-gather (trades footprint for
        # collective+memory traffic).
        policy = jax.checkpoint_policies.save_only_these_names("ag_out")
        remat = lambda f: jax.checkpoint(f, policy=policy)  # noqa: E731
    else:
        remat = jax.checkpoint

    budget = ctx.prefetch
    if budget > 0:
        # Async prefetch: an unrolled walk over per-unit ParamGroups — layer
        # k+1's FSDP window gathers are issued while layer k computes, at
        # most `budget` groups unsharded at once.  The unit body runs with
        # fsdp_axes cleared (its params arrive already full), which also
        # keeps the gathers OUTSIDE the remat region: the bwd recompute
        # reuses the unsharded copy instead of re-gathering.
        inner = dataclasses.replace(ctx, fsdp_axes=())

        def unit_full(x, pu):
            for i, k in enumerate(kinds):
                x = _block_train(k, x, pu[f"b{i}"], defs["units"][f"b{i}"],
                                 inner, cfg)
            return x
        unit_f = remat(unit_full)
        groups = [ParamGroup(ctx,
                             jax.tree.map(lambda u, i=i: u[i],
                                          params["units"]),
                             defs["units"])
                  for i in range(cfg.n_units)]
        x = prefetch_walk(groups, lambda c, _k, full: unit_f(c, full), x,
                          budget)
        return x, None

    unit_r = remat(unit)
    x, _ = lax.scan(lambda c, pu: (unit_r(c, pu), None), x, params["units"],
                    unroll=unroll)
    return x, None


def _rem_blocks(cfg, ctx, defs, params, x, *, collect_state=False, pos=None,
                cache=None, decode=False):
    states = {}
    for i, k in enumerate(cfg.remainder_kinds):
        key = f"r{i}"
        if decode:
            x, st = _block_decode(k, x, params["rem"][key], defs["rem"][key],
                                  cache[key], ctx, cfg, pos=pos)
            states[key] = st
        elif collect_state:
            x, st = _block_train(k, x, params["rem"][key], defs["rem"][key],
                                 ctx, cfg, return_state=True)
            states[key] = st
        else:
            x = _block_train(k, x, params["rem"][key], defs["rem"][key], ctx,
                             cfg)
    return x, states


def _loss(cfg, ctx, defs, params, batch, *, unroll: int = 1):
    """Returns (loss_sum, token_count) — local partials; caller reduces."""
    T = (batch["frames"].shape[1] if cfg.frontend == "encodec"
         else batch["tokens"].shape[1] - 1)
    x, labels, mask = _embed_sp(cfg, ctx, defs, params, batch, T=T)
    x, _ = _scan_units(cfg, ctx, defs, params, x, unroll=unroll)
    x, _ = _rem_blocks(cfg, ctx, defs, params, x)
    x = rms_norm(x, ctx.gather_w(params["final_ln"],
                                 defs["final_ln"].fsdp_dim), cfg.norm_eps)
    w_un = _unembed_weight(cfg, ctx, defs, params)
    return unembed_xent(x, labels, mask, w_un, ctx, chunk=XENT_CHUNK,
                        softcap=cfg.logit_softcap)


# ---------------------------------------------------------------------------
# Prefill / decode
# ---------------------------------------------------------------------------

def _state_to_cache(cfg, ctx, st, T: int, s_max, kind, tdim: int = 1):
    """Re-layout prefill (k, v) T-chunks into the decode cache layout.

    Prefill chunks are sharded on the prompt length T; the decode cache is
    sharded on s_max (or the ring window).  Relayout = intra-pod gather (the
    shared-window read) + local slice.  Ring slots whose global position
    predates the prompt (T < window) are zero-filled — they are masked out
    of decode attention, but must not hold NaN (an out-of-bounds gather
    fill), because even a zero-weighted NaN poisons the softmax-weighted
    sum.  ``tdim``: time axis (2 for unit-stacked states).
    """
    if kind not in ("attn", "local"):
        return st
    window = cfg.window if kind == "local" else None
    tp, rank = max(ctx.tp, 1), ctx.tp_rank

    def relayout(a):                               # (..., T/tp, kv, hd)
        full = ctx.ag_tokens(a, dim=tdim)          # (..., T, kv, hd)
        if window is not None:
            W = min(window, s_max)
            # ring slot s holds position g = T-W + ((s - (T-W)) mod W)
            s = jnp.arange(W)
            g = T - W + ((s - (T - W)) % W)
            full = jnp.take(full, jnp.maximum(g, 0),
                            axis=tdim)             # (..., W, kv, hd)
            shape = [1] * full.ndim
            shape[tdim] = W
            full = jnp.where((g >= 0).reshape(shape), full,
                             jnp.zeros_like(full))
            S_loc = W // tp
            return lax.dynamic_slice_in_dim(full, rank * S_loc, S_loc, tdim)
        S_loc = s_max // tp
        pad = [(0, 0)] * full.ndim
        pad[tdim] = (0, s_max - T)
        full = jnp.pad(full, pad)
        return lax.dynamic_slice_in_dim(full, rank * S_loc, S_loc, tdim)

    return {"k": relayout(st["k"]), "v": relayout(st["v"])}


def _cache_init(cfg, ctx, B_loc, s_max):
    tp = max(ctx.tp, 1)
    d2d = (M.decode2d_groups(cfg, tp)
           if (ctx.has("decode2d") and ctx.tp_axis) else None)

    def one(kind):
        if kind in ("attn", "local"):
            window = cfg.window if kind == "local" else None
            S = min(window, s_max) if window else s_max
            if d2d:
                g_h, g_s = d2d
                z = jnp.zeros((B_loc, S // g_s, cfg.n_kv // g_h,
                               cfg.head_dim), ctx.compute_dtype)
                return {"k": z, "v": z}
            S_loc = S // tp
            z = jnp.zeros((B_loc, S_loc, cfg.n_kv, cfg.head_dim),
                          ctx.compute_dtype)
            return {"k": z, "v": z}
        if kind == "mlstm":
            return mlstm_state_init(cfg, B_loc, ctx, ctx.compute_dtype)
        if kind == "slstm":
            return slstm_state_init(cfg, B_loc, ctx.compute_dtype)
        if kind == "rglru":
            return rglru_state_init(cfg, B_loc, ctx, ctx.compute_dtype)
        raise ValueError(kind)

    units = {f"b{i}": one(k) for i, k in enumerate(cfg.pattern)}
    units = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_units,) + a.shape), units)
    out = {"units": units}
    if cfg.remainder_kinds:
        out["rem"] = {f"r{i}": one(k)
                      for i, k in enumerate(cfg.remainder_kinds)}
    return out


def _prefill(cfg, ctx, defs, params, batch, s_max, *, unroll: int = 1):
    """Run the prompt, return (cache, last-token logits).

    Attention blocks emit T-sharded KV chunks (re-laid-out to the decode
    cache); recurrent blocks emit their final state straight from the
    chunkwise-parallel form.
    """
    T = (batch["frames"].shape[1] if cfg.frontend == "encodec"
         else batch["tokens"].shape[1] - 1)
    x, _, _ = _embed_sp(cfg, ctx, defs, params, batch, T=T)
    x, states = _scan_units(cfg, ctx, defs, params, x, collect_state=True,
                            unroll=unroll)
    x, rem_states = _rem_blocks(cfg, ctx, defs, params, x,
                                collect_state=True)
    x = rms_norm(x, ctx.gather_w(params["final_ln"],
                                 defs["final_ln"].fsdp_dim), cfg.norm_eps)
    # last-token logits (token T-1 lives on the last tp rank's chunk; after
    # the gather below every chip holds it)
    last = ctx.ag_tokens(x)[:, -1:] if ctx.tp_axis else x[:, -1:]
    w_un = _unembed_weight(cfg, ctx, defs, params)
    logits = decode_logits(last, w_un, ctx, softcap=cfg.logit_softcap)

    cache_units = {}
    for i, k in enumerate(cfg.pattern):
        key = f"b{i}"
        cache_units[key] = _state_to_cache(cfg, ctx, states[key], T, s_max,
                                           k, tdim=2)
    cache = {"units": cache_units}
    if cfg.remainder_kinds:
        cache["rem"] = {f"r{i}": _state_to_cache(cfg, ctx, rem_states[f"r{i}"],
                                                 T, s_max, k)
                        for i, k in enumerate(cfg.remainder_kinds)}
    return cache, logits


def _decode(cfg, ctx, defs, params, cache, token, pos, *, unroll: int = 1):
    """One decode step.  token: (B, 1) int32 (or (B, 1, d_f) frames);
    pos: current position — a scalar shared by the batch, or a (B,) vector
    of per-slot positions (continuous batching over heterogeneous sequence
    lengths).  Returns (new_cache, logits (B, 1, V))."""
    if cfg.frontend == "encodec":
        w_fe = ctx.gather_w(params["frontend"], defs["frontend"].fsdp_dim)
        x = token.astype(ctx.compute_dtype) @ w_fe
    else:
        emb = ctx.gather_w(params["embed"], defs["embed"].fsdp_dim)
        x = embed(token, emb, ctx)
    if cfg.tie_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if cfg.pos == "sinusoidal":
        if jnp.ndim(pos) == 1:           # per-slot positions: (B, 1, d)
            x = x + sinusoidal_pe(pos, cfg.d_model)[:, None].astype(x.dtype)
        else:
            x = x + sinusoidal_pe(jnp.full((1,), pos),
                                  cfg.d_model)[None].astype(x.dtype)

    kinds = cfg.pattern

    def unit(x, scan_in):
        pu, cu = scan_in
        new_c = {}
        for i, k in enumerate(kinds):
            key = f"b{i}"
            x, st = _block_decode(k, x, pu[key], defs["units"][key], cu[key],
                                  ctx, cfg, pos=pos)
            new_c[key] = st
        return x, new_c

    x, new_units = lax.scan(unit, x, (params["units"], cache["units"]),
                            unroll=unroll)
    new_cache = {"units": new_units}
    if cfg.remainder_kinds:
        x, new_rem = _rem_blocks(cfg, ctx, defs, params, x, decode=True,
                                 pos=pos, cache=cache["rem"])
        new_cache["rem"] = new_rem
    x = rms_norm(x, ctx.gather_w(params["final_ln"],
                                 defs["final_ln"].fsdp_dim), cfg.norm_eps)
    w_un = _unembed_weight(cfg, ctx, defs, params)
    logits = decode_logits(x, w_un, ctx, softcap=cfg.logit_softcap)
    return new_cache, logits


# ---------------------------------------------------------------------------
# Analytic cost notes (loop-body undercount corrections; DESIGN.md §7)
# ---------------------------------------------------------------------------

def _cost_notes(cfg: ModelConfig, *, kind: str, B: int, T: int
                ) -> dict[str, float]:
    """FLOPs hidden from HLO cost analysis by inner sequential loops:
      * flash-attention KV-block scan: all but one block per attention call,
      * sLSTM time scan: all but one timestep,
      * streamed-xent chunk scan: all but one chunk.
    ``mult``: fwd-only (serve) vs fwd+bwd (train, ~3x matmul flops).
    """
    mult = 3.0 if kind == "train" else 1.0
    flops = 0.0
    bytes_ = 0.0
    if kind == "decode":
        return {"flops": 0.0, "bytes": 0.0}
    for k in cfg.block_kinds:
        if k in ("attn", "local"):
            window = cfg.window if k == "local" else None
            full = attn_flops(B, T, T, cfg.n_heads, cfg.head_dim,
                              causal=True, window=window)
            n_blocks = max(T // KV_BLOCK, 1)
            flops += mult * full * (1.0 - 1.0 / n_blocks)
            kv_bytes = 2 * B * T * cfg.n_kv * cfg.head_dim * 2
            bytes_ += mult * kv_bytes * (n_blocks - 1)
        elif k == "slstm":
            per_layer = slstm_scan_flops(cfg, B, T)
            flops += mult * per_layer * (1.0 - 1.0 / T)
            bytes_ += mult * 8 * B * cfg.d_model * T  # state traffic
    v = cfg.vocab_padded
    n_chunks = max(T // XENT_CHUNK, 1)
    xent = 2.0 * B * T * cfg.d_model * v
    flops += mult * xent * (1.0 - 1.0 / n_chunks)
    bytes_ += mult * (2.0 * cfg.d_model * v) * (n_chunks - 1)
    return {"flops": flops, "bytes": bytes_}
