from repro.configs.base import (ModelConfig, MoESpec, get_config,
                                list_configs, register)

__all__ = ["ModelConfig", "MoESpec", "get_config", "list_configs", "register"]
