"""Assigned input-shape sets (LM-family: seq_len x global_batch)."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# long_500k needs sub-quadratic sequence handling: only SSM/hybrid archs run
# it; pure full-attention archs skip (recorded per-cell; see DESIGN.md §5).
LONG_CONTEXT_ARCHS = ("xlstm-1.3b", "recurrentgemma-9b")


def get_shape(name: str) -> ShapeSpec:
    return SHAPES[name]


def cell_applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_CONTEXT_ARCHS
    return True
