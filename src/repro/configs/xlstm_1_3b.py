"""xLSTM 1.3B [arXiv:2405.04517; unverified] — 7:1 mLSTM:sLSTM units."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv=4, head_dim=512,
    d_ff=0, vocab=50304, pos="none", proj_factor=2.0, conv_kernel=4,
    pattern=("mlstm",) * 7 + ("slstm",),
))
