"""StarCoder2-7B [arXiv:2402.19173; hf] — GQA, RoPE."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv=4, head_dim=128,
    d_ff=18432, vocab=49152, act="gelu", rope_theta=100000.0,
))
