"""Gemma-2B [arXiv:2403.08295; hf] — GeGLU, head_dim 256, MQA."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv=1, head_dim=256,
    d_ff=16384, vocab=256000, act="geglu", tie_embeddings=True,
))
