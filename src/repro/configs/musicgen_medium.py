"""MusicGen-medium [arXiv:2306.05284; hf] — decoder over EnCodec tokens (stub)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv=24, head_dim=64,
    d_ff=6144, vocab=2048, act="geglu", pos="sinusoidal",
    frontend="encodec", d_frontend=128,
))
