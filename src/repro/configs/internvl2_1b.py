"""InternVL2-1B [arXiv:2404.16821; hf] — InternViT stub + Qwen2-0.5B backbone."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv=2, head_dim=64,
    d_ff=4864, vocab=151655, rope_theta=1_000_000.0,
    frontend="vit", d_frontend=1024, n_prefix=256,
))
