"""RecurrentGemma-9B [arXiv:2402.19427; unverified] — RG-LRU + local attn 1:2."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv=1, head_dim=256,
    d_ff=12288, vocab=256000, act="geglu", window=2048,
    pattern=("rglru", "rglru", "local"), d_rnn=4096, conv_kernel=4,
    tie_embeddings=True,
))
