"""Model configuration system.

One ``ModelConfig`` per assigned architecture (``src/repro/configs/<id>.py``),
plus ``reduced()`` variants for CPU smoke tests.  Configs are plain frozen
dataclasses — no jax imports — so they are cheap to build anywhere.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence


def pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class MoESpec:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25

    def ep_tp(self, tp: int) -> tuple[int, int]:
        """Factor the model axis into (expert-parallel, ffn-tensor-parallel)
        degrees: largest ep dividing both tp and num_experts."""
        ep = math.gcd(self.num_experts, tp)
        return ep, tp // ep


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense|moe|ssm|hybrid|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    d_ff: int
    vocab: int
    # block pattern, repeated to fill n_layers (remainder allowed):
    #   attn | local | mlstm | slstm | rglru  — each block includes its own
    #   channel-mixing (ffn/moe) except mlstm/slstm (xLSTM has none).
    pattern: tuple[str, ...] = ("attn",)
    act: str = "swiglu"            # swiglu|geglu
    norm_eps: float = 1e-6
    qk_norm: bool = False
    rope_theta: float = 10000.0
    pos: str = "rope"              # rope|sinusoidal|none
    window: Optional[int] = None   # sliding window for "local" blocks
    moe: Optional[MoESpec] = None
    frontend: Optional[str] = None  # None|"vit"|"encodec" (stub embeddings)
    d_frontend: int = 0
    n_prefix: int = 0              # frontend tokens prepended (vlm)
    tie_embeddings: bool = False
    logit_softcap: Optional[float] = None
    # xLSTM specifics
    proj_factor: float = 2.0       # mLSTM inner-dim multiplier
    conv_kernel: int = 4
    d_rnn: int = 0                 # RG-LRU recurrence width (0 -> d_model)

    # ---- derived ------------------------------------------------------------
    @property
    def vocab_padded(self) -> int:
        return pad_to(self.vocab, 128)

    @property
    def block_kinds(self) -> tuple[str, ...]:
        reps = (self.n_layers + len(self.pattern) - 1) // len(self.pattern)
        return (self.pattern * reps)[: self.n_layers]

    @property
    def n_units(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def remainder_kinds(self) -> tuple[str, ...]:
        return self.block_kinds[self.n_units * len(self.pattern):]

    @property
    def d_inner(self) -> int:
        """mLSTM inner width."""
        return int(self.d_model * self.proj_factor)

    @property
    def rnn_width(self) -> int:
        return self.d_rnn or self.d_model

    def param_count(self) -> int:
        """Analytic parameter count (embedding included, padding excluded)."""
        d, hd, H, kv = self.d_model, self.head_dim, self.n_heads, self.n_kv
        total = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.frontend:
            total += self.d_frontend * d
        attn = d * (H + 2 * kv) * hd + H * hd * d + 2 * d
        nmat = 2 if self.act == 'gelu' else 3
        ffn = nmat * d * self.d_ff + 2 * d if self.d_ff else 0
        if self.moe:
            ffn = (self.moe.num_experts * 3 * d * self.moe.d_ff_expert
                   + d * self.moe.num_experts + 2 * d)
        din = self.d_inner
        nh = max(self.n_heads, 1)
        mlstm = (d * 2 * din + 3 * din * din // nh + 3 * din * nh
                 + din * self.conv_kernel + din * d + 2 * d)
        slstm = 8 * d * d + 4 * d + d * self.conv_kernel + 2 * d
        dr = self.rnn_width
        # w_x (d,2,dr) + w_rg (d,2,dr) + conv + lam + w_out + ln
        rglru = (4 * d * dr + dr * self.conv_kernel
                 + dr + dr * d + d) + ffn
        per_kind = {"attn": attn + ffn, "local": attn + ffn,
                    "mlstm": mlstm, "slstm": slstm, "rglru": rglru}
        for k in self.block_kinds:
            total += per_kind[k]
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of num_experts)."""
        if not self.moe:
            return self.param_count()
        moe_all = (self.n_layers * self.moe.num_experts * 3 * self.d_model
                   * self.moe.d_ff_expert)
        frac = self.moe.top_k / self.moe.num_experts
        return self.param_count() - int(moe_all * (1 - frac))

    def reduced(self, *, n_layers: int = 2, d_model: int = 64,
                n_heads: int = 4, n_kv: Optional[int] = None,
                vocab: int = 256, d_ff: Optional[int] = None,
                seq: int = 32) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        del seq
        kv = n_kv if n_kv is not None else min(self.n_kv, n_heads)
        kv = max(1, min(kv, n_heads))
        moe = None
        if self.moe:
            moe = MoESpec(num_experts=4, top_k=2, d_ff_expert=32,
                          capacity_factor=self.moe.capacity_factor)
        pat_reps = max(1, n_layers // len(self.pattern))
        return dataclasses.replace(
            self, n_layers=len(self.pattern) * pat_reps, d_model=d_model,
            n_heads=n_heads, n_kv=kv, head_dim=d_model // n_heads,
            d_ff=(d_ff if d_ff is not None else (0 if self.d_ff == 0 else 128)),
            vocab=vocab, moe=moe, window=min(self.window, 16) if self.window
            else None, d_frontend=32 if self.frontend else 0,
            n_prefix=4 if self.n_prefix else 0,
            d_rnn=d_model if self.d_rnn else 0)


_REGISTRY: dict[str, "ModelConfig"] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def list_configs() -> Sequence[str]:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all() -> None:
    import importlib
    for mod in ("qwen3_moe_235b_a22b", "granite_moe_3b_a800m", "xlstm_1_3b",
                "qwen3_0_6b", "starcoder2_7b", "gemma_2b", "mistral_nemo_12b",
                "internvl2_1b", "recurrentgemma_9b", "musicgen_medium"):
        importlib.import_module(f"repro.configs.{mod}")
