"""Granite-3.0 MoE 3B-A800M [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
from repro.configs.base import ModelConfig, MoESpec, register

CONFIG = register(ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv=8, head_dim=64,
    d_ff=512, vocab=49155, rope_theta=10000.0,
    moe=MoESpec(num_experts=40, top_k=8, d_ff_expert=512),
))
