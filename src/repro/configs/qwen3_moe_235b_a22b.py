"""Qwen3-MoE 235B-A22B [hf:Qwen/Qwen3-30B-A3B family; hf]."""
from repro.configs.base import ModelConfig, MoESpec, register

CONFIG = register(ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv=4, head_dim=128,
    d_ff=1536, vocab=151936, qk_norm=True, rope_theta=1_000_000.0,
    moe=MoESpec(num_experts=128, top_k=8, d_ff_expert=1536),
))
