"""Qwen3 0.6B [hf:Qwen/Qwen3-8B family; hf] — qk_norm, GQA."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-0.6b", family="dense",
    n_layers=28, d_model=1024, n_heads=16, n_kv=8, head_dim=128,
    d_ff=3072, vocab=151936, qk_norm=True, rope_theta=1_000_000.0,
))
