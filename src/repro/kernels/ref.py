"""Pure-jnp oracles for every kernel (the allclose ground truth)."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: Optional[int] = None,
                  q_offset: int = 0) -> jax.Array:
    """q: (B, H, Tq, hd); k, v: (B, KV, Tkv, hd).  Exact softmax attention
    with GQA head mapping, fp32 throughout."""
    B, H, Tq, hd = q.shape
    KV, Tkv = k.shape[1], k.shape[2]
    group = H // KV
    kq = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vq = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kq)
    s = s / math.sqrt(hd)
    qpos = q_offset + jnp.arange(Tq)
    kpos = jnp.arange(Tkv)
    mask = jnp.ones((Tq, Tkv), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= (qpos[:, None] - kpos[None, :]) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vq)
    return out.astype(q.dtype)


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return (a.astype(jnp.float32) @ b.astype(jnp.float32)).astype(a.dtype)


def lru_scan_ref(a: jax.Array, x: jax.Array) -> jax.Array:
    """h_t = a_t h_{t-1} + x_t via associative scan, fp32."""
    def combine(p, q):
        a1, x1 = p
        a2, x2 = q
        return a1 * a2, a2 * x1 + x2

    _, h = jax.lax.associative_scan(
        combine, (a.astype(jnp.float32), x.astype(jnp.float32)), axis=1)
    return h.astype(x.dtype)
