"""Pallas TPU blocked matmul — the SUMMA per-panel compute kernel.

The paper's SUMMA benchmark (§5.2.1) multiplies b x b panels after each
broadcast round; this kernel is that panel product, tiled for the MXU:
(block_m, block_k) x (block_k, block_n) VMEM tiles, fp32 accumulation in a
VMEM scratch carried across the k grid dimension (``arbitrary`` semantics),
written out once on the last k step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_pallas(a: jax.Array, b: jax.Array, *, block_m: int = 128,
                  block_n: int = 128, block_k: int = 128,
                  interpret: bool = True) -> jax.Array:
    """a: (M, K) @ b: (K, N) -> (M, N).  Dims must divide by the blocks
    (ops.py pads)."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    block_m, block_n, block_k = (min(block_m, M), min(block_n, N),
                                 min(block_k, K))
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0
    n_k = K // block_k
    grid = (M // block_m, N // block_n, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(a, b)
