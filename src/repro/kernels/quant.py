"""Pallas TPU dequant-fused matmul over packed-int4 weights.

The ``q4_shared`` wire format ships weight windows as two int4 nibbles per
byte plus one f32 scale per length-``group`` run of K rows
(``repro.comm.quantize.quantize_q4``).  Dequantizing to a dense f32 weight
before the matmul would materialize 8x the gathered bytes in VMEM; this
kernel instead unpacks and rescales each (group, block_n) weight tile
*inside* the matmul loop, so the packed bytes are what travels through the
memory hierarchy.

Grid ``(M / block_m, N / block_n, K / group)`` with the k-block pinned to
``group``: each k step covers exactly one scale row, so the rescale is a
single broadcast multiply.  fp32 accumulation in a VMEM scratch carried
across the k dimension, written out once on the last step — the same
schedule as ``kernels.matmul``.

Note the int8/uint8 VMEM tile floor on real TPUs is (32, 128): the packed
operand's k-extent is ``group // 2``, so ``group >= 64`` is required for
compiled TPU runs; the CPU interpret mode (this container's validation
path) has no such floor.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, p_ref, s_ref, o_ref, acc_ref, *, n_k: int, group: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pk = p_ref[...]                                   # (group // 2, bn)
    lo = (pk & 0xF).astype(jnp.int8) - 8
    hi = (pk >> 4).astype(jnp.int8) - 8
    # byte r holds K rows (2r, 2r+1): interleave back to row order
    codes = jnp.stack([lo, hi], axis=1).reshape(group, pk.shape[1])
    w = codes.astype(jnp.float32) * s_ref[...]        # scale row broadcasts
    acc_ref[...] += jax.lax.dot(
        a_ref[...].astype(jnp.float32), w,
        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def q4_matmul_pallas(a: jax.Array, packed: jax.Array, scales: jax.Array, *,
                     group: int = 32, block_m: int = 128,
                     block_n: int = 128, interpret: bool = True) -> jax.Array:
    """``a @ dequantize_q4(packed, scales)`` without densifying the weight.

    ``a``: (M, K); ``packed``: uint8 (K // 2, N); ``scales``: f32
    (K // group, N).  M and N must divide by the blocks and K by ``group``
    (the jit wrapper below pads).
    """
    M, K = a.shape
    N = packed.shape[1]
    assert packed.shape[0] * 2 == K and scales.shape == (K // group, N)
    block_m, block_n = min(block_m, M), min(block_n, N)
    assert M % block_m == 0 and N % block_n == 0 and K % group == 0
    n_k = K // group
    grid = (M // block_m, N // block_n, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k, group=group),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, group), lambda i, j, k: (i, k)),
            pl.BlockSpec((group // 2, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(a, packed, scales)
