"""Pallas TPU flash attention (causal / sliding-window, GQA-native).

TPU adaptation of the online-softmax attention kernel: q is tiled into
(block_q, head_dim) VMEM blocks aligned to the MXU (128-multiples); the KV
stream is walked in block_kv chunks with fp32 running (m, l, o) carried in
registers/VMEM.  GQA is expressed in the BlockSpec index maps: the kv-block
of q-head ``h`` is head ``h // group`` — no KV replication in HBM.

Validated on CPU via interpret=True against kernels/ref.py (exact softmax).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, block_kv: int, Tkv: int,
            causal: bool, window: Optional[int], q_offset: int, scale: float):
    bq, hd = q_ref.shape[1], q_ref.shape[2]
    q = q_ref[0].astype(jnp.float32) * scale                 # (bq, hd)
    qi = pl.program_id(1)
    qpos = q_offset + qi * bq + lax.iota(jnp.int32, bq)      # (bq,)

    n_kv = Tkv // block_kv

    def body(j, carry):
        o, m, l = carry
        k = k_ref[0, pl.dslice(j * block_kv, block_kv)].astype(jnp.float32)
        v = v_ref[0, pl.dslice(j * block_kv, block_kv)].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        kpos = j * block_kv + lax.iota(jnp.int32, block_kv)
        mask = jnp.ones((bq, block_kv), jnp.bool_)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= (qpos[:, None] - kpos[None, :]) < window
        s = jnp.where(mask, s, NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=1)
        o_new = o * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return o_new, m_new, l_new

    o0 = jnp.zeros((bq, hd), jnp.float32)
    m0 = jnp.full((bq,), NEG, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)

    if causal:
        # skip fully-masked kv blocks beyond the last q position
        hi = jnp.minimum(
            (q_offset + (qi + 1) * bq + block_kv - 1) // block_kv, n_kv)
    else:
        hi = n_kv
    o, m, l = lax.fori_loop(0, hi, body, (o0, m0, l0))
    o_ref[0] = (o / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True,
                           window: Optional[int] = None,
                           q_offset: int = 0,
                           block_q: int = 128, block_kv: int = 128,
                           interpret: bool = True) -> jax.Array:
    """q: (B, H, Tq, hd); k, v: (B, KV, Tkv, hd).  Returns (B, H, Tq, hd).

    H % KV == 0 (GQA).  Tq % block_q == 0, Tkv % block_kv == 0 (pad in
    ops.py).  hd should be a multiple of 128 for MXU alignment on real TPUs
    (not enforced in interpret mode).
    """
    B, H, Tq, hd = q.shape
    KV, Tkv = k.shape[1], k.shape[2]
    assert H % KV == 0, (H, KV)
    group = H // KV
    block_q = min(block_q, Tq)
    block_kv = min(block_kv, Tkv)
    assert Tq % block_q == 0 and Tkv % block_kv == 0

    qr = q.reshape(B * H, Tq, hd)
    kr = k.reshape(B * KV, Tkv, hd)
    vr = v.reshape(B * KV, Tkv, hd)

    grid = (B * H, Tq // block_q)
    out = pl.pallas_call(
        functools.partial(_kernel, block_kv=block_kv, Tkv=Tkv, causal=causal,
                          window=window, q_offset=q_offset,
                          scale=1.0 / math.sqrt(hd)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((1, Tkv, hd), lambda bh, qi, g=group: (bh // g, 0, 0)),
            pl.BlockSpec((1, Tkv, hd), lambda bh, qi, g=group: (bh // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda bh, qi: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Tq, hd), q.dtype),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, Tq, hd)
