"""Pallas TPU blocked linear-recurrence scan (RG-LRU / mLSTM decay core).

h_t = a_t * h_{t-1} + x_t, elementwise over channels.  The time axis is
walked in (block_t) chunks along an ``arbitrary`` grid dimension; the carry
h lives in a VMEM scratch that persists across the time-grid steps, so HBM
traffic is exactly one read of (a, x) and one write of h — the memory-bound
roofline for this op.  Channels tile the lane dimension (128-aligned).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, x_ref, o_ref, h_ref, *, block_t: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)          # (block_t, bc)
    x = x_ref[0].astype(jnp.float32)

    def step(t, carry):
        h = carry * a[t] + x[t]
        o_ref[0, t] = h.astype(o_ref.dtype)
        return h

    h = lax.fori_loop(0, block_t, step, h_ref[...])
    h_ref[...] = h


def lru_scan_pallas(a: jax.Array, x: jax.Array, *, block_t: int = 256,
                    block_c: int = 128, interpret: bool = True) -> jax.Array:
    """a, x: (B, T, C) -> h: (B, T, C).  T % block_t == 0, C % block_c == 0
    (ops.py pads)."""
    B, T, C = a.shape
    block_t = min(block_t, T)
    block_c = min(block_c, C)
    assert T % block_t == 0 and C % block_c == 0
    grid = (B, C // block_c, T // block_t)
    return pl.pallas_call(
        functools.partial(_kernel, block_t=block_t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, block_c), lambda b, c, t: (b, t, c)),
            pl.BlockSpec((1, block_t, block_c), lambda b, c, t: (b, t, c)),
        ],
        out_specs=pl.BlockSpec((1, block_t, block_c),
                               lambda b, c, t: (b, t, c)),
        out_shape=jax.ShapeDtypeStruct((B, T, C), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c,), jnp.float32)],
        interpret=interpret,
    )(a, x)
