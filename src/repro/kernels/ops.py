"""Jit'd public wrappers: padding/layout glue around the Pallas kernels.

``interpret`` defaults to True on CPU (the validation mode for this
container) and False on TPU (real kernels).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.lru_scan import lru_scan_pallas
from repro.kernels.matmul import matmul_pallas
from repro.kernels.quant import q4_matmul_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if not pad:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_offset",
                                             "block_q", "block_kv",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, q_offset: int = 0,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: Optional[bool] = None):
    """q: (B, H, Tq, hd); k, v: (B, KV, Tkv, hd)."""
    interpret = _default_interpret() if interpret is None else interpret
    Tq, Tkv = q.shape[2], k.shape[2]
    bq = min(block_q, Tq)
    bkv = min(block_kv, Tkv)
    qp, pq = _pad_to(q, bq, 2)
    kp, pk = _pad_to(k, bkv, 2)
    vp, _ = _pad_to(v, bkv, 2)
    # padded kv positions are masked out by causality only if they come after
    # every real q position — true here because kv padding extends the tail.
    out = flash_attention_pallas(qp, kp, vp, causal=causal, window=window,
                                 q_offset=q_offset, block_q=bq, block_kv=bkv,
                                 interpret=interpret)
    return out[:, :, :Tq]


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def matmul(a, b, *, block_m: int = 128, block_n: int = 128,
           block_k: int = 128, interpret: Optional[bool] = None):
    interpret = _default_interpret() if interpret is None else interpret
    M, K = a.shape
    N = b.shape[1]
    ap, _ = _pad_to(_pad_to(a, min(block_m, M) if M >= block_m else M, 0)[0],
                    block_k if K >= block_k else K, 1)
    bp, _ = _pad_to(_pad_to(b, block_k if K >= block_k else K, 0)[0],
                    block_n if N >= block_n else N, 1)
    out = matmul_pallas(ap, bp, block_m=block_m, block_n=block_n,
                        block_k=block_k, interpret=interpret)
    return out[:M, :N]


@functools.partial(jax.jit, static_argnames=("group", "block_m", "block_n",
                                             "interpret"))
def q4_matmul(a, packed, scales, *, group: int = 32, block_m: int = 128,
              block_n: int = 128, interpret: Optional[bool] = None):
    """``a (M, K) @ dequantize_q4(packed (K//2, N), scales)`` fused.

    K must already divide by ``group`` (the quantizer enforces it); M and N
    are padded here.  Zero-padding N is sound because a padded column's
    scale is zero, so its dequantized weights are exactly zero.
    """
    interpret = _default_interpret() if interpret is None else interpret
    M, K = a.shape
    N = packed.shape[1]
    bm = min(block_m, M)
    ap, _ = _pad_to(a, bm, 0)
    bn = block_n if N >= block_n else N
    pp, _ = _pad_to(packed, bn, 1)
    sp, _ = _pad_to(scales, bn, 1)
    out = q4_matmul_pallas(ap, pp, sp, group=group, block_m=block_m,
                           block_n=block_n, interpret=interpret)
    return out[:M, :N]


@functools.partial(jax.jit, static_argnames=("block_t", "block_c",
                                             "interpret"))
def lru_scan(a, x, *, block_t: int = 256, block_c: int = 128,
             interpret: Optional[bool] = None):
    """a, x: (B, T, C)."""
    interpret = _default_interpret() if interpret is None else interpret
    B, T, C = a.shape
    bt = min(block_t, T)
    bc = min(block_c, C)
    ap, _ = _pad_to(_pad_to(a, bt, 1)[0], bc, 2)
    xp, _ = _pad_to(_pad_to(x, bt, 1)[0], bc, 2)
    out = lru_scan_pallas(ap, xp, block_t=bt, block_c=bc,
                          interpret=interpret)
    return out[:, :T, :C]
