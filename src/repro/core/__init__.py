"""Core: the paper's hierarchical MPI+MPI collective scheme for TPU meshes.

(The deprecated ``repro.core.collectives`` free-function shims were removed
after their one-release window — use ``repro.comm.Communicator``.)
"""

from repro.core import plans, shared_buffer, sync, topology
from repro.core.topology import (DATA_AXIS, MODEL_AXIS, POD_AXIS,
                                 MeshTopology, multi_pod, single_pod)

__all__ = [
    "plans", "shared_buffer", "sync", "topology",
    "MeshTopology", "single_pod", "multi_pod",
    "POD_AXIS", "DATA_AXIS", "MODEL_AXIS",
]
