"""One-copy-per-pod parameter store (the MPI-3 shared window analogue).

The window semantics live in ``repro.comm.window`` now (``SharedWindow`` +
the FSDP-style ``window_gather``/``window_scatter`` access); this module
keeps the host-side layout helpers (choosing shard dims, slicing for
init/checkpoint) and delegates the device-side load/store to ``repro.comm``
so every consumer reaches the shared window through one API.

In the paper, replicated data lives once per node in an ``MPI_Win_allocate_
shared`` segment; on-node ranks load/store it directly.  On TPU the analogue
is: a tensor that is *logically replicated* across the pod is *physically
sharded* over the pod's ``data`` axis and gathered over ICI at use time
(``fsdp_gather`` = the load), with gradient transpose writing back partitions
(reduce-scatter = the store).  Across pods the tensor is replicated — one
copy per pod, exactly Fig. 3b.
"""

from __future__ import annotations

from typing import Optional

import jax

from repro.comm.window import window_gather, window_scatter


def choose_shard_dim(shape: tuple[int, ...], n: int,
                     skip_dims: tuple[int, ...] = ()) -> Optional[int]:
    """Pick the dim to shard an FSDP tensor over ``n`` chips: the largest dim
    divisible by ``n`` (ties -> earliest), skipping ``skip_dims`` (e.g. the
    stacked-layer dim under scan).  None -> keep replicated (tiny tensor)."""
    best, best_size = None, 0
    for d, s in enumerate(shape):
        if d in skip_dims or s % n != 0:
            continue
        if s > best_size:
            best, best_size = d, s
    return best


def shard_slice(x, idx: int, n: int, dim: Optional[int]):
    """Host-side: take shard ``idx`` of ``n`` along ``dim`` (None -> as-is)."""
    if dim is None:
        return x
    size = x.shape[dim] // n
    sl = [slice(None)] * x.ndim
    sl[dim] = slice(idx * size, (idx + 1) * size)
    return x[tuple(sl)]


def fsdp_gather(x: jax.Array, dim: Optional[int], fast_axis) -> jax.Array:
    """Load from the pod-shared window (``repro.comm.window.window_gather``):
    intra-pod all-gather at use time; AD transpose is automatically the
    intra-pod reduce-scatter (the store)."""
    return window_gather(x, dim, fast_axis)


def fsdp_scatter(x: jax.Array, dim: Optional[int], fast_axis) -> jax.Array:
    """Explicit store: reduce-scatter partial contributions back to shards."""
    return window_scatter(x, dim, fast_axis)
