"""Mesh topology: axis roles and hardware constants.

The paper's two-tier cluster (shared-memory node / network) maps onto the TPU
mesh axes:

* fast tier ("node")  -> intra-pod axes, wired with ICI      (``data``, ``model``)
* slow tier (network) -> cross-pod axis, wired with DCN      (``pod``)

``MeshTopology`` is a lightweight, jax-free description so the plan algebra in
``plans.py`` can be property-tested without touching device state.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Sequence

# ---------------------------------------------------------------------------
# Hardware constants (TPU v5e, per the brief).
# ---------------------------------------------------------------------------
PEAK_FLOPS_BF16 = 197e12  # FLOP/s per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW_PER_LINK = 50e9    # bytes/s per link (fast tier)
DCN_BW_PER_HOST = 25e9    # bytes/s cross-pod (slow tier, assumed 2x slower)

POD_AXIS = "pod"
DATA_AXIS = "data"
MODEL_AXIS = "model"


@dataclasses.dataclass(frozen=True)
class MeshTopology:
    """Axis names/sizes plus the fast/slow tier split.

    ``axis_sizes`` is ordered as the physical mesh is ordered.  Axes listed in
    ``slow_axes`` cross the DCN (the paper's "network between nodes"); all
    others are intra-pod ICI (the paper's "shared memory").
    """

    axis_sizes: Mapping[str, int]
    slow_axes: Sequence[str] = (POD_AXIS,)

    def __post_init__(self):
        for ax, sz in self.axis_sizes.items():
            if sz < 1:
                raise ValueError(f"axis {ax!r} has non-positive size {sz}")

    # -- sizes ---------------------------------------------------------------
    @property
    def num_devices(self) -> int:
        return math.prod(self.axis_sizes.values())

    def size(self, axis: str) -> int:
        return self.axis_sizes[axis]

    @property
    def num_pods(self) -> int:
        return math.prod(self.axis_sizes[a] for a in self.slow_axes
                         if a in self.axis_sizes) or 1

    @property
    def chips_per_pod(self) -> int:
        return self.num_devices // self.num_pods

    @property
    def fast_axes(self) -> tuple[str, ...]:
        return tuple(a for a in self.axis_sizes if a not in self.slow_axes)

    @property
    def has_pod_axis(self) -> bool:
        return any(a in self.axis_sizes for a in self.slow_axes)

    def axis_names(self) -> tuple[str, ...]:
        return tuple(self.axis_sizes)


def single_pod(data: int = 16, model: int = 16) -> MeshTopology:
    return MeshTopology({DATA_AXIS: data, MODEL_AXIS: model})


def multi_pod(pods: int = 2, data: int = 16, model: int = 16) -> MeshTopology:
    return MeshTopology({POD_AXIS: pods, DATA_AXIS: data, MODEL_AXIS: model})
