"""DEPRECATED free-function collectives — use ``repro.comm`` instead.

The implementations moved to ``repro.comm.primitives`` and are dispatched
through the scheme registry by ``repro.comm.Communicator``:

    from repro.comm import Communicator
    comm = Communicator.from_cluster(vc)          # or from_topology(topo)
    full = comm.allgather(x, scheme="naive")      # was naive_all_gather(...)
    win  = comm.allgather(x, scheme="shared")     # was shared_all_gather(...)
    buf  = win.read()                             # was shared_read(...)

This module re-exports every old name as a thin shim that emits a
``DeprecationWarning`` on first access; the shims will be removed next
release (see README "Communicator API" for the full migration table).
Private helpers (``_axes``, ``axis_index``, ``axis_size``, ``_flat_root``)
are re-exported silently for internal callers mid-migration.
"""

from __future__ import annotations

import warnings

from repro.comm import primitives as _primitives
# internal helpers: no deprecation gate (still imported by in-repo code)
from repro.comm.primitives import _axes, _flat_root, axis_index, axis_size

# exactly the OLD module's public surface — names born in repro.comm
# (naive_all_to_all, naive_reduce_scatter) are deliberately NOT shimmed
_DEPRECATED = (
    "naive_all_gather", "hier_all_gather", "shared_all_gather",
    "shared_read", "shared_to_rank_order", "shared_all_gather_v",
    "naive_broadcast", "hier_broadcast", "shared_broadcast",
    "naive_psum", "hier_psum", "shared_psum_scatter",
    "hier_all_to_all",
)

__all__ = list(_DEPRECATED) + ["_axes", "axis_index", "axis_size"]


def _legacy_hier_all_to_all(x, *, fast_axis, split_axis, concat_axis):
    """The OLD free-function signature/lowering (one tiled fast-tier
    exchange, independent split/concat dims).  The ``repro.comm`` version
    generalized the primitive (node-aware bridge phase, single ``axis``),
    so the shim preserves exactly what old callers got."""
    from jax import lax
    return lax.all_to_all(x, _axes(fast_axis), split_axis=split_axis,
                          concat_axis=concat_axis, tiled=True)


def __getattr__(name: str):
    if name in _DEPRECATED:
        warnings.warn(
            f"repro.core.collectives.{name} is deprecated and will be "
            "removed next release; construct a repro.comm.Communicator and "
            "dispatch through its methods/scheme registry (README "
            "'Communicator API' has the migration table)",
            DeprecationWarning, stacklevel=2)
        if name == "hier_all_to_all":
            return _legacy_hier_all_to_all
        return getattr(_primitives, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
