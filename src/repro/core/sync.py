"""Synchronization primitives (paper §6: heavy barrier vs light-weight flags).

Inside a jitted step, XLA's dataflow already provides the paper's two-barrier
integrity guarantee (a consumer of a gathered/reduced value cannot run before
the exchange).  These helpers exist for *control* synchronization across steps
— checkpoint quiesce, elastic resize, straggler fences — and to make the
paper's two mechanisms explicit and benchmarkable.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.substrate.compat import axis_size as _axis_size_one

from repro.comm.primitives import _axes, axis_index


def barrier(token: jax.Array, axis) -> jax.Array:
    """Heavy-weight barrier: a scalar allreduce over ``axis`` (the paper's
    ``MPI_Barrier(sharedmemComm)``).  Returns a value data-dependent on every
    participant — thread it into downstream computation to enforce ordering."""
    return lax.psum(token, _axes(axis))  # raw-collective: the barrier primitive itself


def flag_chain(token: jax.Array, axis) -> jax.Array:
    """Light-weight point-to-point flags (paper §6): a ring of ppermute sends,
    each process waits only for its predecessor.  One hop instead of a full
    reduction tree — cheaper when only neighbor ordering is needed."""
    axes = _axes(axis)
    out = token
    for a in axes:
        n = _axis_size_one(a)
        perm = [(i, (i + 1) % n) for i in range(n)]
        out = lax.ppermute(out, a, perm)
    return out


def leader_flag(token: jax.Array, *, fast_axis) -> jax.Array:
    """Children signal the leader (chip 0 of the pod) that their partitions
    are ready — the paper's first barrier, light-weight flavor."""
    me = axis_index(fast_axis)
    contrib = jnp.where(me == 0, jnp.zeros_like(token), token)
    # raw-collective: the barrier primitive itself
    return lax.psum(contrib, _axes(fast_axis))
