"""Decomposition algebra for hierarchical (MPI+MPI-style) collectives.

Pure python/numpy — no jax — so invariants are hypothesis-testable.

The paper's scheme (Figs 3b/4): ranks are grouped into *nodes* (fast-memory
domains).  Each node keeps ONE shared result buffer; the lowest rank per node
is the *leader*; leaders form the *bridge communicator* and perform the only
network exchange (an irregular allgatherv, since node contributions differ).
Here a "node" is a TPU pod and the leader role is spread over every chip of
the pod (multi-leader, paper ref [14]): chip i exchanges shard i.

Two kinds of object live here:

* placement / displacement math (``GatherPlan``) — the "one-off" counts and
  displs computation of the paper's Fig. 4, generalized to irregular node
  populations (Fig. 10);
* the traffic model (``CollectiveTraffic``) — closed-form bytes moved per
  memory tier for the naive (pure-MPI analogue) and hierarchical schemes,
  used for benchmark "derived" columns and roofline cross-checks.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence


# ---------------------------------------------------------------------------
# Node/bridge placement (paper Fig. 1/2).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NodeMap:
    """Assignment of global ranks to nodes (fast-memory domains).

    ``node_of[r]`` = node id of global rank ``r``.  SMP-style placement packs
    consecutive ranks; irregular populations (paper §5.1.3) are allowed.
    """

    node_of: tuple[int, ...]

    def __post_init__(self):
        if not self.node_of:
            raise ValueError("empty rank set")
        seen: list[int] = []
        for n in self.node_of:
            if n not in seen:
                seen.append(n)
        # node ids must be dense 0..N-1 in first-appearance order (the paper's
        # comm-split semantics with key=rank keeps rank order inside nodes).
        if seen != list(range(len(seen))):
            raise ValueError(f"node ids must be dense/ordered, got {seen}")

    @staticmethod
    def smp(num_nodes: int, ranks_per_node: int) -> "NodeMap":
        return NodeMap(tuple(r // ranks_per_node
                             for r in range(num_nodes * ranks_per_node)))

    @staticmethod
    def irregular(populations: Sequence[int]) -> "NodeMap":
        out: list[int] = []
        for node, p in enumerate(populations):
            if p < 1:
                raise ValueError("every node needs >=1 rank")
            out.extend([node] * p)
        return NodeMap(tuple(out))

    @property
    def num_ranks(self) -> int:
        return len(self.node_of)

    @property
    def num_nodes(self) -> int:
        return max(self.node_of) + 1

    def population(self, node: int) -> int:
        return sum(1 for n in self.node_of if n == node)

    def populations(self) -> tuple[int, ...]:
        return tuple(self.population(n) for n in range(self.num_nodes))

    def leaders(self) -> tuple[int, ...]:
        """Lowest global rank per node (paper: 'the lowest ranking process')."""
        first: dict[int, int] = {}
        for r, n in enumerate(self.node_of):
            first.setdefault(n, r)
        return tuple(first[n] for n in range(self.num_nodes))

    def local_rank(self, rank: int) -> int:
        node = self.node_of[rank]
        return sum(1 for r in range(rank) if self.node_of[r] == node)


# ---------------------------------------------------------------------------
# Allgatherv plan (paper Fig. 4: counts / displacements, computed one-off).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GatherPlan:
    """Bridge-exchange plan for the induced irregular allgather.

    Every rank contributes ``elem_per_rank`` elements.  Node ``k``'s shared
    buffer region holds the concatenation of its ranks' contributions; the
    bridge allgatherv exchanges whole node regions between leaders.
    """

    node_map: NodeMap
    elem_per_rank: int

    @property
    def total_elems(self) -> int:
        return self.elem_per_rank * self.node_map.num_ranks

    def counts(self) -> tuple[int, ...]:
        """recvcounts of the bridge allgatherv: one entry per node."""
        return tuple(p * self.elem_per_rank
                     for p in self.node_map.populations())

    def displs(self) -> tuple[int, ...]:
        """Displacements of each node's region in the shared result buffer."""
        out, acc = [], 0
        for c in self.counts():
            out.append(acc)
            acc += c
        return tuple(out)

    def rank_offset(self, rank: int) -> int:
        """Where rank's private partition starts in the global result buffer.

        This is the paper's ``s_buf + msg*rank`` pointer arithmetic (line 20 of
        Fig. 4) generalized to irregular populations via the node-sorted rank
        order.
        """
        node = self.node_map.node_of[rank]
        return self.displs()[node] + \
            self.node_map.local_rank(rank) * self.elem_per_rank

    def check(self) -> None:
        """Structural invariants (used by hypothesis tests)."""
        counts, displs = self.counts(), self.displs()
        assert sum(counts) == self.total_elems
        assert displs[0] == 0
        for i in range(1, len(displs)):
            assert displs[i] == displs[i - 1] + counts[i - 1]
        offsets = sorted(self.rank_offset(r)
                         for r in range(self.node_map.num_ranks))
        # partitions tile the buffer exactly (no gap, no overlap)
        assert offsets == list(range(0, self.total_elems, self.elem_per_rank))


# ---------------------------------------------------------------------------
# Traffic model (bytes moved per tier).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CollectiveTraffic:
    """Bytes crossing each tier, and result bytes resident per node.

    ``slow_bytes``  — total bytes crossing the network (bridge) tier.
    ``fast_bytes``  — total bytes copied inside nodes (shared-memory tier).
    ``result_bytes_per_node`` — memory footprint of the collective's result
    per node (the paper's C1 memory claim: hybrid keeps ONE copy).
    """

    slow_bytes: int
    fast_bytes: int
    result_bytes_per_node: int


def allgather_traffic(*, scheme: str, num_nodes: int, ranks_per_node: int,
                      bytes_per_rank: int) -> CollectiveTraffic:
    """Traffic for an allgather of ``bytes_per_rank`` from every rank.

    naive (pure MPI, SMP-aware, Fig. 3a): gather to leader (fast), bridge
    exchange (slow), broadcast to children (fast); every rank ends with a
    private full copy.

    hier (paper, Fig. 3b): children write partitions in place (zero copies),
    leaders exchange node regions (slow), result shared once per node.
    """
    P, c, m = num_nodes, ranks_per_node, bytes_per_rank
    n = P * c * m  # full result size
    node_contrib = c * m
    # bridge allgather among P leaders: each leader sends its region to P-1
    # peers (counting bytes leaving a node once per remote destination).
    slow = P * node_contrib * (P - 1)
    if scheme == "naive":
        # fast tier: children->leader aggregation ((c-1) contributions) plus
        # leader->children broadcast of the full result to c-1 children.
        fast = P * ((c - 1) * m + (c - 1) * n)
        result_per_node = c * n  # one private copy per rank
    elif scheme == "hier":
        fast = 0  # partitions written in place in the shared window
        result_per_node = n  # ONE shared copy (paper C1)
    else:
        raise ValueError(f"unknown scheme {scheme!r}")
    return CollectiveTraffic(slow, fast, result_per_node)


def allgatherv_traffic(*, scheme: str, populations: Sequence[int],
                       bytes_per_rank: int) -> CollectiveTraffic:
    """Irregular-population allgather traffic (paper §5.1.3 / Fig. 10).

    Every *present* rank contributes ``bytes_per_rank``; node ``k`` holds
    ``populations[k]`` ranks.  Reduces exactly to ``allgather_traffic`` when
    all populations are equal.  ``result_bytes_per_node`` reports the
    worst-case (largest) node, so C1 reads: naive/hier ratio equals the
    population of the fullest node.
    """
    pops = tuple(populations)
    if not pops or any(p < 1 for p in pops):
        raise ValueError(f"every node needs >=1 rank, got {pops}")
    P, m = len(pops), bytes_per_rank
    n = sum(pops) * m  # full (compact) result size
    # bridge allgatherv among P leaders: node k's region goes to P-1 peers.
    slow = sum(p * m * (P - 1) for p in pops)
    if scheme == "naive":
        fast = sum((p - 1) * m + (p - 1) * n for p in pops)
        result_per_node = max(pops) * n
    elif scheme == "hier":
        fast = 0
        result_per_node = n
    else:
        raise ValueError(f"unknown scheme {scheme!r}")
    return CollectiveTraffic(slow, fast, result_per_node)


def broadcast_traffic(*, scheme: str, num_nodes: int, ranks_per_node: int,
                      msg_bytes: int) -> CollectiveTraffic:
    """Traffic for a broadcast of ``msg_bytes`` from a single root."""
    P, c, n = num_nodes, ranks_per_node, msg_bytes
    slow = (P - 1) * n  # root's node region -> every other leader
    if scheme == "naive":
        fast = P * (c - 1) * n  # leader -> each child's private copy
        result_per_node = c * n
    elif scheme == "hier":
        fast = 0
        result_per_node = n
    else:
        raise ValueError(f"unknown scheme {scheme!r}")
    return CollectiveTraffic(slow, fast, result_per_node)


def allreduce_traffic(*, scheme: str, num_nodes: int, ranks_per_node: int,
                      msg_bytes: int) -> CollectiveTraffic:
    """Traffic for an allreduce (grad-reduction analogue).

    hier: reduce-scatter intra-node (each chip ends with shard), cross-node
    allreduce of shards on the bridge (multi-leader), result stays sharded —
    one copy per node.  naive: flat ring allreduce over all ranks; every rank
    keeps a private full copy.
    """
    P, c, n = num_nodes, ranks_per_node, msg_bytes
    if scheme == "naive":
        R = P * c
        ring = 2 * n * (R - 1)  # total bytes on the ring
        # fraction of ring hops that cross nodes under SMP placement: P/R of
        # the hops are node boundaries.
        slow = ring * (P / R) if P > 1 else 0
        fast = ring - slow
        result_per_node = c * n
    elif scheme == "hier":
        fast = 2 * n * (c - 1) / c * P  # RS + AG inside each node
        slow = 2 * n * (P - 1) / P if P > 1 else 0  # bridge ring on shards
        result_per_node = n
    else:
        raise ValueError(f"unknown scheme {scheme!r}")
    return CollectiveTraffic(int(slow), int(fast), result_per_node)


def reduce_scatter_traffic(*, scheme: str, num_nodes: int,
                           ranks_per_node: int, msg_bytes: int
                           ) -> CollectiveTraffic:
    """Traffic for a reduce-scatter of a ``msg_bytes`` buffer (every rank
    contributes the full buffer; the summed result is scattered).

    naive (flat): one ring reduce-scatter over all R ranks — each rank ends
    with its private 1/R slice, so a node retains only ``msg/num_nodes``
    bytes.  hier: intra-node RS, bridge RS on shards — the node's full
    reduced message stays resident once, sharded over the window (exactly
    the first half of ``allreduce_traffic``'s hier cycle).
    """
    P, c, n = num_nodes, ranks_per_node, msg_bytes
    if scheme == "naive":
        R = P * c
        ring = n * (R - 1)               # total bytes on the flat RS ring
        slow = ring * (P / R) if P > 1 else 0
        fast = ring - slow
        result_per_node = n // P
    elif scheme == "hier":
        fast = n * (c - 1) / c * P       # RS inside each node
        slow = n * (P - 1) / P if P > 1 else 0  # bridge ring on shards
        result_per_node = n
    else:
        raise ValueError(f"unknown scheme {scheme!r}")
    return CollectiveTraffic(int(slow), int(fast), result_per_node)


def alltoall_traffic(*, scheme: str, num_nodes: int, ranks_per_node: int,
                     bytes_per_pair: int) -> CollectiveTraffic:
    """Traffic for a personalized all-to-all: every rank sends a distinct
    ``bytes_per_pair`` message to every rank (its own chunk stays local).

    All-to-all results are inherently rank-private, so there is NO shared-
    copy saving on the result (C1 does not apply): ``result_bytes_per_node``
    is the same for both schemes.  The hybrid win is elsewhere — C2-style
    zero intra-node copy bytes (on-node chunks are exchanged through the
    shared segment in place) and node-aggregated bridge messages (P
    superchunk messages per node pair instead of c*c rank pairs).

    naive (pure MPI): every cross-node rank pair ships its chunk on the
    network; intra-node pairs copy through per-rank private buffers.

    hier (node-aware two-phase): node superchunks cross the bridge exactly
    once per node pair — identical network bytes (the data is all distinct;
    aggregation saves messages, not bytes) — and the intra-node
    redistribution happens in the shared window with zero copy bytes.
    """
    P, c, m = num_nodes, ranks_per_node, bytes_per_pair
    slow = P * (P - 1) * c * c * m       # cross-node rank pairs, counted once
    if scheme == "naive":
        fast = P * c * (c - 1) * m       # intra-node non-self pairs
    elif scheme == "hier":
        fast = 0                         # exchanged in the shared segment
    else:
        raise ValueError(f"unknown scheme {scheme!r}")
    result_per_node = c * (P * c) * m    # every rank keeps its private R*m
    return CollectiveTraffic(slow, fast, result_per_node)


# ---------------------------------------------------------------------------
# Size buckets (the tuning-table key space).
# ---------------------------------------------------------------------------

def size_bucket(nbytes: int) -> int:
    """Power-of-two bucket id of a message size: ``round(log2(nbytes))``.

    The tuning table (``repro.comm.tuning``) keys measured cells by bucket
    rather than exact bytes, so a lookup at an unmeasured size lands on the
    geometrically-nearest measured cell.  Sizes <= 1 byte share bucket 0.
    """
    if nbytes <= 1:
        return 0
    return int(round(math.log2(nbytes)))


def nearest_bucket(nbytes: int, available: Sequence[int]) -> int:
    """The member of ``available`` (bucket ids) nearest to ``nbytes``'s own
    bucket; ties break toward the SMALLER bucket (under-provisioning a
    scheme choice is cheaper than over-committing to a large-message
    winner).  Raises on an empty candidate set."""
    if not available:
        raise ValueError("no buckets to pick from")
    b = size_bucket(nbytes)
    return min(available, key=lambda a: (abs(a - b), a))


def collective_time_model(traffic: CollectiveTraffic, *, num_nodes: int,
                          ranks_per_node: int, fast_bw: float = 100e9,
                          slow_bw: float = 25e9) -> float:
    """Crude alpha-free time model: per-tier bytes / per-tier bandwidth.

    Used only for benchmark 'derived' columns — real numbers come from the
    dry-run roofline.
    """
    slow_t = (traffic.slow_bytes / max(num_nodes, 1)) / slow_bw
    fast_t = (traffic.fast_bytes / max(num_nodes * ranks_per_node, 1)) / fast_bw
    return slow_t + fast_t


# ---------------------------------------------------------------------------
# Pipelined (chunked two-phase) latency model — the overlap term.
# ---------------------------------------------------------------------------

def pipelined_time_model(traffic: CollectiveTraffic, *, n_chunks: int,
                         num_nodes: int, ranks_per_node: int,
                         fast_bw: float = 100e9, slow_bw: float = 25e9,
                         alpha: float = 0.0) -> float:
    """Latency of the chunked two-phase schedule with bridge/on-node overlap.

    The message is split into ``n_chunks`` segments; the bridge (slow) stage
    of segment *k* runs concurrently with the on-node (fast) stage of
    segment *k+1* (double-buffered window).  With per-segment tier times
    ``tf = T_fast/n`` and ``ts = T_slow/n``, the classic software-pipeline
    fill/drain formula applies::

        T(n) = tf + ts + (n - 1) * max(tf, ts) + n * alpha

    ``alpha`` is a fixed per-segment startup cost (chunking is not free);
    with ``alpha == 0`` the model is monotone non-increasing in ``n`` and
    approaches ``max(T_fast, T_slow)`` — the overlap win the paper's
    companion study (Zhou et al., arXiv:2007.11496) measures.  Exactly the
    serial ``collective_time_model`` at ``n_chunks == 1, alpha == 0``.
    """
    if n_chunks < 1:
        raise ValueError(f"n_chunks must be >= 1, got {n_chunks}")
    slow_t = (traffic.slow_bytes / max(num_nodes, 1)) / slow_bw
    fast_t = (traffic.fast_bytes / max(num_nodes * ranks_per_node, 1)) \
        / fast_bw
    tf, ts = fast_t / n_chunks, slow_t / n_chunks
    return tf + ts + (n_chunks - 1) * max(tf, ts) + n_chunks * alpha


def overlap_efficiency(traffic: CollectiveTraffic, *, n_chunks: int,
                       num_nodes: int, ranks_per_node: int,
                       fast_bw: float = 100e9, slow_bw: float = 25e9
                       ) -> float:
    """Serial / pipelined time ratio (>= 1; == 1 when one tier is empty or
    ``n_chunks == 1``).  Upper-bounded by 2 (perfectly balanced tiers,
    infinite chunks)."""
    serial = collective_time_model(traffic, num_nodes=num_nodes,
                                   ranks_per_node=ranks_per_node,
                                   fast_bw=fast_bw, slow_bw=slow_bw)
    pipe = pipelined_time_model(traffic, n_chunks=n_chunks,
                                num_nodes=num_nodes,
                                ranks_per_node=ranks_per_node,
                                fast_bw=fast_bw, slow_bw=slow_bw)
    return serial / pipe if pipe > 0 else 1.0


# ---------------------------------------------------------------------------
# Schedule-level cost model (the step-graph optimizer's pricing).
# ---------------------------------------------------------------------------

#: Fixed per-message dispatch cost of the schedule model: collective launch +
#: rendezvous overhead that the per-byte bandwidth terms cannot see.  This is
#: the term bucketing amortizes — N tiny allreduces pay N alphas, one packed
#: bucket pays one.
SCHEDULE_ALPHA = 5e-6


def schedule_time(message_bytes: Sequence[int], *, num_nodes: int,
                  ranks_per_node: int, scheme: str = "hier",
                  fast_bw: float = 100e9, slow_bw: float = 25e9,
                  alpha: float = SCHEDULE_ALPHA) -> float:
    """Latency of a whole schedule of allreduce messages issued back-to-back.

    Each message is priced by ``collective_time_model`` over its
    ``allreduce_traffic`` closed form, plus a fixed per-message ``alpha``
    (launch/rendezvous cost).  The sum is the serial model — the step-graph
    optimizer compares *schedules* (many small messages vs few packed ones),
    so the per-message constant is the load-bearing term: bandwidth bytes
    are conserved by packing, alphas are not.
    """
    total = 0.0
    for m in message_bytes:
        tr = allreduce_traffic(scheme=scheme, num_nodes=num_nodes,
                               ranks_per_node=ranks_per_node, msg_bytes=m)
        total += collective_time_model(tr, num_nodes=num_nodes,
                                       ranks_per_node=ranks_per_node,
                                       fast_bw=fast_bw, slow_bw=slow_bw)
        total += alpha
    return total


def greedy_buckets(sizes: Sequence[int],
                   target_bytes: int) -> tuple[tuple[int, ...], ...]:
    """Order-preserving greedy partition of message indices into buckets.

    Items are packed in program order; a bucket closes once its byte total
    reaches ``target_bytes`` (an item larger than the target gets a bucket
    of its own).  Order preservation matters: the packed buffer's layout is
    the issue order, so gradients produced early fill early buckets and the
    first reduction can issue before the last leaf exists.
    """
    if target_bytes < 1:
        raise ValueError(f"target_bytes must be >= 1, got {target_bytes}")
    buckets: list[tuple[int, ...]] = []
    cur: list[int] = []
    cur_bytes = 0
    for i, s in enumerate(sizes):
        if s < 0:
            raise ValueError(f"negative message size {s} at index {i}")
        cur.append(i)
        cur_bytes += s
        if cur_bytes >= target_bytes:
            buckets.append(tuple(cur))
            cur, cur_bytes = [], 0
    if cur:
        buckets.append(tuple(cur))
    return tuple(buckets)


def _pad_up(nbytes: int, pad_to: int) -> int:
    if pad_to <= 1:
        return nbytes
    return ((nbytes + pad_to - 1) // pad_to) * pad_to


def bucket_time_model(sizes: Sequence[int], target_bytes: int, *,
                      num_nodes: int, ranks_per_node: int,
                      scheme: str = "hier", pad_to: int = 1,
                      fast_bw: float = 100e9, slow_bw: float = 25e9,
                      alpha: float = SCHEDULE_ALPHA) -> float:
    """``schedule_time`` of the bucketed schedule: the messages are packed
    by ``greedy_buckets(sizes, target_bytes)``, each bucket padded up to a
    multiple of ``pad_to`` bytes (the reduction scheme's tiling divisor),
    and the packed buckets priced as the schedule.  Padding is a real cost
    the model must see: an oversized target with a coarse ``pad_to`` can
    lose to smaller buckets."""
    packed = []
    for bucket in greedy_buckets(sizes, target_bytes):
        packed.append(_pad_up(sum(sizes[i] for i in bucket), pad_to))
    return schedule_time(packed, num_nodes=num_nodes,
                         ranks_per_node=ranks_per_node, scheme=scheme,
                         fast_bw=fast_bw, slow_bw=slow_bw, alpha=alpha)


#: Candidate bucket targets swept by ``best_bucket_bytes`` — spans the
#: tuning table's measured size range (2**10..2**22 bytes) so the picked
#: sweet spot always lands on (or near) a measured cell.
BUCKET_BYTES_CANDIDATES = (1 << 14, 1 << 16, 1 << 18, 1 << 20, 1 << 22)


def best_bucket_bytes(sizes: Sequence[int], *, num_nodes: int,
                      ranks_per_node: int, scheme: str = "hier",
                      pad_to: int = 1,
                      candidates: Sequence[int] = BUCKET_BYTES_CANDIDATES,
                      fast_bw: float = 100e9, slow_bw: float = 25e9,
                      alpha: float = SCHEDULE_ALPHA) -> int:
    """Model-predicted bucket target: argmin of ``bucket_time_model`` over
    ``candidates`` (ties toward the smaller target — smaller buckets free
    their operands earlier).  The step-graph optimizer seeds this with the
    tuning table's measured sweet spot when one exists; the model decides
    only off-table."""
    if not candidates:
        raise ValueError("no bucket-size candidates")
    return min(candidates,
               key=lambda t: (bucket_time_model(
                   sizes, t, num_nodes=num_nodes,
                   ranks_per_node=ranks_per_node, scheme=scheme,
                   pad_to=pad_to, fast_bw=fast_bw, slow_bw=slow_bw,
                   alpha=alpha), t))


def best_chunk_count(traffic: CollectiveTraffic, *, num_nodes: int,
                     ranks_per_node: int, candidates: Sequence[int] = (1, 2,
                                                                       4, 8),
                     fast_bw: float = 100e9, slow_bw: float = 25e9,
                     alpha: float = 1e-6) -> int:
    """Model-predicted chunk count: argmin of ``pipelined_time_model`` over
    ``candidates`` (ties go to the smaller count).  The bench autotune
    measures instead of trusting this — the model seeds the sweep order."""
    return min(candidates,
               key=lambda n: (pipelined_time_model(
                   traffic, n_chunks=n, num_nodes=num_nodes,
                   ranks_per_node=ranks_per_node, fast_bw=fast_bw,
                   slow_bw=slow_bw, alpha=alpha), n))
