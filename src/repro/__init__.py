"""repro: hierarchical MPI+MPI-style collectives as a multi-pod JAX framework."""

__version__ = "1.0.0"
