"""Communicator: the two-tier communicator as a first-class object.

The paper's setup is ``MPI_Comm_split_type(COMM_TYPE_SHARED)``: the world
communicator splits into a *node* communicator (ranks sharing memory — the
fast tier) and a *bridge* communicator (one leader per node — the slow
tier).  ``Communicator`` carries exactly that structure for a jax mesh:

* ``fast_axis`` — intra-pod tier (ICI / shared memory); one name or a tuple;
* ``slow_axis`` — cross-pod tier (DCN / network), ``None`` on a single node;
* static ``pods``/``chips`` counts when known (rank maps, plan algebra);
* collective methods (``allgather``/``allgatherv``/``broadcast``/
  ``allreduce``/``reduce_scatter``/``alltoall``) that dispatch through the
  scheme registry — ``scheme="naive" | "hier" | "shared" | <future entry>``
  replaces the old per-scheme free functions.

``scheme="auto"`` (the default) resolves the scheme per call through
``repro.comm.tuning``: the committed tuning table where the (family,
topology, size) cell was measured, the ``core.plans`` closed forms where it
was not (see that module's measured -> modeled -> fallback chain).  Because
schemes differ in result CLASS (replicated array vs ``SharedWindow``), call
sites that can only consume one class pass ``result="replicated"`` /
``result="shared"`` — a constraint on the pick, not a scheme name.
Resolution happens at trace time; the lowered program is bit-identical to
calling the chosen concrete scheme directly.

Shared-scheme results come back as a ``SharedWindow`` (ONE copy per node,
sharded over the fast tier) whose ``read()``/``fence()`` carry the paper's
synchronization-epoch semantics; replicated schemes return plain arrays.
Exception: ``allgatherv`` always returns raw ``(blocks, counts)`` — the
irregular result is mediated by ``core.plans.GatherPlan`` compaction, not
by a window.

All methods are shard_map-body operations: call them on local shards inside
a ``shard_map`` (e.g. via ``VirtualCluster.run``/``smap``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import jax

from repro.comm import primitives as p
from repro.comm import registry
from repro.comm.window import SharedWindow
from repro.core.plans import NodeMap

Axis = Union[str, Sequence[str]]


def _norm(ax: Optional[Axis]):
    if ax is None:
        return None
    if isinstance(ax, (tuple, list)):
        ax = tuple(ax)
        if not ax:
            return None
        return ax if len(ax) > 1 else ax[0]
    return ax


@dataclasses.dataclass(frozen=True)
class Communicator:
    """Two-tier communicator over mesh axis names.

    ``pods``/``chips`` are optional static counts: in-trace collectives work
    without them, but rank maps (``node_map``) and rank-order reads need
    them.  Construct via ``from_cluster`` (tests/bench) or
    ``from_topology`` (production meshes) to get them filled in.
    """

    fast_axis: Axis
    slow_axis: Optional[Axis] = None
    pods: Optional[int] = None
    chips: Optional[int] = None

    def __post_init__(self):
        fast = _norm(self.fast_axis)
        if fast is None:
            raise ValueError("Communicator needs a fast_axis (the node tier)")
        object.__setattr__(self, "fast_axis", fast)
        object.__setattr__(self, "slow_axis", _norm(self.slow_axis))

    # -- constructors --------------------------------------------------------
    @classmethod
    def from_cluster(cls, vc) -> "Communicator":
        """From a ``repro.substrate.VirtualCluster`` (its ``slow`` is already
        ``None`` for single-node shapes)."""
        return cls(fast_axis=vc.fast, slow_axis=vc.slow, pods=vc.pods,
                   chips=vc.chips)

    @classmethod
    def from_topology(cls, topo) -> "Communicator":
        """From a ``repro.core.topology.MeshTopology``: fast tier = every
        non-slow axis, slow tier = the pod axes present."""
        slow = tuple(a for a in topo.slow_axes if a in topo.axis_sizes)
        return cls(fast_axis=topo.fast_axes, slow_axis=slow or None,
                   pods=topo.num_pods, chips=topo.chips_per_pod)

    # -- structure -----------------------------------------------------------
    @property
    def slow(self) -> Optional[Axis]:
        return self.slow_axis

    @property
    def axes(self) -> tuple[str, ...]:
        """Every mesh axis this communicator spans, slow tier first (the
        ``lax.psum`` order of the naive lowering)."""
        return (p._axes(self.slow_axis) if self.slow_axis else ()) + \
            tuple(p._axes(self.fast_axis))

    @property
    def num_nodes(self) -> Optional[int]:
        return self.pods

    @property
    def ranks_per_node(self) -> Optional[int]:
        return self.chips

    @property
    def num_ranks(self) -> Optional[int]:
        if self.pods is None or self.chips is None:
            return None
        return self.pods * self.chips

    @property
    def signature(self) -> Optional[str]:
        """Tuning-table topology signature (``None`` without static
        pods/chips counts).  An elastic rebuild changes this key — the
        re-resolution of ``scheme="auto"`` against the tuning table hangs
        off it (``repro.comm.tuning.retune_for``)."""
        if self.pods is None or self.chips is None:
            return None
        from repro.comm import tuning
        return tuning.signature_for(self)

    @property
    def node_map(self) -> NodeMap:
        """SMP rank->node assignment (``core.plans`` algebra)."""
        if self.pods is None or self.chips is None:
            raise ValueError("node_map needs static pods/chips counts")
        return NodeMap.smp(self.pods, self.chips)

    def split_type_shared(self) -> "Communicator":
        """The node communicator of ``MPI_Comm_split_type(COMM_TYPE_SHARED)``:
        same fast tier, no bridge."""
        return Communicator(fast_axis=self.fast_axis, slow_axis=None,
                            pods=1, chips=self.chips)

    def bridge(self) -> "Communicator":
        """The leaders' bridge communicator: the slow tier as a flat
        single-tier communicator (multi-leader: every chip participates in
        its own shard's bridge exchange)."""
        if self.slow_axis is None:
            raise ValueError("single-node communicator has no bridge tier")
        return Communicator(fast_axis=self.slow_axis, slow_axis=None,
                            pods=1, chips=self.pods)

    # -- in-trace indices ----------------------------------------------------
    def rank(self) -> jax.Array:
        """Flat SMP rank, (pod, chip) row-major — the broadcast root
        numbering."""
        names = (p._axes(self.slow_axis) if self.slow_axis else ()) + \
            p._axes(self.fast_axis)
        return p.axis_index(names)

    def local_rank(self) -> jax.Array:
        return p.axis_index(self.fast_axis)

    def node_rank(self) -> jax.Array:
        if self.slow_axis is None:
            import jax.numpy as jnp
            return jnp.zeros((), jnp.int32)
        return p.axis_index(self.slow_axis)

    # -- dispatch ------------------------------------------------------------
    def _auto_elems(self, family: str, x) -> int:
        """Per-rank payload elems — the tuning table's size normalization
        (alltoall cells are keyed per PAIR, and the local buffer holds one
        chunk per rank)."""
        n = int(x.size)
        if family == "alltoall" and self.num_ranks:
            n = max(1, n // self.num_ranks)
        return n

    def _resolve(self, family: str, scheme: str, x, opts: dict,
                 result: Optional[str], precision: str = "exact",
                 tol: Optional[float] = None) -> tuple[str, dict]:
        """Turn ``scheme="auto"`` into a concrete registry entry (plus its
        recorded tunables; explicit caller opts win).  A concrete scheme
        passes through — but still checked against ``result`` and
        ``precision`` so a constraint can never be silently violated."""
        if scheme != "auto":
            sch = registry.get_scheme(scheme)
            if result is not None and sch.result_class != result:
                raise ValueError(
                    f"scheme {scheme!r} is "
                    f"{sch.result_class}-class but "
                    f"the call requires result={result!r}")
            if sch.precision == "lossy" and precision != "lossy":
                raise ValueError(
                    f"scheme {scheme!r} is lossy but the call did not opt "
                    f"in with precision='lossy'")
            return scheme, opts
        from repro.comm import tuning
        import numpy as np
        dt = np.dtype(x.dtype)
        res = tuning.resolve_for(
            self, family, elems=self._auto_elems(family, x),
            elem_bytes=dt.itemsize, dtype=dt.name, result_class=result,
            precision=precision, tol=tol)
        return res.scheme, {**res.opts, **opts}

    def _call(self, family: str, scheme: str, *args, **kw):
        sch = registry.get_scheme(scheme)
        return sch, sch.op(family)(*args, fast=self.fast_axis,
                                   slow=self.slow_axis, **kw)

    def _wrap(self, sch, out, axis: int):
        if sch.result_class == "shared":
            return SharedWindow(self, out, axis=axis, epoch=1)
        return out

    def allgather(self, x: jax.Array, *, scheme: str = "auto",
                  axis: int = 0, result: Optional[str] = None,
                  precision: str = "exact", tol: Optional[float] = None,
                  **opts):
        """Gather every rank's contribution.  Replicated schemes return the
        full rank-ordered buffer; ``shared`` returns the node's
        ``SharedWindow`` (chip *i* holds shard *i*, (local, pod) order).
        ``**opts`` are scheme tunables (e.g. ``pipelined``'s
        ``n_chunks=``); ``result=`` constrains an ``"auto"`` pick to one
        result class; ``precision="lossy"`` admits quantized wire formats
        (``tol=`` caps their relative error bound)."""
        scheme, opts = self._resolve("allgather", scheme, x, opts, result,
                                     precision, tol)
        sch, out = self._call("allgather", scheme, x, axis=axis, **opts)
        return self._wrap(sch, out, axis)

    def allgatherv(self, x_padded: jax.Array, valid: jax.Array, *,
                   scheme: str = "auto", axis: int = 0,
                   result: Optional[str] = None, precision: str = "exact",
                   tol: Optional[float] = None, **opts):
        """Irregular allgather (padded blocks + valid counts).

        The one family that returns raw ``(blocks, counts)`` for EVERY
        scheme — never a ``SharedWindow``: the irregular result is
        plan-mediated (compaction via ``core.plans.GatherPlan``), not
        window-mediated, matching the paper's counts/displs one-off.
        NOTE the two result classes still differ in block LAYOUT
        (rank-major vs node regions), so auto callers either handle both
        or pass ``result=``."""
        scheme, opts = self._resolve("allgatherv", scheme, x_padded, opts,
                                     result, precision, tol)
        _, out = self._call("allgatherv", scheme, x_padded, valid, axis=axis,
                            **opts)
        return out

    def broadcast(self, x: jax.Array, *, root: int = 0,
                  scheme: str = "auto", axis: int = 0,
                  result: Optional[str] = None, precision: str = "exact",
                  tol: Optional[float] = None, **opts):
        """Broadcast from the flat SMP rank ``root`` (pod, chip row-major).
        ``shared`` returns the node's ``SharedWindow`` of the message."""
        scheme, opts = self._resolve("broadcast", scheme, x, opts, result,
                                     precision, tol)
        sch, out = self._call("broadcast", scheme, x, root=root, axis=axis,
                              **opts)
        return self._wrap(sch, out, axis)

    def allreduce(self, x: jax.Array, *, scheme: str = "auto",
                  axis: int = 0, result: Optional[str] = None,
                  precision: str = "exact", tol: Optional[float] = None,
                  error_feedback=None, **opts):
        """Global sum.  Replicated schemes return the full sum per rank;
        ``shared`` returns it once per node as a ``SharedWindow``.

        ``precision="lossy"`` admits quantized wire formats; with
        ``error_feedback=`` (the carried residual, ``jnp.float32(0)`` to
        start) the call returns ``(sum, new_residual)`` so the local
        quantization error re-enters the next step's payload — the error-
        feedback loop of the gradient bridge.  An exact pick under
        ``"lossy"`` simply absorbs the residual and carries zero."""
        scheme, opts = self._resolve("psum", scheme, x, opts, result,
                                     precision, tol)
        if error_feedback is not None:
            if precision != "lossy":
                raise ValueError(
                    "error_feedback requires precision='lossy'")
            import jax.numpy as jnp
            if registry.get_scheme(scheme).precision == "lossy":
                sch, pair = self._call("psum", scheme, x, axis=axis,
                                       err=error_feedback, **opts)
                out, new_err = pair
            else:
                sch, out = self._call("psum", scheme, x + error_feedback,
                                      axis=axis, **opts)
                new_err = jnp.zeros((), jnp.float32)
            return self._wrap(sch, out, axis), new_err
        sch, out = self._call("psum", scheme, x, axis=axis, **opts)
        return self._wrap(sch, out, axis)

    def reduce_scatter(self, x: jax.Array, *, scheme: str = "auto",
                       axis: int = 0, result: Optional[str] = None,
                       precision: str = "exact", tol: Optional[float] = None,
                       **opts):
        """Sum + scatter.  ``naive``/``pipelined``: every rank gets its flat
        1/R slice; ``shared``: the node's window shards (1/c each,
        bridge-reduced)."""
        scheme, opts = self._resolve("reduce_scatter", scheme, x, opts,
                                     result, precision, tol)
        sch, out = self._call("reduce_scatter", scheme, x, axis=axis, **opts)
        return self._wrap(sch, out, axis)

    def alltoall(self, x: jax.Array, *, scheme: str = "auto", axis: int = 0,
                 result: Optional[str] = None, precision: str = "exact",
                 tol: Optional[float] = None, **opts):
        """Personalized exchange: the local buffer along ``axis`` is R rank-
        ordered chunks; chunk *s* goes to rank *s*.  ``hier`` routes node
        superchunks over the bridge once (P messages instead of P*c), with
        identical results."""
        scheme, opts = self._resolve("alltoall", scheme, x, opts, result,
                                     precision, tol)
        _, out = self._call("alltoall", scheme, x, axis=axis, **opts)
        return out

    # -- async (issue-early / resolve-late) -----------------------------------
    def allgather_async(self, x: jax.Array, *, scheme: str = "auto",
                        axis: int = 0, **opts):
        """Issue the gather now, consume later: returns an
        ``AsyncCollectiveHandle`` whose ``resolve()`` yields the full node
        buffer ((local, pod) order, same as ``SharedWindow.read``).  The
        pick is constrained to the shared result class — the window IS the
        async object; its epoch stands in for the CUDA event, and a store
        between issue and resolve makes ``resolve()`` raise
        ``WindowEpochError`` instead of returning torn bytes."""
        from repro.comm.handle import AsyncCollectiveHandle
        win = self.allgather(x, scheme=scheme, axis=axis, result="shared",
                             **opts)
        return AsyncCollectiveHandle.issue("allgather", win)

    # -- fused collective-matmul (compute overlap) ----------------------------
    def ag_matmul(self, x: jax.Array, w_shard: jax.Array, *,
                  n_chunks: int = 2, use_kernel: bool = False,
                  precision: str = "exact", q4_group: int = 32):
        """``x @ read(window)`` fused: the node-tier gather of the
        contraction-sharded weight streams behind the panel matmuls
        (``repro.comm.pipeline.ag_matmul``).  ``precision="lossy"``
        gathers the weight panels as packed int4 (group size
        ``q4_group``) and dequantizes inside the matmul."""
        from repro.comm import pipeline
        if precision == "lossy":
            return pipeline.ag_matmul_q4(x, w_shard,
                                         fast_axis=self.fast_axis,
                                         n_chunks=n_chunks, group=q4_group,
                                         use_kernel=use_kernel)
        return pipeline.ag_matmul(x, w_shard, fast_axis=self.fast_axis,
                                  n_chunks=n_chunks, use_kernel=use_kernel)

    def ag_matmul_rows(self, a_shard: jax.Array, b: jax.Array, *,
                       n_chunks: int = 2, use_kernel: bool = False):
        """``read(window) @ b`` fused, window sharded along OUTPUT rows
        (e.g. the SUMMA A-panel): per-chunk row panels, no accumulation."""
        from repro.comm import pipeline
        return pipeline.ag_matmul_rows(a_shard, b, fast_axis=self.fast_axis,
                                       n_chunks=n_chunks,
                                       use_kernel=use_kernel)

    def matmul_rs(self, x: jax.Array, w: jax.Array, *, axis: int = 0,
                  n_chunks: int = 2, use_kernel: bool = False):
        """``reduce_scatter(x @ w)`` over the fast tier fused: the scatter
        of panel *k* overlaps the matmul of panel *k+1*."""
        from repro.comm import pipeline
        return pipeline.matmul_rs(x, w, axis_name=self.fast_axis,
                                  scatter_dim=axis, n_chunks=n_chunks,
                                  use_kernel=use_kernel)

    # -- windows & sync -------------------------------------------------------
    def window(self, shard: jax.Array, *, axis: int = 0,
               epoch: int = 0) -> SharedWindow:
        """Wrap an existing node-sharded buffer as a ``SharedWindow``."""
        return SharedWindow(self, shard, axis=axis, epoch=epoch)

    def barrier(self, token: jax.Array) -> jax.Array:
        """Heavy-weight world barrier (``core.sync.barrier`` over both
        tiers)."""
        from repro.core import sync
        names = (p._axes(self.slow_axis) if self.slow_axis else ()) + \
            p._axes(self.fast_axis)
        return sync.barrier(token, names)

    def bridge_psum(self, x):
        """The multi-leader gradient bridge: psum over the slow tier only
        (intra-node reduction already happened via the window transpose).
        Identity on a single node."""
        if self.slow_axis is None:
            return x
        from jax import lax
        return lax.psum(x, p._axes(self.slow_axis))

    # -- step-graph optimizer -------------------------------------------------
    def record(self, *, table=None):
        """Open a step-graph recording against this communicator: record
        collectives (``rec.allreduce``/``rec.gather``), get ``Deferred``
        refs back, then ``rec.run()`` to bucket/dedup/reorder the whole
        schedule and resolve the refs (``repro.comm.stepgraph``)."""
        from repro.comm.stepgraph import GraphRecorder
        return GraphRecorder(self, table=table)

    def apply_schedule(self, schedule, values: dict) -> dict:
        """Execute an already-optimized ``stepgraph.Schedule`` against this
        communicator (``values``: nid -> operand; returns nid -> result)."""
        from repro.comm import stepgraph
        return stepgraph.apply_schedule(self, schedule, values)
