"""Pipelined (chunked two-phase) collectives + fused collective-matmul.

The plain ``hier`` schedule serializes the bridge (slow-axis) stage behind
the on-node (fast-axis) stage: no byte crosses pods until the whole node
region is assembled.  The paper's companion study (Zhou et al.,
arXiv:2007.11496) closes that gap by *segmenting* the message: split it
into ``n_chunks`` pieces and software-pipeline the bridge stage of chunk
*k* against the on-node stage of chunk *k+1*.

Every primitive here produces bit-identical results to its unchunked
``naive``/``hier`` counterpart (the chunk split/merge is pure local layout
algebra) and moves exactly the same total link bytes — chunking only
re-schedules them, which is why the ``pipelined`` registry entry reuses the
``hier`` closed forms.  The latency win is modeled by
``core.plans.pipelined_time_model`` and *measured* by the bench autotune
sweep (``n_chunks`` is a registry tunable).

Integrity discipline: each chunk's staged intermediate lives in one of TWO
alternating ``SharedWindow`` epochs (double buffering, the paper's §6 rule
applied per segment).  A chunk's store into buffer *b* is ordered after the
previous occupant of *b* was fully consumed (``fence_local`` — an
``optimization_barrier`` dependency, zero wire bytes), so the pipeline
never holds more than two segments in flight and a read of a still-dirty
buffer raises ``WindowEpochError`` instead of serving torn data.

The fused ``ag_matmul`` / ``matmul_rs`` primitives apply the same chunking
to compute overlap: per-chunk gather/scatter interleaved with the panel
matmul (``repro.kernels`` Pallas kernel or ``jnp.matmul``), double-buffered
the same way.
"""

from __future__ import annotations

from types import SimpleNamespace
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.comm import primitives as p
from repro.comm.window import SharedWindow

DEFAULT_CHUNKS = 2


# ---------------------------------------------------------------------------
# Chunk layout algebra (pure local reshapes — zero wire bytes)
# ---------------------------------------------------------------------------

def _split_blocked(x: jax.Array, axis: int, n_chunks: int) -> list[jax.Array]:
    """Contiguous split of ``x`` along ``axis`` into ``n_chunks`` pieces."""
    n = x.shape[axis]
    if n_chunks < 1 or n % n_chunks:
        raise ValueError(f"cannot split dim {n} into n_chunks={n_chunks}")
    return jnp.split(x, n_chunks, axis=axis)


def _split_strided(x: jax.Array, axis: int, n_chunks: int, blocks: int
                   ) -> list[jax.Array]:
    """Strided split: view ``axis`` as (blocks, n_chunks, piece); chunk *j*
    is every block's *j*-th piece (the reduce-scatter pre-interleave)."""
    moved = jnp.moveaxis(x, axis, 0)
    n = moved.shape[0]
    if n_chunks < 1 or n % (blocks * n_chunks):
        raise ValueError(f"cannot stride dim {n} over blocks={blocks} x "
                         f"n_chunks={n_chunks}")
    piece = n // (blocks * n_chunks)
    r = moved.reshape((blocks, n_chunks, piece) + moved.shape[1:])
    return [jnp.moveaxis(r[:, j].reshape((blocks * piece,) + moved.shape[1:]),
                         0, axis) for j in range(n_chunks)]


def _merge_strided(parts: list[jax.Array], axis: int, blocks: int
                   ) -> jax.Array:
    """Inverse of ``_split_strided``: part *j* holds every block's *j*-th
    piece; the merge restores block-major (e.g. rank-major) element order."""
    moved = [jnp.moveaxis(q, axis, 0) for q in parts]
    nc = len(moved)
    if nc == 1:
        return parts[0]
    piece = moved[0].shape[0] // blocks
    rest = moved[0].shape[1:]
    r = jnp.stack([m.reshape((blocks, piece) + rest) for m in moved], axis=1)
    return jnp.moveaxis(r.reshape((blocks * nc * piece,) + rest), 0, axis)


def _merge_blocked(parts: list[jax.Array], axis: int) -> jax.Array:
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=axis)


# ---------------------------------------------------------------------------
# The double-buffered two-phase pipeline driver
# ---------------------------------------------------------------------------

def _token_after(x) -> jax.Array:
    """A scalar token data-dependent on ``x`` (optimization_barrier joins
    the tuple, never arithmetic on the payload)."""
    _, tok = lax.optimization_barrier((x, jnp.ones((), jnp.float32)))
    return tok


def _node_comm(fast_axis) -> SimpleNamespace:
    """Minimal node-communicator view for a staged ``SharedWindow`` (a real
    ``Communicator`` would be an import cycle: registry -> pipeline)."""
    return SimpleNamespace(fast_axis=fast_axis, slow_axis=None,
                           pods=None, chips=None)


def two_phase_pipeline(chunks: list[jax.Array], *, stage_a: Callable,
                       stage_b: Callable, fast_axis, axis: int
                       ) -> list[jax.Array]:
    """Run ``stage_b(stage_a(chunk))`` per chunk with double-buffered window
    epochs between the stages.

    ``stage_a`` of chunk *k* and ``stage_b`` of chunk *k-1* share no data
    dependency, so the compiler is free to overlap them (the software
    pipeline).  The only added ordering is the two-buffer reuse rule: the
    epoch of chunk *k* (buffer ``k % 2``) opens after chunk *k-2*'s stage_b
    consumed that buffer.  That ordering is ``optimization_barrier``-
    threaded — zero wire bytes, values bit-preserved — and is emitted ONLY
    where the constraint binds (``k >= 2``): a fresh buffer's epoch closes
    by dataflow alone, so ``n_chunks <= 2`` lowers with no barriers at all
    and ``n_chunks == 1`` is bit- and schedule-identical to the unchunked
    two-phase path.
    """
    import dataclasses as _dc

    comm = _node_comm(fast_axis)
    n = len(chunks)
    free: list[Optional[jax.Array]] = [None, None]
    outs = []
    for k, ck in enumerate(chunks):
        b = k % 2
        staged = stage_a(ck)
        win = SharedWindow(comm, staged, axis=axis, epoch=k, dirty=True)
        if free[b] is not None:
            # buffer b reusable only once its previous occupant was consumed
            win = win.fence_local(free[b])
        else:
            # fresh buffer: XLA dataflow already orders store before read —
            # close the epoch with bookkeeping only (no barrier, no copy)
            win = _dc.replace(win, dirty=False, epoch=k + 1)
        out = stage_b(win.shard)
        if k + 2 < n:                 # someone will reuse this buffer
            free[b] = _token_after(out)
        outs.append(out)
    return outs


# ---------------------------------------------------------------------------
# Pipelined collective primitives (bit-identical to the hier/naive results)
# ---------------------------------------------------------------------------

def pipelined_all_gather(x: jax.Array, *, fast_axis, slow_axis=None,
                         axis: int = 0, n_chunks: int = DEFAULT_CHUNKS
                         ) -> jax.Array:
    """Chunked two-phase allgather == ``hier_all_gather`` bit-for-bit.

    Per chunk: intra-pod gather (stage a), bridge exchange of the node
    region (stage b).  The merge interleaves per-chunk rank-major results
    back into the unchunked rank-major order.
    """
    chunks = _split_blocked(x, axis, n_chunks)
    ranks = p.axis_size(fast_axis) * (p.axis_size(slow_axis)
                                      if slow_axis is not None else 1)

    def stage_a(ck):
        return lax.all_gather(ck, p._axes(fast_axis), axis=axis, tiled=True)

    def stage_b(region):
        if slow_axis is None:
            return region
        return lax.all_gather(region, p._axes(slow_axis), axis=axis,
                              tiled=True)

    outs = two_phase_pipeline(chunks, stage_a=stage_a, stage_b=stage_b,
                              fast_axis=fast_axis, axis=axis)
    return _merge_strided(outs, axis, blocks=ranks)


def pipelined_broadcast(x: jax.Array, *, root: int = 0, fast_axis,
                        slow_axis=None, axis: int = 0,
                        n_chunks: int = DEFAULT_CHUNKS) -> jax.Array:
    """Chunked two-phase broadcast == ``hier_broadcast`` bit-for-bit.

    Per chunk: bridge bcast between the pods' leader chips (stage a), then
    the intra-pod leader->children copy (stage b) — so the on-node fan-out
    of chunk *k-1* overlaps the bridge crossing of chunk *k*.
    """
    my_pod_root, my_local_root = p._flat_root(root, fast_axis, slow_axis)
    fast = p._axes(fast_axis)
    me_fast = p.axis_index(fast)

    def stage_a(ck):
        if slow_axis is None:
            return jnp.where(me_fast == my_local_root, ck,
                             jnp.zeros_like(ck))
        slow = p._axes(slow_axis)
        my_pod = p.axis_index(slow)
        lead = jnp.where((my_pod == my_pod_root)
                         & (me_fast == my_local_root), ck,
                         jnp.zeros_like(ck))
        return lax.psum(lead, slow)      # bridge bcast (leaders nonzero)

    def stage_b(lead):
        return lax.psum(jnp.where(me_fast == my_local_root, lead,
                                  jnp.zeros_like(lead)), fast)

    outs = two_phase_pipeline(_split_blocked(x, axis, n_chunks),
                              stage_a=stage_a, stage_b=stage_b,
                              fast_axis=fast_axis, axis=axis)
    return _merge_blocked(outs, axis)


def pipelined_psum(x: jax.Array, *, fast_axis, slow_axis=None, axis: int = 0,
                   n_chunks: int = DEFAULT_CHUNKS) -> jax.Array:
    """Chunked two-phase allreduce == ``hier_psum`` bit-for-bit.

    Per chunk: intra-pod reduce-scatter (stage a — the window store), then
    bridge allreduce on shards + intra-pod allgather (stage b).
    """
    def stage_a(ck):
        return lax.psum_scatter(ck, p._axes(fast_axis),
                                scatter_dimension=axis, tiled=True)

    def stage_b(shard):
        if slow_axis is not None:
            shard = lax.psum(shard, p._axes(slow_axis))
        return lax.all_gather(shard, p._axes(fast_axis), axis=axis,
                              tiled=True)

    outs = two_phase_pipeline(_split_blocked(x, axis, n_chunks),
                              stage_a=stage_a, stage_b=stage_b,
                              fast_axis=fast_axis, axis=axis)
    return _merge_blocked(outs, axis)


def pipelined_reduce_scatter(x: jax.Array, *, fast_axis, slow_axis=None,
                             axis: int = 0, n_chunks: int = DEFAULT_CHUNKS
                             ) -> jax.Array:
    """Chunked two-phase reduce-scatter: rank *r* ends with the same flat
    1/R slice (rank-major) as ``naive_reduce_scatter``.

    Per chunk: bridge reduce-scatter over pods (stage a), intra-pod
    reduce-scatter of the pod slice (stage b).  The strided pre-split makes
    each chunk carry every rank-slice's *j*-th piece, so the blocked merge
    of per-chunk results is the contiguous unchunked slice.  Unlike the
    other families (whose per-chunk op sequence IS the reference's), the
    two-phase sum reassociates the flat ring's float adds (pods first,
    then chips) — numerically equivalent, not bitwise.
    """
    ranks = p.axis_size(fast_axis) * (p.axis_size(slow_axis)
                                      if slow_axis is not None else 1)
    chunks = _split_strided(x, axis, n_chunks, blocks=ranks)

    def stage_a(ck):
        if slow_axis is None:
            return ck
        return lax.psum_scatter(ck, p._axes(slow_axis),
                                scatter_dimension=axis, tiled=True)

    def stage_b(pod_slice):
        return lax.psum_scatter(pod_slice, p._axes(fast_axis),
                                scatter_dimension=axis, tiled=True)

    outs = two_phase_pipeline(chunks, stage_a=stage_a, stage_b=stage_b,
                              fast_axis=fast_axis, axis=axis)
    return _merge_blocked(outs, axis)


# ---------------------------------------------------------------------------
# Fused collective-matmul (compute overlap)
# ---------------------------------------------------------------------------

def _default_matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.matmul(a, b)


def _kernel_matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    from repro.kernels.ops import matmul as pallas_mm
    lead = a.shape[:-1]
    out = pallas_mm(a.reshape(-1, a.shape[-1]), b)
    return out.reshape(lead + (b.shape[-1],))


def _resolve_mm(use_kernel: bool, matmul: Optional[Callable]) -> Callable:
    if matmul is not None:
        return matmul
    return _kernel_matmul if use_kernel else _default_matmul


class _ReuseFence:
    """The double-buffer reuse discipline of the fused matmul loops, in ONE
    place: ``enter`` orders chunk *j*'s input after buffer ``j % 2``'s
    previous tenant was consumed; ``exit`` records the consumption token —
    only when a later chunk will actually reuse the buffer, so shallow
    pipelines (``n_chunks <= 2``) emit no barriers at all.  (The collective
    pipeline's window-epoch flavor of the same rule lives in
    ``two_phase_pipeline``.)"""

    def __init__(self, n_chunks: int):
        self.n = n_chunks
        self.free: list[Optional[jax.Array]] = [None, None]

    def enter(self, j: int, x: jax.Array) -> jax.Array:
        if self.free[j % 2] is not None:
            x, _ = lax.optimization_barrier((x, self.free[j % 2]))
        return x

    def exit(self, j: int, out: jax.Array) -> jax.Array:
        if j + 2 < self.n:
            self.free[j % 2] = _token_after(out)
        return out


def ag_matmul(x: jax.Array, w_shard: jax.Array, *, fast_axis,
              n_chunks: int = DEFAULT_CHUNKS, use_kernel: bool = False,
              matmul: Optional[Callable] = None) -> jax.Array:
    """``x @ all_gather(w_shard, axis=0)`` — the FSDP window *read* fused
    into the matmul.

    ``w_shard``: this rank's ``(K/c, N)`` shard of the ``(K, N)`` weight,
    sharded over ``fast_axis`` along the contraction dim.  Each chunk
    gathers a strided K-panel of the weight, multiplies the matching
    ``x`` columns and accumulates in fp32 — the gather of panel *k+1* has
    no dependency on the matmul of panel *k* (double-buffered), so the
    window read streams behind the MXU instead of completing up front.

    ``use_kernel=True`` routes panels through the Pallas blocked kernel
    (``repro.kernels.ops.matmul``); default is the jnp matmul (the Pallas
    interpreter is the CPU validation mode, far too slow for benching).
    """
    mm = _resolve_mm(use_kernel, matmul)
    c = p.axis_size(fast_axis)
    s, n_out = w_shard.shape
    if s % n_chunks:
        raise ValueError(f"weight shard rows {s} must divide by "
                         f"n_chunks={n_chunks}")
    k_total = c * s
    if x.shape[-1] != k_total:
        raise ValueError(f"x contraction dim {x.shape[-1]} != gathered "
                         f"weight rows {k_total}")
    piece = s // n_chunks
    lead = x.shape[:-1]
    xr = x.reshape(lead + (c, n_chunks, piece))
    fence = _ReuseFence(n_chunks)
    acc = jnp.zeros(lead + (n_out,), jnp.float32)
    for j in range(n_chunks):
        shard_piece = fence.enter(j, lax.slice_in_dim(
            w_shard, j * piece, (j + 1) * piece, axis=0))
        panel = lax.all_gather(shard_piece, p._axes(fast_axis), axis=0,
                               tiled=True)              # (c*piece, N)
        xj = xr[..., :, j, :].reshape(lead + (c * piece,))
        prod = fence.exit(j, mm(xj, panel))
        acc = acc + prod.astype(jnp.float32)
    return acc.astype(x.dtype)


def ag_matmul_q4(x: jax.Array, w_shard: jax.Array, *, fast_axis,
                 n_chunks: int = DEFAULT_CHUNKS, group: int = 32,
                 use_kernel: bool = False) -> jax.Array:
    """``ag_matmul`` with a packed-int4 weight wire format.

    Each chunk's local K-panel piece is groupwise int4-quantized
    (``quantize_q4``) BEFORE the gather, so the collective moves two
    nibbles per weight plus one f32 scale per ``group`` rows instead of
    four bytes per weight.  The gathered panel is never densified when
    ``use_kernel=True``: the Pallas kernel (``kernels.quant``) unpacks and
    rescales tiles inside the matmul loop.  The per-chip piece must divide
    by ``group`` so concatenated packings respect group boundaries.
    """
    from repro.comm import quantize as qz
    c = p.axis_size(fast_axis)
    s, n_out = w_shard.shape
    if s % n_chunks:
        raise ValueError(f"weight shard rows {s} must divide by "
                         f"n_chunks={n_chunks}")
    piece = s // n_chunks
    if piece % group:
        raise ValueError(f"per-chunk shard rows {piece} must divide by "
                         f"group={group}")
    k_total = c * s
    if x.shape[-1] != k_total:
        raise ValueError(f"x contraction dim {x.shape[-1]} != gathered "
                         f"weight rows {k_total}")
    lead = x.shape[:-1]
    xr = x.reshape(lead + (c, n_chunks, piece))
    fence = _ReuseFence(n_chunks)
    acc = jnp.zeros(lead + (n_out,), jnp.float32)
    for j in range(n_chunks):
        shard_piece = fence.enter(j, lax.slice_in_dim(
            w_shard, j * piece, (j + 1) * piece, axis=0))
        packed, scales = qz.quantize_q4(shard_piece, group=group)
        # raw-collective: the packed-int4 panel gather IS the wire format
        gp = lax.all_gather(packed, p._axes(fast_axis), axis=0, tiled=True)
        gs = lax.all_gather(scales, p._axes(fast_axis), axis=0, tiled=True)
        xj = xr[..., :, j, :].reshape(lead + (c * piece,))
        x2d = xj.reshape(-1, c * piece)
        if use_kernel:
            from repro.kernels.ops import q4_matmul
            prod2d = q4_matmul(x2d, gp, gs, group=group)
        else:
            prod2d = jnp.matmul(
                x2d, qz.dequantize_q4(gp, gs, group=group))
        prod = fence.exit(j, prod2d.reshape(lead + (n_out,)))
        acc = acc + prod.astype(jnp.float32)
    return acc.astype(x.dtype)


def ag_matmul_rows(a_shard: jax.Array, b: jax.Array, *, fast_axis,
                   n_chunks: int = DEFAULT_CHUNKS, use_kernel: bool = False,
                   matmul: Optional[Callable] = None) -> jax.Array:
    """``all_gather(a_shard, axis=0) @ b`` — the row-panel flavor: the
    gathered operand carries OUTPUT rows (e.g. the SUMMA A-panel shared
    window), so chunks produce disjoint row panels — no accumulation; the
    strided merge restores rank-major row order.  The gather of panel *k+1*
    overlaps the matmul of panel *k* (double-buffered)."""
    mm = _resolve_mm(use_kernel, matmul)
    c = p.axis_size(fast_axis)
    rows = a_shard.shape[0]
    if rows % n_chunks:
        raise ValueError(f"shard rows {rows} must divide by "
                         f"n_chunks={n_chunks}")
    piece = rows // n_chunks
    fence = _ReuseFence(n_chunks)
    outs = []
    for j in range(n_chunks):
        pj = fence.enter(j, lax.slice_in_dim(a_shard, j * piece,
                                             (j + 1) * piece, axis=0))
        panel = lax.all_gather(pj, p._axes(fast_axis), axis=0, tiled=True)
        outs.append(fence.exit(j, mm(panel, b)))
    return _merge_strided(outs, 0, blocks=c)


def matmul_rs(x: jax.Array, w: jax.Array, *, axis_name, scatter_dim: int = 0,
              n_chunks: int = DEFAULT_CHUNKS, use_kernel: bool = False,
              matmul: Optional[Callable] = None) -> jax.Array:
    """``reduce_scatter(x @ w)`` over ``axis_name`` along ``scatter_dim`` —
    the partial-sum *store* fused into the matmul.

    Output rows are computed in ``n_chunks`` strided panels; the
    reduce-scatter of panel *k* overlaps the matmul of panel *k+1*.  The
    strided split mirrors ``pipelined_reduce_scatter``: the blocked merge of
    scattered panels is exactly the contiguous unchunked shard.
    """
    mm = _resolve_mm(use_kernel, matmul)
    n = p.axis_size(axis_name)
    chunks = _split_strided(x, scatter_dim, n_chunks, blocks=n)
    fence = _ReuseFence(n_chunks)
    outs = []
    for j, xc in enumerate(chunks):
        prod = mm(fence.enter(j, xc), w)
        out = lax.psum_scatter(prod, p._axes(axis_name),
                               scatter_dimension=scatter_dim, tiled=True)
        outs.append(fence.exit(j, out))
    return _merge_blocked(outs, scatter_dim)
