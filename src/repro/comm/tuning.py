"""Tuning-table-driven scheme selection: the ``scheme="auto"`` backend.

The paper's central measurement (Figs 7-10) is that the best collective
algorithm depends on topology and message size — hier wins on multi-node
shapes, flat schemes on SMP, and the crossover moves with the op family.
``repro.comm.tuning`` turns that observation into the dispatch rule:

* ``TuningTable``  — a schema-versioned, persisted table of per-cell scheme
  rankings, keyed by (op family x topology signature x dtype x size
  bucket).  Measured entries are folded out of a ``repro.bench`` report
  (``python -m repro.bench --emit-tuning-table``) and committed as
  ``TUNING_default.json``; every entry carries a ``source`` tag
  (``measured`` | ``modeled``) and the full per-scheme ranking, so a
  result-class-constrained lookup can fall through to the best *allowed*
  scheme instead of only the overall winner.
* ``resolve()``    — the dispatch rule ``Communicator`` consults when
  ``scheme="auto"``:

  1. **measured** — nearest-size-bucket table entry for the communicator's
     topology signature; the ranking is walked best-first, skipping schemes
     the caller's ``result`` constraint or the cell's tiling rules out.
  2. **modeled**  — no usable entry: every registry scheme prices the cell
     with its ``predicted_time`` closed form (``core.plans``; ``pipelined``
     folds in ``best_chunk_count``) and the cheapest wins.
  3. **fallback** — the communicator has no static ``pods``/``chips``
     counts (nothing to key or model on): the pre-auto per-family defaults
     apply (``shared`` for the window families, ``hier`` for alltoall;
     ``naive`` under a ``replicated`` constraint).

Resolution is pure Python on static shapes — it happens once at trace
time, never inside the compiled program.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import logging
import os
import pathlib
from typing import Iterable, Mapping, Optional, Sequence

from repro.comm import registry
from repro.core.plans import nearest_bucket, size_bucket

SCHEMA_VERSION = "repro.tuning/v1"

#: Per-family defaults when nothing can be measured or modeled (no static
#: pods/chips counts) — exactly the pre-auto hard-coded defaults, so an
#: unannotated Communicator behaves as it always did.
FALLBACK = {
    None: {"allgather": "shared", "broadcast": "shared", "psum": "shared",
           "reduce_scatter": "shared", "allgatherv": "shared",
           "alltoall": "hier", "step_time": "prefetch",
           "serving": "sync"},
    "shared": {"allgather": "shared", "broadcast": "shared",
               "psum": "shared", "reduce_scatter": "shared",
               "allgatherv": "shared"},
    "replicated": {"allgather": "naive", "broadcast": "naive",
                   "psum": "naive", "reduce_scatter": "naive",
                   "allgatherv": "naive", "alltoall": "hier",
                   "step_time": "prefetch", "serving": "sync"},
}


def topo_signature(pods: int, chips: int, n_fast_axes: int = 1) -> str:
    """Stable topology key: ``{pods}x{chips}`` plus a ``-f{n}`` suffix when
    the fast tier spans several named mesh axes (the tuple-axis
    ``pod x (dp, tp)`` layout lowers differently from the flat ``2x4``
    even though the tier sizes match)."""
    sig = f"{pods}x{chips}"
    if n_fast_axes > 1:
        sig += f"-f{n_fast_axes}"
    return sig


# ---------------------------------------------------------------------------
# Table entries
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Choice:
    """One ranked (scheme, tunable-opts) alternative of a measured cell."""

    scheme: str
    opts: Mapping = dataclasses.field(default_factory=dict)
    median_us: Optional[float] = None

    def to_dict(self) -> dict:
        out = {"scheme": self.scheme, "opts": dict(self.opts)}
        if self.median_us is not None:
            out["median_us"] = self.median_us
        return out

    @classmethod
    def from_dict(cls, d: dict) -> "Choice":
        return cls(scheme=d["scheme"], opts=dict(d.get("opts") or {}),
                   median_us=d.get("median_us"))


@dataclasses.dataclass(frozen=True)
class TuningEntry:
    """One (family, topology, dtype, size) cell: the full scheme ranking.

    ``nbytes`` is the per-rank payload (message bytes for broadcast/psum,
    per-rank contribution for allgather, per-pair bytes for alltoall) —
    the same normalization ``repro.bench`` keys its sweep by."""

    family: str
    topo: str                       # topo_signature(...)
    dtype: str
    nbytes: int
    source: str                     # "measured" | "modeled"
    ranking: tuple[Choice, ...]     # best first
    label: str = ""                 # human topology label, e.g. "2x4"

    def __post_init__(self):
        if self.source not in ("measured", "modeled"):
            raise ValueError(f"bad source {self.source!r}")
        if not self.ranking:
            raise ValueError(f"{self.family}/{self.topo}: empty ranking")

    @property
    def bucket(self) -> int:
        return size_bucket(self.nbytes)

    @property
    def best(self) -> Choice:
        return self.ranking[0]

    def to_dict(self) -> dict:
        return {"family": self.family, "topo": self.topo,
                "dtype": self.dtype, "nbytes": self.nbytes,
                "bucket": self.bucket, "source": self.source,
                "label": self.label,
                "ranking": [c.to_dict() for c in self.ranking]}

    @classmethod
    def from_dict(cls, d: dict) -> "TuningEntry":
        return cls(family=d["family"], topo=d["topo"], dtype=d["dtype"],
                   nbytes=int(d["nbytes"]), source=d["source"],
                   label=d.get("label", ""),
                   ranking=tuple(Choice.from_dict(c)
                                 for c in d["ranking"]))


# ---------------------------------------------------------------------------
# The table
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TuningTable:
    """Persisted scheme-selection table (``TUNING_default.json``)."""

    entries: tuple[TuningEntry, ...] = ()
    meta: Mapping = dataclasses.field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.entries)

    # -- lookup --------------------------------------------------------------
    def lookup(self, family: str, topo: str, dtype: str, nbytes: int
               ) -> Optional[TuningEntry]:
        """Nearest-size-bucket entry for one (family, topology) cell.

        Exact-dtype entries are preferred; with none recorded the search
        widens to every dtype (a bf16 payload is better served by the f32
        ranking of its size class than by the modeled cold start).  Among
        candidates the geometrically-nearest bucket wins, ties toward the
        smaller size (``core.plans.nearest_bucket``)."""
        cands = [e for e in self.entries
                 if e.family == family and e.topo == topo]
        if not cands:
            return None
        exact = [e for e in cands if e.dtype == dtype]
        cands = exact or cands
        best_bucket = nearest_bucket(nbytes, [e.bucket for e in cands])
        matches = [e for e in cands if e.bucket == best_bucket]
        return min(matches, key=lambda e: e.nbytes)

    def signatures(self) -> tuple[str, ...]:
        return tuple(sorted({e.topo for e in self.entries}))

    # -- (de)serialization ---------------------------------------------------
    def to_dict(self) -> dict:
        return {"schema": SCHEMA_VERSION,
                "meta": dict(self.meta),
                "entries": [e.to_dict() for e in sorted(
                    self.entries,
                    key=lambda e: (e.family, e.topo, e.dtype, e.nbytes))]}

    @classmethod
    def from_dict(cls, d: dict) -> "TuningTable":
        schema = d.get("schema")
        if schema != SCHEMA_VERSION:
            raise ValueError(
                f"not a {SCHEMA_VERSION} table (schema={schema!r})")
        return cls(entries=tuple(TuningEntry.from_dict(e)
                                 for e in d.get("entries", [])),
                   meta=dict(d.get("meta") or {}))

    def save(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1)
            f.write("\n")

    @classmethod
    def load(cls, path) -> "TuningTable":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    # -- folding a bench report into measured entries ------------------------
    @classmethod
    def from_bench_report(cls, report: dict, *,
                          source_name: str = "") -> "TuningTable":
        """Fold a ``repro.bench`` report's per-cell medians + ``autotune``
        winners into measured entries: one entry per (family, topology
        signature, dtype, elems) cell, ranking every scheme the sweep
        timed there by its (autotuned-best) pooled median.

        Operates on the plain report dict, so emitting a table needs no
        re-measurement — the committed ``BENCH_collectives.json`` (or a
        fresh nightly artifact) is the input."""
        entries = []
        for (family, sig, dtype, nbytes), cell in sorted(
                bench_cells(report).items()):
            ranking = tuple(sorted(
                (Choice(scheme=s, opts=dict(opts), median_us=med)
                 for s, (med, opts) in cell["schemes"].items()),
                key=lambda c: (c.median_us, c.scheme)))
            entries.append(TuningEntry(
                family=family, topo=sig, dtype=dtype, nbytes=nbytes,
                source="measured", ranking=ranking, label=cell["label"]))
        meta = {"generated_by": "python -m repro.bench --emit-tuning-table",
                "generated_from": source_name or report.get("generated_by",
                                                            ""),
                "bench_schema": report.get("schema"),
                "jax_version": report.get("jax_version"),
                "backend": report.get("backend"),
                "sweep": report.get("sweep")}
        return cls(entries=tuple(entries), meta=meta)


def bench_cells(report: dict) -> dict[tuple, dict]:
    """A bench report regrouped into tuning cells: ``(family, topology
    signature, dtype, nbytes) -> {"label", "schemes": {scheme: (median_us,
    best_opts)}}``.  The shared keying of ``from_bench_report`` and the
    ``repro.bench.validate`` winner cross-check — both sides MUST bucket a
    report identically or the check would compare different cells."""
    schema = str(report.get("schema", ""))
    if not schema.startswith("repro.bench/"):
        raise ValueError(f"not a repro.bench report (schema={schema!r})")
    cells: dict[tuple, dict] = {}
    for case in report.get("cases", []):
        # fast_axes entered the report schema with the tuning table; older
        # artifacts only betray a factored fast tier through their label
        sig = topo_signature(case["pods"], case["chips"],
                             case.get("fast_axes",
                                      2 if "." in case["topology"] else 1))
        dtype = case.get("dtype", "float32")
        key = (case["family"], sig, dtype, int(case["bytes_per_rank"]))
        opts = (case["autotune"] or {}).get("best", {}) \
            if case.get("autotune") else {}
        cell = cells.setdefault(key, {"label": case["topology"],
                                      "schemes": {}})
        cell["schemes"][case["scheme"]] = (
            float(case["timing"]["median_us"]), dict(opts))
    return cells


# ---------------------------------------------------------------------------
# The active table (process-wide; tests swap it with ``use_table``)
# ---------------------------------------------------------------------------

_ENV_VAR = "REPRO_TUNING_TABLE"
_DEFAULT_PATH = pathlib.Path(__file__).resolve().parents[3] \
    / "TUNING_default.json"
_active: Optional[TuningTable] = None
_default_cache: Optional[TuningTable] = None


def default_table_path() -> pathlib.Path:
    """The committed table, overridable via ``REPRO_TUNING_TABLE``."""
    env = os.environ.get(_ENV_VAR)
    return pathlib.Path(env) if env else _DEFAULT_PATH


def default_table() -> TuningTable:
    """The committed ``TUNING_default.json`` (cached); an EMPTY table when
    the file does not exist — every auto dispatch then takes the modeled
    cold-start path."""
    global _default_cache
    if _default_cache is None:
        path = default_table_path()
        _default_cache = TuningTable.load(path) if path.exists() \
            else TuningTable()
    return _default_cache


def active_table() -> TuningTable:
    return _active if _active is not None else default_table()


@contextlib.contextmanager
def use_table(table: Optional[TuningTable]):
    """Swap the process-wide active table (``None`` = empty: force the
    modeled path).  Tests drive resolution through this."""
    global _active
    prev = _active
    _active = table if table is not None else TuningTable()
    try:
        yield
    finally:
        _active = prev


# ---------------------------------------------------------------------------
# Resolution
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Resolution:
    """The outcome of one ``scheme="auto"`` dispatch decision."""

    scheme: str
    opts: dict
    source: str                    # "measured" | "modeled" | "fallback"
    entry: Optional[TuningEntry] = None


def _usable(sch, family: str, result_class: Optional[str], pods: int,
            chips: int, elems: int, precision: str = "exact",
            tol: Optional[float] = None):
    """The scheme's valid tunable grid for this cell, or ``None`` when the
    caller's result-class / precision constraint or the cell's tiling
    rules it out.  ``precision="exact"`` (the default) filters lossy
    (quantized) schemes out of the walk entirely; ``"lossy"`` admits
    them, optionally capped by ``tol`` — a lossy scheme whose declared
    ``error_bound_rel`` exceeds the caller's tolerance is skipped."""
    if result_class is not None and sch.result_class != result_class:
        return None
    if sch.precision == "lossy":
        if precision != "lossy":
            return None
        if tol is not None and pods \
                and sch.error_bound_rel(family, pods=pods) > tol:
            return None
    cands = sch.candidates(family, pods=pods, chips=chips, elems=elems)
    return cands or None


def best_scheme_predicted(family: str, *, pods: int, chips: int, elems: int,
                          elem_bytes: int = 4,
                          result_class: Optional[str] = None,
                          precision: str = "exact",
                          tol: Optional[float] = None,
                          populations: Optional[Sequence[int]] = None
                          ) -> Optional[tuple[str, dict, float]]:
    """Model-predicted (scheme, opts, time) for one cell: every registry
    scheme that can run it prices the cell with its ``predicted_time``
    closed form; the cheapest wins (ties: registration order)."""
    best = None
    for sch in registry.schemes_for(family):
        if _usable(sch, family, result_class, pods, chips, elems,
                   precision, tol) is None:
            continue
        pred = sch.predicted_time(family, pods=pods, chips=chips,
                                  elems=elems, elem_bytes=elem_bytes,
                                  populations=populations)
        if pred is None:
            continue
        t, opts = pred
        if best is None or t < best[2]:
            best = (sch.name, dict(opts), t)
    return best


#: Static-fallback overrides under ``precision="lossy"``: a communicator
#: with no pods/chips counts is all bridge (the ``reduce_grads`` gradient
#: path), so lossy opt-in means "compress that bridge" — the q8 wire
#: format, run single-tier.  Families without an override keep the exact
#: fallback (lossy *admits* quantized schemes, it never requires one).
LOSSY_FALLBACK = {"psum": "q8_hier", "allgather": "q8_hier"}


def resolve(family: str, *, pods: Optional[int], chips: Optional[int],
            elems: int, elem_bytes: int = 4, dtype: str = "float32",
            n_fast_axes: int = 1, result_class: Optional[str] = None,
            precision: str = "exact", tol: Optional[float] = None,
            table: Optional[TuningTable] = None) -> Resolution:
    """Resolve one ``scheme="auto"`` dispatch (see module docstring for the
    measured -> modeled -> fallback chain).  ``result_class`` constrains
    the pick to schemes of one result class (``"replicated"`` /
    ``"shared"``); ``precision`` mirrors it for the exact/lossy axis —
    ``"exact"`` (the default) never returns a quantized scheme,
    ``"lossy"`` admits them (capped by ``tol``, a relative error bound).
    Call sites pass constraints, never scheme names."""
    if result_class not in (None, "replicated", "shared"):
        raise ValueError(f"bad result constraint {result_class!r}")
    if precision not in ("exact", "lossy"):
        raise ValueError(f"bad precision constraint {precision!r} "
                         "(pick 'exact' or 'lossy')")
    table = table if table is not None else active_table()
    if pods and chips:
        entry = table.lookup(family, topo_signature(pods, chips,
                                                    n_fast_axes),
                             dtype, elems * elem_bytes)
        if entry is not None:
            for choice in entry.ranking:
                try:
                    sch = registry.get_scheme(choice.scheme)
                except KeyError:
                    continue           # table from a build with more schemes
                cands = _usable(sch, family, result_class, pods, chips,
                                elems, precision, tol)
                if cands is None:
                    continue
                opts = dict(choice.opts)
                if opts and opts not in [dict(c) for c in cands]:
                    # recorded tunables do not tile THIS size: re-predict
                    # them from the closed form instead of mis-lowering
                    pred = sch.predicted_time(family, pods=pods,
                                              chips=chips, elems=elems,
                                              elem_bytes=elem_bytes)
                    opts = dict(pred[1]) if pred else dict(cands[0])
                return Resolution(sch.name, opts, entry.source, entry)
        best = best_scheme_predicted(family, pods=pods, chips=chips,
                                     elems=elems, elem_bytes=elem_bytes,
                                     result_class=result_class,
                                     precision=precision, tol=tol)
        if best is not None:
            return Resolution(best[0], best[1], "modeled")
        raise ValueError(
            f"no registered scheme can run {family} with elems={elems} on "
            f"a {pods}x{chips} topology"
            + (f" under result={result_class!r}" if result_class else "")
            + " — every candidate grid is empty (tiling)")
    name = None
    if precision == "lossy":
        cand = LOSSY_FALLBACK.get(family)
        if cand is not None and result_class in (
                None, registry.get_scheme(cand).result_class):
            name = cand
    if name is None:
        try:
            name = FALLBACK[result_class][family]
        except KeyError:
            raise ValueError(
                f"scheme='auto' cannot resolve {family} under "
                f"result={result_class!r} without static pods/chips counts"
            ) from None
    return Resolution(name, {}, "fallback")


# package-level alias: ``repro.comm.resolve_scheme`` reads better than the
# module-qualified ``tuning.resolve`` at call sites outside this package
resolve_scheme = resolve


def resolve_for(comm, family: str, *, elems: int, elem_bytes: int = 4,
                dtype: str = "float32",
                result_class: Optional[str] = None,
                precision: str = "exact", tol: Optional[float] = None,
                table: Optional[TuningTable] = None) -> Resolution:
    """``resolve`` keyed by a ``Communicator``'s static structure."""
    from repro.comm import primitives as p
    return resolve(family, pods=comm.pods, chips=comm.chips, elems=elems,
                   elem_bytes=elem_bytes, dtype=dtype,
                   n_fast_axes=len(p._axes(comm.fast_axis)),
                   result_class=result_class, precision=precision, tol=tol,
                   table=table)


# ---------------------------------------------------------------------------
# Signature re-resolution (the elastic-rebuild surface)
# ---------------------------------------------------------------------------

logger = logging.getLogger("repro.comm.tuning")


def signature_for(comm) -> str:
    """The tuning-table topology signature of a ``Communicator`` — the key
    that changes when an elastic rebuild shrinks or grows the cluster."""
    from repro.comm import primitives as p
    if comm.pods is None or comm.chips is None:
        raise ValueError("topology signature needs static pods/chips counts "
                         "— build the communicator via from_cluster/"
                         "from_topology")
    return topo_signature(comm.pods, comm.chips,
                          len(p._axes(comm.fast_axis)))


@dataclasses.dataclass(frozen=True)
class RetuneReport:
    """What ``scheme="auto"`` now resolves to on a (possibly brand-new)
    topology signature: one row per (family, elems) the caller is about to
    dispatch.  ``sources`` summarizes the measured/modeled/fallback mix —
    after a shrink onto a signature the bench never swept, every row is
    ``modeled`` (closed-form pricing), which is the designed degradation,
    not an error."""

    signature: str
    rows: tuple[tuple[str, int, Resolution], ...]   # (family, elems, res)

    @property
    def sources(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for _, _, res in self.rows:
            out[res.source] = out.get(res.source, 0) + 1
        return out

    def scheme_for(self, family: str) -> Optional[str]:
        for fam, _, res in self.rows:
            if fam == family:
                return res.scheme
        return None


def retune_for(comm, families: Sequence[str], elems_list: Sequence[int], *,
               elem_bytes: int = 4, dtype: str = "float32",
               result_class: Optional[str] = None,
               table: Optional[TuningTable] = None) -> RetuneReport:
    """Re-resolve ``scheme="auto"`` for a rebuilt communicator and LOG every
    decision — the elastic runtime calls this right after a communicator
    rebuild so the measured -> modeled fallback for an unseen signature is
    visible in the recovery record instead of silently changing schedules.
    Resolution itself is exactly the dispatch-time ``resolve_for`` chain;
    this surface only batches and reports it."""
    sig = signature_for(comm)
    known = (table if table is not None else active_table()).signatures()
    if sig not in known:
        logger.info("retune %s: signature not in tuning table %s — "
                    "expect modeled (closed-form) resolutions", sig,
                    list(known))
    rows = []
    for family in families:
        for elems in elems_list:
            res = resolve_for(comm, family, elems=elems,
                              elem_bytes=elem_bytes, dtype=dtype,
                              result_class=result_class, table=table)
            logger.info("retune %s: %s elems=%d -> scheme=%s (%s)",
                        sig, family, elems, res.scheme, res.source)
            rows.append((family, int(elems), res))
    return RetuneReport(signature=sig, rows=tuple(rows))


def modeled_entries(families: Iterable[str], *, pods: int, chips: int,
                    elems_list: Sequence[int], elem_bytes: int = 4,
                    dtype: str = "float32", n_fast_axes: int = 1
                    ) -> tuple[TuningEntry, ...]:
    """Cold-start table rows for an unmeasured topology: one ``modeled``
    entry per (family, size), ranking every runnable scheme by its
    ``predicted_time``.  Useful to pre-seed a table for a mesh the bench
    has never run on."""
    out = []
    sig = topo_signature(pods, chips, n_fast_axes)
    for family in families:
        for elems in elems_list:
            ranked = []
            for sch in registry.schemes_for(family):
                pred = sch.predicted_time(family, pods=pods, chips=chips,
                                          elems=elems,
                                          elem_bytes=elem_bytes)
                if pred is None:
                    continue
                t, opts = pred
                ranked.append((t, Choice(sch.name, dict(opts))))
            if ranked:
                ranked.sort(key=lambda tc: (tc[0], tc[1].scheme))
                out.append(TuningEntry(
                    family=family, topo=sig, dtype=dtype,
                    nbytes=elems * elem_bytes, source="modeled",
                    ranking=tuple(c for _, c in ranked),
                    label=f"{pods}x{chips}"))
    return tuple(out)
