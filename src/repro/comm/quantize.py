"""Quantized wire-format collective bodies (int8 / bf16 / packed int4).

The paper's C1 invariant attacks the *resident* bytes of a collective;
this module attacks the *wire* bytes on the slow bridge tier, where the
hierarchical decomposition concentrates all inter-node traffic.  Every
body here keeps the on-node stages full precision — only the payload that
actually crosses ``slow_axis`` is compressed — so the shared window a
``shared``-class result hands out stays exact.

Layering: the registry schemes in ``repro.comm.registry`` (``q8_hier``,
``qbf16_hier``, ``q4_shared``) bind these bodies; call sites reach them
only through ``Communicator(..., precision="lossy")``.  The deprecated
free functions in ``repro.optim.compression`` shim onto the same cores.

Quantization model (per-block symmetric):

* the payload is flattened and cut into ``block``-sized blocks, each with
  its own f32 scale ``amax / qmax`` — an outlier only collapses its own
  block, not the whole tensor;
* for *psum* payloads the wire schedule is picked by the bridge's rank
  count: small-world bridges (<= 3 ranks) fuse int8 codes + LOCAL scales
  into ONE u8 gather summed locally in f32; wider bridges share block
  scales with one tiny ``lax.pmax`` (so every rank quantizes onto the
  same grid and the int16 wire sum is exact for <= 256 pods:
  127 * 256 < 2**15);
* for *gather* payloads scales stay local and travel with the data;
* error feedback: the psum cores optionally take the previous step's
  residual (``err``) and return the new local quantization residual —
  local, never the divergent global total (see PR 6).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from .primitives import _axes, axis_index
from repro.substrate.compat import axis_size

DEFAULT_BLOCK = 256
Q8_MAX = 127.0
Q4_MAX = 7.0
_EPS = 1e-30


# ---------------------------------------------------------------------------
# Per-block quantize / dequantize cores
# ---------------------------------------------------------------------------

def _to_blocks(x: jax.Array, block: int) -> tuple[jax.Array, int, int]:
    """Flatten ``x`` to f32 ``(n_blocks, block_eff)``; zero-pad the tail.

    Returns ``(blocks, size, block_eff)``.  ``block_eff`` shrinks to the
    flat size for tensors smaller than one block (per-tensor scale, the
    pre-fix behaviour, which is exact there).  Padding zeros quantize to
    zero and are sliced off after dequantization.
    """
    flat = x.astype(jnp.float32).reshape(-1)
    size = flat.shape[0]
    block_eff = max(1, min(int(block), size))
    pad = (-size) % block_eff
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, block_eff), size, block_eff


def _from_blocks(blocks: jax.Array, size: int, shape, dtype) -> jax.Array:
    return blocks.reshape(-1)[:size].reshape(shape).astype(dtype)


def block_quantize(x: jax.Array, *, block: int = DEFAULT_BLOCK,
                   qmax: float = Q8_MAX, shared_axes=(),
                   stochastic: bool = False,
                   key: Optional[jax.Array] = None):
    """Per-block symmetric quantization of ``x``.

    Returns ``(q, scale, meta)`` where ``q`` is int8 ``(n_blocks, block)``,
    ``scale`` is f32 ``(n_blocks,)`` and ``meta = (size, block_eff)`` for
    :func:`block_dequantize`.  ``shared_axes`` max-reduces the block amax
    across ranks first (psum payloads must share one grid).
    """
    blocks, size, block_eff = _to_blocks(x, block)
    amax = jnp.max(jnp.abs(blocks), axis=1)
    if shared_axes:
        amax = lax.pmax(amax, _axes(shared_axes))
    scale = jnp.maximum(amax, _EPS) / qmax
    scaled = blocks / scale[:, None]
    if stochastic:
        if key is None:
            raise ValueError("stochastic rounding requires a PRNG key")
        noise = jax.random.uniform(key, scaled.shape)
        q = jnp.floor(scaled + noise)
    else:
        q = jnp.round(scaled)
    q = jnp.clip(q, -qmax, qmax).astype(jnp.int8)
    return q, scale, (size, block_eff)


def block_dequantize(q: jax.Array, scale: jax.Array, meta, shape,
                     dtype=jnp.float32) -> jax.Array:
    size, _ = meta
    blocks = q.astype(jnp.float32) * scale[:, None]
    return _from_blocks(blocks, size, shape, dtype)


# ---------------------------------------------------------------------------
# Packed-int4 codec (two nibbles per uint8)
# ---------------------------------------------------------------------------

def pack_int4(q: jax.Array) -> jax.Array:
    """Pack int8 values in ``[-7, 7]`` two-per-byte along the last axis.

    Values are biased to ``[1, 15]`` (0 is never produced, so an all-zero
    byte can only mean padding).  The last axis extent must be even.
    """
    if q.shape[-1] % 2:
        raise ValueError(f"int4 pack needs an even extent, got {q.shape}")
    b = (q.astype(jnp.int32) + 8).astype(jnp.uint8)
    lo, hi = b[..., 0::2], b[..., 1::2]
    return lo | (hi << 4)


def unpack_int4(p: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_int4`: uint8 ``(..., n)`` -> int8 ``(..., 2n)``."""
    lo = (p & 0xF).astype(jnp.int8) - 8
    hi = (p >> 4).astype(jnp.int8) - 8
    pairs = jnp.stack([lo, hi], axis=-1)
    return pairs.reshape(p.shape[:-1] + (2 * p.shape[-1],))


def quantize_q4(w: jax.Array, *, group: int = 32):
    """Groupwise-K int4 weight quantization for the ``ag_matmul`` fast path.

    ``w`` is a ``(K, N)`` panel; each length-``group`` run of K rows in a
    column shares one f32 scale.  Returns ``(packed, scales)`` with
    ``packed`` uint8 ``(K // 2, N)`` (nibble pairs along K) and ``scales``
    f32 ``(K // group, N)``.
    """
    k, n = w.shape
    if group % 2 or k % group:
        raise ValueError(f"K={k} must divide into even groups of {group}")
    g = w.astype(jnp.float32).reshape(k // group, group, n)
    amax = jnp.max(jnp.abs(g), axis=1)
    scales = jnp.maximum(amax, _EPS) / Q4_MAX
    q = jnp.clip(jnp.round(g / scales[:, None, :]), -Q4_MAX, Q4_MAX)
    q = q.astype(jnp.int8).reshape(k, n)
    # pack along K: byte r holds rows (2r, 2r+1)
    b = (q.astype(jnp.int32) + 8).astype(jnp.uint8)
    packed = b[0::2, :] | (b[1::2, :] << 4)
    return packed, scales


def dequantize_q4(packed: jax.Array, scales: jax.Array, *,
                  group: int = 32, dtype=jnp.float32) -> jax.Array:
    """Inverse of :func:`quantize_q4` -> ``(K, N)`` in ``dtype``."""
    k2, n = packed.shape
    lo = (packed & 0xF).astype(jnp.int8) - 8
    hi = (packed >> 4).astype(jnp.int8) - 8
    q = jnp.stack([lo, hi], axis=1).reshape(2 * k2, n)
    g = q.astype(jnp.float32).reshape(-1, group, n)
    return (g * scales[:, None, :]).reshape(2 * k2, n).astype(dtype)


# ---------------------------------------------------------------------------
# Quantized psum cores (gradient-bridge wire formats)
# ---------------------------------------------------------------------------

def _axes_count(axes) -> int:
    """Static rank count of a (possibly empty) axis-name tuple."""
    n = 1
    for a in axes:
        n *= int(axis_size(a))
    return n


def q8_psum_flat(x: jax.Array, axes, *, block: int = DEFAULT_BLOCK,
                 err: Optional[jax.Array] = None,
                 stochastic: bool = False, key=None):
    """int8-on-the-wire psum of ``x`` over ``axes``.

    The whole reduction is treated as one bridge, with two wire schedules
    picked statically by the bridge's rank count ``p``:

    * ``p <= 3`` (the small-world bridge): ONE tiled ``u8`` all-gather of
      a fused buffer — int8 codes followed by the rank's LOCAL per-block
      f32 scales — and every rank dequantizes ALL contributions (its own
      included, so totals stay bit-identical across ranks) and sums in
      f32.  ``(p-1)`` wire bytes/elem beats the code-sum's ``4(p-1)/p``
      there, and one rendezvous replaces the pmax + reduce pair.
    * ``p >= 4``: per-block amax is shared via ``lax.pmax`` so all ranks
      quantize onto the same grid, then the int8 codes are summed exactly
      in int16 (exact for <= 256 pods: 127 * 256 < 2**15).

    With ``err`` the previous residual is folded in first and the new
    LOCAL residual is returned: ``(total, new_err)``; otherwise just
    ``total``.
    """
    axes = _axes(axes) if axes else ()
    x32 = x.astype(jnp.float32)
    if err is not None:
        x32 = x32 + err.astype(jnp.float32)
    p = _axes_count(axes)
    if p <= 3:
        q, scale, meta = block_quantize(x32, block=block, qmax=Q8_MAX,
                                        stochastic=stochastic, key=key)
        local = block_dequantize(q, scale, meta, x.shape, jnp.float32)
        if axes and p > 1:
            nb = scale.shape[0]
            wire = jnp.concatenate([
                lax.bitcast_convert_type(q, jnp.uint8).reshape(-1),
                lax.bitcast_convert_type(scale, jnp.uint8).reshape(-1)])
            length = wire.shape[0]
            # raw-collective: the fused u8 gather IS the scheme body
            g = lax.all_gather(wire, axes, axis=0, tiled=True) \
                .reshape(p, length)
            codes = lax.bitcast_convert_type(
                g[:, :length - 4 * nb], jnp.int8).reshape(p, *q.shape)
            scales = lax.bitcast_convert_type(
                g[:, length - 4 * nb:].reshape(p, nb, 4), jnp.float32)
            blocks = (codes.astype(jnp.float32)
                      * scales[:, :, None]).sum(axis=0)
            total = _from_blocks(blocks, meta[0], x.shape, jnp.float32)
        else:
            total = local
        out = total.astype(x.dtype)
        if err is None:
            return out
        return out, (x32 - local)
    q, scale, meta = block_quantize(x32, block=block, qmax=Q8_MAX,
                                    shared_axes=axes, stochastic=stochastic,
                                    key=key)
    local = block_dequantize(q, scale, meta, x.shape, jnp.float32)
    # raw-collective: int16 wire sum IS the scheme body (registry q8_hier)
    tot16 = lax.psum(q.astype(jnp.int16), axes)
    total = _from_blocks(tot16.astype(jnp.float32) * scale[:, None],
                         meta[0], x.shape, jnp.float32)
    out = total.astype(x.dtype)
    if err is None:
        return out
    return out, (x32 - local)


def qbf16_psum_flat(x: jax.Array, axes, *,
                    err: Optional[jax.Array] = None):
    """bf16-on-the-wire psum of ``x`` over ``axes`` (no scales).

    Scale-free truncation: each contribution is rounded to bf16, crosses
    the wire as a bitcast ``uint16`` gather, and the sum runs locally in
    f32.  The bitcast matters twice: integer collectives lower natively on
    every backend (XLA's CPU bf16 normalization would silently widen a
    bf16 collective to an f32 wire), and the local f32 accumulation keeps
    the error at one rounding per contribution instead of one per ring
    hop.  Exact when ``x`` is already bf16.
    """
    axes = _axes(axes) if axes else ()
    x32 = x.astype(jnp.float32)
    if err is not None:
        x32 = x32 + err.astype(jnp.float32)
    wire = x32.astype(jnp.bfloat16)
    if axes:
        codes = lax.bitcast_convert_type(wire, jnp.uint16)
        # raw-collective: the u16 bridge exchange IS the scheme body
        g = lax.all_gather(codes, axes, axis=0, tiled=False)
        tot = lax.bitcast_convert_type(g, jnp.bfloat16) \
            .astype(jnp.float32).sum(axis=0)
    else:
        tot = wire.astype(jnp.float32)
    out = tot.astype(x.dtype)
    if err is None:
        return out
    return out, (x32 - wire.astype(jnp.float32))


def _bridge_psum(x, fast_axis, slow_axis, axis, bridge_core, err):
    """Two-tier scaffold shared by the quantized psum bodies.

    Full-precision ``psum_scatter`` over the fast tier, quantized
    ``bridge_core`` over the slow tier, full-precision ``all_gather``
    back.  On a single-tier communicator (``slow_axis=None``) the whole
    reduction IS the bridge — the gradient-bridge case ``reduce_grads``
    dispatches — so the core runs over ``fast_axis`` with no scatter.
    """
    fast = _axes(fast_axis)
    if slow_axis is None:
        return bridge_core(x, fast, err)
    shard = lax.psum_scatter(x, fast, scatter_dimension=axis, tiled=True)
    res = bridge_core(shard, _axes(slow_axis), err)
    total, new_err = res if err is not None else (res, None)
    out = lax.all_gather(total, fast, axis=axis, tiled=True)
    if err is None:
        return out
    return out, new_err


def q8_hier_psum(x: jax.Array, *, fast_axis, slow_axis=None, axis: int = 0,
                 block: int = DEFAULT_BLOCK, err=None):
    """Hier allreduce with an int8 bridge: on-node stages full precision."""
    def core(v, axes, e):
        return q8_psum_flat(v, axes, block=block, err=e)
    return _bridge_psum(x, fast_axis, slow_axis, axis, core, err)


def qbf16_hier_psum(x: jax.Array, *, fast_axis, slow_axis=None,
                    axis: int = 0, err=None):
    """Hier allreduce with a bf16 bridge: on-node stages full precision."""
    def core(v, axes, e):
        return qbf16_psum_flat(v, axes, err=e)
    return _bridge_psum(x, fast_axis, slow_axis, axis, core, err)


# ---------------------------------------------------------------------------
# Quantized allgather bodies
# ---------------------------------------------------------------------------

def _bridge_gather_blocks(q_flat, scale, slow_axis):
    """Gather int8 codes + f32 scales across the bridge (untiled)."""
    slow = _axes(slow_axis)
    # raw-collective: the compressed bridge exchange IS the scheme body
    gq = lax.all_gather(q_flat, slow, axis=0, tiled=False)
    gs = lax.all_gather(scale, slow, axis=0, tiled=False)
    return gq, gs


def _restore_own_region(out, node, slow_axis, axis):
    """Overwrite this pod's region with the exact full-precision copy —
    a pod never pays quantization error for its own contribution."""
    start = axis_index(slow_axis) * node.shape[axis]
    return lax.dynamic_update_slice_in_dim(
        out, node.astype(out.dtype), start, axis=axis)


def _concat_pods(deq_flat, node_shape, axis, n_pods):
    """(n_pods, flat) -> concatenation of pod regions along ``axis``."""
    per_pod = deq_flat.reshape((n_pods,) + tuple(node_shape))
    return jnp.concatenate([per_pod[i] for i in range(n_pods)], axis=axis)


def q8_hier_all_gather(x: jax.Array, *, fast_axis, slow_axis=None,
                       axis: int = 0, block: int = DEFAULT_BLOCK):
    """Hier allgather with an int8 bridge.

    Intra-pod gather stays full precision (shared-memory tier); the node
    region is per-block quantized with LOCAL scales and both codes and
    scales cross the bridge.  The caller's own pod region is restored
    exactly afterwards.
    """
    fast = _axes(fast_axis)
    node = lax.all_gather(x, fast, axis=axis, tiled=True)
    if slow_axis is None:
        return node
    q, scale, meta = block_quantize(node, block=block, qmax=Q8_MAX)
    gq, gs = _bridge_gather_blocks(q.reshape(-1), scale, slow_axis)
    n_pods = gq.shape[0]
    blocks = gq.reshape(n_pods, *q.shape).astype(jnp.float32) \
        * gs[:, :, None]
    deq = blocks.reshape(n_pods, -1)[:, :meta[0]]
    out = _concat_pods(deq, node.shape, axis, n_pods).astype(x.dtype)
    return _restore_own_region(out, node, slow_axis, axis)


def qbf16_hier_all_gather(x: jax.Array, *, fast_axis, slow_axis=None,
                          axis: int = 0):
    """Hier allgather with a bf16 bridge (scale-free truncation)."""
    fast = _axes(fast_axis)
    node = lax.all_gather(x, fast, axis=axis, tiled=True)
    if slow_axis is None:
        return node
    # the wire carries bitcast u16: an integer gather lowers natively
    # everywhere, where a bf16 float collective would be widened to f32 by
    # XLA's CPU bf16 normalization (silently doubling the wire)
    codes = lax.bitcast_convert_type(node.astype(jnp.bfloat16), jnp.uint16)
    # raw-collective: the compressed bridge exchange IS the scheme body
    gw = lax.all_gather(codes, _axes(slow_axis), axis=axis, tiled=True)
    wide = lax.bitcast_convert_type(gw, jnp.bfloat16)
    out = wide.astype(jnp.float32).astype(x.dtype)
    return _restore_own_region(out, node, slow_axis, axis)


def q4_shared_all_gather(x: jax.Array, *, fast_axis, slow_axis=None,
                         axis: int = 0, block: int = DEFAULT_BLOCK):
    """Shared-window allgather with a packed-int4 bridge.

    Mirrors ``shared_all_gather``: the result lives ONCE per pod, sharded
    over ``fast_axis``; only the bridge exchange is compressed (two
    nibbles per byte + per-block f32 scales).  Identity on one pod.
    """
    if slow_axis is None:
        return x
    if x.size % 2:
        raise ValueError(f"q4 shared allgather needs an even payload size, "
                         f"got {x.shape}")
    q, scale, meta = block_quantize(x, block=block, qmax=Q4_MAX)
    packed = pack_int4(q.reshape(-1).reshape(-1, 2)).reshape(-1)
    slow = _axes(slow_axis)
    # raw-collective: the packed-int4 bridge exchange IS the scheme body
    gp = lax.all_gather(packed, slow, axis=0, tiled=False)
    gs = lax.all_gather(scale, slow, axis=0, tiled=False)
    n_pods = gp.shape[0]
    codes = unpack_int4(gp).reshape(n_pods, *q.shape).astype(jnp.float32)
    deq = (codes * gs[:, :, None]).reshape(n_pods, -1)[:, :meta[0]]
    out = _concat_pods(deq, x.shape, axis, n_pods).astype(x.dtype)
    return _restore_own_region(out, x, slow_axis, axis)
