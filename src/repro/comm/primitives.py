"""Shard_map-body collective primitives for two-tier meshes.

This module is the *implementation* layer of ``repro.comm``: every function
operates on the local shard and takes mesh axis names.  ``fast_axis`` is the
intra-pod tier (ICI — the paper's shared-memory node); ``slow_axis`` is the
cross-pod tier (DCN — the paper's network between nodes).  Each may be a
single name or a tuple of names.

Callers should not use these free functions directly: construct a
``repro.comm.Communicator`` and dispatch through the scheme registry
(``repro.comm.registry``).  (The ``repro.core.collectives`` shims were
removed after their one-release deprecation window.)

Three families, mirroring the paper's comparison (the chunked ``pipelined``
family lives in ``repro.comm.pipeline``):

* ``naive_*``   — pure-MPI analogue: single flat phase, result fully
                  replicated on every chip (one private copy per rank).
* ``hier_*``    — two-phase (intra-pod, then bridge) schedule producing the
                  same fully-replicated result; isolates the *latency* effect
                  of the hierarchical schedule (paper Figs 7-10).
* ``shared_*``  — the paper's memory-optimal scheme: the result exists ONCE
                  per pod, sharded over ``fast_axis`` (the shared-memory
                  window).  Children "load" from it with ``shared_read`` (an
                  intra-pod gather at use time — the TPU's load/store).

The multi-leader refinement (paper ref [14]) is built in: chip *i* of every
pod is the leader for shard *i*, so the bridge exchange is spread over all
chips instead of serialized through one.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.substrate.compat import axis_size as _axis_size_one


def _axes(ax) -> tuple:
    return tuple(ax) if isinstance(ax, (tuple, list)) else (ax,)


def axis_size(ax) -> int:
    s = 1
    for a in _axes(ax):
        s *= _axis_size_one(a)
    return s


def axis_index(ax) -> jax.Array:
    """Linearized index over (possibly tuple) axis, row-major."""
    axes = _axes(ax)
    idx = lax.axis_index(axes[0])
    for a in axes[1:]:
        idx = idx * _axis_size_one(a) + lax.axis_index(a)
    return idx


# ---------------------------------------------------------------------------
# Allgather (paper §4.1)
# ---------------------------------------------------------------------------

def naive_all_gather(x: jax.Array, *, fast_axis, slow_axis=None,
                     axis: int = 0) -> jax.Array:
    """Pure-MPI analogue: one flat all-gather; full private copy per chip."""
    names = (_axes(slow_axis) if slow_axis else ()) + _axes(fast_axis)
    return lax.all_gather(x, names, axis=axis, tiled=True)


def hier_all_gather(x: jax.Array, *, fast_axis, slow_axis=None,
                    axis: int = 0) -> jax.Array:
    """Two-phase allgather: intra-pod gather, then bridge exchange of whole
    node regions (leaders' ``MPI_Allgatherv`` in the regular case)."""
    node_region = lax.all_gather(x, _axes(fast_axis), axis=axis, tiled=True)
    if slow_axis is None:
        return node_region
    return lax.all_gather(node_region, _axes(slow_axis), axis=axis, tiled=True)


def shared_all_gather(x: jax.Array, *, fast_axis, slow_axis=None,
                      axis: int = 0) -> jax.Array:
    """Paper's scheme: children write their partitions in place (no intra-pod
    copies); only the bridge exchange runs.  Chip *i* ends holding shard *i*
    of the pod's single shared copy: the concatenation over pods of every
    pod's chip-*i* contribution.

    Global element order of the shared copy is (local_rank, pod) — i.e. the
    node-sorted rank array of paper §6 with the multi-leader interleave.  Use
    ``shared_read`` to materialize the full buffer (ordered (local, pod)), or
    ``shared_to_rank_order`` to get SMP rank order.
    """
    if slow_axis is None:
        return x  # single node: partition already in the shared window
    return lax.all_gather(x, _axes(slow_axis), axis=axis, tiled=True)


def shared_read(shard: jax.Array, *, fast_axis, axis: int = 0) -> jax.Array:
    """Load the pod-shared buffer (an intra-pod gather at use time)."""
    return lax.all_gather(shard, _axes(fast_axis), axis=axis, tiled=True)


def shared_to_rank_order(full: jax.Array, *, num_pods: int,
                         chips_per_pod: int, axis: int = 0) -> jax.Array:
    """Reorder a ``shared_read`` result from (local, pod, chunk) layout to
    SMP rank order (pod, local, chunk) along ``axis``."""
    moved = jnp.moveaxis(full, axis, 0)
    n = moved.shape[0]
    chunk = n // (num_pods * chips_per_pod)
    r = moved.reshape((chips_per_pod, num_pods, chunk) + moved.shape[1:])
    r = jnp.swapaxes(r, 0, 1)
    r = r.reshape((n,) + moved.shape[1:])
    return jnp.moveaxis(r, 0, axis)


def shared_all_gather_v(x_padded: jax.Array, valid: jax.Array, *,
                        slow_axis=None, axis: int = 0
                        ) -> tuple[jax.Array, jax.Array]:
    """Irregular variant (paper Figs 4/10): per-chip contributions of
    different true lengths, padded to a common max.  Returns the bridge-
    gathered padded blocks plus the gathered valid-counts; the compaction map
    is ``plans.GatherPlan`` (a one-off, like the paper's counts/displs).

    On a single node (``slow_axis=None``) there is no bridge: the local
    partition is already in the shared window, so the "gathered" leading pod
    dimension has extent 1."""
    if slow_axis is None:
        return jnp.expand_dims(x_padded, axis), valid[None]
    blocks = lax.all_gather(x_padded, _axes(slow_axis), axis=axis, tiled=False)
    counts = lax.all_gather(valid, _axes(slow_axis), tiled=False)
    return blocks, counts


# ---------------------------------------------------------------------------
# Broadcast (paper §4.2)
# ---------------------------------------------------------------------------

def naive_broadcast(x: jax.Array, *, root: int, fast_axis, slow_axis=None
                    ) -> jax.Array:
    """Pure-MPI analogue: every chip ends with a private full copy."""
    names = (_axes(slow_axis) if slow_axis else ()) + _axes(fast_axis)
    me = axis_index(names)
    contrib = jnp.where(me == root, x, jnp.zeros_like(x))
    return lax.psum(contrib, names)


def _flat_root(root, fast_axis, slow_axis):
    """Resolve the (root_pod, root_local) pair from a flat SMP rank.

    ``root`` is a flat rank in (pod, chip) row-major order — the same
    numbering as ``naive_broadcast``.  (The legacy ``root_pod=`` pod-only
    spelling was removed after its deprecation release; pass
    ``root=pod * ranks_per_node`` for a pod's leader.)
    """
    if root is None:
        root = 0
    c = axis_size(fast_axis)
    if isinstance(root, int) and isinstance(c, int):
        total = c * (axis_size(slow_axis) if slow_axis is not None else 1)
        if isinstance(total, int) and not 0 <= root < total:
            raise ValueError(f"root={root} out of range for "
                             f"{total} ranks")
    return root // c, root % c


def hier_broadcast(x: jax.Array, *, root: int | None = None, fast_axis,
                   slow_axis=None) -> jax.Array:
    """Two-phase broadcast to full replication: bridge bcast between leaders,
    then intra-pod bcast (leader -> children copies of the naive scheme).

    ``root`` is the flat SMP rank of the source (same numbering as
    ``naive_broadcast``); the chip holding it acts as its pod's leader."""
    my_pod_root, my_local_root = _flat_root(root, fast_axis, slow_axis)
    fast = _axes(fast_axis)
    me_fast = axis_index(fast)
    if slow_axis is not None:
        slow = _axes(slow_axis)
        my_pod = axis_index(slow)
        lead = jnp.where((my_pod == my_pod_root) & (me_fast == my_local_root),
                         x, jnp.zeros_like(x))
        lead = lax.psum(lead, slow)  # bridge bcast (only leaders nonzero)
    else:
        lead = jnp.where(me_fast == my_local_root, x, jnp.zeros_like(x))
    return lax.psum(jnp.where(me_fast == my_local_root, lead,
                              jnp.zeros_like(lead)), fast)


def shared_broadcast(x: jax.Array, *, root: int | None = None, fast_axis,
                     slow_axis=None, axis: int = 0) -> jax.Array:
    """Paper's scheme: ONE shared copy per pod, sharded over ``fast_axis``.

    Phase 1 (intra-pod scatter at the root pod): the root chip's message is
    reduce-scattered so chip *i* holds shard *i* — this is the write into the
    shared window.  Phase 2 (bridge): shard *i* crosses pods once (multi-
    leader bcast).  Children read via ``shared_read``.

    ``root`` is the flat SMP rank of the source (same numbering as
    ``naive_broadcast``).
    """
    my_pod_root, my_local_root = _flat_root(root, fast_axis, slow_axis)
    fast = _axes(fast_axis)
    me_fast = axis_index(fast)
    contrib = jnp.where(me_fast == my_local_root, x, jnp.zeros_like(x))
    shard = lax.psum_scatter(contrib, fast, scatter_dimension=axis,
                             tiled=True)
    if slow_axis is None:
        return shard
    slow = _axes(slow_axis)
    my_pod = axis_index(slow)
    shard = jnp.where(my_pod == my_pod_root, shard, jnp.zeros_like(shard))
    return lax.psum(shard, slow)


# ---------------------------------------------------------------------------
# Allreduce / reductions (gradient bridge — paper's scheme applied to psum)
# ---------------------------------------------------------------------------

def naive_psum(x: jax.Array, *, fast_axis, slow_axis=None) -> jax.Array:
    """Flat allreduce; result replicated per chip."""
    names = (_axes(slow_axis) if slow_axis else ()) + _axes(fast_axis)
    return lax.psum(x, names)


def hier_psum(x: jax.Array, *, fast_axis, slow_axis=None, axis: int = 0
              ) -> jax.Array:
    """Two-phase allreduce to full replication: intra-pod reduce-scatter,
    bridge allreduce on shards (multi-leader), intra-pod allgather."""
    shard = lax.psum_scatter(x, _axes(fast_axis), scatter_dimension=axis,
                             tiled=True)
    if slow_axis is not None:
        shard = lax.psum(shard, _axes(slow_axis))
    return lax.all_gather(shard, _axes(fast_axis), axis=axis, tiled=True)


def shared_psum_scatter(x: jax.Array, *, fast_axis, slow_axis=None,
                        axis: int = 0) -> jax.Array:
    """Paper's memory-optimal reduction: result exists once per pod, sharded
    over ``fast_axis``.  This is the gradient-reduction of hier train mode:
    children write partial sums (intra-pod RS), leaders exchange on the
    bridge, the reduced value never gets replicated."""
    shard = lax.psum_scatter(x, _axes(fast_axis), scatter_dimension=axis,
                             tiled=True)
    if slow_axis is not None:
        shard = lax.psum(shard, _axes(slow_axis))
    return shard


def naive_reduce_scatter(x: jax.Array, *, fast_axis, slow_axis=None,
                         axis: int = 0) -> jax.Array:
    """Flat MPI_Reduce_scatter analogue: every rank ends with its 1/R slice
    of the global sum, rank-major (pod, chip) order."""
    names = (_axes(slow_axis) if slow_axis else ()) + _axes(fast_axis)
    return lax.psum_scatter(x, names, scatter_dimension=axis, tiled=True)


# ---------------------------------------------------------------------------
# All-to-all (MoE dispatch / SUMMA panel exchange / transpose workloads)
# ---------------------------------------------------------------------------

def naive_all_to_all(x: jax.Array, *, fast_axis, slow_axis=None,
                     axis: int = 0) -> jax.Array:
    """Pure-MPI analogue: one flat all-to-all over every rank.  The local
    buffer along ``axis`` is R equal chunks in flat (pod, chip) rank order;
    chunk *s* goes to rank *s* and the result is ordered by source rank."""
    names = (_axes(slow_axis) if slow_axis else ()) + _axes(fast_axis)
    return lax.all_to_all(x, names, split_axis=axis, concat_axis=axis,
                          tiled=True)


def hier_all_to_all(x: jax.Array, *, fast_axis, slow_axis=None,
                    axis: int = 0) -> jax.Array:
    """Node-aware two-phase all-to-all (same result as ``naive_all_to_all``).

    Phase 1 (bridge): whole node-sized superchunks cross pods once — the
    leaders' aggregated exchange, P messages instead of P*c.  Phase 2
    (intra-pod): ranks redistribute within the shared-memory node, one
    untiled exchange per fast-tier axis.  Rank order of the result is
    identical to the flat scheme.
    """
    fast = _axes(fast_axis)
    if slow_axis is not None:
        x = lax.all_to_all(x, _axes(slow_axis), split_axis=axis,
                           concat_axis=axis, tiled=True)
    pods = axis_size(slow_axis) if slow_axis is not None else 1
    fast_sizes = tuple(_axis_size_one(a) for a in fast)
    chips = 1
    for s in fast_sizes:
        chips *= s
    moved = jnp.moveaxis(x, axis, 0)
    n = moved.shape[0]
    if n % (pods * chips):
        raise ValueError(f"all-to-all buffer dim {n} must tile over "
                         f"{pods * chips} ranks")
    chunk = n // (pods * chips)
    y = moved.reshape((pods,) + fast_sizes + (chunk,) + moved.shape[1:])
    for i, a in enumerate(fast):
        if fast_sizes[i] > 1:
            y = lax.all_to_all(y, a, split_axis=1 + i, concat_axis=1 + i,
                               tiled=False)
    y = y.reshape((n,) + moved.shape[1:])
    return jnp.moveaxis(y, 0, axis)
