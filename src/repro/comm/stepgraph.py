"""Step-graph collective optimizer: record a step's collectives, rewrite
the schedule, then apply it.

The paper's win is treating ONE collective as a node-granular schedule
(one shared on-node copy, bridge traffic only across nodes).  Applied one
level up, a *step's worth* of collectives is also a schedule worth
optimizing: a train step issues dozens of tiny bridge messages (per-leaf
gradient psums, scalar loss/count/norm reductions) that never reach the
message sizes where the tuning table has measured winners, and every one
of them pays a fixed dispatch cost.  Task & Chauhan's communication model
and the multi-object aggregation of Huang et al. (PAPERS.md) both say the
same thing: aggregate small on-node-reducible messages *before* they hit
the slow tier.

Lifecycle (record -> rewrite -> apply):

1. **record** — ``Communicator.record()`` returns a ``GraphRecorder``;
   call sites record their collectives (``rec.allreduce(x, axes=...)``,
   ``rec.gather(window, key=...)``) and get back lightweight ``Deferred``
   refs instead of values.  Recording builds a ``CollectiveGraph`` of
   ``CollectiveNode``s: family, operand key, axes, dtype, nbytes, program
   position.
2. **rewrite** — ``optimize()`` runs three registry-driven passes:

   * **bucketing** — bucketable same-(axes, dtype, scheme) allreduces are
     packed into flat buffers.  Bucket sizes come from
     ``core.plans.best_bucket_bytes`` / ``bucket_time_model`` over the
     tuning table's measured psum cells for this topology (the measured
     sweet spot seeds the candidate list; the closed-form schedule model
     decides off-table).  The pack/unpack codec (``pack_leaves`` /
     ``unpack_leaves``) is ravel + concat + zero-pad + slice + reshape —
     arithmetic-free, so it is bit-identical leaf-for-leaf.
   * **dedup** — repeated gathers of the same ``SharedWindow`` within one
     epoch collapse to one issue; the (key, epoch) pair is the identity,
     so a fence between records keeps both issues (epoch integrity comes
     from the ``AsyncCollectiveHandle`` machinery, not from trust).
   * **sink/reorder** — every surviving issue happens up front (in first-
     record order) and results resolve late through the existing handle /
     ``_ordered``-token machinery, so independent collectives overlap the
     compute between issue and use inside one jitted dataflow.

3. **apply** — ``Communicator.apply_schedule()`` (via
   ``GraphRecorder.run()``) executes the rewritten schedule and returns a
   ``ScheduleResult`` that resolves ``Deferred`` refs (``result[ref]`` /
   ``result.resolve(tree)``).

``Schedule.report()`` is a JSON-able before/after account of the rewrite
(message counts, bytes, per-bucket detail) with its own schema version —
``scripts/check_schedule_report.py`` validates committed reports with
stdlib only.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Hashable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import registry
from repro.comm.handle import AsyncCollectiveHandle, _ordered
from repro.core.plans import BUCKET_BYTES_CANDIDATES, best_bucket_bytes

SCHEMA_VERSION = "repro.stepgraph/v1"


# ---------------------------------------------------------------------------
# The graph
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CollectiveNode:
    """One recorded collective call (or an identity placeholder)."""

    nid: int
    family: str                     # "allreduce" | "gather" | "identity"
    key: Hashable                   # operand identity (leaf path, window id)
    axes: tuple[str, ...]           # mesh axes the collective spans
    dtype: str
    shape: tuple[int, ...]
    elems: int
    nbytes: int
    pos: int                        # program position (record order)
    scheme: str = "naive"           # pinned registry scheme ("auto" allowed)
    result: Optional[str] = None    # result-class constraint for dispatch
    bucketable: bool = False
    epoch: int = 0                  # gather only: the window's issue epoch


class CollectiveGraph:
    """Append-only record of a step's collective calls."""

    def __init__(self):
        self._nodes: list[CollectiveNode] = []

    def add(self, *, family: str, key: Hashable, axes: Sequence[str],
            dtype: str, shape: Sequence[int], elem_bytes: int,
            scheme: str = "naive", result: Optional[str] = None,
            bucketable: bool = False, epoch: int = 0) -> int:
        nid = len(self._nodes)
        elems = int(math.prod(shape)) if shape else 1
        self._nodes.append(CollectiveNode(
            nid=nid, family=family, key=key, axes=tuple(axes),
            dtype=str(dtype), shape=tuple(int(d) for d in shape),
            elems=elems, nbytes=elems * elem_bytes, pos=nid,
            scheme=scheme, result=result, bucketable=bucketable,
            epoch=epoch))
        return nid

    @property
    def nodes(self) -> tuple[CollectiveNode, ...]:
        return tuple(self._nodes)

    def __len__(self) -> int:
        return len(self._nodes)


# ---------------------------------------------------------------------------
# Pack/unpack codec (bit-identical leaf-for-leaf)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PackSpec:
    """Layout of one packed bucket buffer: per-leaf shapes in pack order,
    plus the zero-padding appended to reach the scheme's tiling multiple."""

    shapes: tuple[tuple[int, ...], ...]
    dtype: str
    pad_elems: int

    @property
    def leaf_elems(self) -> tuple[int, ...]:
        return tuple(int(math.prod(s)) if s else 1 for s in self.shapes)

    @property
    def total_elems(self) -> int:
        return sum(self.leaf_elems) + self.pad_elems


def pack_leaves(leaves: Sequence[jax.Array], *, pad_to: int = 1
                ) -> tuple[jax.Array, PackSpec]:
    """Ravel + concatenate ``leaves`` into one flat buffer, zero-padded up
    to a multiple of ``pad_to`` elements.  Pure data movement — no
    arithmetic touches the payload, which is what makes the bucketed
    reduction bit-identical to the per-leaf one (an elementwise reduction
    of the concatenation IS the concatenation of the reductions)."""
    if not leaves:
        raise ValueError("cannot pack an empty bucket")
    dtypes = {str(x.dtype) for x in leaves}
    if len(dtypes) > 1:
        raise ValueError(f"mixed dtypes in one bucket: {sorted(dtypes)}")
    flat = [jnp.ravel(x) for x in leaves]
    total = sum(f.shape[0] for f in flat)
    pad = (-total) % max(1, pad_to)
    if pad:
        flat.append(jnp.zeros((pad,), dtype=leaves[0].dtype))
    buf = jnp.concatenate(flat) if len(flat) > 1 else flat[0]
    spec = PackSpec(shapes=tuple(tuple(x.shape) for x in leaves),
                    dtype=dtypes.pop(), pad_elems=pad)
    return buf, spec


def unpack_leaves(buf: jax.Array, spec: PackSpec) -> list[jax.Array]:
    """Slice + reshape the packed buffer back into its leaves (padding is
    dropped).  Exact inverse of ``pack_leaves`` element-for-element."""
    if buf.shape != (spec.total_elems,):
        raise ValueError(f"buffer shape {buf.shape} does not match spec "
                         f"({spec.total_elems},)")
    out, off = [], 0
    for shape, n in zip(spec.shapes, spec.leaf_elems):
        out.append(jax.lax.slice_in_dim(buf, off, off + n).reshape(shape))
        off += n
    return out


# ---------------------------------------------------------------------------
# The optimized schedule
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Bucket:
    """One packed reduction: members share (axes, dtype, scheme)."""

    axes: tuple[str, ...]
    dtype: str
    scheme: str
    nids: tuple[int, ...]           # member nodes, pack order == pos order
    pad_to: int                     # element tiling of the packed buffer
    target_bytes: int               # the partitioner's target for this group

    def elems(self, graph: CollectiveGraph) -> int:
        n = sum(graph.nodes[i].elems for i in self.nids)
        return n + ((-n) % max(1, self.pad_to))

    def nbytes(self, graph: CollectiveGraph) -> int:
        per = graph.nodes[self.nids[0]].nbytes // \
            max(1, graph.nodes[self.nids[0]].elems)
        return self.elems(graph) * per


@dataclasses.dataclass(frozen=True)
class Schedule:
    """The rewritten schedule: what to issue, in what order."""

    graph: CollectiveGraph
    buckets: tuple[Bucket, ...]
    singles: tuple[int, ...]              # unbucketed allreduce nids
    gather_primary: dict                  # gather nid -> issuing nid
    order: tuple[tuple[str, int], ...]    # ("bucket", idx) | ("single"|
    #                                       "gather", nid), issue order

    def report(self) -> dict:
        """JSON-able before/after account of the rewrite (the committed
        ``SCHEDULE_stepgraph.json`` rows; schema-checked in CI)."""
        nodes = self.graph.nodes
        ar_nodes = [n for n in nodes if n.family == "allreduce"]
        g_nodes = [n for n in nodes if n.family == "gather"]
        bucket_rows = []
        for b in self.buckets:
            raw = sum(nodes[i].nbytes for i in b.nids)
            bucket_rows.append({
                "axes": list(b.axes), "dtype": b.dtype, "scheme": b.scheme,
                "count": len(b.nids), "bytes": raw,
                "padded_bytes": b.nbytes(self.graph),
                "target_bytes": b.target_bytes})
        after_msgs = len(self.buckets) + len(self.singles)
        return {
            "schema": SCHEMA_VERSION,
            "nodes": len(nodes),
            "allreduce": {
                "before_messages": len(ar_nodes),
                "after_messages": after_msgs,
                "before_bytes": sum(n.nbytes for n in ar_nodes),
                "after_bytes": sum(r["padded_bytes"] for r in bucket_rows)
                + sum(nodes[i].nbytes for i in self.singles),
            },
            "gather": {
                "before_issues": len(g_nodes),
                "after_issues": len(set(self.gather_primary.values())),
            },
            "buckets": bucket_rows,
            "singles": len(self.singles),
            "order": [[kind, int(idx)] for kind, idx in self.order],
        }


def bucket_target_candidates(table, *, pods: Optional[int],
                             chips: Optional[int], n_fast_axes: int = 1,
                             dtype: str = "float32") -> tuple[int, ...]:
    """Bucket-size candidates for ``best_bucket_bytes``: the tuning table's
    MEASURED psum cell sizes for this topology signature (the sweet spot
    the bench actually found), falling back to the static
    ``core.plans.BUCKET_BYTES_CANDIDATES`` grid when nothing was measured
    (no table, unknown topology, or no static counts)."""
    if table is None or not pods or not chips:
        return BUCKET_BYTES_CANDIDATES
    from repro.comm.tuning import topo_signature
    sig = topo_signature(pods, chips, n_fast_axes)
    measured = sorted({e.nbytes for e in table.entries
                       if e.family == "psum" and e.topo == sig
                       and e.source == "measured"})
    return tuple(measured) or BUCKET_BYTES_CANDIDATES


def optimize(graph: CollectiveGraph, *, pods: Optional[int] = None,
             chips: Optional[int] = None, n_fast_axes: int = 1,
             table=None, target_bytes: Optional[int] = None) -> Schedule:
    """Rewrite the recorded graph: bucket, dedup, sink/reorder.

    Pure Python on static metadata — runs once at trace time.  An explicit
    ``target_bytes`` pins the bucket size; otherwise
    ``core.plans.best_bucket_bytes`` picks it per (axes, dtype, scheme)
    group from the tuning table's measured candidates.
    """
    from repro.core.plans import greedy_buckets

    nodes = graph.nodes
    # -- pass 1: bucketing ---------------------------------------------------
    groups: dict[tuple, list[CollectiveNode]] = {}
    singles: list[int] = []
    for n in nodes:
        if n.family != "allreduce":
            continue
        if (n.bucketable and n.scheme != "auto"
                and registry.get_scheme(n.scheme).bucketable("psum")):
            groups.setdefault((n.axes, n.dtype, n.scheme), []).append(n)
        else:
            singles.append(n.nid)
    buckets: list[Bucket] = []
    for (axes, dtype, scheme), members in groups.items():
        members.sort(key=lambda n: n.pos)
        if len(members) == 1:
            singles.append(members[0].nid)
            continue
        sch = registry.get_scheme(scheme)
        pad_to = sch.tiling("psum", pods=pods or 1, chips=chips or 1)
        elem_bytes = members[0].nbytes // max(1, members[0].elems)
        sizes = [n.nbytes for n in members]
        tgt = target_bytes
        if tgt is None:
            cands = bucket_target_candidates(
                table, pods=pods, chips=chips, n_fast_axes=n_fast_axes,
                dtype=dtype)
            tgt = best_bucket_bytes(
                sizes, num_nodes=pods or 1, ranks_per_node=chips or 1,
                scheme=sch._plans_scheme, pad_to=pad_to * elem_bytes,
                candidates=cands)
        for part in greedy_buckets(sizes, tgt):
            buckets.append(Bucket(
                axes=axes, dtype=dtype, scheme=scheme,
                nids=tuple(members[i].nid for i in part),
                pad_to=pad_to, target_bytes=tgt))
    # -- pass 2: gather dedup ------------------------------------------------
    gather_primary: dict[int, int] = {}
    first_issue: dict[tuple, int] = {}
    for n in nodes:
        if n.family != "gather":
            continue
        ident = (n.key, n.axes, n.epoch)
        gather_primary[n.nid] = first_issue.setdefault(ident, n.nid)
    # -- pass 3: sink/reorder (issue early, in first-record order) ----------
    order: list[tuple[str, int]] = []
    order += [("gather", nid) for nid in sorted(set(gather_primary.values()),
                                                key=lambda i: nodes[i].pos)]
    order += [("bucket", i) for i, _ in sorted(
        enumerate(buckets), key=lambda ib: nodes[ib[1].nids[0]].pos)]
    order += [("single", nid) for nid in sorted(
        singles, key=lambda i: nodes[i].pos)]
    return Schedule(graph=graph, buckets=tuple(buckets),
                    singles=tuple(sorted(singles)),
                    gather_primary=gather_primary, order=tuple(order))


# ---------------------------------------------------------------------------
# Apply (the executor)
# ---------------------------------------------------------------------------

def _split_tier(axes: Sequence[str], slow_names: Sequence[str]
                ) -> tuple[tuple[str, ...], Optional[tuple[str, ...]]]:
    """Split a node's axes into the issuing communicator's (fast, slow)
    tiers, slow-first ordering preserved: ``naive_psum`` lowers to
    ``lax.psum(x, slow + fast)``, so a recorded ``axes`` that already lists
    bridge axes first reproduces ``lax.psum(x, axes)`` exactly."""
    slow = tuple(a for a in axes if a in slow_names)
    fast = tuple(a for a in axes if a not in slow_names)
    if not fast:
        return slow, None           # bridge-only: flat single-tier comm
    return fast, slow or None


def _issue_comm(comm, axes: tuple[str, ...]):
    """The communicator that issues one node: the recording communicator
    itself when the axes match (keeps static counts, so ``scheme="auto"``
    resolves exactly as an un-recorded call would), else a fresh two-tier
    split of the node's own axes."""
    from repro.comm import primitives as p
    from repro.comm.communicator import Communicator
    if axes == comm.axes:
        return comm
    fast, slow = _split_tier(axes, p._axes(comm.slow_axis)
                             if comm.slow_axis else ())
    return Communicator(fast_axis=fast, slow_axis=slow)


def apply_schedule(comm, schedule: Schedule, values: dict) -> dict:
    """Execute the rewritten schedule inside the current trace.

    ``values`` maps nid -> recorded operand (arrays for allreduce nodes,
    ``SharedWindow``s for gathers).  Every issue happens up front in
    schedule order; the results are then pinned behind ONE shared ordering
    token (the ``ParamGroup`` one-event-per-bucket idiom: two barrier ops
    for the whole schedule instead of two per message) and unpacked late.
    Returns nid -> resolved value.
    """
    nodes = schedule.graph.nodes
    out: dict[int, Any] = {}
    for n in nodes:                       # identity nodes resolve directly
        if n.family == "identity":
            out[n.nid] = values[n.nid]

    issued: list[tuple[str, Any, Any]] = []   # (kind, meta, raw result)
    for kind, idx in schedule.order:
        if kind == "bucket":
            b = schedule.buckets[idx]
            buf, spec = pack_leaves([values[i] for i in b.nids],
                                    pad_to=b.pad_to)
            red = _issue_comm(comm, b.axes).allreduce(
                buf, scheme=b.scheme, result="replicated")
            issued.append(("bucket", (b, spec), red))
        elif kind == "single":
            n = nodes[idx]
            red = _issue_comm(comm, n.axes).allreduce(
                values[idx], scheme=n.scheme, result=n.result)
            issued.append(("single", idx, red))
        else:                             # gather (already deduped)
            handle = AsyncCollectiveHandle.issue("allgather", values[idx])
            issued.append(("gather", idx, handle))

    arrays = tuple(r for k, _, r in issued if k != "gather")
    if arrays:
        ordered, token = _ordered(arrays, jnp.ones((), jnp.float32))
        it = iter(ordered)
        arrays = {id(r): next(it) for k, _, r in issued if k != "gather"}

    resolved_gathers: dict[int, Any] = {}
    for kind, meta, raw in issued:
        if kind == "bucket":
            b, spec = meta
            for nid, leaf in zip(b.nids, unpack_leaves(arrays[id(raw)],
                                                       spec)):
                out[nid] = leaf
        elif kind == "single":
            out[meta] = arrays[id(raw)]
        else:
            resolved_gathers[meta] = raw.resolve()
    for nid, primary in schedule.gather_primary.items():
        out[nid] = resolved_gathers[primary]
    return out


# ---------------------------------------------------------------------------
# Recorder (the Communicator.record() entry point)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Deferred:
    """A ref to a recorded collective's (future) result.  Opaque: hold it,
    hand it back to the ``ScheduleResult``."""

    nid: int


class ScheduleResult:
    """Resolved schedule: maps ``Deferred`` refs back to values."""

    def __init__(self, values: dict, schedule: Schedule):
        self._values = values
        self.schedule = schedule

    def __getitem__(self, ref: Deferred):
        return self._values[ref.nid]

    def resolve(self, tree):
        """Replace every ``Deferred`` leaf in ``tree`` with its value."""
        is_ref = lambda x: isinstance(x, Deferred)  # noqa: E731
        return jax.tree.map(lambda x: self._values[x.nid] if is_ref(x)
                            else x, tree, is_leaf=is_ref)

    def report(self) -> dict:
        return self.schedule.report()


class GraphRecorder:
    """Records a step's collectives against one base communicator.

    ``allreduce``/``gather`` return ``Deferred`` refs; ``run()`` optimizes
    and applies the schedule, returning a ``ScheduleResult``.
    """

    def __init__(self, comm, *, table=None):
        self.comm = comm
        self.graph = CollectiveGraph()
        self._values: dict[int, Any] = {}
        self._table = table

    def allreduce(self, x: jax.Array, *, axes: Sequence[str],
                  scheme: str = "naive", result: Optional[str] = None,
                  bucketable: Optional[bool] = None,
                  key: Hashable = None) -> Deferred:
        """Record one allreduce over ``axes`` (slow axes first, as
        ``grad_reduce_axes`` emits them).  Empty ``axes`` records an
        identity (the leaf needs no reduction but keeps its slot).
        ``bucketable`` defaults to True exactly when the pinned scheme's
        packed reduction is elementwise (``registry`` ``bucketable``) —
        an ``"auto"`` pick is resolved per message size, so it never
        buckets unless the caller opts in."""
        axes = tuple(axes)
        dt = np.dtype(x.dtype)
        if not axes:
            nid = self.graph.add(family="identity", key=key, axes=(),
                                 dtype=dt.name, shape=x.shape,
                                 elem_bytes=dt.itemsize)
            self._values[nid] = x
            return Deferred(nid)
        if bucketable is None:
            bucketable = (scheme != "auto"
                          and registry.get_scheme(scheme).bucketable("psum"))
        nid = self.graph.add(family="allreduce", key=key, axes=axes,
                             dtype=dt.name, shape=x.shape,
                             elem_bytes=dt.itemsize, scheme=scheme,
                             result=result, bucketable=bucketable)
        self._values[nid] = x
        return Deferred(nid)

    def gather(self, window, *, key: Hashable) -> Deferred:
        """Record a gather (read) of a ``SharedWindow``.  ``key`` is the
        window's stable identity (e.g. the leaf path): repeated gathers of
        the same key in the same epoch dedup to one issue; a fence bumps
        the epoch and keeps both."""
        from repro.comm import primitives as p
        dt = np.dtype(window.shard.dtype)
        nid = self.graph.add(
            family="gather", key=key,
            axes=tuple(p._axes(window.comm.fast_axis)), dtype=dt.name,
            shape=window.shard.shape, elem_bytes=dt.itemsize,
            epoch=window.epoch)
        self._values[nid] = window
        return Deferred(nid)

    def run(self, *, target_bytes: Optional[int] = None) -> ScheduleResult:
        """Optimize the recorded graph and apply it."""
        from repro.comm import primitives as p
        from repro.comm import tuning
        table = self._table if self._table is not None \
            else tuning.active_table()
        schedule = optimize(
            self.graph, pods=self.comm.pods, chips=self.comm.chips,
            n_fast_axes=len(p._axes(self.comm.fast_axis)), table=table,
            target_bytes=target_bytes)
        values = apply_schedule(self.comm, schedule, self._values)
        return ScheduleResult(values, schedule)


# ---- the committed schedule artifact ----------------------------------------
def schedule_reports(matrix=None, configs=None) -> list[dict]:
    """One schedule ``report()`` per (model config, topology): trace the
    ``step_time`` bench body with the ``stepgraph`` opt and collect what
    the optimizer did.  Pure tracing (``jax.eval_shape``) — no compile,
    no execution, a few seconds for the whole matrix."""
    from repro.bench.step_time import STEP_CONFIGS
    from repro.configs import get_config
    from repro.runtime.steps import make_step_bench
    from repro.substrate.cluster import default_matrix

    rows = []
    for vc in (matrix if matrix is not None else default_matrix()):
        for cfg_name in (configs or STEP_CONFIGS):
            cfg = get_config(cfg_name).reduced()
            sink: list[dict] = []
            body, in_specs, out_specs, make_args, elems = make_step_bench(
                cfg, vc, opts=("stepgraph",), unroll=cfg.n_units,
                schedule_sink=sink)
            avals = tuple(jax.ShapeDtypeStruct(a.shape, a.dtype)
                          for a in make_args())
            jax.eval_shape(vc.smap(body, in_specs, out_specs), *avals)
            rows.append({"config": cfg_name, "topology": vc.label,
                         "pods": vc.pods, "chips": vc.chips,
                         "elems": elems, **sink[-1]})
    return rows


def _main(argv=None) -> int:
    """Emit ``SCHEDULE_stepgraph.json`` — the committed record of the
    optimizer's rewrite over the standard topology matrix, validated by
    ``scripts/check_schedule_report.py`` in CI.

        python -m repro.comm.stepgraph [--out SCHEDULE_stepgraph.json]
    """
    import argparse
    import json

    from repro.substrate.cluster import ensure_host_device_count
    ensure_host_device_count(8)

    ap = argparse.ArgumentParser(prog="python -m repro.comm.stepgraph")
    ap.add_argument("--out", default="SCHEDULE_stepgraph.json")
    args = ap.parse_args(argv)
    reports = schedule_reports()
    doc = {
        "schema": SCHEMA_VERSION,
        "generated_by": "python -m repro.comm.stepgraph",
        "jax_version": jax.__version__,
        "reports": reports,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    n_topo = len({r["topology"] for r in reports})
    print(f"repro.comm.stepgraph: wrote {args.out} "
          f"({len(reports)} schedules over {n_topo} topologies)")
    return 0


if __name__ == "__main__":
    raise SystemExit(_main())
