"""Collective scheme registry: every scheme is ONE self-describing entry.

A ``CollectiveScheme`` bundles everything the rest of the repo needs to know
about one collective strategy:

* ``ops``          — the shard_map-body implementation per family
                     (``repro.comm.primitives`` functions behind a uniform
                     keyword signature);
* ``result_class`` — ``"replicated"`` (a private full result per rank — the
                     pure-MPI analogue and the two-phase hier schedule) or
                     ``"shared"`` (ONE copy per node, sharded over the fast
                     tier — the paper's MPI-3 shared window);
* ``traffic``      — the closed-form ``core.plans`` traffic model for a
                     measured config;
* ``links``        — expected per-chip link bytes of the scheme's known
                     lowering (ring model, matching
                     ``analysis.roofline.parse_collectives`` exactly);
* ``result_node``  — expected resident result bytes on one node;
* ``identities``   — documented exact identities between parsed wire /
                     resident bytes and the traffic model.

``repro.bench.suites`` sweeps ``schemes_for(family)``, ``repro.bench.
validate`` pulls every expectation from here, and ``Communicator`` methods
dispatch through ``get_scheme``: registering a new scheme is the ONLY step
needed to have it swept, cross-checked and callable — no string matching of
scheme names anywhere else.
"""

from __future__ import annotations

from types import MappingProxyType
from typing import Callable, Mapping, Optional, Sequence

from repro.comm import pipeline as pipe
from repro.comm import primitives as p
from repro.core.plans import (CollectiveTraffic, allgather_traffic,
                              allgatherv_traffic, allreduce_traffic,
                              alltoall_traffic, best_chunk_count,
                              broadcast_traffic, collective_time_model,
                              pipelined_time_model, reduce_scatter_traffic)

CNT_BYTES = 4  # int32 valid-count payload of the irregular allgatherv


# ---------------------------------------------------------------------------
# Ring-model per-chip link costs (parse_collectives' accounting exactly).
# ---------------------------------------------------------------------------

def _ag(out_bytes: float, n: int) -> float:
    return out_bytes * (n - 1) / n if n > 1 else 0.0


def _rs(out_bytes: float, n: int) -> float:
    return out_bytes * (n - 1) if n > 1 else 0.0


def _ar(msg_bytes: float, n: int) -> float:
    return 2.0 * msg_bytes * (n - 1) / n if n > 1 else 0.0


def _a2a(buf_bytes: float, n: int) -> float:
    return buf_bytes * (n - 1) / n if n > 1 else 0.0


class CollectiveScheme:
    """One registered collective strategy.  Subclass + ``register_scheme``
    is the complete recipe for adding a scheme: shadow ``ops`` with the
    family table (it is a read-only mapping on purpose — mutating the
    inherited one would leak bodies into every other scheme)."""

    name: str = ""
    result_class: str = "replicated"        # "replicated" | "shared"
    ops: Mapping[str, Callable] = MappingProxyType({})

    # -- dispatch ------------------------------------------------------------
    def supports(self, family: str) -> bool:
        return family in self.ops

    def op(self, family: str) -> Callable:
        if family not in self.ops:
            have = [s.name for s in schemes_for(family)]
            raise NotImplementedError(
                f"scheme {self.name!r} does not implement {family!r}; "
                f"schemes supporting it: {have or 'none registered'}")
        return self.ops[family]

    # -- plans.py traffic model ----------------------------------------------
    @property
    def _plans_scheme(self) -> str:
        # plans.py spells the two result classes "naive" (replicated) and
        # "hier" (one shared copy per node).
        return "naive" if self.result_class == "replicated" else "hier"

    def traffic(self, family: str, *, pods: int, chips: int, elems: int,
                elem_bytes: int = 4,
                populations: Optional[Sequence[int]] = None
                ) -> CollectiveTraffic:
        m = elems * elem_bytes
        if family == "allgather":
            return allgather_traffic(scheme=self._plans_scheme,
                                     num_nodes=pods, ranks_per_node=chips,
                                     bytes_per_rank=m)
        if family == "allgatherv":
            return allgatherv_traffic(scheme=self._plans_scheme,
                                      populations=populations,
                                      bytes_per_rank=m)
        if family == "broadcast":
            return broadcast_traffic(scheme=self._plans_scheme,
                                     num_nodes=pods, ranks_per_node=chips,
                                     msg_bytes=m)
        if family == "psum":
            return allreduce_traffic(scheme=self._plans_scheme,
                                     num_nodes=pods, ranks_per_node=chips,
                                     msg_bytes=m)
        if family == "reduce_scatter":
            return reduce_scatter_traffic(scheme=self._plans_scheme,
                                          num_nodes=pods,
                                          ranks_per_node=chips, msg_bytes=m)
        if family == "alltoall":
            return alltoall_traffic(scheme=self._alltoall_plans_scheme,
                                    num_nodes=pods, ranks_per_node=chips,
                                    bytes_per_pair=m)
        raise ValueError(f"no traffic model for family {family!r}")

    # All-to-all results are inherently rank-private, so the naive/hier
    # distinction there is wire-schedule only (flat vs node-aware).
    _alltoall_plans_scheme = "naive"

    # -- expected lowering (overridden per scheme) ---------------------------
    def links(self, family: str, *, pods: int, chips: int,
              fast_shape: tuple[int, ...], elems: int, elem_bytes: int = 4
              ) -> tuple[float, float]:
        """Expected (fast, slow) per-chip link bytes of this scheme's known
        collective sequence for one measured config."""
        raise NotImplementedError

    def result_node(self, family: str, *, pods: int, chips: int, elems: int,
                    elem_bytes: int = 4) -> int:
        """Expected resident result bytes on ONE node, from the known output
        layout: replicated schemes keep ranks_per_node copies, shared one."""
        R, m = pods * chips, elems * elem_bytes
        if family == "allgather":
            n = R * m
            return chips * n if self.result_class == "replicated" else n
        if family in ("broadcast", "psum"):
            return chips * m if self.result_class == "replicated" else m
        if family == "reduce_scatter":
            # replicated class = the flat scheme: each rank keeps its 1/R
            # slice, so a node holds c*m/R = m/num_nodes bytes; the shared
            # window keeps the node's full m (c shards of m/c).
            return m // pods if self.result_class == "replicated" else m
        if family == "alltoall":
            return chips * R * m          # rank-private in every scheme
        if family == "allgatherv":
            per_rank = m + CNT_BYTES      # padded block + its int32 count
            blocks = R if self.result_class == "replicated" else pods
            return chips * blocks * per_rank
        raise ValueError(f"unknown family {family!r}")

    def identities(self, family: str, *, traffic: CollectiveTraffic,
                   pods: int, chips: int, elems: int,
                   fast_total: float, slow_total: float, result_node: int,
                   elem_bytes: int = 4, fast_shape: tuple[int, ...] = (),
                   populations: Optional[Sequence[int]] = None
                   ) -> list[tuple[str, float, float, str]]:
        """Documented exact identities between parsed totals and the plans
        model, as (name, expected, measured, note) rows."""
        return []

    # -- tunables (autotuned by repro.bench) ---------------------------------
    def candidates(self, family: str, *, pods: int, chips: int, elems: int
                   ) -> tuple[dict, ...]:
        """Tunable-kwarg grid for one measured config.  The bench autotune
        compiles/times every candidate and records the best; an EMPTY grid
        means the scheme cannot run this (family, topology, size) cell at
        all (the cell is skipped-and-logged, not raised).  Default: one
        untunable candidate when the family tiles, else empty."""
        if not self.supports(family):
            return ()
        if elems % self.tiling(family, pods=pods, chips=chips):
            return ()
        return ({},)

    def tiling(self, family: str, *, pods: int, chips: int) -> int:
        """Divisor ``elems`` must tile by for this scheme to lower (e.g.
        scatter-based schemes shard the message over the fast tier).
        Overridden per scheme; 1 = any size fits."""
        return 1

    def bucketable(self, family: str) -> bool:
        """True when packing several same-axes/same-dtype operands into one
        flat buffer and running this scheme once over the concatenation is
        elementwise-equivalent to running it once per operand — the
        contract the step-graph optimizer's bucketing pass rewrites under.
        Holds for any replicated elementwise reduction (``psum``: the sum
        of a concatenation IS the concatenation of the sums); a shared
        result is a ``SharedWindow`` over the *packed* layout, which the
        unpack codec cannot slice back per-leaf."""
        return family == "psum" and self.result_class == "replicated" \
            and self.supports(family)

    # -- model-predicted latency (cold-start for scheme="auto") --------------
    def predicted_time(self, family: str, *, pods: int, chips: int,
                       elems: int, elem_bytes: int = 4,
                       populations: Optional[Sequence[int]] = None
                       ) -> Optional[tuple[float, dict]]:
        """Closed-form latency prediction for one config, plus the tunable
        kwargs the prediction assumes — the cold-start input of
        ``repro.comm.tuning`` when no measured table entry covers a cell.

        Returns ``None`` when the scheme cannot run the cell at all (empty
        ``candidates`` grid).  The base implementation is the serial
        ``core.plans.collective_time_model`` of the scheme's own traffic
        closed form; schemes with tunables override it (``pipelined`` picks
        ``best_chunk_count`` and prices the overlap)."""
        if not self.candidates(family, pods=pods, chips=chips, elems=elems):
            return None
        if family == "allgatherv" and populations is None:
            populations = (chips,) * pods    # regular cold-start assumption
        tr = self.traffic(family, pods=pods, chips=chips, elems=elems,
                          elem_bytes=elem_bytes, populations=populations)
        return collective_time_model(tr, num_nodes=pods,
                                     ranks_per_node=chips), {}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, CollectiveScheme] = {}


def register_scheme(scheme: CollectiveScheme) -> CollectiveScheme:
    if not scheme.name:
        raise ValueError("scheme needs a name")
    _REGISTRY[scheme.name] = scheme
    return scheme


def get_scheme(name: str) -> CollectiveScheme:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown collective scheme {name!r}; registered: "
                       f"{sorted(_REGISTRY)}") from None


def scheme_names() -> tuple[str, ...]:
    return tuple(_REGISTRY)


def schemes_for(family: str) -> tuple[CollectiveScheme, ...]:
    return tuple(s for s in _REGISTRY.values() if s.supports(family))


# ---------------------------------------------------------------------------
# The three schemes of the paper's comparison
# ---------------------------------------------------------------------------

class NaiveScheme(CollectiveScheme):
    """Pure-MPI analogue: one flat phase, private full result per rank."""

    name = "naive"
    result_class = "replicated"
    ops = MappingProxyType({
        "allgather": lambda x, *, fast, slow, axis=0, **_:
            p.naive_all_gather(x, fast_axis=fast, slow_axis=slow, axis=axis),
        "broadcast": lambda x, *, fast, slow, root=0, axis=0, **_:
            p.naive_broadcast(x, root=root, fast_axis=fast, slow_axis=slow),
        "psum": lambda x, *, fast, slow, axis=0, **_:
            p.naive_psum(x, fast_axis=fast, slow_axis=slow),
        "reduce_scatter": lambda x, *, fast, slow, axis=0, **_:
            p.naive_reduce_scatter(x, fast_axis=fast, slow_axis=slow,
                                   axis=axis),
        "alltoall": lambda x, *, fast, slow, axis=0, **_:
            p.naive_all_to_all(x, fast_axis=fast, slow_axis=slow, axis=axis),
        "allgatherv": lambda x, valid, *, fast, slow, axis=0, **_:
            (p.naive_all_gather(x, fast_axis=fast, slow_axis=slow, axis=axis),
             p.naive_all_gather(valid, fast_axis=fast, slow_axis=slow,
                                axis=axis)),
    })

    def tiling(self, family, *, pods, chips):
        return pods * chips if family == "reduce_scatter" else 1

    def links(self, family, *, pods, chips, fast_shape, elems, elem_bytes=4):
        Pn, c = pods, chips
        R, m = Pn * c, elems * elem_bytes
        fast = slow = 0.0
        if family == "allgather":
            link = _ag(R * m, R) if Pn > 1 else _ag(R * m, c)
        elif family in ("broadcast", "psum"):
            link = _ar(m, R) if Pn > 1 else _ar(m, c)
        elif family == "reduce_scatter":
            link = _rs(m / R, R) if Pn > 1 else _rs(m / c, c)
        elif family == "alltoall":
            link = _a2a(R * m, R) if Pn > 1 else _a2a(R * m, c)
        elif family == "allgatherv":
            link = (_ag(R * m, R) + _ag(R * CNT_BYTES, R)) if Pn > 1 \
                else (_ag(R * m, c) + _ag(R * CNT_BYTES, c))
        else:
            raise ValueError(f"unknown family {family!r}")
        if Pn > 1:
            slow = link                  # flat group spans pods
        else:
            fast = link
        return fast, slow

    def identities(self, family, *, traffic, pods, chips, elems,
                   fast_total, slow_total, result_node, elem_bytes=4,
                   fast_shape=(), populations=None):
        tr = traffic
        out = []
        if family == "reduce_scatter":
            out.append(("model/total-bytes", tr.slow_bytes + tr.fast_bytes,
                        fast_total + slow_total,
                        "flat reduce-scatter ring total == model ring "
                        "bytes m*(R-1)"))
            out.append(("model/result-node", tr.result_bytes_per_node,
                        result_node,
                        "flat 1/R slices: a node retains msg/num_nodes "
                        "bytes"))
        if family == "allgather":
            out.append(("model/result-node", tr.result_bytes_per_node,
                        result_node,
                        "resident result bytes == model "
                        "result_bytes_per_node"))
        elif family == "broadcast":
            out.append(("model/total-bytes",
                        2 * (tr.slow_bytes + tr.fast_bytes),
                        fast_total + slow_total,
                        "psum-emulated bcast costs exactly 2x the model's "
                        "one-way bytes"))
            out.append(("model/result-node", tr.result_bytes_per_node,
                        result_node, "resident result bytes == model "
                        "result_bytes_per_node"))
        elif family == "psum":
            out.append(("model/total-bytes", tr.slow_bytes + tr.fast_bytes,
                        fast_total + slow_total,
                        "flat ring allreduce total == model ring bytes"))
            out.append(("model/result-node", tr.result_bytes_per_node,
                        result_node, "resident result bytes == model "
                        "result_bytes_per_node"))
        elif family == "alltoall":
            out.append(("model/total-bytes", tr.slow_bytes + tr.fast_bytes,
                        fast_total + slow_total,
                        "flat all-to-all wire total == model pairwise "
                        "bytes m*R*(R-1)"))
            out.append(("model/result-node", tr.result_bytes_per_node,
                        result_node,
                        "rank-private all-to-all results: ranks_per_node x "
                        "R*m resident per node"))
        return out


class HierScheme(CollectiveScheme):
    """Two-phase (intra-pod, then bridge) schedule; result still fully
    replicated — isolates the latency effect of the hierarchical schedule."""

    name = "hier"
    result_class = "replicated"
    _alltoall_plans_scheme = "hier"     # node-aware wire schedule
    ops = MappingProxyType({
        "allgather": lambda x, *, fast, slow, axis=0, **_:
            p.hier_all_gather(x, fast_axis=fast, slow_axis=slow, axis=axis),
        "broadcast": lambda x, *, fast, slow, root=0, axis=0, **_:
            p.hier_broadcast(x, root=root, fast_axis=fast, slow_axis=slow),
        "psum": lambda x, *, fast, slow, axis=0, **_:
            p.hier_psum(x, fast_axis=fast, slow_axis=slow, axis=axis),
        "alltoall": lambda x, *, fast, slow, axis=0, **_:
            p.hier_all_to_all(x, fast_axis=fast, slow_axis=slow, axis=axis),
    })

    def tiling(self, family, *, pods, chips):
        return chips if family == "psum" else 1   # intra-pod psum_scatter

    def links(self, family, *, pods, chips, fast_shape, elems, elem_bytes=4):
        Pn, c = pods, chips
        R, m = Pn * c, elems * elem_bytes
        if family == "allgather":
            return _ag(c * m, c), _ag(R * m, Pn)
        if family == "broadcast":
            return _ar(m, c), _ar(m, Pn)
        if family == "psum":
            return _rs(m / c, c) + _ag(m, c), _ar(m / c, Pn)
        if family == "alltoall":
            buf = R * m
            fast = buf * sum((n - 1) / n for n in fast_shape if n > 1)
            return fast, _a2a(buf, Pn)
        raise ValueError(f"unknown family {family!r}")

    def identities(self, family, *, traffic, pods, chips, elems,
                   fast_total, slow_total, result_node, elem_bytes=4,
                   fast_shape=(), populations=None):
        Pn, c, m = pods, chips, elems * elem_bytes
        tr = traffic
        out = []
        if family == "allgather" and Pn > 1:
            shared_tr = allgather_traffic(scheme="hier", num_nodes=Pn,
                                          ranks_per_node=c, bytes_per_rank=m)
            out.append(("model/bridge-bytes", c * shared_tr.slow_bytes,
                        slow_total,
                        "full replication pays C1 on the wire: "
                        "ranks_per_node x the shared bridge bytes"))
        elif family == "broadcast":
            # every chip of a pod participates in the emulated bridge psum:
            # full replication pays C1 on the wire (x ranks_per_node).
            out.append(("model/bridge-bytes", 2 * c * tr.slow_bytes,
                        slow_total,
                        "replicated bridge == 2 x ranks_per_node x model "
                        "slow_bytes (C1 on the wire)"))
            out.append(("model/fast-bytes", 2 * tr.fast_bytes, fast_total,
                        "intra-pod psum == 2x the model's "
                        "leader-to-children copy bytes"))
        elif family == "psum":
            trh = allreduce_traffic(scheme="hier", num_nodes=Pn,
                                    ranks_per_node=c, msg_bytes=m)
            out.append(("model/bridge-bytes", Pn * trh.slow_bytes,
                        slow_total,
                        "c parallel shard rings sum to num_nodes x the "
                        "model's per-node bridge bytes"))
            out.append(("model/fast-bytes", c * trh.fast_bytes, fast_total,
                        "intra-node RS+AG == ranks_per_node x the model's "
                        "per-node cycle"))
        elif family == "alltoall":
            if Pn > 1:
                out.append(("model/bridge-bytes", tr.slow_bytes, slow_total,
                            "node-aware bridge == model slow_bytes: node "
                            "superchunks cross pods exactly once"))
            naive_tr = alltoall_traffic(scheme="naive", num_nodes=Pn,
                                        ranks_per_node=c, bytes_per_pair=m)
            out.append(("model/result-node", tr.result_bytes_per_node,
                        result_node,
                        "rank-private all-to-all results: same resident "
                        "bytes as the flat scheme"))
            if naive_tr.fast_bytes and len(fast_shape) == 1:
                # single-fast-axis identity; a factored fast tier (tuple
                # axes) moves the buffer once per sub-axis instead.
                out.append(("model/fast-ratio",
                            Pn * naive_tr.fast_bytes, fast_total,
                            "intra-node redistribution == num_nodes x the "
                            "flat scheme's intra-node pair bytes "
                            "(single-axis fast tier only)"))
        return out


class SharedScheme(CollectiveScheme):
    """The paper's memory-optimal scheme: ONE result copy per node, sharded
    over the fast tier (the MPI-3 shared window); readers use
    ``SharedWindow.read``."""

    name = "shared"
    result_class = "shared"
    ops = MappingProxyType({
        "allgather": lambda x, *, fast, slow, axis=0, **_:
            p.shared_all_gather(x, fast_axis=fast, slow_axis=slow, axis=axis),
        "broadcast": lambda x, *, fast, slow, root=0, axis=0, **_:
            p.shared_broadcast(x, root=root, fast_axis=fast, slow_axis=slow,
                               axis=axis),
        "psum": lambda x, *, fast, slow, axis=0, **_:
            p.shared_psum_scatter(x, fast_axis=fast, slow_axis=slow,
                                  axis=axis),
        "reduce_scatter": lambda x, *, fast, slow, axis=0, **_:
            p.shared_psum_scatter(x, fast_axis=fast, slow_axis=slow,
                                  axis=axis),
        "allgatherv": lambda x, valid, *, fast, slow, axis=0, **_:
            p.shared_all_gather_v(x, valid, slow_axis=slow, axis=axis),
    })

    def tiling(self, family, *, pods, chips):
        if family in ("broadcast", "psum", "reduce_scatter"):
            return chips                  # window shards: 1/c of the message
        return 1

    def links(self, family, *, pods, chips, fast_shape, elems, elem_bytes=4):
        Pn, c = pods, chips
        m = elems * elem_bytes
        if family == "allgather":
            return 0.0, _ag(Pn * m, Pn)
        if family == "broadcast":
            return _rs(m / c, c), _ar(m / c, Pn)
        if family in ("psum", "reduce_scatter"):
            return _rs(m / c, c), _ar(m / c, Pn)
        if family == "allgatherv":
            return 0.0, _ag(Pn * m, Pn) + _ag(Pn * CNT_BYTES, Pn)
        raise ValueError(f"unknown family {family!r}")

    def identities(self, family, *, traffic, pods, chips, elems,
                   fast_total, slow_total, result_node, elem_bytes=4,
                   fast_shape=(), populations=None):
        Pn, c = pods, chips
        tr = traffic
        out = []
        if family == "allgather":
            out.append(("model/bridge-bytes", tr.slow_bytes, slow_total,
                        "bridge wire bytes == model slow_bytes (node "
                        "regions cross once)"))
            out.append(("model/fast-bytes", tr.fast_bytes, fast_total,
                        "zero intra-node copy bytes — paper C2"))
            out.append(("model/result-node", tr.result_bytes_per_node,
                        result_node, "resident result bytes == model "
                        "result_bytes_per_node"))
        elif family == "broadcast":
            out.append(("model/bridge-bytes", 2 * tr.slow_bytes, slow_total,
                        "shard bridge == 2x model slow_bytes (one shared "
                        "copy crosses once, psum-doubled)"))
            out.append(("model/result-node", tr.result_bytes_per_node,
                        result_node, "resident result bytes == model "
                        "result_bytes_per_node"))
        elif family == "psum":
            out.append(("model/bridge-bytes", Pn * tr.slow_bytes, slow_total,
                        "c parallel shard rings sum to num_nodes x the "
                        "model's per-node bridge bytes"))
            out.append(("model/fast-bytes", (c / 2) * tr.fast_bytes,
                        fast_total,
                        "intra-node RS vs the model's per-node RS+AG cycle "
                        "(shared skips the AG half)"))
            out.append(("model/result-node", tr.result_bytes_per_node,
                        result_node, "resident result bytes == model "
                        "result_bytes_per_node"))
        elif family == "allgatherv" and Pn > 1:
            R = Pn * c
            S = sum(populations)          # present ranks
            # subtract the (tiny, closed-form) int32 counts exchange from
            # the MEASURED bridge bytes; what remains is the padded data
            # exchange, which scaled by the compact fraction S/R must hit
            # the model's GatherPlan-compact bridge bytes.
            counts_slow_total = R * CNT_BYTES * (Pn - 1)
            data_slow_total = slow_total - counts_slow_total
            out.append(("model/bridge-bytes", tr.slow_bytes,
                        data_slow_total * S / R,
                        "measured padded bridge bytes (minus the counts "
                        "exchange) x compact fraction == model compact "
                        "bridge bytes (GatherPlan)"))
        return out


class PipelinedScheme(HierScheme):
    """Chunked two-phase schedule (``repro.comm.pipeline``): the message is
    split into ``n_chunks`` segments and the bridge stage of segment *k*
    overlaps the on-node stage of segment *k+1* through double-buffered
    window epochs.

    Results are bit-identical to ``hier`` (``reduce_scatter``: the flat
    ``naive`` slices, numerically equivalent — the two-phase sum
    reassociates the flat ring's adds) and the total link bytes are
    EXACTLY the unchunked
    closed forms — chunking is linear in the message, so every ``links``/
    ``identities`` expectation is inherited unchanged and must hold for
    every ``n_chunks``.  What changes is latency:
    ``core.plans.pipelined_time_model`` adds the overlap term, and the
    bench autotunes ``n_chunks`` per (topology, size) cell.
    """

    name = "pipelined"
    result_class = "replicated"
    n_chunk_candidates = (1, 2, 4, 8)
    ops = MappingProxyType({
        "allgather": lambda x, *, fast, slow, axis=0, n_chunks=2, **_:
            pipe.pipelined_all_gather(x, fast_axis=fast, slow_axis=slow,
                                      axis=axis, n_chunks=n_chunks),
        "broadcast": lambda x, *, fast, slow, root=0, axis=0, n_chunks=2,
                            **_:
            pipe.pipelined_broadcast(x, root=root, fast_axis=fast,
                                     slow_axis=slow, axis=axis,
                                     n_chunks=n_chunks),
        "psum": lambda x, *, fast, slow, axis=0, n_chunks=2, **_:
            pipe.pipelined_psum(x, fast_axis=fast, slow_axis=slow,
                                axis=axis, n_chunks=n_chunks),
        "reduce_scatter": lambda x, *, fast, slow, axis=0, n_chunks=2, **_:
            pipe.pipelined_reduce_scatter(x, fast_axis=fast, slow_axis=slow,
                                          axis=axis, n_chunks=n_chunks),
    })

    def tiling(self, family, *, pods, chips):
        if family == "psum":
            return chips                  # per-chunk intra-pod psum_scatter
        if family == "reduce_scatter":
            return pods * chips           # per-chunk flat 1/R slices
        return 1

    def candidates(self, family, *, pods, chips, elems):
        if not self.supports(family):
            return ()
        need = self.tiling(family, pods=pods, chips=chips)
        return tuple({"n_chunks": nc} for nc in self.n_chunk_candidates
                     if elems % (nc * need) == 0)

    def links(self, family, *, pods, chips, fast_shape, elems, elem_bytes=4):
        if family == "reduce_scatter":
            # two-phase: bridge RS over pods, then intra-pod RS of the pod
            # slice (linear in the chunk size, so nc-invariant).
            Pn, c = pods, chips
            m = elems * elem_bytes
            if Pn > 1:
                return _rs(m / (Pn * c), c), _rs(m / Pn, Pn)
            return _rs(m / c, c), 0.0
        return super().links(family, pods=pods, chips=chips,
                             fast_shape=fast_shape, elems=elems,
                             elem_bytes=elem_bytes)

    def predicted_time(self, family, *, pods, chips, elems, elem_bytes=4,
                       populations=None):
        """Overlap-aware prediction: ``core.plans.best_chunk_count`` over
        the cell's valid ``n_chunks`` candidates, priced by
        ``pipelined_time_model``.  The nonzero per-chunk alpha makes the
        one-chunk pipeline strictly pricier than the plain ``hier``
        schedule, so the model never prefers chunking that buys nothing."""
        cands = self.candidates(family, pods=pods, chips=chips, elems=elems)
        if not cands:
            return None
        tr = self.traffic(family, pods=pods, chips=chips, elems=elems,
                          elem_bytes=elem_bytes, populations=populations)
        ncs = tuple(c["n_chunks"] for c in cands)
        alpha = 1e-6
        nc = best_chunk_count(tr, num_nodes=pods, ranks_per_node=chips,
                              candidates=ncs, alpha=alpha)
        t = pipelined_time_model(tr, n_chunks=nc, num_nodes=pods,
                                 ranks_per_node=chips, alpha=alpha)
        return t, {"n_chunks": nc}


NAIVE = register_scheme(NaiveScheme())
HIER = register_scheme(HierScheme())
SHARED = register_scheme(SharedScheme())
PIPELINED = register_scheme(PipelinedScheme())
