"""Collective scheme registry: every scheme is ONE self-describing entry.

A ``CollectiveScheme`` bundles everything the rest of the repo needs to know
about one collective strategy:

* ``ops``          — the shard_map-body implementation per family
                     (``repro.comm.primitives`` functions behind a uniform
                     keyword signature);
* ``result_class`` — ``"replicated"`` (a private full result per rank — the
                     pure-MPI analogue and the two-phase hier schedule) or
                     ``"shared"`` (ONE copy per node, sharded over the fast
                     tier — the paper's MPI-3 shared window);
* ``traffic``      — the closed-form ``core.plans`` traffic model for a
                     measured config;
* ``links``        — expected per-chip link bytes of the scheme's known
                     lowering (ring model, matching
                     ``analysis.roofline.parse_collectives`` exactly);
* ``result_node``  — expected resident result bytes on one node;
* ``identities``   — documented exact identities between parsed wire /
                     resident bytes and the traffic model.

``repro.bench.suites`` sweeps ``schemes_for(family)``, ``repro.bench.
validate`` pulls every expectation from here, and ``Communicator`` methods
dispatch through ``get_scheme``: registering a new scheme is the ONLY step
needed to have it swept, cross-checked and callable — no string matching of
scheme names anywhere else.
"""

from __future__ import annotations

from types import MappingProxyType
from typing import Callable, Mapping, Optional, Sequence

import numpy as np

from repro.comm import pipeline as pipe
from repro.comm import primitives as p
from repro.comm import quantize as qz
from repro.core.plans import (CollectiveTraffic, allgather_traffic,
                              allgatherv_traffic, allreduce_traffic,
                              alltoall_traffic, best_chunk_count,
                              broadcast_traffic, collective_time_model,
                              pipelined_time_model, reduce_scatter_traffic)

CNT_BYTES = 4  # int32 valid-count payload of the irregular allgatherv


# ---------------------------------------------------------------------------
# Ring-model per-chip link costs (parse_collectives' accounting exactly).
# ---------------------------------------------------------------------------

def _ag(out_bytes: float, n: int) -> float:
    return out_bytes * (n - 1) / n if n > 1 else 0.0


def _rs(out_bytes: float, n: int) -> float:
    return out_bytes * (n - 1) if n > 1 else 0.0


def _ar(msg_bytes: float, n: int) -> float:
    return 2.0 * msg_bytes * (n - 1) / n if n > 1 else 0.0


def _a2a(buf_bytes: float, n: int) -> float:
    return buf_bytes * (n - 1) / n if n > 1 else 0.0


class CollectiveScheme:
    """One registered collective strategy.  Subclass + ``register_scheme``
    is the complete recipe for adding a scheme: shadow ``ops`` with the
    family table (it is a read-only mapping on purpose — mutating the
    inherited one would leak bodies into every other scheme)."""

    name: str = ""
    result_class: str = "replicated"        # "replicated" | "shared"
    precision: str = "exact"                # "exact" | "lossy"
    ops: Mapping[str, Callable] = MappingProxyType({})

    # -- dispatch ------------------------------------------------------------
    def supports(self, family: str) -> bool:
        return family in self.ops

    def op(self, family: str) -> Callable:
        if family not in self.ops:
            have = [s.name for s in schemes_for(family)]
            raise NotImplementedError(
                f"scheme {self.name!r} does not implement {family!r}; "
                f"schemes supporting it: {have or 'none registered'}")
        return self.ops[family]

    # -- plans.py traffic model ----------------------------------------------
    @property
    def _plans_scheme(self) -> str:
        # plans.py spells the two result classes "naive" (replicated) and
        # "hier" (one shared copy per node).
        return "naive" if self.result_class == "replicated" else "hier"

    def traffic(self, family: str, *, pods: int, chips: int, elems: int,
                elem_bytes: int = 4,
                populations: Optional[Sequence[int]] = None
                ) -> CollectiveTraffic:
        m = elems * elem_bytes
        if family == "allgather":
            return allgather_traffic(scheme=self._plans_scheme,
                                     num_nodes=pods, ranks_per_node=chips,
                                     bytes_per_rank=m)
        if family == "allgatherv":
            return allgatherv_traffic(scheme=self._plans_scheme,
                                      populations=populations,
                                      bytes_per_rank=m)
        if family == "broadcast":
            return broadcast_traffic(scheme=self._plans_scheme,
                                     num_nodes=pods, ranks_per_node=chips,
                                     msg_bytes=m)
        if family == "psum":
            return allreduce_traffic(scheme=self._plans_scheme,
                                     num_nodes=pods, ranks_per_node=chips,
                                     msg_bytes=m)
        if family == "reduce_scatter":
            return reduce_scatter_traffic(scheme=self._plans_scheme,
                                          num_nodes=pods,
                                          ranks_per_node=chips, msg_bytes=m)
        if family == "alltoall":
            return alltoall_traffic(scheme=self._alltoall_plans_scheme,
                                    num_nodes=pods, ranks_per_node=chips,
                                    bytes_per_pair=m)
        raise ValueError(f"no traffic model for family {family!r}")

    # All-to-all results are inherently rank-private, so the naive/hier
    # distinction there is wire-schedule only (flat vs node-aware).
    _alltoall_plans_scheme = "naive"

    # -- expected lowering (overridden per scheme) ---------------------------
    def links(self, family: str, *, pods: int, chips: int,
              fast_shape: tuple[int, ...], elems: int, elem_bytes: int = 4,
              opts: Optional[dict] = None, dtype: str = "float32"
              ) -> tuple[float, float]:
        """Expected (fast, slow) per-chip link bytes of this scheme's known
        collective sequence for one measured config.  ``opts`` is the
        tunable-kwarg dict of the measured candidate — quantized schemes
        need it because the block size changes the scales-exchange bytes;
        exact schemes ignore it.  ``dtype`` is the LOGICAL payload dtype:
        ``elem_bytes`` already prices the compiled wire width (f32 on the
        CPU backend even for bf16 floats), but schemes that ship a bf16
        result as bitcast u16 lower natively at 2 bytes and need to know
        the payload is really bf16."""
        raise NotImplementedError

    def result_node(self, family: str, *, pods: int, chips: int, elems: int,
                    elem_bytes: int = 4) -> int:
        """Expected resident result bytes on ONE node, from the known output
        layout: replicated schemes keep ranks_per_node copies, shared one."""
        R, m = pods * chips, elems * elem_bytes
        if family == "allgather":
            n = R * m
            return chips * n if self.result_class == "replicated" else n
        if family in ("broadcast", "psum"):
            return chips * m if self.result_class == "replicated" else m
        if family == "reduce_scatter":
            # replicated class = the flat scheme: each rank keeps its 1/R
            # slice, so a node holds c*m/R = m/num_nodes bytes; the shared
            # window keeps the node's full m (c shards of m/c).
            return m // pods if self.result_class == "replicated" else m
        if family == "alltoall":
            return chips * R * m          # rank-private in every scheme
        if family == "allgatherv":
            per_rank = m + CNT_BYTES      # padded block + its int32 count
            blocks = R if self.result_class == "replicated" else pods
            return chips * blocks * per_rank
        raise ValueError(f"unknown family {family!r}")

    def identities(self, family: str, *, traffic: CollectiveTraffic,
                   pods: int, chips: int, elems: int,
                   fast_total: float, slow_total: float, result_node: int,
                   elem_bytes: int = 4, fast_shape: tuple[int, ...] = (),
                   populations: Optional[Sequence[int]] = None
                   ) -> list[tuple[str, float, float, str]]:
        """Documented exact identities between parsed totals and the plans
        model, as (name, expected, measured, note) rows."""
        return []

    # -- tunables (autotuned by repro.bench) ---------------------------------
    def candidates(self, family: str, *, pods: int, chips: int, elems: int
                   ) -> tuple[dict, ...]:
        """Tunable-kwarg grid for one measured config.  The bench autotune
        compiles/times every candidate and records the best; an EMPTY grid
        means the scheme cannot run this (family, topology, size) cell at
        all (the cell is skipped-and-logged, not raised).  Default: one
        untunable candidate when the family tiles, else empty."""
        if not self.supports(family):
            return ()
        if elems % self.tiling(family, pods=pods, chips=chips):
            return ()
        return ({},)

    def tiling(self, family: str, *, pods: int, chips: int) -> int:
        """Divisor ``elems`` must tile by for this scheme to lower (e.g.
        scatter-based schemes shard the message over the fast tier).
        Overridden per scheme; 1 = any size fits."""
        return 1

    def bucketable(self, family: str) -> bool:
        """True when packing several same-axes/same-dtype operands into one
        flat buffer and running this scheme once over the concatenation is
        elementwise-equivalent to running it once per operand — the
        contract the step-graph optimizer's bucketing pass rewrites under.
        Holds for any replicated elementwise reduction (``psum``: the sum
        of a concatenation IS the concatenation of the sums); a shared
        result is a ``SharedWindow`` over the *packed* layout, which the
        unpack codec cannot slice back per-leaf.  Lossy schemes are never
        bucketable: packing moves block boundaries, so the bucketed error
        differs from the per-leaf error the scheme's bound was checked
        under."""
        return family == "psum" and self.result_class == "replicated" \
            and self.supports(family) and self.precision == "exact"

    # -- error model (lossy schemes only) ------------------------------------
    def error_bound_rel(self, family: str, *, pods: int) -> float:
        """Worst-case quantization error relative to the payload's
        per-block amax — the quantity a per-call ``tol=`` constraint is
        compared against during auto-resolution.  Exact schemes: 0.0."""
        return 0.0

    def error_check(self, family: str, *, inputs, output, pods: int,
                    chips: int, elems: int, dtype: str = "float32",
                    opts: Optional[dict] = None
                    ) -> Optional[tuple[float, float]]:
        """Host-side error model for one inspected bench run: given the
        case's global input arrays and the measured global output, return
        ``(bound, measured_abs_err)`` — the validator asserts
        ``measured <= bound``.  ``None`` (the default) means exact scheme
        or unmodeled family; lossy schemes MUST model every family they
        register."""
        return None

    # -- model-predicted latency (cold-start for scheme="auto") --------------
    def predicted_time(self, family: str, *, pods: int, chips: int,
                       elems: int, elem_bytes: int = 4,
                       populations: Optional[Sequence[int]] = None
                       ) -> Optional[tuple[float, dict]]:
        """Closed-form latency prediction for one config, plus the tunable
        kwargs the prediction assumes — the cold-start input of
        ``repro.comm.tuning`` when no measured table entry covers a cell.

        Returns ``None`` when the scheme cannot run the cell at all (empty
        ``candidates`` grid).  The base implementation is the serial
        ``core.plans.collective_time_model`` of the scheme's own traffic
        closed form; schemes with tunables override it (``pipelined`` picks
        ``best_chunk_count`` and prices the overlap)."""
        if not self.candidates(family, pods=pods, chips=chips, elems=elems):
            return None
        if family == "allgatherv" and populations is None:
            populations = (chips,) * pods    # regular cold-start assumption
        tr = self.traffic(family, pods=pods, chips=chips, elems=elems,
                          elem_bytes=elem_bytes, populations=populations)
        return collective_time_model(tr, num_nodes=pods,
                                     ranks_per_node=chips), {}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, CollectiveScheme] = {}


def register_scheme(scheme: CollectiveScheme) -> CollectiveScheme:
    if not scheme.name:
        raise ValueError("scheme needs a name")
    _REGISTRY[scheme.name] = scheme
    return scheme


def get_scheme(name: str) -> CollectiveScheme:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown collective scheme {name!r}; registered: "
                       f"{sorted(_REGISTRY)}") from None


def scheme_names() -> tuple[str, ...]:
    return tuple(_REGISTRY)


def schemes_for(family: str) -> tuple[CollectiveScheme, ...]:
    return tuple(s for s in _REGISTRY.values() if s.supports(family))


# ---------------------------------------------------------------------------
# The three schemes of the paper's comparison
# ---------------------------------------------------------------------------

class NaiveScheme(CollectiveScheme):
    """Pure-MPI analogue: one flat phase, private full result per rank."""

    name = "naive"
    result_class = "replicated"
    ops = MappingProxyType({
        "allgather": lambda x, *, fast, slow, axis=0, **_:
            p.naive_all_gather(x, fast_axis=fast, slow_axis=slow, axis=axis),
        "broadcast": lambda x, *, fast, slow, root=0, axis=0, **_:
            p.naive_broadcast(x, root=root, fast_axis=fast, slow_axis=slow),
        "psum": lambda x, *, fast, slow, axis=0, **_:
            p.naive_psum(x, fast_axis=fast, slow_axis=slow),
        "reduce_scatter": lambda x, *, fast, slow, axis=0, **_:
            p.naive_reduce_scatter(x, fast_axis=fast, slow_axis=slow,
                                   axis=axis),
        "alltoall": lambda x, *, fast, slow, axis=0, **_:
            p.naive_all_to_all(x, fast_axis=fast, slow_axis=slow, axis=axis),
        "allgatherv": lambda x, valid, *, fast, slow, axis=0, **_:
            (p.naive_all_gather(x, fast_axis=fast, slow_axis=slow, axis=axis),
             p.naive_all_gather(valid, fast_axis=fast, slow_axis=slow,
                                axis=axis)),
    })

    def tiling(self, family, *, pods, chips):
        return pods * chips if family == "reduce_scatter" else 1

    def links(self, family, *, pods, chips, fast_shape, elems,
              elem_bytes=4, opts=None, dtype="float32"):
        Pn, c = pods, chips
        R, m = Pn * c, elems * elem_bytes
        fast = slow = 0.0
        if family == "allgather":
            link = _ag(R * m, R) if Pn > 1 else _ag(R * m, c)
        elif family in ("broadcast", "psum"):
            link = _ar(m, R) if Pn > 1 else _ar(m, c)
        elif family == "reduce_scatter":
            link = _rs(m / R, R) if Pn > 1 else _rs(m / c, c)
        elif family == "alltoall":
            link = _a2a(R * m, R) if Pn > 1 else _a2a(R * m, c)
        elif family == "allgatherv":
            link = (_ag(R * m, R) + _ag(R * CNT_BYTES, R)) if Pn > 1 \
                else (_ag(R * m, c) + _ag(R * CNT_BYTES, c))
        else:
            raise ValueError(f"unknown family {family!r}")
        if Pn > 1:
            slow = link                  # flat group spans pods
        else:
            fast = link
        return fast, slow

    def identities(self, family, *, traffic, pods, chips, elems,
                   fast_total, slow_total, result_node, elem_bytes=4,
                   fast_shape=(), populations=None):
        tr = traffic
        out = []
        if family == "reduce_scatter":
            out.append(("model/total-bytes", tr.slow_bytes + tr.fast_bytes,
                        fast_total + slow_total,
                        "flat reduce-scatter ring total == model ring "
                        "bytes m*(R-1)"))
            out.append(("model/result-node", tr.result_bytes_per_node,
                        result_node,
                        "flat 1/R slices: a node retains msg/num_nodes "
                        "bytes"))
        if family == "allgather":
            out.append(("model/result-node", tr.result_bytes_per_node,
                        result_node,
                        "resident result bytes == model "
                        "result_bytes_per_node"))
        elif family == "broadcast":
            out.append(("model/total-bytes",
                        2 * (tr.slow_bytes + tr.fast_bytes),
                        fast_total + slow_total,
                        "psum-emulated bcast costs exactly 2x the model's "
                        "one-way bytes"))
            out.append(("model/result-node", tr.result_bytes_per_node,
                        result_node, "resident result bytes == model "
                        "result_bytes_per_node"))
        elif family == "psum":
            out.append(("model/total-bytes", tr.slow_bytes + tr.fast_bytes,
                        fast_total + slow_total,
                        "flat ring allreduce total == model ring bytes"))
            out.append(("model/result-node", tr.result_bytes_per_node,
                        result_node, "resident result bytes == model "
                        "result_bytes_per_node"))
        elif family == "alltoall":
            out.append(("model/total-bytes", tr.slow_bytes + tr.fast_bytes,
                        fast_total + slow_total,
                        "flat all-to-all wire total == model pairwise "
                        "bytes m*R*(R-1)"))
            out.append(("model/result-node", tr.result_bytes_per_node,
                        result_node,
                        "rank-private all-to-all results: ranks_per_node x "
                        "R*m resident per node"))
        return out


class HierScheme(CollectiveScheme):
    """Two-phase (intra-pod, then bridge) schedule; result still fully
    replicated — isolates the latency effect of the hierarchical schedule."""

    name = "hier"
    result_class = "replicated"
    _alltoall_plans_scheme = "hier"     # node-aware wire schedule
    ops = MappingProxyType({
        "allgather": lambda x, *, fast, slow, axis=0, **_:
            p.hier_all_gather(x, fast_axis=fast, slow_axis=slow, axis=axis),
        "broadcast": lambda x, *, fast, slow, root=0, axis=0, **_:
            p.hier_broadcast(x, root=root, fast_axis=fast, slow_axis=slow),
        "psum": lambda x, *, fast, slow, axis=0, **_:
            p.hier_psum(x, fast_axis=fast, slow_axis=slow, axis=axis),
        "alltoall": lambda x, *, fast, slow, axis=0, **_:
            p.hier_all_to_all(x, fast_axis=fast, slow_axis=slow, axis=axis),
    })

    def tiling(self, family, *, pods, chips):
        return chips if family == "psum" else 1   # intra-pod psum_scatter

    def links(self, family, *, pods, chips, fast_shape, elems,
              elem_bytes=4, opts=None, dtype="float32"):
        Pn, c = pods, chips
        R, m = Pn * c, elems * elem_bytes
        if family == "allgather":
            return _ag(c * m, c), _ag(R * m, Pn)
        if family == "broadcast":
            return _ar(m, c), _ar(m, Pn)
        if family == "psum":
            return _rs(m / c, c) + _ag(m, c), _ar(m / c, Pn)
        if family == "alltoall":
            buf = R * m
            fast = buf * sum((n - 1) / n for n in fast_shape if n > 1)
            return fast, _a2a(buf, Pn)
        raise ValueError(f"unknown family {family!r}")

    def identities(self, family, *, traffic, pods, chips, elems,
                   fast_total, slow_total, result_node, elem_bytes=4,
                   fast_shape=(), populations=None):
        Pn, c, m = pods, chips, elems * elem_bytes
        tr = traffic
        out = []
        if family == "allgather" and Pn > 1:
            shared_tr = allgather_traffic(scheme="hier", num_nodes=Pn,
                                          ranks_per_node=c, bytes_per_rank=m)
            out.append(("model/bridge-bytes", c * shared_tr.slow_bytes,
                        slow_total,
                        "full replication pays C1 on the wire: "
                        "ranks_per_node x the shared bridge bytes"))
        elif family == "broadcast":
            # every chip of a pod participates in the emulated bridge psum:
            # full replication pays C1 on the wire (x ranks_per_node).
            out.append(("model/bridge-bytes", 2 * c * tr.slow_bytes,
                        slow_total,
                        "replicated bridge == 2 x ranks_per_node x model "
                        "slow_bytes (C1 on the wire)"))
            out.append(("model/fast-bytes", 2 * tr.fast_bytes, fast_total,
                        "intra-pod psum == 2x the model's "
                        "leader-to-children copy bytes"))
        elif family == "psum":
            trh = allreduce_traffic(scheme="hier", num_nodes=Pn,
                                    ranks_per_node=c, msg_bytes=m)
            out.append(("model/bridge-bytes", Pn * trh.slow_bytes,
                        slow_total,
                        "c parallel shard rings sum to num_nodes x the "
                        "model's per-node bridge bytes"))
            out.append(("model/fast-bytes", c * trh.fast_bytes, fast_total,
                        "intra-node RS+AG == ranks_per_node x the model's "
                        "per-node cycle"))
        elif family == "alltoall":
            if Pn > 1:
                out.append(("model/bridge-bytes", tr.slow_bytes, slow_total,
                            "node-aware bridge == model slow_bytes: node "
                            "superchunks cross pods exactly once"))
            naive_tr = alltoall_traffic(scheme="naive", num_nodes=Pn,
                                        ranks_per_node=c, bytes_per_pair=m)
            out.append(("model/result-node", tr.result_bytes_per_node,
                        result_node,
                        "rank-private all-to-all results: same resident "
                        "bytes as the flat scheme"))
            if naive_tr.fast_bytes and len(fast_shape) == 1:
                # single-fast-axis identity; a factored fast tier (tuple
                # axes) moves the buffer once per sub-axis instead.
                out.append(("model/fast-ratio",
                            Pn * naive_tr.fast_bytes, fast_total,
                            "intra-node redistribution == num_nodes x the "
                            "flat scheme's intra-node pair bytes "
                            "(single-axis fast tier only)"))
        return out


class SharedScheme(CollectiveScheme):
    """The paper's memory-optimal scheme: ONE result copy per node, sharded
    over the fast tier (the MPI-3 shared window); readers use
    ``SharedWindow.read``."""

    name = "shared"
    result_class = "shared"
    ops = MappingProxyType({
        "allgather": lambda x, *, fast, slow, axis=0, **_:
            p.shared_all_gather(x, fast_axis=fast, slow_axis=slow, axis=axis),
        "broadcast": lambda x, *, fast, slow, root=0, axis=0, **_:
            p.shared_broadcast(x, root=root, fast_axis=fast, slow_axis=slow,
                               axis=axis),
        "psum": lambda x, *, fast, slow, axis=0, **_:
            p.shared_psum_scatter(x, fast_axis=fast, slow_axis=slow,
                                  axis=axis),
        "reduce_scatter": lambda x, *, fast, slow, axis=0, **_:
            p.shared_psum_scatter(x, fast_axis=fast, slow_axis=slow,
                                  axis=axis),
        "allgatherv": lambda x, valid, *, fast, slow, axis=0, **_:
            p.shared_all_gather_v(x, valid, slow_axis=slow, axis=axis),
    })

    def tiling(self, family, *, pods, chips):
        if family in ("broadcast", "psum", "reduce_scatter"):
            return chips                  # window shards: 1/c of the message
        return 1

    def links(self, family, *, pods, chips, fast_shape, elems,
              elem_bytes=4, opts=None, dtype="float32"):
        Pn, c = pods, chips
        m = elems * elem_bytes
        if family == "allgather":
            return 0.0, _ag(Pn * m, Pn)
        if family == "broadcast":
            return _rs(m / c, c), _ar(m / c, Pn)
        if family in ("psum", "reduce_scatter"):
            return _rs(m / c, c), _ar(m / c, Pn)
        if family == "allgatherv":
            return 0.0, _ag(Pn * m, Pn) + _ag(Pn * CNT_BYTES, Pn)
        raise ValueError(f"unknown family {family!r}")

    def identities(self, family, *, traffic, pods, chips, elems,
                   fast_total, slow_total, result_node, elem_bytes=4,
                   fast_shape=(), populations=None):
        Pn, c = pods, chips
        tr = traffic
        out = []
        if family == "allgather":
            out.append(("model/bridge-bytes", tr.slow_bytes, slow_total,
                        "bridge wire bytes == model slow_bytes (node "
                        "regions cross once)"))
            out.append(("model/fast-bytes", tr.fast_bytes, fast_total,
                        "zero intra-node copy bytes — paper C2"))
            out.append(("model/result-node", tr.result_bytes_per_node,
                        result_node, "resident result bytes == model "
                        "result_bytes_per_node"))
        elif family == "broadcast":
            out.append(("model/bridge-bytes", 2 * tr.slow_bytes, slow_total,
                        "shard bridge == 2x model slow_bytes (one shared "
                        "copy crosses once, psum-doubled)"))
            out.append(("model/result-node", tr.result_bytes_per_node,
                        result_node, "resident result bytes == model "
                        "result_bytes_per_node"))
        elif family == "psum":
            out.append(("model/bridge-bytes", Pn * tr.slow_bytes, slow_total,
                        "c parallel shard rings sum to num_nodes x the "
                        "model's per-node bridge bytes"))
            out.append(("model/fast-bytes", (c / 2) * tr.fast_bytes,
                        fast_total,
                        "intra-node RS vs the model's per-node RS+AG cycle "
                        "(shared skips the AG half)"))
            out.append(("model/result-node", tr.result_bytes_per_node,
                        result_node, "resident result bytes == model "
                        "result_bytes_per_node"))
        elif family == "allgatherv" and Pn > 1:
            R = Pn * c
            S = sum(populations)          # present ranks
            # subtract the (tiny, closed-form) int32 counts exchange from
            # the MEASURED bridge bytes; what remains is the padded data
            # exchange, which scaled by the compact fraction S/R must hit
            # the model's GatherPlan-compact bridge bytes.
            counts_slow_total = R * CNT_BYTES * (Pn - 1)
            data_slow_total = slow_total - counts_slow_total
            out.append(("model/bridge-bytes", tr.slow_bytes,
                        data_slow_total * S / R,
                        "measured padded bridge bytes (minus the counts "
                        "exchange) x compact fraction == model compact "
                        "bridge bytes (GatherPlan)"))
        return out


class PipelinedScheme(HierScheme):
    """Chunked two-phase schedule (``repro.comm.pipeline``): the message is
    split into ``n_chunks`` segments and the bridge stage of segment *k*
    overlaps the on-node stage of segment *k+1* through double-buffered
    window epochs.

    Results are bit-identical to ``hier`` (``reduce_scatter``: the flat
    ``naive`` slices, numerically equivalent — the two-phase sum
    reassociates the flat ring's adds) and the total link bytes are
    EXACTLY the unchunked
    closed forms — chunking is linear in the message, so every ``links``/
    ``identities`` expectation is inherited unchanged and must hold for
    every ``n_chunks``.  What changes is latency:
    ``core.plans.pipelined_time_model`` adds the overlap term, and the
    bench autotunes ``n_chunks`` per (topology, size) cell.
    """

    name = "pipelined"
    result_class = "replicated"
    n_chunk_candidates = (1, 2, 4, 8)
    ops = MappingProxyType({
        "allgather": lambda x, *, fast, slow, axis=0, n_chunks=2, **_:
            pipe.pipelined_all_gather(x, fast_axis=fast, slow_axis=slow,
                                      axis=axis, n_chunks=n_chunks),
        "broadcast": lambda x, *, fast, slow, root=0, axis=0, n_chunks=2,
                            **_:
            pipe.pipelined_broadcast(x, root=root, fast_axis=fast,
                                     slow_axis=slow, axis=axis,
                                     n_chunks=n_chunks),
        "psum": lambda x, *, fast, slow, axis=0, n_chunks=2, **_:
            pipe.pipelined_psum(x, fast_axis=fast, slow_axis=slow,
                                axis=axis, n_chunks=n_chunks),
        "reduce_scatter": lambda x, *, fast, slow, axis=0, n_chunks=2, **_:
            pipe.pipelined_reduce_scatter(x, fast_axis=fast, slow_axis=slow,
                                          axis=axis, n_chunks=n_chunks),
    })

    def tiling(self, family, *, pods, chips):
        if family == "psum":
            return chips                  # per-chunk intra-pod psum_scatter
        if family == "reduce_scatter":
            return pods * chips           # per-chunk flat 1/R slices
        return 1

    def candidates(self, family, *, pods, chips, elems):
        if not self.supports(family):
            return ()
        need = self.tiling(family, pods=pods, chips=chips)
        return tuple({"n_chunks": nc} for nc in self.n_chunk_candidates
                     if elems % (nc * need) == 0)

    def links(self, family, *, pods, chips, fast_shape, elems,
              elem_bytes=4, opts=None, dtype="float32"):
        if family == "reduce_scatter":
            # two-phase: bridge RS over pods, then intra-pod RS of the pod
            # slice (linear in the chunk size, so nc-invariant).
            Pn, c = pods, chips
            m = elems * elem_bytes
            if Pn > 1:
                return _rs(m / (Pn * c), c), _rs(m / Pn, Pn)
            return _rs(m / c, c), 0.0
        return super().links(family, pods=pods, chips=chips,
                             fast_shape=fast_shape, elems=elems,
                             elem_bytes=elem_bytes, dtype=dtype)

    def predicted_time(self, family, *, pods, chips, elems, elem_bytes=4,
                       populations=None):
        """Overlap-aware prediction: ``core.plans.best_chunk_count`` over
        the cell's valid ``n_chunks`` candidates, priced by
        ``pipelined_time_model``.  The nonzero per-chunk alpha makes the
        one-chunk pipeline strictly pricier than the plain ``hier``
        schedule, so the model never prefers chunking that buys nothing."""
        cands = self.candidates(family, pods=pods, chips=chips, elems=elems)
        if not cands:
            return None
        tr = self.traffic(family, pods=pods, chips=chips, elems=elems,
                          elem_bytes=elem_bytes, populations=populations)
        ncs = tuple(c["n_chunks"] for c in cands)
        alpha = 1e-6
        nc = best_chunk_count(tr, num_nodes=pods, ranks_per_node=chips,
                              candidates=ncs, alpha=alpha)
        t = pipelined_time_model(tr, n_chunks=nc, num_nodes=pods,
                                 ranks_per_node=chips, alpha=alpha)
        return t, {"n_chunks": nc}


# ---------------------------------------------------------------------------
# Quantized wire-format schemes (lossy precision class)
# ---------------------------------------------------------------------------

def _qblocks(n: int, block: int) -> tuple[int, int]:
    """(n_blocks, padded_elems) of ``repro.comm.quantize``'s block layout
    for an ``n``-element payload — the wire carries the padded count."""
    beff = max(1, min(int(block), int(n)))
    nb = -(-int(n) // beff)
    return nb, nb * beff


def _np32(a) -> np.ndarray:
    # bf16 payloads arrive as ml_dtypes arrays; widen before np math
    return np.asarray(a).astype(np.float32)


class _QuantizedScheme(CollectiveScheme):
    """Shared scaffolding of the lossy wire-format schemes.

    Subclasses set ``WIRE`` (bytes per element actually crossing the
    bridge, per family), ``QREL`` (worst-case quantization error relative
    to the payload's per-block amax, per bridge contribution) and
    ``SCALE_BYTES`` (0 for scale-free formats).  The traffic model prices
    the compressed bridge; the fast tier stays the parent scheme's full
    precision bytes.  ``candidates`` gate on ``pods >= 2``: a single-pod
    communicator has no bridge to compress, so the exact parent always
    wins that cell (the single-tier quantized *bodies* still run — the
    static-fallback gradient-bridge path uses them — they are just never
    offered to the tuner).
    """

    precision = "lossy"
    block_candidates = (64, 256)
    WIRE: Mapping[str, float] = MappingProxyType({})
    QREL: Mapping[str, float] = MappingProxyType({})
    SCALE_BYTES = 4.0                  # f32 scales travel with the data

    def _payload(self, family: str, *, chips: int, elems: int) -> int:
        """Elems of the flattened payload the bridge codec sees."""
        if family == "psum":
            return max(1, elems // chips)      # post psum_scatter shard
        return chips * elems                   # gathered node region

    def candidates(self, family, *, pods, chips, elems):
        if not self.supports(family) or pods < 2:
            return ()
        if elems % self.tiling(family, pods=pods, chips=chips):
            return ()
        payload = self._payload(family, chips=chips, elems=elems)
        return tuple({"block": b} for b in self.block_candidates
                     if payload % b == 0)

    def traffic(self, family, *, pods, chips, elems, elem_bytes=4,
                populations=None):
        tr = super().traffic(family, pods=pods, chips=chips, elems=elems,
                             elem_bytes=elem_bytes, populations=populations)
        if pods <= 1 or family not in self.WIRE:
            return tr
        factor = (self._wire(family, pods=pods)
                  + self.SCALE_BYTES / qz.DEFAULT_BLOCK) / elem_bytes
        return CollectiveTraffic(
            slow_bytes=tr.slow_bytes * factor,
            fast_bytes=tr.fast_bytes,
            result_bytes_per_node=tr.result_bytes_per_node)

    def _wire(self, family: str, *, pods: int) -> float:
        """Bridge bytes per payload element; hook for schedules whose wire
        format depends on the bridge's rank count."""
        return self.WIRE[family]

    def error_bound_rel(self, family, *, pods):
        q = self.QREL[family]
        return pods * q if family == "psum" else q

    def error_check(self, family, *, inputs, output, pods, chips, elems,
                    dtype="float32", opts=None):
        if family not in self.QREL:
            return None
        eps = 2.0 ** -8 if dtype == "bfloat16" else 2.0 ** -24
        if family == "psum":
            x = _np32(inputs[0])               # global (R, elems)
            exact = x.sum(axis=0)
            partials = x.reshape(pods, chips, -1).sum(axis=1)
            amax = float(np.max(np.abs(partials)))
            bound = self.QREL["psum"] * pods * amax \
                + 2.0 * (pods + chips) * eps * amax + 1e-12
            measured = float(np.max(np.abs(_np32(output) - exact)))
            return bound, measured
        if family == "allgather":
            x = _np32(inputs[0])               # global rank-major buffer
            amax = float(np.max(np.abs(x)))
            bound = self.QREL["allgather"] * amax + 2.0 * eps * amax + 1e-12
            exact = self._allgather_reference(x, pods=pods, chips=chips,
                                              elems=elems)
            measured = float(np.max(np.abs(_np32(output) - exact)))
            return bound, measured
        return None

    def _allgather_reference(self, x, *, pods, chips, elems):
        """Exact expected output layout: replicated hier order (== the
        rank-major input) unless a subclass overrides."""
        return x


class Q8HierScheme(_QuantizedScheme):
    """Hier schedule with an int8 bridge: intra-pod stages full precision,
    per-block symmetric int8 on the wire.  psum picks its bridge schedule
    by rank count — small-world bridges (<= 3 pods) fuse codes + LOCAL
    scales into ONE tiled u8 gather summed locally in f32 ((p-1) wire
    bytes/elem, one rendezvous); wider bridges share block scales with
    one ``pmax`` and sum codes exactly in int16 (exact for <= 256 pods).
    allgather ships local scales with the codes and restores the
    caller's own pod region exactly."""

    name = "q8_hier"
    result_class = "replicated"
    WIRE = MappingProxyType({"psum": 2.0, "allgather": 1.0})
    QREL = MappingProxyType({"psum": 1 / 254, "allgather": 1 / 254})
    ops = MappingProxyType({
        "psum": lambda x, *, fast, slow, axis=0, block=qz.DEFAULT_BLOCK,
                       err=None, **_:
            qz.q8_hier_psum(x, fast_axis=fast, slow_axis=slow, axis=axis,
                            block=block, err=err),
        "allgather": lambda x, *, fast, slow, axis=0,
                            block=qz.DEFAULT_BLOCK, **_:
            qz.q8_hier_all_gather(x, fast_axis=fast, slow_axis=slow,
                                  axis=axis, block=block),
    })

    def tiling(self, family, *, pods, chips):
        return chips if family == "psum" else 1   # intra-pod psum_scatter

    def _wire(self, family, *, pods):
        if family == "psum" and 2 <= pods <= 3:
            # fused u8 gather bridge: (p-1) B/elem where the parent ring
            # all-reduce moves 2(p-1)/p f32 elems -> p/2 x the u8 wire
            return 1.0 * pods / 2.0
        return self.WIRE[family]

    def links(self, family, *, pods, chips, fast_shape, elems,
              elem_bytes=4, opts=None, dtype="float32"):
        Pn, c = pods, chips
        R, m = Pn * c, elems * elem_bytes
        block = (opts or {}).get("block", qz.DEFAULT_BLOCK)
        if family == "psum":
            if Pn == 1:
                nb, padded = _qblocks(elems, block)
                if c <= 3:
                    # single-tier small world: one fused u8 code+scale gather
                    return _ag(c * (padded + 4.0 * nb), c), 0.0
                return _ar(2.0 * padded, c) + _ar(4.0 * nb, c), 0.0
            nb, padded = _qblocks(elems // c, block)
            fast = _rs(m / c, c) + _ag(m, c)
            if Pn <= 3:
                # fused u8 gather: codes + local f32 block scales, one op
                return fast, _ag(Pn * (padded + 4.0 * nb), Pn)
            # int16 wire sum + the f32 block-scales pmax exchange
            return fast, _ar(2.0 * padded, Pn) + _ar(4.0 * nb, Pn)
        if family == "allgather":
            fast = _ag(c * m, c)
            if Pn == 1:
                return fast, 0.0
            nb, padded = _qblocks(c * elems, block)
            # int8 codes + f32 scales, both gathered across the bridge
            return fast, _ag(Pn * 1.0 * padded, Pn) + _ag(Pn * 4.0 * nb, Pn)
        raise ValueError(f"unknown family {family!r}")


class QBf16HierScheme(_QuantizedScheme):
    """Hier schedule with a bf16 bridge: scale-free truncation, halving
    the f32 wire with no scales exchange.  The wire is a bitcast ``u16``
    gather summed locally in f32 (native integer lowering on every
    backend; a bf16 float collective would be widened back to f32 by
    XLA's CPU bf16 normalization).  Exact on bf16 payloads (the dtype
    sweep shows it winning nothing there — the table learns that the
    reduction only exists for wider payloads)."""

    name = "qbf16_hier"
    result_class = "replicated"
    WIRE = MappingProxyType({"psum": 2.0, "allgather": 2.0})
    QREL = MappingProxyType({"psum": 2.0 ** -8, "allgather": 2.0 ** -8})
    SCALE_BYTES = 0.0
    ops = MappingProxyType({
        "psum": lambda x, *, fast, slow, axis=0, err=None, **_:
            qz.qbf16_hier_psum(x, fast_axis=fast, slow_axis=slow, axis=axis,
                               err=err),
        "allgather": lambda x, *, fast, slow, axis=0, **_:
            qz.qbf16_hier_all_gather(x, fast_axis=fast, slow_axis=slow,
                                     axis=axis),
    })

    def tiling(self, family, *, pods, chips):
        return chips if family == "psum" else 1

    def candidates(self, family, *, pods, chips, elems):
        # no block tunable: one candidate when the cell tiles multi-pod
        if not self.supports(family) or pods < 2:
            return ()
        if elems % self.tiling(family, pods=pods, chips=chips):
            return ()
        return ({},)

    def traffic(self, family, *, pods, chips, elems, elem_bytes=4,
                populations=None):
        tr = CollectiveScheme.traffic(self, family, pods=pods, chips=chips,
                                      elems=elems, elem_bytes=elem_bytes,
                                      populations=populations)
        if pods <= 1:
            return tr
        # psum crosses the bridge as a gather of all pods' bf16 partials
        # (summed locally), not a ring all-reduce: pods x the 2-byte
        # payload where the parent's all-reduce moves ~2x the f32 payload
        factor = (float(pods) if family == "psum" else 2.0) / elem_bytes
        return CollectiveTraffic(
            slow_bytes=tr.slow_bytes * factor,
            fast_bytes=tr.fast_bytes,
            result_bytes_per_node=tr.result_bytes_per_node)

    def links(self, family, *, pods, chips, fast_shape, elems,
              elem_bytes=4, opts=None, dtype="float32"):
        Pn, c = pods, chips
        R, m = Pn * c, elems * elem_bytes
        if family == "psum":
            if Pn == 1:
                # single tier: the whole reduction is the u16-gather bridge
                return _ag(c * 2.0 * elems, c), 0.0
            fast = _rs(m / c, c) + _ag(m, c)
            # untiled u16 gather of every pod's shard, summed locally
            return fast, _ag(Pn * 2.0 * elems / c, Pn)
        if family == "allgather":
            fast = _ag(c * m, c)
            if Pn == 1:
                return fast, 0.0
            return fast, _ag(R * elems * 2.0, Pn)
        raise ValueError(f"unknown family {family!r}")


class Q4SharedScheme(_QuantizedScheme):
    """Shared-window allgather with a packed-int4 bridge (two nibbles per
    byte + per-block f32 scales): the weight-window format.  The result
    stays ONE copy per pod sharded over the fast tier, so the C1 claim is
    untouched — only the bridge exchange is compressed."""

    name = "q4_shared"
    result_class = "shared"
    WIRE = MappingProxyType({"allgather": 0.5})
    QREL = MappingProxyType({"allgather": 1 / 14})
    ops = MappingProxyType({
        "allgather": lambda x, *, fast, slow, axis=0,
                            block=qz.DEFAULT_BLOCK, **_:
            qz.q4_shared_all_gather(x, fast_axis=fast, slow_axis=slow,
                                    axis=axis, block=block),
    })

    def _payload(self, family, *, chips, elems):
        return elems                       # per-rank shard, pre-gather

    def links(self, family, *, pods, chips, fast_shape, elems,
              elem_bytes=4, opts=None, dtype="float32"):
        if family != "allgather":
            raise ValueError(f"unknown family {family!r}")
        Pn = pods
        if Pn == 1:
            return 0.0, 0.0                # identity: already in the window
        block = (opts or {}).get("block", qz.DEFAULT_BLOCK)
        nb, padded = _qblocks(elems, block)
        return 0.0, _ag(Pn * 0.5 * padded, Pn) + _ag(Pn * 4.0 * nb, Pn)

    def _allgather_reference(self, x, *, pods, chips, elems):
        # shared layout: rank (p, i)'s window shard is chip i's
        # contribution from EVERY pod, pod-major (identical across p)
        cols = x.reshape(pods, chips, elems)
        shard = [np.concatenate([cols[q, i] for q in range(pods)])
                 for i in range(chips)]
        return np.concatenate([shard[i]
                               for _ in range(pods)
                               for i in range(chips)])


NAIVE = register_scheme(NaiveScheme())
HIER = register_scheme(HierScheme())
SHARED = register_scheme(SharedScheme())
PIPELINED = register_scheme(PipelinedScheme())
Q8_HIER = register_scheme(Q8HierScheme())
QBF16_HIER = register_scheme(QBf16HierScheme())
Q4_SHARED = register_scheme(Q4SharedScheme())
