"""repro.comm: communicator + shared-window collective API.

The single entry point for collectives (the old ``repro.core.collectives``
free functions were removed after their deprecation release):

* ``Communicator``  — the two-tier (node + bridge) communicator; methods
  ``allgather``/``allgatherv``/``broadcast``/``allreduce``/
  ``reduce_scatter``/``alltoall`` dispatch through the scheme registry;
* ``SharedWindow``  — the MPI-3 shared-window analogue with explicit
  ``fence()``/epoch synchronization semantics;
* ``AsyncCollectiveHandle`` — issue-early / resolve-late collectives
  (``Communicator.allgather_async``): window epochs stand in for CUDA
  events, and a torn resolve raises ``WindowEpochError``;
* ``registry``      — self-describing scheme entries (``naive``/``hier``/
  ``shared``/``pipelined``): bodies + traffic closed-forms + expected
  lowerings + tunable grids.  New schemes register here and are
  immediately swept by ``repro.bench`` and callable from every
  ``Communicator``;
* ``pipeline``      — the chunked two-phase primitives behind the
  ``pipelined`` scheme, plus the fused collective-matmul compute-overlap
  primitives (``ag_matmul``/``matmul_rs``);
* ``tuning``        — the ``scheme="auto"`` backend: the persisted
  ``TuningTable`` (measured winners per family x topology x dtype x size
  bucket, ``TUNING_default.json``) and the ``resolve()`` chain that falls
  back to the ``core.plans`` closed forms on unmeasured cells;
* ``stepgraph``     — the step-graph collective optimizer:
  ``Communicator.record()`` returns a ``GraphRecorder`` that records a
  whole step's collectives, then buckets / dedups / reorders the schedule
  before applying it (``record -> rewrite -> apply``).
"""

from repro.comm import (handle, pipeline, primitives, registry, stepgraph,
                        tuning, window)
from repro.comm.communicator import Communicator
from repro.comm.handle import AsyncCollectiveHandle
from repro.comm.registry import (CollectiveScheme, get_scheme,
                                 register_scheme, scheme_names, schemes_for)
from repro.comm.stepgraph import (CollectiveGraph, Deferred, GraphRecorder,
                                  Schedule, ScheduleResult)
from repro.comm.tuning import (Resolution, TuningTable, resolve_scheme,
                               use_table)
from repro.comm.window import SharedWindow, WindowEpochError

__all__ = [
    "AsyncCollectiveHandle", "Communicator", "SharedWindow",
    "WindowEpochError", "CollectiveScheme", "get_scheme", "register_scheme",
    "scheme_names", "schemes_for", "handle", "pipeline", "primitives",
    "registry", "stepgraph", "tuning", "window",
    "Resolution", "TuningTable", "resolve_scheme", "use_table",
    "CollectiveGraph", "Deferred", "GraphRecorder", "Schedule",
    "ScheduleResult",
]
