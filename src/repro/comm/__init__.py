"""repro.comm: communicator + shared-window collective API.

The single entry point for collectives (replaces the free functions of
``repro.core.collectives``, which remain as deprecated shims for one
release):

* ``Communicator``  — the two-tier (node + bridge) communicator; methods
  ``allgather``/``allgatherv``/``broadcast``/``allreduce``/
  ``reduce_scatter``/``alltoall`` dispatch through the scheme registry;
* ``SharedWindow``  — the MPI-3 shared-window analogue with explicit
  ``fence()``/epoch synchronization semantics;
* ``registry``      — self-describing scheme entries (``naive``/``hier``/
  ``shared``): bodies + traffic closed-forms + expected lowerings.  New
  schemes register here and are immediately swept by ``repro.bench`` and
  callable from every ``Communicator``.
"""

from repro.comm import primitives, registry, window
from repro.comm.communicator import Communicator
from repro.comm.registry import (CollectiveScheme, get_scheme,
                                 register_scheme, scheme_names, schemes_for)
from repro.comm.window import SharedWindow, WindowEpochError

__all__ = [
    "Communicator", "SharedWindow", "WindowEpochError",
    "CollectiveScheme", "get_scheme", "register_scheme", "scheme_names",
    "schemes_for", "primitives", "registry", "window",
]
