"""SharedWindow: the MPI-3 shared-memory window as a first-class object.

In the paper, replicated data lives once per node in an
``MPI_Win_allocate_shared`` segment; on-node ranks load/store it directly,
and integrity is guarded by *synchronization epochs*: stores made in one
epoch become readable only after the epoch is closed (``MPI_Win_fence`` /
the two-barrier discipline of §6).

Here the window is the pod-sharded buffer the ``shared`` scheme produces:
chip *i* physically holds shard *i* of the node's single logical copy.
``SharedWindow`` wraps that shard together with its communicator and an
explicit epoch counter:

* ``read()``            — load the full node buffer (intra-pod gather at use
                          time; AD transpose is the reduce-scatter store);
* ``store(x)``          — replace the local shard, opening a *dirty* store
                          epoch;
* ``accumulate(x)``     — reduce-scatter partial contributions into the
                          window (the gradient store), also dirty;
* ``fence()``           — close the epoch: a ``core.sync`` barrier over the
                          node makes every rank's result data-dependent on
                          every other rank's stores, then marks the window
                          clean and bumps ``epoch``.

Reading a dirty window raises — that is the paper's data-integrity rule
("a process cannot read until all writers finished") made unskippable.

Inside one jitted step XLA's dataflow already orders exchange before use;
the fence exists for *cross-step* control sync and to make the epoch
discipline explicit and testable.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.comm import primitives as p


class WindowEpochError(RuntimeError):
    """A read hit an open (dirty) store epoch — call ``fence()`` first."""


@dataclasses.dataclass(frozen=True)
class SharedWindow:
    """One node-shared buffer: the local shard + its epoch state.

    ``comm`` is the ``repro.comm.Communicator`` whose fast tier is the node
    (the ``sharedmemComm`` of ``MPI_Comm_split_type``); ``axis`` is the
    array dimension the buffer is sharded over.
    """

    comm: object                      # Communicator (typed loosely: no cycle)
    shard: jax.Array
    axis: int = 0
    epoch: int = 0
    dirty: bool = False

    # -- stores (open an epoch) ----------------------------------------------
    def store(self, shard: jax.Array) -> "SharedWindow":
        """Replace this rank's partition (a direct store into the segment).
        The window is dirty until the next ``fence()``."""
        return dataclasses.replace(self, shard=shard, dirty=True)

    def accumulate(self, x: jax.Array) -> "SharedWindow":
        """Reduce partial contributions from every on-node rank into the
        window shards (intra-pod reduce-scatter — the gradient store)."""
        shard = lax.psum_scatter(x, p._axes(self.comm.fast_axis),
                                 scatter_dimension=self.axis, tiled=True)
        return dataclasses.replace(self, shard=shard, dirty=True)

    # -- synchronization ------------------------------------------------------
    def fence(self) -> "SharedWindow":
        """Close the current epoch (``MPI_Win_fence`` on the node comm).

        Built on ``core.sync.barrier``: the returned shard is data-dependent
        on every on-node rank's shard, so no consumer of the fenced window
        can be scheduled before every store of the closing epoch.

        The dependency is threaded with ``optimization_barrier``, never
        arithmetic on the payload — the fence is exactly value-preserving
        even for NaN/inf shards (a near-overflow gradient must not be
        corrupted by its own synchronization) and for zero-size shards."""
        from repro.core import sync
        # token computable only after this rank's stores...
        shard, token = lax.optimization_barrier(
            (self.shard, jnp.ones((), jnp.float32)))
        done = sync.barrier(token, self.comm.fast_axis)
        # ...and the fenced shard available only after every rank reported.
        shard, _ = lax.optimization_barrier((shard, done))
        return dataclasses.replace(self, shard=shard, dirty=False,
                                   epoch=self.epoch + 1)

    def fence_local(self, token: jax.Array) -> "SharedWindow":
        """Close the epoch with *local* ordering only: the fenced shard
        becomes data-dependent on ``token`` via ``optimization_barrier`` —
        zero wire bytes, value bit-preserving.

        Valid when the epoch's writers and readers live inside ONE jitted
        dataflow (the double-buffered pipeline of ``repro.comm.pipeline``):
        there XLA already orders every store before its data-dependent
        consumers, and the token carries the only extra constraint — buffer
        reuse (a chunk may not reoccupy a buffer its previous tenant still
        feeds).  Cross-step epochs still require the heavy ``fence()``
        (node barrier)."""
        shard, _ = lax.optimization_barrier((self.shard, token))
        return dataclasses.replace(self, shard=shard, dirty=False,
                                   epoch=self.epoch + 1)

    # -- loads ---------------------------------------------------------------
    def _check_clean(self) -> None:
        if self.dirty:
            raise WindowEpochError(
                "read from a dirty SharedWindow: a store/accumulate opened "
                "an epoch that was never closed — call fence() before "
                "reading (paper §6: readers wait for all writers)")

    def read(self) -> jax.Array:
        """Materialize the full node buffer in (local_rank, pod) element
        order — the load from the shared segment (intra-pod gather)."""
        self._check_clean()
        return p.shared_read(self.shard, fast_axis=self.comm.fast_axis,
                             axis=self.axis)

    def read_rank_order(self) -> jax.Array:
        """Full buffer in SMP (pod, local_rank) rank order; needs the
        communicator's static shape."""
        full = self.read()
        if self.comm.pods is None or self.comm.chips is None:
            raise ValueError("read_rank_order needs a Communicator with "
                             "static pods/chips counts")
        return p.shared_to_rank_order(full, num_pods=self.comm.pods,
                                      chips_per_pod=self.comm.chips,
                                      axis=self.axis)


jax.tree_util.register_pytree_node(
    SharedWindow,
    lambda w: ((w.shard,), (w.comm, w.axis, w.epoch, w.dirty)),
    lambda aux, ch: SharedWindow(aux[0], ch[0], axis=aux[1], epoch=aux[2],
                                 dirty=aux[3]))


# ---------------------------------------------------------------------------
# FSDP-style parameter access (the window applied along a weight dim).
# ---------------------------------------------------------------------------

def window_gather(x: jax.Array, dim: Optional[int], fast_axis) -> jax.Array:
    """Load from the pod-shared parameter store: intra-pod all-gather along
    ``dim`` at use time (AD transpose is the reduce-scatter store).
    ``dim=None`` means the tensor is too small to shard — it is replicated
    and the load is free."""
    if dim is None:
        return x
    return p.shared_read(x, fast_axis=fast_axis, axis=dim)


def window_scatter(x: jax.Array, dim: Optional[int], fast_axis) -> jax.Array:
    """Explicit store: reduce-scatter partial contributions back to shards
    (``dim=None``: plain psum of the replicated tensor)."""
    axes = p._axes(fast_axis)
    if dim is None:
        return lax.psum(x, axes)
    return lax.psum_scatter(x, axes, scatter_dimension=dim, tiled=True)
