"""AsyncCollectiveHandle: issue-early / resolve-late collectives.

The companion paper (Zhou et al., *Collectives in hybrid MPI+MPI code*)
observes that the shared-window synchronization epochs are what make
*asynchronous* collectives safe: a gather may be issued in one epoch and its
result consumed much later, as long as no store re-opens the window in
between.  On GPUs this is the CUDA-event idiom (record at issue, wait at
use); here the window's **epoch counter is the event**:

* ``issue`` — materialize the gather from a clean window and capture a
  dependency token (the AD-safe twin of ``pipeline._token_after``) plus
  the window's epoch;
* ``resolve`` — return the gathered value, ordered after the token via
  ``optimization_barrier`` (the "event wait"); if the window was stored to
  or fenced past the issue epoch in the meantime, the handle is *torn* and
  ``resolve`` raises ``WindowEpochError``.

Handles are frozen pytrees, so they thread through ``lax`` control flow and
``jax.tree`` walks like any other value.  Inside one jitted step XLA's
dataflow already overlaps the issued gather with unrelated compute between
issue and resolve — exactly the double-buffer overlap of
``repro.comm.pipeline``, but spanning arbitrary user code instead of one
fused matmul.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.comm.window import SharedWindow, WindowEpochError


@jax.custom_vjp
def _ordered(value, token):
    """``pipeline._token_after``-style ordering pair, but differentiable:
    ``optimization_barrier`` has no AD rule, and handles live inside
    differentiated model code.  Forward lowers to the barrier (the pair is
    scheduled as a unit); backward passes cotangents straight through —
    grads need no ordering constraint, remat policy handles the bwd."""
    return lax.optimization_barrier((value, token))


def _ordered_fwd(value, token):
    return _ordered(value, token), None


def _ordered_bwd(_, g):
    return g


_ordered.defvjp(_ordered_fwd, _ordered_bwd)


@dataclasses.dataclass(frozen=True)
class AsyncCollectiveHandle:
    """An in-flight collective: the issuing window, the materialized value,
    and the epoch "event" that guards the resolve."""

    family: str
    window: SharedWindow
    value: jax.Array
    token: jax.Array
    issue_epoch: int

    @classmethod
    def issue(cls, family: str, window: SharedWindow) \
            -> "AsyncCollectiveHandle":
        """Start the collective: read the (clean) window now, record the
        epoch.  Raises ``WindowEpochError`` if the window is dirty — an
        async gather may not overlap an open store epoch."""
        value = window.read()
        # token computable only after the gather issued (the "event record")
        _, token = _ordered(value, jnp.ones((), jnp.float32))
        return cls(family=family, window=window, value=value,
                   token=token, issue_epoch=window.epoch)

    @property
    def done(self) -> bool:
        """Event query (``MPI_Test`` / ``cudaEventQuery``): the handle is
        resolvable iff the window is still clean in the issue epoch."""
        return (not self.window.dirty) and \
            self.window.epoch == self.issue_epoch

    def resolve(self) -> jax.Array:
        """Event wait: return the gathered buffer, data-dependent on the
        issue token.  A dirty window or an epoch bump since issue means the
        buffer may have been torn by a concurrent store — raise instead of
        returning stale bytes."""
        if not self.done:
            raise WindowEpochError(
                f"resolve of a torn {self.family} handle: the window was "
                f"stored to or fenced past epoch {self.issue_epoch} "
                f"(now epoch {self.window.epoch}, "
                f"dirty={self.window.dirty}) — re-issue after the fence")
        out, _ = _ordered(self.value, self.token)
        return out


jax.tree_util.register_pytree_node(
    AsyncCollectiveHandle,
    lambda h: ((h.window, h.value, h.token), (h.family, h.issue_epoch)),
    lambda aux, ch: AsyncCollectiveHandle(
        family=aux[0], window=ch[0], value=ch[1], token=ch[2],
        issue_epoch=aux[1]))
