"""Deterministic synthetic LM data pipeline.

Seeded, shardable, restart-reproducible: batch ``i`` of host ``h`` is a pure
function of (seed, step, host) — after a checkpoint restart the stream
resumes exactly, and each data-parallel host draws a disjoint slice without
coordination (the property a 1000-node fleet needs from its loader).

The token stream is a mixture of Zipf-distributed unigrams and short copy
motifs so that a language model has learnable structure (quickstart's loss
drops well below ln(V))."""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    zipf_a: float = 1.3
    motif_len: int = 8
    motif_prob: float = 0.5


class SyntheticLM:
    """Iterator of {tokens: (local_batch, T+1)} batches for one host."""

    def __init__(self, cfg: DataConfig, *, host_id: int = 0,
                 num_hosts: int = 1, start_step: int = 0):
        assert cfg.global_batch % num_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.step = start_step
        # fixed motif table (shared across hosts; seeded)
        rng = np.random.default_rng(cfg.seed)
        self._motifs = rng.integers(
            0, cfg.vocab, size=(64, cfg.motif_len)).astype(np.int32)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self._p = p / p.sum()

    def _batch_rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.cfg.seed, step, self.host_id))

    def next_batch(self) -> dict:
        cfg = self.cfg
        b_loc = cfg.global_batch // self.num_hosts
        rng = self._batch_rng(self.step)
        toks = rng.choice(cfg.vocab, size=(b_loc, cfg.seq_len + 1),
                          p=self._p).astype(np.int32)
        # splice in copy motifs (learnable bigram structure)
        n_splice = int(cfg.seq_len * cfg.motif_prob / cfg.motif_len)
        for b in range(b_loc):
            pos = rng.integers(0, cfg.seq_len - cfg.motif_len,
                               size=n_splice)
            mid = rng.integers(0, len(self._motifs), size=n_splice)
            for p0, m in zip(pos, mid):
                toks[b, p0:p0 + cfg.motif_len] = self._motifs[m]
        self.step += 1
        return {"tokens": toks}

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next_batch()
