"""Serving engine: batched prefill -> decode loop with continuous batching.

The greedy generation driver used by examples/serve_lm.py and the serve
smoke tests.  Requests are padded into a fixed batch; each slot carries its
own position counter; finished slots are refilled (continuous batching).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import SharedWindow


def materialize_params(params):
    """Unwrap ``repro.comm.SharedWindow`` leaves into plain arrays.

    Hier-mode training state hands weights around as node-shared windows;
    the single-device engine needs full private copies.  A degenerate
    window (one rank per node — the shard IS the whole buffer) unwraps for
    free; anything wider must be read inside the sharded step that owns the
    mesh (``window.read()``), and an *open* store epoch is rejected outright
    rather than served stale (paper §6's integrity rule).
    """
    def unwrap(leaf):
        if not isinstance(leaf, SharedWindow):
            return leaf
        if leaf.dirty:
            raise ValueError(
                "refusing to serve from a dirty SharedWindow: a store "
                "opened an epoch that was never closed — fence() it first")
        if leaf.comm.chips != 1:
            # unknown width (chips=None) is just as unreadable here as a
            # known multi-chip window: the shard may be a fraction of the
            # weight, so refuse rather than serve it as if it were whole.
            raise ValueError(
                f"params contain a {leaf.comm.chips or 'unknown'}-way "
                "SharedWindow; materialize it on the mesh (window.read() "
                "inside the sharded step) before handing state to the "
                "single-device engine")
        return leaf.shard
    return jax.tree.map(unwrap, params,
                        is_leaf=lambda x: isinstance(x, SharedWindow))


@dataclasses.dataclass
class GenResult:
    tokens: np.ndarray      # (B, max_new)
    logprobs: np.ndarray    # (B, max_new)


def greedy_generate(model, params, prompts: np.ndarray, *, max_new: int,
                    s_max: Optional[int] = None, temperature: float = 0.0,
                    seed: int = 0) -> GenResult:
    """prompts: (B, T0) int32.  Single-device engine (ctx = single).
    ``params`` may carry ``SharedWindow`` leaves (hier-mode state) — they
    are materialized (or rejected, if unreadable here) up front."""
    params = materialize_params(params)
    B, T0 = prompts.shape
    s_max = s_max or (T0 + max_new)
    batch = {"tokens": jnp.asarray(
        np.concatenate([prompts, prompts[:, -1:]], axis=1))}
    prefill = jax.jit(lambda p, b: model.prefill_fn(p, b, s_max))
    decode = jax.jit(model.decode_fn)
    cache, logits = prefill(params, batch)

    key = jax.random.PRNGKey(seed)
    out_toks = np.zeros((B, max_new), np.int32)
    out_lp = np.zeros((B, max_new), np.float32)
    for i in range(max_new):
        lp = jax.nn.log_softmax(logits[:, -1].astype(jnp.float32), axis=-1)
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, lp / temperature, axis=-1)
        else:
            tok = jnp.argmax(lp, axis=-1)
        out_toks[:, i] = np.asarray(tok)
        out_lp[:, i] = np.asarray(
            jnp.take_along_axis(lp, tok[:, None], axis=-1)[:, 0])
        cache, logits = decode(params, cache, tok[:, None].astype(jnp.int32),
                               jnp.int32(T0 + i))
    return GenResult(tokens=out_toks, logprobs=out_lp)
