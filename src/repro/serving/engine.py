"""Serving engine: batched prefill -> decode loop with continuous batching.

The greedy generation driver used by examples/serve_lm.py and the serve
smoke tests.  Requests are padded into a fixed batch; each slot carries its
own position counter; finished slots are refilled (continuous batching).
"""

from __future__ import annotations

import dataclasses
import weakref
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import SharedWindow


def materialize_params(params):
    """Unwrap ``repro.comm.SharedWindow`` leaves into plain arrays.

    Hier-mode training state hands weights around as node-shared windows;
    the single-device engine needs full private copies.  A degenerate
    window (one rank per node — the shard IS the whole buffer) unwraps for
    free; anything wider must be read on the mesh that owns it
    (``materialize_params_on_mesh``), and an *open* store epoch is rejected
    outright rather than served stale (paper §6's integrity rule).
    """
    def unwrap(leaf):
        if not isinstance(leaf, SharedWindow):
            return leaf
        _check_clean(leaf)
        if leaf.comm.chips != 1:
            # unknown width (chips=None) is just as unreadable here as a
            # known multi-chip window: the shard may be a fraction of the
            # weight, so refuse rather than serve it as if it were whole.
            raise ValueError(
                f"params contain a {leaf.comm.chips or 'unknown'}-way "
                "SharedWindow; materialize it on the mesh "
                "(materialize_params_on_mesh) before handing state to the "
                "single-device engine")
        return leaf.shard
    return jax.tree.map(unwrap, params,
                        is_leaf=lambda x: isinstance(x, SharedWindow))


def _check_clean(window: SharedWindow) -> None:
    if window.dirty:
        raise ValueError(
            "refusing to serve from a dirty SharedWindow: a store "
            "opened an epoch that was never closed — fence() it first")


def materialize_params_on_mesh(params, cluster, *, scheme: str = "auto"):
    """The multi-chip companion of ``materialize_params``: read every
    node-window leaf back into a full private array by gathering its shards
    on the mesh that owns them.

    ``cluster`` is the ``repro.substrate.VirtualCluster`` (or any object
    with ``.run``/``.axis_names``) whose mesh matches each window's
    communicator; a leaf's global ``shard`` array must be the rank-major
    stack of per-rank window shards along ``leaf.axis`` (the layout a
    shard_map with the natural specs produces).  The gather dispatches
    through the window's OWN communicator with ``scheme="auto"`` — the
    tuning table (or the closed forms, on an unmeasured shape) picks the
    scheme, constrained to the replicated class so the engine always
    receives plain arrays.  Epoch integrity is enforced exactly as in the
    single-device path: dirty windows are rejected, never served stale.
    """
    from jax.sharding import PartitionSpec as P

    def unwrap(leaf):
        if not isinstance(leaf, SharedWindow):
            return leaf
        _check_clean(leaf)
        comm, axis = leaf.comm, leaf.axis
        if comm.chips == 1:
            return leaf.shard
        if comm.pods is None or comm.chips is None:
            raise ValueError(
                "materialize_params_on_mesh needs windows with static "
                "pods/chips counts (construct their Communicator via "
                "from_cluster/from_topology)")
        node = comm
        if comm.slow_axis is not None:
            # Multi-pod windows are pod-replicated: every pod holds the
            # same node copy, so the read is a node-tier gather through
            # the COMM_TYPE_SHARED split — never a bridge collective.
            node = comm.split_type_shared()

        def body(shard):
            return node.allgather(shard, scheme=scheme, axis=axis,
                                  result="replicated")

        spec = P(*((None,) * axis + (cluster.axis_names,)))
        return cluster.run(body, leaf.shard, in_specs=(spec,),
                           out_specs=P(None))
    return jax.tree.map(unwrap, params,
                        is_leaf=lambda x: isinstance(x, SharedWindow))


@dataclasses.dataclass
class GenResult:
    tokens: np.ndarray      # (B, max_new)
    logprobs: np.ndarray    # (B, max_new)


# (model id, s_max) -> (weakref(model), jitted prefill, jitted decode).
# Model trees hold dicts (unhashable), so the cache keys on identity with
# a weakref guard against id reuse after collection.
_JIT_CACHE: dict = {}


def compiled_serve_fns(model, s_max: int):
    """Jitted ``(prefill, decode)`` for ``model`` at context length
    ``s_max``, cached so repeated generate calls stop re-tracing."""
    key = (id(model), int(s_max))
    hit = _JIT_CACHE.get(key)
    if hit is not None and hit[0]() is model:
        return hit[1], hit[2]
    prefill = jax.jit(lambda p, b: model.prefill_fn(p, b, s_max))
    decode = jax.jit(model.decode_fn)
    ref = weakref.ref(model, lambda _, k=key: _JIT_CACHE.pop(k, None))
    _JIT_CACHE[key] = (ref, prefill, decode)
    return prefill, decode


def greedy_generate(model, params, prompts: np.ndarray, *, max_new: int,
                    s_max: Optional[int] = None, temperature: float = 0.0,
                    seed: int = 0) -> GenResult:
    """prompts: (B, T0) int32.  Single-device engine (ctx = single).
    ``params`` may carry ``SharedWindow`` leaves (hier-mode state) — they
    are materialized (or rejected, if unreadable here) up front."""
    params = materialize_params(params)
    B, T0 = prompts.shape
    s_max = s_max or (T0 + max_new)
    batch = {"tokens": jnp.asarray(
        np.concatenate([prompts, prompts[:, -1:]], axis=1))}
    prefill, decode = compiled_serve_fns(model, s_max)
    cache, logits = prefill(params, batch)

    key = jax.random.PRNGKey(seed)
    out_toks = np.zeros((B, max_new), np.int32)
    out_lp = np.zeros((B, max_new), np.float32)
    for i in range(max_new):
        lp = jax.nn.log_softmax(logits[:, -1].astype(jnp.float32), axis=-1)
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, lp / temperature, axis=-1)
        else:
            tok = jnp.argmax(lp, axis=-1)
        out_toks[:, i] = np.asarray(tok)
        out_lp[:, i] = np.asarray(
            jnp.take_along_axis(lp, tok[:, None], axis=-1)[:, 0])
        cache, logits = decode(params, cache, tok[:, None].astype(jnp.int32),
                               jnp.int32(T0 + i))
    return GenResult(tokens=out_toks, logprobs=out_lp)
