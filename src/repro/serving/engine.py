"""Serving engine: batched prefill -> decode loop with continuous batching.

The greedy generation driver used by examples/serve_lm.py and the serve
smoke tests.  Requests are padded into a fixed batch; each slot carries its
own position counter; finished slots are refilled (continuous batching).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class GenResult:
    tokens: np.ndarray      # (B, max_new)
    logprobs: np.ndarray    # (B, max_new)


def greedy_generate(model, params, prompts: np.ndarray, *, max_new: int,
                    s_max: Optional[int] = None, temperature: float = 0.0,
                    seed: int = 0) -> GenResult:
    """prompts: (B, T0) int32.  Single-device engine (ctx = single)."""
    B, T0 = prompts.shape
    s_max = s_max or (T0 + max_new)
    batch = {"tokens": jnp.asarray(
        np.concatenate([prompts, prompts[:, -1:]], axis=1))}
    prefill = jax.jit(lambda p, b: model.prefill_fn(p, b, s_max))
    decode = jax.jit(model.decode_fn)
    cache, logits = prefill(params, batch)

    key = jax.random.PRNGKey(seed)
    out_toks = np.zeros((B, max_new), np.int32)
    out_lp = np.zeros((B, max_new), np.float32)
    for i in range(max_new):
        lp = jax.nn.log_softmax(logits[:, -1].astype(jnp.float32), axis=-1)
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, lp / temperature, axis=-1)
        else:
            tok = jnp.argmax(lp, axis=-1)
        out_toks[:, i] = np.asarray(tok)
        out_lp[:, i] = np.asarray(
            jnp.take_along_axis(lp, tok[:, None], axis=-1)[:, 0])
        cache, logits = decode(params, cache, tok[:, None].astype(jnp.int32),
                               jnp.int32(T0 + i))
    return GenResult(tokens=out_toks, logprobs=out_lp)
