"""Decode-step collectives routed through a recorded ``CollectiveGraph``.

On a cluster with the ``serve_fsdp`` opt, serve weights stay in the pod's
one-copy-per-node ``SharedWindow`` store (the paper's C1 layout applied to
inference) and every decode step gathers them at use.  Issued eagerly,
each gather is its own collective; :class:`RecordedDecoder` instead
*records* them once per batch signature through ``Communicator.record()``,
runs the step-graph optimizer (same-epoch gather dedup, issue
front-loading behind one ordering token), and on later traces of the same
signature replays the cached :class:`~repro.comm.stepgraph.Schedule` via
``apply_schedule`` — the PR 7 passes applied to serving for free, with
bit-identical outputs.

Live re-tuning plugs in through :meth:`RecordedDecoder.set_table`: handing
it a fresh ``LiveTuner.overlay()`` re-optimizes subsequent signatures
under live latency estimates instead of the committed nightly table.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.comm.stepgraph import Deferred, ScheduleResult, apply_schedule
from repro.models.meta import PMeta

_IS_META = lambda x: isinstance(x, PMeta)  # noqa: E731


class RecordedDecoder:
    """A drop-in ``decode_fn`` whose window gathers go through the step
    graph.  Call signature matches ``model.decode_fn``; falls back to the
    eager decode when the ctx has no window store (single device, naive
    mode, or no fsdp axes)."""

    def __init__(self, model, *, table=None,
                 target_bytes: Optional[int] = None):
        self.model = model
        self._table = table
        self._target_bytes = target_bytes
        self._schedules: dict[tuple, object] = {}

    def set_table(self, table) -> None:
        """Install a new tuning table (e.g. a ``LiveTuner.overlay()``) and
        drop cached schedules so they re-optimize under it."""
        self._table = table
        self._schedules.clear()

    @property
    def schedules(self) -> dict:
        """Batch signature -> optimized ``Schedule`` (for inspection)."""
        return dict(self._schedules)

    @staticmethod
    def _signature(token, pos) -> tuple:
        return (tuple(token.shape), str(token.dtype), jnp.ndim(pos))

    def __call__(self, params, cache, token, pos, *, unroll: int = 1):
        model, ctx = self.model, self.model.ctx
        comm = ctx.comm
        if comm is None or ctx.mode != "hier" or not ctx.fsdp_axes:
            return model.decode_fn(params, cache, token, pos, unroll=unroll)
        from repro.models.transformer import _decode

        defs = model.serve_defs
        metas = jax.tree_util.tree_leaves_with_path(defs, is_leaf=_IS_META)
        leaves, treedef = jax.tree.flatten(params)
        rec = comm.record(table=self._table)
        refs = []
        for (path, m), w in zip(metas, leaves):
            if m.fsdp_dim is None:
                refs.append(w)
                continue
            # 'units' metas are per-layer; the leaf carries a stacked
            # leading dim, shifting the window axis by one.
            off = 1 if getattr(path[0], "key", None) == "units" else 0
            win = comm.window(w.astype(ctx.compute_dtype),
                              axis=m.fsdp_dim + off, epoch=1)
            refs.append(rec.gather(win, key=jax.tree_util.keystr(path)))

        sig = self._signature(token, pos)
        sched = self._schedules.get(sig)
        if sched is None:
            res = rec.run(target_bytes=self._target_bytes)
            self._schedules[sig] = res.schedule
        else:                             # replay: skip the optimizer
            values = apply_schedule(comm, sched, rec._values)
            res = ScheduleResult(values, sched)

        full = jax.tree.unflatten(
            treedef, [res[r] if isinstance(r, Deferred) else r for r in refs])
        # every window already read: the inner decode's gather_w is a cast
        inner = dataclasses.replace(ctx, fsdp_axes=())
        return _decode(model.cfg, inner, defs, full, cache, token, pos,
                       unroll=unroll)
