"""Request queue + admission control for the continuous-batching engine.

Requests enter FIFO through :meth:`RequestQueue.submit`, which applies
admission control (pending-depth backpressure, prompt-length limits) and
assigns request ids.  The scheduler drains the queue with
:meth:`RequestQueue.take_group`, which returns a *length-bucketed* group:
the head-of-line request picks the prefill bucket and a bounded lookahead
window is scanned for same-bucket requests, so one prefill trace serves
many prompt lengths without unbounded head-of-line reordering.

Bucketing modes:

* ``"pow2"``  — prompts are right-padded to the next power of two.  Safe
  for pure global-attention models: padded KV positions are never
  attendable before the decode loop has overwritten them (the causal
  ``gidx <= pos`` mask plus write-before-read induction).
* ``"exact"`` — requests are grouped by exact prefill length.  Required
  for models with recurrent or sliding-window blocks, where padded
  prefill steps would corrupt carried state / evict real window entries.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np


class AdmissionError(RuntimeError):
    """The queue refused a request (backpressure or a hard limit)."""


@dataclasses.dataclass
class Request:
    """One generation request: ``prompt`` is a (T0,) int32 token vector."""

    rid: int
    prompt: np.ndarray
    max_new: int
    arrival: float = 0.0


def bucket_len(n: int, mode: str = "pow2") -> int:
    """Prefill bucket for an ``n``-token prefill (``n = T0 - 1``)."""
    if mode == "exact" or n == 0:
        return n
    if mode == "pow2":
        return 1 << max(0, int(n - 1).bit_length())
    raise ValueError(f"unknown bucket mode: {mode!r}")


class RequestQueue:
    """Bounded FIFO with length-bucketed group draining."""

    def __init__(self, *, max_pending: int = 1024,
                 max_prompt_len: Optional[int] = None,
                 lookahead: int = 32):
        self.max_pending = max_pending
        self.max_prompt_len = max_prompt_len
        self.lookahead = lookahead
        self._q: deque[Request] = deque()
        self._next_rid = 0

    def __len__(self) -> int:
        return len(self._q)

    def submit(self, prompt, max_new: int, *, arrival: float = 0.0) -> int:
        prompt = np.asarray(prompt, np.int32)
        if prompt.ndim != 1 or prompt.size == 0:
            raise AdmissionError("prompt must be a non-empty 1-D int32 array")
        if self.max_prompt_len is not None \
                and prompt.size > self.max_prompt_len:
            raise AdmissionError(
                f"prompt of {prompt.size} tokens exceeds the admission "
                f"limit of {self.max_prompt_len}")
        if max_new < 1:
            raise AdmissionError("max_new must be >= 1")
        if len(self._q) >= self.max_pending:
            raise AdmissionError(
                f"queue full ({self.max_pending} pending) — backpressure")
        rid = self._next_rid
        self._next_rid += 1
        self._q.append(Request(rid=rid, prompt=prompt, max_new=max_new,
                               arrival=arrival))
        return rid

    def take_group(self, n: int, *, bucket: str = "pow2") -> list[Request]:
        """Pop up to ``n`` requests sharing the head-of-line request's
        prefill bucket, scanning at most ``lookahead`` queued requests."""
        if n < 1 or not self._q:
            return []
        head_bucket = bucket_len(self._q[0].prompt.size - 1, bucket)
        picked: list[Request] = []
        kept: list[Request] = []
        scanned = 0
        while self._q and scanned < self.lookahead and len(picked) < n:
            req = self._q.popleft()
            scanned += 1
            if bucket_len(req.prompt.size - 1, bucket) == head_bucket:
                picked.append(req)
            else:
                kept.append(req)
        for req in reversed(kept):
            self._q.appendleft(req)
        return picked
