"""KV-cache pages as node-``SharedWindow`` state with epoch fences.

The paper's claim is that replicated state should live ONCE per node in a
shared segment, with integrity guarded by synchronization epochs.  Training
already applies that to parameters; serving is where replicated KV state
dominates memory, so the decode cache gets the same treatment: every cache
leaf is held as a :class:`repro.comm.SharedWindow` on the node communicator
(one logical copy per node — the C1 invariant), and slot reuse is guarded
by store epochs — admitting a request *stores* into the pages (opening a
dirty epoch) and the scheduler may not read the cache again until it
fences.  A dirty read raises :class:`repro.comm.WindowEpochError` exactly
as it does for parameter windows.

Cache tree layout (``model.cache_init``): leaves under ``"units"`` carry a
leading ``n_units`` dim with the slot (batch) axis at position 1; leaves
under ``"rem"`` have the slot axis at position 0.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.comm import Communicator, SharedWindow

_IS_WIN = lambda x: isinstance(x, SharedWindow)  # noqa: E731


def _slot_axis(top_key: str) -> int:
    return 1 if top_key == "units" else 0


@dataclasses.dataclass(frozen=True)
class KVCachePages:
    """The decode cache held as per-leaf node windows.

    ``windows`` mirrors ``model.cache_init``'s tree with every array leaf
    wrapped in a ``SharedWindow`` on ``comm``.  All mutators return a new
    ``KVCachePages`` (the windows are frozen dataclasses)."""

    windows: dict
    comm: Communicator

    @classmethod
    def for_model(cls, model, slots: int, s_max: int,
                  comm: Optional[Communicator] = None) -> "KVCachePages":
        """Fresh pages for ``slots`` concurrent requests at context
        ``s_max``.  ``comm`` defaults to the degenerate one-rank node (the
        single-device engine); a wider node comm shards each leaf's slot
        axis across the node's chips."""
        comm = comm or Communicator(fast_axis="node", slow_axis=None,
                                    pods=1, chips=1)
        cache = model.cache_init(slots, s_max)
        windows = jax.tree.map(
            lambda a: SharedWindow(comm, a, axis=0, epoch=1), cache)
        return cls(windows=windows, comm=comm)

    # -- loads ---------------------------------------------------------------
    @property
    def cache(self):
        """The plain cache tree for the decode step.  Raises
        ``WindowEpochError`` while a store epoch is open (un-fenced admit
        or commit) — the paper's readers-wait-for-writers rule applied to
        inference state."""
        if (self.comm.chips or 1) != 1:
            raise ValueError(
                "multi-chip KV windows must be read on the mesh that owns "
                "them (window.read() inside the decode step)")

        def unwrap(w):
            w._check_clean()
            return w.shard
        return jax.tree.map(unwrap, self.windows, is_leaf=_IS_WIN)

    # -- stores (open an epoch) ----------------------------------------------
    def admit(self, idx, sub_cache) -> "KVCachePages":
        """Scatter ``sub_cache`` (a ``len(idx)``-slot cache tree, e.g. a
        prefill result) into pages ``idx``.  Opens a dirty store epoch:
        the slots are not readable until :meth:`fence`."""
        idx = jnp.asarray(idx, jnp.int32)
        new = {}
        for top, sub in self.windows.items():
            ax = _slot_axis(top)

            def put(w, b, ax=ax):
                a = w.shard
                scattered = (a.at[:, idx].set(b.astype(a.dtype)) if ax == 1
                             else a.at[idx].set(b.astype(a.dtype)))
                return w.store(scattered)
            new[top] = jax.tree.map(put, sub, sub_cache[top], is_leaf=_IS_WIN)
        return dataclasses.replace(self, windows=new)

    def commit(self, new_cache) -> "KVCachePages":
        """Store a decode step's updated cache tree into the pages (dirty
        until fenced)."""
        windows = jax.tree.map(lambda w, a: w.store(a), self.windows,
                               new_cache, is_leaf=_IS_WIN)
        return dataclasses.replace(self, windows=windows)

    # -- synchronization ------------------------------------------------------
    def fence(self) -> "KVCachePages":
        """Close the open store epoch.  On the degenerate one-rank node the
        barrier is vacuous, so the epoch bookkeeping advances host-side; a
        wider node comm must fence inside the jitted step
        (``SharedWindow.fence`` — a real node barrier)."""
        if (self.comm.chips or 1) != 1:
            raise NotImplementedError(
                "multi-chip pages fence on the mesh: map SharedWindow."
                "fence() over the windows inside the decode step")
        windows = jax.tree.map(
            lambda w: dataclasses.replace(w, dirty=False, epoch=w.epoch + 1),
            self.windows, is_leaf=_IS_WIN)
        return dataclasses.replace(self, windows=windows)

    # -- C1 accounting --------------------------------------------------------
    def logical_bytes(self) -> int:
        """Bytes of ONE logical cache copy."""
        chips = self.comm.chips or 1
        return sum(w.shard.nbytes * chips
                   for w in jax.tree.leaves(self.windows, is_leaf=_IS_WIN))

    def resident_node_bytes(self) -> int:
        """Physical bytes resident per node: the sum of every rank's window
        shard (each rank holds 1/chips of each buffer)."""
        chips = self.comm.chips or 1
        return sum(w.shard.nbytes * chips
                   for w in jax.tree.leaves(self.windows, is_leaf=_IS_WIN))

    def assert_c1(self) -> dict:
        """Assert the paper's C1 invariant for inference state: the node
        holds exactly ONE logical copy, not the ``chips``-way replication a
        per-rank cache would cost.  Returns the accounting."""
        chips = self.comm.chips or 1
        logical = self.logical_bytes()
        resident = self.resident_node_bytes()
        replicated = logical * chips
        if resident != logical:
            raise AssertionError(
                f"C1 violated for KV pages: {resident} bytes resident per "
                f"node vs {logical} for one copy")
        return {"logical_bytes": logical, "resident_node_bytes": resident,
                "replicated_baseline_bytes": replicated,
                "copies_per_node": resident / logical}
