"""Continuous / in-flight batching over heterogeneous sequence lengths.

The scheduler owns a fixed array of ``slots`` decode lanes.  Each step it

1. **refills** finished slots: drains a length-bucketed group from the
   :class:`repro.serving.queue.RequestQueue`, prefills the group's prompts
   in one padded batch, and *admits* the resulting per-request caches into
   the KV pages (a ``SharedWindow`` store epoch — the pages are unreadable
   until the fence closes it);
2. runs **one decode step over the whole batch** with a per-slot position
   vector (heterogeneous lengths decode together — no lane waits for its
   neighbours), commits + fences the updated cache;
3. **samples** the next token per active slot host-side and retires slots
   whose budget is spent.

Prefill admission protocol: prefill consumes ``prompt[:-1]``; a slot is
admitted with ``(next_token, pos) = (prompt[-1], T0 - 1)``, so its first
decode step re-feeds the last prompt token and produces the logits for the
first generated token.  Prompts are right-padded to the group's bucket on
pure global-attention models: a padded KV position is only attendable once
``pos`` has passed it, by which point the decode loop has overwritten it
(write-before-read induction) — recurrent / sliding-window models use
exact-length buckets instead, because padded prefill steps would corrupt
carried state.

Sampling is keyed per request (``fold_in(seed, rid)``) and per token
index, never per slot or per step — the token stream of a request is
independent of which slot it lands in and of its batch neighbours.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.engine import GenResult, materialize_params
from repro.serving.kv_cache import KVCachePages
from repro.serving.queue import Request, RequestQueue, bucket_len

DecodeFn = Callable[..., tuple]


def _bucket_mode(cfg) -> str:
    kinds = set(cfg.pattern) | set(cfg.remainder_kinds)
    return "pow2" if kinds <= {"attn"} and cfg.window is None else "exact"


@dataclasses.dataclass
class StepStats:
    """Telemetry for one scheduler step."""

    decode_us: float
    active: int
    admitted: int
    finished: int


class ContinuousBatchingScheduler:
    """Fixed-slot continuous batching engine (single-device decode).

    ``decode_fn`` defaults to a jitted ``model.decode_fn``; pass a
    :class:`repro.serving.recorded.RecordedDecoder`-style callable to
    route the decode step's collectives through a recorded
    ``CollectiveGraph``.  ``tuner`` (a
    :class:`repro.serving.live_tuning.LiveTuner`) receives per-step
    latencies keyed by the decode batch signature.
    """

    def __init__(self, model, params, *, slots: int, s_max: int,
                 temperature: float = 0.0, seed: int = 0,
                 queue: Optional[RequestQueue] = None,
                 decode_fn: Optional[DecodeFn] = None,
                 tuner=None):
        if slots < 1:
            raise ValueError("need at least one slot")
        self.model = model
        self.params = materialize_params(params)
        self.slots = slots
        self.s_max = s_max
        self.temperature = temperature
        self.seed = seed
        self.queue = queue if queue is not None else RequestQueue()
        self.tuner = tuner
        self.bucket_mode = _bucket_mode(model.cfg)
        self.pages = KVCachePages.for_model(model, slots, s_max)
        self._decode = decode_fn if decode_fn is not None \
            else jax.jit(model.decode_fn)
        self._prefills: dict[tuple[int, int], Callable] = {}
        # live-tuning feed: decode-step latencies land in the same
        # (family="serving", topo, dtype, size-bucket) cells the nightly
        # bench sweep measures — nbytes is the model's global parameter
        # byte count (the serving family's case-sizing convention), the
        # scheme label whichever decode path this engine runs.
        comm = model.ctx.comm
        self._tuner_key = dict(
            pods=(comm.pods if comm is not None and comm.pods else 1),
            chips=(comm.chips if comm is not None and comm.chips else 1),
            nbytes=4 * sum(
                int(np.prod(leaf.shape)) for leaf in
                jax.tree.leaves(jax.eval_shape(model.init_params))),
            scheme=("recorded" if hasattr(self._decode, "set_table")
                    else "sync"))

        # host-side slot map
        self.active = np.zeros(slots, bool)
        self.pos = np.zeros(slots, np.int32)
        self.next_tok = np.zeros(slots, np.int32)
        self.remaining = np.zeros(slots, np.int32)
        self.rid = np.full(slots, -1, np.int64)
        self.emitted = np.zeros(slots, np.int32)
        self._bufs: dict[int, tuple[list, list]] = {}   # rid -> (toks, lps)
        self.results: dict[int, GenResult] = {}
        self.stats: list[StepStats] = []

    # -- admission -----------------------------------------------------------
    def _prefill_fn(self, n: int, tb: int) -> Callable:
        key = (n, tb)
        fn = self._prefills.get(key)
        if fn is None:
            fn = jax.jit(lambda p, b: self.model.prefill_fn(p, b, self.s_max))
            self._prefills[key] = fn
        return fn

    def _admit(self, group: list[Request]) -> None:
        n = len(group)
        tb = bucket_len(group[0].prompt.size - 1, self.bucket_mode)
        if tb > 0:
            toks = np.zeros((n, tb + 1), np.int32)
            for i, req in enumerate(group):
                toks[i, :req.prompt.size - 1] = req.prompt[:-1]
            sub_cache, _ = self._prefill_fn(n, tb)(
                self.params, {"tokens": jnp.asarray(toks)})
        else:
            sub_cache = self.model.cache_init(n, self.s_max)
        idx = np.flatnonzero(~self.active)[:n]
        self.pages = self.pages.admit(idx, sub_cache).fence()
        for slot, req in zip(idx, group):
            self.active[slot] = True
            self.pos[slot] = req.prompt.size - 1
            self.next_tok[slot] = req.prompt[-1]
            self.remaining[slot] = req.max_new
            self.rid[slot] = req.rid
            self.emitted[slot] = 0
            self._bufs[req.rid] = ([], [])

    # -- sampling ------------------------------------------------------------
    def _sample(self, lp_row: np.ndarray, rid: int, tok_idx: int) -> int:
        if self.temperature <= 0:
            return int(np.argmax(lp_row))
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), rid), tok_idx)
        return int(jax.random.categorical(
            key, jnp.asarray(lp_row) / self.temperature))

    # -- the step ------------------------------------------------------------
    def step(self) -> bool:
        """One scheduler iteration.  Returns False when fully idle."""
        admitted = 0
        free = int(np.sum(~self.active))
        if free and len(self.queue):
            group = self.queue.take_group(free, bucket=self.bucket_mode)
            if group:
                self._admit(group)
                admitted = len(group)
        if not self.active.any():
            return False

        cache = self.pages.cache
        tok = jnp.asarray(self.next_tok[:, None])
        posv = jnp.asarray(self.pos)
        t0 = time.perf_counter()
        new_cache, logits = self._decode(self.params, cache, tok, posv)
        lp = jax.nn.log_softmax(logits[:, -1].astype(jnp.float32), axis=-1)
        lp = np.asarray(lp)
        decode_us = (time.perf_counter() - t0) * 1e6
        if self.tuner is not None:
            self.tuner.observe("serving", us=decode_us, **self._tuner_key)
        self.pages = self.pages.commit(new_cache).fence()

        finished = 0
        for slot in np.flatnonzero(self.active):
            rid = int(self.rid[slot])
            tok_i = self._sample(lp[slot], rid, int(self.emitted[slot]))
            toks, lps = self._bufs[rid]
            toks.append(tok_i)
            lps.append(float(lp[slot, tok_i]))
            self.next_tok[slot] = tok_i
            self.pos[slot] += 1
            self.emitted[slot] += 1
            self.remaining[slot] -= 1
            if self.remaining[slot] <= 0 or self.pos[slot] >= self.s_max:
                self.results[rid] = GenResult(
                    tokens=np.asarray([toks], np.int32),
                    logprobs=np.asarray([lps], np.float32))
                del self._bufs[rid]
                self.active[slot] = False
                self.rid[slot] = -1
                finished += 1

        self.stats.append(StepStats(decode_us=decode_us,
                                    active=int(self.active.sum()),
                                    admitted=admitted, finished=finished))
        return True

    def run(self, *, max_steps: Optional[int] = None) -> dict[int, GenResult]:
        """Drive steps until queue + slots drain (or ``max_steps``)."""
        steps = 0
        while max_steps is None or steps < max_steps:
            busy = self.step()
            steps += 1
            if not busy and not len(self.queue):
                break
        return self.results


def generate(model, params, prompts, *, max_new: int, slots: int = 4,
             s_max: Optional[int] = None, temperature: float = 0.0,
             seed: int = 0, decode_fn: Optional[DecodeFn] = None
             ) -> GenResult:
    """Batch-generate via the continuous-batching scheduler.

    ``prompts`` is a list of 1-D int32 arrays (heterogeneous lengths are
    fine).  Returns tokens/logprobs stacked in request order — drop-in for
    ``greedy_generate`` on same-length prompts."""
    prompts = [np.asarray(p, np.int32) for p in prompts]
    s_max = s_max or (max(p.size for p in prompts) + max_new)
    sched = ContinuousBatchingScheduler(
        model, params, slots=min(slots, len(prompts)), s_max=s_max,
        temperature=temperature, seed=seed, decode_fn=decode_fn)
    rids = [sched.queue.submit(p, max_new) for p in prompts]
    results = sched.run()
    return GenResult(
        tokens=np.concatenate([results[r].tokens for r in rids], axis=0),
        logprobs=np.concatenate([results[r].logprobs for r in rids], axis=0))
