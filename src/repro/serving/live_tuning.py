"""Session-local ``TuningTable`` overlay fed by live collective latencies.

The committed ``TUNING_default.json`` comes from the nightly virtual-cluster
sweep; a serving session sees *real* traffic — different message sizes,
different contention — and its scheme winners can drift from the sweep's.
:class:`LiveTuner` closes that loop without touching the committed table:

* every observed collective latency updates a decaying (EWMA) per-cell
  estimator, keyed exactly like the table — ``(family, topology signature,
  dtype, size bucket, scheme)``;
* :meth:`LiveTuner.overlay` folds the estimates over a base table into a
  fresh in-memory ``TuningTable``: cells with live data get re-ranked by
  the live medians (base medians fill schemes not yet observed), cells
  without keep the base ranking, and cells the base never measured are
  synthesized from live data alone;
* the overlay is installed session-locally via ``tuning.use_table`` (or
  passed to ``Communicator.record(table=...)``), so ``scheme="auto"`` —
  and the step-graph optimizer's bucket sizing — track real traffic while
  the committed artifact stays untouched.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.comm import tuning
from repro.comm.tuning import Choice, TuningEntry, TuningTable
from repro.core.plans import size_bucket


@dataclasses.dataclass
class _Cell:
    """Live estimates for one (family, topo, dtype, bucket) cell."""

    us: dict          # scheme -> EWMA latency (microseconds)
    count: dict       # scheme -> observation count
    nbytes: int       # representative per-rank payload
    label: str = ""


class LiveTuner:
    """Decaying per-collective latency estimator + table overlay.

    ``alpha`` is the EWMA weight of a new observation; ``min_count`` is how
    many observations a (cell, scheme) needs before its estimate is
    trusted into the overlay — a single outlier must not flip a winner.
    """

    def __init__(self, base: Optional[TuningTable] = None, *,
                 alpha: float = 0.25, min_count: int = 1):
        if not 0.0 < alpha <= 1.0:
            raise ValueError("alpha must be in (0, 1]")
        self._base = base
        self.alpha = alpha
        self.min_count = min_count
        self._cells: dict[tuple, _Cell] = {}

    @property
    def base(self) -> TuningTable:
        return self._base if self._base is not None else tuning.default_table()

    # -- feeding -------------------------------------------------------------
    def observe(self, family: str, *, pods: int, chips: int, nbytes: int,
                scheme: str, us: float, dtype: str = "float32",
                n_fast_axes: int = 1, label: str = "") -> None:
        """Record one live latency sample for a collective call."""
        if us <= 0:
            raise ValueError("latency must be positive")
        topo = tuning.topo_signature(pods, chips, n_fast_axes)
        key = (family, topo, dtype, size_bucket(int(nbytes)))
        cell = self._cells.get(key)
        if cell is None:
            cell = self._cells[key] = _Cell(us={}, count={},
                                            nbytes=int(nbytes), label=label)
        prev = cell.us.get(scheme)
        cell.us[scheme] = us if prev is None \
            else (1 - self.alpha) * prev + self.alpha * us
        cell.count[scheme] = cell.count.get(scheme, 0) + 1
        if label:
            cell.label = label

    def observe_comm(self, comm, family: str, *, nbytes: int, scheme: str,
                     us: float, dtype: str = "float32") -> None:
        """``observe`` keyed by a ``Communicator``'s static topology."""
        if comm.pods is None or comm.chips is None:
            raise ValueError("live tuning needs a Communicator with static "
                             "pods/chips counts")
        fast = comm.fast_axis
        n_fast = len(fast) if isinstance(fast, tuple) else 1
        self.observe(family, pods=comm.pods, chips=comm.chips, nbytes=nbytes,
                     scheme=scheme, us=us, dtype=dtype, n_fast_axes=n_fast)

    def estimate(self, family: str, topo: str, dtype: str, nbytes: int,
                 scheme: str) -> Optional[float]:
        cell = self._cells.get((family, topo, dtype, size_bucket(int(nbytes))))
        if cell is None or cell.count.get(scheme, 0) < self.min_count:
            return None
        return cell.us[scheme]

    # -- the overlay ---------------------------------------------------------
    def overlay(self) -> TuningTable:
        """The base table with live estimates folded in (in-memory only)."""
        base = self.base
        live_left = dict(self._cells)
        entries = []
        for e in base.entries:
            key = (e.family, e.topo, e.dtype, e.bucket)
            cell = live_left.pop(key, None)
            if cell is None:
                entries.append(e)
                continue
            medians = {c.scheme: (c.median_us, dict(c.opts))
                       for c in e.ranking}
            for scheme, us in cell.us.items():
                if cell.count.get(scheme, 0) < self.min_count:
                    continue
                _, opts = medians.get(scheme, (None, {}))
                medians[scheme] = (us, opts)
            ranking = tuple(sorted(
                (Choice(scheme=s, opts=opts, median_us=us)
                 for s, (us, opts) in medians.items() if us is not None),
                key=lambda c: (c.median_us, c.scheme)))
            entries.append(dataclasses.replace(
                e, ranking=ranking or e.ranking,
                label=e.label or cell.label))
        # cells the base never measured: synthesize from live data alone
        for (family, topo, dtype, _), cell in sorted(live_left.items()):
            ranking = tuple(sorted(
                (Choice(scheme=s, median_us=us)
                 for s, us in cell.us.items()
                 if cell.count.get(s, 0) >= self.min_count),
                key=lambda c: (c.median_us, c.scheme)))
            if not ranking:
                continue
            entries.append(TuningEntry(
                family=family, topo=topo, dtype=dtype, nbytes=cell.nbytes,
                source="measured", ranking=ranking,
                label=cell.label or "live"))
        meta = dict(base.meta)
        meta["live_overlay"] = {
            "cells": len(self._cells), "alpha": self.alpha,
            "min_count": self.min_count}
        return TuningTable(entries=tuple(entries), meta=meta)

    def use(self):
        """``with tuner.use():`` — install the overlay session-locally."""
        return tuning.use_table(self.overlay())
