"""Virtual-cluster substrate: jax version portability + in-process
multi-topology testing.

* ``repro.substrate.compat``  — version-portable ``shard_map`` /
  ``make_mesh`` / axis-type shims (jax 0.4.x–0.7.x).  Import jax mesh and
  shard_map APIs from here, never from jax directly.
* ``repro.substrate.cluster`` — ``VirtualCluster``: builds the two-tier
  (pods x chips) mesh and wraps collective bodies so one check sweeps a
  whole topology matrix in-process.  Call ``ensure_host_device_count(n)``
  before jax initializes its backends (the test suite does this in
  ``tests/conftest.py``) to provide the fake host CPU devices.
"""

from repro.substrate import compat
from repro.substrate.cluster import (VirtualCluster, default_matrix,
                                     ensure_host_device_count)
from repro.substrate.compat import auto_axis_types, make_mesh, shard_map

__all__ = [
    "compat",
    "VirtualCluster",
    "default_matrix",
    "ensure_host_device_count",
    "auto_axis_types",
    "make_mesh",
    "shard_map",
]
