"""VirtualCluster: in-process multi-topology harness for the collectives.

The paper's two-tier cluster (shared-memory nodes joined by a network) maps
onto forced host CPU devices:

* slow tier — ``pods`` (the network / MPI bridge communicator);
* fast tier — ``chips`` per pod (the shared-memory node).

A ``VirtualCluster`` builds the two-tier device mesh for one (pods, chips)
shape and wraps collective *bodies* (functions of local shards, as in
``repro.comm.primitives``) with ``shard_map``, so the same equivalence
check runs unchanged over a whole topology matrix — single-node, one chip
per pod, square, and tuple-axis meshes — instead of only the one shape a
subprocess script happened to hard-code.

Axis handling mirrors ``collectives._axes``: ``fast_axis`` / ``slow_axis``
may each be one name or a tuple of names (with per-name sizes given by
``fast_shape`` / ``slow_shape``).  A single-pod cluster drops the slow tier
entirely (``slow is None``), exercising the collectives' single-node code
paths rather than hiding them behind a size-1 axis.
"""

from __future__ import annotations

import dataclasses
import functools
import math
import os
from typing import Optional, Sequence, Union

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.substrate import compat

Axis = Union[str, Sequence[str]]


def ensure_host_device_count(n: int = 8) -> None:
    """Force >= ``n`` fake host CPU devices for this process.

    Must run before jax initializes its backends (i.e. before the first
    ``jax.devices()`` / array op anywhere in the process) — the flag is a
    no-op afterwards.  Respects an already-present force flag so callers
    (CI, a parent test runner) can pin their own count.
    """
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        return
    os.environ["XLA_FLAGS"] = \
        (f"--xla_force_host_platform_device_count={n} " + flags).strip()


def _names(ax: Optional[Axis]) -> tuple[str, ...]:
    if ax is None:
        return ()
    return (ax,) if isinstance(ax, str) else tuple(ax)


@functools.lru_cache(maxsize=None)
def _cached_mesh(axis_shapes: tuple[int, ...], axis_names: tuple[str, ...]):
    return compat.make_mesh(axis_shapes, axis_names)


@dataclasses.dataclass(frozen=True)
class VirtualCluster:
    """One point of the topology matrix: ``pods`` nodes x ``chips`` per node.

    ``fast_axis``/``slow_axis`` name the mesh axes of each tier; a tuple of
    names splits that tier over several mesh axes whose sizes are given by
    ``fast_shape``/``slow_shape`` (products must equal ``chips``/``pods``).
    """

    pods: int = 2
    chips: int = 4
    fast_axis: Axis = "data"
    slow_axis: Optional[Axis] = "pod"
    fast_shape: Optional[tuple[int, ...]] = None
    slow_shape: Optional[tuple[int, ...]] = None

    def __post_init__(self):
        if self.pods < 1 or self.chips < 1:
            raise ValueError(f"bad shape {self.pods}x{self.chips}")
        fast = _names(self.fast_axis)
        slow = _names(self.slow_axis)
        if not fast:
            raise ValueError("fast_axis is required")
        if self.pods > 1 and not slow:
            raise ValueError("multi-pod cluster needs a slow_axis")
        fshape = self.fast_shape if self.fast_shape is not None \
            else (self.chips,)
        sshape = self.slow_shape if self.slow_shape is not None \
            else (self.pods,)
        if len(fshape) != len(fast) or math.prod(fshape) != self.chips:
            raise ValueError(f"fast_shape {fshape} does not factor "
                             f"chips={self.chips} over axes {fast}")
        if len(sshape) != len(slow) or math.prod(sshape) != self.pods:
            raise ValueError(f"slow_shape {sshape} does not factor "
                             f"pods={self.pods} over axes {slow}")
        if set(fast) & set(slow):
            raise ValueError("fast and slow axis names must be disjoint")
        object.__setattr__(self, "fast_axis", fast if len(fast) > 1
                           else fast[0])
        object.__setattr__(self, "slow_axis", (slow if len(slow) > 1
                                               else slow[0]) if slow else None)
        object.__setattr__(self, "fast_shape", tuple(fshape))
        object.__setattr__(self, "slow_shape", tuple(sshape))

    # -- shape ---------------------------------------------------------------
    @property
    def num_devices(self) -> int:
        return self.pods * self.chips

    @property
    def fast(self) -> Axis:
        """Fast-tier axis arg for the collectives (name or tuple of names)."""
        return self.fast_axis

    @property
    def slow(self) -> Optional[Axis]:
        """Slow-tier axis arg; ``None`` on a single node (pods == 1)."""
        return self.slow_axis if self.pods > 1 else None

    @property
    def fast_names(self) -> tuple[str, ...]:
        return _names(self.fast_axis)

    @property
    def slow_names(self) -> tuple[str, ...]:
        return _names(self.slow) if self.pods > 1 else ()

    @property
    def axis_names(self) -> tuple[str, ...]:
        """Mesh axis order: slow (outer) then fast (inner) — rank order is
        (pod, chip), the SMP placement of the paper."""
        return self.slow_names + self.fast_names

    @property
    def axis_shapes(self) -> tuple[int, ...]:
        return (self.slow_shape if self.pods > 1 else ()) + self.fast_shape

    @property
    def label(self) -> str:
        """Stable test id, e.g. ``2x4``, ``1x8``, ``2x(2x2)-pod.dp.tp``."""
        def side(shape, names):
            s = "x".join(str(d) for d in shape) if len(shape) > 1 else \
                str(shape[0])
            return f"({s})" if len(shape) > 1 else s
        base = f"{side(self.slow_shape, self.slow_names)}" \
               f"x{side(self.fast_shape, self.fast_names)}"
        if len(self.fast_names) > 1 or len(self.slow_names) > 1:
            base += "-" + ".".join(self.axis_names)
        return base

    # -- elastic shrink / grow ----------------------------------------------
    def with_pods(self, pods: int) -> "VirtualCluster":
        """This cluster re-shaped to ``pods`` nodes (same chips per node).

        The paper's two-tier layout makes failure node-granular: losing a
        host removes ONE pod (one bridge participant, one shared window),
        never an arbitrary slice of ranks — so elastic resize is a change
        of the slow-tier extent only.  A factored slow tier has no single
        extent to rewrite and is rejected."""
        if pods < 1:
            raise ValueError(f"cannot shrink below one node (pods={pods})")
        if len(self.slow_names) > 1:
            raise ValueError(
                f"cannot resize a factored slow tier {self.slow_names}: "
                "no single pod extent to rewrite")
        if pods > 1 and not self.slow_names:
            raise ValueError("single-node cluster has no slow axis to grow "
                             "over — build a multi-pod VirtualCluster")
        return dataclasses.replace(
            self, pods=pods,
            slow_shape=(pods,) if self.slow_names else None)

    def without_pod(self, pod: int = -1) -> "VirtualCluster":
        """The surviving cluster after losing one node.  ``pod`` is the
        index of the lost node (identity only matters to the caller's
        bookkeeping: survivors renumber densely, exactly like ranks after
        ``MPI_Comm_split`` drops the failed members)."""
        if self.pods == 1:
            raise ValueError("cannot lose the last node: no survivors to "
                             "rebuild a cluster from")
        if not -self.pods <= pod < self.pods:
            raise ValueError(f"pod {pod} out of range for {self.pods} nodes")
        return self.with_pods(self.pods - 1)

    # -- device state --------------------------------------------------------
    def available(self) -> bool:
        return jax.device_count() >= self.num_devices

    @property
    def mesh(self):
        return _cached_mesh(self.axis_shapes, self.axis_names)

    @property
    def spec(self) -> P:
        """Rank-sharded spec: dim 0 split over every mesh axis, (pod, chip)
        rank-major — the layout of one contribution per global rank."""
        return P(self.axis_names)

    def smap(self, body, in_specs, out_specs):
        """Wrap a local-shard body over this cluster's mesh (replication
        checking off: the hier/shared bodies are deliberately 'unsound' in
        the checker's eyes — they build replicated values from psums)."""
        return compat.shard_map(body, mesh=self.mesh, in_specs=in_specs,
                                out_specs=out_specs, check_vma=False)

    def run(self, body, *args, in_specs=None, out_specs=None):
        """One-shot: shard rank-major inputs, run the body, return outputs."""
        if in_specs is None:
            in_specs = (self.spec,) * len(args)
        if out_specs is None:
            out_specs = self.spec
        return self.smap(body, in_specs, out_specs)(*args)

    # -- data helpers --------------------------------------------------------
    def rank_major_input(self, m: int = 6, extra: int = 3, seed: int = 0):
        """(pods*chips*m, extra) float32 array, ``m`` rows per global rank."""
        import jax.numpy as jnp
        rng = np.random.default_rng(seed)
        return jnp.asarray(rng.normal(
            size=(self.num_devices * m, extra)).astype(np.float32))


def default_matrix(max_devices: int = 8) -> tuple[VirtualCluster, ...]:
    """The standard topology matrix swept by the test suite.

    Covers: single node (no bridge at all), the seed 2x4 shape, its
    transpose, one-chip-per-pod (bridge only — the paper's worst case), and
    a tuple-axis mesh where the fast tier spans two named axes (the
    production (dp, tp) layout).
    """
    matrix = (
        VirtualCluster(pods=1, chips=8),
        VirtualCluster(pods=2, chips=4),
        VirtualCluster(pods=4, chips=2),
        VirtualCluster(pods=8, chips=1),
        VirtualCluster(pods=2, chips=4, fast_axis=("dp", "tp"),
                       fast_shape=(2, 2), slow_axis="pod"),
    )
    return tuple(vc for vc in matrix if vc.num_devices <= max_devices)
