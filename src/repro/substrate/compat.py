"""Version-portable jax API shims (jax 0.4.x through 0.7.x).

jax moved or renamed every API the collectives stack depends on:

==================  ==============================  =============================
API                 jax 0.4.x                       jax >= 0.6 / 0.7
==================  ==============================  =============================
shard_map           jax.experimental.shard_map      jax.shard_map
replication check   ``check_rep=`` kwarg            ``check_vma=`` kwarg
make_mesh           no ``axis_types`` kwarg         ``axis_types`` kwarg
axis types          absent                          jax.sharding.AxisType
==================  ==============================  =============================

Everything else in the repo imports these names from here instead of from
jax directly, so a version bump is a change to this one module.  The shims
are resolved once at import by *introspection* (signature probing), not by
version comparison — point releases that backport a kwarg keep working.
"""

from __future__ import annotations

import inspect
import math
from typing import Any, Optional, Sequence

import jax


# ---------------------------------------------------------------------------
# shard_map: location + replication-check kwarg name
# ---------------------------------------------------------------------------

if hasattr(jax, "shard_map"):
    _shard_map_impl = jax.shard_map
else:  # 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_SHARD_MAP_KWARGS = frozenset(inspect.signature(_shard_map_impl).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: Optional[bool] = None,
              check_rep: Optional[bool] = None, **kwargs):
    """``jax.shard_map`` under every supported jax.

    ``check_vma`` (new name) and ``check_rep`` (old name) are aliases; pass
    either and it is forwarded under whatever kwarg the installed jax accepts.
    ``None`` leaves the installed default in place.
    """
    if check_vma is None:
        check_vma = check_rep
    kw = dict(kwargs)
    if check_vma is not None:
        if "check_vma" in _SHARD_MAP_KWARGS:
            kw["check_vma"] = check_vma
        elif "check_rep" in _SHARD_MAP_KWARGS:
            kw["check_rep"] = check_vma
        # else: the installed jax dropped the knob entirely — nothing to do.
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **kw)


# ---------------------------------------------------------------------------
# axis_size: absent from jax.lax on 0.4.x
# ---------------------------------------------------------------------------

from jax import lax as _lax


def axis_size(name):
    """``lax.axis_size`` under every supported jax.

    On 0.4.x (no ``lax.axis_size``) a psum of the constant 1 over the named
    axis folds to the static axis size.
    """
    if hasattr(_lax, "axis_size"):
        return _lax.axis_size(name)
    return _lax.psum(1, name)


# ---------------------------------------------------------------------------
# Mesh construction: axis_types portability
# ---------------------------------------------------------------------------

AxisType = getattr(jax.sharding, "AxisType", None)

_MAKE_MESH_KWARGS = (frozenset(inspect.signature(jax.make_mesh).parameters)
                     if hasattr(jax, "make_mesh") else frozenset())


def auto_axis_types(n: int):
    """``(AxisType.Auto,) * n`` where axis types exist, else ``None``."""
    if AxisType is None:
        return None
    return (AxisType.Auto,) * n


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices: Optional[Sequence[Any]] = None, axis_types=None):
    """``jax.make_mesh`` that tolerates the ``axis_types`` kwarg appearing,
    disappearing, or being mandatory-by-style across jax versions.  Falls
    back to a hand-built ``jax.sharding.Mesh`` on jax without ``make_mesh``.
    """
    axis_shapes = tuple(axis_shapes)
    axis_names = tuple(axis_names)
    supports_types = "axis_types" in _MAKE_MESH_KWARGS
    if axis_types is not None and not supports_types:
        raise ValueError(
            f"axis_types={axis_types!r} requested, but the installed jax "
            f"{jax.__version__} has no axis-type support — drop the argument "
            "(the default matches old-jax behavior) or upgrade jax")
    if hasattr(jax, "make_mesh"):
        kw: dict[str, Any] = {}
        if devices is not None:
            kw["devices"] = devices
        if supports_types:
            types = axis_types if axis_types is not None \
                else auto_axis_types(len(axis_names))
            if types is not None:
                kw["axis_types"] = types
        return jax.make_mesh(axis_shapes, axis_names, **kw)
    import numpy as np
    n = math.prod(axis_shapes)
    devs = devices if devices is not None else jax.devices()[:n]
    if len(devs) < n:
        raise ValueError(f"mesh {dict(zip(axis_names, axis_shapes))} needs "
                         f"{n} devices, got {len(devs)}")
    return jax.sharding.Mesh(np.asarray(devs).reshape(axis_shapes), axis_names)
