"""CLI: ``python -m repro.bench [--quick] [--out BENCH_collectives.json]``.

Runs the matrix-driven collective sweep in-process on forced host CPU
devices, cross-checks every measured config against the plans.py traffic
model (any mismatch exits non-zero) and writes the schema-versioned JSON
artifact.  ``--csv`` additionally prints the legacy
``name,us_per_call,derived`` rows so ``benchmarks/run.py`` can consume the
output unchanged.

``--emit-tuning-table`` instead FOLDS an existing report (``--bench``,
default the committed ``BENCH_collectives.json``) into the scheme-selection
table ``scheme="auto"`` dispatches through (``--table-out``, default
``TUNING_default.json``) — no re-measurement.  The fold is self-checked:
every emitted winner must hold the best pooled median of the very report it
came from (``repro.bench.validate.tuning_table_checks``), so a broken fold
can never reach dispatch.

Device forcing happens HERE, before the jax backend initializes — which is
why the heavy imports live inside ``main``.
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def _emit_tuning_table(bench_path: str, table_out: str) -> int:
    from repro.bench.validate import tuning_table_checks
    from repro.comm.tuning import TuningTable

    with open(bench_path) as f:
        rep = json.load(f)
    table = TuningTable.from_bench_report(rep, source_name=bench_path)
    bad = [ch for ch in tuning_table_checks(table, rep) if not ch.ok]
    if bad:
        print(f"repro.bench: tuning-table fold FAILED {len(bad)} winner "
              "cross-check(s) against its own report:", file=sys.stderr)
        for ch in bad:
            print(f"  {ch.name}: expected {ch.expected}, measured "
                  f"{ch.measured} ({ch.note})", file=sys.stderr)
        return 1
    table.save(table_out)
    measured = sum(1 for e in table.entries if e.source == "measured")
    print(f"repro.bench: wrote {table_out} ({measured} measured entries "
          f"over {len(table.signatures())} topology signatures, folded "
          f"from {bench_path})", file=sys.stderr)
    return 0


def _force_devices(n: int | None) -> None:
    """``--devices N`` overrides any inherited force flag (XLA honors the
    last duplicate); the default defers to an already-present flag (CI
    pins its own count)."""
    if n is None:
        from repro.substrate import ensure_host_device_count
        ensure_host_device_count(8)
    else:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={n}").strip()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.bench",
        description="matrix-driven collective benchmarks with "
                    "traffic-model cross-checks")
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweep: one message size, 5 reps")
    ap.add_argument("--out", default="BENCH_collectives.json",
                    help="JSON artifact path (default %(default)s)")
    ap.add_argument("--csv", action="store_true",
                    help="also print name,us_per_call,derived rows "
                         "(no header: benchmarks/run.py prints its own)")
    ap.add_argument("--devices", type=int, default=None,
                    help="force this many host devices (default: respect "
                         "XLA_FLAGS, else 8)")
    ap.add_argument("--max-devices", type=int, default=8,
                    help="cap the topology matrix (default %(default)s)")
    ap.add_argument("--families", default=None,
                    help="comma list: allgather,broadcast,psum,"
                         "reduce_scatter,allgatherv,alltoall,"
                         "step_time,serving")
    ap.add_argument("--schemes", default=None,
                    help="comma list of registry scheme names (fast "
                         "autotune iteration, e.g. pipelined,hier)")
    ap.add_argument("--elems", default=None,
                    help="comma list of message sizes in elems, overriding "
                         "the quick/full defaults (e.g. 1024,65536)")
    ap.add_argument("--dtypes", default="float32",
                    help="comma list of payload dtypes; non-float32 "
                         "entries sweep only the wire-format-sensitive "
                         "families (allgather, psum) so the tuning table "
                         "can discriminate by dtype "
                         "(e.g. float32,bfloat16; default %(default)s)")
    ap.add_argument("--reps", type=int, default=None,
                    help="timed reps per case (default 30, quick 5)")
    ap.add_argument("--min-rep-s", type=float, default=0.0,
                    help="calibrate an inner loop so every timed rep lasts "
                         "at least this many seconds (smooths per-call "
                         "scheduling jitter on noisy hosts)")
    ap.add_argument("--no-validate", action="store_true",
                    help="skip the traffic-model cross-checks (timing "
                         "only; the JSON then carries no checks)")
    ap.add_argument("--emit-tuning-table", action="store_true",
                    help="fold an existing report (--bench) into the "
                         "scheme='auto' tuning table (--table-out) and "
                         "exit — runs no sweep")
    ap.add_argument("--bench", default="BENCH_collectives.json",
                    help="input report for --emit-tuning-table "
                         "(default %(default)s)")
    ap.add_argument("--table-out", default="TUNING_default.json",
                    help="tuning-table path for --emit-tuning-table "
                         "(default %(default)s)")
    args = ap.parse_args(argv)

    if args.emit_tuning_table:
        return _emit_tuning_table(args.bench, args.table_out)

    _force_devices(args.devices)

    # jax backends initialize on first device query — after the flag above.
    from repro.bench import report, suites
    from repro.bench.validate import BenchValidationError

    families = tuple(args.families.split(",")) if args.families \
        else suites.FAMILIES
    schemes = tuple(args.schemes.split(",")) if args.schemes else None
    if args.elems:
        elems = tuple(int(e) for e in args.elems.split(","))
    else:
        elems = suites.QUICK_ELEMS if args.quick else suites.FULL_ELEMS
    reps = args.reps if args.reps is not None else (5 if args.quick else 30)
    dtypes = tuple(args.dtypes.split(","))

    cases = suites.build_cases(
        families=families, elems=elems, max_devices=args.max_devices,
        schemes=schemes, dtypes=dtypes,
        on_skip=lambda msg: print(f"repro.bench: {msg}", file=sys.stderr))
    print(f"repro.bench: {len(cases)} cases over "
          f"{len({c.topology for c in cases})} topologies x {elems} elems "
          f"x dtypes {dtypes} (reps={reps})", file=sys.stderr)
    try:
        suite = suites.run_suite(cases, reps=reps,
                                 min_rep_s=args.min_rep_s,
                                 validate=not args.no_validate,
                                 log=lambda s: print(s, file=sys.stderr))
    except BenchValidationError as e:
        print(f"repro.bench: {e}", file=sys.stderr)
        return 1

    rep = report.to_report(suite, quick=args.quick, reps=reps,
                           families=families, elems=elems, dtypes=dtypes)
    report.write_report(rep, args.out)
    if args.csv:
        for row in report.csv_rows(suite):
            print(row)
    ok = rep["validation"]["ok"]
    print(f"repro.bench: wrote {args.out} "
          f"({len(rep['cases'])} cases, validation "
          f"{'OK' if ok else 'FAILED'}, "
          f"{rep['validation']['num_checks']} checks)", file=sys.stderr)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
