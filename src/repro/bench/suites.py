"""Matrix-driven collective benchmark suites.

Every case is one (family, scheme, topology, message size) cell:

* families — ``allgather``, ``broadcast``, ``psum`` (paper §4.1/4.2 and the
  gradient-reduction analogue), ``reduce_scatter``, ``allgatherv``
  (irregularly populated nodes, paper Figs 4/10) and ``alltoall``
  (personalized exchange: flat vs node-aware two-phase schedule);
* schemes  — whatever the ``repro.comm`` registry declares for the family
  (today ``naive``/``hier``/``shared``/``pipelined``): cases are built by
  sweeping ``registry.schemes_for(family)`` and dispatching through a
  ``Communicator``, so registering a new scheme adds it to the sweep with
  no edits here.  A scheme whose tunable grid is empty for a cell (its
  tiling divisor does not divide ``elems`` on that topology) is
  skipped-and-logged, never raised — irregular sizes can enter the sweep;
* tunables — a scheme's ``candidates()`` grid (e.g. ``pipelined``'s
  ``n_chunks``) is autotuned per (topology, size) cell: every candidate is
  compiled, cross-checked and timed, and the best median is the recorded
  number (the full sweep lands in the JSON's ``autotune`` record);
* topologies — ``repro.substrate.default_matrix()``: 1x8, 2x4, 4x2, 8x1 and
  the tuple-axis ``pod x (dp, tp)`` mesh.

A case AOT-compiles once per candidate (``jit(...).lower(...).compile()``);
the same executable is timed by ``run_suite``'s interleaved round-robin
loop (``runner.timed_call``/``summarize``) *and* its HLO text is what
``validate`` cross-checks against the scheme's self-described traffic model.
Inputs are ``device_put`` onto the cluster mesh before timing, so
host-to-device transfer never lands inside the timed region.
"""

from __future__ import annotations

import dataclasses
import random
import re
import time
import warnings
from typing import Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.bench import runner
from repro.comm import Communicator, SharedWindow, registry
from repro.core.plans import CollectiveTraffic, GatherPlan, NodeMap
from repro.substrate import VirtualCluster, default_matrix

ELEM_BYTES = 4  # the default float32 payload (NOT float64 — the x64-disabled
                # downcast warning of the seed bench came from f64 arange)
ELEM_DTYPE = "float32"  # recorded per case: the tuning table keys by dtype

# families swept at extra dtypes (``--dtypes float32,bfloat16``): the
# gradient-reduction and weight-window payloads whose wire format the
# quantized schemes compress — a bf16 sweep lets the tuning table
# discriminate by dtype (an int8 wire buys ~4x over f32 but only ~2x over
# bf16, so the ranking can legitimately flip).
DTYPE_SWEPT = ("allgather", "psum")


def _dtype_bytes(dtype: str) -> int:
    """Per-element bytes of a named jnp dtype (handles bfloat16, which
    plain ``np.dtype(str)`` does not know)."""
    return int(np.dtype(getattr(jnp, dtype)).itemsize)


def _wire_bytes(dtype: str) -> int:
    """Per-element bytes the payload occupies ON THE WIRE in the compiled
    artifact.  XLA's CPU backend normalizes sub-f32 *float* collectives to
    f32 (``convert -> f32 collective -> convert``), so a bf16 payload
    crosses links at 4 bytes there — the link-byte expectations must price
    the artifact, not the logical dtype.  Integer wires (the quantized
    schemes' codes, incl. the bitcast-u16 bf16 wire) lower natively on
    every backend and are priced inside each scheme's ``links`` closed
    form, independent of this payload width."""
    eb = _dtype_bytes(dtype)
    if eb < 4 and jax.default_backend() == "cpu" and \
            jnp.issubdtype(getattr(jnp, dtype), jnp.floating):
        return 4
    return eb

def _case_traffic(sch, family: str, vc, elems: int, dtype: str,
                  **kw) -> CollectiveTraffic:
    """The scheme's traffic model for one case: wire bytes priced at the
    COMPILED width (``_wire_bytes`` — the HLO cross-check target), the
    resident result at the LOGICAL dtype width (output shards really are
    e.g. bf16 even when the CPU backend widens the wire)."""
    web, eb = _wire_bytes(dtype), _dtype_bytes(dtype)
    tr = sch.traffic(family, pods=vc.pods, chips=vc.chips, elems=elems,
                     elem_bytes=web, **kw)
    if eb == web:
        return tr
    res = sch.traffic(family, pods=vc.pods, chips=vc.chips, elems=elems,
                      elem_bytes=eb, **kw)
    return CollectiveTraffic(
        slow_bytes=tr.slow_bytes, fast_bytes=tr.fast_bytes,
        result_bytes_per_node=res.result_bytes_per_node)


FAMILIES = ("allgather", "broadcast", "psum", "reduce_scatter",
            "allgatherv", "alltoall", "step_time", "serving")
# families that size themselves per cluster (one sweep per topology,
# outside the message-size loop) and register their schemes on import
SELF_SIZED = ("step_time", "serving")
# QUICK_ELEMS must stay a subset of FULL_ELEMS: CI's perf-regression gate
# compares the quick sweep against a committed full-sweep baseline, and
# only shared (family, scheme, topology, elems) cells can be compared.
FULL_ELEMS = (256, 1024, 4096, 65536)
QUICK_ELEMS = (1024,)
assert set(QUICK_ELEMS) <= set(FULL_ELEMS)


def slug(s: str) -> str:
    """CSV-safe case name (``benchmarks/run.py`` matches ``^[a-z0-9_]+,``)."""
    return re.sub(r"[^a-z0-9]+", "_", s.lower()).strip("_")


def _raw(out):
    """Bench bodies return arrays: unwrap shared-scheme windows."""
    return out.shard if isinstance(out, SharedWindow) else out


@dataclasses.dataclass
class BenchCase:
    """One measurable config: a shard_map body bound to a cluster + inputs
    + the registry-supplied traffic model it must agree with.

    ``tunable_grid`` holds the scheme's autotune candidates for this cell
    (``({},)`` = untunable); ``body_with(kwargs)`` builds the body for one
    candidate (``body`` is the default-candidate body)."""

    family: str
    scheme: str                      # a repro.comm registry entry name
    cluster: VirtualCluster
    elems: int                       # per-rank / message / per-pair elems
    body: Callable
    in_specs: tuple
    out_specs: object
    make_args: Callable[[], tuple]
    traffic: CollectiveTraffic       # scheme.traffic(...) for this config
    plan: Optional[GatherPlan] = None        # allgatherv only
    populations: Optional[tuple] = None      # allgatherv only
    body_with: Optional[Callable[[dict], Callable]] = None
    tunable_grid: tuple = ({},)
    dtype: str = ELEM_DTYPE          # payload dtype (wire-format sweeps)

    @property
    def topology(self) -> str:
        return self.cluster.label

    @property
    def elem_bytes(self) -> int:
        """Logical per-element bytes (result layouts, tuning-table keys)."""
        return _dtype_bytes(self.dtype)

    @property
    def wire_elem_bytes(self) -> int:
        """Per-element bytes on the compiled wire (see ``_wire_bytes``)."""
        return _wire_bytes(self.dtype)

    @property
    def name(self) -> str:
        # f32 names stay unsuffixed so the CI regression gate's committed
        # baseline cells keep matching across the dtype-sweep introduction
        base = f"{self.family}/{self.scheme}/{self.topology}/e{self.elems}"
        return base if self.dtype == ELEM_DTYPE else f"{base}/{self.dtype}"

    @property
    def csv_name(self) -> str:
        base = f"{self.family}_{self.scheme}_{self.topology}_{self.elems}"
        if self.dtype != ELEM_DTYPE:
            base = f"{base}_{self.dtype}"
        return slug(base)

    def compile(self, tunable: Optional[dict] = None):
        """AOT-compile on the cluster mesh (one tunable candidate).
        Returns ``(compiled, args)`` with ``args`` already device_put to
        the in_specs shardings."""
        body = self.body if not tunable and self.body_with is None \
            else self.body_with(dict(tunable or {}))
        mesh = self.cluster.mesh
        f = jax.jit(self.cluster.smap(body, self.in_specs,
                                      self.out_specs))
        args = tuple(
            jax.device_put(a, NamedSharding(mesh, s))
            for a, s in zip(self.make_args(), self.in_specs))
        return f.lower(*args).compile(), args


def _ranked_f32(num: int) -> jax.Array:
    return jnp.arange(num, dtype=jnp.float32)


def _ranked(num: int, dtype: str) -> jax.Array:
    """Ranked payload in the case dtype (built in f32, downcast once, so
    the bf16 sweep measures a bf16 wire, not an f32 arange side effect)."""
    return _ranked_f32(num).astype(getattr(jnp, dtype))


# ---------------------------------------------------------------------------
# Family builders (one BenchCase per registered scheme)
# ---------------------------------------------------------------------------

def _swept(schs, schemes):
    """Registry entries filtered to an explicit scheme subset (None = all):
    excluded schemes are never built and never logged as skipped."""
    if schemes is None:
        return schs
    return tuple(s for s in schs if s.name in schemes)


class BenchCoverageWarning(UserWarning):
    """A (family, scheme, topology, size) cell was dropped from the sweep
    (size does not tile for the scheme) — coverage, not correctness."""


def _grid_or_skip(sch, family: str, vc: VirtualCluster, elems: int,
                  on_skip) -> tuple:
    """The scheme's tunable grid for one cell; empty = skip-and-log (the
    cell's size does not tile on this topology for this scheme).  With no
    ``on_skip`` logger the drop still surfaces as a
    ``BenchCoverageWarning`` — never a fully silent coverage loss."""
    grid = sch.candidates(family, pods=vc.pods, chips=vc.chips, elems=elems)
    if not grid:
        need = sch.tiling(family, pods=vc.pods, chips=vc.chips)
        msg = (f"skip {family}/{sch.name}/{vc.label}/e{elems}: "
               f"elems={elems} does not tile by {need} "
               f"(scheme tiling divisor on this topology)")
        if on_skip is not None:
            on_skip(msg)
        else:
            warnings.warn(msg, BenchCoverageWarning, stacklevel=3)
    return grid


def allgather_cases(vc: VirtualCluster, elems: int, on_skip=None,
                    schemes=None, dtype: str = ELEM_DTYPE):
    comm = Communicator.from_cluster(vc)
    R = vc.num_devices

    def args():
        return (_ranked(R * elems, dtype),)

    for sch in _swept(registry.schemes_for("allgather"), schemes):
        grid = _grid_or_skip(sch, "allgather", vc, elems, on_skip)
        if not grid:
            continue
        out_specs = P(None) if sch.result_class == "replicated" else vc.spec

        # a concretely-named lossy scheme must opt in (Communicator raises
        # otherwise); exact schemes keep the default constraint
        def body_with(opts, s=sch.name, p=sch.precision):
            return lambda v: _raw(comm.allgather(v, scheme=s, precision=p,
                                                 **opts))

        yield BenchCase(
            "allgather", sch.name, vc, elems,
            body=body_with({}),
            in_specs=(vc.spec,), out_specs=out_specs, make_args=args,
            traffic=_case_traffic(sch, "allgather", vc, elems, dtype),
            body_with=body_with, tunable_grid=grid, dtype=dtype)


def broadcast_cases(vc: VirtualCluster, elems: int, on_skip=None,
                    schemes=None, dtype: str = ELEM_DTYPE):
    comm = Communicator.from_cluster(vc)
    R = vc.num_devices
    root = R // 2          # a non-zero, non-leader root: the flat-root API

    def args():
        return (_ranked(R * elems, dtype).reshape(R, elems),)

    for sch in _swept(registry.schemes_for("broadcast"), schemes):
        grid = _grid_or_skip(sch, "broadcast", vc, elems, on_skip)
        if not grid:
            continue
        out_specs = P(None) if sch.result_class == "replicated" \
            else P(vc.fast)

        def body_with(opts, s=sch.name, p=sch.precision):
            return lambda v: _raw(comm.broadcast(v[0], root=root, scheme=s,
                                                 precision=p, **opts))

        yield BenchCase(
            "broadcast", sch.name, vc, elems,
            body=body_with({}),
            in_specs=(vc.spec,), out_specs=out_specs, make_args=args,
            traffic=_case_traffic(sch, "broadcast", vc, elems, dtype),
            body_with=body_with, tunable_grid=grid, dtype=dtype)


def psum_cases(vc: VirtualCluster, elems: int, on_skip=None,
               schemes=None, dtype: str = ELEM_DTYPE):
    comm = Communicator.from_cluster(vc)
    R = vc.num_devices

    def args():
        # scaled so the reduction stays well inside f32 range (built in
        # f32, then downcast to the case dtype)
        return ((_ranked_f32(R * elems).reshape(R, elems) / (R * elems))
                .astype(getattr(jnp, dtype)),)

    for sch in _swept(registry.schemes_for("psum"), schemes):
        grid = _grid_or_skip(sch, "psum", vc, elems, on_skip)
        if not grid:
            continue
        out_specs = P(None) if sch.result_class == "replicated" \
            else P(vc.fast)

        def body_with(opts, s=sch.name, p=sch.precision):
            return lambda v: _raw(comm.allreduce(v[0], scheme=s,
                                                 precision=p, **opts))

        yield BenchCase(
            "psum", sch.name, vc, elems,
            body=body_with({}),
            in_specs=(vc.spec,), out_specs=out_specs, make_args=args,
            traffic=_case_traffic(sch, "psum", vc, elems, dtype),
            body_with=body_with, tunable_grid=grid, dtype=dtype)


def reduce_scatter_cases(vc: VirtualCluster, elems: int, on_skip=None,
                         schemes=None, dtype: str = ELEM_DTYPE):
    """Every rank contributes a full ``elems`` buffer; the global sum is
    scattered.  ``naive``/``pipelined`` end with flat 1/R slices
    (rank-major); ``shared`` keeps the node's reduced message once,
    sharded over the window."""
    comm = Communicator.from_cluster(vc)
    R = vc.num_devices

    def args():
        return ((_ranked_f32(R * elems).reshape(R, elems) / (R * elems))
                .astype(getattr(jnp, dtype)),)

    for sch in _swept(registry.schemes_for("reduce_scatter"), schemes):
        grid = _grid_or_skip(sch, "reduce_scatter", vc, elems, on_skip)
        if not grid:
            continue
        out_specs = P(vc.axis_names) if sch.result_class == "replicated" \
            else P(vc.fast)

        def body_with(opts, s=sch.name, p=sch.precision):
            return lambda v: _raw(comm.reduce_scatter(v[0], scheme=s,
                                                      precision=p, **opts))

        yield BenchCase(
            "reduce_scatter", sch.name, vc, elems,
            body=body_with({}),
            in_specs=(vc.spec,), out_specs=out_specs, make_args=args,
            traffic=_case_traffic(sch, "reduce_scatter", vc, elems,
                                  dtype),
            body_with=body_with, tunable_grid=grid, dtype=dtype)


def alltoall_cases(vc: VirtualCluster, elems: int, on_skip=None,
                   schemes=None, dtype: str = ELEM_DTYPE):
    """Personalized exchange: every rank holds R rank-ordered chunks of
    ``elems`` each; chunk *s* goes to rank *s* (flat vs node-aware)."""
    comm = Communicator.from_cluster(vc)
    R = vc.num_devices

    def args():
        return (_ranked(R * R * elems, dtype),)

    for sch in _swept(registry.schemes_for("alltoall"), schemes):
        grid = _grid_or_skip(sch, "alltoall", vc, elems, on_skip)
        if not grid:
            continue

        def body_with(opts, s=sch.name, p=sch.precision):
            return lambda v: comm.alltoall(v, scheme=s, precision=p, **opts)

        yield BenchCase(
            "alltoall", sch.name, vc, elems,
            body=body_with({}),
            in_specs=(vc.spec,), out_specs=vc.spec, make_args=args,
            traffic=_case_traffic(sch, "alltoall", vc, elems, dtype),
            body_with=body_with, tunable_grid=grid, dtype=dtype)


def bench_populations(pods: int, chips: int) -> tuple[int, ...]:
    """Deterministic irregular node populations: node k holds
    ``chips - (k % chips)`` ranks (always >= 1, node 0 always full)."""
    return tuple(chips - (k % chips) for k in range(pods))


def allgatherv_cases(vc: VirtualCluster, max_elems: int,
                     populations=None, on_skip=None, schemes=None,
                     dtype: str = ELEM_DTYPE):
    comm = Communicator.from_cluster(vc)
    R = vc.num_devices
    pops = tuple(populations) if populations is not None \
        else bench_populations(vc.pods, vc.chips)
    plan = GatherPlan(NodeMap.irregular(list(pops)), elem_per_rank=max_elems)
    plan.check()

    def args():
        data = np.arange(R * max_elems,
                         dtype=np.float32).reshape(R, max_elems)
        valid = np.zeros((R, 1), np.int32)
        for pd in range(vc.pods):
            for i in range(vc.chips):
                r = pd * vc.chips + i
                valid[r, 0] = max_elems if i < pops[pd] else 0
                if i >= pops[pd]:
                    data[r] = 0.0
        return (jnp.asarray(data).astype(getattr(jnp, dtype)),
                jnp.asarray(valid))

    # the naive scheme gathers the padded blocks AND the counts flat (an MPI
    # allgatherv still exchanges counts), so the two schemes move the same
    # *kinds* of payload and C1 stays an exact shard-level ratio.
    for sch in _swept(registry.schemes_for("allgatherv"), schemes):
        grid = _grid_or_skip(sch, "allgatherv", vc, max_elems, on_skip)
        if not grid:
            continue
        out_specs = (P(None), P(None)) if sch.result_class == "replicated" \
            else (P(None, vc.fast), P(None, vc.fast))

        def body_with(opts, s=sch.name, p=sch.precision):
            return lambda v, val: comm.allgatherv(v, val, scheme=s,
                                                  precision=p, **opts)

        yield BenchCase(
            "allgatherv", sch.name, vc, max_elems,
            body=body_with({}),
            in_specs=(vc.spec, vc.spec), out_specs=out_specs,
            make_args=args,
            traffic=_case_traffic(sch, "allgatherv", vc, max_elems, dtype,
                                  populations=pops),
            plan=plan, populations=pops,
            body_with=body_with, tunable_grid=grid, dtype=dtype)


def step_time_cases(vc: VirtualCluster, elems=None, on_skip=None,
                    schemes=None):
    """Bridge to ``repro.bench.step_time``: whole-train-step cases.  The
    family sizes itself (``elems`` is each model config's global parameter
    element count), so ``build_cases`` invokes it once per cluster, outside
    the message-size sweep."""
    from repro.bench import step_time as st
    return st.step_time_cases(vc, on_skip=on_skip, schemes=schemes)


def serving_cases(vc: VirtualCluster, elems=None, on_skip=None,
                  schemes=None):
    """Bridge to ``repro.bench.serving``: continuous-batching decode-step
    cases (self-sized per cluster, like ``step_time``)."""
    from repro.bench import serving as sv
    return sv.serving_cases(vc, on_skip=on_skip, schemes=schemes)


_FAMILY_BUILDERS = {
    "allgather": allgather_cases,
    "broadcast": broadcast_cases,
    "psum": psum_cases,
    "reduce_scatter": reduce_scatter_cases,
    "allgatherv": allgatherv_cases,
    "alltoall": alltoall_cases,
    "step_time": step_time_cases,
    "serving": serving_cases,
}


def build_cases(*, clusters: Optional[Sequence[VirtualCluster]] = None,
                families: Sequence[str] = FAMILIES,
                elems: Sequence[int] = FULL_ELEMS,
                max_devices: int = 8,
                schemes: Optional[Sequence[str]] = None,
                dtypes: Sequence[str] = (ELEM_DTYPE,),
                on_skip=None) -> list[BenchCase]:
    """The sweep: topology matrix x families x message sizes (x dtypes).

    ``schemes`` filters to a subset of registry entries (fast autotune
    iteration: ``--schemes pipelined,hier``); ``on_skip`` receives one
    message per (family, scheme, topology, size) cell whose size does not
    tile for that scheme — such cells are skipped, never raised.
    ``dtypes`` widens the sweep beyond float32 for the ``DTYPE_SWEPT``
    families only (the wire-format-sensitive payloads); other families run
    at float32 regardless.
    """
    if clusters is None:
        clusters = default_matrix(max_devices)
    unknown = set(families) - set(_FAMILY_BUILDERS)
    if unknown:
        raise ValueError(f"unknown families {sorted(unknown)}; "
                         f"pick from {list(_FAMILY_BUILDERS)}")
    for dt in dtypes:
        if not hasattr(jnp, dt):
            raise ValueError(f"unknown dtype {dt!r}: not a jax.numpy "
                             f"dtype name (try float32, bfloat16)")
    if "step_time" in families:
        from repro.bench import step_time  # noqa: F401  registers its
        # eager/prefetch schemes before the scheme-name validation below
    if "serving" in families:
        from repro.bench import serving  # noqa: F401  registers sync/
        # recorded before the scheme-name validation below
    if schemes is not None:
        if "auto" in schemes:
            raise ValueError(
                "'auto' is the tuning-table dispatch mode, not a registry "
                "entry — the sweep measures the concrete schemes auto "
                "chooses between (emit the table from the sweep instead: "
                "python -m repro.bench --emit-tuning-table)")
        unknown_s = set(schemes) - set(registry.scheme_names())
        if unknown_s:
            raise ValueError(f"unknown schemes {sorted(unknown_s)}; "
                             f"registered: {list(registry.scheme_names())}")
    cases: list[BenchCase] = []
    per_size = tuple(f for f in families if f not in SELF_SIZED)
    for vc in clusters:
        for dt in dict.fromkeys(dtypes):   # de-duped, order-preserving
            fams = per_size if dt == ELEM_DTYPE else \
                tuple(f for f in per_size if f in DTYPE_SWEPT)
            for e in elems:
                for fam in fams:
                    cases.extend(_FAMILY_BUILDERS[fam](
                        vc, e, on_skip=on_skip, schemes=schemes, dtype=dt))
        for fam in SELF_SIZED:
            if fam in families:
                # self-sized family: one sweep per cluster, not per size
                cases.extend(_FAMILY_BUILDERS[fam](vc, on_skip=on_skip,
                                                   schemes=schemes))
    return cases


# ---------------------------------------------------------------------------
# Suite execution
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CaseResult:
    case: BenchCase
    timing: runner.TimingResult
    hlo: dict                    # parsed link/result bytes (validate.py)
    checks: list                 # per-case validate.Check list
    autotune: Optional[dict] = None   # tunable sweep record (best wins)


def _cand_tag(cand: dict) -> str:
    return ",".join(f"{k}={v}" for k, v in sorted(cand.items()))


class _Entry(NamedTuple):
    """One compiled (case, tunable-candidate) executable in a timing cell."""
    case: BenchCase
    cand: dict
    compiled: object
    args: tuple
    hlo: dict
    checks: list
    inner: int
    canon: str          # program identity: HLO minus source metadata


@dataclasses.dataclass
class SuiteResult:
    cases: list[CaseResult]
    cross_checks: list           # cross-scheme C1 validate.Check list


def run_suite(cases: Sequence[BenchCase], *, reps: int = 30,
              min_rep_s: float = 0.0, validate: bool = True,
              log=None) -> SuiteResult:
    """Compile, measure and cross-check every case.

    A case with a tunable grid (e.g. ``pipelined``'s ``n_chunks``) is
    autotuned: EVERY candidate is compiled, cross-checked (the closed forms
    are tunable-invariant — chunking must not change the total bytes) and
    timed with the same reps; the best median is the case's recorded
    number and the full sweep lands in ``CaseResult.autotune``.

    Timing is **interleaved per cell**: all (case, candidate) executables
    of one (family, topology, size) group are timed round-robin — rep *r*
    of every entry runs back-to-back before rep *r+1* of any — so the
    scheme-vs-scheme and candidate-vs-candidate comparisons the sweep
    exists for share one machine-drift profile instead of each entry
    meeting a different moment of a noisy host.  The within-round order is
    shuffled per round (fixed seed — deterministic sweeps) so no entry
    systematically inherits a fixed neighbor's cache/thermal state.
    Entries within a cell whose compiled programs are IDENTICAL modulo
    source metadata (e.g. ``pipelined`` at ``n_chunks=1`` vs ``hier`` —
    one chunk is the unchunked schedule) and share the same calibrated
    inner count are measurements of one program: their samples are pooled,
    so they report one median instead of two allocation-luck-separated
    numbers for the same executable.  A pooled case's ``timing.reps`` is
    the POOLED sample count backing its statistics (a multiple of the
    requested reps).

    Per-case and cross-scheme (C1) validation failures are collected and
    raised together as ``validate.BenchValidationError`` AFTER the whole
    sweep ran, so one bad config reports alongside the full picture.
    """
    from repro.bench import validate as V

    def _canon(hlo_text: str) -> str:
        # program identity: the compiled module minus source metadata
        return re.sub(r"metadata=\{[^}]*\}", "", hlo_text)

    # preserve input order while grouping into comparison cells
    groups: dict[tuple, list[BenchCase]] = {}
    for case in cases:
        groups.setdefault(
            (case.family, case.topology, case.elems, case.dtype),
            []).append(case)

    results_by_id: dict[int, CaseResult] = {}
    done = 0
    for group in groups.values():
        # phase 1 — compile every (case, candidate); the one inspection
        # execution IS the timer's warmup: its outputs feed the
        # shard-level result-bytes measurement
        entries: list[_Entry] = []
        for case in group:
            if not case.cluster.available():
                raise RuntimeError(
                    f"{case.name}: needs {case.cluster.num_devices} "
                    f"devices, have {jax.device_count()} — force more host "
                    "devices (see repro.substrate."
                    "ensure_host_device_count)")
            for cand in tuple(case.tunable_grid) or ({},):
                compiled, args = case.compile(cand)
                t0 = time.perf_counter()
                outputs = runner.block_all(compiled(*args))
                warm_s = time.perf_counter() - t0
                hlo_text = compiled.as_text()
                hlo_meas, checks = V.inspect_case(case, hlo_text, outputs,
                                                  opts=cand)
                entries.append(_Entry(
                    case=case, cand=cand, compiled=compiled, args=args,
                    hlo=hlo_meas, checks=checks,
                    inner=runner.calibrate_inner(warm_s, min_rep_s),
                    canon=_canon(hlo_text)))
        # identical programs must share ONE calibration, or warmup jitter
        # could split their pools (same canon, different inner)
        min_inner: dict[str, int] = {}
        for e in entries:
            min_inner[e.canon] = min(min_inner.get(e.canon, e.inner),
                                     e.inner)
        entries = [e._replace(inner=min_inner[e.canon]) for e in entries]
        # phase 2 — interleaved round-robin timing over the cell; the
        # within-round order is re-shuffled each round (fixed seed) so no
        # entry always follows the same neighbor
        rng = random.Random(0x5EED)
        samples: list[list[float]] = [[] for _ in entries]
        order = list(range(len(entries)))
        for _ in range(reps):
            rng.shuffle(order)
            for i in order:
                e = entries[i]
                samples[i].append(runner.timed_call(e.compiled, *e.args,
                                                    inner=e.inner))
        # pool samples of program-identical entries (same canonical HLO +
        # same inner calibration = the same executable measured under two
        # labels; per-call microseconds, so pooling is unit-consistent)
        by_prog: dict[tuple, list[float]] = {}
        for i, e in enumerate(entries):
            by_prog.setdefault((e.canon, e.inner), []).extend(samples[i])
        pooled = [by_prog[(e.canon, e.inner)] for e in entries]
        # phase 3 — aggregate per case: best candidate wins
        for case in group:
            tuned = [(e.cand, runner.summarize(pooled[i], inner=e.inner),
                      e.hlo, e.checks)
                     for i, e in enumerate(entries) if e.case is case]
            best = min(tuned, key=lambda t: t[1].median_us)
            checks = list(best[3])
            for cand, _, _, cand_checks in tuned:
                if cand is best[0]:
                    continue
                # non-best candidates contribute only their FAILURES
                # (tagged): the closed forms are tunable-invariant, so a
                # pass adds no news
                checks.extend(
                    dataclasses.replace(ch,
                                        name=f"{ch.name}@{_cand_tag(cand)}")
                    for ch in cand_checks if not ch.ok)
            autotune = None
            if len(tuned) > 1 or tuned[0][0]:
                autotune = {
                    "param_grid": [dict(c) for c, _, _, _ in tuned],
                    "results": [{**dict(c), "median_us": t.median_us}
                                for c, t, _, _ in tuned],
                    "best": dict(best[0]),
                }
            results_by_id[id(case)] = CaseResult(
                case, best[1], best[2], checks if validate else [],
                autotune)
            done += 1
            if log:
                tag = f" [{_cand_tag(best[0])}]" if best[0] else ""
                log(f"[{done}/{len(cases)}] {case.name}{tag}: "
                    f"{best[1].median_us:.1f}us (iqr "
                    f"{best[1].iqr_us:.1f}, {len(tuned)} candidate(s))")
    results = [results_by_id[id(c)] for c in cases]
    cross = V.cross_scheme_checks(results) if validate else []
    if validate:
        V.raise_on_failure(results, cross)
    return SuiteResult(results, cross)
