"""Matrix-driven collective benchmark suites.

Every case is one (family, scheme, topology, message size) cell:

* families — ``allgather``, ``broadcast``, ``psum`` (paper §4.1/4.2 and the
  gradient-reduction analogue), ``allgatherv`` (irregularly populated
  nodes, paper Figs 4/10) and ``alltoall`` (personalized exchange: flat vs
  node-aware two-phase schedule);
* schemes  — whatever the ``repro.comm`` registry declares for the family
  (today ``naive``/``hier``/``shared``): cases are built by sweeping
  ``registry.schemes_for(family)`` and dispatching through a
  ``Communicator``, so registering a new scheme adds it to the sweep with
  no edits here;
* topologies — ``repro.substrate.default_matrix()``: 1x8, 2x4, 4x2, 8x1 and
  the tuple-axis ``pod x (dp, tp)`` mesh.

A case AOT-compiles once (``jit(...).lower(...).compile()``); the same
executable is timed by ``runner.timeit`` *and* its HLO text is what
``validate`` cross-checks against the scheme's self-described traffic model.
Inputs are ``device_put`` onto the cluster mesh before timing, so
host-to-device transfer never lands inside the timed region.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.bench import runner
from repro.comm import Communicator, SharedWindow, registry
from repro.core.plans import CollectiveTraffic, GatherPlan, NodeMap
from repro.substrate import VirtualCluster, default_matrix

ELEM_BYTES = 4  # all payloads are float32 (NOT float64 — the x64-disabled
                # downcast warning of the seed bench came from f64 arange)

FAMILIES = ("allgather", "broadcast", "psum", "allgatherv", "alltoall")
FULL_ELEMS = (256, 4096, 65536)
QUICK_ELEMS = (1024,)


def slug(s: str) -> str:
    """CSV-safe case name (``benchmarks/run.py`` matches ``^[a-z0-9_]+,``)."""
    return re.sub(r"[^a-z0-9]+", "_", s.lower()).strip("_")


def _raw(out):
    """Bench bodies return arrays: unwrap shared-scheme windows."""
    return out.shard if isinstance(out, SharedWindow) else out


@dataclasses.dataclass
class BenchCase:
    """One measurable config: a shard_map body bound to a cluster + inputs
    + the registry-supplied traffic model it must agree with."""

    family: str
    scheme: str                      # a repro.comm registry entry name
    cluster: VirtualCluster
    elems: int                       # per-rank / message / per-pair elems
    body: Callable
    in_specs: tuple
    out_specs: object
    make_args: Callable[[], tuple]
    traffic: CollectiveTraffic       # scheme.traffic(...) for this config
    plan: Optional[GatherPlan] = None        # allgatherv only
    populations: Optional[tuple] = None      # allgatherv only

    @property
    def topology(self) -> str:
        return self.cluster.label

    @property
    def name(self) -> str:
        return f"{self.family}/{self.scheme}/{self.topology}/e{self.elems}"

    @property
    def csv_name(self) -> str:
        return slug(f"{self.family}_{self.scheme}_{self.topology}"
                    f"_{self.elems}")

    def compile(self):
        """AOT-compile on the cluster mesh.  Returns ``(compiled, args)``
        with ``args`` already device_put to the in_specs shardings."""
        mesh = self.cluster.mesh
        f = jax.jit(self.cluster.smap(self.body, self.in_specs,
                                      self.out_specs))
        args = tuple(
            jax.device_put(a, NamedSharding(mesh, s))
            for a, s in zip(self.make_args(), self.in_specs))
        return f.lower(*args).compile(), args


def _ranked_f32(num: int) -> jax.Array:
    return jnp.arange(num, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# Family builders (one BenchCase per registered scheme)
# ---------------------------------------------------------------------------

def allgather_cases(vc: VirtualCluster, elems: int):
    comm = Communicator.from_cluster(vc)
    R = vc.num_devices

    def args():
        return (_ranked_f32(R * elems),)

    for sch in registry.schemes_for("allgather"):
        out_specs = P(None) if sch.result_class == "replicated" else vc.spec
        yield BenchCase(
            "allgather", sch.name, vc, elems,
            body=lambda v, s=sch.name: _raw(comm.allgather(v, scheme=s)),
            in_specs=(vc.spec,), out_specs=out_specs, make_args=args,
            traffic=sch.traffic("allgather", pods=vc.pods, chips=vc.chips,
                                elems=elems, elem_bytes=ELEM_BYTES))


def _require_tiling(vc: VirtualCluster, elems: int, family: str) -> None:
    """Scatter-based schemes shard the message over the fast tier."""
    if elems % vc.chips:
        raise ValueError(
            f"{family}: elems={elems} must divide by ranks_per_node="
            f"{vc.chips} (topology {vc.label}) for the shared shards "
            "to tile")


def broadcast_cases(vc: VirtualCluster, elems: int):
    _require_tiling(vc, elems, "broadcast")
    comm = Communicator.from_cluster(vc)
    R = vc.num_devices
    root = R // 2          # a non-zero, non-leader root: the flat-root API

    def args():
        return (_ranked_f32(R * elems).reshape(R, elems),)

    for sch in registry.schemes_for("broadcast"):
        out_specs = P(None) if sch.result_class == "replicated" \
            else P(vc.fast)
        yield BenchCase(
            "broadcast", sch.name, vc, elems,
            body=lambda v, s=sch.name:
                _raw(comm.broadcast(v[0], root=root, scheme=s)),
            in_specs=(vc.spec,), out_specs=out_specs, make_args=args,
            traffic=sch.traffic("broadcast", pods=vc.pods, chips=vc.chips,
                                elems=elems, elem_bytes=ELEM_BYTES))


def psum_cases(vc: VirtualCluster, elems: int):
    _require_tiling(vc, elems, "psum")
    comm = Communicator.from_cluster(vc)
    R = vc.num_devices

    def args():
        # scaled so the reduction stays well inside f32 range
        return (_ranked_f32(R * elems).reshape(R, elems) / (R * elems),)

    for sch in registry.schemes_for("psum"):
        out_specs = P(None) if sch.result_class == "replicated" \
            else P(vc.fast)
        yield BenchCase(
            "psum", sch.name, vc, elems,
            body=lambda v, s=sch.name: _raw(comm.allreduce(v[0], scheme=s)),
            in_specs=(vc.spec,), out_specs=out_specs, make_args=args,
            traffic=sch.traffic("psum", pods=vc.pods, chips=vc.chips,
                                elems=elems, elem_bytes=ELEM_BYTES))


def alltoall_cases(vc: VirtualCluster, elems: int):
    """Personalized exchange: every rank holds R rank-ordered chunks of
    ``elems`` each; chunk *s* goes to rank *s* (flat vs node-aware)."""
    comm = Communicator.from_cluster(vc)
    R = vc.num_devices

    def args():
        return (_ranked_f32(R * R * elems),)

    for sch in registry.schemes_for("alltoall"):
        yield BenchCase(
            "alltoall", sch.name, vc, elems,
            body=lambda v, s=sch.name: comm.alltoall(v, scheme=s),
            in_specs=(vc.spec,), out_specs=vc.spec, make_args=args,
            traffic=sch.traffic("alltoall", pods=vc.pods, chips=vc.chips,
                                elems=elems, elem_bytes=ELEM_BYTES))


def bench_populations(pods: int, chips: int) -> tuple[int, ...]:
    """Deterministic irregular node populations: node k holds
    ``chips - (k % chips)`` ranks (always >= 1, node 0 always full)."""
    return tuple(chips - (k % chips) for k in range(pods))


def allgatherv_cases(vc: VirtualCluster, max_elems: int,
                     populations=None):
    comm = Communicator.from_cluster(vc)
    R = vc.num_devices
    pops = tuple(populations) if populations is not None \
        else bench_populations(vc.pods, vc.chips)
    plan = GatherPlan(NodeMap.irregular(list(pops)), elem_per_rank=max_elems)
    plan.check()

    def args():
        data = np.arange(R * max_elems,
                         dtype=np.float32).reshape(R, max_elems)
        valid = np.zeros((R, 1), np.int32)
        for pd in range(vc.pods):
            for i in range(vc.chips):
                r = pd * vc.chips + i
                valid[r, 0] = max_elems if i < pops[pd] else 0
                if i >= pops[pd]:
                    data[r] = 0.0
        return jnp.asarray(data), jnp.asarray(valid)

    # the naive scheme gathers the padded blocks AND the counts flat (an MPI
    # allgatherv still exchanges counts), so the two schemes move the same
    # *kinds* of payload and C1 stays an exact shard-level ratio.
    for sch in registry.schemes_for("allgatherv"):
        out_specs = (P(None), P(None)) if sch.result_class == "replicated" \
            else (P(None, vc.fast), P(None, vc.fast))
        yield BenchCase(
            "allgatherv", sch.name, vc, max_elems,
            body=lambda v, val, s=sch.name:
                comm.allgatherv(v, val, scheme=s),
            in_specs=(vc.spec, vc.spec), out_specs=out_specs,
            make_args=args,
            traffic=sch.traffic("allgatherv", pods=vc.pods, chips=vc.chips,
                                elems=max_elems, elem_bytes=ELEM_BYTES,
                                populations=pops),
            plan=plan, populations=pops)


_FAMILY_BUILDERS = {
    "allgather": allgather_cases,
    "broadcast": broadcast_cases,
    "psum": psum_cases,
    "allgatherv": allgatherv_cases,
    "alltoall": alltoall_cases,
}


def build_cases(*, clusters: Optional[Sequence[VirtualCluster]] = None,
                families: Sequence[str] = FAMILIES,
                elems: Sequence[int] = FULL_ELEMS,
                max_devices: int = 8) -> list[BenchCase]:
    """The sweep: topology matrix x families x message sizes."""
    if clusters is None:
        clusters = default_matrix(max_devices)
    unknown = set(families) - set(_FAMILY_BUILDERS)
    if unknown:
        raise ValueError(f"unknown families {sorted(unknown)}; "
                         f"pick from {list(_FAMILY_BUILDERS)}")
    cases: list[BenchCase] = []
    for vc in clusters:
        for e in elems:
            for fam in families:
                cases.extend(_FAMILY_BUILDERS[fam](vc, e))
    return cases


# ---------------------------------------------------------------------------
# Suite execution
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CaseResult:
    case: BenchCase
    timing: runner.TimingResult
    hlo: dict                    # parsed link/result bytes (validate.py)
    checks: list                 # per-case validate.Check list


@dataclasses.dataclass
class SuiteResult:
    cases: list[CaseResult]
    cross_checks: list           # cross-scheme C1 validate.Check list


def run_suite(cases: Sequence[BenchCase], *, reps: int = 30,
              min_rep_s: float = 0.0, validate: bool = True,
              log=None) -> SuiteResult:
    """Compile, measure and cross-check every case.

    Per-case and cross-scheme (C1) validation failures are collected and
    raised together as ``validate.BenchValidationError`` AFTER the whole
    sweep ran, so one bad config reports alongside the full picture.
    """
    from repro.bench import validate as V

    results: list[CaseResult] = []
    for i, case in enumerate(cases):
        if not case.cluster.available():
            raise RuntimeError(
                f"{case.name}: needs {case.cluster.num_devices} devices, "
                f"have {jax.device_count()} — force more host devices "
                "(see repro.substrate.ensure_host_device_count)")
        compiled, args = case.compile()
        # this one execution IS the timer's warmup (warmup=False below):
        # its outputs feed the shard-level result-bytes measurement
        outputs = runner.block_all(compiled(*args))
        hlo_meas, checks = V.inspect_case(case, compiled.as_text(), outputs)
        timing = runner.timeit(compiled, *args, reps=reps,
                               min_rep_s=min_rep_s, warmup=False)
        results.append(CaseResult(case, timing, hlo_meas,
                                  checks if validate else []))
        if log:
            log(f"[{i + 1}/{len(cases)}] {case.name}: "
                f"{timing.median_us:.1f}us (iqr {timing.iqr_us:.1f})")
    cross = V.cross_scheme_checks(results) if validate else []
    if validate:
        V.raise_on_failure(results, cross)
    return SuiteResult(results, cross)
