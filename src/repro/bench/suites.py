"""Matrix-driven collective benchmark suites.

Every case is one (family, scheme, topology, message size) cell:

* families — ``allgather``, ``broadcast``, ``psum`` (paper §4.1/4.2 and the
  gradient-reduction analogue) and ``allgatherv`` (irregularly populated
  nodes, paper Figs 4/10);
* schemes  — ``naive`` (pure-MPI analogue, private copy per rank), ``hier``
  (two-phase schedule, still fully replicated) and ``shared`` (the paper's
  one-copy-per-node shared-window scheme);
* topologies — ``repro.substrate.default_matrix()``: 1x8, 2x4, 4x2, 8x1 and
  the tuple-axis ``pod x (dp, tp)`` mesh.  Every case runs over the whole
  matrix instead of the one shape the old subprocess script hard-coded.

A case AOT-compiles once (``jit(...).lower(...).compile()``); the same
executable is timed by ``runner.timeit`` *and* its HLO text is what
``validate`` cross-checks against the ``core.plans`` traffic model.  Inputs
are ``device_put`` onto the cluster mesh before timing, so host-to-device
transfer never lands inside the timed region (another seed-bench flaw).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.bench import runner
from repro.core import collectives as cc
from repro.core.plans import (CollectiveTraffic, GatherPlan, NodeMap,
                              allgather_traffic, allgatherv_traffic,
                              allreduce_traffic, broadcast_traffic)
from repro.substrate import VirtualCluster, default_matrix

ELEM_BYTES = 4  # all payloads are float32 (NOT float64 — the x64-disabled
                # downcast warning of the seed bench came from f64 arange)

FAMILIES = ("allgather", "broadcast", "psum", "allgatherv")
FULL_ELEMS = (256, 4096, 65536)
QUICK_ELEMS = (1024,)


def slug(s: str) -> str:
    """CSV-safe case name (``benchmarks/run.py`` matches ``^[a-z0-9_]+,``)."""
    return re.sub(r"[^a-z0-9]+", "_", s.lower()).strip("_")


@dataclasses.dataclass
class BenchCase:
    """One measurable config: a shard_map body bound to a cluster + inputs
    + the plans.py traffic model it must agree with."""

    family: str
    scheme: str                      # naive | hier | shared
    cluster: VirtualCluster
    elems: int                       # per-rank (allgather[v]) / message elems
    body: Callable
    in_specs: tuple
    out_specs: object
    make_args: Callable[[], tuple]
    traffic: CollectiveTraffic       # plans model for this scheme's class
    plan: Optional[GatherPlan] = None        # allgatherv only
    populations: Optional[tuple] = None      # allgatherv only

    @property
    def topology(self) -> str:
        return self.cluster.label

    @property
    def name(self) -> str:
        return f"{self.family}/{self.scheme}/{self.topology}/e{self.elems}"

    @property
    def csv_name(self) -> str:
        return slug(f"{self.family}_{self.scheme}_{self.topology}"
                    f"_{self.elems}")

    def compile(self):
        """AOT-compile on the cluster mesh.  Returns ``(compiled, args)``
        with ``args`` already device_put to the in_specs shardings."""
        mesh = self.cluster.mesh
        f = jax.jit(self.cluster.smap(self.body, self.in_specs,
                                      self.out_specs))
        args = tuple(
            jax.device_put(a, NamedSharding(mesh, s))
            for a, s in zip(self.make_args(), self.in_specs))
        return f.lower(*args).compile(), args


def _ranked_f32(num: int) -> jax.Array:
    return jnp.arange(num, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# Family builders
# ---------------------------------------------------------------------------

def allgather_cases(vc: VirtualCluster, elems: int):
    R = vc.num_devices
    m_bytes = elems * ELEM_BYTES
    tr_rep = allgather_traffic(scheme="naive", num_nodes=vc.pods,
                               ranks_per_node=vc.chips,
                               bytes_per_rank=m_bytes)
    tr_shr = allgather_traffic(scheme="hier", num_nodes=vc.pods,
                               ranks_per_node=vc.chips,
                               bytes_per_rank=m_bytes)

    def args():
        return (_ranked_f32(R * elems),)

    yield BenchCase(
        "allgather", "naive", vc, elems,
        body=lambda v: cc.naive_all_gather(v, fast_axis=vc.fast,
                                           slow_axis=vc.slow),
        in_specs=(vc.spec,), out_specs=P(None), make_args=args,
        traffic=tr_rep)
    yield BenchCase(
        "allgather", "hier", vc, elems,
        body=lambda v: cc.hier_all_gather(v, fast_axis=vc.fast,
                                          slow_axis=vc.slow),
        in_specs=(vc.spec,), out_specs=P(None), make_args=args,
        traffic=tr_rep)
    yield BenchCase(
        "allgather", "shared", vc, elems,
        body=lambda v: cc.shared_all_gather(v, fast_axis=vc.fast,
                                            slow_axis=vc.slow),
        in_specs=(vc.spec,), out_specs=vc.spec, make_args=args,
        traffic=tr_shr)


def _require_tiling(vc: VirtualCluster, elems: int, family: str) -> None:
    """Scatter-based schemes shard the message over the fast tier."""
    if elems % vc.chips:
        raise ValueError(
            f"{family}: elems={elems} must divide by ranks_per_node="
            f"{vc.chips} (topology {vc.label}) for the shared shards "
            "to tile")


def broadcast_cases(vc: VirtualCluster, elems: int):
    _require_tiling(vc, elems, "broadcast")
    R = vc.num_devices
    root = R // 2          # a non-zero, non-leader root: the flat-root API
    n_bytes = elems * ELEM_BYTES
    tr_rep = broadcast_traffic(scheme="naive", num_nodes=vc.pods,
                               ranks_per_node=vc.chips, msg_bytes=n_bytes)
    tr_shr = broadcast_traffic(scheme="hier", num_nodes=vc.pods,
                               ranks_per_node=vc.chips, msg_bytes=n_bytes)

    def args():
        return (_ranked_f32(R * elems).reshape(R, elems),)

    yield BenchCase(
        "broadcast", "naive", vc, elems,
        body=lambda v: cc.naive_broadcast(v[0], root=root, fast_axis=vc.fast,
                                          slow_axis=vc.slow),
        in_specs=(vc.spec,), out_specs=P(None), make_args=args,
        traffic=tr_rep)
    yield BenchCase(
        "broadcast", "hier", vc, elems,
        body=lambda v: cc.hier_broadcast(v[0], root=root, fast_axis=vc.fast,
                                         slow_axis=vc.slow),
        in_specs=(vc.spec,), out_specs=P(None), make_args=args,
        traffic=tr_rep)
    yield BenchCase(
        "broadcast", "shared", vc, elems,
        body=lambda v: cc.shared_broadcast(v[0], root=root, fast_axis=vc.fast,
                                           slow_axis=vc.slow, axis=0),
        in_specs=(vc.spec,), out_specs=P(vc.fast), make_args=args,
        traffic=tr_shr)


def psum_cases(vc: VirtualCluster, elems: int):
    _require_tiling(vc, elems, "psum")
    R = vc.num_devices
    n_bytes = elems * ELEM_BYTES
    tr_rep = allreduce_traffic(scheme="naive", num_nodes=vc.pods,
                               ranks_per_node=vc.chips, msg_bytes=n_bytes)
    tr_shr = allreduce_traffic(scheme="hier", num_nodes=vc.pods,
                               ranks_per_node=vc.chips, msg_bytes=n_bytes)

    def args():
        # scaled so the reduction stays well inside f32 range
        return (_ranked_f32(R * elems).reshape(R, elems) / (R * elems),)

    yield BenchCase(
        "psum", "naive", vc, elems,
        body=lambda v: cc.naive_psum(v[0], fast_axis=vc.fast,
                                     slow_axis=vc.slow),
        in_specs=(vc.spec,), out_specs=P(None), make_args=args,
        traffic=tr_rep)
    yield BenchCase(
        "psum", "hier", vc, elems,
        body=lambda v: cc.hier_psum(v[0], fast_axis=vc.fast,
                                    slow_axis=vc.slow, axis=0),
        in_specs=(vc.spec,), out_specs=P(None), make_args=args,
        traffic=tr_rep)
    yield BenchCase(
        "psum", "shared", vc, elems,
        body=lambda v: cc.shared_psum_scatter(v[0], fast_axis=vc.fast,
                                              slow_axis=vc.slow, axis=0),
        in_specs=(vc.spec,), out_specs=P(vc.fast), make_args=args,
        traffic=tr_shr)


def bench_populations(pods: int, chips: int) -> tuple[int, ...]:
    """Deterministic irregular node populations: node k holds
    ``chips - (k % chips)`` ranks (always >= 1, node 0 always full)."""
    return tuple(chips - (k % chips) for k in range(pods))


def allgatherv_cases(vc: VirtualCluster, max_elems: int,
                     populations=None):
    R = vc.num_devices
    pops = tuple(populations) if populations is not None \
        else bench_populations(vc.pods, vc.chips)
    plan = GatherPlan(NodeMap.irregular(list(pops)), elem_per_rank=max_elems)
    plan.check()
    m_bytes = max_elems * ELEM_BYTES
    tr_rep = allgatherv_traffic(scheme="naive", populations=pops,
                                bytes_per_rank=m_bytes)
    tr_shr = allgatherv_traffic(scheme="hier", populations=pops,
                                bytes_per_rank=m_bytes)

    def args():
        data = np.arange(R * max_elems,
                         dtype=np.float32).reshape(R, max_elems)
        valid = np.zeros((R, 1), np.int32)
        for p in range(vc.pods):
            for i in range(vc.chips):
                r = p * vc.chips + i
                valid[r, 0] = max_elems if i < pops[p] else 0
                if i >= pops[p]:
                    data[r] = 0.0
        return jnp.asarray(data), jnp.asarray(valid)

    # naive gathers the padded blocks AND the counts flat (an MPI
    # allgatherv still exchanges counts), so the two schemes move the same
    # *kinds* of payload and C1 stays an exact shard-level ratio.
    yield BenchCase(
        "allgatherv", "naive", vc, max_elems,
        body=lambda v, val: (cc.naive_all_gather(v, fast_axis=vc.fast,
                                                 slow_axis=vc.slow),
                             cc.naive_all_gather(val, fast_axis=vc.fast,
                                                 slow_axis=vc.slow)),
        in_specs=(vc.spec, vc.spec), out_specs=(P(None), P(None)),
        make_args=args, traffic=tr_rep, plan=plan, populations=pops)
    yield BenchCase(
        "allgatherv", "shared", vc, max_elems,
        body=lambda v, val: cc.shared_all_gather_v(v, val,
                                                   slow_axis=vc.slow),
        in_specs=(vc.spec, vc.spec),
        out_specs=(P(None, vc.fast), P(None, vc.fast)),
        make_args=args, traffic=tr_shr, plan=plan, populations=pops)


_FAMILY_BUILDERS = {
    "allgather": allgather_cases,
    "broadcast": broadcast_cases,
    "psum": psum_cases,
    "allgatherv": allgatherv_cases,
}


def build_cases(*, clusters: Optional[Sequence[VirtualCluster]] = None,
                families: Sequence[str] = FAMILIES,
                elems: Sequence[int] = FULL_ELEMS,
                max_devices: int = 8) -> list[BenchCase]:
    """The sweep: topology matrix x families x message sizes."""
    if clusters is None:
        clusters = default_matrix(max_devices)
    unknown = set(families) - set(_FAMILY_BUILDERS)
    if unknown:
        raise ValueError(f"unknown families {sorted(unknown)}; "
                         f"pick from {list(_FAMILY_BUILDERS)}")
    cases: list[BenchCase] = []
    for vc in clusters:
        for e in elems:
            for fam in families:
                cases.extend(_FAMILY_BUILDERS[fam](vc, e))
    return cases


# ---------------------------------------------------------------------------
# Suite execution
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CaseResult:
    case: BenchCase
    timing: runner.TimingResult
    hlo: dict                    # parsed link/result bytes (validate.py)
    checks: list                 # per-case validate.Check list


@dataclasses.dataclass
class SuiteResult:
    cases: list[CaseResult]
    cross_checks: list           # cross-scheme C1 validate.Check list


def run_suite(cases: Sequence[BenchCase], *, reps: int = 30,
              min_rep_s: float = 0.0, validate: bool = True,
              log=None) -> SuiteResult:
    """Compile, measure and cross-check every case.

    Per-case and cross-scheme (C1) validation failures are collected and
    raised together as ``validate.BenchValidationError`` AFTER the whole
    sweep ran, so one bad config reports alongside the full picture.
    """
    from repro.bench import validate as V

    results: list[CaseResult] = []
    for i, case in enumerate(cases):
        if not case.cluster.available():
            raise RuntimeError(
                f"{case.name}: needs {case.cluster.num_devices} devices, "
                f"have {jax.device_count()} — force more host devices "
                "(see repro.substrate.ensure_host_device_count)")
        compiled, args = case.compile()
        # this one execution IS the timer's warmup (warmup=False below):
        # its outputs feed the shard-level result-bytes measurement
        outputs = runner.block_all(compiled(*args))
        hlo_meas, checks = V.inspect_case(case, compiled.as_text(), outputs)
        timing = runner.timeit(compiled, *args, reps=reps,
                               min_rep_s=min_rep_s, warmup=False)
        results.append(CaseResult(case, timing, hlo_meas,
                                  checks if validate else []))
        if log:
            log(f"[{i + 1}/{len(cases)}] {case.name}: "
                f"{timing.median_us:.1f}us (iqr {timing.iqr_us:.1f})")
    cross = V.cross_scheme_checks(results) if validate else []
    if validate:
        V.raise_on_failure(results, cross)
    return SuiteResult(results, cross)
