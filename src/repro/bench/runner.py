"""Calibrated microbenchmark timer.

Fixes the seed timer's two bugs (``benchmarks/_collective_bench.py:timeit``):

* the warmup expression called ``fn(*xs)`` up to three times (once for the
  ``isinstance`` probe, once per conditional branch) — here warmup is exactly
  ONE call;
* only ``jax.tree.leaves(out)[0]`` was blocked on, so multi-output
  computations (tuples, pytrees) could still be in flight when the clock
  stopped — here every leaf of every timed output is blocked on.

It also reports a median with dispersion instead of a bare mean: fake host
CPU devices schedule noisily, and the mean of 30 reps is dominated by the
slowest outliers.
"""

from __future__ import annotations

import dataclasses
import math
import statistics
import time

import jax


def block_all(out):
    """Block until *every* array leaf of ``out`` is ready (not just the
    first — the seed-timer bug this module exists to fix)."""
    for leaf in jax.tree.leaves(out):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()
    return out


@dataclasses.dataclass(frozen=True)
class TimingResult:
    """Median-of-reps timing with dispersion, all in microseconds."""

    median_us: float
    mean_us: float
    min_us: float
    max_us: float
    iqr_us: float       # p75 - p25 over the reps: the dispersion estimate
    reps: int
    inner: int          # calls per timed rep (calibrated; 1 unless tiny)
    # tail percentiles over the reps (nearest-rank): what the serving
    # family's latency reporting and the regression gate's p99 pass read.
    # p50 duplicates median on purpose — consumers address percentiles
    # uniformly without special-casing the 50th.
    p50_us: float = 0.0
    p99_us: float = 0.0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _percentile(sorted_us: list, q: float) -> float:
    """Nearest-rank percentile of an ascending sample list."""
    import math as _math
    n = len(sorted_us)
    return sorted_us[min(n - 1, max(0, _math.ceil(q * n) - 1))]


def calibrate_inner(warm_s: float, min_rep_s: float,
                    max_inner: int = 64) -> int:
    """Inner-loop count so one timed rep lasts at least ``min_rep_s``,
    given a ``warm_s``-second calibration call (1 = no batching).  The one
    home of this formula — ``timeit`` and ``suites.run_suite`` both use
    it."""
    if min_rep_s <= 0.0 or warm_s >= min_rep_s:
        return 1
    return min(max_inner, max(1, math.ceil(min_rep_s / max(warm_s, 1e-9))))


def summarize(times_us, inner: int = 1) -> TimingResult:
    """Aggregate raw per-rep microsecond samples into a ``TimingResult``
    (used by ``suites.run_suite``'s interleaved round-robin timing, where
    the rep loop lives OUTSIDE the per-case timer so concurrent cases share
    one drift profile)."""
    times_us = list(times_us)
    if not times_us:
        raise ValueError("no samples")
    if len(times_us) >= 2:
        q1, _, q3 = statistics.quantiles(times_us, n=4)
        iqr = q3 - q1
    else:
        iqr = 0.0
    ordered = sorted(times_us)
    return TimingResult(
        median_us=statistics.median(times_us),
        mean_us=statistics.fmean(times_us),
        min_us=ordered[0], max_us=ordered[-1],
        iqr_us=iqr, reps=len(times_us), inner=inner,
        p50_us=_percentile(ordered, 0.50),
        p99_us=_percentile(ordered, 0.99))


def timed_call(fn, *args, inner: int = 1) -> float:
    """One timed rep (``inner`` back-to-back calls, every output leaf
    blocked) in microseconds-per-call."""
    t0 = time.perf_counter()
    out = fn(*args)
    for _ in range(inner - 1):
        out = fn(*args)
    block_all(out)
    return (time.perf_counter() - t0) / inner * 1e6


def timeit(fn, *args, reps: int = 30, min_rep_s: float = 0.0,
           max_inner: int = 64, warmup: bool = True) -> TimingResult:
    """Time ``fn(*args)``: one warmup call, then ``reps`` timed reps.

    Calibration: the warmup call is also timed; if it ran faster than
    ``min_rep_s``, each rep loops ``fn`` ``inner`` times (capped at
    ``max_inner``) so a rep is long enough for the clock.  Every rep blocks
    on all output leaves before the clock stops.

    ``warmup=False`` is for callers that already executed ``fn`` once
    (e.g. ``run_suite`` runs each compiled case once to inspect its output
    shards — THAT is the single warmup); calibration then uses the first
    timed rep, which stays in the measured set.
    """
    if reps < 1:
        raise ValueError(f"reps must be >= 1, got {reps}")
    inner = 1
    if warmup:
        t0 = time.perf_counter()
        block_all(fn(*args))             # the single warmup call
        warm_s = time.perf_counter() - t0
        inner = calibrate_inner(warm_s, min_rep_s, max_inner)
    times_us = []
    for i in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        for _ in range(inner - 1):
            out = fn(*args)
        block_all(out)
        dt = time.perf_counter() - t0
        times_us.append(dt / inner * 1e6)
        if not warmup and i == 0:
            inner = calibrate_inner(dt, min_rep_s, max_inner)
    return summarize(times_us, inner=inner)
