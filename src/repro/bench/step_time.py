"""End-to-end ``step_time`` bench family: whole train steps, not medians of
one collective.

The single-collective families measure each schedule in isolation; what the
async prefetch engine actually buys — layer *k+1*'s FSDP window gather
overlapping layer *k*'s compute — only shows up in a full forward/backward
step.  This family times exactly that, over the model-zoo configs, through
the same machinery as every other family: its two schemes are registry
entries, its cases carry traffic expectations that ``repro.bench.validate``
cross-checks against the compiled HLO, its cells land in
``BENCH_collectives.json`` and fold into the ``scheme="auto"`` tuning table,
and the CI regression gate diffs it like any ``allgather`` cell.

* ``eager``    — issue-at-use baseline: the unit loop fully unrolled
  (``lax.scan(unroll=n_units)``), weight gathers issued inside each unit
  body at use time and re-issued by the remat bwd;
* ``prefetch`` — the same step with the ``prefetch`` opt: the unrolled
  ``ParamGroup`` walk (``models.parallel``) that issues the next unit's
  gathers as ``AsyncCollectiveHandle``s while the current unit computes;
* ``stepgraph`` — the same step with the ``stepgraph`` opt: the step's
  scalar stats and per-leaf gradient reductions recorded into one
  ``CollectiveGraph`` (``repro.comm.stepgraph``) and re-issued as the
  bucketed/deduped/reordered schedule — fewer, larger bridge messages,
  bit-identical outputs.

Both schemes unroll the unit loop, so the measured delta isolates the
prefetch engine (gather placement and issue order) — rolled-scan vs
unrolled is an orthogonal code-layout effect that would otherwise swamp
the comparison on small reduced configs.  Production training keeps its
rolled scan; this family measures the *schedule*, not the loop form.

A step's collective content is whatever the model traced — there is no
closed form in ``(pods, chips, elems)`` alone — so each scheme carries a
per-config **link inventory** recorded by the case builder from the step's
own jaxpr (``link_inventory``), priced with the very ring model
``analysis.roofline.parse_collectives`` applies to the compiled HLO.  The
jaxpr is what we asked for and the HLO is what XLA lowered, so the
``link/fast``/``link/slow`` checks pin real rewrites (a lost overlap, an
accidental re-gather, a wrong replica group), not a tautology.

Case sizing: ``elems`` is the model's global parameter element count —
deterministic per config, so quick (CI) and full sweeps land on the same
(family, topology, dtype, size) cells and stay comparable.
"""

from __future__ import annotations

import dataclasses
import math
from types import MappingProxyType
from typing import Optional

import jax

from repro.bench.suites import ELEM_BYTES, BenchCase, _swept
from repro.comm import registry
from repro.comm.registry import CollectiveScheme, register_scheme
from repro.configs import get_config
from repro.core.plans import CollectiveTraffic, collective_time_model

#: model-zoo configs timed by the family (reduced shapes: the bench measures
#: schedules, not model quality).  Both are plain dense, untied-embedding
#: entries on purpose: a tied unembed gathers the SAME leaf twice and XLA
#: CSE merges the two gathers, which a jaxpr-side count cannot see.
STEP_CONFIGS = ("starcoder2-7b", "mistral-nemo-12b")


# ---------------------------------------------------------------------------
# Jaxpr link inventory (the expected side of the HLO cross-check)
# ---------------------------------------------------------------------------

_AR_LIKE = ("psum", "pmax", "pmin")


def _names(axis_name) -> tuple[str, ...]:
    if axis_name is None:
        return ()
    if isinstance(axis_name, (tuple, list)):
        return tuple(a for a in axis_name if isinstance(a, str))
    return (axis_name,) if isinstance(axis_name, str) else ()


def _aval_bytes(v) -> int:
    aval = v.aval
    return math.prod(aval.shape) * aval.dtype.itemsize


def _inner_jaxprs(eqn):
    core = jax.extend.core if hasattr(jax, "extend") else jax.core
    kinds = (core.ClosedJaxpr, core.Jaxpr)
    for val in eqn.params.values():
        vals = val if isinstance(val, (tuple, list)) else (val,)
        for v in vals:
            if isinstance(v, core.ClosedJaxpr):
                yield v.jaxpr
            elif isinstance(v, kinds):
                yield v


def _scan_copies(eqn) -> int:
    """Static body copies a ``scan`` leaves in the lowered module text.

    ``unroll`` is a lowering-time knob invisible in the jaxpr structure:
    the body jaxpr stays one step, but lowering emits ``unroll`` copies
    inside the loop (all of them when fully unrolled, where the loop
    disappears entirely)."""
    length = eqn.params.get("length", 1) or 1
    unroll = eqn.params.get("unroll", 1)
    if unroll is True:
        return length
    return min(int(unroll) or 1, length)


@dataclasses.dataclass(frozen=True)
class LinkEntry:
    """One physical collective message in a traced step's lowering: the
    unit the inventory sums and the bucketing/dedup tests count."""

    kind: str                   # "ar" | "ag" | "rs" | "a2a" | "perm"
    names: tuple[str, ...]      # axis names the group spans
    tier: str                   # "fast" | "slow" (any pod axis -> slow)
    out_bytes: int              # result payload of the op
    link_bytes: float           # ring-model per-chip wire bytes, one copy
    copies: float               # static lowered copies (unrolled scans)
    group_size: int             # ranks per replica group


def _walk(jaxpr, sizes: dict, pod_names: set, entries: list,
          mult: float = 1.0) -> None:
    # within one jaxpr, identical collective eqns over the same operands are
    # one HLO op after CSE — count them once
    seen = set()
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim in _AR_LIKE:
            names = _names(eqn.params.get("axes", ()))
            kind = "ar"
        elif prim == "all_gather":
            names = _names(eqn.params.get("axis_name"))
            kind = "ag"
        elif prim == "reduce_scatter":
            names = _names(eqn.params.get("axis_name"))
            kind = "rs"
        elif prim == "all_to_all":
            names = _names(eqn.params.get("axis_name"))
            kind = "a2a"
        elif prim == "ppermute":
            names = _names(eqn.params.get("axis_name"))
            kind = "perm"
        else:
            # loop/branch/remat/pjit bodies appear once in the lowered
            # module text, which is exactly how parse_collectives counts
            # them — recurse once per eqn; a partially/fully unrolled scan
            # body is the one exception (``unroll`` static copies)
            inner_mult = mult * _scan_copies(eqn) if prim == "scan" else mult
            for inner in _inner_jaxprs(eqn):
                _walk(inner, sizes, pod_names, entries, inner_mult)
            continue
        if not names:
            continue            # positional-axes only: no wire traffic
        groups = eqn.params.get("axis_index_groups")
        if groups is not None:
            n = len(groups[0])
        else:
            n = 1
            for a in names:
                n *= sizes.get(a, 1)
        if n <= 1:
            continue
        key = (prim, tuple(map(id, eqn.invars)),
               tuple(sorted((k, repr(v)) for k, v in eqn.params.items())))
        if key in seen:
            continue
        seen.add(key)
        out_b = sum(_aval_bytes(v) for v in eqn.outvars)
        if kind == "ag":
            link = out_b * (n - 1) / n
        elif kind == "rs":
            link = out_b * (n - 1)
        elif kind == "ar":
            link = 2.0 * out_b * (n - 1) / n
        elif kind == "a2a":
            link = out_b * (n - 1) / n
        else:                   # ppermute -> collective-permute
            link = float(out_b)
        tier = "slow" if any(a in pod_names for a in names) else "fast"
        entries.append(LinkEntry(kind=kind, names=names, tier=tier,
                                 out_bytes=out_b, link_bytes=link,
                                 copies=mult, group_size=n))


def _traced_entries(fn, example_args, vc) -> list[LinkEntry]:
    closed = jax.make_jaxpr(fn)(*example_args)
    try:
        from jax.interpreters.partial_eval import dce_jaxpr
    except ImportError:                       # pragma: no cover
        from jax._src.interpreters.partial_eval import dce_jaxpr
    jaxpr, _ = dce_jaxpr(closed.jaxpr, [True] * len(closed.jaxpr.outvars))
    sizes = dict(zip(vc.axis_names, vc.axis_shapes))
    entries: list[LinkEntry] = []
    _walk(jaxpr, sizes, set(vc.slow_names), entries)
    return entries


def link_entries(fn, example_args, vc) -> list[LinkEntry]:
    """Per-message inventory of ``fn``'s lowering: one ``LinkEntry`` per
    physical collective (post-DCE, per-jaxpr CSE applied the way jit
    applies it, ``axis_index_groups``-aware).  This is how the step-graph
    tests verify bucketing/dedup did what they claim — counting entries
    counts messages, not bytes."""
    return _traced_entries(fn, example_args, vc)


def link_inventory(fn, example_args, vc) -> tuple[float, float]:
    """Expected per-chip (fast, slow) link bytes of ``fn``'s lowering.

    Traces ``fn`` (a mesh-level function, e.g. an ``smap``-wrapped body) to
    a jaxpr, DCEs it the way jit will, and prices every collective primitive
    with ``parse_collectives``' ring model: AG ``out*(n-1)/n``, RS
    ``out*(n-1)``, AR ``2*out*(n-1)/n``, A2A ``out*(n-1)/n``, permute
    ``out``.  Loop bodies count once (static module text); size-1 groups are
    skipped; a group naming a slow axis is charged to the bridge tier.
    Sums ``link_entries`` — the per-message detail the step-graph tests
    assert on.
    """
    fast = slow = 0.0
    for e in _traced_entries(fn, example_args, vc):
        if e.tier == "slow":
            slow += e.link_bytes * e.copies
        else:
            fast += e.link_bytes * e.copies
    return fast, slow


# ---------------------------------------------------------------------------
# The two step schemes
# ---------------------------------------------------------------------------

def _no_dispatch(*_a, **_k):
    raise NotImplementedError(
        "step_time schemes are whole-train-step bench entries; they have no "
        "Communicator dispatch body — build cases via "
        "repro.bench.step_time.step_time_cases")


class StepTimeScheme(CollectiveScheme):
    """Base of the ``step_time`` schemes: a registry entry whose expected
    lowering is a recorded per-config inventory instead of a closed form.

    ``step_time_cases`` records each built case's jaxpr inventory here;
    ``links()`` replays it for ``validate.expected_links``, ``traffic``/
    ``predicted_time`` express it in ``core.plans`` terms so the tuning
    table's modeled fallback ranks the schemes off-table too.
    """

    result_class = "replicated"
    FAMILY = "step_time"        # subclasses re-key (e.g. bench.serving)
    ops = MappingProxyType({"step_time": _no_dispatch})
    opts: tuple = ()            # ParallelCtx opts that select this schedule
    N_OUT = 3                   # loss, gnorm, checksum: replicated f32

    def __init__(self):
        # (pods, chips, fast_shape, elems) -> (fast, slow) per-chip bytes
        self._inventory: dict = {}

    def record(self, *, pods: int, chips: int, fast_shape, elems: int,
               fast: float, slow: float) -> None:
        self._inventory[(pods, chips, tuple(fast_shape), elems)] = \
            (fast, slow)

    def _lookup(self, pods: int, chips: int, elems: int
                ) -> Optional[tuple[float, float]]:
        for (p, c, _fs, e), v in self._inventory.items():
            if (p, c, e) == (pods, chips, elems):
                return v
        return None

    def links(self, family, *, pods, chips, fast_shape, elems, elem_bytes=4,
              opts=None, dtype="float32"):
        inv = self._inventory.get((pods, chips, tuple(fast_shape), elems))
        if inv is None:
            raise ValueError(
                f"{self.name!r} has no recorded link inventory for "
                f"{pods}x{chips} (fast {fast_shape}) at {elems} elems — "
                f"{self.FAMILY} expectations are recorded per case by the "
                "family's case builder, not closed forms")
        return inv

    def result_node(self, family, *, pods, chips, elems, elem_bytes=4):
        # replicated scalars: every rank holds each f32 output once
        return self.N_OUT * 4 * chips

    def traffic_for(self, *, pods: int, chips: int, fast_shape, elems: int
                    ) -> CollectiveTraffic:
        fast, slow = self.links(self.FAMILY, pods=pods, chips=chips,
                                fast_shape=fast_shape, elems=elems)
        R = pods * chips
        return CollectiveTraffic(
            slow_bytes=slow * R, fast_bytes=fast * R,
            result_bytes_per_node=self.result_node(
                self.FAMILY, pods=pods, chips=chips, elems=elems))

    def traffic(self, family, *, pods, chips, elems, elem_bytes=4,
                populations=None):
        if family != self.FAMILY:
            return super().traffic(family, pods=pods, chips=chips,
                                   elems=elems, elem_bytes=elem_bytes,
                                   populations=populations)
        inv = self._lookup(pods, chips, elems)
        if inv is None:
            raise ValueError(f"{self.name!r}: no recorded inventory for "
                             f"{pods}x{chips}/e{elems}")
        R = pods * chips
        return CollectiveTraffic(
            slow_bytes=inv[1] * R, fast_bytes=inv[0] * R,
            result_bytes_per_node=self.result_node(
                family, pods=pods, chips=chips, elems=elems))

    def predicted_time(self, family, *, pods, chips, elems, elem_bytes=4,
                       populations=None):
        if self._lookup(pods, chips, elems) is None:
            return None         # unrecorded config: cannot rank off-table
        tr = self.traffic(family, pods=pods, chips=chips, elems=elems)
        return collective_time_model(tr, num_nodes=pods,
                                     ranks_per_node=chips), {}


class StepEagerScheme(StepTimeScheme):
    """Issue-at-use baseline: the unit loop fully unrolled, weight gathers
    issued inside each unit body at use time (and re-issued by the remat
    bwd) — the prefetch schedule minus the prefetching."""

    name = "eager"
    opts = ()


class StepPrefetchScheme(StepTimeScheme):
    """The async-prefetch step: unrolled ``ParamGroup`` walk, unit *k+1*'s
    gathers in flight (``AsyncCollectiveHandle``) while unit *k* computes,
    double-buffered (in-flight budget 2)."""

    name = "prefetch"
    opts = ("prefetch",)


class StepStepgraphScheme(StepTimeScheme):
    """The step-graph-optimized step: scalar stats + per-leaf gradient
    reductions recorded into one ``CollectiveGraph`` and re-issued as the
    rewritten schedule (``repro.comm.stepgraph``) — small same-axes
    allreduces packed into flat buckets sized off the tuning table, issues
    front-loaded behind one shared ordering token.  Fewer, larger bridge
    messages; outputs bit-identical to ``eager``."""

    name = "stepgraph"
    opts = ("stepgraph",)


EAGER = register_scheme(StepEagerScheme())
PREFETCH = register_scheme(StepPrefetchScheme())
STEPGRAPH = register_scheme(StepStepgraphScheme())


# ---------------------------------------------------------------------------
# Case builder
# ---------------------------------------------------------------------------

def step_time_cases(vc, on_skip=None, schemes=None):
    """One case per (model config, step scheme) on this cluster.

    Builds the flattened-state train-step body (``runtime.steps.
    make_step_bench``), records its jaxpr link inventory on the scheme, and
    yields a ``BenchCase`` whose HLO the validate layer must match."""
    from repro.runtime.steps import make_step_bench

    for cfg_name in STEP_CONFIGS:
        cfg = get_config(cfg_name).reduced()
        for sch in _swept(registry.schemes_for("step_time"), schemes):
            body, in_specs, out_specs, make_args, elems = make_step_bench(
                cfg, vc, opts=sch.opts, unroll=cfg.n_units)
            avals = tuple(jax.ShapeDtypeStruct(a.shape, a.dtype)
                          for a in make_args())
            fast_b, slow_b = link_inventory(
                vc.smap(body, in_specs, out_specs), avals, vc)
            sch.record(pods=vc.pods, chips=vc.chips,
                       fast_shape=vc.fast_shape, elems=elems,
                       fast=fast_b, slow=slow_b)
            yield BenchCase(
                "step_time", sch.name, vc, elems,
                body=body, in_specs=in_specs, out_specs=out_specs,
                make_args=make_args,
                traffic=sch.traffic_for(pods=vc.pods, chips=vc.chips,
                                        fast_shape=vc.fast_shape,
                                        elems=elems))
