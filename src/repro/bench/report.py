"""Schema-versioned benchmark report (``BENCH_collectives.json``) + the
legacy ``name,us_per_call,derived`` CSV rows.

The JSON is the artifact that seeds the perf trajectory: every later perf
PR appends a measured config to the same schema and diffs against the
previous artifact.  Structure (``repro.bench/v1``):

* top level — ``schema``, environment (jax version / backend / device
  count), the sweep parameters and the topology-matrix labels;
* ``cases[]`` — one record per measured config: identity (family, scheme,
  topology, pods, chips, elems), ``timing`` (median/mean/min/max/iqr us,
  reps, inner), ``traffic`` (the plans.py model), ``hlo`` (bytes parsed
  from the compiled module) and the per-case ``checks``;
* ``cross_checks[]`` — the C1 resident-memory invariants measured across
  schemes;
* ``validation`` — overall verdict (always ``ok: true`` in a written file:
  a mismatch raises before the report is written).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Sequence

from repro.bench import SCHEMA_VERSION
from repro.bench.suites import CaseResult, SuiteResult


def case_record(r: CaseResult) -> dict:
    c = r.case
    serving = None
    if c.family == "serving":
        # open-loop Poisson load model priced by the measured step median:
        # tokens/sec + p50/p99 per-token latency per matrix topology
        # (deterministic given the timing — seeded sim, no wall clock)
        from repro.bench.serving import serving_metrics
        serving = serving_metrics(r.timing.median_us)
    return {
        "name": c.name,
        "csv_name": c.csv_name,
        "family": c.family,
        "scheme": c.scheme,
        "topology": c.topology,
        "pods": c.cluster.pods,
        "chips": c.cluster.chips,
        "elems": c.elems,
        "bytes_per_rank": c.elems * c.elem_bytes,
        "dtype": c.dtype,
        "fast_axes": len(c.cluster.fast_names),
        "populations": list(c.populations) if c.populations else None,
        "timing": r.timing.to_dict(),
        "traffic": dataclasses.asdict(c.traffic),
        "hlo": r.hlo,
        "checks": [ch.to_dict() for ch in r.checks],
        "autotune": r.autotune,
        "serving": serving,
        "ok": all(ch.ok for ch in r.checks),
    }


def copies_per_node(r: CaseResult) -> int:
    """The fixed fig7 'derived' column: how many copies of the FULL result
    a node holds (naive: one per rank; shared: one — paper C1).  The seed
    bench divided by per-rank bytes and printed rank counts instead."""
    c = r.case
    eb = c.elem_bytes
    if c.family in ("allgather", "alltoall"):
        # alltoall: the "full result" is one rank's R*m receive buffer —
        # rank-private in every scheme, so copies_per_node == ranks_per_node
        full = c.cluster.num_devices * c.elems * eb
    elif c.family == "allgatherv":
        full = sum(c.populations) * c.elems * eb
    elif c.family == "reduce_scatter":
        # unit = the node's flat share of the scattered result; the shared
        # window keeps the whole reduced message (num_nodes shares) once
        full = c.elems * eb // c.cluster.pods
    else:                       # broadcast / psum: the message itself
        full = c.elems * eb
    return c.traffic.result_bytes_per_node // full


def csv_rows(suite: SuiteResult) -> list[str]:
    """``name,us_per_call,derived`` rows (benchmarks/run.py format)."""
    rows = []
    for r in suite.cases:
        t = r.case.traffic
        derived = (f"slow_bytes={t.slow_bytes};fast_bytes={t.fast_bytes};"
                   f"result_bytes_per_node={t.result_bytes_per_node};"
                   f"copies_per_node={copies_per_node(r)}")
        rows.append(f"{r.case.csv_name},{r.timing.median_us:.1f},{derived}")
    return rows


def to_report(suite: SuiteResult, *, quick: bool, reps: int,
              families: Sequence[str], elems: Sequence[int],
              dtypes: Sequence[str] = ("float32",)) -> dict:
    import jax
    matrix = sorted({r.case.topology for r in suite.cases})
    n_checks = sum(len(r.checks) for r in suite.cases) + \
        len(suite.cross_checks)
    return {
        "schema": SCHEMA_VERSION,
        "generated_by": "python -m repro.bench",
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "sweep": {"quick": quick, "reps": reps,
                  "families": list(families), "elems": list(elems),
                  "dtypes": list(dtypes)},
        "matrix": matrix,
        "cases": [case_record(r) for r in suite.cases],
        "cross_checks": [ch.to_dict() for ch in suite.cross_checks],
        "validation": {
            "ok": all(ch.ok for r in suite.cases for ch in r.checks)
                  and all(ch.ok for ch in suite.cross_checks),
            "num_checks": n_checks,
            "invariants": {
                "C1": "naive/shared resident-result bytes per node ratio "
                      "== ranks_per_node (measured from output shards)",
                "C2": "shared allgather moves zero intra-node copy bytes",
                "bridge": "shared-scheme bridge wire bytes == plans.py "
                          "slow_bytes (exact, ring model)",
            },
        },
    }


def write_report(report: dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(report, f, indent=1, sort_keys=False)
        f.write("\n")
