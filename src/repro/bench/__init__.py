"""In-process collective benchmark subsystem (``python -m repro.bench``).

Replaces the loose subprocess CSV scripts with a matrix-driven measurement
backbone:

* ``runner``   — calibrated microbenchmark timer: exactly one warmup call,
  blocking on *every* output leaf, median-of-reps with dispersion;
* ``suites``   — sweeps the allgather, broadcast, psum, irregular allgatherv
  and alltoall families over ``repro.substrate.default_matrix()`` (1x8,
  2x4, 4x2, 8x1, tuple-axis) x message sizes, with the scheme list per
  family pulled from the ``repro.comm`` registry and every case dispatched
  through a ``Communicator``;
* ``validate`` — cross-checks every measured config's compiled-HLO collective
  bytes (``analysis.roofline.parse_collectives``) against the scheme's
  self-described traffic model/lowering (``repro.comm.registry``); the
  paper's C1 one-copy-per-node claim is an asserted invariant
  (replicated/shared resident-result ratio == ranks_per_node) and any
  mismatch fails the run;
* ``report``   — schema-versioned ``BENCH_collectives.json`` + the legacy
  ``name,us_per_call,derived`` CSV rows.

This module deliberately imports nothing jax-heavy: ``python -m repro.bench``
must be able to force the host device count (``XLA_FLAGS``) before any jax
backend initializes, and ``-m`` imports the package ``__init__`` first.
"""

SCHEMA_VERSION = "repro.bench/v1"

__all__ = ["SCHEMA_VERSION"]
