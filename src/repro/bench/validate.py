"""Traffic-model cross-checks: compiled HLO vs ``core.plans``.

Three layers run for every measured config; ANY mismatch fails the bench
run (``BenchValidationError``):

1. **Lowering check** (``link/fast``, ``link/slow``) — the per-chip link
   bytes parsed out of the compiled HLO by
   ``analysis.roofline.parse_collectives`` (ring model) must equal the
   closed-form expectation for the exact collective sequence each scheme
   lowers to.  This pins the compiled artifact: an XLA rewrite, a wrong
   replica group, or an accidental extra collective shows up here.

2. **Model identities** (``model/*``) — documented exact mappings between
   the parsed wire/resident bytes and the ``plans.py`` traffic model:

   * shared allgather bridge bytes == model ``slow_bytes`` (and zero
     intra-node bytes — paper C2);
   * hier allgather bridge bytes == ranks_per_node x the shared bridge:
     full replication pays C1 *on the wire*;
   * the psum-emulated broadcast costs exactly 2x the model's one-way
     bytes (a psum moves data up and back down the ring);
   * the flat naive psum's total wire bytes == model ring total; the
     shared/hier psum bridge == num_nodes x the model's per-node shard
     ring, intra-node RS(+AG) == c/2 (c) x the model's per-node cycle;
   * irregular allgatherv: padded wire bytes scaled by the compact
     fraction == the model's compact bridge bytes (GatherPlan-consistent);
   * resident result bytes per node (measured from the actual output
     shards) == model ``result_bytes_per_node``.

3. **C1, the paper's memory claim** (``C1/*``) — within every (family,
   topology, size) group, the measured naive/shared resident-result ratio
   equals ranks_per_node, from the real shards on the real devices.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax

from repro.analysis.roofline import parse_collectives
from repro.bench.suites import ELEM_BYTES, BenchCase, CaseResult
from repro.core.plans import allgather_traffic, allreduce_traffic


class BenchValidationError(AssertionError):
    """The compiled HLO disagrees with the traffic model (or C1 broke)."""


@dataclasses.dataclass
class Check:
    name: str
    expected: float
    measured: float
    note: str = ""
    # link-byte expectations are exact under the ring model; tolerance only
    # absorbs float accumulation in the parser and int truncation in plans.
    tol: float = 2.0

    @property
    def ok(self) -> bool:
        return abs(self.measured - self.expected) <= \
            max(self.tol, 1e-9 * abs(self.expected))

    def to_dict(self) -> dict:
        return {"name": self.name, "expected": self.expected,
                "measured": self.measured, "ok": self.ok, "note": self.note}


# ---------------------------------------------------------------------------
# Ring-model closed forms for each scheme's known lowering (per-chip bytes,
# matching parse_collectives' accounting exactly).
# ---------------------------------------------------------------------------

def _ag(out_bytes: float, n: int) -> float:
    return out_bytes * (n - 1) / n if n > 1 else 0.0


def _rs(out_bytes: float, n: int) -> float:
    return out_bytes * (n - 1) if n > 1 else 0.0


def _ar(msg_bytes: float, n: int) -> float:
    return 2.0 * msg_bytes * (n - 1) / n if n > 1 else 0.0


def expected_links(case: BenchCase) -> tuple[float, float]:
    """Expected (fast, slow) per-chip link bytes of the case's lowering."""
    Pn, c = case.cluster.pods, case.cluster.chips
    R = Pn * c
    m = case.elems * ELEM_BYTES        # per-rank / message bytes
    fam, sch = case.family, case.scheme
    fast = slow = 0.0
    if fam == "allgather":
        n = R * m
        if sch == "naive":             # one flat all-gather over all ranks
            if Pn > 1:
                slow = _ag(n, R)
            else:
                fast = _ag(n, c)
        elif sch == "hier":            # intra-pod AG, then bridge AG
            fast = _ag(c * m, c)
            slow = _ag(n, Pn)
        else:                          # shared: bridge AG only
            slow = _ag(Pn * m, Pn)
    elif fam == "broadcast":
        if sch == "naive":             # masked psum over all ranks
            if Pn > 1:
                slow = _ar(m, R)
            else:
                fast = _ar(m, c)
        elif sch == "hier":            # bridge psum, then intra-pod psum
            slow = _ar(m, Pn)
            fast = _ar(m, c)
        else:                          # shared: intra RS, bridge psum on shard
            fast = _rs(m / c, c)
            slow = _ar(m / c, Pn)
    elif fam == "psum":
        if sch == "naive":             # one flat all-reduce
            if Pn > 1:
                slow = _ar(m, R)
            else:
                fast = _ar(m, c)
        elif sch == "hier":            # RS fast + AR bridge + AG fast
            fast = _rs(m / c, c) + _ag(m, c)
            slow = _ar(m / c, Pn)
        else:                          # shared: RS fast + AR bridge
            fast = _rs(m / c, c)
            slow = _ar(m / c, Pn)
    elif fam == "allgatherv":
        cnt = 4                        # int32 valid-count payload per rank
        if sch == "naive":             # flat AG of padded blocks + counts
            if Pn > 1:
                slow = _ag(R * m, R) + _ag(R * cnt, R)
            else:
                fast = _ag(R * m, c) + _ag(R * cnt, c)
        else:                          # shared: bridge AG of padded + counts
            slow = _ag(Pn * m, Pn) + _ag(Pn * cnt, Pn)
    else:
        raise ValueError(f"unknown family {fam!r}")
    return fast, slow


def expected_result_node(case: BenchCase) -> int:
    """Expected resident result bytes on ONE node (pod), from the known
    output layout: replicated schemes keep ranks_per_node copies, shared
    keeps one."""
    Pn, c = case.cluster.pods, case.cluster.chips
    R = Pn * c
    m = case.elems * ELEM_BYTES
    fam, sch = case.family, case.scheme
    if fam == "allgather":
        n = R * m
        return c * n if sch in ("naive", "hier") else n
    if fam in ("broadcast", "psum"):
        return c * m if sch in ("naive", "hier") else m
    if fam == "allgatherv":
        per_rank = m + 4               # padded block + its int32 count
        return c * R * per_rank if sch == "naive" else c * Pn * per_rank
    raise ValueError(f"unknown family {fam!r}")


# ---------------------------------------------------------------------------
# Measurement
# ---------------------------------------------------------------------------

def _node0_devices(case: BenchCase):
    devs = case.cluster.mesh.devices
    if case.cluster.pods > 1:
        devs = devs[(0,) * len(case.cluster.slow_names)]
    return {d for d in devs.flatten().tolist()}


def measured_result_node(case: BenchCase, outputs) -> int:
    """Resident result bytes on node 0, from the real output shards."""
    node0 = _node0_devices(case)
    total = 0
    for leaf in jax.tree.leaves(outputs):
        for sh in leaf.addressable_shards:
            if sh.device in node0:
                total += sh.data.nbytes
    return total


def inspect_case(case: BenchCase, hlo_text: str, outputs
                 ) -> tuple[dict, list[Check]]:
    """Parse the compiled HLO + output shards; return (measurements,
    per-case checks)."""
    vc = case.cluster
    R = vc.num_devices
    cb = parse_collectives(hlo_text, num_devices=R, pod_size=vc.chips)
    result_node = measured_result_node(case, outputs)
    meas = {
        "fast_link_bytes_per_chip": cb.fast,
        "slow_link_bytes_per_chip": cb.slow,
        "fast_link_bytes_total": cb.fast * R,
        "slow_link_bytes_total": cb.slow * R,
        "by_op": dict(cb.by_op),
        "result_bytes_per_node": result_node,
    }

    exp_fast, exp_slow = expected_links(case)
    checks = [
        Check("link/fast", exp_fast, cb.fast,
              "per-chip intra-pod link bytes (ring model) of the scheme's "
              "known collective sequence"),
        Check("link/slow", exp_slow, cb.slow,
              "per-chip bridge link bytes (ring model) of the scheme's "
              "known collective sequence"),
        Check("result/node", expected_result_node(case), result_node,
              "resident result bytes on node 0, summed over real output "
              "shards"),
    ]
    checks.extend(_model_checks(case, cb.fast * R, cb.slow * R, result_node))
    return meas, checks


def _model_checks(case: BenchCase, fast_total: float, slow_total: float,
                  result_node: int) -> list[Check]:
    """Documented exact identities between parsed bytes and plans.py."""
    Pn, c = case.cluster.pods, case.cluster.chips
    tr = case.traffic
    fam, sch = case.family, case.scheme
    out: list[Check] = []
    if fam == "allgather":
        m = case.elems * ELEM_BYTES
        tr_shared = allgather_traffic(scheme="hier", num_nodes=Pn,
                                      ranks_per_node=c, bytes_per_rank=m)
        if sch == "shared":
            out.append(Check("model/bridge-bytes", tr.slow_bytes, slow_total,
                             "bridge wire bytes == model slow_bytes (node "
                             "regions cross once)"))
            out.append(Check("model/fast-bytes", tr.fast_bytes, fast_total,
                             "zero intra-node copy bytes — paper C2"))
        elif sch == "hier" and Pn > 1:
            out.append(Check("model/bridge-bytes",
                             c * tr_shared.slow_bytes, slow_total,
                             "full replication pays C1 on the wire: "
                             "ranks_per_node x the shared bridge bytes"))
        if sch in ("naive", "shared"):
            out.append(Check("model/result-node", tr.result_bytes_per_node,
                             result_node,
                             "resident result bytes == model "
                             "result_bytes_per_node"))
    elif fam == "broadcast":
        # The psum emulation of a one-way broadcast moves data up AND back
        # down the ring: every wire identity carries an exact factor 2.
        if sch == "naive":
            out.append(Check("model/total-bytes",
                             2 * (tr.slow_bytes + tr.fast_bytes),
                             fast_total + slow_total,
                             "psum-emulated bcast costs exactly 2x the "
                             "model's one-way bytes"))
        elif sch == "hier":
            # every chip of a pod participates in the emulated bridge psum:
            # full replication pays C1 on the wire (x ranks_per_node).
            out.append(Check("model/bridge-bytes", 2 * c * tr.slow_bytes,
                             slow_total,
                             "replicated bridge == 2 x ranks_per_node x "
                             "model slow_bytes (C1 on the wire)"))
            out.append(Check("model/fast-bytes", 2 * tr.fast_bytes,
                             fast_total,
                             "intra-pod psum == 2x the model's "
                             "leader-to-children copy bytes"))
        else:                          # shared
            out.append(Check("model/bridge-bytes", 2 * tr.slow_bytes,
                             slow_total,
                             "shard bridge == 2x model slow_bytes (one "
                             "shared copy crosses once, psum-doubled)"))
        if sch in ("naive", "shared"):
            out.append(Check("model/result-node", tr.result_bytes_per_node,
                             result_node,
                             "resident result bytes == model "
                             "result_bytes_per_node"))
    elif fam == "psum":
        m = case.elems * ELEM_BYTES
        trh = allreduce_traffic(scheme="hier", num_nodes=Pn,
                                ranks_per_node=c, msg_bytes=m)
        if sch == "naive":
            out.append(Check("model/total-bytes",
                             tr.slow_bytes + tr.fast_bytes,
                             fast_total + slow_total,
                             "flat ring allreduce total == model ring "
                             "bytes"))
        else:
            out.append(Check("model/bridge-bytes", Pn * trh.slow_bytes,
                             slow_total,
                             "c parallel shard rings sum to num_nodes x "
                             "the model's per-node bridge bytes"))
            factor = c if sch == "hier" else c / 2
            out.append(Check("model/fast-bytes", factor * trh.fast_bytes,
                             fast_total,
                             "intra-node RS(+AG) vs the model's per-node "
                             "RS+AG cycle (shared skips the AG half)"))
        if sch in ("naive", "shared"):
            out.append(Check("model/result-node", tr.result_bytes_per_node,
                             result_node,
                             "resident result bytes == model "
                             "result_bytes_per_node"))
    elif fam == "allgatherv":
        if sch == "shared" and Pn > 1:
            R = Pn * c
            S = sum(case.populations)      # present ranks
            # subtract the (tiny, closed-form) int32 counts exchange from
            # the MEASURED bridge bytes; what remains is the padded data
            # exchange, which scaled by the compact fraction S/R must hit
            # the model's GatherPlan-compact bridge bytes.  Unlike the
            # link/slow check this anchors the model identity to the
            # parsed HLO: a rewritten lowering moves slow_total and fails.
            counts_slow_total = R * 4 * (Pn - 1)
            data_slow_total = slow_total - counts_slow_total
            out.append(Check("model/bridge-bytes", tr.slow_bytes,
                             data_slow_total * S / R,
                             "measured padded bridge bytes (minus the "
                             "counts exchange) x compact fraction == model "
                             "compact bridge bytes (GatherPlan)"))
    return out


# ---------------------------------------------------------------------------
# Cross-scheme (C1) checks + failure aggregation
# ---------------------------------------------------------------------------

def cross_scheme_checks(results: Sequence[CaseResult]) -> list[Check]:
    """Paper C1 as a measured invariant: within every (family, topology,
    size) group, naive resident-result bytes / shared resident-result bytes
    == ranks_per_node — from the actual output shards."""
    by_key: dict[tuple, dict] = {}
    for r in results:
        k = (r.case.family, r.case.topology, r.case.elems)
        by_key.setdefault(k, {})[r.case.scheme] = r
    checks = []
    for (fam, topo, elems), group in sorted(by_key.items()):
        if "naive" not in group or "shared" not in group:
            continue
        c = group["naive"].case.cluster.chips
        naive_b = group["naive"].hlo["result_bytes_per_node"]
        shared_b = group["shared"].hlo["result_bytes_per_node"]
        checks.append(Check(
            f"C1/{fam}/{topo}/e{elems}", c, naive_b / shared_b,
            "naive/shared resident-result ratio == ranks_per_node "
            f"(naive {naive_b} B, shared {shared_b} B per node)",
            tol=1e-9))
        if "hier" in group:
            hier_b = group["hier"].hlo["result_bytes_per_node"]
            checks.append(Check(
                f"C1/{fam}/{topo}/e{elems}/hier-replicates", naive_b, hier_b,
                "the two-phase hier schedule is replication-class: same "
                "resident bytes as naive", tol=0.0))
    return checks


def raise_on_failure(results: Sequence[CaseResult],
                     cross: Sequence[Check]) -> None:
    lines = []
    for r in results:
        for ch in r.checks:
            if not ch.ok:
                lines.append(f"  {r.case.name} :: {ch.name}: expected "
                             f"{ch.expected}, measured {ch.measured} "
                             f"({ch.note})")
    for ch in cross:
        if not ch.ok:
            lines.append(f"  {ch.name}: expected {ch.expected}, measured "
                         f"{ch.measured} ({ch.note})")
    if lines:
        raise BenchValidationError(
            "traffic-model cross-check FAILED for "
            f"{len(lines)} check(s):\n" + "\n".join(lines))
