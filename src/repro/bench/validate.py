"""Traffic-model cross-checks: compiled HLO vs the scheme registry.

Three layers run for every measured config; ANY mismatch fails the bench
run (``BenchValidationError``):

1. **Lowering check** (``link/fast``, ``link/slow``) — the per-chip link
   bytes parsed out of the compiled HLO by
   ``analysis.roofline.parse_collectives`` (ring model) must equal the
   scheme's self-described closed form for the exact collective sequence it
   lowers to (``repro.comm.registry.CollectiveScheme.links``).  This pins
   the compiled artifact: an XLA rewrite, a wrong replica group, or an
   accidental extra collective shows up here.

2. **Model identities** (``model/*``) — documented exact mappings between
   the parsed wire/resident bytes and the ``core.plans`` traffic model,
   declared by each scheme (``CollectiveScheme.identities``): e.g. the
   shared allgather's bridge bytes == model ``slow_bytes`` with zero
   intra-node bytes (paper C2); the hier allgather paying C1 *on the wire*;
   the psum-emulated broadcast's exact factor 2; the irregular allgatherv's
   padded-to-compact GatherPlan scaling; the node-aware alltoall's
   superchunks crossing the bridge exactly once.

3. **C1, the paper's memory claim** (``C1/*``) — within every (family,
   topology, size) group holding both result classes, the measured
   replicated/shared resident-result ratio equals ranks_per_node, from the
   real shards on the real devices; and every replicated-class scheme holds
   identical resident bytes.

Nothing here matches scheme *names*: expectations come from the registry
entry, so a newly registered scheme is cross-checked automatically.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax

from repro.analysis.roofline import parse_collectives
from repro.bench.suites import BenchCase, CaseResult
from repro.comm import registry


class BenchValidationError(AssertionError):
    """The compiled HLO disagrees with the traffic model (or C1 broke)."""


@dataclasses.dataclass
class Check:
    name: str
    expected: float
    measured: float
    note: str = ""
    # link-byte expectations are exact under the ring model; tolerance only
    # absorbs float accumulation in the parser and int truncation in plans.
    tol: float = 2.0
    # one-sided checks assert measured <= expected (+tol): error bounds are
    # ceilings, not equalities — beating the bound is a pass.
    one_sided: bool = False

    @property
    def ok(self) -> bool:
        slack = max(self.tol, 1e-9 * abs(self.expected))
        if self.one_sided:
            return self.measured <= self.expected + slack
        return abs(self.measured - self.expected) <= slack

    def to_dict(self) -> dict:
        d = {"name": self.name, "expected": self.expected,
             "measured": self.measured, "ok": self.ok, "note": self.note}
        if self.one_sided:
            d["one_sided"] = True
        return d


# ---------------------------------------------------------------------------
# Registry-supplied expectations
# ---------------------------------------------------------------------------

def expected_links(case: BenchCase, opts: dict = None) -> tuple[float, float]:
    """Expected (fast, slow) per-chip link bytes of the case's lowering.
    ``opts`` is the tunable candidate being inspected: quantized schemes
    price the wire per ``block``, so their closed form is candidate-aware."""
    vc = case.cluster
    return registry.get_scheme(case.scheme).links(
        case.family, pods=vc.pods, chips=vc.chips, fast_shape=vc.fast_shape,
        elems=case.elems, elem_bytes=case.wire_elem_bytes, opts=opts,
        dtype=case.dtype)


def expected_result_node(case: BenchCase) -> int:
    """Expected resident result bytes on ONE node (pod), from the scheme's
    known output layout: replicated schemes keep ranks_per_node copies,
    shared keeps one."""
    vc = case.cluster
    return registry.get_scheme(case.scheme).result_node(
        case.family, pods=vc.pods, chips=vc.chips, elems=case.elems,
        elem_bytes=case.elem_bytes)


# ---------------------------------------------------------------------------
# Measurement
# ---------------------------------------------------------------------------

def _node0_devices(case: BenchCase):
    devs = case.cluster.mesh.devices
    if case.cluster.pods > 1:
        devs = devs[(0,) * len(case.cluster.slow_names)]
    return {d for d in devs.flatten().tolist()}


def measured_result_node(case: BenchCase, outputs) -> int:
    """Resident result bytes on node 0, from the real output shards."""
    node0 = _node0_devices(case)
    total = 0
    for leaf in jax.tree.leaves(outputs):
        for sh in leaf.addressable_shards:
            if sh.device in node0:
                total += sh.data.nbytes
    return total


def inspect_case(case: BenchCase, hlo_text: str, outputs,
                 opts: dict = None) -> tuple[dict, list[Check]]:
    """Parse the compiled HLO + output shards; return (measurements,
    per-case checks).  ``opts`` is the tunable candidate the inspected
    executable was compiled with (quantized schemes' wire bytes and error
    model depend on their ``block``)."""
    vc = case.cluster
    R = vc.num_devices
    cb = parse_collectives(hlo_text, num_devices=R, pod_size=vc.chips)
    result_node = measured_result_node(case, outputs)
    meas = {
        "fast_link_bytes_per_chip": cb.fast,
        "slow_link_bytes_per_chip": cb.slow,
        "fast_link_bytes_total": cb.fast * R,
        "slow_link_bytes_total": cb.slow * R,
        "by_op": dict(cb.by_op),
        "result_bytes_per_node": result_node,
    }

    exp_fast, exp_slow = expected_links(case, opts)
    checks = [
        Check("link/fast", exp_fast, cb.fast,
              "per-chip intra-pod link bytes (ring model) of the scheme's "
              "known collective sequence"),
        Check("link/slow", exp_slow, cb.slow,
              "per-chip bridge link bytes (ring model) of the scheme's "
              "known collective sequence"),
        Check("result/node", expected_result_node(case), result_node,
              "resident result bytes on node 0, summed over real output "
              "shards"),
    ]
    sch = registry.get_scheme(case.scheme)
    for name, expected, measured, note in sch.identities(
            case.family, traffic=case.traffic, pods=vc.pods, chips=vc.chips,
            elems=case.elems, elem_bytes=case.wire_elem_bytes,
            fast_shape=vc.fast_shape, populations=case.populations,
            fast_total=cb.fast * R, slow_total=cb.slow * R,
            result_node=result_node):
        checks.append(Check(name, expected, measured, note))
    # lossy schemes: measured end-to-end quantization error must sit inside
    # the declared bound (host-side numpy reference — exact arithmetic)
    err = sch.error_check(case.family, inputs=case.make_args(),
                          output=outputs, pods=vc.pods, chips=vc.chips,
                          elems=case.elems, dtype=case.dtype, opts=opts)
    if err is not None:
        bound, measured_err = err
        checks.append(Check(
            "error/bound", bound, measured_err,
            "max abs quantization error vs the exact host-side reference; "
            "the scheme's declared error model is a ceiling",
            tol=0.0, one_sided=True))
    return meas, checks


# ---------------------------------------------------------------------------
# Tuning-table winner cross-check
# ---------------------------------------------------------------------------

def tuning_table_checks(table, report: dict, *,
                        rel_tol: float = 1.0) -> list[Check]:
    """Every MEASURED tuning-table entry's winner must actually have the
    best pooled median in the bench run being checked.

    Two callers, one rule:

    * ``--emit-tuning-table`` passes the table TOGETHER WITH the report it
      was folded from (``rel_tol=1.0``): a mismatch means the fold itself
      is broken — the table would steer ``scheme="auto"`` away from the
      run's own winners.
    * the nightly staleness gate passes the COMMITTED table with a fresh
      report and a tolerance band: the committed winner may trail the
      fresh winner by up to ``rel_tol``x before the table counts as stale.

    Cells only one side measured are skipped; ZERO overlapping cells is a
    failing check (a gate that compares nothing passes forever).
    """
    from repro.comm.tuning import TuningTable, bench_cells

    if isinstance(table, dict):
        table = TuningTable.from_dict(table)
    cells = bench_cells(report)
    checks: list[Check] = []
    overlap = 0
    for entry in table.entries:
        if entry.source != "measured":
            continue
        key = (entry.family, entry.topo, entry.dtype, entry.nbytes)
        cell = cells.get(key)
        if cell is None:
            continue
        overlap += 1
        name = (f"tuning/{entry.family}/{entry.topo}/"
                f"b{entry.nbytes}")
        best_med = min(med for med, _ in cell["schemes"].values())
        winner = cell["schemes"].get(entry.best.scheme)
        if winner is None:
            checks.append(Check(
                name, best_med, -1.0,
                f"table winner {entry.best.scheme!r} was not timed in this "
                "run — regenerate the table from a sweep that covers it",
                tol=0.0))
            continue
        checks.append(Check(
            name, best_med, winner[0],
            f"table winner {entry.best.scheme!r} vs the run's best pooled "
            f"median (band {rel_tol}x)",
            tol=max(best_med * (rel_tol - 1.0), 0.0)))
    if not overlap:
        checks.append(Check(
            "tuning/overlap", 1.0, 0.0,
            "no (family, topology, dtype, size) cell appears in both the "
            "tuning table and the bench report — nothing was cross-checked",
            tol=0.0))
    return checks


# ---------------------------------------------------------------------------
# Cross-scheme (C1) checks + failure aggregation
# ---------------------------------------------------------------------------

def cross_scheme_checks(results: Sequence[CaseResult]) -> list[Check]:
    """Paper C1 as a measured invariant: within every (family, topology,
    size) group holding both result classes, the replicated/shared
    resident-result byte ratio — from the actual output shards — equals
    the registry's closed-form ratio.  For full-result families that ratio
    IS ranks_per_node (the paper's claim); for ``reduce_scatter`` the flat
    scheme keeps only its node's 1/num_nodes share while the window keeps
    the whole reduced message, so the closed-form ratio is 1/num_nodes.
    Every replicated-class scheme must also hold identical resident bytes
    (the two-phase/pipelined schedule does not change the memory class)."""
    by_key: dict[tuple, dict] = {}
    for r in results:
        k = (r.case.family, r.case.topology, r.case.elems, r.case.dtype)
        by_key.setdefault(k, {})[r.case.scheme] = r
    checks = []
    for (fam, topo, elems, dtype), group in sorted(by_key.items()):
        reps = [s for s in registry.scheme_names()
                if s in group
                and registry.get_scheme(s).result_class == "replicated"]
        shared = [s for s in registry.scheme_names()
                  if s in group
                  and registry.get_scheme(s).result_class == "shared"]
        if not reps or not shared:
            continue
        base, sh = reps[0], shared[0]
        vc = group[base].case.cluster
        c = vc.chips
        eb = group[base].case.elem_bytes
        exp_rep = registry.get_scheme(base).result_node(
            fam, pods=vc.pods, chips=c, elems=elems, elem_bytes=eb)
        exp_sh = registry.get_scheme(sh).result_node(
            fam, pods=vc.pods, chips=c, elems=elems, elem_bytes=eb)
        expected = exp_rep / exp_sh
        rep_b = group[base].hlo["result_bytes_per_node"]
        shared_b = group[sh].hlo["result_bytes_per_node"]
        what = "ranks_per_node" if expected == c \
            else "the registry closed-form ratio"
        tag = f"C1/{fam}/{topo}/e{elems}" if dtype == "float32" \
            else f"C1/{fam}/{topo}/e{elems}/{dtype}"
        checks.append(Check(
            tag, expected, rep_b / shared_b,
            f"{base}/{sh} resident-result ratio == {what} "
            f"({base} {rep_b} B, {sh} {shared_b} B per node)",
            tol=1e-9))
        for other in reps[1:]:
            other_b = group[other].hlo["result_bytes_per_node"]
            checks.append(Check(
                f"{tag}/{other}-replicates", rep_b,
                other_b,
                f"the {other} schedule is replication-class: same resident "
                f"bytes as {base}", tol=0.0))
    return checks


def raise_on_failure(results: Sequence[CaseResult],
                     cross: Sequence[Check]) -> None:
    lines = []
    for r in results:
        for ch in r.checks:
            if not ch.ok:
                lines.append(f"  {r.case.name} :: {ch.name}: expected "
                             f"{ch.expected}, measured {ch.measured} "
                             f"({ch.note})")
    for ch in cross:
        if not ch.ok:
            lines.append(f"  {ch.name}: expected {ch.expected}, measured "
                         f"{ch.measured} ({ch.note})")
    if lines:
        raise BenchValidationError(
            "traffic-model cross-check FAILED for "
            f"{len(lines)} check(s):\n" + "\n".join(lines))
