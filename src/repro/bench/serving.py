"""``serving`` bench family: continuous-batching decode steps under load.

The ``step_time`` family times whole train steps; this family times the
serving engine's inner loop — ONE decode step over a full slot batch with
a heterogeneous per-slot position vector, weights living in the pod's
one-copy-per-node window store (the ``serve_fsdp`` layout).  Two schemes:

* ``sync``     — issue-at-use baseline: ``model.decode_fn`` with every
  window gather issued inside the unit body at use time;
* ``recorded`` — ``repro.serving.recorded.RecordedDecoder``: the step's
  window gathers recorded into one ``CollectiveGraph``, deduped and
  front-loaded behind a shared ordering token, replayed per batch
  signature.  Outputs bit-identical to ``sync`` (asserted in
  ``tests/test_serving_engine.py``).

Like ``step_time``, a decode step's collective content is whatever the
model traced, so each scheme carries a per-config jaxpr **link inventory**
(``link_inventory``) that ``repro.bench.validate`` cross-checks against
the compiled HLO's ring-model bytes.  Decode token batches come from the
deterministic ``repro.data.synthetic`` stream.

The measured step median then prices an **open-loop Poisson load model**
(``serving_metrics``): requests arrive at a fixed offered utilization,
occupy one of ``slots`` decode lanes for ``max_new`` steps, and every
emitted token's latency sample is recorded — ``tokens_per_s`` plus
p50/p99 per-token latency land in the case's report record per matrix
topology.  The simulation is a pure, seeded function of the measured
median, so reports stay deterministic given the timing.

Case sizing: ``elems`` is the model's global parameter element count —
deterministic per config, so quick (CI) and full sweeps land on the same
(family, topology, dtype, size) cells and stay comparable.
"""

from __future__ import annotations

from types import MappingProxyType

import jax
import jax.numpy as jnp
import numpy as np

from repro.bench.step_time import (StepTimeScheme, _no_dispatch,
                                   link_inventory)
from repro.bench.suites import BenchCase, _swept
from repro.comm import registry
from repro.comm.registry import register_scheme
from repro.configs import get_config

#: model-zoo configs the family times (reduced shapes; dense untied
#: global-attention entry on purpose: pow2 prompt bucketing applies and no
#: tied-leaf gather is CSE-merged behind the jaxpr inventory's back).
SERVE_CONFIGS = ("starcoder2-7b",)
SERVE_SLOTS = 4                 # decode lanes = batch rows per step
SERVE_SMAX = 32                 # KV page length per lane

#: open-loop load-model constants (pure function of the measured median —
#: fixed here so every report row is comparable across topologies/runs)
LOAD_MAX_NEW = 8
LOAD_REQUESTS = 64
LOAD_UTILIZATION = 0.8
LOAD_SEED = 0


# ---------------------------------------------------------------------------
# The two serving schemes
# ---------------------------------------------------------------------------

class ServingScheme(StepTimeScheme):
    """Base of the ``serving`` schemes: per-config recorded link inventory
    (no closed form in (pods, chips, elems) exists for a traced decode)."""

    FAMILY = "serving"
    ops = MappingProxyType({"serving": _no_dispatch})
    N_OUT = 2                   # logits + cache checksums: replicated f32


class ServeSyncScheme(ServingScheme):
    """Issue-at-use baseline: ``model.decode_fn`` — every unit's window
    gather issued inside the unit body when the weight is used."""

    name = "sync"


class ServeRecordedScheme(ServingScheme):
    """The recorded decode step: window gathers recorded into one
    ``CollectiveGraph`` (``repro.serving.recorded.RecordedDecoder``),
    same-epoch duplicates deduped, issues front-loaded behind one ordering
    token, replayed per batch signature.  Bit-identical to ``sync``."""

    name = "recorded"


SYNC = register_scheme(ServeSyncScheme())
RECORDED = register_scheme(ServeRecordedScheme())


# ---------------------------------------------------------------------------
# Open-loop Poisson load model
# ---------------------------------------------------------------------------

def serving_metrics(step_us: float, *, slots: int = SERVE_SLOTS,
                    max_new: int = LOAD_MAX_NEW,
                    n_requests: int = LOAD_REQUESTS,
                    utilization: float = LOAD_UTILIZATION,
                    seed: int = LOAD_SEED) -> dict:
    """Open-loop Poisson serving simulation priced by one measured median.

    Requests arrive as a Poisson process offered at ``utilization`` of the
    engine's token capacity (``slots`` lanes, each token costing one
    ``step_us`` engine step); a request occupies one lane for ``max_new``
    steps and queues FIFO while all lanes are busy.  Every emitted token
    contributes one latency sample: a request's FIRST token pays its queue
    wait plus one step (time-to-first-token under load), later tokens pay
    the inter-token step time.  Deterministic: seeded arrivals, discrete
    event loop, no wall clock.
    """
    if step_us <= 0:
        raise ValueError("step_us must be positive")
    step_s = step_us * 1e-6
    rate = utilization * slots / (max_new * step_s)   # offered requests/s
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_requests))
    lanes: list[list] = []      # [steps_remaining, last_event_time]
    t = 0.0
    nxt = 0
    latencies: list[float] = []
    tokens = 0
    while nxt < n_requests or lanes:
        if not lanes:           # idle: jump to the next arrival
            t = max(t, arrivals[nxt])
        while (nxt < n_requests and len(lanes) < slots
               and arrivals[nxt] <= t):
            lanes.append([max_new, arrivals[nxt]])
            nxt += 1
        t_end = t + step_s
        for lane in lanes:
            latencies.append(t_end - lane[1])
            lane[1] = t_end
            lane[0] -= 1
            tokens += 1
        lanes = [ln for ln in lanes if ln[0] > 0]
        t = t_end
    lat_ms = np.asarray(latencies) * 1e3
    return {
        "tokens_per_s": float(tokens / t),
        "p50_token_ms": float(np.percentile(lat_ms, 50)),
        "p99_token_ms": float(np.percentile(lat_ms, 99)),
        "step_us": float(step_us),
        "slots": slots, "max_new": max_new, "requests": n_requests,
        "utilization": utilization, "offered_rps": float(rate),
        "sim_seed": seed,
    }


# ---------------------------------------------------------------------------
# Case builder
# ---------------------------------------------------------------------------

def serving_cases(vc, on_skip=None, schemes=None):
    """One case per (model config, serving scheme) on this cluster.

    Builds the slot-batch decode-step body in the ``serve_fsdp`` layout
    (weights once per node in the window store), records its jaxpr link
    inventory on the scheme, and yields a ``BenchCase`` whose HLO the
    validate layer must match."""
    from repro.data.synthetic import DataConfig, SyntheticLM
    from repro.models.transformer import build
    from repro.runtime.steps import cluster_ctx
    from repro.serving.recorded import RecordedDecoder

    for cfg_name in SERVE_CONFIGS:
        cfg = get_config(cfg_name).reduced()
        ctx = cluster_ctx(vc, opts=("serve_fsdp",))
        sizes = dict(zip(vc.axis_names, vc.axis_shapes))
        data = 1
        for a in ctx.fsdp_axes:
            data *= sizes[a]
        model = build(cfg, ctx, data=data)
        pshapes = jax.eval_shape(model.init_params)
        _, tdef = jax.tree.flatten(pshapes)
        elems = 0
        for leaf in jax.tree.leaves(pshapes):
            n = 1
            for d in leaf.shape:
                n *= d
            elems += n
        pspecs = model.param_specs(
            serve=True, tp_axis=ctx.tp_axis,
            fsdp_axis=ctx.fsdp_axes[0] if ctx.fsdp_axes else None)
        from jax.sharding import PartitionSpec as P
        in_specs = tuple(jax.tree.leaves(pspecs)) + (P(), P())
        out_specs = (P(), P())
        axes = vc.axis_names

        def make_args(model=model, cfg=cfg):
            params = model.init_params(0)
            stream = SyntheticLM(DataConfig(
                vocab=cfg.vocab, seq_len=SERVE_SMAX,
                global_batch=SERVE_SLOTS, seed=7))
            toks = stream.next_batch()["tokens"]
            tok = jnp.asarray(toks[:, :1].astype(np.int32))
            # heterogeneous per-slot positions: the continuous-batching
            # signature (every lane mid-stream at a different depth)
            pos = jnp.asarray((np.arange(SERVE_SLOTS) * 5 + 1) % SERVE_SMAX,
                              jnp.int32)
            return tuple(jax.tree.leaves(params)) + (tok, pos)

        for sch in _swept(registry.schemes_for("serving"), schemes):
            decode = RecordedDecoder(model) if sch.name == "recorded" \
                else model.decode_fn

            def body(*args, _decode=decode, _tdef=tdef, _model=model,
                     _axes=axes):
                pl, tok, pos = args[:-2], args[-2], args[-1]
                p = jax.tree.unflatten(_tdef, pl)
                cache = _model.cache_init(SERVE_SLOTS, SERVE_SMAX)
                new_cache, logits = _decode(p, cache, tok, pos)
                # two replicated f32 scalars keep logits AND the cache
                # update alive under DCE (psum over the whole mesh: cache
                # shards are tp-rank-local, the sum is not)
                # raw-collective: result-liveness checksum reduction
                chk_l = jax.lax.psum(
                    jnp.sum(logits.astype(jnp.float32)), _axes)
                chk_c = jnp.float32(0.0)
                for leaf in jax.tree.leaves(new_cache):
                    chk_c += jnp.sum(leaf.astype(jnp.float32))
                chk_c = jax.lax.psum(chk_c, _axes)  # raw-collective: checksum
                return chk_l, chk_c

            avals = tuple(jax.ShapeDtypeStruct(a.shape, a.dtype)
                          for a in make_args())
            fast_b, slow_b = link_inventory(
                vc.smap(body, in_specs, out_specs), avals, vc)
            sch.record(pods=vc.pods, chips=vc.chips,
                       fast_shape=vc.fast_shape, elems=elems,
                       fast=fast_b, slow=slow_b)
            yield BenchCase(
                "serving", sch.name, vc, elems,
                body=body, in_specs=in_specs, out_specs=out_specs,
                make_args=make_args,
                traffic=sch.traffic_for(pods=vc.pods, chips=vc.chips,
                                        fast_shape=vc.fast_shape,
                                        elems=elems))
