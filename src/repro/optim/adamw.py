"""AdamW on parameter shards.

In hier mode the optimizer state inherits the paper's one-copy-per-pod
layout for free: m/v are allocated exactly like the FSDP param shards, the
update runs on the shard, and nothing is ever replicated (ZeRO-style, but
derived from the paper's shared-window rule rather than bolted on).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params):
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)
    return jax.tree.map(zeros, params), jax.tree.map(zeros, params)


def adamw_update(params, grads, m, v, step, *, lr, weight_decay=0.1,
                 b1=0.9, b2=0.95, eps=1e-8):
    stepf = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** stepf
    c2 = 1.0 - b2 ** stepf

    def upd(p, g, m_, v_):
        g32 = g.astype(jnp.float32)
        m_n = b1 * m_ + (1.0 - b1) * g32
        v_n = b2 * v_ + (1.0 - b2) * g32 * g32
        mhat = m_n / c1
        vhat = v_n / c2
        p32 = p.astype(jnp.float32)
        p_n = p32 - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p32)
        return p_n.astype(p.dtype), m_n, v_n

    flat_p, td = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(m)
    flat_v = jax.tree.leaves(v)
    out = [upd(p, g, m_, v_) for p, g, m_, v_ in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(td, [o[0] for o in out])
    new_m = jax.tree.unflatten(td, [o[1] for o in out])
    new_v = jax.tree.unflatten(td, [o[2] for o in out])
    return new_p, new_m, new_v


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        stepf = jnp.asarray(step, jnp.float32)
        warm = stepf / jnp.maximum(warmup, 1)
        prog = jnp.clip((stepf - warmup) / jnp.maximum(total - warmup, 1),
                        0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return base_lr * jnp.where(stepf < warmup, warm, 0.1 + 0.9 * cos)
    return lr
