"""DEPRECATED gradient-compression free functions (one-release shims).

The int8 bridge wire format now lives in the scheme registry: ``q8_hier``
(`repro.comm.quantize` bodies) reached through
``Communicator.allreduce(..., precision="lossy")`` or
``ParallelCtx.reduce_grads(..., precision="lossy")`` — the residual state
of error feedback rides the same call (``error_state=`` / the returned new
state).  Nothing here should gain new call sites
(``scripts/check_api_surface.py`` flags them); the shims below delegate to
the registry bodies and warn.

The per-tensor absmax scale of the original ``_quantize`` is gone: the
shared cores quantize per ``block`` (default
``repro.comm.quantize.DEFAULT_BLOCK``), so one outlier gradient leaf no
longer collapses every other element's grid to zero.

Migration table:

=====================================  ====================================
deprecated                             replacement
=====================================  ====================================
``int8_bridge_psum(g, axes)``          ``Communicator(fast_axis=axes)``
                                       ``.allreduce(g, precision="lossy")``
``make_error_feedback(params)``        ``reduce_grads(grads, metas,``
                                       ``precision="lossy",``
                                       ``error_state=state)``
=====================================  ====================================
"""

from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp

from repro.comm import quantize as qz


def _warn(name: str, repl: str) -> None:
    warnings.warn(
        f"repro.optim.compression.{name} is deprecated; use {repl} "
        f"(removal next release)", DeprecationWarning, stacklevel=3)


def _quantize(g32: jax.Array, axes, *, stochastic: bool = False, key=None):
    """Per-BLOCK int8 quantization (scales agreed over ``axes`` via pmax).

    Returns ``(q, scale)`` with ``q`` int8 ``(n_blocks, block)`` and
    ``scale`` f32 ``(n_blocks,)`` — per-block now, so an outlier only
    collapses its own block's grid.
    """
    q, scale, _ = qz.block_quantize(g32, block=qz.DEFAULT_BLOCK,
                                    shared_axes=axes, stochastic=stochastic,
                                    key=key)
    return q, scale


def int8_bridge_psum(g: jax.Array, axes, *, stochastic: bool = False,
                     key=None) -> jax.Array:
    """Quantized psum over ``axes`` (the bridge).  DEPRECATED shim."""
    _warn("int8_bridge_psum",
          "Communicator.allreduce(..., precision='lossy')")
    return qz.q8_psum_flat(g, axes, stochastic=stochastic, key=key)


def make_error_feedback(params_like):
    """Returns (init_state, compress_fn(g, axes, state) -> (g_red, state)).
    DEPRECATED shim over the registry error-feedback path
    (``reduce_grads(..., precision="lossy", error_state=...)``)."""
    _warn("make_error_feedback",
          "reduce_grads(..., precision='lossy', error_state=...)")

    def init():
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                            params_like)

    def compress_leaf(g, err, axes):
        # residual of the LOCAL quantization only: the psum total includes
        # the other pods' contributions, so `g32 - total` would grow like
        # (P-1)*g per step and the feedback would diverge instead of
        # correcting rounding bias.
        return qz.q8_psum_flat(g, axes, err=err)

    return init, compress_leaf
