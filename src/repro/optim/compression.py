"""Cross-pod gradient compression (beyond-paper slow-tier optimization).

The paper's bridge exchange is the only slow-tier traffic; int8-quantizing
the bridge psum cuts it 4x (fp32) / 2x (bf16).  Error feedback keeps the
quantization bias out of the optimizer trajectory: the residual of each
step's quantization is added back before the next quantization.

Stateless variant (``int8_bridge_psum``) quantizes per-call with a shared
absmax scale: q = round(g / s * 127); psum(q) stays exact in int32 for up to
2^23/127 pods, so the only error is the rounding — bounded by s/254 per
element and unbiased with stochastic rounding.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def _quantize(g32: jax.Array, axes, *, stochastic: bool = False, key=None):
    """int8-quantize ``g32`` with an absmax scale agreed over ``axes`` via a
    tiny fp32 pmax (one scalar per tensor).  Returns (q, scale)."""
    amax = jnp.max(jnp.abs(g32))
    amax = lax.pmax(amax, axes)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    x = g32 / scale
    if stochastic and key is not None:
        x = jnp.floor(x + jax.random.uniform(key, x.shape))
    else:
        x = jnp.round(x)
    q = jnp.clip(x, -127, 127).astype(jnp.int8)
    return q, scale


def int8_bridge_psum(g: jax.Array, axes, *, stochastic: bool = False,
                     key=None) -> jax.Array:
    """Quantized psum over ``axes`` (the bridge)."""
    g32 = g.astype(jnp.float32)
    q, scale = _quantize(g32, axes, stochastic=stochastic, key=key)
    # int16 on the wire: exact for <= 256 pods (sum <= 127*256 < 2^15) and
    # half the fp32 bridge bytes; int8 itself would overflow at 2 pods.
    # raw-collective: int16 wire format, registry has no dtype dispatch
    total = lax.psum(q.astype(jnp.int16), axes)
    return (total.astype(jnp.float32) * scale).astype(g.dtype)


def make_error_feedback(params_like):
    """Returns (init_state, compress_fn(g, axes, state) -> (g_red, state)).
    Residuals live on the gradient shards — same one-copy-per-pod layout."""
    def init():
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                            params_like)

    def compress_leaf(g, err, axes):
        g32 = g.astype(jnp.float32) + err
        q, scale = _quantize(g32, axes)
        # residual of the LOCAL quantization only: the psum total includes
        # the other pods' contributions, so `g32 - total` would grow like
        # (P-1)*g per step and the feedback would diverge instead of
        # correcting rounding bias.
        new_err = g32 - q.astype(jnp.float32) * scale
        # raw-collective: int16 wire format (same as bridge path)
        total = lax.psum(q.astype(jnp.int16), axes)
        out = (total.astype(jnp.float32) * scale).astype(g.dtype)
        return out, new_err

    return init, compress_leaf
