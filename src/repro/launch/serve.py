"""Serving launcher: batched greedy generation with a reduced-config model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --batch 4 \
        --prompt-len 32 --max-new 16
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.models import build_by_name
from repro.serving.engine import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    model = build_by_name(args.arch, reduced=True)
    params = model.init_params(0)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, model.cfg.vocab,
                           size=(args.batch, args.prompt_len)).astype(np.int32)
    res = greedy_generate(model, params, prompts, max_new=args.max_new,
                          temperature=args.temperature)
    for b in range(args.batch):
        print(f"req{b}: {res.tokens[b].tolist()}")
    print("mean logprob:", float(res.logprobs.mean()))


if __name__ == "__main__":
    main()
