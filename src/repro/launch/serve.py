"""Serving launcher: continuous batching under open-loop Poisson load.

Drives :class:`repro.serving.scheduler.ContinuousBatchingScheduler` the way
a real frontend would: requests with heterogeneous prompt lengths arrive on
a Poisson process (open loop — arrivals do not wait for completions), are
admitted through the bounded queue, and decode together in fixed slots.
Reports throughput (tokens/sec), decode-step latency percentiles, and
end-to-end request latency percentiles; ``--live-tuning`` attaches a
:class:`repro.serving.live_tuning.LiveTuner` so the session's measured
decode latencies build a session-local tuning overlay.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --requests 32 --slots 4 --max-new 8 --rate 50

``--rate 0`` (the default) submits everything up front — a closed batch,
useful for a quick throughput number without wall-clock waiting.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.data.synthetic import DataConfig, SyntheticLM
from repro.models import build_by_name
from repro.serving.queue import AdmissionError
from repro.serving.scheduler import ContinuousBatchingScheduler


def _pct(xs, q):
    if not xs:
        return 0.0
    s = sorted(xs)
    return s[min(len(s) - 1, max(0, int(np.ceil(q * len(s))) - 1))]


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="continuous-batching serving driver (synthetic load)")
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-max", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--s-max", type=int, default=None)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="mean request arrival rate (req/s); 0 = submit "
                         "everything up front")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--live-tuning", action="store_true",
                    help="feed decode latencies into a session-local "
                         "LiveTuner overlay")
    args = ap.parse_args(argv)

    model = build_by_name(args.arch, reduced=True)
    params = model.init_params(0)
    s_max = args.s_max or (args.prompt_max + args.max_new)

    # heterogeneous prompts drawn from the synthetic pipeline
    lm = SyntheticLM(DataConfig(vocab=model.cfg.vocab,
                                seq_len=args.prompt_max,
                                global_batch=args.requests, seed=args.seed))
    tokens = np.asarray(lm.next_batch()["tokens"])
    rng = np.random.default_rng(args.seed)
    lengths = rng.integers(2, args.prompt_max + 1, size=args.requests)
    prompts = [tokens[i, :lengths[i]].astype(np.int32)
               for i in range(args.requests)]
    arrivals = (np.zeros(args.requests) if args.rate <= 0 else
                rng.exponential(1.0 / args.rate, args.requests).cumsum())

    tuner = None
    if args.live_tuning:
        from repro.serving.live_tuning import LiveTuner
        tuner = LiveTuner(min_count=1)

    sched = ContinuousBatchingScheduler(
        model, params, slots=args.slots, s_max=s_max,
        temperature=args.temperature, seed=args.seed, tuner=tuner)

    done_at: dict[int, float] = {}
    rid_arrival: dict[int, float] = {}
    nxt = 0
    t0 = time.perf_counter()
    while len(sched.results) < args.requests:
        now = time.perf_counter() - t0
        while nxt < args.requests and arrivals[nxt] <= now:
            try:
                rid = sched.queue.submit(prompts[nxt], args.max_new,
                                         arrival=arrivals[nxt])
            except AdmissionError:
                break                       # backpressure: retry next loop
            rid_arrival[rid] = arrivals[nxt]
            nxt += 1
        busy = sched.step()
        now = time.perf_counter() - t0
        for rid in sched.results:
            done_at.setdefault(rid, now)
        if not busy and nxt < args.requests:
            time.sleep(max(0.0, arrivals[nxt] - now))
    elapsed = time.perf_counter() - t0

    total_tokens = sum(r.tokens.size for r in sched.results.values())
    step_us = [s.decode_us for s in sched.stats if s.active]
    e2e_ms = [1e3 * (done_at[r] - rid_arrival[r]) for r in sched.results]
    print(f"{args.arch}: {args.requests} requests, {args.slots} slots, "
          f"rate={'inf' if args.rate <= 0 else args.rate}/s")
    print(f"  tokens/sec:      {total_tokens / elapsed:10.1f}")
    print(f"  decode step us:  p50 {_pct(step_us, 0.5):8.0f}   "
          f"p99 {_pct(step_us, 0.99):8.0f}")
    print(f"  request e2e ms:  p50 {_pct(e2e_ms, 0.5):8.1f}   "
          f"p99 {_pct(e2e_ms, 0.99):8.1f}")
    print(f"  steps: {len(sched.stats)}  mean batch: "
          f"{np.mean([s.active for s in sched.stats if s.active]):.2f}")
    if tuner is not None:
        k = sched._tuner_key
        from repro.comm.tuning import topo_signature
        est = tuner.estimate("serving", topo_signature(k["pods"], k["chips"]),
                             "float32", k["nbytes"], k["scheme"])
        print(f"  live tuner: serving/{k['scheme']} EWMA {est:.0f} us "
              f"({len(sched.stats)} observations) — overlay has "
              f"{len(tuner.overlay().entries)} entries")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
