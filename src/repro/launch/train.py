"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --reduced --steps 100 --batch 8 --seq 128 --mode hier

On the production fleet the same entry point runs under one process per host
(jax.distributed.initialize); on this container it runs single-process with
however many devices the platform exposes.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.topology import MeshTopology
from repro.data.synthetic import DataConfig
from repro.launch.mesh import make_mesh_from_topo
from repro.runtime.steps import make_train_step
from repro.runtime.train_loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--d-model", type=int, default=64)
    ap.add_argument("--n-layers", type=int, default=2)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mode", default="hier", choices=["hier", "naive"])
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(n_layers=args.n_layers, d_model=args.d_model)

    n_dev = len(jax.devices())
    topo = MeshTopology({"data": n_dev, "model": 1}, slow_axes=())
    mesh = make_mesh_from_topo(topo)
    bundle = make_train_step(cfg, topo, mesh, mode=args.mode, lr=args.lr,
                             compute_dtype=jnp.float32)
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch)
    train(bundle, steps=args.steps, data_cfg=data_cfg, ckpt_dir=args.ckpt,
          save_every=args.save_every)


if __name__ == "__main__":
    main()
