import os
# appended: XLA honors the LAST duplicate flag, and the dry-run's device
# count must win over anything inherited from the environment
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes, dump memory/cost/collective artifacts for the roofline.

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b \
        --shape train_4k --mesh single --mode hier --out experiments/dryrun

The 512 fake host devices exist ONLY in this process (flag set above before
any jax import).  ``.lower().compile()`` succeeding for a cell proves the
sharding + collective program is coherent; ``memory_analysis()`` proves it
fits; ``cost_analysis()`` + HLO collective parsing feed EXPERIMENTS.md.
"""

import argparse   # noqa: E402
import dataclasses  # noqa: E402
import json       # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.analysis.roofline import (CollectiveBytes, extrapolate_cost,  # noqa: E402
                                     parse_collectives, roofline)
from repro.configs import get_config, list_configs  # noqa: E402
from repro.configs.shapes import SHAPES, cell_applicable, get_shape  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.core.topology import multi_pod, single_pod  # noqa: E402
from repro.runtime.steps import (make_serve_steps, make_train_step)  # noqa: E402


def _absify(tree, specs, mesh):
    def mk(l, s):
        return jax.ShapeDtypeStruct(l.shape, l.dtype,
                                    sharding=NamedSharding(mesh, s))
    return jax.tree.map(mk, tree, specs,
                        is_leaf=lambda x: hasattr(x, "shape")
                        and not isinstance(x, P))


def abstract_batch(cfg, shape, mesh, bspec):
    B, T = shape.global_batch, shape.seq_len
    out = {}
    if cfg.frontend == "encodec":
        out["frames"] = jax.ShapeDtypeStruct((B, T, cfg.d_frontend),
                                             jnp.float32)
        out["labels"] = jax.ShapeDtypeStruct((B, T), jnp.int32)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((B, T + 1), jnp.int32)
        if cfg.frontend == "vit":
            out["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.n_prefix, cfg.d_frontend), jnp.float32)
    return _absify(out, bspec, mesh)


def lower_cell(arch: str, shape_name: str, multi: bool, mode: str,
               unroll: int, opts=()):
    cfg = get_config(arch)
    if cfg.moe and any(o.startswith("cap=") for o in opts):
        cf = float([o for o in opts if o.startswith("cap=")][0][4:])
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=cf))
    shape = get_shape(shape_name)
    topo = multi_pod() if multi else single_pod()
    mesh = make_production_mesh(multi_pod=multi)

    if shape.kind == "train":
        bundle = make_train_step(cfg, topo, mesh, mode=mode, unroll=unroll,
                                 opts=opts)
        state_abs = _absify(jax.eval_shape(bundle.init_state),
                            bundle.state_specs, mesh)
        batch_abs = abstract_batch(cfg, shape, mesh, bundle.batch_spec)
        lowered = jax.jit(bundle.fn).lower(state_abs, batch_abs)
        model = bundle.model
    else:
        sb = make_serve_steps(cfg, topo, mesh, mode=mode,
                              global_batch=shape.global_batch,
                              s_max=shape.seq_len, unroll=unroll, opts=opts)
        model = sb.model
        if shape.kind == "prefill":
            params_abs = _mesh_attach(None, sb.prefill_param_specs, mesh,
                                      model, serve=False)
            batch_abs = abstract_batch(cfg, shape, mesh, sb.batch_spec)
            lowered = jax.jit(sb.prefill).lower(params_abs, batch_abs)
        else:  # decode
            params_abs = _mesh_attach(None, sb.param_specs, mesh, model,
                                      serve=True)
            n_dp = 1
            for a in ("pod", "data"):
                if a in topo.axis_sizes:
                    n_dp *= topo.size(a)
            cache_local = jax.eval_shape(
                lambda: model.cache_init(sb.b_loc, sb.s_max))
            shard_b = shape.global_batch % n_dp == 0 \
                and shape.global_batch >= n_dp
            dp_n = n_dp if shard_b else 1
            tp_n = topo.size("model")

            def cache_abs(l, s):
                return jax.ShapeDtypeStruct((dp_n, tp_n) + l.shape, l.dtype,
                                            sharding=NamedSharding(mesh, s))
            cache = jax.tree.map(cache_abs, cache_local, sb.cache_spec)
            B = shape.global_batch
            if cfg.frontend == "encodec":
                tok = jax.ShapeDtypeStruct((B, 1, cfg.d_frontend),
                                           jnp.float32)
            else:
                tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
            tok = jax.tree.map(
                lambda l: jax.ShapeDtypeStruct(
                    l.shape, l.dtype, sharding=NamedSharding(
                        mesh, P(("pod", "data") if (multi and shard_b) else
                                ("data",) if shard_b else None))), tok)
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = jax.jit(sb.decode).lower(params_abs, cache, tok, pos)
    return lowered, model, topo, mesh


def _mesh_attach(_, specs, mesh, model, serve: bool):
    return model.abstract_params(
        jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                     is_leaf=lambda x: isinstance(x, P)), serve=serve)


def run_cell(arch: str, shape_name: str, multi: bool, mode: str,
             out_dir: str, opts=()) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    rec = {"arch": arch, "shape": shape_name, "opts": list(opts),
           "mesh": "multi" if multi else "single", "mode": mode}
    if not cell_applicable(arch, shape_name):
        rec["status"] = "skip"
        rec["reason"] = ("full-attention arch: 500k dense-KV decode is "
                         "architecturally out of scope (DESIGN.md §5)")
        return rec
    try:
        t0 = time.time()
        lowered_a, model, topo, mesh = lower_cell(arch, shape_name, multi,
                                                  mode, unroll=1, opts=opts)
        compiled_a = lowered_a.compile()
        rec["compile_s"] = round(time.time() - t0, 1)
        ca = compiled_a.cost_analysis() or {}
        ma = compiled_a.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
        }
        rec["cost_a"] = {"flops": float(ca.get("flops", 0.0)),
                         "bytes": float(ca.get("bytes accessed", 0.0))}
        pod_chips = topo.chips_per_pod
        coll_a = parse_collectives(compiled_a.as_text(),
                                   num_devices=topo.num_devices,
                                   pod_size=pod_chips)

        # B lowering (unroll=2) for the loop extrapolation
        n_units = cfg.n_units
        if n_units >= 2 and n_units % 2 == 0:
            lowered_b, *_ = lower_cell(arch, shape_name, multi, mode,
                                       unroll=2, opts=opts)
            compiled_b = lowered_b.compile()
            cb = compiled_b.cost_analysis() or {}
            rec["cost_b"] = {"flops": float(cb.get("flops", 0.0)),
                             "bytes": float(cb.get("bytes accessed", 0.0))}
            coll_b = parse_collectives(compiled_b.as_text(),
                                       num_devices=topo.num_devices,
                                       pod_size=pod_chips)
            flops, bytes_ = extrapolate_cost(
                {"flops": rec["cost_a"]["flops"],
                 "bytes accessed": rec["cost_a"]["bytes"]},
                {"flops": rec["cost_b"]["flops"],
                 "bytes accessed": rec["cost_b"]["bytes"]}, n_units)
            coll = CollectiveBytes.combine(coll_a, coll_b, n_units)
        else:
            flops, bytes_ = rec["cost_a"]["flops"], rec["cost_a"]["bytes"]
            coll = coll_a

        B, T = shape.global_batch, shape.seq_len
        n_active = cfg.active_param_count()
        if shape.kind == "train":
            model_flops = 6.0 * n_active * B * T
            notes = model.cost_notes(kind="train", B=B, T=T)
        elif shape.kind == "prefill":
            model_flops = 2.0 * n_active * B * T
            notes = model.cost_notes(kind="prefill", B=B, T=T)
        else:
            model_flops = 2.0 * n_active * B  # one token per sequence
            notes = model.cost_notes(kind="decode", B=B, T=T)

        terms = roofline(flops_per_dev=flops, bytes_per_dev=bytes_,
                         coll=coll, chips=topo.num_devices, notes=notes,
                         model_flops=model_flops)
        rec["collectives"] = {"fast_bytes_per_dev": coll.fast,
                              "slow_bytes_per_dev": coll.slow,
                              "by_op": coll.by_op}
        rec["roofline"] = terms.to_dict()
        rec["n_units"] = n_units
        rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-3000:]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--mode", default="hier", choices=["hier", "naive",
                                                       "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--opts", default="",
                    help="comma list: bf16_rope,bf16_xent,decode2d,...")
    args = ap.parse_args()
    opts = tuple(o for o in args.opts.split(",") if o)

    archs = list_configs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    modes = ["hier", "naive"] if args.mode == "both" else [args.mode]

    os.makedirs(args.out, exist_ok=True)
    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                for mode in modes:
                    tag = (f"{arch}__{shape}__"
                           f"{'multi' if multi else 'single'}__{mode}")
                    path = os.path.join(args.out, tag + ".json")
                    if os.path.exists(path):
                        rec = json.load(open(path))
                        if rec.get("status") in ("ok", "skip"):
                            print(f"CACHED {tag}: {rec['status']}")
                            continue
                    rec = run_cell(arch, shape, multi, mode, args.out,
                                   opts=opts)
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    msg = rec["status"]
                    if rec["status"] == "ok":
                        r = rec["roofline"]
                        msg += (f" compile={rec['compile_s']}s"
                                f" dom={r['dominant']}"
                                f" comp={r['compute_s']*1e3:.1f}ms"
                                f" mem={r['memory_s']*1e3:.1f}ms"
                                f" coll={r['collective_s']*1e3:.1f}ms"
                                f" frac={r['roofline_fraction']:.2f}")
                    elif rec["status"] == "fail":
                        n_fail += 1
                        msg += " " + rec["error"][:200]
                    print(f"{tag}: {msg}", flush=True)
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
