"""Production meshes.  Functions only — importing this module never touches
jax device state (the dry-run sets the fake-device flag first)."""

from __future__ import annotations

from repro.comm import Communicator
from repro.core.topology import MeshTopology, multi_pod, single_pod
from repro.substrate.compat import make_mesh


def communicator_for_topo(topo: MeshTopology) -> Communicator:
    """The production two-tier communicator of a topology: fast tier =
    intra-pod axes (ICI), slow tier = the pod axes (DCN).  Pair with
    ``make_mesh_from_topo`` so mesh and communicator can never disagree on
    the tier split."""
    return Communicator.from_topology(topo)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh_from_topo(topo: MeshTopology):
    names = topo.axis_names()
    shape = tuple(topo.axis_sizes[a] for a in names)
    return make_mesh(shape, names)


def topo_for(*, multi_pod_flag: bool) -> MeshTopology:
    return multi_pod() if multi_pod_flag else single_pod()


def small_topo(pods: int = 2, data: int = 2, model: int = 2) -> MeshTopology:
    """Test-scale topology (8 fake CPU devices)."""
    if pods > 1:
        return MeshTopology({"pod": pods, "data": data, "model": model})
    return MeshTopology({"data": data, "model": model})
