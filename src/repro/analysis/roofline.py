"""Roofline terms from the compiled dry-run artifact.

Three terms per (arch x shape x mesh), TPU v5e constants:

  compute    = HLO_FLOPs / (chips * 197e12)
  memory     = HLO_bytes / (chips * 819e9)
  collective = link_bytes(fast tier) / (chips * ICI_bw)
               + link_bytes(slow tier) / (chips * DCN_bw)

Sources: ``compiled.cost_analysis()`` (flops / bytes accessed) and the
optimized HLO text (collective ops).  Two corrections are applied:

* **loop-body undercount** — cost analysis counts while-loop bodies once; we
  lower the step at scan ``unroll=1`` (A) and ``unroll=2`` (B) and
  extrapolate: per-unit u = B - A, outside = 2A - B, total = outside + n*u.
* **inner sequential scans** (flash KV blocks, sLSTM time steps, xent chunks)
  are invisible to the unroll trick; the model supplies analytic notes
  (``Model.cost_notes``) that are added to the compute/memory terms.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

import numpy as np

from repro.core.topology import (DCN_BW_PER_HOST, HBM_BW, ICI_BW_PER_LINK,
                                 PEAK_FLOPS_BF16)

DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
               "f32": 4, "s32": 4, "u32": 4, "f16": 2, "bf16": 2,
               "s16": 2, "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
               "s8": 1, "u8": 1, "pred": 1}

COLL_RE = re.compile(
    r"=\s*(?P<shape>\(?[a-z0-9\[\],{}:/*= ]+?\)?)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<suffix>-start|-done)?\(")
SHAPE_RE = re.compile(r"(?P<dt>[a-z0-9]+)\[(?P<dims>[0-9,]*)\]")
GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in SHAPE_RE.finditer(type_str):
        dt = m.group("dt")
        if dt not in DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _first_group(line: str, num_devices: int) -> tuple[int, list[int]]:
    """(group_size, first group's device ids)."""
    m = GROUPS_BRACE_RE.search(line)
    if m:
        ids = [int(x) for x in m.group(1).split(",")]
        return len(ids), ids
    m = GROUPS_IOTA_RE.search(line)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        arr = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            arr = arr.transpose(perm)
        rows = arr.reshape(g, s)
        return s, rows[0].tolist()
    return num_devices, list(range(num_devices))


@dataclasses.dataclass
class CollectiveBytes:
    """Per-chip link bytes by tier (each device's share of the traffic)."""
    fast: float = 0.0   # intra-pod ICI
    slow: float = 0.0   # cross-pod DCN
    by_op: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "CollectiveBytes", scale: float = 1.0):
        self.fast += other.fast * scale
        self.slow += other.slow * scale
        for k, v in other.by_op.items():
            self.by_op[k] = self.by_op.get(k, 0.0) + v * scale
        return self

    @staticmethod
    def combine(a: "CollectiveBytes", b: "CollectiveBytes", n_units: int
                ) -> "CollectiveBytes":
        """A/B unroll extrapolation: out + n*(B-A)."""
        out = CollectiveBytes()
        out.add(a, 2.0).add(b, -1.0)            # outside = 2A - B
        out.add(b, float(n_units)).add(a, -float(n_units))
        out.fast = max(out.fast, 0.0)
        out.slow = max(out.slow, 0.0)
        return out


def parse_collectives(hlo: str, *, num_devices: int,
                      pod_size: Optional[int] = None) -> CollectiveBytes:
    """Sum per-chip link bytes of every collective in the (already SPMD-
    partitioned) HLO module.  Ring-model cost per chip:
      all-gather: out*(n-1)/n ; reduce-scatter: out*(n-1) (out = in/n);
      all-reduce: 2*out*(n-1)/n ; all-to-all: out*(n-1)/n ; permute: out.
    A collective whose group spans pods is charged to the slow tier.
    """
    out = CollectiveBytes()
    for line in hlo.splitlines():
        m = COLL_RE.search(line)
        if not m or m.group("suffix") == "-done":
            continue
        op = m.group("op")
        bytes_out = _shape_bytes(m.group("shape"))
        n, ids = _first_group(line, num_devices)
        if n <= 1:
            continue
        if op == "all-gather":
            link = bytes_out * (n - 1) / n
        elif op == "reduce-scatter":
            link = bytes_out * (n - 1)
        elif op == "all-reduce":
            link = 2.0 * bytes_out * (n - 1) / n
        elif op == "all-to-all":
            link = bytes_out * (n - 1) / n
        else:  # collective-permute
            link = float(bytes_out)
        cross = (pod_size is not None
                 and len({i // pod_size for i in ids}) > 1)
        key = f"{op}{'/slow' if cross else ''}"
        out.by_op[key] = out.by_op.get(key, 0.0) + link
        if cross:
            out.slow += link
        else:
            out.fast += link
    return out


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    fast_coll_s: float
    slow_coll_s: float
    hlo_flops: float
    hlo_bytes: float
    model_flops: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """compute / max-term: 1.0 = compute-bound at peak."""
        bound = max(self.compute_s, self.memory_s, self.collective_s, 1e-30)
        return self.compute_s / bound

    def to_dict(self) -> dict:
        return {**dataclasses.asdict(self), "dominant": self.dominant,
                "useful_flops_ratio": self.useful_flops_ratio,
                "roofline_fraction": self.roofline_fraction}


def extrapolate_cost(cost_a: dict, cost_b: dict, n_units: int
                     ) -> tuple[float, float]:
    """(flops, bytes) per device: outside + n_units * per_unit."""
    def one(key):
        a = float(cost_a.get(key, 0.0))
        b = float(cost_b.get(key, 0.0))
        u = max(b - a, 0.0)
        return max(2 * a - b, 0.0) + n_units * u
    return one("flops"), one("bytes accessed")


def roofline(*, flops_per_dev: float, bytes_per_dev: float,
             coll: CollectiveBytes, chips: int, notes: dict,
             model_flops: float, ici_links: int = 4) -> RooflineTerms:
    """All *_per_dev quantities are per-device (cost_analysis of the SPMD
    module is per-device); notes are GLOBAL analytic corrections."""
    flops = flops_per_dev + notes.get("flops", 0.0) / chips
    bytes_ = bytes_per_dev + notes.get("bytes", 0.0) / chips
    fast_s = coll.fast / (ici_links * ICI_BW_PER_LINK)
    slow_s = coll.slow / DCN_BW_PER_HOST
    return RooflineTerms(
        compute_s=flops / PEAK_FLOPS_BF16,
        memory_s=bytes_ / HBM_BW,
        collective_s=fast_s + slow_s,
        fast_coll_s=fast_s, slow_coll_s=slow_s,
        hlo_flops=flops, hlo_bytes=bytes_, model_flops=model_flops / chips)
