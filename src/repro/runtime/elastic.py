"""ElasticRuntime: fault injection, communicator rebuild, checkpointed
recovery — the kill -> rebuild -> re-tune -> resume path, end to end.

The paper's two-tier design makes failure NODE-granular: one shared copy per
node plus a bridge tier means losing a host removes exactly one bridge
participant and one shared window, never an arbitrary slice of ranks.  This
runtime exploits that:

1. **Fault injection** — a ``FaultPlan`` scripts deterministic failures
   keyed by step (pod loss, host slowdown feeding the straggler watchdog,
   torn checkpoints), injected in-process over any ``VirtualCluster`` of
   the topology matrix.  A new failure kind is ONE ``@register_event``
   registration: the handler gets the runtime and the event, nothing else
   changes.
2. **Communicator rebuild** — on pod loss the runtime shrinks the cluster
   (``VirtualCluster.without_pod``: the slow tier loses one extent) and
   rebuilds the world communicator via ``Communicator.from_cluster`` — the
   blessed constructor, so static pods/chips counts (rank maps, tuning
   signatures) are always filled in (enforced by
   ``scripts/check_api_surface.py``).
3. **Re-tune** — the new topology signature re-resolves ``scheme="auto"``
   against the tuning table (``repro.comm.tuning.retune_for``): measured
   entries where the bench swept the surviving shape, modeled closed forms
   where it did not — logged per family into the recovery record, never a
   crash.
4. **Re-record** — rebuilding the step function re-traces the train step,
   and with the ``stepgraph`` opt (the default here) the whole collective
   schedule is re-recorded through ``Communicator.record()`` and rewritten
   for the surviving topology — the post-shrink schedule is just a new
   graph through the same three passes.
5. **Resume** — state restores from ``checkpoint/`` re-sharded onto the new
   mesh (the checkpoint layout is logical; ``shardings=`` does the
   re-shard), with torn newest steps discarded with a warning and saves
   from the aborted timeline invalidated (``Checkpointer.discard_after``).

Recovery is *provably* clean: the continued loss trajectory is bit-identical
to a reference run that STARTED on the shrunk topology at the restored step
(``reference_run``) — asserted over the topology matrix in the slow test
lane (tests/test_elastic.py).
"""

from __future__ import annotations

import dataclasses
import logging
import os
import re
import warnings
from typing import Callable, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.checkpoint.checkpointer import Checkpointer
from repro.comm import Communicator
from repro.comm import tuning
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.runtime.fault_tolerance import RestartManager, StragglerPolicy
from repro.runtime.steps import make_cluster_train_step

logger = logging.getLogger("repro.runtime.elastic")


# ---------------------------------------------------------------------------
# Fault events: the injection grammar
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scripted failure.  ``kind`` selects the registered handler;
    ``step`` is the train step it fires at (before the step executes — a
    pod lost "mid-step" aborts that step's work, exactly like a real
    preemption tearing down the collective).  The remaining fields are the
    kind's parameters; unused ones keep their defaults."""

    kind: str
    step: int
    pod: int = -1          # pod_loss: which node dies (-1 = last)
    host: int = -1         # host_slowdown: which host drags
    factor: float = 4.0    # host_slowdown: step-time multiplier
    duration: int = 8      # host_slowdown: steps the slowdown lasts

    # -- constructors (the event grammar) ------------------------------------
    @classmethod
    def pod_loss(cls, step: int, pod: int = -1) -> "FaultEvent":
        return cls(kind="pod_loss", step=step, pod=pod)

    @classmethod
    def host_slowdown(cls, step: int, host: int, *, factor: float = 4.0,
                      duration: int = 8) -> "FaultEvent":
        return cls(kind="host_slowdown", step=step, host=host,
                   factor=factor, duration=duration)

    @classmethod
    def torn_checkpoint(cls, step: int) -> "FaultEvent":
        return cls(kind="torn_checkpoint", step=step)


#: kind -> handler(runtime, event).  A handler either mutates runtime
#: bookkeeping (slowdowns, disk corruption) or raises ``PodLost`` to enter
#: the recovery path.  Registering here is ALL a new failure kind needs.
EVENT_HANDLERS: dict[str, Callable] = {}


def register_event(kind: str):
    def deco(fn):
        EVENT_HANDLERS[kind] = fn
        return fn
    return deco


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic failure script: events fire when the loop reaches
    their step, each exactly once (recovery replays the steps between the
    restored checkpoint and the failure — a consumed event must not fire
    again on the replay)."""

    events: tuple[FaultEvent, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))
        for ev in self.events:
            if ev.kind not in EVENT_HANDLERS:
                raise ValueError(
                    f"unknown fault kind {ev.kind!r}: registered kinds are "
                    f"{sorted(EVENT_HANDLERS)}")
            if ev.step < 0:
                raise ValueError(f"event step must be >= 0, got {ev.step}")

    def pending(self, step: int, fired: set) -> list[tuple[int, FaultEvent]]:
        return [(i, ev) for i, ev in enumerate(self.events)
                if ev.step == step and i not in fired]


class PodLost(Exception):
    """Control-flow signal: a node died (scripted or straggler-evicted);
    unwind to the recovery path."""

    def __init__(self, pod: int, cause: str):
        super().__init__(f"pod {pod} lost ({cause})")
        self.pod = pod
        self.cause = cause


@register_event("pod_loss")
def _on_pod_loss(rt: "ElasticRuntime", ev: FaultEvent) -> None:
    raise PodLost(ev.pod, "pod_loss")


@register_event("host_slowdown")
def _on_host_slowdown(rt: "ElasticRuntime", ev: FaultEvent) -> None:
    rt._slowdowns.append(ev)
    logger.info("step %d: host %d slows %.1fx for %d steps", ev.step,
                ev.host, ev.factor, ev.duration)


@register_event("torn_checkpoint")
def _on_torn_checkpoint(rt: "ElasticRuntime", ev: FaultEvent) -> None:
    rt._tear_newest_checkpoint()


# ---------------------------------------------------------------------------
# Reports
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RecoveryRecord:
    """One completed kill -> rebuild -> re-tune -> resume cycle."""

    trigger_step: int                 # step whose execution was aborted
    cause: str                        # "pod_loss" | "straggler"
    lost_pod: int
    old_label: str
    new_label: str
    old_signature: str
    new_signature: str
    restored_step: int                # checkpoint the run resumed from
    torn_discarded: tuple[int, ...]   # torn steps skipped by the restore
    stale_dropped: tuple[int, ...]    # aborted-timeline saves invalidated
    retune: tuning.RetuneReport       # scheme="auto" on the new signature


@dataclasses.dataclass
class ElasticReport:
    """What the supervised run did.  ``losses`` maps step -> loss; steps
    replayed after a recovery overwrite their pre-failure entries, so the
    map always holds the SURVIVING trajectory (the one bit-identical to a
    reference run on the final topology)."""

    losses: dict
    recoveries: tuple
    start_step: int
    final_step: int
    cluster_label: str
    signature: str
    state: object = None

    def loss_trajectory(self, from_step: int = 0) -> list[float]:
        return [self.losses[s] for s in sorted(self.losses)
                if s >= from_step]


# ---------------------------------------------------------------------------
# The runtime
# ---------------------------------------------------------------------------

class ElasticRuntime:
    """Owns the live ``VirtualCluster`` + ``Communicator`` + active tuning
    resolution and drives supervised training through scripted faults.

    The step function comes from ``runtime.steps.make_cluster_train_step``
    (``opts=("stepgraph",)`` by default, so every rebuild re-records the
    step's collective schedule through ``Communicator.record()``).  One
    host == one pod here — node-granular failure, per the paper's layout.
    """

    RETUNE_FAMILIES = ("psum", "allgather")

    def __init__(self, cfg, cluster, *, ckpt_dir: str,
                 plan: Optional[FaultPlan] = None, mode: str = "hier",
                 opts=("stepgraph",), global_batch: int = 8, seq: int = 16,
                 lr: float = 1e-3, save_every: int = 2, keep: int = 10,
                 seed: int = 0, data_seed: int = 1234, unroll: int = 1,
                 straggler_factory: Optional[Callable[[], StragglerPolicy]]
                 = None):
        self.cfg = cfg
        self.mode = mode
        self.opts = tuple(opts)
        self.global_batch = global_batch
        self.seq = seq
        self.lr = lr
        self.seed = seed
        self.data_seed = data_seed
        self.unroll = unroll
        self.ckpt = Checkpointer(ckpt_dir, keep=keep)
        self.mgr = RestartManager(self.ckpt, save_every=save_every)
        self.plan = plan if plan is not None else FaultPlan()
        self._fired: set[int] = set()
        self._slowdowns: list[FaultEvent] = []
        self._straggler_factory = straggler_factory or StragglerPolicy
        self.recoveries: list[RecoveryRecord] = []
        self._build(cluster)

    # -- build / rebuild -----------------------------------------------------
    def _build(self, vc) -> None:
        """(Re)build every topology-dependent piece for ``vc``: the world
        communicator (via ``from_cluster`` — never the bare constructor),
        the step function (re-traced, step graph re-recorded), the restore
        shardings, the straggler watchdog (host ids renumber with the
        survivors, so the policy starts a fresh epoch), and the
        ``scheme="auto"`` re-resolution report for the new signature."""
        self.cluster = vc
        self.comm = Communicator.from_cluster(vc)
        self.bundle = make_cluster_train_step(
            self.cfg, vc, mode=self.mode, lr=self.lr, unroll=self.unroll,
            global_batch=self.global_batch, opts=self.opts)
        self.step_fn = jax.jit(self.bundle.fn)
        self.shardings = jax.tree.map(
            lambda spec: NamedSharding(vc.mesh, spec),
            self.bundle.state_specs,
            is_leaf=lambda s: isinstance(s, P))
        self.straggler = self._straggler_factory()
        self._slowdowns = []
        pshapes = jax.eval_shape(lambda: self.bundle.model.init_params(0))
        sizes = sorted({int(np.prod(l.shape)) or 1
                        for l in jax.tree.leaves(pshapes)})
        elems = tuple(dict.fromkeys((1, sizes[0], sizes[-1])))
        self.retuned = tuning.retune_for(self.comm, self.RETUNE_FAMILIES,
                                         elems)

    # -- checkpoint plumbing -------------------------------------------------
    def _data_cfg(self) -> DataConfig:
        return DataConfig(vocab=self.cfg.vocab, seq_len=self.seq,
                          global_batch=self.global_batch,
                          seed=self.data_seed)

    def _restore(self, *, max_step: Optional[int] = None):
        """(state, start_step, torn_discarded): restore the newest intact
        checkpoint at step <= ``max_step`` re-sharded onto the CURRENT
        mesh, or init fresh when none exists.  Torn steps the checkpointer
        discarded are surfaced for the recovery record."""
        if self.ckpt.latest_step() is None and max_step is None:
            state = jax.device_put(self.bundle.init_state(self.seed),
                                   self.shardings)
            return state, 0, ()
        template = jax.eval_shape(lambda: self.bundle.init_state(self.seed))
        zeros = jax.tree.map(lambda s: np.zeros(s.shape, s.dtype), template)
        with warnings.catch_warnings(record=True) as wlog:
            warnings.simplefilter("always")
            state, start = self.ckpt.restore(zeros, step=max_step,
                                             shardings=self.shardings)
        discarded = []
        for w in wlog:
            m = re.search(r"checkpoint step (\d+) is torn", str(w.message))
            if m:
                discarded.append(int(m.group(1)))
                logger.warning("%s", w.message)
        return state, start, tuple(discarded)

    def _tear_newest_checkpoint(self) -> None:
        """Fault injection: corrupt the newest committed step on disk
        (truncated shard file — a writer that died after commit, or media
        corruption).  The next restore must discard it with a warning and
        fall back to the previous intact step."""
        self.ckpt.wait()
        step = self.ckpt.latest_step()
        if step is None:
            return
        path = os.path.join(self.ckpt.root, f"step_{step:08d}",
                            "shard_0.npz")
        with open(path, "wb") as f:
            f.write(b"torn")
        logger.warning("fault injection: tore checkpoint step %d (%s)",
                       step, path)

    # -- failure detection ---------------------------------------------------
    def _heartbeat(self, step: int) -> dict[int, float]:
        """Synthetic per-host step times (base 1.0) with active scripted
        slowdowns applied — what a real fleet's heartbeat transport would
        deliver; the decision logic downstream is identical."""
        times = {}
        for h in range(self.cluster.pods):
            f = 1.0
            for ev in self._slowdowns:
                if ev.host == h and ev.step <= step < ev.step + ev.duration:
                    f = max(f, ev.factor)
            times[h] = f
        return times

    # -- recovery ------------------------------------------------------------
    def _recover(self, failure: PodLost, *, at_step: int):
        """The full recovery path.  Returns (state, resume_step, stream)."""
        self.ckpt.wait()   # land (or surface) the in-flight save first
        old_label = self.cluster.label
        old_sig = self.comm.signature
        survivor = self.cluster.without_pod(failure.pod)
        logger.warning("step %d: %s — rebuilding %s -> %s", at_step,
                       failure, old_label, survivor.label)
        self._build(survivor)
        state, start, torn = self._restore()
        stale = self.ckpt.discard_after(start)
        self.recoveries.append(RecoveryRecord(
            trigger_step=at_step, cause=failure.cause, lost_pod=failure.pod,
            old_label=old_label, new_label=survivor.label,
            old_signature=old_sig, new_signature=self.comm.signature,
            restored_step=start, torn_discarded=torn,
            stale_dropped=tuple(stale), retune=self.retuned))
        logger.warning(
            "recovered: signature %s -> %s, resumed step %d, retune "
            "sources %s", old_sig, self.comm.signature, start,
            self.retuned.sources)
        stream = SyntheticLM(self._data_cfg(), start_step=start)
        return state, start, stream

    # -- the supervised loop -------------------------------------------------
    def run(self, steps: int, *, from_step: Optional[int] = None,
            save: bool = True) -> ElasticReport:
        """Train to ``steps``, surviving the fault plan.

        ``from_step`` pins the starting checkpoint (a reference run
        starting mid-trajectory); ``save=False`` makes the run read-only on
        the checkpoint directory (a reference run must not overwrite the
        run under test)."""
        state, start, _ = self._restore(max_step=from_step)
        stream = SyntheticLM(self._data_cfg(), start_step=start)
        losses: dict[int, float] = {}
        step = start
        while step < steps:
            try:
                for idx, ev in self.plan.pending(step, self._fired):
                    self._fired.add(idx)
                    EVENT_HANDLERS[ev.kind](self, ev)
                evicted = self.straggler.observe(self._heartbeat(step))
                if evicted:
                    raise PodLost(evicted[0], "straggler")
                batch = stream.next_batch()
                state, metrics = self.step_fn(state, batch)
                losses[step] = float(metrics["loss"])
                step += 1
                if save:
                    self.mgr.maybe_save(step, state)
            except PodLost as failure:
                state, step, stream = self._recover(failure, at_step=step)
        if save:
            self.ckpt.save(steps, state, blocking=True)
        return ElasticReport(losses=losses,
                             recoveries=tuple(self.recoveries),
                             start_step=start, final_step=steps,
                             cluster_label=self.cluster.label,
                             signature=self.comm.signature, state=state)


def reference_run(cfg, cluster, *, ckpt_dir: str, from_step: int,
                  steps: int, **kw) -> ElasticReport:
    """The bit-identity oracle: a fresh run that STARTS on ``cluster`` (the
    post-failure topology) at ``from_step``, restoring the same pinned
    checkpoint and training forward with no faults and no saves.  A
    recovered ``ElasticRuntime`` run must match its loss trajectory
    bit-for-bit from ``from_step`` on."""
    rt = ElasticRuntime(cfg, cluster, ckpt_dir=ckpt_dir, **kw)
    return rt.run(steps, from_step=from_step, save=False)
