"""End-to-end training loop: data -> step -> metrics -> checkpoint/restart.

Works at any scale: single CPU device (examples), 8 fake devices (tests), or
the production mesh (dry-run lowering).  Fault tolerance is exercised by
killing and re-entering ``train()`` — it resumes from the newest checkpoint
with the data stream fast-forwarded (the stream is a pure function of step).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.runtime.fault_tolerance import RestartManager, StragglerPolicy


@dataclasses.dataclass
class TrainReport:
    steps: int
    final_loss: float
    losses: list
    step_times: list
    resumed_from: int
    state: object = None


def train(bundle, *, steps: int, data_cfg: DataConfig,
          ckpt_dir: Optional[str] = None, save_every: int = 50,
          log_every: int = 10, seed: int = 0,
          on_step: Optional[Callable] = None) -> TrainReport:
    step_fn = jax.jit(bundle.fn, donate_argnums=(0,))
    start = 0
    if ckpt_dir:
        mgr = RestartManager(Checkpointer(ckpt_dir), save_every=save_every)
        state, start = mgr.resume_or_init(lambda: bundle.init_state(seed))
    else:
        mgr = None
        state = bundle.init_state(seed)

    stream = SyntheticLM(data_cfg, start_step=start)
    straggler = StragglerPolicy()
    losses, times = [], []
    t_total = time.time()
    for step in range(start, steps):
        batch = stream.next_batch()
        t0 = time.time()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        losses.append(loss)
        times.append(dt)
        straggler.observe({0: dt})
        if mgr:
            mgr.maybe_save(step + 1, state)
        if on_step:
            on_step(step, metrics)
        if log_every and (step % log_every == 0 or step == steps - 1):
            print(f"step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['gnorm']):7.3f} {dt*1e3:7.1f} ms",
                  flush=True)
    if mgr:
        mgr.ckpt.save(steps, state, blocking=True)
    print(f"trained {steps - start} steps in {time.time()-t_total:.1f}s")
    return TrainReport(steps=steps, final_loss=losses[-1] if losses else
                       float("nan"), losses=losses, step_times=times,
                       resumed_from=start, state=state)


def train_elastic(cfg, cluster, *, steps: int, ckpt_dir: str, plan=None,
                  **kw):
    """Supervised elastic training over a ``VirtualCluster``: the
    ``ElasticRuntime`` loop (fault injection, communicator rebuild,
    tuning re-resolution, checkpointed recovery) behind a one-call entry
    point.  Returns an ``ElasticReport``; extra kwargs go to the runtime
    (``global_batch``, ``seq``, ``save_every``, ``opts``, ...)."""
    from repro.runtime.elastic import ElasticRuntime
    rt = ElasticRuntime(cfg, cluster, ckpt_dir=ckpt_dir, plan=plan, **kw)
    return rt.run(steps)
