"""Fault tolerance & elasticity: restart manager, straggler watchdog,
elastic mesh rebuild.

What runs for real on this CPU container: checkpoint/restart (exercised in
tests and examples), the straggler EWMA policy (driven with recorded step
times), and elastic re-sharding between the (2,16,16) and (16,16) meshes
(dry-run tested).  What a real fleet adds is only transport: heartbeats over
DCN and a coordinator — the decision logic is all here.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.checkpoint.checkpointer import Checkpointer


# ---------------------------------------------------------------------------
# Straggler detection (per-host step-time EWMA vs fleet median)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StragglerPolicy:
    """Flags hosts whose EWMA step time exceeds ``threshold`` x the fleet
    median for ``patience`` consecutive steps.  On a synchronous-SPMD fleet
    one slow host gates every step, so the mitigation is replacement
    (re-pool a hot spare) or eviction + elastic shrink — both are surfaced
    as actions for the launcher."""
    alpha: float = 0.2
    threshold: float = 1.5
    patience: int = 5

    def __post_init__(self):
        self.ewma: dict[int, float] = {}
        self.strikes: dict[int, int] = {}
        self.evicted: set[int] = set()

    def observe(self, step_times: dict[int, float]) -> list[int]:
        """step_times: host_id -> wall seconds for this step.  Returns hosts
        to evict/replace.  Each host is returned at most once: its EWMA and
        strike state are dropped on eviction so a dead host neither inflates
        the fleet median nor gets re-flagged every call."""
        for h, t in step_times.items():
            if h in self.evicted:
                continue
            prev = self.ewma.get(h, t)
            self.ewma[h] = (1 - self.alpha) * prev + self.alpha * t
        if not self.ewma:
            return []
        med = float(np.median(list(self.ewma.values())))
        evict = []
        for h, e in self.ewma.items():
            if e > self.threshold * med:
                self.strikes[h] = self.strikes.get(h, 0) + 1
                if self.strikes[h] >= self.patience:
                    evict.append(h)
            else:
                self.strikes[h] = 0
        for h in evict:
            self.evicted.add(h)
            self.ewma.pop(h, None)
            self.strikes.pop(h, None)
        return evict


# ---------------------------------------------------------------------------
# Elastic topology: rebuild the mesh from surviving resources
# ---------------------------------------------------------------------------

def elastic_topology(n_chips: int, *, model: int | None = None, prev=None):
    """Largest (pod, data, model) topology that fits ``n_chips``: model is
    fixed (TP degree is a model property), pods shrink first, then data.

    The model degree is derived from ``prev`` — the topology the run was on
    before the failure — so a run launched with any TP degree keeps it
    through every shrink; an explicit ``model=`` overrides, and only with
    neither does the production default of 16 apply.  Survivors that do not
    factor into whole model groups are an ERROR naming the stranded chips
    (silently dropping them would strand live hardware *and* change the
    data-parallel arithmetic without anyone deciding to): the caller evicts
    down to a clean multiple or re-pools a spare.

    Returns a MeshTopology; raises if fewer than one model group survives.
    """
    from repro.core.topology import MeshTopology
    if model is None:
        if prev is not None and "model" in prev.axis_sizes:
            model = prev.size("model")
        else:
            model = 16
    if n_chips < model:
        raise ValueError(f"need >= {model} chips, have {n_chips}")
    stranded = n_chips % model
    if stranded:
        raise ValueError(
            f"{stranded} stranded chip(s): {n_chips} survivors do not "
            f"factor into model={model} groups ({n_chips // model} whole "
            f"groups + {stranded} extra) — evict down to "
            f"{n_chips - stranded} chips or re-pool {model - stranded} "
            "spares")
    data = n_chips // model
    pods = 1
    # prefer 256-chip pods (16 data x 16 model), extras become pods
    if data >= 32 and data % 16 == 0:
        pods, data = data // 16, 16
    if pods > 1:
        return MeshTopology({"pod": pods, "data": data, "model": model})
    return MeshTopology({"data": data, "model": model})


# ---------------------------------------------------------------------------
# Restart manager
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RestartManager:
    """Drives the save/restore cycle: periodic async saves, resume from the
    newest intact checkpoint after a crash, re-shard on a changed mesh."""
    ckpt: Checkpointer
    save_every: int = 100

    def maybe_save(self, step: int, state) -> None:
        if step % self.save_every == 0 and step > 0:
            self.ckpt.save(step, state)

    def resume_or_init(self, init_fn: Callable[[], object], *,
                       shardings=None):
        """Returns (state, start_step)."""
        import jax
        step = self.ckpt.latest_step()
        if step is None:
            return init_fn(), 0
        template = jax.eval_shape(init_fn)
        # pin the step we validated: a concurrent save landing between
        # latest_step() and restore() must not switch the checkpoint under us
        state, step = self.ckpt.restore(
            jax.tree.map(lambda s: np.zeros(s.shape, s.dtype), template),
            step=step, shardings=shardings)
        return state, step
